//! Ablation semantics (Fig. 9 backing tests): every optimization knob
//! must preserve correctness and move resources in the documented
//! direction.

use spada::harness::common::{run_reduce, run_stencil};
use spada::passes::Options;

#[test]
fn copy_elim_reduces_memory_and_cycles() {
    let k = 512;
    let (with, _) = run_reduce("two_phase_reduce", 8, 4, k, &Options::default()).unwrap();
    let (without, _) = run_reduce(
        "two_phase_reduce",
        8,
        4,
        k,
        &Options { copy_elim: false, ..Options::default() },
    )
    .unwrap();
    assert!(
        without.stats.mem_bytes_max > with.stats.mem_bytes_max,
        "mem: {} vs {}",
        with.stats.mem_bytes_max,
        without.stats.mem_bytes_max
    );
    assert!(
        without.report.cycles > with.report.cycles,
        "cycles: {} vs {}",
        with.report.cycles,
        without.report.cycles
    );
}

#[test]
fn recycling_reduces_task_ids() {
    let (with, _) = run_reduce("tree_reduce", 16, 16, 64, &Options::default()).unwrap();
    let (without, _) = run_reduce(
        "tree_reduce",
        16,
        16,
        64,
        &Options { recycling: false, ..Options::default() },
    )
    .unwrap();
    assert!(
        without.stats.hw_task_ids > with.stats.hw_task_ids,
        "task IDs: {} vs {}",
        with.stats.hw_task_ids,
        without.stats.hw_task_ids
    );
}

#[test]
fn fusion_reduces_logical_tasks() {
    let r_with = run_stencil("laplacian", 8, 8, 8, &Options::default()).unwrap();
    let r_without = run_stencil(
        "laplacian",
        8,
        8,
        8,
        &Options { fusion: false, ..Options::default() },
    )
    .unwrap();
    assert!(
        r_without.run.stats.logical_tasks > r_with.run.stats.logical_tasks,
        "logical tasks: {} vs {}",
        r_with.run.stats.logical_tasks,
        r_without.run.stats.logical_tasks
    );
    // Unfused runs must still be correct (same output as fused).
    assert_eq!(r_with.outputs[0].1.len(), r_without.outputs[0].1.len());
    for (a, b) in r_with.outputs[0].1.iter().zip(&r_without.outputs[0].1) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

/// The paper's scaling claim: tree reduce at a scale where per-level
/// tasks exceed the hardware IDs cannot compile without recycling, but
/// compiles with it.
#[test]
fn tree_reduce_needs_recycling_at_scale() {
    let with = run_reduce("tree_reduce", 64, 64, 16, &Options::default());
    assert!(with.is_ok(), "{:?}", with.err());
    let without = run_reduce(
        "tree_reduce",
        64,
        64,
        16,
        &Options { recycling: false, fusion: false, copy_elim: true, check: true },
    );
    let err = without.err().expect("expected OOR").to_string();
    assert!(err.contains("OOR"), "{err}");
}

//! Trace determinism and profile reconciliation, end to end.
//!
//! The tracing layer (`machine/trace.rs`) claims three cross-cutting
//! guarantees, each pinned here over every library kernel — the six
//! dense paper kernels and the sparse SpMV variants alike:
//!
//! 1. **No perturbation**: a run with tracing enabled produces the
//!    bit-identical `RunReport` and output words of a run without it —
//!    instrumentation observes the event loop, it never reschedules it.
//! 2. **Thread-count determinism**: the rendered Chrome-trace JSON (and
//!    the underlying record stream) is *byte-identical* between the
//!    classic 1-thread engine and the epoch-parallel engine, for every
//!    kernel. Records are emitted per shard and merged by a stable
//!    `(start, pe)` sort, which reproduces single-threaded order.
//! 3. **Exact reconciliation**: the profile aggregator's busy and stall
//!    totals equal `Metrics::busy_cycles` / `Metrics::stall_cycles`
//!    exactly — not approximately — because spans are emitted at the
//!    same program points that bump the counters.

use spada::harness::common::{output_words, stage_kernel_inputs};
use spada::kernels::{self, CompiledKernel};
use spada::machine::{chrome_trace_json, MachineConfig, Profile, RunReport, Trace};
use spada::passes::Options;

/// Workload scale every suite kernel runs at (the registry derives
/// each kernel's binds and grid from it).
const G: i64 = 4;
const K: i64 = 8;

/// Every registry kernel at its `(G, K)` recipe — dense and sparse.
///
/// Exception: under an ambient `SPADA_BUF_CAP` (the CI backpressure
/// leg) the buffer-hungry sparse dataflows may legitimately wedge as a
/// classified buffer deadlock (`tests/buffers.rs` pins that contract),
/// so these completion-assuming trace guarantees skip them there —
/// like the golden cycle-identity tests skip under any cap.
fn all_kernels() -> Vec<(&'static str, Vec<(&'static str, i64)>, i64, i64)> {
    let capped = std::env::var_os("SPADA_BUF_CAP").is_some();
    kernels::specs()
        .into_iter()
        .filter(|s| !(capped && s.sparse))
        .map(|s| {
            let (binds, w, h) =
                s.scaled_binds(G, K).unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
            (s.name, binds, w, h)
        })
        .collect()
}

fn compile(name: &str, binds: &[(&str, i64)], w: i64, h: i64) -> CompiledKernel {
    let cfg = MachineConfig::with_grid(w, h);
    kernels::compile(name, binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// Run over deterministic inputs with tracing on, returning the report,
/// raw output words, and the captured trace.
fn run_traced(
    name: &str,
    ck: &CompiledKernel,
    threads: usize,
) -> (RunReport, Vec<(String, Vec<u32>)>, Trace) {
    let mut sim = ck.simulator().unwrap();
    sim.set_threads(threads);
    sim.set_tracing(true);
    stage_kernel_inputs(&mut sim, name, G, K, 0xEB0C).unwrap();
    let report = sim.run().unwrap_or_else(|e| panic!("{name} threads={threads}: {e}"));
    let outs = output_words(&sim);
    let trace = sim.take_trace().expect("tracing was enabled");
    (report, outs, trace)
}

/// Guarantee 2: byte-identical trace files across `SPADA_THREADS`.
/// Rendering to the final JSON (not just comparing record vectors)
/// also covers the writer: any nondeterminism in name resolution or
/// field ordering would surface as a byte diff here.
#[test]
fn chrome_trace_byte_identical_across_thread_counts() {
    for (name, binds, w, h) in all_kernels() {
        let ck = compile(name, &binds, w, h);
        let (report1, _, trace1) = run_traced(name, &ck, 1);
        let json1 = chrome_trace_json(&trace1, &ck.machine, &ck.plan, false);
        assert!(!trace1.records.is_empty(), "{name}: traced run captured no records");
        for threads in [4] {
            let (report, _, trace) = run_traced(name, &ck, threads);
            assert_eq!(report, report1, "{name}: report diverged at threads={threads}");
            assert_eq!(
                trace.records, trace1.records,
                "{name}: record stream diverged at threads={threads}"
            );
            let json = chrome_trace_json(&trace, &ck.machine, &ck.plan, false);
            assert_eq!(json, json1, "{name}: trace JSON not byte-identical at threads={threads}");
        }
    }
}

/// Guarantee 1: tracing never perturbs the simulation. Runs with the
/// instrumentation armed must match untraced runs bit for bit, on both
/// engines.
#[test]
fn tracing_is_inert_on_both_engines() {
    for (name, binds, w, h) in all_kernels() {
        let ck = compile(name, &binds, w, h);
        for threads in [1, 4] {
            let mut sim = ck.simulator().unwrap();
            sim.set_threads(threads);
            stage_kernel_inputs(&mut sim, name, G, K, 0xEB0C).unwrap();
            let plain_report = sim.run().unwrap();
            let plain_outs = output_words(&sim);
            assert!(sim.trace().is_none(), "{name}: untraced run must capture nothing");

            let (report, outs, _) = run_traced(name, &ck, threads);
            assert_eq!(
                report, plain_report,
                "{name}: tracing perturbed the report at threads={threads}"
            );
            assert_eq!(
                outs, plain_outs,
                "{name}: tracing perturbed outputs at threads={threads}"
            );
        }
    }
}

/// Guarantee 3: profile totals reconcile with the run metrics exactly.
#[test]
fn profile_reconciles_with_metrics_exactly() {
    for (name, binds, w, h) in all_kernels() {
        let ck = compile(name, &binds, w, h);
        let (report, _, trace) = run_traced(name, &ck, 1);
        let profile = Profile::build(&trace, &ck.plan, report.cycles);
        assert_eq!(
            profile.total_busy, report.metrics.busy_cycles,
            "{name}: profile busy must equal Metrics::busy_cycles exactly"
        );
        assert_eq!(
            profile.total_stall, report.metrics.stall_cycles,
            "{name}: profile stall must equal Metrics::stall_cycles exactly"
        );
        assert_eq!(profile.flows, report.metrics.flows, "{name}: flow count mismatch");
        assert_eq!(profile.dsd_ops, report.metrics.dsd_ops, "{name}: dsd_ops mismatch");
        let tasks: u64 = profile.pes.iter().map(|p| p.tasks).sum();
        assert_eq!(tasks, report.metrics.task_runs, "{name}: task_runs mismatch");
        // Per-PE invariants: non-preemptive tasks keep busy within the
        // makespan, and idle is its exact complement.
        for pe in &profile.pes {
            assert!(pe.busy <= report.cycles, "{name} PE {}: busy > makespan", pe.pe);
            assert_eq!(pe.busy + pe.idle, report.cycles, "{name} PE {}: busy+idle", pe.pe);
        }
    }
}

/// The exported JSON is structurally sound for every kernel: one
/// balanced `traceEvents` array, metadata naming, and integer
/// timestamps (Perfetto rejects files violating any of these).
#[test]
fn chrome_export_is_well_formed() {
    for (name, binds, w, h) in all_kernels() {
        let ck = compile(name, &binds, w, h);
        let (_, _, trace) = run_traced(name, &ck, 1);
        let json = chrome_trace_json(&trace, &ck.machine, &ck.plan, false);
        assert!(json.starts_with("{\"traceEvents\":["), "{name}");
        assert!(json.trim_end().ends_with("]}"), "{name}");
        assert!(json.contains("\"ph\":\"M\""), "{name}: missing metadata events");
        assert!(json.contains("\"ph\":\"X\""), "{name}: missing span events");
        assert!(json.contains("process_name"), "{name}");
        assert!(json.contains("PE(0,0)"), "{name}: missing thread naming");
        // Braces balance — a cheap structural check that catches a
        // truncated or doubly-terminated writer without a JSON parser.
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes, "{name}: unbalanced JSON braces");
        assert!(!json.contains("\"ts\":-"), "{name}: negative timestamp");
    }
}

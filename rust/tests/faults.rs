//! Fault injection: classification totality, engine determinism under
//! faults, inert-fault bit-identity, and the watchdog/error contracts.
//!
//! The resilience layer (`machine::fault` + the campaign harness)
//! promises four things, each pinned here:
//!
//! 1. **No silent hangs.** Any single link drop on any library kernel
//!    terminates with a classified outcome — a label from the closed
//!    taxonomy, never an unbounded run (property-tested over random
//!    sites and injection times; the event budget is the backstop).
//! 2. **Determinism.** A faulted run is bit-identical at 1 and 4
//!    worker threads — report, outputs, and error text — so a campaign
//!    matrix does not depend on `SPADA_THREADS`.
//! 3. **Zero-cost when inert.** A fault armed far past the run's end
//!    reproduces the clean run bit for bit at both thread counts:
//!    arming the machinery must not perturb a healthy simulation.
//! 4. **Loud aborts.** The wall-clock watchdog surfaces as a
//!    `SimError::Timeout` naming the last-progress cycle, and every
//!    `SimError` renders the one-line JSON object `spada run --json`
//!    emits on failure.

use std::sync::Arc;

use spada::harness::common::{output_words, scaled_binds, stage_random_inputs};
use spada::kernels::{self, CompiledKernel};
use spada::machine::{
    chrome_trace_json, classify, Direction, FaultPlan, FaultSpec, MachineConfig, Outcome,
    RunReport, SimError, Simulator,
};
use spada::passes::Options;
use spada::ptest::run_prop;

const INPUT_SEED: u64 = 0xFA57;

/// The closed outcome vocabulary — campaign rows and CI validators key
/// on these exact labels.
const LABELS: [&str; 7] =
    ["correct", "sdc", "buffer-deadlock", "circular-wait", "runaway", "timeout", "error"];

/// Compile one library kernel with an explicit fault plan. Explicit
/// `faults`/`timeout_ms`/capacity shield the suite from the ambient CI
/// legs (`SPADA_FAULTS`, `SPADA_TIMEOUT_MS`, `SPADA_BUF_CAP` all run
/// the full test binary).
fn compile_faulted(kernel: &str, g: i64, k: i64, faults: FaultPlan) -> CompiledKernel {
    let (binds, w, h) = scaled_binds(kernel, g, k).unwrap();
    let mut cfg = MachineConfig::with_grid(w, h);
    cfg.faults = faults;
    cfg.timeout_ms = None;
    cfg.endpoint_capacity_words = None;
    kernels::compile(kernel, &binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{kernel}: {e:#}"))
}

/// Run over the shared deterministic inputs; outputs are drained even
/// from an errored run (the `--drain` contract: both engines restore
/// the PE table before returning an error).
fn run_with(
    ck: &CompiledKernel,
    threads: usize,
) -> (Result<RunReport, SimError>, Vec<(String, Vec<u32>)>) {
    let mut sim = ck.simulator().unwrap();
    sim.set_threads(threads);
    stage_random_inputs(&mut sim, INPUT_SEED);
    let res = sim.run();
    let outs = output_words(&sim);
    (res, outs)
}

/// Re-run a compiled kernel under a different fault plan without
/// recompiling — the campaign harness's own pattern (`with_plan` reuses
/// the routing plan; faults never change routing).
fn run_faulted(
    ck: &CompiledKernel,
    faults: FaultPlan,
    threads: usize,
) -> (Result<RunReport, SimError>, Vec<(String, Vec<u32>)>) {
    let mut cfg = ck.cfg.clone();
    cfg.faults = faults;
    let mut sim = Simulator::with_plan(cfg, ck.machine.clone(), Arc::clone(&ck.plan)).unwrap();
    sim.set_threads(threads);
    stage_random_inputs(&mut sim, INPUT_SEED);
    let res = sim.run();
    let outs = output_words(&sim);
    (res, outs)
}

/// Every distinct mesh-link site the plan actually routes over,
/// decoded from the flows' dense link slots.
fn link_sites(ck: &CompiledKernel) -> Vec<(i64, i64, Direction)> {
    let plan = &ck.plan;
    let mut slots: Vec<u32> = plan
        .flows
        .iter()
        .filter(|f| f.error.is_none())
        .flat_map(|f| f.links.iter().map(|&(li, _)| li))
        .collect();
    slots.sort_unstable();
    slots.dedup();
    slots
        .iter()
        .map(|&li| {
            let cell = (li / 5) as i64;
            (cell % plan.width, cell / plan.width, Direction::ALL[(li % 5) as usize])
        })
        .collect()
}

/// `(x, y, color)` of every planned flow that reaches a destination.
fn flow_sites(ck: &CompiledKernel) -> Vec<(i64, i64, u8)> {
    let plan = &ck.plan;
    let mut sites: Vec<(i64, i64, u8)> = plan
        .flows
        .iter()
        .filter(|f| f.error.is_none() && !f.dests.is_empty())
        .map(|f| {
            let p = &plan.pes[f.src_pe as usize];
            (p.x, p.y, f.color)
        })
        .collect();
    sites.sort_unstable();
    sites.dedup();
    sites
}

/// Guarantee 1, property-tested: a random single link kill at a random
/// time on a random kernel always terminates with a label from the
/// closed taxonomy. (The simulator's event budget bounds runaways, so
/// a hang would surface as a test timeout — the property passing *is*
/// the no-silent-hang proof.)
#[test]
fn link_drop_always_terminates_classified() {
    struct Subject {
        name: &'static str,
        ck: CompiledKernel,
        sites: Vec<(i64, i64, Direction)>,
        reference: Vec<(String, Vec<u32>)>,
        clean_cycles: u64,
    }
    let subjects: Vec<Subject> =
        ["chain_reduce", "broadcast", "tree_reduce", "two_phase_reduce", "gemv", "gemv_tree"]
            .iter()
            .map(|&name| {
                let ck = compile_faulted(name, 3, 4, FaultPlan::default());
                let sites = link_sites(&ck);
                assert!(!sites.is_empty(), "{name}: no mesh links to fault");
                let (res, reference) = run_with(&ck, 1);
                let clean_cycles = res.expect("clean run completes").cycles;
                Subject { name, ck, sites, reference, clean_cycles }
            })
            .collect();

    run_prop(
        "link-drop-classified",
        0xD00D,
        18,
        |r| {
            let ki = (r.next_u64() % subjects.len() as u64) as usize;
            let si = (r.next_u64() % subjects[ki].sites.len() as u64) as usize;
            // Bias toward early kills (the interesting regime) but
            // cover post-completion arming too.
            let t = r.next_u64() % (2 * subjects[ki].clean_cycles);
            (ki, si, t)
        },
        |&(ki, si, t)| {
            let s = &subjects[ki];
            let (x, y, dir) = s.sites[si];
            let spec = FaultSpec::LinkKill { x, y, dir, at: t };
            let (res, outs) = run_faulted(&s.ck, FaultPlan::single(spec), 1);
            let outcome = classify(&res, &outs, &s.reference);
            let label = outcome.label();
            if !LABELS.contains(&label) {
                return Err(format!("{}: {spec} produced unknown label {label}", s.name));
            }
            // detail() must render for every variant (campaign rows
            // embed it in JSONL).
            let _ = outcome.detail();
            Ok(())
        },
    );

    // A link killed before any word moves always drops at least one
    // destination: the run must never classify as correct.
    for s in &subjects {
        let (x, y, dir) = s.sites[0];
        let spec = FaultSpec::LinkKill { x, y, dir, at: 0 };
        let (res, outs) = run_faulted(&s.ck, FaultPlan::single(spec), 1);
        let outcome = classify(&res, &outs, &s.reference);
        assert_ne!(
            outcome.label(),
            "correct",
            "{}: killing {dir:?}-link at ({x},{y}) cycle 0 cannot be correct",
            s.name
        );
    }
}

/// Guarantee 2: the same faulted run is bit-identical at 1 and 4
/// threads — completed reports and outputs, or the error text when the
/// fault wedges the fabric. This is what makes the campaign matrix
/// independent of `SPADA_THREADS`.
#[test]
fn faulted_runs_bit_identical_across_thread_counts() {
    for name in ["tree_reduce", "gemv"] {
        let ck = compile_faulted(name, 4, 4, FaultPlan::default());
        let (clean, _) = run_with(&ck, 1);
        let mid = clean.expect("clean run completes").cycles / 2;
        let links = link_sites(&ck);
        let flows = flow_sites(&ck);
        let (lx, ly, dir) = links[links.len() / 2];
        let (fx, fy, color) = flows[0];
        let last = *ck.plan.pes.last().unwrap();

        // A mixed plan: one kill mid-run, one delayed flow, one corrupt
        // word, one late halt — every effect class in a single run.
        let mut fp = FaultPlan::single(FaultSpec::LinkKill { x: lx, y: ly, dir, at: mid });
        fp.specs.push(FaultSpec::Delay { x: fx, y: fy, color, at: 0, extra: 7 });
        fp.specs.push(FaultSpec::Corrupt { x: fx, y: fy, color, at: 0 });
        fp.specs.push(FaultSpec::PeHalt { x: last.x, y: last.y, at: mid });

        let (res1, outs1) = run_faulted(&ck, fp.clone(), 1);
        let (res4, outs4) = run_faulted(&ck, fp, 4);
        assert_eq!(
            format!("{res1:?}"),
            format!("{res4:?}"),
            "{name}: faulted result diverged across thread counts"
        );
        assert_eq!(outs1, outs4, "{name}: faulted outputs diverged across thread counts");
        if let Ok(rep) = &res1 {
            assert!(rep.metrics.faults_injected > 0, "{name}: plan never fired");
        }
    }
}

/// Guarantee 3: faults armed far beyond the run's horizon leave the
/// run bit-identical to the clean golden at both thread counts, with
/// zero recorded injections.
#[test]
fn inert_armed_faults_reproduce_clean_run_exactly() {
    let clean_ck = compile_faulted("chain_reduce", 4, 6, FaultPlan::default());
    let (clean_res, clean_outs) = run_with(&clean_ck, 1);
    let clean_rep = clean_res.expect("clean run completes");

    let (x, y, dir) = link_sites(&clean_ck)[0];
    let far = 1u64 << 40;
    let mut fp = FaultPlan::single(FaultSpec::PeHalt { x: 0, y: 0, at: far });
    fp.specs.push(FaultSpec::LinkSlow { x, y, dir, at: far, extra: 99 });
    fp.specs.push(FaultSpec::LinkKill { x, y, dir, at: far });

    for threads in [1, 4] {
        let (res, outs) = run_faulted(&clean_ck, fp.clone(), threads);
        let rep = res.expect("armed-but-inert run completes");
        assert_eq!(rep, clean_rep, "threads={threads}: inert faults perturbed the report");
        assert_eq!(outs, clean_outs, "threads={threads}: inert faults perturbed outputs");
        assert_eq!(rep.metrics.faults_injected, 0, "threads={threads}: nothing may fire");
    }
}

/// Payload corruption is invisible to timing: the run completes, the
/// diff against the clean reference classifies it as silent data
/// corruption, and the trace gains a record on the fault lane.
#[test]
fn corrupt_classifies_as_sdc_and_lands_on_the_fault_lane() {
    let ck = compile_faulted("chain_reduce", 4, 6, FaultPlan::default());
    let (_, reference) = run_with(&ck, 1);
    let (fx, fy, color) = flow_sites(&ck)[0];

    let mut cfg = ck.cfg.clone();
    cfg.faults = FaultPlan::single(FaultSpec::Corrupt { x: fx, y: fy, color, at: 0 });
    let mut sim = Simulator::with_plan(cfg, ck.machine.clone(), Arc::clone(&ck.plan)).unwrap();
    sim.set_tracing(true);
    stage_random_inputs(&mut sim, INPUT_SEED);
    let res = sim.run();
    let outs = output_words(&sim);

    let rep = res.as_ref().expect("corruption does not change timing");
    assert_eq!(rep.metrics.faults_injected, 1, "corrupt fires exactly once");
    let outcome = classify(&res, &outs, &reference);
    assert!(matches!(outcome, Outcome::Sdc { .. }), "want sdc, got {}", outcome.label());
    assert!(outcome.detail().contains("!="), "SDC detail names the first differing word");

    let trace = sim.take_trace().expect("tracing was enabled");
    let json = chrome_trace_json(&trace, &ck.machine, &ck.plan, false);
    assert!(json.contains("injected faults"), "fault lane missing from chrome trace");
    assert!(json.contains("corrupt"), "corrupt record missing from chrome trace");
}

/// Halting the chain's head PE starves every downstream consumer: the
/// run terminates (quiescence detection, not a hang) and classifies as
/// a deadlock-family outcome, with the halt recorded as an injection.
#[test]
fn halt_at_cycle_zero_is_classified_not_silent() {
    let ck = compile_faulted("chain_reduce", 4, 6, FaultPlan::default());
    let (_, reference) = run_with(&ck, 1);
    let (res, outs) = run_faulted(&ck, FaultPlan::single(FaultSpec::PeHalt { x: 0, y: 0, at: 0 }), 1);
    let outcome = classify(&res, &outs, &reference);
    assert!(
        matches!(
            outcome,
            Outcome::BufferDeadlock { .. }
                | Outcome::CircularWait { .. }
                | Outcome::Runaway { .. }
                | Outcome::Sdc { .. }
        ),
        "halted head must starve the chain, got {}: {}",
        outcome.label(),
        outcome.detail()
    );
    assert_ne!(outcome.label(), "correct");
    if let Err(SimError::Deadlock(msg)) = &res {
        assert!(msg.contains("fault effect"), "deadlock diagnostic must flag the injection: {msg}");
    }
}

/// Satellite 1: the wall-clock watchdog aborts with `SimError::Timeout`
/// naming the last-progress cycle and the backlog (or its absence), at
/// both thread counts.
#[test]
fn watchdog_aborts_with_timeout_diagnostic() {
    for threads in [1, 4] {
        let (binds, w, h) = scaled_binds("chain_reduce", 4, 6).unwrap();
        let mut cfg = MachineConfig::with_grid(w, h);
        cfg.faults = FaultPlan::default();
        cfg.endpoint_capacity_words = None;
        cfg.timeout_ms = Some(0); // expires before the first event batch
        let ck = kernels::compile("chain_reduce", &binds, &cfg, &Options::default()).unwrap();
        let mut sim = ck.simulator().unwrap();
        sim.set_threads(threads);
        stage_random_inputs(&mut sim, INPUT_SEED);
        let err = sim.run().expect_err("0 ms watchdog must fire");
        assert_eq!(err.kind(), "timeout");
        let msg = err.to_string();
        assert!(msg.contains("wall-clock watchdog (0 ms) fired"), "{msg}");
        assert!(msg.contains("last progress at cycle"), "{msg}");
        assert!(
            msg.contains("busiest endpoints") || msg.contains("no queued endpoint words"),
            "timeout must report the backlog: {msg}"
        );
    }
}

/// Satellite 2: the one-line JSON error object every `spada run --json`
/// failure path emits — kind + message always, cycle + PE when the
/// engine recorded an error site.
#[test]
fn sim_errors_render_as_json_objects() {
    let e = SimError::Timeout("wall-clock watchdog (5 ms) fired".into());
    let j = e.to_json(Some((12, 1, 2)));
    assert!(j.contains("\"error\":{"), "{j}");
    assert!(j.contains("\"kind\":\"timeout\""), "{j}");
    assert!(j.contains("\"cycle\":12"), "{j}");
    assert!(j.contains("\"pe\":[1,2]"), "{j}");
    assert!(j.ends_with('\n'), "one line per error object: {j:?}");

    // No site → no cycle/pe keys; quotes and backslashes escape.
    let j = SimError::Deadlock("endpoint \"full\" at c:\\x".into()).to_json(None);
    assert!(j.contains("\"kind\":\"deadlock\""), "{j}");
    assert!(!j.contains("cycle"), "{j}");
    assert!(!j.contains("\"pe\""), "{j}");
    assert!(j.contains("\\\"full\\\""), "{j}");
    assert!(j.contains("c:\\\\x"), "{j}");

    // A real engine failure carries its site through `error_site`.
    let ck = compile_faulted("chain_reduce", 4, 6, FaultPlan::default());
    let (res, _) = run_faulted(
        &ck,
        FaultPlan::single(FaultSpec::PeHalt { x: 0, y: 0, at: 0 }),
        1,
    );
    let err = res.expect_err("halted head wedges the chain");
    let j = err.to_json(Some((3, 0, 0)));
    assert!(j.contains(&format!("\"kind\":\"{}\"", err.kind())), "{j}");
}

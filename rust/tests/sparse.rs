//! Sparse subsystem, end to end: the seeded CSR generator, per-PE
//! staging, the three SpMV dataflow variants against the CPU oracle,
//! engine-equivalence (threads × vectorization), tight-buffer
//! behaviour, and the adaptive selector's decision function.
//!
//! The simulator legs run the same 64×64-on-4×4 geometry as
//! `spada bench --exp sparse`; the selector legs are pure unit tests
//! on hand-built matrices whose partition criticals are computed by
//! hand in the assertions.

use spada::harness::common::output_words;
use spada::kernels;
use spada::machine::{MachineConfig, RunReport, SimError, SimOptions};
use spada::passes::Options;
use spada::sparse::{
    self, estimate, features, outer_critical, rows_critical, seeded_x, select, spmv_ref,
    variant_of, CsrMatrix, Profile, Variant,
};

/// Grid side and matrix size — the bench corpus geometry.
const G: usize = 4;
const SIZE: usize = 64;

/// Seeded matrices covering every generator profile (the three bench
/// classes plus two off-bench seeds, so tests don't only exercise the
/// exact corpus the baseline was blessed on).
fn matrices() -> Vec<(Profile, u64)> {
    vec![
        (Profile::Uniform { nnz_per_row: 8 }, 0xA11CE),
        (Profile::PowerLaw { max_row: SIZE }, 0xB0B),
        (Profile::Banded { half_width: 2 }, 0xC0FFEE),
        (Profile::Uniform { nnz_per_row: 3 }, 0xD1CE),
        (Profile::PowerLaw { max_row: SIZE / 2 }, 0xFACE),
    ]
}

/// Stage, compile and run one variant under explicit [`SimOptions`]
/// (never the ambient environment), returning the run result and the
/// raw output words — captured even on failure, so deadlock legs can
/// still inspect them.
fn run_sparse(
    v: Variant,
    a: &CsrMatrix,
    x: &[f32],
    opts: &SimOptions,
) -> (Result<RunReport, SimError>, Vec<(String, Vec<u32>)>) {
    let staged = sparse::stage(v, a, x, G, G).expect("staging");
    let cfg = MachineConfig::with_grid(G as i64, G as i64);
    let ck = kernels::compile(v.kernel(), &staged.binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{}: {e:#}", v.kernel()));
    let mut sim = ck.simulator_with(opts).unwrap();
    staged.apply(&mut sim).unwrap();
    let result = sim.run();
    let outs = output_words(&sim);
    (result, outs)
}

/// Decode the `y_out` words back to the result vector.
fn y_of(outs: &[(String, Vec<u32>)]) -> Vec<f32> {
    let (_, words) = outs.iter().find(|(n, _)| n == "y_out").expect("y_out staged");
    words.iter().map(|&w| f32::from_bits(w)).collect()
}

/// Oracle comparison with the harness tolerance — the fabric
/// accumulates partials in a different order than the f64 reference.
fn assert_close(y: &[f32], want: &[f32], tag: &str) {
    assert_eq!(y.len(), want.len(), "{tag}: output length");
    for (r, (got, exp)) in y.iter().zip(want.iter()).enumerate() {
        assert!(
            (got - exp).abs() <= 1e-3 * (1.0 + exp.abs()),
            "{tag}: y[{r}] = {got}, oracle {exp}"
        );
    }
}

/// Every variant reproduces the CPU CSR oracle on every generator
/// profile.
#[test]
fn every_variant_matches_the_csr_oracle() {
    for (profile, seed) in matrices() {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let x = seeded_x(SIZE, seed ^ 0x5EED);
        let want = spmv_ref(&a, &x);
        for v in Variant::ALL {
            let tag = format!("{}:{}", v.kernel(), profile.name());
            let (res, outs) = run_sparse(v, &a, &x, &SimOptions::default().threads(1));
            res.unwrap_or_else(|e| panic!("{tag}: {e}"));
            assert_close(&y_of(&outs), &want, &tag);
        }
    }
}

/// Cross-engine bit-identity: the epoch-parallel engine (4 threads)
/// and the per-element DSD interpreter (`vectorize(false)`) must both
/// reproduce the classic 1-thread vectorized run exactly — full
/// `RunReport` and raw output words, on every variant and class.
#[test]
fn engines_agree_across_threads_and_vectorization() {
    for (profile, seed) in matrices() {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let x = seeded_x(SIZE, seed ^ 0x5EED);
        for v in Variant::ALL {
            let tag = format!("{}:{}", v.kernel(), profile.name());
            let (base, base_outs) = run_sparse(v, &a, &x, &SimOptions::default().threads(1));
            let base = base.unwrap_or_else(|e| panic!("{tag}: {e}"));
            for (threads, vec) in [(4, true), (1, false), (4, false)] {
                let opts = SimOptions::default().threads(threads).vectorize(vec);
                let (res, outs) = run_sparse(v, &a, &x, &opts);
                let report = res
                    .unwrap_or_else(|e| panic!("{tag} threads={threads} vec={vec}: {e}"));
                assert_eq!(
                    report, base,
                    "{tag}: report diverged at threads={threads} vectorize={vec}"
                );
                assert_eq!(
                    outs, base_outs,
                    "{tag}: outputs diverged at threads={threads} vectorize={vec}"
                );
            }
        }
    }
}

/// A tight 8-word endpoint cap either completes with outputs
/// bit-identical to the unbounded run, or wedges as a *classified*
/// buffer deadlock naming the blocked endpoint — never a silent wrong
/// answer. (Sparse partials are long, so sparse dataflows are exactly
/// where an under-provisioned cap may legitimately wedge.)
#[test]
fn tight_buffer_cap_completes_bit_identical_or_classifies_the_wedge() {
    for (profile, seed) in
        [(Profile::Uniform { nnz_per_row: 8 }, 0xA11CE), (Profile::Banded { half_width: 2 }, 0xC0FFEE)]
    {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let x = seeded_x(SIZE, seed ^ 0x5EED);
        for v in Variant::ALL {
            let tag = format!("{}:{}", v.kernel(), profile.name());
            let (base, base_outs) = run_sparse(v, &a, &x, &SimOptions::default().threads(1));
            base.unwrap_or_else(|e| panic!("{tag} unbounded: {e}"));
            let capped = SimOptions::default().threads(1).buf_cap(8);
            match run_sparse(v, &a, &x, &capped) {
                (Ok(_), outs) => {
                    assert_eq!(outs, base_outs, "{tag}: outputs must survive backpressure");
                }
                (Err(SimError::Deadlock(msg)), _) => {
                    assert!(
                        msg.contains("endpoint full"),
                        "{tag}: wedge must be classified as a buffer deadlock: {msg}"
                    );
                    assert!(
                        msg.contains("PE ("),
                        "{tag}: the report must name the blocked endpoint: {msg}"
                    );
                }
                (Err(e), _) => panic!("{tag} cap=8: unexpected failure class: {e}"),
            }
        }
    }
}

/// The generator is a pure function of `(dims, profile, seed)` and
/// always emits well-formed CSR: monotone row pointers, strictly
/// ascending in-range column indices.
#[test]
fn generator_is_deterministic_and_well_formed() {
    for (profile, seed) in matrices() {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let b = sparse::generate(SIZE, SIZE, profile, seed);
        assert_eq!(a, b, "{}: same seed must be bit-identical", profile.name());
        assert_eq!(a.rp.len(), SIZE + 1);
        assert_eq!(a.rp[0], 0);
        assert_eq!(*a.rp.last().unwrap() as usize, a.nnz());
        assert_eq!(a.av.len(), a.nnz());
        for r in 0..a.rows {
            assert!(a.rp[r] <= a.rp[r + 1], "{}: rp monotone", profile.name());
            let row = &a.ci[a.rp[r] as usize..a.rp[r + 1] as usize];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "{}: row {r} columns strictly ascending", profile.name());
            }
            for &c in row {
                assert!((c as usize) < a.cols, "{}: row {r} column in range", profile.name());
            }
        }
    }
    let c = sparse::generate(SIZE, SIZE, Profile::Uniform { nnz_per_row: 8 }, 1);
    let d = sparse::generate(SIZE, SIZE, Profile::Uniform { nnz_per_row: 8 }, 2);
    assert_ne!(c, d, "different seeds must differ");
    assert_eq!(seeded_x(SIZE, 7), seeded_x(SIZE, 7));
    assert_ne!(seeded_x(SIZE, 7), seeded_x(SIZE, 8));
}

/// Feature extraction on a hand-built 4×4 matrix with row lengths
/// `[3, 1, 1, 1]` — every field is computed by hand here.
#[test]
fn features_of_a_hand_built_matrix() {
    let a = CsrMatrix {
        rows: 4,
        cols: 4,
        rp: vec![0, 3, 4, 5, 6],
        ci: vec![0, 1, 3, 1, 2, 3],
        av: vec![1.0; 6],
    };
    let f = features(&a);
    assert_eq!(f.nnz, 6);
    assert!((f.mean - 1.5).abs() < 1e-12);
    // Population variance of [3, 1, 1, 1] around 1.5.
    assert!((f.variance - 0.75).abs() < 1e-12);
    // Max row length 3 over mean 1.5.
    assert!((f.skew - 2.0).abs() < 1e-12);
    // Row 0 holds column 3: |3 - 0|.
    assert_eq!(f.bandwidth, 3);
    // The generated classes order as documented: power-law is the most
    // skewed, uniform the least.
    let u = features(&sparse::generate(SIZE, SIZE, Profile::Uniform { nnz_per_row: 8 }, 0xA11CE));
    let p = features(&sparse::generate(SIZE, SIZE, Profile::PowerLaw { max_row: SIZE }, 0xB0B));
    assert!(p.skew > u.skew, "power-law skew {} must exceed uniform {}", p.skew, u.skew);
}

/// Partition criticals on the same hand-built matrix, 2×2 grid:
/// row-stationary blocks put 3 nonzeros on PE (0,0) (rows 0–1 ×
/// cols 0–1), while contiguous column slices peak at 2.
#[test]
fn partition_criticals_match_hand_computation() {
    let a = CsrMatrix {
        rows: 4,
        cols: 4,
        rp: vec![0, 3, 4, 5, 6],
        ci: vec![0, 1, 3, 1, 2, 3],
        av: vec![1.0; 6],
    };
    assert_eq!(rows_critical(&a, 2, 2), 3);
    assert_eq!(outer_critical(&a, 2, 2), 2);
}

/// `select` is exactly the argmin of the closed-form estimates, in
/// `Variant::ALL` order with first-wins ties.
#[test]
fn select_is_the_argmin_of_the_estimates() {
    for (profile, seed) in matrices() {
        let a = sparse::generate(SIZE, SIZE, profile, seed);
        let (pick, ests) = select(&a, G, G);
        let want: Vec<u64> = Variant::ALL.iter().map(|&v| estimate(v, &a, G, G)).collect();
        assert_eq!(ests.to_vec(), want, "{}: reported estimates", profile.name());
        let min = *ests.iter().min().unwrap();
        assert_eq!(
            estimate(pick, &a, G, G),
            min,
            "{}: pick must carry the smallest estimate",
            profile.name()
        );
        let first = Variant::ALL[ests.iter().position(|&e| e == min).unwrap()];
        assert_eq!(pick, first, "{}: ties resolve in Variant::ALL order", profile.name());
    }
}

/// The selector's structural preference, on matrices whose criticals
/// are trivial to compute by hand: a diagonal matrix keeps row blocks
/// perfectly balanced (row-stationary wins), while an arrowhead
/// concentrates a full row on one block PE (column slices win).
#[test]
fn selector_prefers_rows_on_balanced_and_outer_on_skewed_structure() {
    let n = 16;
    let diag = CsrMatrix {
        rows: n,
        cols: n,
        rp: (0..=n as u32).collect(),
        ci: (0..n as u32).collect(),
        av: vec![1.0; n],
    };
    assert_eq!(select(&diag, 2, 2).0, Variant::Rows);

    // Row 0 dense, rows 1.. diagonal: 15 of 31 nonzeros land on one
    // row-partition PE, but column slices stay near-balanced.
    let mut rp = vec![0u32, n as u32];
    let mut ci: Vec<u32> = (0..n as u32).collect();
    for r in 1..n {
        ci.push(r as u32);
        rp.push(ci.len() as u32);
    }
    let arrow = CsrMatrix { rows: n, cols: n, rp, ci, av: vec![1.0; 2 * n - 1] };
    assert_eq!(rows_critical(&arrow, 2, 2), 15, "dense row + its quadrant's diagonal");
    assert_eq!(outer_critical(&arrow, 2, 2), 8, "4-column slices stay near-balanced");
    assert_eq!(select(&arrow, 2, 2).0, Variant::Outer);
}

/// Kernel-name mapping round-trips and rejects dense kernels.
#[test]
fn variant_names_round_trip() {
    for v in Variant::ALL {
        assert_eq!(variant_of(v.kernel()).unwrap(), v);
    }
    assert!(variant_of("gemv").is_err());
    assert!(variant_of("spmv_nope").is_err());
}

/// The registry knows the sparse kernels: they compile from their
/// `scaled_binds` recipes and are marked sparse, so every
/// registry-driven suite (trace, buffers, properties, faults) covers
/// them.
#[test]
fn registry_covers_the_sparse_kernels() {
    for v in Variant::ALL {
        let spec = kernels::spec(v.kernel()).expect("sparse kernel registered");
        assert!(spec.sparse, "{} must be flagged sparse", v.kernel());
        assert!(spec.grid_pow2, "{} instantiates on power-of-two grids", v.kernel());
        let (binds, w, h) = spec.scaled_binds(4, 8).expect("registry recipe");
        let cfg = MachineConfig::with_grid(w, h);
        kernels::compile(v.kernel(), &binds, &cfg, &Options::default())
            .unwrap_or_else(|e| panic!("{}: {e:#}", v.kernel()));
    }
    assert!(!kernels::dense_names().iter().any(|n| n.starts_with("spmv_")));
}

//! Static dataflow semantics checker: negative fixtures (route
//! conflict, two-writer race, circular-wait deadlock, starvation) must
//! be flagged with the right diagnostic kind, and every paper kernel
//! (fig4–fig9, table2) must pass the checker with zero findings.

use spada::analysis::{self, DiagKind};
use spada::machine::program::*;
use spada::machine::MachineConfig;
use spada::passes::Options;
use spada::sem::Bindings;
use spada::util::Subgrid;

fn binds(pairs: &[(&str, i64)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

// ---------------------------------------------------------------------
// Hand-written machine-program fixtures
// ---------------------------------------------------------------------

fn fab_out(color: u8, len: i64, on_complete: Vec<TaskAction>) -> MOp {
    MOp::Dsd(DsdOp {
        kind: DsdKind::Mov,
        dst: DsdRef::FabOut { color, len: SExpr::imm(len), ty: Dtype::F32 },
        src0: Some(DsdRef::mem(0, SExpr::imm(len), Dtype::F32)),
        src1: None,
        scalar: None,
        is_async: true,
        on_complete,
    })
}

fn fab_in(color: u8, len: i64, on_complete: Vec<TaskAction>) -> MOp {
    MOp::Dsd(DsdOp {
        kind: DsdKind::Mov,
        dst: DsdRef::mem(0, SExpr::imm(len), Dtype::F32),
        src0: Some(DsdRef::FabIn { color, len: SExpr::imm(len), ty: Dtype::F32 }),
        src1: None,
        scalar: None,
        is_async: true,
        on_complete,
    })
}

fn local_task(name: &str, hw_id: u8, active: bool, body: Vec<MOp>) -> TaskDef {
    TaskDef {
        name: name.into(),
        hw_id,
        kind: TaskKind::Local,
        initially_active: active,
        initially_blocked: false,
        body,
    }
}

fn class_at(name: &str, x: i64, tasks: Vec<TaskDef>, entry: Vec<u8>) -> PeClass {
    PeClass {
        name: name.into(),
        subgrids: vec![Subgrid::point(x, 0)],
        fields: vec![FieldAlloc {
            name: "buf".into(),
            addr: 0,
            len: 64,
            ty: Dtype::F32,
            is_extern: false,
        }],
        mem_size: 256,
        tasks,
        entry_tasks: entry,
    }
}

fn route(color: u8, x: i64, rx: DirSet, tx: DirSet) -> RouteRule {
    RouteRule { color, subgrid: Subgrid::point(x, 0), rx, tx }
}

/// (a) Two flows injected on the *same color* share a physical link:
/// the router cannot tell their wavelets apart.
#[test]
fn machine_fixture_route_conflict() {
    let c = 3u8;
    let prog = MachineProgram {
        name: "linkshare".into(),
        classes: vec![
            class_at("src0", 0, vec![local_task("s0", 27, true, vec![fab_out(c, 8, vec![])])], vec![27]),
            class_at("src1", 1, vec![local_task("s1", 27, true, vec![fab_out(c, 8, vec![])])], vec![27]),
            class_at("dst", 2, vec![local_task("d", 27, true, vec![fab_in(c, 16, vec![])])], vec![27]),
        ],
        routes: vec![
            route(c, 0, DirSet::single(Direction::Ramp), DirSet::single(Direction::East)),
            route(
                c,
                1,
                DirSet::single(Direction::West).with(Direction::Ramp),
                DirSet::single(Direction::East),
            ),
            route(c, 2, DirSet::single(Direction::West), DirSet::single(Direction::Ramp)),
        ],
        colors_used: vec![c],
        ..Default::default()
    };
    let report = analysis::check(&prog, &MachineConfig::with_grid(4, 1));
    assert!(report.has_kind(DiagKind::RouteConflict), "{report}");
    assert!(report.has_errors());
}

/// (b) Two writers from distinct PEs deliver to one endpoint over
/// disjoint links: no routing conflict, but an arrival-order race.
#[test]
fn machine_fixture_two_writer_race() {
    let c = 5u8;
    let prog = MachineProgram {
        name: "race".into(),
        classes: vec![
            class_at("west", 0, vec![local_task("w", 27, true, vec![fab_out(c, 8, vec![])])], vec![27]),
            class_at("mid", 1, vec![local_task("m", 27, true, vec![fab_in(c, 16, vec![])])], vec![27]),
            class_at("east", 2, vec![local_task("e", 27, true, vec![fab_out(c, 8, vec![])])], vec![27]),
        ],
        routes: vec![
            route(c, 0, DirSet::single(Direction::Ramp), DirSet::single(Direction::East)),
            route(c, 2, DirSet::single(Direction::Ramp), DirSet::single(Direction::West)),
            route(
                c,
                1,
                DirSet::single(Direction::West).with(Direction::East),
                DirSet::single(Direction::Ramp),
            ),
        ],
        colors_used: vec![c],
        ..Default::default()
    };
    let report = analysis::check(&prog, &MachineConfig::with_grid(4, 1));
    assert!(report.has_kind(DiagKind::DataRace), "{report}");
    assert!(
        !report.has_kind(DiagKind::RouteConflict),
        "disjoint links must not be a route conflict: {report}"
    );
}

/// (c) Circular wait: each PE's sender is gated on its own receive
/// completing, and the two receives wait on each other's senders.
#[test]
fn machine_fixture_circular_deadlock() {
    let (c_ab, c_ba) = (1u8, 2u8);
    let mk = |name: &str, x: i64, recv_color: u8, send_color: u8| {
        class_at(
            name,
            x,
            vec![
                local_task(
                    "recv",
                    27,
                    true,
                    vec![fab_in(recv_color, 8, vec![TaskAction::activate(26)])],
                ),
                local_task("send", 26, false, vec![fab_out(send_color, 8, vec![])]),
            ],
            vec![27],
        )
    };
    let prog = MachineProgram {
        name: "cycle".into(),
        classes: vec![mk("a", 0, c_ba, c_ab), mk("b", 1, c_ab, c_ba)],
        routes: vec![
            // a → b on c_ab.
            route(c_ab, 0, DirSet::single(Direction::Ramp), DirSet::single(Direction::East)),
            route(c_ab, 1, DirSet::single(Direction::West), DirSet::single(Direction::Ramp)),
            // b → a on c_ba.
            route(c_ba, 1, DirSet::single(Direction::Ramp), DirSet::single(Direction::West)),
            route(c_ba, 0, DirSet::single(Direction::East), DirSet::single(Direction::Ramp)),
        ],
        colors_used: vec![c_ab, c_ba],
        ..Default::default()
    };
    let report = analysis::check(&prog, &MachineConfig::with_grid(2, 1));
    assert!(report.has_kind(DiagKind::Deadlock), "{report}");
    let msg = report
        .diagnostics
        .iter()
        .find(|d| d.kind == DiagKind::Deadlock)
        .unwrap()
        .message
        .clone();
    assert!(msg.contains("circular wait"), "{msg}");
}

/// A consumer no flow ever reaches is starvation (the static analogue
/// of the simulator's quiescence deadlock).
#[test]
fn machine_fixture_starvation() {
    let prog = MachineProgram {
        name: "starve".into(),
        classes: vec![class_at(
            "waiter",
            0,
            vec![local_task("w", 27, true, vec![fab_in(9, 8, vec![])])],
            vec![27],
        )],
        colors_used: vec![9],
        ..Default::default()
    };
    let report = analysis::check(&prog, &MachineConfig::with_grid(1, 1));
    assert!(report.has_kind(DiagKind::Starvation), "{report}");
}

// ---------------------------------------------------------------------
// SpaDA-source fixtures (the `spada check` CLI path)
// ---------------------------------------------------------------------

const ROUTE_CONFLICT: &str = include_str!("../fixtures/route_conflict.spada");
const RACE_TWO_WRITERS: &str = include_str!("../fixtures/race_two_writers.spada");
const DEADLOCK_CYCLE: &str = include_str!("../fixtures/deadlock_cycle.spada");

fn check_fixture(src: &str, b: &[(&str, i64)], w: i64, h: i64) -> analysis::AnalysisReport {
    analysis::check_source(src, &binds(b), &MachineConfig::with_grid(w, h), &Options::default())
        .expect("fixture must reach the checker")
}

#[test]
fn spada_fixture_route_conflict() {
    let report = check_fixture(ROUTE_CONFLICT, &[("K", 8), ("N", 8)], 8, 1);
    assert!(report.has_errors(), "{report}");
    assert!(report.has_kind(DiagKind::RouteConflict), "{report}");
}

#[test]
fn spada_fixture_race_two_writers() {
    let report = check_fixture(RACE_TWO_WRITERS, &[("K", 8)], 2, 1);
    assert!(report.has_errors(), "{report}");
    assert!(report.has_kind(DiagKind::DataRace), "{report}");
}

#[test]
fn spada_fixture_deadlock_cycle() {
    let report = check_fixture(DEADLOCK_CYCLE, &[("K", 8)], 2, 1);
    assert!(report.has_errors(), "{report}");
    assert!(report.has_kind(DiagKind::Deadlock), "{report}");
    let d = report.diagnostics.iter().find(|d| d.kind == DiagKind::Deadlock).unwrap();
    assert!(d.message.contains("circular wait"), "{}", d.message);
    assert!(d.pe.is_some(), "deadlock diagnostics must be located");
}

// ---------------------------------------------------------------------
// All paper kernels must pass the checker with zero findings
// ---------------------------------------------------------------------

#[test]
fn paper_kernels_check_clean() {
    let cases: Vec<(&str, Vec<(&str, i64)>, (i64, i64))> = vec![
        ("broadcast", vec![("K", 32), ("N", 8)], (8, 1)),
        ("chain_reduce", vec![("K", 32), ("N", 8)], (8, 1)),
        ("chain_reduce", vec![("K", 16), ("N", 7)], (7, 1)), // odd row
        ("tree_reduce", vec![("K", 16), ("NX", 8), ("NY", 4)], (8, 4)),
        ("two_phase_reduce", vec![("K", 16), ("NX", 8), ("NY", 4)], (8, 4)),
        ("two_phase_reduce", vec![("K", 8), ("NX", 5), ("NY", 3)], (5, 3)),
        ("gemv", vec![("M", 16), ("N", 16), ("NX", 4), ("NY", 4)], (4, 4)),
        ("gemv_tree", vec![("M", 16), ("N", 16), ("NX", 4), ("NY", 4)], (4, 4)),
    ];
    for (name, b, (w, h)) in cases {
        let cfg = MachineConfig::with_grid(w, h);
        let opts = Options { check: false, ..Options::default() };
        let ck = spada::kernels::compile(name, &b, &cfg, &opts)
            .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        // Check against the compiler's own plan instance (the shared
        // trace-once path).
        let report = analysis::check_with_plan(&ck.machine, &cfg, &ck.plan);
        assert!(
            report.is_clean(),
            "{name} {b:?} must have zero findings:\n{report}"
        );
    }
}

#[test]
fn paper_stencils_check_clean() {
    for (name, nx, ny, k) in
        [("laplacian", 6i64, 5i64, 4i64), ("vertical", 3, 3, 8), ("uvbke", 5, 6, 3)]
    {
        let (_, prog, _, _) = spada::harness::common::compile_stencil(
            name,
            nx,
            ny,
            k,
            &Options::default(),
        )
        .unwrap_or_else(|e| panic!("{name}: {e:#}"));
        let cfg = MachineConfig::with_grid(nx, ny);
        let report = analysis::check(&prog, &cfg);
        assert!(report.is_clean(), "{name} must have zero findings:\n{report}");
    }
}

/// Compiling through `kernels::compile` with checking on (the default)
/// must succeed for the paper kernels and fail for a program the
/// checker rejects.
#[test]
fn compile_runs_checker_by_default() {
    let cfg = MachineConfig::with_grid(8, 1);
    spada::kernels::compile("chain_reduce", &[("K", 8), ("N", 8)], &cfg, &Options::default())
        .expect("clean kernel must compile with checking on");
}

/// The ablation option sets keep the kernels clean too (the checker
/// runs on every `kernels::compile` in the test suite).
#[test]
fn checker_clean_across_ablations() {
    for opts in [
        Options::none(),
        Options { fusion: false, ..Options::default() },
        Options { recycling: false, ..Options::default() },
        Options { copy_elim: false, ..Options::default() },
    ] {
        let cfg = MachineConfig::with_grid(8, 1);
        let ck = spada::kernels::compile("chain_reduce", &[("K", 8), ("N", 8)], &cfg, &opts)
            .unwrap_or_else(|e| panic!("{opts:?}: {e:#}"));
        let report = analysis::check_with_plan(&ck.machine, &cfg, &ck.plan);
        assert!(report.is_clean(), "{opts:?}:\n{report}");
    }
}

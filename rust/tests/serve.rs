//! Integration tests for `spada serve` — the long-lived service loop:
//! journal + resume byte-identity, admission-control shedding, bounded
//! retry, graceful drain on the shutdown flag, heartbeat stats, and the
//! bounded plan cache holding its budget under many-shape streams.

use spada::fleet::{serve, FleetOptions, PlanCache, ServeOptions, ServeSummary};
use spada::machine::CacheBudget;
use std::io::{Cursor, Read};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Run a serve session over an in-memory input, returning the summary,
/// the emitted row bytes and the stats (stderr) bytes.
fn run_serve(
    input: &str,
    opts: &ServeOptions,
    cache: &PlanCache,
) -> (ServeSummary, String, String) {
    let mut out = Vec::new();
    let mut stats = Vec::new();
    let shutdown = AtomicU32::new(0);
    let summary = serve::serve(
        Cursor::new(input.as_bytes().to_vec()),
        opts,
        cache,
        &mut out,
        &mut stats,
        &shutdown,
    )
    .expect("serve session");
    (summary, String::from_utf8(out).unwrap(), String::from_utf8(stats).unwrap())
}

fn tmp_path(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("spada-serve-{}-{name}", std::process::id()))
}

/// A six-line stream: five jobs across three shapes plus one malformed
/// line (which must become a `spec` error row at its line position).
const MIXED_STREAM: &str = "{\"kernel\":\"broadcast\",\"g\":4}\n\
     {\"kernel\":\"broadcast\",\"g\":4}\n\
     {\"kernel\":\"broadcast\",\"g\":8}\n\
     this is not json\n\
     {\"kernel\":\"gemv\",\"g\":4}\n\
     {\"kernel\":\"broadcast\",\"g\":4,\"seed\":7}\n";

#[test]
fn journal_resume_byte_identity() {
    let j_full = tmp_path("journal-full");
    let j_split = tmp_path("journal-split");
    let opts = ServeOptions {
        journal: Some(j_full.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    };

    // Reference: one uninterrupted run.
    let cache = PlanCache::new();
    let (summary, reference, _) = run_serve(MIXED_STREAM, &opts, &cache);
    assert_eq!(summary.rows, 6);
    assert!(!summary.drained);

    // Interrupted twin: the first three lines complete and journal,
    // then the "process" dies; a resumed run sees the whole stream.
    let prefix: String =
        MIXED_STREAM.lines().take(3).map(|l| format!("{l}\n")).collect();
    let opts_split = ServeOptions {
        journal: Some(j_split.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    };
    let cache = PlanCache::new();
    let (s1, part1, _) = run_serve(&prefix, &opts_split, &cache);
    assert_eq!(s1.rows, 3);
    let opts_resume = ServeOptions { resume: true, ..opts_split.clone() };
    // A fresh cache too: the restarted process starts cold.
    let cache = PlanCache::new();
    let (s2, part2, _) = run_serve(MIXED_STREAM, &opts_resume, &cache);
    assert_eq!(s2.skipped, 3, "the journaled prefix is skipped, not re-run");
    assert_eq!(s2.rows, 3);

    assert_eq!(
        reference,
        format!("{part1}{part2}"),
        "interrupted+resumed output must be byte-identical to the uninterrupted run"
    );
    // The journals agree too: same ids, same order.
    assert_eq!(
        std::fs::read_to_string(&j_full).unwrap(),
        std::fs::read_to_string(&j_split).unwrap()
    );
    let _ = std::fs::remove_file(&j_full);
    let _ = std::fs::remove_file(&j_split);
}

#[test]
fn resume_requires_a_journal() {
    let opts = ServeOptions { resume: true, ..ServeOptions::default() };
    let mut out = Vec::new();
    let mut stats = Vec::new();
    let shutdown = AtomicU32::new(0);
    let err = serve::serve(
        Cursor::new(Vec::new()),
        &opts,
        &PlanCache::new(),
        &mut out,
        &mut stats,
        &shutdown,
    )
    .unwrap_err();
    assert!(err.to_string().contains("--journal"), "got: {err}");
}

#[test]
fn overload_shed_emits_structured_rows() {
    // One worker, queue of one, shedding on. The first job holds the
    // worker for several backoff rounds (injected transient failures),
    // so the burst behind it overflows the queue and sheds.
    let head = "{\"kernel\":\"broadcast\",\"g\":4,\"id\":\"slow\",\"inject_fail\":2}\n";
    let burst: String = (0..8)
        .map(|i| format!("{{\"kernel\":\"broadcast\",\"g\":4,\"id\":\"q{i}\"}}\n"))
        .collect();
    let opts = ServeOptions {
        fleet: FleetOptions { pool: 1, budget: 1 },
        queue_cap: 1,
        shed: true,
        retries: 2,
        backoff_ms: 60,
        ..ServeOptions::default()
    };
    let cache = PlanCache::new();
    let (summary, rows, _) = run_serve(&format!("{head}{burst}"), &opts, &cache);
    assert_eq!(summary.rows, 9, "every job gets a row, shed or not");
    assert!(summary.shed >= 1, "the burst must shed at least one job:\n{rows}");
    assert_eq!(summary.shed, rows.matches("\"kind\":\"overload\"").count() as u64);
    assert!(
        rows.contains("admission queue full"),
        "shed rows carry the structured overload diagnostic"
    );
    // Rows still arrive in input order: `slow` first.
    assert!(rows.starts_with("{\"id\":\"slow\""), "got: {rows}");
}

#[test]
fn transient_failures_retry_until_success() {
    let input = "{\"kernel\":\"broadcast\",\"g\":4,\"id\":\"flaky\",\"inject_fail\":1}\n";
    let opts =
        ServeOptions { retries: 1, backoff_ms: 1, ..ServeOptions::default() };
    let cache = PlanCache::new();
    let (summary, rows, _) = run_serve(input, &opts, &cache);
    assert!(rows.contains("\"ok\":true"), "attempt 2 must succeed: {rows}");
    assert!(rows.contains("\"attempts\":2"), "the row records both attempts: {rows}");
    assert_eq!(summary.retries, 1);
    assert_eq!(summary.ok, 1);

    // Without retry budget the same job is a panic error row.
    let opts = ServeOptions { retries: 0, ..ServeOptions::default() };
    let cache = PlanCache::new();
    let (summary, rows, _) = run_serve(input, &opts, &cache);
    assert!(rows.contains("\"kind\":\"panic\"") && rows.contains("\"attempts\":1"), "{rows}");
    assert_eq!(summary.errors, 1);
    assert_eq!(summary.retries, 0);
}

#[test]
fn deterministic_failures_are_not_retried() {
    // An unknown kernel fails identically on every attempt; the retry
    // budget must not be spent re-proving it.
    let input = "{\"kernel\":\"no_such_kernel\",\"id\":\"det\"}\n";
    let opts =
        ServeOptions { retries: 3, backoff_ms: 1, ..ServeOptions::default() };
    let cache = PlanCache::new();
    let (summary, rows, _) = run_serve(input, &opts, &cache);
    assert!(rows.contains("\"ok\":false") && rows.contains("\"attempts\":1"), "{rows}");
    assert_eq!(summary.retries, 0);
}

#[test]
fn pool_width_does_not_change_output_bytes() {
    let mut reference = None;
    for pool in [1, 4] {
        let opts = ServeOptions {
            fleet: FleetOptions { pool, budget: 4 },
            ..ServeOptions::default()
        };
        let cache = PlanCache::new();
        let (_, rows, _) = run_serve(MIXED_STREAM, &opts, &cache);
        match &reference {
            None => reference = Some(rows),
            Some(r) => assert_eq!(r, &rows, "pool {pool} changed the row bytes"),
        }
    }
}

/// Yields its payload, then blocks until the release flag rises, then
/// reports EOF — a stand-in for a stalled client connection, so the
/// drain path (not input EOF) ends the session.
struct StallingReader {
    payload: Cursor<Vec<u8>>,
    release: Arc<AtomicU32>,
}

impl Read for StallingReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.payload.read(buf)?;
        if n > 0 {
            return Ok(n);
        }
        while self.release.load(Ordering::SeqCst) == 0 {
            std::thread::sleep(Duration::from_millis(2));
        }
        Ok(0)
    }
}

#[test]
fn shutdown_flag_drains_and_journals_the_prefix() {
    let journal = tmp_path("journal-drain");
    let payload = "{\"kernel\":\"broadcast\",\"g\":4}\n\
         {\"kernel\":\"broadcast\",\"g\":4}\n\
         {\"kernel\":\"broadcast\",\"g\":8}\n\
         {\"kernel\":\"gemv\",\"g\":4}\n";
    let release = Arc::new(AtomicU32::new(0));
    let reader = StallingReader {
        payload: Cursor::new(payload.as_bytes().to_vec()),
        release: Arc::clone(&release),
    };
    let opts = ServeOptions {
        journal: Some(journal.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    };
    let cache = PlanCache::new();
    let shutdown = AtomicU32::new(0);
    let mut out = Vec::new();
    let mut stats = Vec::new();
    let summary = std::thread::scope(|scope| {
        let handle = scope.spawn(|| {
            serve::serve(reader, &opts, &cache, &mut out, &mut stats, &shutdown)
                .expect("serve session")
        });
        // Wait until all four jobs have been journaled (the stream is
        // fully processed, the reader is stalling), then signal.
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let done = std::fs::read_to_string(&journal)
                .map(|t| t.lines().count())
                .unwrap_or(0);
            if done >= 4 {
                break;
            }
            assert!(Instant::now() < deadline, "jobs never completed");
            std::thread::sleep(Duration::from_millis(5));
        }
        shutdown.store(1, Ordering::SeqCst);
        let summary = handle.join().expect("serve thread");
        release.store(1, Ordering::SeqCst); // let the reader exit too
        summary
    });
    assert!(summary.drained, "the session must report a drain, not EOF");
    assert_eq!(summary.rows, 4);
    let rows = String::from_utf8(out).unwrap();
    let journal_ids: Vec<String> = std::fs::read_to_string(&journal)
        .unwrap()
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(journal_ids, vec!["job-1", "job-2", "job-3", "job-4"]);
    for id in &journal_ids {
        assert!(rows.contains(&format!("\"id\":\"{id}\"")), "journaled id {id} missing a row");
    }
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn heartbeat_stats_stream_and_reconcile() {
    let opts = ServeOptions { stats_every: Some(2), ..ServeOptions::default() };
    let cache = PlanCache::new();
    let (summary, _, stats) = run_serve(MIXED_STREAM, &opts, &cache);
    assert_eq!(summary.rows, 6);
    let heartbeats = stats.matches("\"event\":\"heartbeat\"").count();
    let finals = stats.matches("\"event\":\"final\"").count();
    assert_eq!(heartbeats, 3, "6 rows at --stats-every 2:\n{stats}");
    assert_eq!(finals, 1, "exactly one final line:\n{stats}");
    let final_line = stats.lines().last().unwrap();
    assert!(final_line.contains("\"event\":\"final\""));
    assert!(final_line.contains("\"rows\":6"));
    assert!(final_line.contains("\"drained\":false"));
    // The cache counter set on the final line reconciles exactly.
    assert!(final_line.contains(&format!(
        "\"cache\":{{\"lookups\":{},\"hits\":{},\"misses\":{}",
        cache.lookups(),
        cache.hits(),
        cache.misses()
    )));
    assert_eq!(cache.hits() + cache.misses(), cache.lookups());
}

#[test]
fn bounded_cache_holds_budget_under_many_shapes() {
    // Acceptance pin: a many-shape workload against a small budget
    // stays within it, and the counters reconcile exactly.
    let input: String = (0..12)
        .map(|i| format!("{{\"kernel\":\"broadcast\",\"g\":{}}}\n", 4 + i))
        .collect();
    let cache = PlanCache::bounded(CacheBudget { max_entries: Some(3), max_bytes: None });
    let (summary, _, _) = run_serve(&input, &ServeOptions::default(), &cache);
    assert_eq!(summary.rows, 12);
    assert_eq!(summary.ok, 12);
    assert!(cache.len() <= 3, "budget violated: {} entries live", cache.len());
    assert_eq!(cache.hits() + cache.misses(), cache.lookups());
    assert!(cache.evictions() <= cache.misses());
    assert_eq!(cache.lookups(), 12);
    assert!(cache.evictions() >= 9, "12 distinct shapes through 3 slots must evict");
}

//! Property-based tests over the compiler invariants (DESIGN.md §7),
//! using the deterministic `ptest` helper (proptest is unavailable
//! offline).

use spada::csl;
use spada::kernels;
use spada::machine::MachineConfig;
use spada::passes::{self, Options};
use spada::ptest::run_prop;
use spada::sem::{instantiate, Bindings};
use spada::spada::parse_kernel;
use spada::util::{Range1, SplitMix64, Subgrid};

fn bindings(pairs: &[(&str, i64)]) -> Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

// ---------------------------------------------------------------------
// Strided-range algebra
// ---------------------------------------------------------------------

#[test]
fn prop_range_intersection_is_exact() {
    run_prop(
        "range-intersection",
        1,
        500,
        |r| {
            let a = Range1::new(
                r.below(20) as i64,
                r.below(60) as i64,
                1 + r.below(5) as i64,
            );
            let b = Range1::new(
                r.below(20) as i64,
                r.below(60) as i64,
                1 + r.below(5) as i64,
            );
            (a, b)
        },
        |(a, b)| {
            let c = a.intersect(b);
            for x in -5..70 {
                let in_both = a.contains(x) && b.contains(x);
                if in_both != c.contains(x) {
                    return Err(format!("x={x}: a∩b={in_both}, c={}", c.contains(x)));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_split_parity_partitions() {
    run_prop(
        "split-parity",
        2,
        500,
        |r| Range1::new(r.below(30) as i64, r.below(90) as i64, 1 + r.below(4) as i64),
        |a| {
            let (e, o) = a.split_parity();
            for x in -2..100 {
                let want = a.contains(x);
                let got = e.contains(x) || o.contains(x);
                if want != got {
                    return Err(format!("x={x}: member={want}, split={got}"));
                }
                if e.contains(x) && x % 2 != 0 {
                    return Err(format!("odd {x} in even part"));
                }
                if o.contains(x) && x.rem_euclid(2) != 1 {
                    return Err(format!("even {x} in odd part"));
                }
                if e.contains(x) && o.contains(x) {
                    return Err(format!("{x} in both parts"));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Routing invariants
// ---------------------------------------------------------------------

/// Compile random instantiations of all library kernels and check the
/// hard routing invariant: for a fixed color, no two route rules may
/// overlap (one router holds exactly one configuration per color).
#[test]
fn prop_routes_conflict_free() {
    run_prop(
        "conflict-free-routing",
        3,
        40,
        |r| {
            let kind = r.below(4);
            let k = 1 + r.below(64) as i64;
            match kind {
                0 => {
                    let n = 3 + r.below(14) as i64;
                    ("chain_reduce", vec![("K", k), ("N", n)], n, 1)
                }
                1 => {
                    let n = 4 + r.below(13) as i64;
                    ("broadcast", vec![("K", k), ("N", n)], n, 1)
                }
                2 => {
                    let nx = 1i64 << (1 + r.below(4));
                    let ny = 1i64 << (1 + r.below(3));
                    ("tree_reduce", vec![("K", k), ("NX", nx), ("NY", ny)], nx, ny)
                }
                _ => {
                    let nx = 3 + r.below(8) as i64;
                    let ny = 3 + r.below(8) as i64;
                    ("two_phase_reduce", vec![("K", k), ("NX", nx), ("NY", ny)], nx, ny)
                }
            }
        },
        |(name, binds, w, h)| {
            let cfg = MachineConfig::with_grid(*w, *h);
            let prog = kernels::compile(name, binds, &cfg, &Options::default())
                .map_err(|e| e.to_string())?
                .machine;
            for i in 0..prog.routes.len() {
                for j in (i + 1)..prog.routes.len() {
                    let (a, b) = (&prog.routes[i], &prog.routes[j]);
                    if a.color == b.color && !a.subgrid.intersect(&b.subgrid).is_empty() {
                        return Err(format!(
                            "{name}: color {} configured twice on {:?}",
                            a.color,
                            a.subgrid.intersect(&b.subgrid)
                        ));
                    }
                }
            }
            // Hardware limits must hold (the simulator re-validates too).
            let errs = prog.validate(&cfg);
            if !errs.is_empty() {
                return Err(errs.join("; "));
            }
            Ok(())
        },
    );
}

/// Checkerboarded pipelines: every stream variant's senders have uniform
/// parity along the active dimension.
#[test]
fn prop_checkerboard_parity() {
    run_prop(
        "checkerboard-parity",
        4,
        60,
        |r| (3 + r.below(20) as i64, 1 + r.below(32) as i64),
        |(n, k)| {
            let src = "kernel @p<K, N>() {
                place i16 i, i16 j in [0:N, 0] { f32[K] a }
                dataflow i32 i, i32 j in [0:N, 0] {
                    stream<f32> s = relative_stream(-1, 0)
                }
                compute i32 i, i32 j in [1:N, 0] { await send(a, s) }
                compute i32 i, i32 j in [0:N-1, 0] { await receive(a, s) }
            }";
            let kast = parse_kernel(src).map_err(|e| e.to_string())?;
            let prog = instantiate(&kast, &bindings(&[("K", *k), ("N", *n)]))
                .map_err(|e| e.to_string())?;
            let res = passes::checkerboard(&prog).map_err(|e| e.to_string())?;
            for s in &res.program.phases[0].streams {
                let xs: Vec<i64> = s.subgrid.dims[0].iter().collect();
                if let Some(first) = xs.first() {
                    if !xs.iter().all(|x| (x - first) % 2 == 0) {
                        return Err(format!("variant {} mixes parities: {:?}", s.name, xs));
                    }
                }
            }
            Ok(())
        },
    );
}

/// PE equivalence classes form an exact partition of the used PEs.
#[test]
fn prop_classes_partition() {
    run_prop(
        "classes-partition",
        5,
        40,
        |r| {
            let nx = 1i64 << (1 + r.below(4));
            let ny = 1i64 << (1 + r.below(4));
            (nx, ny, 1 + r.below(16) as i64)
        },
        |(nx, ny, k)| {
            let kast = parse_kernel(kernels::TREE_REDUCE).map_err(|e| e.to_string())?;
            let prog = instantiate(&kast, &bindings(&[("K", *k), ("NX", *nx), ("NY", *ny)]))
                .map_err(|e| e.to_string())?;
            let prog = passes::checkerboard(&prog).map_err(|e| e.to_string())?.program;
            let classes = passes::equivalence_classes(&prog);
            passes::classes::check_partition(&classes)?;
            let total: i64 =
                classes.iter().flat_map(|c| c.subgrids.iter()).map(Subgrid::len).sum();
            if total != nx * ny {
                return Err(format!("classes cover {total} PEs, grid has {}", nx * ny));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// End-to-end correctness under random sizes and option sets
// ---------------------------------------------------------------------

#[test]
fn prop_reduce_correct_all_option_sets() {
    run_prop(
        "reduce-correct",
        6,
        25,
        |r| {
            let nx = (1i64 << (1 + r.below(3))).max(2);
            let ny = (1i64 << (1 + r.below(3))).max(2);
            let k = 1 + r.below(48) as i64;
            let opts = Options {
                fusion: r.below(2) == 0,
                recycling: r.below(2) == 0,
                copy_elim: r.below(2) == 0,
                check: true,
            };
            let kernel = if r.below(2) == 0 { "tree_reduce" } else { "two_phase_reduce" };
            (kernel, nx, ny, k, opts, r.next_u64())
        },
        |(kernel, nx, ny, k, opts, seed)| {
            let cfg = MachineConfig::with_grid(*nx, *ny);
            let compiled =
                kernels::compile(kernel, &[("K", *k), ("NX", *nx), ("NY", *ny)], &cfg, opts);
            let ck = match compiled {
                Ok(p) => p,
                // Resource exhaustion is a legitimate outcome for
                // pessimized option sets (the paper's OOR results) —
                // only wrong numerics fail the property.
                Err(e) if e.to_string().contains("OOR") || e.to_string().contains("OOM") => {
                    return Ok(())
                }
                Err(e) => return Err(e.to_string()),
            };
            let mut sim = ck.simulator().map_err(|e| e.to_string())?;
            let mut rng = SplitMix64::new(*seed);
            let data: Vec<f32> = (0..(k * nx * ny) as usize).map(|_| rng.next_f32()).collect();
            sim.set_input("a_in", &data).map_err(|e| e.to_string())?;
            sim.run().map_err(|e| e.to_string())?;
            let out = sim.get_output("out").map_err(|e| e.to_string())?;
            for kk in 0..*k as usize {
                let want: f32 = data.chunks(*k as usize).map(|c| c[kk]).sum();
                let got = out[kk];
                if (got - want).abs() > 1e-3 * (1.0 + want.abs()) {
                    return Err(format!("{kernel} k={kk}: got {got}, want {want}"));
                }
            }
            Ok(())
        },
    );
}

/// Deliberate resource exhaustion must fail with OOM, not silently.
#[test]
fn failure_injection_oom() {
    let src = "kernel @big<K>() {
        place i16 i, i16 j in [0:2, 0] { f32[K] a }
        compute i32 i, i32 j in [0:2, 0] { a[0] = 1.0 }
    }";
    let kast = parse_kernel(src).unwrap();
    let prog = instantiate(&kast, &bindings(&[("K", 20_000)])).unwrap();
    let cfg = MachineConfig::with_grid(2, 1);
    let err = csl::compile(&prog, &cfg, &Options::default()).unwrap_err();
    assert!(err.0.contains("OOM"), "{err}");
}

/// Deliberate channel exhaustion must fail with OOR.
#[test]
fn failure_injection_color_exhaustion() {
    let mut decls = String::new();
    let mut uses = String::new();
    for i in 0..26 {
        decls.push_str(&format!("stream<f32> s{i} = relative_stream(1, 0)\n"));
        uses.push_str(&format!("send(v, s{i})\n"));
    }
    let src = format!(
        "kernel @many<N>() {{
            place i16 i, i16 j in [0:N, 0] {{ f32 v }}
            dataflow i32 i, i32 j in [0:N, 0] {{ {decls} }}
            compute i32 i, i32 j in [0, 0] {{ {uses} awaitall }}
        }}"
    );
    let kast = parse_kernel(&src).unwrap();
    let prog = instantiate(&kast, &bindings(&[("N", 4)])).unwrap();
    let cfg = MachineConfig::with_grid(4, 1);
    let err = csl::compile(&prog, &cfg, &Options::default()).unwrap_err();
    assert!(err.0.contains("OOR"), "{err}");
}

// ---------------------------------------------------------------------
// Precompiled routing plan vs. the reference tracer
// ---------------------------------------------------------------------

/// For random router configurations, every path the precompiled
/// [`RoutingPlan`] stores must be identical (links, destinations, and
/// errors) to what `machine::router::trace_route` computes directly —
/// the invariant that lets the simulator and the static checker share
/// one route resolution.
#[test]
fn prop_routing_plan_matches_trace_route() {
    use spada::machine::plan::RoutingPlan;
    use spada::machine::program::{
        DirSet, Direction, DsdKind, DsdOp, DsdRef, Dtype, FieldAlloc, MOp, PeClass, RouteRule,
        SExpr, TaskDef, TaskKind,
    };
    use spada::machine::{router::trace_route, MachineProgram};

    fn dir_of(k: u64) -> Direction {
        match k {
            0 => Direction::North,
            1 => Direction::East,
            2 => Direction::South,
            3 => Direction::West,
            _ => Direction::Ramp,
        }
    }

    run_prop(
        "plan-vs-trace",
        0xB10C,
        60,
        |r| {
            let w = 2 + r.below(5) as i64;
            let h = 2 + r.below(5) as i64;
            let ncolors = 1 + r.below(3) as u8;
            let mut routes = vec![];
            for _ in 0..(1 + r.below(8)) {
                let x0 = r.below(w as u64) as i64;
                let x1 = x0 + r.below((w - x0) as u64) as i64;
                let y0 = r.below(h as u64) as i64;
                let y1 = y0 + r.below((h - y0) as u64) as i64;
                routes.push(RouteRule {
                    color: r.below(ncolors as u64) as u8,
                    subgrid: Subgrid::new(Range1::dense(x0, x1 + 1), Range1::dense(y0, y1 + 1)),
                    rx: DirSet::single(Direction::Ramp).with(dir_of(r.below(5))),
                    tx: DirSet::single(dir_of(r.below(5))),
                });
            }
            (w, h, ncolors, routes)
        },
        |(w, h, ncolors, routes)| {
            // One class covering the whole grid, producing every color.
            let body: Vec<MOp> = (0..*ncolors)
                .map(|c| {
                    MOp::Dsd(DsdOp {
                        kind: DsdKind::Mov,
                        dst: DsdRef::FabOut { color: c, len: SExpr::imm(4), ty: Dtype::F32 },
                        src0: Some(DsdRef::mem(0, SExpr::imm(4), Dtype::F32)),
                        src1: None,
                        scalar: None,
                        is_async: true,
                        on_complete: vec![],
                    })
                })
                .collect();
            let class = PeClass {
                name: "p".into(),
                subgrids: vec![Subgrid::new(Range1::dense(0, *w), Range1::dense(0, *h))],
                fields: vec![FieldAlloc {
                    name: "a".into(),
                    addr: 0,
                    len: 4,
                    ty: Dtype::F32,
                    is_extern: false,
                }],
                mem_size: 16,
                tasks: vec![TaskDef {
                    name: "t".into(),
                    hw_id: 24,
                    kind: TaskKind::Local,
                    initially_active: false,
                    initially_blocked: false,
                    body,
                }],
                entry_tasks: vec![],
            };
            let prog = MachineProgram {
                name: "prop".into(),
                classes: vec![class],
                routes: routes.clone(),
                ..Default::default()
            };
            let cfg = MachineConfig::with_grid(*w, *h);
            let plan = RoutingPlan::build(&prog, &cfg);
            for y in 0..*h {
                for x in 0..*w {
                    for color in 0..*ncolors {
                        let want = trace_route(&prog, &cfg, color, x, y);
                        let Some(got) = plan.path(x, y, color) else {
                            return Err(format!("plan missing flow ({x},{y}) color {color}"));
                        };
                        match (&want, got) {
                            (Ok(a), Ok(b)) => {
                                if a.links != b.links || a.dests != b.dests {
                                    return Err(format!(
                                        "path mismatch at ({x},{y}) color {color}: \
                                         {a:?} vs {b:?}"
                                    ));
                                }
                            }
                            (Err(a), Err(b)) => {
                                if a != b {
                                    return Err(format!(
                                        "error mismatch at ({x},{y}) color {color}: \
                                         {a:?} vs {b:?}"
                                    ));
                                }
                            }
                            (a, b) => {
                                return Err(format!(
                                    "verdict mismatch at ({x},{y}) color {color}: \
                                     {a:?} vs {b:?}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Batched-DSD eligibility vs aliasing
// ---------------------------------------------------------------------

/// Random `(base, offset, stride, n, dtype)` descriptor pairs: the
/// batched-eligibility pipeline (static `classify_vec` + runtime
/// `admit_map`/`admit_fold`) must never mark an aliased or overlapping
/// (dst, src) pair as vectorizable, must only admit contiguous f32
/// spans, and must keep every admitted span inside PE memory. The
/// brute-force oracle enumerates the exact byte set each descriptor
/// touches.
#[test]
fn prop_vec_classifier_never_admits_overlap() {
    use spada::machine::program::{DsdRef, Dtype, SExpr};
    use spada::machine::vecop::{admit_fold, admit_map, classify_vec, Span, VecOp};

    const MEM_LEN: usize = 1024;

    fn ty_of(code: u64) -> Dtype {
        // Biased toward f32 so the Map/Fold arms are exercised often.
        match code {
            0..=5 => Dtype::F32,
            6 => Dtype::F16,
            7 => Dtype::I32,
            _ => Dtype::U16,
        }
    }

    /// (base bytes, offset elems, stride elems, dtype code)
    type Desc = (u32, i64, i64, u64);

    fn mk(d: &Desc, n: usize) -> DsdRef {
        DsdRef::Mem {
            base: d.0,
            offset: SExpr::imm(d.1),
            stride: d.2,
            len: SExpr::imm(n as i64),
            ty: ty_of(d.3),
        }
    }

    /// Mirror of the simulator's descriptor resolution:
    /// byte base = base + offset·size, byte stride = stride·size.
    fn resolved(d: &Desc) -> (i64, i64, usize) {
        let esz = ty_of(d.3).size() as i64;
        (d.0 as i64 + d.1 * esz, d.2 * esz, esz as usize)
    }

    /// Exact byte intervals touched by n elements.
    fn touched(base: i64, stride: i64, esz: usize, n: usize) -> Vec<(i64, i64)> {
        (0..n)
            .map(|i| {
                let a = base + i as i64 * stride;
                (a, a + esz as i64)
            })
            .collect()
    }

    fn intersects(a: &[(i64, i64)], b: &[(i64, i64)]) -> bool {
        a.iter().any(|(al, ah)| b.iter().any(|(bl, bh)| al < *bh && *bl < ah))
    }

    fn desc(r: &mut SplitMix64) -> Desc {
        (
            (r.below(64) * 4) as u32,
            r.below(12) as i64 - 4,
            r.below(6) as i64 - 2,
            r.below(9),
        )
    }

    run_prop(
        "vec-no-overlap",
        0xD5D,
        600,
        |r| {
            let dst = desc(r);
            let src0 = if r.below(8) == 0 { None } else { Some(desc(r)) };
            // Bias src0 toward exact dst aliases so the Fold arm and the
            // aliased-Map rejection both fire regularly.
            let src0 = if r.below(3) == 0 { Some(dst) } else { src0 };
            let src1 = if r.below(4) == 0 { None } else { Some(desc(r)) };
            let n = 1 + r.below(16) as usize;
            (dst, src0, src1, n)
        },
        |(dst, src0, src1, n)| {
            let n = *n;
            let d_ref = mk(dst, n);
            let s0_ref = src0.as_ref().map(|d| mk(d, n));
            let s1_ref = src1.as_ref().map(|d| mk(d, n));
            let verdict = classify_vec(&d_ref, &s0_ref, &s1_ref);
            let (db, ds, desz) = resolved(dst);
            // Shared Map/Map16 oracle: admission at element size `esz`
            // must keep every span inside memory and disjoint from the
            // destination.
            let check_map = |esz: usize| -> Result<(), String> {
                if db < 0 {
                    return Ok(()); // wrapped address: admission sees an OOB span
                }
                let d_span = Some(Span { base: db as usize, stride: ds as isize });
                let mut spans = vec![];
                for (s, sref) in [(src0, &s0_ref), (src1, &s1_ref)] {
                    match sref {
                        Some(DsdRef::Mem { .. }) => {
                            let (sb, ss, _) = resolved(s.as_ref().unwrap());
                            if sb < 0 {
                                return Ok(());
                            }
                            spans.push(Some(Span { base: sb as usize, stride: ss as isize }));
                        }
                        _ => spans.push(None),
                    }
                }
                if !admit_map(MEM_LEN, d_span, &spans, n, esz) {
                    return Ok(()); // rejected: interpreter path
                }
                // Admitted: brute-force check bounds + disjointness.
                let d_bytes = touched(db, ds, desz, n);
                if d_bytes.iter().any(|(lo, hi)| *lo < 0 || *hi > MEM_LEN as i64) {
                    return Err(format!("admitted dst leaves memory: {d_bytes:?}"));
                }
                for s in [src0.as_ref(), src1.as_ref()].into_iter().flatten() {
                    let (sb, ss, sesz) = resolved(s);
                    let s_bytes = touched(sb, ss, sesz, n);
                    if intersects(&d_bytes, &s_bytes) {
                        return Err(format!(
                            "admitted overlapping pair: dst {dst:?} src {s:?} (n={n})"
                        ));
                    }
                    if s_bytes.iter().any(|(lo, hi)| *lo < 0 || *hi > MEM_LEN as i64) {
                        return Err(format!("admitted src leaves memory: {s:?}"));
                    }
                }
                Ok(())
            };
            match verdict {
                VecOp::None => Ok(()), // interpreter path: always sound
                VecOp::Map => {
                    // Static stage must only pass contiguous f32 shapes.
                    if dst.2 != 1 || ty_of(dst.3) != Dtype::F32 {
                        return Err(format!("Map with dst stride {} ty {:?}", dst.2, ty_of(dst.3)));
                    }
                    check_map(4)
                }
                VecOp::Map16 => {
                    // Static stage: contiguous 16-bit integer dst, and
                    // every memory source of exactly the same dtype.
                    let dty = ty_of(dst.3);
                    if dst.2 != 1 || !matches!(dty, Dtype::I16 | Dtype::U16) {
                        return Err(format!(
                            "Map16 with dst stride {} ty {dty:?}",
                            dst.2
                        ));
                    }
                    for s in [src0.as_ref(), src1.as_ref()].into_iter().flatten() {
                        if s.2 != 1 || ty_of(s.3) != dty {
                            return Err(format!(
                                "Map16 with src stride {} ty {:?} (dst {dty:?})",
                                s.2,
                                ty_of(s.3)
                            ));
                        }
                    }
                    check_map(2)
                }
                VecOp::MapF16 => {
                    // Static stage: contiguous f16 dst, every memory
                    // source f16 and contiguous too.
                    if dst.2 != 1 || ty_of(dst.3) != Dtype::F16 {
                        return Err(format!(
                            "MapF16 with dst stride {} ty {:?}",
                            dst.2,
                            ty_of(dst.3)
                        ));
                    }
                    for s in [src0.as_ref(), src1.as_ref()].into_iter().flatten() {
                        if s.2 != 1 || ty_of(s.3) != Dtype::F16 {
                            return Err(format!(
                                "MapF16 with src stride {} ty {:?}",
                                s.2,
                                ty_of(s.3)
                            ));
                        }
                    }
                    check_map(2)
                }
                VecOp::Fold => {
                    // src0 must be the destination cell, exactly.
                    let s0 = src0.as_ref().ok_or("Fold without src0")?;
                    let (s0b, s0s, _) = resolved(s0);
                    if s0b != db || s0s != 0 || ds != 0 {
                        return Err(format!("Fold acc is not the dst cell: {dst:?} vs {s0:?}"));
                    }
                    if db < 0 {
                        return Ok(());
                    }
                    let acc = Span { base: db as usize, stride: 0 };
                    let s1_span = match &s1_ref {
                        Some(DsdRef::Mem { .. }) => {
                            let (sb, ss, _) = resolved(src1.as_ref().unwrap());
                            if sb < 0 {
                                return Ok(());
                            }
                            Some(Span { base: sb as usize, stride: ss as isize })
                        }
                        _ => None,
                    };
                    if !admit_fold(MEM_LEN, acc, s1_span, n) {
                        return Ok(());
                    }
                    // Admitted: the streamed source must not touch the
                    // accumulator cell.
                    if let Some(s) = src1 {
                        let (sb, ss, sesz) = resolved(s);
                        let s_bytes = touched(sb, ss, sesz, n);
                        let acc_bytes = touched(db, 0, desz, 1);
                        if intersects(&acc_bytes, &s_bytes) {
                            return Err(format!(
                                "admitted fold with stream over the accumulator: {s:?}"
                            ));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

// ---------------------------------------------------------------------
// Epoch-parallel determinism
// ---------------------------------------------------------------------

/// Randomly drawn (kernel, size, input seed) programs must simulate
/// bit-identically at every worker thread count: threads = 1 is the
/// classic single-queue event loop, ≥ 2 the epoch-parallel sharded
/// engine with deterministic barrier merges. Any divergence in the
/// `RunReport` (cycles, every metric counter) or in raw output words
/// falsifies the engine's conservative-lookahead argument.
#[test]
fn prop_random_programs_deterministic_across_threads() {
    use spada::harness::common::{output_words, stage_kernel_inputs};
    use spada::machine::RunReport;

    // The whole registry — the sparse SpMV variants are subject to the
    // same engine-level determinism contract as the dense kernels.
    // Under an ambient SPADA_BUF_CAP (the CI backpressure leg) sparse
    // dataflows may legitimately wedge as a classified buffer deadlock
    // (tests/buffers.rs pins that contract), so this completion-assuming
    // property skips them there.
    let capped = std::env::var_os("SPADA_BUF_CAP").is_some();
    let all: Vec<&'static str> = kernels::specs()
        .into_iter()
        .filter(|s| !(capped && s.sparse))
        .map(|s| s.name)
        .collect();

    fn run_at(
        kernel: &str,
        k: i64,
        g: i64,
        seed: u64,
        threads: usize,
    ) -> (RunReport, Vec<(String, Vec<u32>)>) {
        let (binds, w, h) =
            spada::harness::common::scaled_binds(kernel, g, k).expect("library kernel");
        let cfg = MachineConfig::with_grid(w, h);
        let ck = kernels::compile(kernel, &binds, &cfg, &Options::default())
            .unwrap_or_else(|e| panic!("{kernel} g={g} k={k}: {e:#}"));
        let mut sim = ck.simulator().unwrap();
        sim.set_threads(threads);
        stage_kernel_inputs(&mut sim, kernel, g, k, seed).expect("staging");
        let report = sim
            .run()
            .unwrap_or_else(|e| panic!("{kernel} g={g} threads={threads}: {e}"));
        let outs = output_words(&sim);
        (report, outs)
    }

    run_prop(
        "parallel-determinism",
        0x9AD,
        6,
        |r| {
            (
                all[r.below(all.len() as u64) as usize],
                1 + r.below(24) as i64, // K
                3 + r.below(3) as i64,  // grid dimension
                r.next_u64(),           // input seed
            )
        },
        |(kernel, k, g, seed)| {
            // Tree-combining (and sparse) kernels instantiate only on
            // power-of-two grid sides — the registry records which.
            let g = if kernels::spec(kernel).expect("registry kernel").grid_pow2 {
                if *g <= 4 {
                    4
                } else {
                    8
                }
            } else {
                *g
            };
            let (base_report, base_outs) = run_at(kernel, *k, g, *seed, 1);
            for threads in [2, 4, 8] {
                let (report, outs) = run_at(kernel, *k, g, *seed, threads);
                if report != base_report {
                    return Err(format!(
                        "RunReport diverged at threads={threads}: {report:?} vs {base_report:?}"
                    ));
                }
                if outs != base_outs {
                    return Err(format!("output words diverged at threads={threads}"));
                }
            }
            Ok(())
        },
    );
}

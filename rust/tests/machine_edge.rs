//! Edge-case integration tests for the WSE-2 simulator: link contention
//! serialization, 16-bit SIMD timing, runaway guards, and CSL emission
//! sanity.

use spada::csl;
use spada::kernels;
use spada::machine::{MachineConfig, Simulator};
use spada::passes::Options;
use spada::sem::instantiate;
use spada::spada::parse_kernel;

fn binds(pairs: &[(&str, i64)]) -> spada::sem::Bindings {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Two sequential sends on the same stream serialize on the shared link:
/// the second flow's arrival is pushed behind the first.
#[test]
fn link_contention_serializes_flows() {
    let src = "kernel @two_sends<K>(stream<f32>[1] readonly a_in, stream<f32>[1] writeonly out) {
        place i16 i, i16 j in [0:2, 0] { f32[K] a f32[K] b }
        phase {
            compute i32 i, i32 j in [0, 0] { await receive(a, a_in[0]) }
        }
        phase {
            dataflow i32 i, i32 j in [0:2, 0] {
                stream<f32> s1 = relative_stream(1, 0)
                stream<f32> s2 = relative_stream(1, 0)
            }
            compute i32 i, i32 j in [0, 0] {
                completion c1 = send(a, s1)
                completion c2 = send(a, s2)
                await c1
                await c2
            }
            compute i32 i, i32 j in [1, 0] {
                await receive(a, s1)
                await receive(b, s2)
            }
        }
        phase {
            compute i32 i, i32 j in [1, 0] {
                map i32 k in [0:K] { a[k] = a[k] + b[k] }
                await send(a, out[0])
            }
        }
    }";
    let k = 64i64;
    let kast = parse_kernel(src).unwrap();
    let prog = instantiate(&kast, &binds(&[("K", k)])).unwrap();
    let cfg = MachineConfig::with_grid(2, 1);
    let compiled = csl::compile(&prog, &cfg, &Options::default()).unwrap();
    // Two streams over the same link → two colors.
    assert_eq!(compiled.stats.colors_used, 2);
    let mut sim = Simulator::new(cfg, compiled.machine).unwrap();
    let data: Vec<f32> = (0..k).map(|i| i as f32).collect();
    sim.set_input("a_in", &data).unwrap();
    let report = sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    for (i, v) in out.iter().enumerate() {
        assert_eq!(*v, 2.0 * i as f32);
    }
    // Both K-word flows cross the single east link: the makespan must
    // include the serialized second flow (≥ 2K link cycles).
    assert!(report.cycles >= 2 * k as u64, "cycles = {}", report.cycles);
}

/// 16-bit element ops run at 4-way SIMD in the cycle model.
#[test]
fn simd16_timing() {
    use spada::machine::program::*;
    use spada::util::Subgrid;
    let n = 64u32;
    let mk_class = |ty: Dtype, x: i64| PeClass {
        name: format!("c{x}"),
        subgrids: vec![Subgrid::point(x, 0)],
        fields: vec![FieldAlloc { name: "a".into(), addr: 0, len: n, ty, is_extern: false }],
        mem_size: 4 * n,
        tasks: vec![TaskDef {
            name: "fill".into(),
            hw_id: 24,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::Dsd(DsdOp {
                kind: DsdKind::Fill,
                dst: DsdRef::Mem {
                    base: 0,
                    offset: SExpr::imm(0),
                    stride: 1,
                    len: SExpr::imm(n as i64),
                    ty,
                },
                src0: None,
                src1: None,
                scalar: Some(SExpr::ImmF(1.0)),
                is_async: false,
                on_complete: vec![],
            })],
        }],
        entry_tasks: vec![24],
    };
    let run = |ty: Dtype| -> u64 {
        let prog = MachineProgram {
            name: "simd".into(),
            classes: vec![mk_class(ty, 0)],
            ..Default::default()
        };
        let mut sim = Simulator::new(MachineConfig::with_grid(1, 1), prog).unwrap();
        sim.run().unwrap().cycles
    };
    let c32 = run(Dtype::F32);
    let c16 = run(Dtype::F16);
    assert!(c16 < c32, "f16 SIMD must be faster: {c16} vs {c32}");
    // 64 elems: f32 = 64 cycles, f16 = 16 cycles (+ fixed overheads).
    assert_eq!(c32 - c16, 48);
}

/// The generated CSL text contains the structures the paper describes:
/// per-PE layout lines, color configs, task bindings.
#[test]
fn csl_emission_structure() {
    let cfg = MachineConfig::with_grid(8, 1);
    let kast = parse_kernel(kernels::CHAIN_REDUCE).unwrap();
    let prog = instantiate(&kast, &binds(&[("K", 16), ("N", 8)])).unwrap();
    let compiled = csl::compile(&prog, &cfg, &Options::default()).unwrap();
    let layout = compiled
        .csl_files
        .iter()
        .find(|(n, _)| n == "layout.csl")
        .map(|(_, t)| t.clone())
        .unwrap();
    assert!(layout.contains("@set_rectangle(8, 1);"));
    assert_eq!(layout.matches("@set_tile_code").count(), 8); // one per PE
    assert!(layout.contains("@set_color_config"));
    let code = compiled
        .csl_files
        .iter()
        .find(|(n, _)| n.starts_with("pe_class_"))
        .map(|(_, t)| t.clone())
        .unwrap();
    assert!(code.contains("@bind_local_task_id"));
    assert!(code.contains("fabout_dsd") || code.contains("fabin_dsd"));
    // Host script emitted too.
    assert!(compiled.csl_files.iter().any(|(n, _)| n == "run.py"));
}

/// Event-budget runaway guard fires instead of hanging.
#[test]
fn runaway_guard() {
    use spada::machine::program::*;
    use spada::util::Subgrid;
    // A task that re-activates itself forever.
    let class = PeClass {
        name: "spin".into(),
        subgrids: vec![Subgrid::point(0, 0)],
        fields: vec![],
        mem_size: 4,
        tasks: vec![TaskDef {
            name: "spin".into(),
            hw_id: 24,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::Control(TaskAction::activate(24))],
        }],
        entry_tasks: vec![24],
    };
    let prog = MachineProgram { name: "spin".into(), classes: vec![class], ..Default::default() };
    let mut cfg = MachineConfig::with_grid(1, 1);
    cfg.max_events = 10_000;
    let err = Simulator::new(cfg, prog).unwrap().run().unwrap_err();
    assert!(matches!(err, spada::machine::SimError::Runaway(_)), "{err}");
}

//! Batched-vs-interpreted DSD execution equivalence.
//!
//! The slice-kernel engine (see `machine/vecop.rs`) claims bit-identity
//! with the per-element interpreter: same cycles, same metrics, same
//! destination memory, same fabric word streams. This suite runs every
//! library kernel twice over identical inputs — batched engine forced
//! on, then forced off — and asserts the full `RunReport` and every
//! output argument's raw words are equal. `SPADA_NO_VEC=1` is the
//! environment-variable form of the same switch.

use spada::harness::common::{output_words, stage_random_inputs};
use spada::kernels::{self, CompiledKernel};
use spada::machine::{MachineConfig, RunReport};
use spada::passes::Options;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Every test in this binary serializes on this lock: the env-var test
/// calls `std::env::set_var`, and `Simulator` construction reads
/// `SPADA_NO_VEC` via `std::env::var_os` — concurrent setenv/getenv is
/// a data race on glibc, so nothing here may construct a simulator
/// while another thread mutates the environment.
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Compile one library kernel at a modest grid.
fn compile(name: &str, binds: &[(&str, i64)], w: i64, h: i64) -> CompiledKernel {
    let cfg = MachineConfig::with_grid(w, h);
    kernels::compile(name, binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// Run a fresh simulator over deterministic inputs with the batched
/// engine toggled, returning the report, all raw output words, and the
/// number of slice-kernel executions.
fn run_mode(ck: &CompiledKernel, vectorize: bool) -> (RunReport, Vec<(String, Vec<u32>)>, u64) {
    let mut sim = ck.simulator().unwrap();
    sim.set_vectorize(vectorize);
    // Fill every input binding with the same deterministic noise in
    // both modes (binding order is deterministic).
    stage_random_inputs(&mut sim, 0xD5D);
    let report = sim.run().unwrap_or_else(|e| panic!("{}: {e}", ck.machine.name));
    let outs = output_words(&sim);
    (report, outs, sim.vec_ops_executed())
}

fn assert_equivalent(name: &str, ck: &CompiledKernel) {
    let _guard = env_lock();
    let (vec_report, vec_outs, vec_ops) = run_mode(ck, true);
    let (int_report, int_outs, int_ops) = run_mode(ck, false);
    // The batched engine must actually engage (every library kernel
    // issues at least one contiguous f32 op), and the interpreter run
    // must not.
    assert!(vec_ops > 0, "{name}: batched engine never engaged");
    assert_eq!(int_ops, 0, "{name}: interpreter run used slice kernels");
    // Cycles, every metric counter, and resource usage: identical.
    assert_eq!(vec_report, int_report, "{name}: RunReport diverged between engines");
    // Output memory: bit-identical words.
    assert_eq!(
        vec_outs.len(),
        int_outs.len(),
        "{name}: output binding count diverged"
    );
    for ((va, vw), (ia, iw)) in vec_outs.iter().zip(&int_outs) {
        assert_eq!(va, ia, "{name}: output order diverged");
        assert_eq!(vw, iw, "{name}: output {va} diverged between engines");
    }
}

#[test]
fn chain_reduce_batched_equivalent() {
    assert_equivalent(
        "chain_reduce",
        &compile("chain_reduce", &[("K", 24), ("N", 7)], 7, 1),
    );
}

#[test]
fn broadcast_batched_equivalent() {
    assert_equivalent("broadcast", &compile("broadcast", &[("K", 16), ("N", 6)], 6, 1));
}

#[test]
fn tree_reduce_batched_equivalent() {
    assert_equivalent(
        "tree_reduce",
        &compile("tree_reduce", &[("K", 8), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

#[test]
fn two_phase_reduce_batched_equivalent() {
    assert_equivalent(
        "two_phase_reduce",
        &compile("two_phase_reduce", &[("K", 8), ("NX", 3), ("NY", 3)], 3, 3),
    );
}

#[test]
fn gemv_batched_equivalent() {
    assert_equivalent(
        "gemv",
        &compile("gemv", &[("M", 8), ("N", 8), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

#[test]
fn gemv_tree_batched_equivalent() {
    assert_equivalent(
        "gemv_tree",
        &compile("gemv_tree", &[("M", 8), ("N", 8), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

/// `SPADA_NO_VEC` in the environment disables the batched engine at
/// construction time. Holds the binary-wide env lock so no other test
/// constructs a simulator (reads the environment) while this one
/// mutates it.
#[test]
fn env_var_disables_batched_engine() {
    let ck = compile("broadcast", &[("K", 8), ("N", 4)], 4, 1);
    let _guard = env_lock();
    std::env::set_var("SPADA_NO_VEC", "1");
    let sim = ck.simulator().unwrap();
    std::env::remove_var("SPADA_NO_VEC");
    assert!(!sim.vectorize_enabled(), "SPADA_NO_VEC must disable vectorization");
    let sim2 = ck.simulator().unwrap();
    assert!(sim2.vectorize_enabled(), "default must be enabled");
}

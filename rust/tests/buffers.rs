//! Finite-buffer flow control: runtime/static agreement and
//! finite≈unbounded equivalence.
//!
//! The credit-based buffer model (`machine::flowctl` +
//! `analysis::credits`) promises three things, each pinned here:
//!
//! 1. **Negative fixture.** A kernel that completes on the unbounded
//!    machine but wedges at a small endpoint capacity is caught *both*
//!    ways: the simulator reports a buffer deadlock naming the blocked
//!    endpoint, and the static credit pass flags the same endpoint as
//!    a certain wedge — the two verdicts cross-reference each other.
//! 2. **Generously-finite equivalence.** Any capacity at or above the
//!    unbounded run's observed peak queue depth reproduces the
//!    unbounded run bit for bit — `RunReport` and raw output words —
//!    at 1 and 4 worker threads (property-tested over random kernels,
//!    sizes and inputs).
//! 3. **Output preservation under backpressure.** With eager consumers
//!    a tight capacity only delays words, never reorders or drops
//!    them: every library kernel either produces bit-identical
//!    *outputs* under an 8-word cap (cycles may grow; that is the
//!    point) or — for the buffer-hungry sparse dataflows — wedges
//!    with a *classified* buffer deadlock naming the endpoint, never
//!    silent corruption.

use spada::harness::common::{output_words, scaled_binds, stage_kernel_inputs, stage_random_inputs};
use spada::kernels;
use spada::machine::{
    DirSet, Direction, DsdKind, DsdOp, DsdRef, Dtype, FieldAlloc, IoBinding, IoDir,
    MachineConfig, MachineProgram, MOp, PeClass, PortMap, RouteRule, RunReport, SExpr, SimError,
    SimOptions, Simulator, TaskDef, TaskKind,
};
use spada::passes::Options;
use spada::ptest::run_prop;
use spada::util::Subgrid;

/// A 2-PE fixture: the sender ships `send` words east on `color`, the
/// receiver consumes only `recv` of them. Legal on an unbounded
/// fabric (leftover words park at the endpoint); wedged whenever
/// `send - recv` exceeds the endpoint capacity.
fn unbalanced_prog(color: u8, send: u32, recv: u32) -> MachineProgram {
    let sender = PeClass {
        name: "sender".into(),
        subgrids: vec![Subgrid::point(0, 0)],
        fields: vec![FieldAlloc {
            name: "a".into(),
            addr: 0,
            len: send,
            ty: Dtype::F32,
            is_extern: true,
        }],
        mem_size: 4 * send,
        tasks: vec![TaskDef {
            name: "send".into(),
            hw_id: 25,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::Dsd(DsdOp {
                kind: DsdKind::Mov,
                dst: DsdRef::FabOut { color, len: SExpr::imm(send as i64), ty: Dtype::F32 },
                src0: Some(DsdRef::mem(0, SExpr::imm(send as i64), Dtype::F32)),
                src1: None,
                scalar: None,
                is_async: true,
                on_complete: vec![],
            })],
        }],
        entry_tasks: vec![25],
    };
    let receiver = PeClass {
        name: "recv".into(),
        subgrids: vec![Subgrid::point(1, 0)],
        fields: vec![FieldAlloc {
            name: "b".into(),
            addr: 0,
            len: recv,
            ty: Dtype::F32,
            is_extern: true,
        }],
        mem_size: 4 * recv,
        tasks: vec![TaskDef {
            name: "recv".into(),
            hw_id: 26,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::Dsd(DsdOp {
                kind: DsdKind::Mov,
                dst: DsdRef::mem(0, SExpr::imm(recv as i64), Dtype::F32),
                src0: Some(DsdRef::FabIn {
                    color,
                    len: SExpr::imm(recv as i64),
                    ty: Dtype::F32,
                }),
                src1: None,
                scalar: None,
                is_async: true,
                on_complete: vec![],
            })],
        }],
        entry_tasks: vec![26],
    };
    MachineProgram {
        name: "unbalanced".into(),
        classes: vec![sender, receiver],
        routes: vec![
            RouteRule {
                color,
                subgrid: Subgrid::point(0, 0),
                rx: DirSet::single(Direction::Ramp),
                tx: DirSet::single(Direction::East),
            },
            RouteRule {
                color,
                subgrid: Subgrid::point(1, 0),
                rx: DirSet::single(Direction::West),
                tx: DirSet::single(Direction::Ramp),
            },
        ],
        io: vec![
            IoBinding {
                arg: "a".into(),
                field: "a".into(),
                dir: IoDir::In,
                subgrid: Subgrid::point(0, 0),
                elems_per_pe: send,
                total_ports: 1,
                port_map: PortMap::default(),
                ty: Dtype::F32,
            },
            IoBinding {
                arg: "b".into(),
                field: "b".into(),
                dir: IoDir::Out,
                subgrid: Subgrid::point(1, 0),
                elems_per_pe: recv,
                total_ports: 1,
                port_map: PortMap::default(),
                ty: Dtype::F32,
            },
        ],
        colors_used: vec![color],
        ..Default::default()
    }
}

/// Grid config with an explicit capacity — explicit `None` shields the
/// unbounded baselines from an ambient `SPADA_BUF_CAP` (the CI cap leg
/// runs this whole suite with it set).
fn cfg_with_cap(w: i64, h: i64, cap: Option<u64>) -> MachineConfig {
    let mut cfg = MachineConfig::with_grid(w, h);
    cfg.endpoint_capacity_words = cap;
    cfg
}

fn run_unbalanced(cap: Option<u64>) -> Result<(RunReport, Vec<f32>), SimError> {
    let mut sim = Simulator::new(cfg_with_cap(2, 1, cap), unbalanced_prog(1, 16, 4))?;
    sim.set_threads(1);
    sim.set_input("a", &(0..16).map(|i| i as f32).collect::<Vec<f32>>())?;
    let report = sim.run()?;
    let out = sim.get_output("b")?;
    Ok((report, out))
}

/// The negative fixture end to end: completes unbounded, wedges at a
/// small capacity, and the runtime report cross-references the static
/// verdict — which flags the very same endpoint.
#[test]
fn fixture_deadlocks_at_small_capacity_and_static_agrees() {
    // Unbounded: completes, leftover words legally park at the endpoint.
    let (report, out) = run_unbalanced(None).expect("unbounded run completes");
    assert_eq!(out, (0..4).map(|i| i as f32).collect::<Vec<f32>>());
    assert_eq!(report.metrics.stall_cycles, 0);
    assert!(report.metrics.peak_queue_depth >= 12, "leftover words occupy the endpoint");

    // Capacity 8 < 12 leftover words: runtime buffer deadlock.
    let err = run_unbalanced(Some(8)).expect_err("12 leftover words exceed an 8-word cap");
    let SimError::Deadlock(msg) = err else { panic!("want Deadlock, got {err}") };
    assert!(msg.contains("endpoint full"), "{msg}");
    assert!(msg.contains("stalled"), "{msg}");
    // The runtime message cites the static credit verdict.
    assert!(msg.contains("spada check --buffers"), "{msg}");
    assert!(msg.contains("buffer-deadlock"), "static verdict must be quoted: {msg}");

    // The static pass, on its own, flags the same endpoint.
    let report = spada::analysis::check(&unbalanced_prog(1, 16, 4), &cfg_with_cap(2, 1, Some(8)));
    let diag = report
        .diagnostics
        .iter()
        .find(|d| d.kind == spada::analysis::DiagKind::BufferDeadlock)
        .expect("static credit pass must flag the wedge");
    assert_eq!(diag.severity, spada::analysis::Severity::Error);
    assert_eq!(diag.pe, Some((1, 0)), "the blocked endpoint is the receiver's");
    assert_eq!(diag.color, Some(1));

    // A capacity that absorbs the leftover completes again, with the
    // unbounded outputs.
    let (_, out12) = run_unbalanced(Some(12)).expect("leftover fits a 12-word buffer");
    assert_eq!(out12, out);
}

/// Generously-finite equivalence, property-tested: for random library
/// kernels, sizes and inputs, a capacity at (or above) the unbounded
/// run's peak queue depth is bit-identical — report and output words —
/// at 1 and 4 worker threads.
#[test]
fn prop_finite_cap_at_peak_depth_is_bit_identical() {
    // The whole registry, sparse SpMV variants included: the
    // cap-at-peak guarantee is engine-level and kernel-agnostic.
    let all = kernels::names();

    fn run_at(
        kernel: &str,
        g: i64,
        k: i64,
        seed: u64,
        cap: Option<u64>,
        threads: usize,
    ) -> (RunReport, Vec<(String, Vec<u32>)>) {
        let (binds, w, h) = scaled_binds(kernel, g, k).expect("library kernel");
        let cfg = cfg_with_cap(w, h, cap);
        let ck = kernels::compile(kernel, &binds, &cfg, &Options::default())
            .unwrap_or_else(|e| panic!("{kernel} g={g}: {e:#}"));
        // Explicit options: an ambient SPADA_BUF_CAP must not fill the
        // deliberately-unbounded baseline config.
        let mut sim = ck.simulator_with(&SimOptions::default().threads(threads)).unwrap();
        stage_kernel_inputs(&mut sim, kernel, g, k, seed).expect("staging the registry workload");
        let report = sim
            .run()
            .unwrap_or_else(|e| panic!("{kernel} g={g} cap={cap:?} threads={threads}: {e}"));
        let outs = output_words(&sim);
        (report, outs)
    }

    run_prop(
        "finite-cap-equivalence",
        0xBFC,
        5,
        |r| {
            (
                all[r.below(all.len() as u64) as usize],
                1 + r.below(16) as i64, // K
                4i64,                   // grid dimension (tree kernels need a power of two)
                r.next_u64(),
            )
        },
        |(kernel, k, g, seed)| {
            let (base, base_outs) = run_at(kernel, *g, *k, *seed, None, 1);
            let peak = base.metrics.peak_queue_depth;
            if peak == 0 {
                return Err(format!("{kernel}: fabric kernel must buffer at least one word"));
            }
            for threads in [1usize, 4] {
                let (capped, outs) = run_at(kernel, *g, *k, *seed, Some(peak), threads);
                if capped != base {
                    return Err(format!(
                        "{kernel} cap={peak} threads={threads}: RunReport diverged from the \
                         unbounded run"
                    ));
                }
                if outs != base_outs {
                    return Err(format!(
                        "{kernel} cap={peak} threads={threads}: outputs diverged"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Backpressure preserves values: every registry kernel under a tight
/// 8-word endpoint cap either completes with outputs bit-identical to
/// the unbounded run (cycles may grow — consumers gate on delayed
/// words — but nothing reorders or drops) or, for the buffer-hungry
/// sparse dataflows, wedges with a *classified* buffer deadlock that
/// names a blocked endpoint — never a silent wrong answer.
#[test]
fn all_kernels_outputs_identical_under_backpressure() {
    for kernel in kernels::names() {
        let (binds, w, h) = scaled_binds(kernel, 4, 16).expect("library kernel");
        let run = |cap: Option<u64>| {
            let cfg = cfg_with_cap(w, h, cap);
            let ck = kernels::compile(kernel, &binds, &cfg, &Options::default())
                .unwrap_or_else(|e| panic!("{kernel}: {e:#}"));
            // Explicit options: an ambient SPADA_BUF_CAP must not fill
            // the deliberately-unbounded baseline config.
            let mut sim = ck.simulator_with(&SimOptions::default().threads(1)).unwrap();
            stage_kernel_inputs(&mut sim, kernel, 4, 16, 0xCAB).expect("staging");
            let result = sim.run();
            let outs = output_words(&sim);
            (result, outs)
        };
        let (base, base_outs) = run(None);
        let base = base.unwrap_or_else(|e| panic!("{kernel} unbounded: {e}"));
        match run(Some(8)) {
            (Ok(capped), outs) => {
                assert_eq!(outs, base_outs, "{kernel}: outputs must survive backpressure");
                assert_eq!(
                    capped.metrics.wavelets, base.metrics.wavelets,
                    "{kernel}: traffic volume is capacity-independent"
                );
                assert!(
                    capped.cycles >= base.cycles,
                    "{kernel}: backpressure can only delay ({} < {})",
                    capped.cycles,
                    base.cycles
                );
            }
            (Err(SimError::Deadlock(msg)), _) => {
                // An under-provisioned cap may legitimately wedge a
                // sparse dataflow — but only as a classified buffer
                // deadlock naming the blocked endpoint.
                assert!(
                    msg.contains("endpoint full"),
                    "{kernel}: capped wedge must be classified as a buffer deadlock: {msg}"
                );
                assert!(
                    msg.contains("PE ("),
                    "{kernel}: buffer-deadlock report must name an endpoint: {msg}"
                );
            }
            (Err(e), _) => panic!("{kernel} cap=8: unexpected failure class: {e}"),
        }
    }
}

/// The capped engines agree with each other: under an 8-word cap the
/// epoch-parallel engine is bit-identical to the single-queue loop
/// (stall state is endpoint-local; admission order is the merged
/// deterministic arrival order).
#[test]
fn capped_runs_bit_identical_across_threads() {
    let (binds, w, h) = scaled_binds("chain_reduce", 8, 24).expect("library kernel");
    let cfg = cfg_with_cap(w, h, Some(8));
    let ck = kernels::compile("chain_reduce", &binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{e:#}"));
    let run = |threads: usize| {
        let mut sim = ck.simulator().unwrap();
        sim.set_threads(threads);
        stage_random_inputs(&mut sim, 0x5EED);
        let report = sim.run().unwrap_or_else(|e| panic!("threads={threads}: {e}"));
        (report, output_words(&sim))
    };
    let (base, base_outs) = run(1);
    for threads in [2usize, 4, 8] {
        let (report, outs) = run(threads);
        assert_eq!(report, base, "capped RunReport diverged at threads={threads}");
        assert_eq!(outs, base_outs, "capped outputs diverged at threads={threads}");
    }
}

/// `spada check --buffers` surfaces sizing hints on the unbounded
/// model (audit mode) while the default pipeline stays silent.
#[test]
fn buffer_audit_reports_sizing_only_on_request() {
    let prog = unbalanced_prog(1, 16, 4);
    let cfg = cfg_with_cap(2, 1, None);

    let plain = spada::analysis::check(&prog, &cfg);
    assert!(
        !plain.has_kind(spada::analysis::DiagKind::BufferDeadlock),
        "unbounded default check must not warn:\n{plain}"
    );

    let plan = spada::machine::RoutingPlan::build(&prog, &cfg);
    let audited = spada::analysis::check_buffers(&prog, &cfg, &plan);
    let diag = audited
        .diagnostics
        .iter()
        .find(|d| d.kind == spada::analysis::DiagKind::BufferDeadlock)
        .expect("audit must emit the sizing warning");
    assert_eq!(diag.severity, spada::analysis::Severity::Warning);
    assert!(diag.message.contains(">= 12"), "{}", diag.message);
}

//! Batch fleet engine contracts (the `spada batch` service surface):
//!
//! 1. **Pool-width determinism** — the same job list yields
//!    byte-identical result rows at pool widths 1, 2 and 4, including
//!    jobs with per-job option overrides (finite buffers, faults,
//!    pinned threads).
//! 2. **Per-job isolation** — an unknown-kernel job and a 1 ms-watchdog
//!    job become error rows; every sibling still completes.
//! 3. **Compile-once** — N jobs over S distinct shapes perform exactly
//!    S compiles and N lookups, and exactly the first job of each shape
//!    (in input order) is labeled the cache miss.
//! 4. **Spec JSONL** — the flat-object job grammar round-trips every
//!    override and rejects garbage without aborting the stream.

use spada::fleet::{parse_jobs, run_batch, FleetOptions, JobSpec, PlanCache};

/// Collect the emitted rows (in emission order) plus the summary.
fn run(jobs: &[JobSpec], pool: usize, cache: &PlanCache) -> (Vec<String>, spada::fleet::BatchSummary) {
    let mut rows = Vec::new();
    let fleet = FleetOptions { pool, budget: pool * 2 };
    let summary = run_batch(jobs, &fleet, cache, |r| rows.push(r.to_jsonl()));
    (rows, summary)
}

/// A mixed workload: duplicate shapes, differing seeds, a finite-buffer
/// variant, a no-vectorize variant, a pinned-thread variant and a
/// single-fault variant. No watchdog jobs here — wall-clock outcomes
/// are the one thing the determinism contract cannot cover.
fn mixed_jobs() -> Vec<JobSpec> {
    let mut jobs = Vec::new();
    for (i, (kernel, g, seed)) in [
        ("broadcast", 4, 1u64),
        ("chain_reduce", 4, 2),
        ("broadcast", 4, 3), // same shape as job 0, different inputs
        ("tree_reduce", 4, 1),
        ("gemv", 4, 1),
        ("chain_reduce", 4, 2), // exact duplicate of job 1
    ]
    .iter()
    .enumerate()
    {
        jobs.push(JobSpec {
            id: format!("j{i}"),
            kernel: kernel.to_string(),
            g: *g,
            k: 8,
            seed: *seed,
            ..JobSpec::default()
        });
    }
    jobs.push(JobSpec {
        id: "capped".into(),
        kernel: "gemv".into(),
        g: 4,
        k: 8,
        seed: 1,
        buf_cap: Some(64),
        ..JobSpec::default()
    });
    jobs.push(JobSpec {
        id: "novec".into(),
        kernel: "tree_reduce".into(),
        g: 4,
        k: 8,
        seed: 1,
        no_vec: true,
        ..JobSpec::default()
    });
    jobs.push(JobSpec {
        id: "pinned".into(),
        kernel: "broadcast".into(),
        g: 4,
        k: 8,
        seed: 1,
        threads: Some(3),
        ..JobSpec::default()
    });
    jobs.push(JobSpec {
        id: "faulted".into(),
        kernel: "broadcast".into(),
        g: 4,
        k: 8,
        seed: 1,
        faults: Some("link(0,0,E):slow@10+5".into()),
        ..JobSpec::default()
    });
    jobs
}

#[test]
fn rows_are_byte_identical_at_pool_widths_1_2_4() {
    let jobs = mixed_jobs();
    let mut streams = Vec::new();
    for pool in [1usize, 2, 4] {
        // Fresh cache per width: every run does the same compile work.
        let (rows, summary) = run(&jobs, pool, &PlanCache::new());
        assert_eq!(summary.jobs, jobs.len(), "pool {pool} dropped jobs");
        assert_eq!(summary.errors, 0, "pool {pool} produced error rows");
        streams.push((pool, rows.concat()));
    }
    let (_, reference) = &streams[0];
    for (pool, stream) in &streams[1..] {
        assert_eq!(
            stream, reference,
            "pool {pool} rows differ from pool 1 rows (determinism contract)"
        );
    }
    // Rows carry simulated observables only — wall-clock never leaks in.
    assert!(!reference.contains("wall"), "rows must not contain wall-clock fields");
}

#[test]
fn error_jobs_become_rows_and_siblings_complete() {
    let jobs = vec![
        JobSpec { id: "ok1".into(), kernel: "broadcast".into(), g: 4, k: 8, ..JobSpec::default() },
        JobSpec { id: "bad".into(), kernel: "no_such_kernel".into(), ..JobSpec::default() },
        JobSpec { id: "ok2".into(), kernel: "chain_reduce".into(), g: 4, k: 8, ..JobSpec::default() },
        // A deliberately impossible watchdog: a 1024-PE GEMV cannot
        // finish inside 1 ms of wall clock, so the watchdog fires and
        // the row must carry the *normalized* timeout message (the
        // engine's own message embeds progress cycles, which vary).
        JobSpec {
            id: "strangled".into(),
            kernel: "gemv".into(),
            g: 32,
            k: 8,
            timeout_ms: Some(1),
            ..JobSpec::default()
        },
        JobSpec { id: "ok3".into(), kernel: "tree_reduce".into(), g: 4, k: 8, ..JobSpec::default() },
    ];
    let (rows, summary) = run(&jobs, 4, &PlanCache::new());
    assert_eq!(rows.len(), 5);
    assert_eq!(summary.ok, 3);
    assert_eq!(summary.errors, 2);
    // Input order is preserved even when the middle jobs fail.
    for (i, id) in ["ok1", "bad", "ok2", "strangled", "ok3"].iter().enumerate() {
        assert!(rows[i].contains(&format!("\"id\":\"{id}\"")), "row {i} is not {id}: {}", rows[i]);
    }
    assert!(rows[0].contains("\"ok\":true"));
    assert!(rows[1].contains("\"ok\":false") && rows[1].contains("\"kind\":\"spec\""));
    assert!(rows[2].contains("\"ok\":true"));
    assert!(
        rows[3].contains("\"kind\":\"timeout\"")
            && rows[3].contains("wall-clock watchdog fired"),
        "timeout row must be normalized: {}",
        rows[3]
    );
    assert!(rows[4].contains("\"ok\":true"));
}

#[test]
fn each_distinct_shape_compiles_exactly_once() {
    // 12 jobs, 3 distinct shapes. Per-job run options (buffer caps)
    // must not split the cache key; seeds obviously must not either.
    let shapes = ["broadcast", "chain_reduce", "tree_reduce"];
    let mut jobs = Vec::new();
    for round in 0..4u64 {
        for kernel in shapes {
            jobs.push(JobSpec {
                id: format!("{kernel}-{round}"),
                kernel: kernel.to_string(),
                g: 4,
                k: 8,
                seed: round,
                buf_cap: if round == 3 { Some(128) } else { None },
                ..JobSpec::default()
            });
        }
    }
    let cache = PlanCache::new();
    let (rows, summary) = run(&jobs, 4, &cache);
    assert_eq!(summary.compiles, 3, "one compile per distinct shape");
    assert_eq!(summary.lookups, 12, "every job consults the cache");
    assert_eq!(cache.compiles(), 3);
    assert_eq!(cache.len(), 3);
    // Exactly the first job of each shape (input order) is the miss.
    let misses: Vec<bool> = rows.iter().map(|r| r.contains("\"cache\":\"miss\"")).collect();
    let want: Vec<bool> = (0..12).map(|i| i < 3).collect();
    assert_eq!(misses, want, "hit/miss labels must follow input order, not the compile race");
}

#[test]
fn job_spec_jsonl_round_trips_and_rejects_garbage() {
    let text = concat!(
        "# fleet smoke\n",
        "\n",
        "{\"kernel\":\"gemv\",\"g\":8,\"k\":16,\"seed\":7}\n",
        "{\"id\":\"x\",\"kernel\":\"broadcast\",\"buf_cap\":64,\"credit_latency\":2,",
        "\"timeout_ms\":5000,\"threads\":2,\"no_vec\":true,",
        "\"faults\":\"pe(1,0):halt@50\",\"ignored_key\":\"fine\"}\n",
        "{\"kernel\":\"gemv\",\"g\":0}\n",
        "{\"g\":4}\n",
        "not json at all\n",
    );
    let parsed = parse_jobs(text);
    assert_eq!(parsed.len(), 5);

    let a = parsed[0].as_ref().unwrap();
    assert_eq!((a.id.as_str(), a.kernel.as_str(), a.g, a.k, a.seed), ("job-3", "gemv", 8, 16, 7));

    let b = parsed[1].as_ref().unwrap();
    assert_eq!(b.id, "x");
    assert_eq!(b.buf_cap, Some(64));
    assert_eq!(b.credit_latency, Some(2));
    assert_eq!(b.timeout_ms, Some(5000));
    assert_eq!(b.threads, Some(2));
    assert!(b.no_vec);
    assert_eq!(b.faults.as_deref(), Some("pe(1,0):halt@50"));

    // Bad lines keep their line-derived ids so row K still answers for
    // input line K.
    assert_eq!(parsed[2].as_ref().unwrap_err().0, "job-5");
    assert_eq!(parsed[3].as_ref().unwrap_err().0, "job-6");
    assert_eq!(parsed[4].as_ref().unwrap_err().0, "job-7");
}

/// The single-resolve-site rule (docs/sim-options.md): `SPADA_*`
/// environment reads live in `machine/options.rs` and nowhere else.
/// Ambient-env reads scattered through the engine are exactly what
/// made per-job option isolation impossible before the fleet.
#[test]
fn env_reads_stay_in_the_options_module() {
    fn walk(dir: &std::path::Path, offenders: &mut Vec<String>) {
        for entry in std::fs::read_dir(dir).expect("source tree is readable") {
            let path = entry.expect("dir entry").path();
            if path.is_dir() {
                walk(&path, offenders);
            } else if path.extension().is_some_and(|e| e == "rs")
                && !path.ends_with("machine/options.rs")
            {
                let src = std::fs::read_to_string(&path).expect("source file reads");
                if src.contains("env::var") {
                    offenders.push(path.display().to_string());
                }
            }
        }
    }
    let src = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut offenders = Vec::new();
    walk(&src, &mut offenders);
    assert!(
        offenders.is_empty(),
        "environment reads outside machine/options.rs (route them through \
         SimOptions::from_env): {offenders:?}"
    );
}

#[test]
fn grid_alias_and_defaults() {
    let spec = JobSpec::parse("{\"kernel\":\"tree_reduce\",\"grid\":16}").unwrap();
    assert_eq!(spec.g, 16);
    let spec = JobSpec::parse("{\"kernel\":\"tree_reduce\"}").unwrap();
    assert_eq!((spec.g, spec.k), (4, 8));
    assert!(spec.buf_cap.is_none() && spec.faults.is_none() && spec.timeout_ms.is_none());
}

//! End-to-end integration: SpaDA source → compile → simulate → verify
//! numerics for the communication-collective kernels (paper §VI-B).

use spada::kernels;
use spada::machine::MachineConfig;
use spada::passes::Options;
use spada::util::SplitMix64;

fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..n).map(|_| rng.next_f32()).collect()
}

/// Elementwise sum of per-PE vectors.
fn expected_sum(data: &[f32], k: usize) -> Vec<f32> {
    let mut out = vec![0f32; k];
    for chunk in data.chunks(k) {
        for (o, v) in out.iter_mut().zip(chunk) {
            *o += v;
        }
    }
    out
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            (g - w).abs() <= tol * (1.0 + w.abs()),
            "{what}[{i}]: got {g}, want {w}"
        );
    }
}

#[test]
fn chain_reduce_e2e() {
    let (k, n) = (32usize, 8i64);
    let cfg = MachineConfig::with_grid(n, 1);
    let ck =
        kernels::compile("chain_reduce", &[("K", k as i64), ("N", n)], &cfg, &Options::default())
            .unwrap();
    assert!(ck.stats.colors_used >= 2, "chain needs red+blue: {:?}", ck.stats);
    let mut sim = ck.simulator().unwrap();
    let data = rand_vec(1, k * n as usize);
    sim.set_input("a_in", &data).unwrap();
    let report = sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    assert_close(&out, &expected_sum(&data, k), 1e-5, "chain_reduce");
    // Pipelined: makespan ~ O(K + N), far below the serialized O(K·N).
    assert!(
        report.cycles < (k as u64) * (n as u64),
        "chain reduce not pipelined: {} cycles",
        report.cycles
    );
}

#[test]
fn chain_reduce_larger() {
    let (k, n) = (256usize, 17i64); // odd PE count exercises both corners
    let cfg = MachineConfig::with_grid(n, 1);
    let ck =
        kernels::compile("chain_reduce", &[("K", k as i64), ("N", n)], &cfg, &Options::default())
            .unwrap();
    let mut sim = ck.simulator().unwrap();
    let data = rand_vec(2, k * n as usize);
    sim.set_input("a_in", &data).unwrap();
    sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    assert_close(&out, &expected_sum(&data, k), 1e-4, "chain_reduce_17");
}

#[test]
fn broadcast_e2e() {
    let (k, n) = (64usize, 8i64);
    let cfg = MachineConfig::with_grid(n, 1);
    let ck =
        kernels::compile("broadcast", &[("K", k as i64), ("N", n)], &cfg, &Options::default())
            .unwrap();
    let mut sim = ck.simulator().unwrap();
    let data = rand_vec(3, k);
    sim.set_input("a_in", &data).unwrap();
    let report = sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    assert_eq!(out.len(), k * n as usize);
    for p in 0..n as usize {
        assert_close(&out[p * k..(p + 1) * k], &data, 1e-6, &format!("broadcast pe {p}"));
    }
    // One multicast flow, not N point-to-point flows.
    assert_eq!(report.metrics.flows, 1, "broadcast must be a single multicast flow");
}

#[test]
fn tree_reduce_e2e() {
    let (k, nx, ny) = (16usize, 8i64, 4i64);
    let cfg = MachineConfig::with_grid(nx, ny);
    let ck = kernels::compile(
        "tree_reduce",
        &[("K", k as i64), ("NX", nx), ("NY", ny)],
        &cfg,
        &Options::default(),
    )
    .unwrap();
    // 2·log2 colors: log2(8) + log2(4) = 5.
    assert_eq!(ck.stats.colors_used, 5, "{:?}", ck.stats);
    let mut sim = ck.simulator().unwrap();
    let data = rand_vec(4, k * (nx * ny) as usize);
    sim.set_input("a_in", &data).unwrap();
    sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    assert_close(&out, &expected_sum(&data, k), 1e-4, "tree_reduce");
}

#[test]
fn two_phase_reduce_e2e() {
    let (k, nx, ny) = (32usize, 8i64, 4i64);
    let cfg = MachineConfig::with_grid(nx, ny);
    let ck = kernels::compile(
        "two_phase_reduce",
        &[("K", k as i64), ("NX", nx), ("NY", ny)],
        &cfg,
        &Options::default(),
    )
    .unwrap();
    let mut sim = ck.simulator().unwrap();
    let data = rand_vec(5, k * (nx * ny) as usize);
    sim.set_input("a_in", &data).unwrap();
    sim.run().unwrap();
    let out = sim.get_output("out").unwrap();
    assert_close(&out, &expected_sum(&data, k), 1e-4, "two_phase_reduce");
}

#[test]
fn gemv_e2e() {
    let (m, n, nx, ny) = (16i64, 12i64, 3i64, 4i64);
    let (bm, bn) = ((m / ny) as usize, (n / nx) as usize);
    let cfg = MachineConfig::with_grid(nx, ny);
    let ck = kernels::compile(
        "gemv",
        &[("M", m), ("N", n), ("NX", nx), ("NY", ny)],
        &cfg,
        &Options::default(),
    )
    .unwrap();
    let mut sim = ck.simulator().unwrap();

    // Dense A (row r, col c), distributed in column-major blocks:
    // PE (i, j) holds rows [j·bm, (j+1)·bm) × cols [i·bn, (i+1)·bn),
    // block element (r, c) at index r + c·bm, ports ordered i·NY + j.
    let a_dense = rand_vec(6, (m * n) as usize);
    let x = rand_vec(7, n as usize);
    let y0 = rand_vec(8, m as usize);
    let (alpha, beta) = (2.0f32, 0.5f32);

    let mut a_blocks = vec![0f32; (m * n) as usize];
    let mut off = 0usize;
    for i in 0..nx {
        for _j in 0..ny {
            let j = _j;
            for c in 0..bn {
                for r in 0..bm {
                    let gr = j as usize * bm + r;
                    let gc = i as usize * bn + c;
                    a_blocks[off + c * bm + r] = a_dense[gr * n as usize + gc];
                }
            }
            off += bm * bn;
        }
    }
    sim.set_input("a_blk", &a_blocks).unwrap();
    sim.set_input("x_in", &x).unwrap();
    sim.set_input("y_in", &y0).unwrap();
    sim.set_input("alpha", &[alpha]).unwrap();
    sim.set_input("beta", &[beta]).unwrap();
    sim.run().unwrap();
    let y = sim.get_output("y_out").unwrap();

    let mut want = vec![0f32; m as usize];
    for r in 0..m as usize {
        let mut acc = 0f32;
        for c in 0..n as usize {
            acc += a_dense[r * n as usize + c] * x[c];
        }
        want[r] = alpha * acc + beta * y0[r];
    }
    assert_close(&y, &want, 1e-4, "gemv");
}

#[test]
fn gemv_tree_e2e() {
    // The tree-reduction GEMV variant must agree with the dense
    // reference (grid must be a power of two for the tree levels).
    let (n, g) = (32i64, 4i64);
    let (run, y, want) = spada::harness::common::run_gemv_variant(
        "gemv_tree",
        n,
        g,
        &Options::default(),
    )
    .unwrap();
    for (a, b) in y.iter().zip(&want) {
        assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
    }
    // log2(4) = 2 row-reduction levels → more colors than the chain's 2.
    assert!(run.stats.colors_used >= 3, "{:?}", run.stats);
}

/// Ablations must change resource usage but never correctness.
#[test]
fn chain_reduce_ablations_correct() {
    let (k, n) = (16usize, 8i64);
    let data = rand_vec(9, k * n as usize);
    let want = expected_sum(&data, k);
    let mut cycles = vec![];
    for opts in [
        Options::default(),
        Options { fusion: false, ..Options::default() },
        Options { copy_elim: false, ..Options::default() },
        Options { recycling: false, ..Options::default() },
        Options::none(),
    ] {
        let cfg = MachineConfig::with_grid(n, 1);
        let ck =
            kernels::compile("chain_reduce", &[("K", k as i64), ("N", n)], &cfg, &opts).unwrap();
        let mut sim = ck.simulator().unwrap();
        sim.set_input("a_in", &data).unwrap();
        let report = sim.run().unwrap();
        let out = sim.get_output("out").unwrap();
        assert_close(&out, &want, 1e-5, &format!("{opts:?}"));
        cycles.push(report.cycles);
    }
    // Disabling all optimizations must not be faster than the default.
    assert!(cycles[4] >= cycles[0], "{cycles:?}");
}

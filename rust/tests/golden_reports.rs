//! Golden run-report tests: simulator-semantics preservation.
//!
//! Every kernel's cycle-level behaviour (cycles, event count, busy
//! cycles, task runs, flow/wavelet traffic, flops) is pinned in a
//! snapshot under `tests/golden/`. A refactor of the simulator core
//! must be cycle-identical: any drift in these fingerprints fails the
//! suite. Snapshots are created on first run (so a fresh checkout
//! bootstraps itself) and re-blessed explicitly with `SPADA_BLESS=1`
//! after an *intended* semantic change.

use spada::harness::common::{run_broadcast, run_gemv_variant, run_reduce};
use spada::machine::RunReport;
use spada::passes::Options;
use std::path::PathBuf;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The cycle-identity fingerprint of one simulation.
fn fingerprint(r: &RunReport) -> String {
    format!(
        "kernel={} grid={}x{} cycles={} events={} busy_cycles={} task_runs={} flows={} \
         wavelets={} wavelet_hops={} flops={} dsd_ops={} active_pes={}\n",
        r.kernel,
        r.width,
        r.height,
        r.cycles,
        r.metrics.events,
        r.metrics.busy_cycles,
        r.metrics.task_runs,
        r.metrics.flows,
        r.metrics.wavelets,
        r.metrics.wavelet_hops,
        r.metrics.flops,
        r.metrics.dsd_ops,
        r.metrics.active_pes,
    )
}

fn check_golden(name: &str, report: &RunReport) {
    // A finite buffer capacity (SPADA_BUF_CAP) legitimately shifts
    // cycle counts (backpressure delays word availability) while
    // leaving outputs bit-identical. The cycle-identity snapshots are
    // pinned to the unbounded machine, so skip — never bootstrap or
    // compare — when a cap is configured (the SPADA_BUF_CAP CI leg
    // gates on output equality through the equivalence suites instead).
    if spada::machine::SimOptions::from_env().buf_cap.is_some() {
        eprintln!("{name}: skipped (SPADA_BUF_CAP set; goldens pin the unbounded machine)");
        return;
    }
    let got = fingerprint(report);
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{name}.golden"));
    let bless = spada::machine::options::env_bless();
    if bless || !path.exists() {
        std::fs::write(&path, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        got, want,
        "{name}: run report diverged from the golden snapshot at {}; the simulator is no \
         longer cycle-identical. Re-bless with SPADA_BLESS=1 only for an intended semantic \
         change.",
        path.display()
    );
}

#[test]
fn golden_chain_reduce() {
    let (run, _) = run_reduce("chain_reduce", 8, 1, 16, &Options::default()).unwrap();
    check_golden("chain_reduce_8x1_k16", &run.report);
}

#[test]
fn golden_broadcast() {
    let run = run_broadcast(8, 16, &Options::default()).unwrap();
    check_golden("broadcast_8x1_k16", &run.report);
}

#[test]
fn golden_tree_reduce() {
    let (run, _) = run_reduce("tree_reduce", 4, 4, 16, &Options::default()).unwrap();
    check_golden("tree_reduce_4x4_k16", &run.report);
}

#[test]
fn golden_two_phase_reduce() {
    let (run, _) = run_reduce("two_phase_reduce", 4, 4, 16, &Options::default()).unwrap();
    check_golden("two_phase_reduce_4x4_k16", &run.report);
}

#[test]
fn golden_gemv() {
    let (run, _, _) = run_gemv_variant("gemv", 16, 4, &Options::default()).unwrap();
    check_golden("gemv_16_4x4", &run.report);
}

#[test]
fn golden_gemv_tree() {
    let (run, _, _) = run_gemv_variant("gemv_tree", 16, 4, &Options::default()).unwrap();
    check_golden("gemv_tree_16_4x4", &run.report);
}

/// The discrete-event core is fully deterministic: two identical runs
/// must produce bit-identical reports (the property the golden
/// snapshots rest on).
#[test]
fn simulation_is_deterministic() {
    let (a, _) = run_reduce("tree_reduce", 4, 4, 8, &Options::default()).unwrap();
    let (b, _) = run_reduce("tree_reduce", 4, 4, 8, &Options::default()).unwrap();
    assert_eq!(fingerprint(&a.report), fingerprint(&b.report));
}

/// GEMV against the dense reference — numeric (not just timing)
/// preservation of the refactored core.
#[test]
fn gemv_matches_dense_reference() {
    let (_, y, want) = run_gemv_variant("gemv", 16, 4, &Options::default()).unwrap();
    assert_eq!(y.len(), want.len());
    for (i, (a, b)) in y.iter().zip(&want).enumerate() {
        assert!(
            (a - b).abs() < 1e-3 * b.abs().max(1.0),
            "y[{i}] = {a}, reference {b}"
        );
    }
}

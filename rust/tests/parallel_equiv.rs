//! Cross-thread-count equivalence: the epoch-parallel simulator claims
//! bit-identical behaviour at every worker count.
//!
//! The engine (see `machine/sim.rs`) decomposes the fabric into
//! link-sharing islands folded onto a fixed shard count, steps shards
//! concurrently inside conservative lookahead epochs, and merges
//! cross-shard flow arrivals deterministically at each barrier. This
//! suite runs every library kernel over identical inputs at threads ∈
//! {1, 2, 4, 8} — 1 is the classic single-queue loop, ≥ 2 the sharded
//! engine — and asserts the full `RunReport` (cycles, every metric
//! counter, resource usage) and every output argument's raw words are
//! equal across all counts.

use spada::harness::common::{output_words, stage_random_inputs};
use spada::kernels::{self, CompiledKernel};
use spada::machine::{MachineConfig, RunReport};
use spada::passes::Options;
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Thread counts every kernel is exercised at.
const THREADS: [usize; 4] = [1, 2, 4, 8];

/// Tests constructing simulators serialize against the env-var test:
/// `Simulator` construction reads `SPADA_THREADS` via `std::env::var`,
/// and concurrent setenv/getenv is a data race on glibc.
fn env_lock() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Compile one library kernel at a modest grid.
fn compile(name: &str, binds: &[(&str, i64)], w: i64, h: i64) -> CompiledKernel {
    let cfg = MachineConfig::with_grid(w, h);
    kernels::compile(name, binds, &cfg, &Options::default())
        .unwrap_or_else(|e| panic!("{name}: {e:#}"))
}

/// Run a fresh simulator over deterministic inputs at a given worker
/// count, returning the report and all raw output words.
fn run_at(ck: &CompiledKernel, threads: usize) -> (RunReport, Vec<(String, Vec<u32>)>) {
    let mut sim = ck.simulator().unwrap();
    sim.set_threads(threads);
    stage_random_inputs(&mut sim, 0xEB0C);
    let report =
        sim.run().unwrap_or_else(|e| panic!("{} threads={threads}: {e}", ck.machine.name));
    let outs = output_words(&sim);
    (report, outs)
}

fn assert_equivalent(name: &str, ck: &CompiledKernel) {
    let _guard = env_lock();
    let (base_report, base_outs) = run_at(ck, THREADS[0]);
    for &threads in &THREADS[1..] {
        let (report, outs) = run_at(ck, threads);
        assert_eq!(
            report, base_report,
            "{name}: RunReport diverged between threads=1 and threads={threads}"
        );
        assert_eq!(
            outs.len(),
            base_outs.len(),
            "{name}: output binding count diverged at threads={threads}"
        );
        for ((ba, bw), (ca, cw)) in base_outs.iter().zip(&outs) {
            assert_eq!(ba, ca, "{name}: output order diverged at threads={threads}");
            assert_eq!(
                bw, cw,
                "{name}: output {ba} not bit-identical at threads={threads}"
            );
        }
    }
}

#[test]
fn chain_reduce_threads_equivalent() {
    assert_equivalent(
        "chain_reduce",
        &compile("chain_reduce", &[("K", 24), ("N", 9)], 9, 1),
    );
}

#[test]
fn broadcast_threads_equivalent() {
    assert_equivalent("broadcast", &compile("broadcast", &[("K", 16), ("N", 8)], 8, 1));
}

#[test]
fn tree_reduce_threads_equivalent() {
    assert_equivalent(
        "tree_reduce",
        &compile("tree_reduce", &[("K", 8), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

#[test]
fn two_phase_reduce_threads_equivalent() {
    assert_equivalent(
        "two_phase_reduce",
        &compile("two_phase_reduce", &[("K", 8), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

#[test]
fn gemv_threads_equivalent() {
    assert_equivalent(
        "gemv",
        &compile("gemv", &[("M", 16), ("N", 16), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

#[test]
fn gemv_tree_threads_equivalent() {
    assert_equivalent(
        "gemv_tree",
        &compile("gemv_tree", &[("M", 16), ("N", 16), ("NX", 4), ("NY", 4)], 4, 4),
    );
}

/// The batched DSD engine and the parallel engine compose: interpreter
/// runs must also be thread-count-invariant (and agree with the
/// vectorized single-thread baseline, which dsd_batch.rs pins).
#[test]
fn interpreter_mode_threads_equivalent() {
    let ck = compile("tree_reduce", &[("K", 8), ("NX", 4), ("NY", 4)], 4, 4);
    let _guard = env_lock();
    let run = |threads: usize| {
        let mut sim = ck.simulator().unwrap();
        sim.set_threads(threads);
        sim.set_vectorize(false);
        stage_random_inputs(&mut sim, 0xEB0C);
        let report = sim.run().unwrap();
        (report, output_words(&sim))
    };
    let (r1, o1) = run(1);
    for threads in [2, 8] {
        let (r, o) = run(threads);
        assert_eq!(r, r1, "interpreter mode diverged at threads={threads}");
        assert_eq!(o, o1);
    }
}

/// `SPADA_THREADS` in the environment seeds the default worker count
/// at construction; `set_threads` overrides it per simulator.
#[test]
fn env_var_sets_default_thread_count() {
    let ck = compile("broadcast", &[("K", 8), ("N", 4)], 4, 1);
    let _guard = env_lock();
    std::env::set_var("SPADA_THREADS", "3");
    let sim = ck.simulator().unwrap();
    std::env::remove_var("SPADA_THREADS");
    assert_eq!(sim.threads(), 3, "SPADA_THREADS must seed the default");
    let mut sim2 = ck.simulator().unwrap();
    sim2.set_threads(7);
    assert_eq!(sim2.threads(), 7);
    sim2.set_threads(0);
    assert_eq!(sim2.threads(), 1, "thread counts clamp to >= 1");
}

//! End-to-end integration for the GT4Py-style stencil pipeline
//! (paper §IV + §VI-C): stencil DSL → Stencil IR → SpaDA → CSL →
//! simulate → verify against a straightforward reference.

use spada::csl;
use spada::frontend::{lower_stencil, parse_stencil, stencil_source};
use spada::machine::{MachineConfig, Simulator};
use spada::passes::Options;
use spada::sem::{instantiate, Bindings};
use spada::util::SplitMix64;

struct Grid {
    nx: usize,
    ny: usize,
    k: usize,
    /// data[(x * ny + y) * k + kk] — the kernel-arg port layout.
    data: Vec<f32>,
}

impl Grid {
    fn random(seed: u64, nx: usize, ny: usize, k: usize) -> Grid {
        let mut rng = SplitMix64::new(seed);
        let data = (0..nx * ny * k).map(|_| rng.next_f32()).collect();
        Grid { nx, ny, k, data }
    }

    fn zero(nx: usize, ny: usize, k: usize) -> Grid {
        Grid { nx, ny, k, data: vec![0.0; nx * ny * k] }
    }

    fn at(&self, x: i64, y: i64, kk: i64) -> f32 {
        self.data[((x as usize) * self.ny + y as usize) * self.k + kk as usize]
    }

    fn set(&mut self, x: i64, y: i64, kk: i64, v: f32) {
        self.data[((x as usize) * self.ny + y as usize) * self.k + kk as usize] = v;
    }
}

fn run_stencil(
    name: &str,
    inputs: &[(&str, &Grid)],
    nx: i64,
    ny: i64,
    k: i64,
) -> (Vec<(String, Vec<f32>)>, spada::machine::RunReport) {
    let ir = parse_stencil(stencil_source(name).unwrap()).unwrap();
    let sk = lower_stencil(&ir).unwrap();
    let binds: Bindings =
        [("K", k), ("NX", nx), ("NY", ny)].iter().map(|(s, v)| (s.to_string(), *v)).collect();
    let prog = instantiate(&sk.kernel, &binds).unwrap();
    let cfg = MachineConfig::with_grid(nx, ny);
    let compiled = csl::compile(&prog, &cfg, &Options::default()).unwrap();
    let mut sim = Simulator::new(cfg, compiled.machine).unwrap();
    for (arg, grid) in inputs {
        sim.set_input(arg, &grid.data).unwrap();
    }
    let report = sim.run().unwrap();
    let outs = sk
        .outputs
        .iter()
        .map(|o| (o.clone(), sim.get_output(o).unwrap()))
        .collect();
    (outs, report)
}

fn assert_interior_close(
    got: &[f32],
    want: &Grid,
    halo: (i64, i64, i64, i64), // w, e, n, s
    what: &str,
) {
    let (nx, ny, k) = (want.nx as i64, want.ny as i64, want.k as i64);
    for x in halo.0..nx - halo.1 {
        for y in halo.2..ny - halo.3 {
            for kk in 0..k {
                let idx = ((x * ny + y) * k + kk) as usize;
                let g = got[idx];
                let w = want.at(x, y, kk);
                assert!(
                    (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
                    "{what} at ({x},{y},{kk}): got {g}, want {w}"
                );
            }
        }
    }
}

#[test]
fn laplacian_e2e() {
    let (nx, ny, k) = (6i64, 5i64, 4i64);
    let input = Grid::random(11, nx as usize, ny as usize, k as usize);
    let (outs, report) = run_stencil("laplacian", &[("in_field_ain", &input)], nx, ny, k);
    let mut want = Grid::zero(nx as usize, ny as usize, k as usize);
    for x in 1..nx - 1 {
        for y in 1..ny - 1 {
            for kk in 0..k {
                let v = -4.0 * input.at(x, y, kk)
                    + input.at(x + 1, y, kk)
                    + input.at(x - 1, y, kk)
                    + input.at(x, y + 1, kk)
                    + input.at(x, y - 1, kk);
                want.set(x, y, kk, v);
            }
        }
    }
    assert_interior_close(&outs[0].1, &want, (1, 1, 1, 1), "laplacian");
    // Halo exchange must be fabric traffic, not magic.
    assert!(report.metrics.flows > 0);
}

#[test]
fn vertical_e2e() {
    let (nx, ny, k) = (3i64, 3i64, 8i64);
    let input = Grid::random(12, nx as usize, ny as usize, k as usize);
    let (outs, report) = run_stencil("vertical", &[("in_field_ain", &input)], nx, ny, k);
    let mut want = Grid::zero(nx as usize, ny as usize, k as usize);
    for x in 0..nx {
        for y in 0..ny {
            // computation(PARALLEL) interval(0, -1): out[k] = in[k+1] - in[k]
            for kk in 0..k - 1 {
                want.set(x, y, kk, input.at(x, y, kk + 1) - input.at(x, y, kk));
            }
            // computation(FORWARD) interval(1, 0): out[k] = out[k-1] + in[k]
            for kk in 1..k {
                let v = want.at(x, y, kk - 1) + input.at(x, y, kk);
                want.set(x, y, kk, v);
            }
        }
    }
    assert_interior_close(&outs[0].1, &want, (0, 0, 0, 0), "vertical");
    // Purely local: no fabric flows at all.
    assert_eq!(report.metrics.flows, 0);
}

#[test]
fn uvbke_e2e() {
    let (nx, ny, k) = (5i64, 6i64, 3i64);
    let u = Grid::random(13, nx as usize, ny as usize, k as usize);
    let v = Grid::random(14, nx as usize, ny as usize, k as usize);
    let (outs, _) = run_stencil("uvbke", &[("u_ain", &u), ("v_ain", &v)], nx, ny, k);
    let mut want = Grid::zero(nx as usize, ny as usize, k as usize);
    for x in 1..nx {
        for y in 1..ny {
            for kk in 0..k {
                let ua = u.at(x, y, kk) + u.at(x - 1, y, kk);
                let va = v.at(x, y, kk) + v.at(x, y - 1, kk);
                want.set(x, y, kk, 0.125 * (ua * ua + va * va));
            }
        }
    }
    assert_interior_close(&outs[0].1, &want, (1, 0, 1, 0), "uvbke");
}

/// The Fig. 9a knob: disabling copy elimination must still be correct
/// but use more memory.
#[test]
fn laplacian_ablation_memory() {
    let (nx, ny, k) = (6i64, 5i64, 16i64);
    let ir = parse_stencil(stencil_source("laplacian").unwrap()).unwrap();
    let sk = lower_stencil(&ir).unwrap();
    let binds: Bindings =
        [("K", k), ("NX", nx), ("NY", ny)].iter().map(|(s, v)| (s.to_string(), *v)).collect();
    let prog = instantiate(&sk.kernel, &binds).unwrap();
    let cfg = MachineConfig::with_grid(nx, ny);
    let with = csl::compile(&prog, &cfg, &Options::default()).unwrap();
    let without =
        csl::compile(&prog, &cfg, &Options { copy_elim: false, ..Options::default() }).unwrap();
    assert!(
        without.stats.mem_bytes_max > with.stats.mem_bytes_max,
        "copy elimination must reduce PE memory: {} vs {}",
        with.stats.mem_bytes_max,
        without.stats.mem_bytes_max
    );
}

//! The "binary" format executed by the simulator.
//!
//! A [`MachineProgram`] is what the CSL backend produces alongside the
//! CSL-like text: per-PE-class task tables of machine operations
//! ([`MOp`]), a routing table mapping (color, subgrid) to router
//! configurations, memory layouts, and I/O metadata. It corresponds to
//! the ELF the real CSL toolchain would load onto each PE.

use crate::util::Subgrid;
use std::collections::BTreeMap;
use std::fmt;

/// Element data types supported by the DSD engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dtype {
    F16,
    F32,
    I16,
    I32,
    U16,
    U32,
}

impl Dtype {
    pub fn size(&self) -> usize {
        match self {
            Dtype::F16 | Dtype::I16 | Dtype::U16 => 2,
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, Dtype::F16 | Dtype::F32)
    }

    pub fn is_16bit(&self) -> bool {
        self.size() == 2
    }
}

impl fmt::Display for Dtype {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Dtype::F16 => "f16",
            Dtype::F32 => "f32",
            Dtype::I16 => "i16",
            Dtype::I32 => "i32",
            Dtype::U16 => "u16",
            Dtype::U32 => "u32",
        };
        f.write_str(s)
    }
}

/// A scalar runtime value (integer or float).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SVal {
    I(i64),
    F(f64),
}

impl SVal {
    pub fn as_i(&self) -> i64 {
        match self {
            SVal::I(v) => *v,
            SVal::F(v) => *v as i64,
        }
    }

    pub fn as_f(&self) -> f64 {
        match self {
            SVal::I(v) => *v as f64,
            SVal::F(v) => *v,
        }
    }

    pub fn truthy(&self) -> bool {
        match self {
            SVal::I(v) => *v != 0,
            SVal::F(v) => *v != 0.0,
        }
    }
}

/// Binary operators in scalar expressions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SBinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Min,
    Max,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// A scalar expression evaluated per-PE at runtime.
///
/// `CoordX`/`CoordY` are the PE's absolute fabric coordinates; `Reg(r)`
/// reads scalar register `r`; `LoadMem` is a scalar load from local SRAM.
#[derive(Clone, Debug, PartialEq)]
pub enum SExpr {
    ImmI(i64),
    ImmF(f64),
    CoordX,
    CoordY,
    Reg(u8),
    LoadMem { addr: Box<SExpr>, ty: Dtype },
    Bin(SBinOp, Box<SExpr>, Box<SExpr>),
    Neg(Box<SExpr>),
    Not(Box<SExpr>),
    /// `cond ? a : b`
    Select(Box<SExpr>, Box<SExpr>, Box<SExpr>),
}

impl SExpr {
    pub fn imm(v: i64) -> SExpr {
        SExpr::ImmI(v)
    }

    pub fn bin(op: SBinOp, a: SExpr, b: SExpr) -> SExpr {
        SExpr::Bin(op, Box::new(a), Box::new(b))
    }

    pub fn add(a: SExpr, b: SExpr) -> SExpr {
        SExpr::bin(SBinOp::Add, a, b)
    }

    pub fn mul(a: SExpr, b: SExpr) -> SExpr {
        SExpr::bin(SBinOp::Mul, a, b)
    }

    /// Rough cycle cost of evaluating this expression (for the scalar
    /// cost model).
    pub fn cost(&self) -> u64 {
        match self {
            SExpr::ImmI(_) | SExpr::ImmF(_) | SExpr::CoordX | SExpr::CoordY | SExpr::Reg(_) => 0,
            SExpr::LoadMem { addr, .. } => 1 + addr.cost(),
            SExpr::Bin(_, a, b) => 1 + a.cost() + b.cost(),
            SExpr::Neg(a) | SExpr::Not(a) => 1 + a.cost(),
            SExpr::Select(c, a, b) => 1 + c.cost() + a.cost().max(b.cost()),
        }
    }
}

/// DSD operation kinds (the vectorized instruction set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DsdKind {
    /// dst[i] = src0[i] + src1[i]  (1 flop/elem)
    Fadd,
    /// dst[i] = src0[i] - src1[i]
    Fsub,
    /// dst[i] = src0[i] * src1[i]
    Fmul,
    /// dst[i] = src0[i] + src1[i] * scalar  (2 flops/elem)
    Fmac,
    /// dst[i] = src0[i] * scalar  (1 flop/elem)
    Fscale,
    /// dst[i] = src0[i]  (data movement / copy / send / receive)
    Mov,
    /// dst[i] = scalar   (fill)
    Fill,
    /// dst[i] = max(src0[i], src1[i])
    FmaxOp,
}

impl DsdKind {
    /// Floating-point operations per element.
    pub fn flops_per_elem(&self) -> u64 {
        match self {
            DsdKind::Fadd | DsdKind::Fsub | DsdKind::Fmul | DsdKind::FmaxOp | DsdKind::Fscale => 1,
            DsdKind::Fmac => 2,
            DsdKind::Mov | DsdKind::Fill => 0,
        }
    }

    pub fn csl_name(&self, ty: Dtype) -> String {
        let base = match self {
            DsdKind::Fadd => "fadd",
            DsdKind::Fsub => "fsub",
            DsdKind::Fmul => "fmul",
            DsdKind::Fmac => "fmac",
            DsdKind::Fscale => "fmul",
            DsdKind::Mov => "mov",
            DsdKind::Fill => "mov",
            DsdKind::FmaxOp => "fmax",
        };
        let suffix = match (self, ty) {
            (DsdKind::Mov | DsdKind::Fill, t) if t.is_16bit() => "16".to_string(),
            (DsdKind::Mov | DsdKind::Fill, _) => "32".to_string(),
            (_, Dtype::F16) => "h".to_string(),
            (_, _) => "s".to_string(),
        };
        format!("@{base}{suffix}")
    }
}

/// A data structure descriptor reference: a memory access pattern or a
/// fabric endpoint.
#[derive(Clone, Debug, PartialEq)]
pub enum DsdRef {
    /// Strided local-memory vector: element i at byte address
    /// `base + (offset + i*stride) * ty.size()`.
    Mem {
        /// Byte base address of the underlying field.
        base: u32,
        /// Element offset expression (evaluated per-op).
        offset: SExpr,
        /// Element stride.
        stride: i64,
        /// Element count expression.
        len: SExpr,
        ty: Dtype,
    },
    /// Fabric input: consume `len` wavelets from `color`.
    FabIn { color: u8, len: SExpr, ty: Dtype },
    /// Fabric output: produce `len` wavelets on `color`.
    FabOut { color: u8, len: SExpr, ty: Dtype },
}

impl DsdRef {
    pub fn mem(base: u32, len: SExpr, ty: Dtype) -> DsdRef {
        DsdRef::Mem { base, offset: SExpr::ImmI(0), stride: 1, len, ty }
    }

    pub fn ty(&self) -> Dtype {
        match self {
            DsdRef::Mem { ty, .. } | DsdRef::FabIn { ty, .. } | DsdRef::FabOut { ty, .. } => *ty,
        }
    }

    pub fn is_fabric(&self) -> bool {
        matches!(self, DsdRef::FabIn { .. } | DsdRef::FabOut { .. })
    }
}

/// What to do when an asynchronous operation completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskActionKind {
    Activate,
    Unblock,
    Block,
}

/// A task-control action, optionally setting a dispatch-state register
/// first (task-ID recycling: the activator selects the logical task).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskAction {
    pub kind: TaskActionKind,
    /// Hardware task ID on the *same* PE.
    pub task: u8,
    /// Optional `(register, value)` written before the action fires.
    pub set_reg: Option<(u8, i64)>,
}

impl TaskAction {
    pub fn activate(task: u8) -> TaskAction {
        TaskAction { kind: TaskActionKind::Activate, task, set_reg: None }
    }

    pub fn unblock(task: u8) -> TaskAction {
        TaskAction { kind: TaskActionKind::Unblock, task, set_reg: None }
    }
}

/// A (possibly asynchronous) DSD operation.
#[derive(Clone, Debug, PartialEq)]
pub struct DsdOp {
    pub kind: DsdKind,
    pub dst: DsdRef,
    pub src0: Option<DsdRef>,
    pub src1: Option<DsdRef>,
    /// Scalar operand (Fmac multiplier, Fill value).
    pub scalar: Option<SExpr>,
    /// Asynchronous (microthreaded): the issuing task continues
    /// immediately; `on_complete` fires when the op drains.
    pub is_async: bool,
    pub on_complete: Vec<TaskAction>,
}

/// Machine operations — the per-task instruction list.
#[derive(Clone, Debug, PartialEq)]
pub enum MOp {
    /// reg = expr
    SetReg { reg: u8, val: SExpr },
    /// Scalar store to local memory.
    Store { addr: SExpr, ty: Dtype, val: SExpr },
    /// Vector / fabric operation.
    Dsd(DsdOp),
    /// Immediate task-control action.
    Control(TaskAction),
    /// Conditional.
    If { cond: SExpr, then_ops: Vec<MOp>, else_ops: Vec<MOp> },
    /// Sequential counted loop: `for reg in start..stop step step`.
    For { reg: u8, start: SExpr, stop: SExpr, step: SExpr, body: Vec<MOp> },
    /// Marks kernel completion on this PE (records the finish cycle).
    Halt,
    /// Debug trace (no cycles).
    Trace(String),
}

/// Task flavor.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TaskKind {
    /// Runs when `active && !blocked`; auto-deactivates after each run.
    Local,
    /// Bound to a color: fires per arriving wavelet (the wavelet value is
    /// bound to register `wavelet_reg`). Always "active"; blockable.
    Data { color: u8, wavelet_reg: u8 },
}

/// One hardware task on a PE class.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskDef {
    pub name: String,
    /// Hardware task ID (0..max_task_ids). Data tasks must use the ID of
    /// their color.
    pub hw_id: u8,
    pub kind: TaskKind,
    pub initially_active: bool,
    pub initially_blocked: bool,
    pub body: Vec<MOp>,
}

/// A named field allocation in PE-local memory.
#[derive(Clone, Debug, PartialEq)]
pub struct FieldAlloc {
    pub name: String,
    /// Byte offset in PE memory.
    pub addr: u32,
    /// Element count.
    pub len: u32,
    pub ty: Dtype,
    /// True for extern (kernel argument) fields: preloaded before the run
    /// (inputs) / read back after (outputs).
    pub is_extern: bool,
}

impl FieldAlloc {
    pub fn bytes(&self) -> u32 {
        self.len * self.ty.size() as u32
    }
}

/// One PE equivalence class — corresponds to one generated CSL code file.
#[derive(Clone, Debug, PartialEq)]
pub struct PeClass {
    pub name: String,
    /// PEs running this class (disjoint from all other classes).
    pub subgrids: Vec<Subgrid>,
    pub fields: Vec<FieldAlloc>,
    /// Bytes of local memory used (must be ≤ config.mem_bytes).
    pub mem_size: u32,
    pub tasks: Vec<TaskDef>,
    /// Tasks activated at kernel start (entry points).
    pub entry_tasks: Vec<u8>,
}

impl PeClass {
    pub fn field(&self, name: &str) -> Option<&FieldAlloc> {
        self.fields.iter().find(|f| f.name == name)
    }

    pub fn task_by_id(&self, hw_id: u8) -> Option<&TaskDef> {
        self.tasks.iter().find(|t| t.hw_id == hw_id)
    }

    pub fn covers(&self, x: i64, y: i64) -> bool {
        self.subgrids.iter().any(|g| g.contains(x, y))
    }
}

/// Mesh directions. `Ramp` is the PE↔router port.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Direction {
    North,
    East,
    South,
    West,
    Ramp,
}

impl Direction {
    pub fn opposite(&self) -> Direction {
        match self {
            Direction::North => Direction::South,
            Direction::South => Direction::North,
            Direction::East => Direction::West,
            Direction::West => Direction::East,
            Direction::Ramp => Direction::Ramp,
        }
    }

    /// Coordinate delta for one hop in this direction.
    /// x grows east, y grows south (row 0 at the north edge).
    pub fn delta(&self) -> (i64, i64) {
        match self {
            Direction::North => (0, -1),
            Direction::South => (0, 1),
            Direction::East => (1, 0),
            Direction::West => (-1, 0),
            Direction::Ramp => (0, 0),
        }
    }

    /// Direction of the unit step (dx, dy); None if not a unit step.
    pub fn from_delta(dx: i64, dy: i64) -> Option<Direction> {
        match (dx, dy) {
            (0, -1) => Some(Direction::North),
            (0, 1) => Some(Direction::South),
            (1, 0) => Some(Direction::East),
            (-1, 0) => Some(Direction::West),
            _ => None,
        }
    }

    pub fn csl_name(&self) -> &'static str {
        match self {
            Direction::North => "NORTH",
            Direction::East => "EAST",
            Direction::South => "SOUTH",
            Direction::West => "WEST",
            Direction::Ramp => "RAMP",
        }
    }

    pub const ALL: [Direction; 5] =
        [Direction::North, Direction::East, Direction::South, Direction::West, Direction::Ramp];

    /// Index for link-occupancy arrays (Ramp = 4).
    pub fn index(&self) -> usize {
        match self {
            Direction::North => 0,
            Direction::East => 1,
            Direction::South => 2,
            Direction::West => 3,
            Direction::Ramp => 4,
        }
    }
}

/// A small set of directions (bitmask).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct DirSet(pub u8);

impl DirSet {
    pub fn empty() -> DirSet {
        DirSet(0)
    }

    pub fn single(d: Direction) -> DirSet {
        DirSet(1 << d.index())
    }

    pub fn with(mut self, d: Direction) -> DirSet {
        self.0 |= 1 << d.index();
        self
    }

    pub fn contains(&self, d: Direction) -> bool {
        self.0 & (1 << d.index()) != 0
    }

    pub fn iter(&self) -> impl Iterator<Item = Direction> + '_ {
        Direction::ALL.iter().copied().filter(move |d| self.contains(*d))
    }

    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }

    pub fn csl_list(&self) -> String {
        let names: Vec<&str> = self.iter().map(|d| d.csl_name()).collect();
        names.join(", ")
    }
}

/// A routing rule: on PEs in `subgrid`, color `color` is configured with
/// receive set `rx` and transmit set `tx`. First matching rule wins.
#[derive(Clone, Debug, PartialEq)]
pub struct RouteRule {
    pub color: u8,
    pub subgrid: Subgrid,
    pub rx: DirSet,
    pub tx: DirSet,
}

/// Extern I/O direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IoDir {
    In,
    Out,
}

/// Affine port map: PE (x, y) serves I/O port `ax·x + ay·y + c`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct PortMap {
    pub ax: i64,
    pub ay: i64,
    pub c: i64,
}

impl PortMap {
    pub fn port(&self, x: i64, y: i64) -> i64 {
        self.ax * x + self.ay * y + self.c
    }
}

/// Host I/O binding: kernel argument `arg` maps to extern field `field`
/// on the PEs of `subgrid`. PE (x, y) holds elements
/// `[port·elems_per_pe, (port+1)·elems_per_pe)` of the argument's flat
/// data, with `port = port_map(x, y)`. An argument may have several
/// bindings (one per PE class that touches it).
#[derive(Clone, Debug, PartialEq)]
pub struct IoBinding {
    pub arg: String,
    pub field: String,
    pub dir: IoDir,
    pub subgrid: Subgrid,
    pub elems_per_pe: u32,
    /// Total number of ports of the argument (flat data size =
    /// `total_ports * elems_per_pe`).
    pub total_ports: u32,
    pub port_map: PortMap,
    pub ty: Dtype,
}

/// The complete loadable program.
#[derive(Clone, Debug, Default)]
pub struct MachineProgram {
    pub name: String,
    pub classes: Vec<PeClass>,
    pub routes: Vec<RouteRule>,
    pub io: Vec<IoBinding>,
    /// Colors referenced anywhere (for resource accounting).
    pub colors_used: Vec<u8>,
    /// Free-form compile metadata (pass statistics etc.).
    pub meta: BTreeMap<String, String>,
}

impl MachineProgram {
    /// Resolve the class covering PE (x, y), if any.
    pub fn class_at(&self, x: i64, y: i64) -> Option<usize> {
        self.classes.iter().position(|c| c.covers(x, y))
    }

    /// Resolve the route entry for `color` at PE (x, y).
    pub fn route_at(&self, color: u8, x: i64, y: i64) -> Option<&RouteRule> {
        self.routes
            .iter()
            .find(|r| r.color == color && r.subgrid.contains(x, y))
    }

    /// Distinct colors referenced by the program, sorted ascending.
    pub fn distinct_colors(&self) -> Vec<u8> {
        let mut colors = self.colors_used.clone();
        colors.sort_unstable();
        colors.dedup();
        colors
    }

    /// Max task IDs used by any class.
    pub fn max_task_ids_used(&self) -> usize {
        self.classes
            .iter()
            .map(|c| {
                let mut ids: Vec<u8> = c.tasks.iter().map(|t| t.hw_id).collect();
                ids.sort_unstable();
                ids.dedup();
                ids.len()
            })
            .max()
            .unwrap_or(0)
    }

    /// Max memory used by any class, in bytes.
    pub fn max_mem_used(&self) -> u32 {
        self.classes.iter().map(|c| c.mem_size).max().unwrap_or(0)
    }

    /// Validate resource constraints against a machine config.
    /// Returns a list of violations ("OOR"/"OOM" in the paper's terms).
    pub fn validate(&self, cfg: &super::MachineConfig) -> Vec<String> {
        let mut errs = vec![];
        let colors = self.distinct_colors();
        if colors.len() > cfg.max_colors as usize {
            errs.push(format!(
                "OOR: {} colors used, only {} routable",
                colors.len(),
                cfg.max_colors
            ));
        }
        for c in &colors {
            if *c >= cfg.max_colors {
                errs.push(format!("OOR: color {} out of range (< {})", c, cfg.max_colors));
            }
        }
        for class in &self.classes {
            let mut ids: Vec<u8> = class.tasks.iter().map(|t| t.hw_id).collect();
            ids.sort_unstable();
            let n = ids.len();
            ids.dedup();
            if ids.len() != n {
                errs.push(format!("class {}: duplicate hardware task IDs", class.name));
            }
            if ids.len() > cfg.max_task_ids as usize {
                errs.push(format!(
                    "OOR: class {} uses {} task IDs, only {} available",
                    class.name,
                    ids.len(),
                    cfg.max_task_ids
                ));
            }
            for t in &class.tasks {
                if t.hw_id >= cfg.max_task_ids {
                    errs.push(format!(
                        "OOR: class {} task {} has ID {} >= {}",
                        class.name, t.name, t.hw_id, cfg.max_task_ids
                    ));
                }
                if let TaskKind::Data { color, .. } = &t.kind {
                    if t.hw_id != *color {
                        errs.push(format!(
                            "class {}: data task {} ID {} != color {}",
                            class.name, t.name, t.hw_id, color
                        ));
                    }
                }
            }
            if class.mem_size as usize > cfg.mem_bytes {
                errs.push(format!(
                    "OOM: class {} needs {} B, only {} B of PE memory",
                    class.name, class.mem_size, cfg.mem_bytes
                ));
            }
            for g in &class.subgrids {
                for (x, y) in g.iter() {
                    if !cfg.in_bounds(x, y) {
                        errs.push(format!(
                            "class {}: subgrid {:?} leaves the {}x{} fabric",
                            class.name, g, cfg.width, cfg.height
                        ));
                        break;
                    }
                }
            }
        }
        // Class overlap check (each PE must map to at most one code file).
        for i in 0..self.classes.len() {
            for j in (i + 1)..self.classes.len() {
                for a in &self.classes[i].subgrids {
                    for b in &self.classes[j].subgrids {
                        if !a.intersect(b).is_empty() {
                            errs.push(format!(
                                "classes {} and {} overlap on {:?}",
                                self.classes[i].name,
                                self.classes[j].name,
                                a.intersect(b)
                            ));
                        }
                    }
                }
            }
        }
        errs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineConfig;
    use crate::util::Range1;

    fn tiny_class(name: &str, x: i64) -> PeClass {
        PeClass {
            name: name.into(),
            subgrids: vec![Subgrid::point(x, 0)],
            fields: vec![],
            mem_size: 128,
            tasks: vec![],
            entry_tasks: vec![],
        }
    }

    #[test]
    fn dirset_roundtrip() {
        let s = DirSet::empty().with(Direction::East).with(Direction::Ramp);
        assert!(s.contains(Direction::East));
        assert!(s.contains(Direction::Ramp));
        assert!(!s.contains(Direction::West));
        assert_eq!(s.iter().count(), 2);
        assert_eq!(s.csl_list(), "EAST, RAMP");
    }

    #[test]
    fn direction_opposite_delta() {
        for d in Direction::ALL {
            assert_eq!(d.opposite().opposite(), d);
            let (dx, dy) = d.delta();
            if d != Direction::Ramp {
                assert_eq!(Direction::from_delta(dx, dy), Some(d));
            }
        }
    }

    #[test]
    fn validate_overlap() {
        let prog = MachineProgram {
            name: "t".into(),
            classes: vec![tiny_class("a", 0), {
                let mut c = tiny_class("b", 0);
                c.subgrids = vec![Subgrid::new(Range1::dense(0, 2), Range1::point(0))];
                c
            }],
            ..Default::default()
        };
        let errs = prog.validate(&MachineConfig::with_grid(4, 4));
        assert!(errs.iter().any(|e| e.contains("overlap")));
    }

    #[test]
    fn validate_oor_colors() {
        let prog = MachineProgram {
            name: "t".into(),
            colors_used: (0..30).collect(),
            ..Default::default()
        };
        let errs = prog.validate(&MachineConfig::with_grid(4, 4));
        assert!(errs.iter().any(|e| e.contains("OOR")));
    }

    #[test]
    fn validate_oom() {
        let mut c = tiny_class("big", 0);
        c.mem_size = 64 * 1024;
        let prog = MachineProgram { name: "t".into(), classes: vec![c], ..Default::default() };
        let errs = prog.validate(&MachineConfig::with_grid(4, 4));
        assert!(errs.iter().any(|e| e.contains("OOM")));
    }

    #[test]
    fn data_task_id_must_match_color() {
        let mut c = tiny_class("d", 0);
        c.tasks.push(TaskDef {
            name: "recv".into(),
            hw_id: 5,
            kind: TaskKind::Data { color: 3, wavelet_reg: 0 },
            initially_active: true,
            initially_blocked: false,
            body: vec![],
        });
        let prog = MachineProgram { name: "t".into(), classes: vec![c], ..Default::default() };
        let errs = prog.validate(&MachineConfig::with_grid(4, 4));
        assert!(errs.iter().any(|e| e.contains("!= color")));
    }
}

//! WSE-2 fabric/PE discrete-event simulator — the substrate the paper's
//! evaluation runs on (we have no Cerebras hardware; see DESIGN.md §1).
//!
//! The simulator models exactly the resources the SpaDA compiler manages:
//!
//! - a 2-D mesh of PEs, each with a small local SRAM (48 KB), a scalar
//!   core, and a DSD vector engine;
//! - a circuit-switched network-on-chip: per-(PE, color) static routes
//!   (rx direction-set → tx direction-set, multicast on tx), one wavelet
//!   per link per cycle, wormhole pipelining (flow-level model);
//! - task-driven execution: ≤ 28 hardware task IDs per PE shared with the
//!   24 routable colors; *local tasks* need `activate` (+ `unblock`),
//!   *data tasks* are bound to a color and fire on wavelet arrival;
//! - asynchronous (microthreaded) DSD operations over memory and fabric,
//!   with completion actions (activate/unblock) — the hardware mechanism
//!   behind SpaDA's async/await.
//!
//! Timing is cycle-granular: vector ops process one 32-bit element per
//! cycle (4-way SIMD for 16-bit), links forward one wavelet per cycle per
//! hop, and tasks are non-preemptive. Cycle counts convert to wall time at
//! 0.85 GHz, matching the paper's `runtime[µs] = cycles/0.85 · 10⁻³`.
//!
//! The event loop is epoch-parallel (`SPADA_THREADS` /
//! [`sim::Simulator::set_threads`]): PEs interact only through routed
//! flows, so link-sharing islands simulate concurrently with
//! conservative lookahead, bit-identically to the single-threaded loop
//! — see [`sim`] module docs.
//!
//! Endpoint buffers are finite when a capacity is configured
//! (`SPADA_BUF_CAP` / [`MachineConfig::endpoint_capacity_words`]):
//! credit-based backpressure stalls a flow's tail in the fabric when
//! its destination buffer fills, and exhausted credits that never
//! return surface as a buffer-deadlock report — see [`flowctl`].
//! Unconfigured (the default), endpoints are unbounded and behaviour
//! is bit-identical to every prior snapshot.
//!
//! Cycle-accurate tracing ([`sim::Simulator::set_tracing`]) captures
//! task/DSD/flow/stall records through both engines into a
//! deterministic stream (byte-identical across `SPADA_THREADS`) for
//! Chrome-trace export, profiling and heatmaps — see [`trace`].
//! Tracing is off by default and never perturbs simulated cycles.
//!
//! Deterministic fault injection (`SPADA_FAULTS` /
//! [`MachineConfig::faults`]) models dead and degraded links, halted
//! PEs, payload corruption and delayed delivery, applied at fixed
//! program points so faulted runs stay bit-identical across
//! `SPADA_THREADS`; outcome triage classifies every faulted run
//! against its clean reference — see [`fault`]. A wall-clock watchdog
//! (`SPADA_TIMEOUT_MS`) aborts hung runs with `SimError::Timeout`.

pub mod config;
pub mod fault;
pub mod flowctl;
pub mod options;
pub mod plan;
pub mod program;
pub mod router;
pub mod sim;
pub mod metrics;
pub mod trace;
pub mod vecop;

pub use config::MachineConfig;
pub use fault::{classify, FaultPlan, FaultSet, FaultSpec, Outcome};
pub use plan::RoutingPlan;
pub use program::{
    DirSet, Direction, DsdKind, DsdOp, DsdRef, Dtype, FieldAlloc, IoBinding, IoDir,
    MachineProgram, MOp, PeClass, PortMap, RouteRule, SExpr, SVal, TaskAction, TaskActionKind,
    TaskDef, TaskKind,
};
pub use metrics::{Metrics, RunReport};
pub use options::{CacheBudget, SimOptions};
pub use sim::{SimError, Simulator};
pub use trace::{
    ascii_heatmap, chrome_trace_json, EngineStats, EpochRecord, PeBreakdown, Profile, Trace,
    TraceRecord, TraceSink,
};

//! Machine configuration: grid geometry, resource limits, cycle costs.

use super::fault::FaultPlan;

/// WSE-2 machine model parameters.
///
/// Defaults follow the paper (§II, §VI) and the public WSE-2 numbers:
/// 750×994 usable PEs, 48 KB SRAM/PE, 24 routable colors (+8 reserved),
/// 28 task IDs, 0.85 GHz clock.
#[derive(Clone, Debug)]
pub struct MachineConfig {
    /// Fabric width (number of PEs in x / west-east direction).
    pub width: i64,
    /// Fabric height (number of PEs in y / north-south direction).
    pub height: i64,
    /// Clock frequency in GHz (cycles → µs conversion).
    pub freq_ghz: f64,
    /// Local SRAM per PE in bytes.
    pub mem_bytes: usize,
    /// Number of routable colors (virtual channels) per router.
    pub max_colors: u8,
    /// Number of hardware task IDs per PE (shared ID space with colors:
    /// binding a data task to color c consumes task ID c).
    pub max_task_ids: u8,
    /// Cycles from task activation to first instruction.
    pub task_wakeup_cycles: u64,
    /// Cycles to issue a DSD operation (descriptor setup + launch).
    pub dsd_issue_cycles: u64,
    /// Extra cycles per logical-task dispatch through a recycled
    /// state-machine task (the cost of task ID virtualization).
    pub dispatch_cycles: u64,
    /// Per-hop fabric latency in cycles.
    pub hop_cycles: u64,
    /// Cycles per scalar ALU op / branch.
    pub scalar_op_cycles: u64,
    /// Per-wavelet overhead when a data task fires per wavelet
    /// (non-vectorized fallback path).
    pub data_task_wavelet_cycles: u64,
    /// SIMD width for 16-bit element DSD operations.
    pub simd16_width: u64,
    /// Hard cap on simulated events (runaway guard).
    pub max_events: u64,
    /// Finite per-(PE, color) endpoint buffer capacity in words, with
    /// credit-based backpressure (see [`super::flowctl`]). `None` (the
    /// default when `SPADA_BUF_CAP` is unset) keeps the historical
    /// unbounded endpoints — bit-identical to every prior snapshot.
    pub endpoint_capacity_words: Option<u64>,
    /// Words of buffering per link stage along a route — how much of a
    /// stalled flow's tail the fabric can absorb before the stall backs
    /// up into the source on-ramp. Consumed by the static credit pass
    /// ([`crate::analysis::credits`]) and the runtime deadlock report;
    /// `None` models zero link-stage slack (most conservative).
    pub link_buffer_words: Option<u64>,
    /// Cycles for a freed credit to travel back to the upstream stall
    /// point (see [`super::flowctl`]). 0 (the default) returns credits
    /// instantly — bit-identical to every prior snapshot.
    pub credit_latency_cycles: u64,
    /// Wall-clock watchdog: abort a run that is still processing events
    /// after this many milliseconds with [`super::SimError::Timeout`]
    /// (set from `SPADA_TIMEOUT_MS`; `None` = no watchdog). Purely an
    /// abort path — it never changes the semantics of a run that
    /// finishes in time.
    pub timeout_ms: Option<u64>,
    /// Fault-injection plan (see [`super::fault`]; set from
    /// `SPADA_FAULTS`). Empty by default; a parse error rides along in
    /// `faults.invalid` and fails the run loudly.
    pub faults: FaultPlan,
}

impl MachineConfig {
    /// Full-wafer WSE-2 geometry (usable fabric).
    pub fn wse2() -> Self {
        Self::with_grid(750, 994)
    }

    /// WSE-2 model with a custom grid (scaled-down simulations).
    ///
    /// Pure: never consults the environment. The `SPADA_*` runtime
    /// options (buffer capacity, watchdog, faults, …) are resolved
    /// once per simulation by [`super::SimOptions`] — `from_env()` for
    /// the CLI-compatible constructors, or an explicit options value
    /// for batch-fleet jobs whose options differ per job.
    pub fn with_grid(width: i64, height: i64) -> Self {
        MachineConfig {
            width,
            height,
            freq_ghz: 0.85,
            mem_bytes: 48 * 1024,
            max_colors: 24,
            max_task_ids: 28,
            task_wakeup_cycles: 6,
            dsd_issue_cycles: 3,
            dispatch_cycles: 4,
            hop_cycles: 1,
            scalar_op_cycles: 1,
            data_task_wavelet_cycles: 2,
            simd16_width: 4,
            max_events: 2_000_000_000,
            endpoint_capacity_words: None,
            link_buffer_words: None,
            credit_latency_cycles: 0,
            timeout_ms: None,
            faults: FaultPlan::default(),
        }
    }

    /// Convert a cycle count to microseconds (paper §VI formula).
    pub fn cycles_to_us(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.freq_ghz * 1e3)
    }

    /// Number of PEs in the fabric.
    pub fn num_pes(&self) -> i64 {
        self.width * self.height
    }

    pub fn in_bounds(&self, x: i64, y: i64) -> bool {
        x >= 0 && x < self.width && y >= 0 && y < self.height
    }

    /// Number of grid cells — the size of dense row-major PE tables
    /// (`machine::plan` indexes them as `y * width + x`).
    pub fn grid_cells(&self) -> usize {
        (self.width.max(0) * self.height.max(0)) as usize
    }

    /// Dense link-occupancy slots: one per (cell, direction incl. ramp).
    pub fn link_slots(&self) -> usize {
        self.grid_cells() * 5
    }

    /// A compact, stable fingerprint of every compile-relevant machine
    /// parameter — the config component of the fleet plan-cache key
    /// ([`crate::fleet::PlanCache`]). Two configs with equal
    /// fingerprints build identical routing plans and compile kernels
    /// identically; per-run options (faults, watchdog — applied via
    /// [`super::SimOptions`] at simulator creation) are deliberately
    /// excluded, so jobs differing only in run options share one
    /// compilation.
    pub fn fingerprint(&self) -> String {
        format!(
            "{}x{} f{} m{} c{} t{} w{} i{} d{} h{} s{} v{} simd{} e{} cap{} lnk{} lat{}",
            self.width,
            self.height,
            self.freq_ghz,
            self.mem_bytes,
            self.max_colors,
            self.max_task_ids,
            self.task_wakeup_cycles,
            self.dsd_issue_cycles,
            self.dispatch_cycles,
            self.hop_cycles,
            self.scalar_op_cycles,
            self.data_task_wavelet_cycles,
            self.simd16_width,
            self.max_events,
            self.endpoint_capacity_words.map(|c| c as i64).unwrap_or(-1),
            self.link_buffer_words.map(|c| c as i64).unwrap_or(-1),
            self.credit_latency_cycles,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wse2_defaults() {
        let c = MachineConfig::wse2();
        assert_eq!(c.num_pes(), 750 * 994);
        assert_eq!(c.mem_bytes, 49152);
        assert_eq!(c.max_colors, 24);
    }

    #[test]
    fn cycles_conversion() {
        let c = MachineConfig::wse2();
        // paper formula: runtime[µs] = cycles / 0.85 · 10⁻³
        let us = c.cycles_to_us(850);
        assert!((us - 1.0).abs() < 1e-9);
    }
}

//! Batched DSD execution: eligibility classification and admission.
//!
//! The per-element interpreter in [`super::sim`] is fully general but
//! pays an enum dispatch, a strided address computation, and two
//! f32↔f64 conversions per element. The paper's kernels overwhelmingly
//! issue *contiguous f32* descriptors, so the plan compiler classifies
//! every DSD operation once ([`classify_vec`], stored in
//! [`super::plan::PDsd::vec`]) and the simulator executes eligible
//! operations as single slice passes — one kernel per
//! [`super::program::DsdKind`], plus a dedicated scalar-fold kernel for
//! the stride-0 accumulate idiom the backend emits for scalar
//! reductions.
//!
//! Classification is split into two stages, both conservative:
//!
//! 1. **Static** ([`classify_vec`], plan time): all operands must be
//!    memory-resident `f32` descriptors with element stride 1 (or the
//!    fold shape: a stride-0 destination re-read as `src0`), fabric-in
//!    value streams, or absent. Contiguous *16-bit integer* (`i16` /
//!    `u16`) operand sets of one uniform dtype get their own verdict
//!    ([`VecOp::Map16`]) and monomorphized kernel, and contiguous
//!    *f16* operand sets likewise ([`VecOp::MapF16`]). Mixed dtypes,
//!    non-unit strides, and any other shape fall back to the
//!    interpreter.
//! 2. **Dynamic** ([`admit_map`] / [`admit_fold`], issue time): offsets
//!    are runtime expressions, so the resolved byte spans are checked
//!    for bounds and for overlap between the destination and every
//!    memory source. Aliased or out-of-bounds operands are *never*
//!    admitted — they take the lazy per-element path, whose
//!    read-after-write semantics define the reference behaviour.
//!
//! The slice kernels themselves live in [`super::sim`] (they need the
//! PE memory); everything here is pure and unit-testable, and the
//! admission functions are exercised by the `properties.rs` fuzz suite.

use super::program::{DsdRef, Dtype};

/// Element size every slice kernel operates on (f32 / one wavelet).
pub const ELEM: usize = 4;

/// Plan-time batching verdict for one DSD operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecOp {
    /// Not statically eligible: always interpret per element.
    None,
    /// Elementwise pass: contiguous f32 destination (memory or fabric
    /// out) from contiguous f32 / fabric-in sources.
    Map,
    /// Elementwise pass over contiguous 16-bit integer (`i16`/`u16`)
    /// memory operands of one uniform dtype (fabric-in sources are
    /// stream-shaped and allowed). Executed by a second monomorphized
    /// kernel that replicates the interpreter's load → f64 → truncate
    /// store arithmetic exactly.
    Map16,
    /// Elementwise pass over contiguous `f16` memory operands (fabric-
    /// in sources allowed). Executed by a dedicated kernel replicating
    /// the interpreter's f16 → f64 widening and f64 → f32 → f16
    /// rounding chain exactly — the last dtype that used to be forced
    /// onto the per-element interpreter.
    MapF16,
    /// Scalar-fold pass: stride-0 f32 destination accumulated through
    /// `src0` aliasing it (the backend's scalar-reduction idiom).
    Fold,
}

fn contiguous_f32(r: &DsdRef) -> bool {
    matches!(r, DsdRef::Mem { stride: 1, ty: Dtype::F32, .. })
}

fn contiguous_16(r: &DsdRef, want: Dtype) -> bool {
    matches!(r, DsdRef::Mem { stride: 1, ty, .. } if *ty == want)
}

/// A source operand admissible for the 16-bit slice kernel: absent, a
/// fabric-in word stream, or contiguous memory of exactly `want`.
fn src_ok_16(s: &Option<DsdRef>, want: Dtype) -> bool {
    match s {
        None => true,
        Some(DsdRef::FabIn { .. }) => true,
        Some(r @ DsdRef::Mem { .. }) => contiguous_16(r, want),
        Some(DsdRef::FabOut { .. }) => false,
    }
}

/// A source operand admissible for slice execution: absent, a fabric-in
/// word stream (already materialized as a dense value slice by the
/// consume machinery), or a contiguous f32 memory descriptor.
fn src_ok(s: &Option<DsdRef>) -> bool {
    match s {
        None => true,
        Some(DsdRef::FabIn { .. }) => true,
        Some(r @ DsdRef::Mem { .. }) => contiguous_f32(r),
        Some(DsdRef::FabOut { .. }) => false,
    }
}

/// Statically classify a DSD operation for batched execution.
///
/// The verdict is kind-independent: the slice kernels replicate the
/// interpreter's per-element arithmetic exactly for every
/// [`super::program::DsdKind`], so only operand *shape* matters.
pub fn classify_vec(dst: &DsdRef, src0: &Option<DsdRef>, src1: &Option<DsdRef>) -> VecOp {
    match dst {
        DsdRef::FabOut { .. } if src_ok(src0) && src_ok(src1) => VecOp::Map,
        DsdRef::Mem { stride: 1, ty: Dtype::F32, .. } if src_ok(src0) && src_ok(src1) => {
            VecOp::Map
        }
        DsdRef::Mem { stride: 1, ty, .. }
            if matches!(ty, Dtype::I16 | Dtype::U16)
                && src_ok_16(src0, *ty)
                && src_ok_16(src1, *ty) =>
        {
            VecOp::Map16
        }
        DsdRef::Mem { stride: 1, ty: Dtype::F16, .. }
            if src_ok_16(src0, Dtype::F16) && src_ok_16(src1, Dtype::F16) =>
        {
            VecOp::MapF16
        }
        DsdRef::Mem { base: bd, offset: od, stride: 0, ty: Dtype::F32, .. } => {
            // Fold requires src0 to be *the same cell* as the
            // destination: same field base and an identical offset
            // expression (evaluated in the same PE state, so equal
            // expressions resolve to equal addresses).
            let acc_aliases_dst = matches!(
                src0,
                Some(DsdRef::Mem { base, offset, stride: 0, ty: Dtype::F32, .. })
                    if base == bd && offset == od
            );
            if acc_aliases_dst && src_ok(src1) {
                VecOp::Fold
            } else {
                VecOp::None
            }
        }
        _ => VecOp::None,
    }
}

/// A resolved memory operand: byte base address and byte stride per
/// element (offset expressions already evaluated).
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub base: usize,
    pub stride: isize,
}

/// The byte interval `[lo, hi)` touched by `n` elements of `esz` bytes
/// each, or `None` when degenerate (n = 0, or address arithmetic
/// leaves usize).
fn interval(s: Span, n: usize, esz: usize) -> Option<(usize, usize)> {
    if n == 0 {
        return None;
    }
    let base = i64::try_from(s.base).ok()?;
    let last = base + (n as i64 - 1) * s.stride as i64;
    let lo = base.min(last);
    let hi = base.max(last) + esz as i64;
    if lo < 0 {
        return None;
    }
    Some((lo as usize, hi as usize))
}

/// Conservative byte-interval overlap test between `na` elements of `a`
/// and `nb` elements of `b`, both `esz` bytes per element. Degenerate
/// spans count as overlapping, so callers reject them.
pub fn overlaps(a: Span, na: usize, b: Span, nb: usize, esz: usize) -> bool {
    match (interval(a, na, esz), interval(b, nb, esz)) {
        (Some((al, ah)), Some((bl, bh))) => al < bh && bl < ah,
        _ => true,
    }
}

fn in_bounds(s: Span, n: usize, esz: usize, mem_len: usize) -> bool {
    matches!(interval(s, n, esz), Some((_, hi)) if hi <= mem_len)
}

/// Runtime admission for a [`VecOp::Map`] / [`VecOp::Map16`] operation
/// over resolved spans; `esz` is the element size every span shares (4
/// for f32, 2 for the 16-bit integer kernel). `dst` is `None` for
/// fabric-out destinations (the output words live in a separate buffer
/// and cannot alias PE memory); `srcs` entries are `None` for absent /
/// fabric-in operands.
///
/// Admits only when every memory span is contiguous (`stride == esz`),
/// fully inside `mem_len` bytes, and no source overlaps the
/// destination. Never admits an aliased or overlapping pair — those
/// take the per-element path.
pub fn admit_map(
    mem_len: usize,
    dst: Option<Span>,
    srcs: &[Option<Span>],
    n: usize,
    esz: usize,
) -> bool {
    if n == 0 {
        return false;
    }
    if let Some(d) = dst {
        if d.stride != esz as isize || !in_bounds(d, n, esz, mem_len) {
            return false;
        }
    }
    for s in srcs.iter().flatten() {
        if s.stride != esz as isize || !in_bounds(*s, n, esz, mem_len) {
            return false;
        }
        if let Some(d) = dst {
            if overlaps(d, n, *s, n, esz) {
                return false;
            }
        }
    }
    true
}

/// Runtime admission for a [`VecOp::Fold`]: the accumulator is a single
/// in-bounds f32 cell (`acc.stride == 0`), and the streamed source (if
/// memory-resident) is contiguous, in bounds, and disjoint from it.
pub fn admit_fold(mem_len: usize, acc: Span, src: Option<Span>, n: usize) -> bool {
    if n == 0 || acc.stride != 0 || !in_bounds(acc, 1, ELEM, mem_len) {
        return false;
    }
    if let Some(s) = src {
        if s.stride != ELEM as isize || !in_bounds(s, n, ELEM, mem_len) {
            return false;
        }
        if overlaps(acc, 1, s, n, ELEM) {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::SExpr;

    fn mem(base: u32, off: i64, stride: i64, ty: Dtype) -> DsdRef {
        DsdRef::Mem { base, offset: SExpr::imm(off), stride, len: SExpr::imm(8), ty }
    }

    #[test]
    fn classify_contiguous_f32_map() {
        let d = mem(0, 0, 1, Dtype::F32);
        let s0 = Some(mem(64, 0, 1, Dtype::F32));
        assert_eq!(classify_vec(&d, &s0, &None), VecOp::Map);
        let fab = Some(DsdRef::FabIn { color: 1, len: SExpr::imm(8), ty: Dtype::F32 });
        assert_eq!(classify_vec(&d, &s0, &fab), VecOp::Map);
    }

    #[test]
    fn classify_rejects_strided_and_mixed_dtype() {
        let d = mem(0, 0, 1, Dtype::F32);
        assert_eq!(classify_vec(&d, &Some(mem(64, 0, 2, Dtype::F32)), &None), VecOp::None);
        assert_eq!(classify_vec(&d, &Some(mem(64, 0, 1, Dtype::F16)), &None), VecOp::None);
        assert_eq!(classify_vec(&mem(0, 0, 1, Dtype::I32), &None, &None), VecOp::None);
        assert_eq!(classify_vec(&mem(0, 0, 2, Dtype::F32), &None, &None), VecOp::None);
    }

    #[test]
    fn classify_fold_requires_exact_acc_alias() {
        let acc = mem(16, 0, 0, Dtype::F32);
        let stream = Some(mem(64, 0, 1, Dtype::F32));
        assert_eq!(classify_vec(&acc, &Some(mem(16, 0, 0, Dtype::F32)), &stream), VecOp::Fold);
        // Different base or offset: not the accumulate idiom.
        assert_eq!(classify_vec(&acc, &Some(mem(20, 0, 0, Dtype::F32)), &stream), VecOp::None);
        assert_eq!(classify_vec(&acc, &Some(mem(16, 1, 0, Dtype::F32)), &stream), VecOp::None);
        // Stride-0 dst without the alias is a last-write op, not a fold.
        assert_eq!(classify_vec(&acc, &stream, &None), VecOp::None);
    }

    #[test]
    fn admit_map_rejects_overlap_and_oob() {
        let d = Span { base: 0, stride: 4 };
        let s = Span { base: 16, stride: 4 };
        assert!(admit_map(1024, Some(d), &[Some(s), None], 4, ELEM));
        // dst [0,16) vs src [12, 28): one shared element word.
        assert!(!admit_map(1024, Some(d), &[Some(Span { base: 12, stride: 4 })], 4, ELEM));
        // Exact alias.
        assert!(!admit_map(1024, Some(d), &[Some(d)], 4, ELEM));
        // Out of bounds.
        assert!(!admit_map(24, Some(d), &[Some(s)], 4, ELEM));
        // Fabric-out dst: only sources constrain admission.
        assert!(admit_map(32, None, &[Some(s), None], 4, ELEM));
        assert!(!admit_map(16, None, &[Some(s)], 4, ELEM));
        // n = 0 falls back (the interpreter no-ops it).
        assert!(!admit_map(1024, Some(d), &[], 0, ELEM));
    }

    #[test]
    fn classify_16bit_int_map() {
        let di = mem(0, 0, 1, Dtype::I16);
        let du = mem(64, 0, 1, Dtype::U16);
        assert_eq!(classify_vec(&di, &Some(mem(64, 0, 1, Dtype::I16)), &None), VecOp::Map16);
        assert_eq!(classify_vec(&du, &Some(mem(128, 0, 1, Dtype::U16)), &None), VecOp::Map16);
        // No sources (Fill) is a valid 16-bit map shape.
        assert_eq!(classify_vec(&di, &None, &None), VecOp::Map16);
        // Fabric-in sources are stream-shaped and allowed.
        let fab = Some(DsdRef::FabIn { color: 1, len: SExpr::imm(8), ty: Dtype::I16 });
        assert_eq!(classify_vec(&di, &fab, &None), VecOp::Map16);
        // Mixed 16-bit integer dtypes (sign extension differs): fall back.
        assert_eq!(classify_vec(&di, &Some(mem(64, 0, 1, Dtype::U16)), &None), VecOp::None);
        // Strided 16-bit source: fall back.
        assert_eq!(classify_vec(&di, &Some(mem(64, 0, 2, Dtype::I16)), &None), VecOp::None);
    }

    #[test]
    fn classify_f16_map() {
        let d = mem(0, 0, 1, Dtype::F16);
        assert_eq!(classify_vec(&d, &Some(mem(64, 0, 1, Dtype::F16)), &None), VecOp::MapF16);
        // No sources (Fill) is a valid f16 map shape.
        assert_eq!(classify_vec(&d, &None, &None), VecOp::MapF16);
        // Fabric-in sources are stream-shaped and allowed.
        let fab = Some(DsdRef::FabIn { color: 1, len: SExpr::imm(8), ty: Dtype::F16 });
        assert_eq!(classify_vec(&d, &fab, &None), VecOp::MapF16);
        // Mixed dtypes and strided f16 operands: fall back.
        assert_eq!(classify_vec(&d, &Some(mem(64, 0, 1, Dtype::I16)), &None), VecOp::None);
        assert_eq!(classify_vec(&d, &Some(mem(64, 0, 2, Dtype::F16)), &None), VecOp::None);
        // An f16 source under an f32 destination is a conversion: fall back.
        assert_eq!(
            classify_vec(&mem(0, 0, 1, Dtype::F32), &Some(mem(64, 0, 1, Dtype::F16)), &None),
            VecOp::None
        );
    }

    #[test]
    fn admit_map_16bit_element_size() {
        let d = Span { base: 0, stride: 2 };
        let s = Span { base: 8, stride: 2 };
        assert!(admit_map(1024, Some(d), &[Some(s), None], 4, 2));
        // dst [0,8) vs src [6,14): one shared halfword.
        assert!(!admit_map(1024, Some(d), &[Some(Span { base: 6, stride: 2 })], 4, 2));
        // A 4-byte stride is not contiguous for 2-byte elements.
        assert!(!admit_map(1024, Some(d), &[Some(Span { base: 8, stride: 4 })], 4, 2));
        // Bounds are measured in halfwords: 4 elems at base 8 end at 16.
        assert!(admit_map(16, None, &[Some(s)], 4, 2));
        assert!(!admit_map(15, None, &[Some(s)], 4, 2));
    }

    #[test]
    fn admit_fold_rejects_acc_inside_stream() {
        let acc = Span { base: 32, stride: 0 };
        assert!(admit_fold(1024, acc, Some(Span { base: 64, stride: 4 }), 8));
        assert!(admit_fold(1024, acc, None, 8));
        // Stream runs over the accumulator cell.
        assert!(!admit_fold(1024, acc, Some(Span { base: 24, stride: 4 }), 8));
        // Strided stream is not a slice.
        assert!(!admit_fold(1024, acc, Some(Span { base: 64, stride: 8 }), 8));
        assert!(!admit_fold(1024, Span { base: 32, stride: 4 }, None, 8));
    }

    #[test]
    fn interval_math_is_exact_for_unit_stride() {
        assert!(!overlaps(
            Span { base: 0, stride: 4 },
            4,
            Span { base: 16, stride: 4 },
            4,
            ELEM
        ));
        assert!(overlaps(Span { base: 0, stride: 4 }, 5, Span { base: 16, stride: 4 }, 4, ELEM));
    }
}

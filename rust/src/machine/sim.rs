//! Discrete-event simulator core.
//!
//! Timing model (see module docs in [`super`]): all *timing* math uses
//! flow/word timestamps carried in metadata; the event queue only drives
//! processing order. Flows deliver their full payload at the first-word
//! arrival event together with per-word availability times, which keeps
//! the event count O(flows), not O(wavelets), while preserving wormhole
//! pipelining behaviour (chained reductions overlap hop-by-hop exactly as
//! on the real fabric).
//!
//! The runtime is *flat-memory*: every lookup the event loop needs is
//! resolved at [`Simulator::new`] time by [`super::plan::RoutingPlan`]
//! — dense row-major PE and link-occupancy arrays, pre-traced multicast
//! routes, per-class color→endpoint-slot tables, and compiled task
//! bodies with interned completion actions. Event-heap entries are
//! `Copy` (flow payloads live in an indexed pool), so processing an
//! event performs no hash lookups and no per-event heap allocation.
//!
//! DSD execution is *batched* where legal: the plan compiler marks
//! contiguous-f32 operations ([`super::vecop`]) and the simulator runs
//! them as single slice passes (one kernel per [`DsdKind`], plus
//! monomorphized variants for contiguous 16-bit integer and f16
//! operands and a scalar-fold kernel for stride-0 accumulation),
//! falling back to the per-element interpreter for aliased / strided /
//! mixed-dtype descriptors. Both paths are bit-identical; `SPADA_NO_VEC=1` (or
//! [`Simulator::set_vectorize`]) forces the interpreter everywhere.
//!
//! Execution is *epoch-parallel* when more than one worker thread is
//! configured (`SPADA_THREADS` / [`Simulator::set_threads`]; default =
//! available host parallelism). PEs share no memory and interact only
//! through routed flows, so the plan partitions them into link-sharing
//! islands (PEs whose flows can contend for a physical link — see
//! [`RoutingPlan`]), the islands fold onto a fixed shard count, and
//! every shard owns its PEs, link slots, event queue, payload pool and
//! metric counters outright. Time advances in epochs bounded by the
//! plan's conservative cross-island lookahead; within an epoch every
//! shard steps independently on a `std::thread::scope` worker pool,
//! and cross-shard flow arrivals are buffered per shard and merged at
//! the epoch barrier in a deterministic order (arrival timestamp, then
//! send timestamp, then dense source-PE index, then per-shard sequence
//! number — the send-timestamp tie-break reproduces the classic
//! global-sequence order at equal arrival times). The shard count
//! is independent of the worker count, and per-shard metrics merge by
//! commutative sums, so outputs, `RunReport` metrics and cycle counts
//! are **bit-identical across all thread counts**; `SPADA_THREADS=1`
//! runs the classic single-queue event loop (the one-shard degenerate
//! case of the same engine).
//!
//! Endpoint buffers are *finite* when a capacity is configured
//! (`SPADA_BUF_CAP` / [`MachineConfig::endpoint_capacity_words`]):
//! each (PE, color) endpoint is a credit-managed
//! [`super::flowctl::EndpointBuf`] — an arriving flow admits words up
//! to the free credits and stalls its tail in the fabric, wormhole
//! style, until consumption returns credits. Stall state is entirely
//! endpoint-local and admission order is the deterministic arrival
//! order, so capped runs are bit-identical across thread counts too
//! (a cross-shard arrival that finds a full endpoint enqueues its
//! stalled tail in the merged order; stalls only *delay* word
//! availability, so the conservative lookahead stays sound). A run
//! that quiesces with stalled words reports a buffer deadlock naming
//! the blocked endpoints. With no capacity configured the buffers are
//! unbounded and behaviour is bit-identical to every prior snapshot.

use super::config::MachineConfig;
use super::fault::{
    FaultSet, FK_CORRUPT, FK_DELAY, FK_LINK_KILL, FK_LINK_SLOW, FK_PE_HALT,
};
use super::flowctl::EndpointBuf;
use super::metrics::{Metrics, RunReport};
use super::options::SimOptions;
use super::plan::{
    FlowError, PAction, PDsd, POp, PTaskKind, RoutingPlan, ACTIONS_EMPTY, SLOT_NONE, TASK_NONE,
};
use super::program::{
    DsdKind, DsdRef, Dtype, IoDir, MachineProgram, SBinOp, SExpr, SVal, TaskActionKind,
};
use super::router::RouteError;
use super::trace::{EngineStats, EpochRecord, Trace, TraceRecord};
use super::vecop::{self, Span, VecOp, ELEM};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Barrier, Mutex};

/// Simulator errors.
#[derive(Debug, Clone)]
pub enum SimError {
    /// Program failed resource validation (paper's OOR / OOM).
    Validation(Vec<String>),
    Route(RouteError),
    /// Quiescence with unsatisfied fabric consumers or blocked tasks.
    Deadlock(String),
    /// Event budget exhausted.
    Runaway(u64),
    /// Bad I/O binding or size mismatch.
    Io(String),
    /// Malformed program detected at runtime.
    Program(String),
    /// Wall-clock watchdog fired (`SPADA_TIMEOUT_MS` /
    /// [`MachineConfig::timeout_ms`]) — the run was aborted, not
    /// completed; simulated state is wherever the engines stopped.
    Timeout(String),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Validation(v) => write!(f, "validation failed: {}", v.join("; ")),
            SimError::Route(e) => write!(f, "routing error: {e}"),
            SimError::Deadlock(s) => write!(f, "deadlock: {s}"),
            SimError::Runaway(n) => write!(f, "event budget exhausted ({n})"),
            SimError::Io(s) => write!(f, "io error: {s}"),
            SimError::Program(s) => write!(f, "program error: {s}"),
            SimError::Timeout(s) => write!(f, "timeout: {s}"),
        }
    }
}

impl SimError {
    /// Stable machine-readable discriminant — `spada run --json` error
    /// objects and resilience-campaign rows key on it.
    pub fn kind(&self) -> &'static str {
        match self {
            SimError::Validation(_) => "validation",
            SimError::Route(_) => "route",
            SimError::Deadlock(_) => "deadlock",
            SimError::Runaway(_) => "runaway",
            SimError::Io(_) => "io",
            SimError::Program(_) => "program",
            SimError::Timeout(_) => "timeout",
        }
    }

    /// The error as a one-line JSON object (every `spada run --json`
    /// failure path emits this). `site` is the engine's error site
    /// (cycle, PE x, PE y) when one is known.
    pub fn to_json(&self, site: Option<(u64, i64, i64)>) -> String {
        let msg = self.to_string().replace('\\', "\\\\").replace('"', "\\\"");
        match site {
            Some((cycle, x, y)) => format!(
                "{{\"error\":{{\"kind\":\"{}\",\"cycle\":{},\"pe\":[{},{}],\
                 \"message\":\"{}\"}}}}\n",
                self.kind(),
                cycle,
                x,
                y,
                msg
            ),
            None => format!(
                "{{\"error\":{{\"kind\":\"{}\",\"message\":\"{}\"}}}}\n",
                self.kind(),
                msg
            ),
        }
    }
}

impl std::error::Error for SimError {}

impl From<RouteError> for SimError {
    fn from(e: RouteError) -> Self {
        SimError::Route(e)
    }
}

const NUM_REGS: usize = 64;

/// Per-task runtime state.
#[derive(Clone, Debug, Default)]
struct TaskState {
    active: bool,
    blocked: bool,
}

/// A vector operand for elementwise DSD application.
enum VOp<'a> {
    Mem(&'a DsdRef),
    Vals(&'a [f64]),
    Nothing,
}

/// A resolved memory descriptor: byte base + byte stride.
struct RMem {
    base: usize,
    stride: isize,
    ty: Dtype,
}

/// A resolved vector operand (hot-loop form of [`VOp`]).
enum RVOp<'a> {
    Mem(RMem),
    Vals(&'a [f64]),
    Nothing,
}

/// An outstanding microthreaded fabric-in consumer. The operation is a
/// plan-time consume template referenced by index — issuing a
/// microthread clones nothing.
struct PendingConsume {
    /// Index into the class's [`RoutingPlan`] consume-template table.
    consume_ix: u32,
    need: usize,
    taken: Vec<u32>,
    /// Availability time of the last word taken so far.
    last_avail: u64,
    issue_time: u64,
}

/// Per-(PE, endpoint slot) fabric endpoint state: the credit-managed
/// arrival buffer (see [`super::flowctl`]) plus pending microthreaded
/// consumers.
struct ColorEndpoint {
    buf: EndpointBuf,
    consumers: VecDeque<PendingConsume>,
}

impl ColorEndpoint {
    fn new(cap: Option<u64>, credit_latency: u64) -> ColorEndpoint {
        ColorEndpoint {
            buf: EndpointBuf::with_credit_latency(cap, credit_latency),
            consumers: VecDeque::new(),
        }
    }
}

/// One pooled flow payload. The pool slot releases its reference after
/// the last destination's `FlowArrive` event is processed, so payload
/// memory is freed once every endpoint holds (or has drained) its own
/// `Arc` — matching the pre-pool lifetime.
struct FlowPayload {
    words: Option<Arc<Vec<u32>>>,
    /// `FlowArrive` events still outstanding for this payload.
    pending: u32,
}

/// Runtime state of one PE.
struct Pe {
    /// Dense (global) PE index — events and plan tables are keyed by
    /// it, and shard-local PE vectors map back through it.
    gix: u32,
    x: i64,
    y: i64,
    class: usize,
    mem: Vec<u8>,
    regs: [SVal; NUM_REGS],
    tasks: Vec<TaskState>,
    /// Bit r (scheduler-rank order) set = the task at `order[r]` is
    /// potentially runnable: local tasks exactly (active && !blocked),
    /// data tasks when unblocked with queued flows and no microthread
    /// bound. Maintained by `ShardState::refresh_task_bit`; lets the
    /// scheduler skip quiescent tasks without re-inspection.
    ready: u32,
    busy_until: u64,
    last_activity: u64,
    /// Dense endpoint table, indexed by the class's color→slot map.
    endpoints: Vec<ColorEndpoint>,
    ran_anything: bool,
    busy_cycles: u64,
}

#[derive(Clone, Copy, Debug)]
enum EventKind {
    /// Try to run a ready task on this PE.
    PeReady(u32),
    /// A flow's first word reaches this PE's ramp. The payload is an
    /// index into the simulator's flow-payload pool.
    FlowArrive { pe: u32, slot: u8, first_word: u64, payload: u32 },
    /// A microthread completed: apply the interned action list.
    Complete { pe: u32, actions: u32 },
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: u64,
    /// Simulation time at which this event was *scheduled* (the
    /// scheduler's `now`; for cross-shard arrivals, the sender's).
    /// Tie-breaking same-`time` events by scheduling time first
    /// reproduces the classic global-sequence order across shards:
    /// within one shard `sched` is non-decreasing in `seq`, so
    /// ordering by (time, sched, seq) is identical to the historical
    /// (time, seq); across shards it puts a flow arrival sent at
    /// simulation time 5 ahead of a wakeup scheduled at time 10 even
    /// though the arrival was merged (and numbered) later. The one
    /// shape this cannot disambiguate is two *same-color* arrivals at
    /// one endpoint with equal (time, sched) from different source
    /// PEs — a multi-writer endpoint race the static checker
    /// (`analysis::races`) rejects before such a program ever
    /// simulates; for statically clean programs the order is the
    /// classic one.
    sched: u64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        (self.time, self.sched, self.seq) == (other.time, other.sched, other.seq)
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.sched, self.seq).cmp(&(other.time, other.sched, other.seq))
    }
}

/// A cross-shard flow delivery buffered by the sending shard during an
/// epoch and merged into the destination shard at the epoch barrier.
struct OutMsg {
    /// Event time at the destination (never earlier than the epoch
    /// boundary — the plan's lookahead guarantees it).
    time: u64,
    /// The sender's simulation time when the flow was sent — the
    /// delivered event's [`Event::sched`] tie-break key.
    sched: u64,
    /// Availability time of word 0 at the destination ramp.
    first_word: u64,
    /// Destination (global PE index, endpoint slot).
    dst: u32,
    slot: u8,
    words: Arc<Vec<u32>>,
    /// Deterministic merge key: (time, src_pe, src_seq) is a total
    /// order over every message of one epoch.
    src_pe: u32,
    src_seq: u64,
}

/// Runtime shard decomposition: global→shard-local index maps shared
/// read-only by every worker. Built per run from the plan's
/// link-sharing islands; `None` in [`Ctx::maps`] means the one-shard
/// (classic single-threaded) layout where every map is the identity.
struct ShardMaps {
    /// Global PE index → owning shard.
    shard_of: Vec<u32>,
    /// Global PE index → position in its shard's PE vector.
    pe_loc: Vec<u32>,
    /// Global dense link index → slot in the owning shard's busy
    /// array (`u32::MAX` for links no planned flow occupies).
    link_loc: Vec<u32>,
}

/// Hard cap on runtime shards. Fixed (never a function of the worker
/// count) so every thread count ≥ 2 sees the same decomposition and
/// therefore processes byte-identical per-shard event sequences.
const MAX_SHARDS: usize = 64;

/// Immutable per-run context shared by every worker thread.
struct Ctx<'a> {
    cfg: &'a MachineConfig,
    plan: &'a RoutingPlan,
    vec_enabled: bool,
    /// Trace-record emission enabled (see [`super::trace`]). Checked
    /// before every push so tracing is zero-cost when off.
    trace: bool,
    maps: Option<&'a ShardMaps>,
    /// Events processed across all shards — the runaway budget is a
    /// *global* bound, like the classic engine's. The one-shard path
    /// checks its local counter exactly; parallel shards add to this
    /// in batches (see [`EVENT_BATCH`]) so a program whose total event
    /// count exceeds `cfg.max_events` errors at every thread count.
    events_total: &'a AtomicU64,
    /// Compiled fault set (see [`super::fault`]); `None` on clean runs,
    /// so the fault paths cost one branch when no faults are configured.
    faults: Option<&'a FaultSet>,
    /// Wall-clock watchdog deadline (`SPADA_TIMEOUT_MS`). Checked at
    /// every `run_until` entry and every [`EVENT_BATCH`] events — an
    /// abort-only guard; it never alters simulated time.
    deadline: Option<std::time::Instant>,
}

/// Granularity at which parallel shards flush their processed-event
/// counts into [`Ctx::events_total`]. The budget check can overshoot
/// by at most `MAX_SHARDS · EVENT_BATCH` events — the Runaway error
/// value itself is identical everywhere.
const EVENT_BATCH: u64 = 1024;

impl Ctx<'_> {
    /// Shard-local index of a global PE.
    #[inline]
    fn loc(&self, gpe: u32) -> usize {
        match self.maps {
            None => gpe as usize,
            Some(m) => m.pe_loc[gpe as usize] as usize,
        }
    }

    /// Owning shard of a global PE.
    #[inline]
    fn shard_of(&self, gpe: u32) -> u32 {
        match self.maps {
            None => 0,
            Some(m) => m.shard_of[gpe as usize],
        }
    }

    /// Shard-local slot of a global link index.
    #[inline]
    fn link(&self, li: u32) -> usize {
        match self.maps {
            None => li as usize,
            Some(m) => m.link_loc[li as usize] as usize,
        }
    }
}

/// One shard's complete runtime state. The event-processing engine
/// lives here: every handler touches only this shard's PEs, links,
/// payload pool and counters, so shards step concurrently without
/// synchronization; cross-shard flow arrivals leave through `outbox`.
/// A single shard spanning the whole fabric (identity maps) *is* the
/// classic single-threaded simulator.
struct ShardState {
    ix: u32,
    /// PEs owned by this shard, in ascending global index order.
    pes: Vec<Pe>,
    /// Busy-until per link slot owned by this shard.
    link_busy: Vec<u64>,
    /// Flow payload pool; `FlowArrive` events reference entries by index
    /// so heap entries stay `Copy`.
    payloads: Vec<FlowPayload>,
    /// Pool slots whose arrivals all drained — recycled by `send_flow`
    /// so the pool stays O(in-flight flows), not O(total flows).
    free_payloads: Vec<u32>,
    events: BinaryHeap<Reverse<Event>>,
    now: u64,
    seq: u64,
    metrics: Metrics,
    /// DSD operations executed through the slice kernels (not a
    /// [`Metrics`] field: metrics are bit-identical across modes).
    vec_ops: u64,
    /// Reusable slice-kernel operand buffers (no per-op allocation).
    scratch_a: Vec<f64>,
    scratch_b: Vec<f64>,
    /// Cross-shard deliveries generated this epoch.
    outbox: Vec<OutMsg>,
    /// First error this shard hit, keyed (event time, global PE) so the
    /// coordinator picks the globally earliest one deterministically.
    error: Option<(u64, u32, SimError)>,
    /// Trace records emitted by this shard (empty unless tracing is
    /// on). Per-shard buffers need no synchronization; the run
    /// epilogue concatenates them in shard-index order and stably
    /// sorts by `(start, pe)` to reproduce the single-threaded stream.
    trace: Vec<TraceRecord>,
    /// Per-fault-spec fired/counted flags (indexed by spec index; empty
    /// on clean runs). One-shot effects — the seeded corruption, the
    /// once-per-halt metric/trace emission — key off these. Each spec's
    /// site (source PE or halted PE) is owned by exactly one shard, so
    /// per-shard flags observe every firing exactly once.
    fault_fired: Vec<bool>,
}

/// Lock a shard even if a panicking worker poisoned its mutex — the
/// shard's own `error` field (set by the panic handler) carries the
/// failure; a poisoned lock must not turn into a second panic or a
/// barrier deadlock.
fn lock_shard(m: &Mutex<ShardState>) -> std::sync::MutexGuard<'_, ShardState> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// The WSE-2 simulator. Construct with [`Simulator::new`], feed inputs
/// with [`Simulator::set_input`], [`Simulator::run`], then read outputs.
pub struct Simulator {
    pub cfg: MachineConfig,
    prog: Arc<MachineProgram>,
    /// Everything resolvable before the first event (see `machine::plan`).
    /// Shared with the compiler/checker when constructed via
    /// [`Simulator::with_plan`] — one trace per compiled kernel.
    plan: Arc<RoutingPlan>,
    /// PE runtime state in dense (global) order. During a run the PEs
    /// are moved into shards; they return here before `run` exits.
    pes: Vec<Pe>,
    /// External inputs staged before run (arg name -> data words).
    inputs: HashMap<String, Vec<u32>>,
    ran: bool,
    /// Batched DSD execution enabled (default on; `SPADA_NO_VEC` in the
    /// environment or [`Simulator::set_vectorize`] force the
    /// per-element interpreter everywhere).
    vec_enabled: bool,
    /// Worker threads for the epoch-parallel engine (`SPADA_THREADS` or
    /// host parallelism by default; 1 = classic single-queue loop).
    threads: usize,
    /// Slice-kernel executions, summed over shards after each run.
    vec_ops: u64,
    /// Trace-record capture enabled ([`Simulator::set_tracing`]).
    tracing: bool,
    /// Raw per-shard records, concatenated in shard-index order during
    /// reassembly, before the deterministic merge sort.
    trace_raw: Vec<TraceRecord>,
    /// Epoch log accumulated by the parallel coordinator.
    epoch_raw: Vec<EpochRecord>,
    /// The finished run's merged trace (tracing runs only).
    trace: Option<Trace>,
    /// Engine shape of the last run (both engines populate this).
    engine: EngineStats,
    /// `(event cycle, global PE)` of the last run's engine error, when
    /// one was recorded — the site `spada run --json` error objects
    /// report. `None` for pre-run errors (validation, I/O) and for the
    /// epilogue's deadlock report.
    err_site: Option<(u64, u32)>,
}

impl Simulator {
    /// Build a simulator for `prog` on `cfg`: validate resources, then
    /// precompile the routing/execution plan (all routes traced, task
    /// tables resolved, bodies compiled) so [`Simulator::run`] does no
    /// per-event resolution work.
    ///
    /// For a kernel compiled through [`crate::kernels::compile`], prefer
    /// [`crate::kernels::CompiledKernel::simulator`], which reuses the
    /// plan instance the compiler and checker already built instead of
    /// re-tracing every route here.
    pub fn new(cfg: MachineConfig, prog: MachineProgram) -> Result<Simulator, SimError> {
        let plan = Arc::new(RoutingPlan::build(&prog, &cfg));
        Self::with_plan(cfg, prog, plan)
    }

    /// Build a simulator around an existing precompiled plan, with the
    /// runtime options resolved from the environment once
    /// ([`SimOptions::from_env`] — the historical `SPADA_*` behaviour,
    /// through the single resolve site). Batch jobs with per-job
    /// options use [`Simulator::with_plan_opts`] instead.
    pub fn with_plan(
        cfg: MachineConfig,
        prog: MachineProgram,
        plan: Arc<RoutingPlan>,
    ) -> Result<Simulator, SimError> {
        Self::with_plan_opts(cfg, prog, plan, &SimOptions::from_env())
    }

    /// Build a simulator around an existing precompiled plan with
    /// **explicit** runtime options — the environment is never
    /// consulted, so concurrent simulations with different options
    /// coexist in one process (the batch-fleet prerequisite). The plan
    /// must have been built from exactly this `(prog, cfg)` pair (the
    /// geometry is cross-checked; the rest is the caller's contract).
    ///
    /// Options mirroring a config field (buffer capacity, credit
    /// latency, watchdog, faults) fill only pristine config defaults —
    /// an explicitly configured `cfg` wins (see [`SimOptions`]).
    pub fn with_plan_opts(
        mut cfg: MachineConfig,
        prog: MachineProgram,
        plan: Arc<RoutingPlan>,
        opts: &SimOptions,
    ) -> Result<Simulator, SimError> {
        opts.apply_defaults_to(&mut cfg);
        let errs = prog.validate(&cfg);
        if !errs.is_empty() {
            return Err(SimError::Validation(errs));
        }
        if plan.width != cfg.width || plan.height != cfg.height {
            return Err(SimError::Program(format!(
                "routing plan was built for a {}x{} fabric, simulator config is {}x{}",
                plan.width, plan.height, cfg.width, cfg.height
            )));
        }
        if let Some(e) = plan.build_errors.first() {
            return Err(SimError::Program(e.clone()));
        }
        let prog = Arc::new(prog);
        let buf_cap = cfg.endpoint_capacity_words;
        let mut pes = Vec::with_capacity(plan.pes.len());
        for (g, p) in plan.pes.iter().enumerate() {
            let class = &prog.classes[p.class];
            let nslots = plan.classes[p.class].slot_color.len();
            pes.push(Pe {
                gix: g as u32,
                x: p.x,
                y: p.y,
                class: p.class,
                mem: vec![0u8; class.mem_size as usize],
                regs: [SVal::I(0); NUM_REGS],
                tasks: vec![TaskState::default(); class.tasks.len()],
                ready: 0,
                busy_until: 0,
                last_activity: 0,
                endpoints: (0..nslots)
                    .map(|_| ColorEndpoint::new(buf_cap, cfg.credit_latency_cycles))
                    .collect(),
                ran_anything: false,
                busy_cycles: 0,
            });
        }
        Ok(Simulator {
            cfg,
            prog,
            plan,
            pes,
            inputs: HashMap::new(),
            ran: false,
            vec_enabled: !opts.no_vectorize,
            threads: opts.resolved_threads(),
            vec_ops: 0,
            tracing: opts.tracing_enabled(),
            trace_raw: Vec::new(),
            epoch_raw: Vec::new(),
            trace: None,
            engine: EngineStats::default(),
            err_site: None,
        })
    }

    pub fn program(&self) -> &MachineProgram {
        &self.prog
    }

    /// The precompiled routing/execution plan.
    pub fn plan(&self) -> &RoutingPlan {
        &self.plan
    }

    /// Toggle the batched (slice-kernel) DSD engine. Defaults to on
    /// unless `SPADA_NO_VEC` is set in the environment. Both modes are
    /// bit-identical in outputs, metrics and cycle counts — the toggle
    /// exists for the equivalence suite and for debugging.
    pub fn set_vectorize(&mut self, on: bool) {
        self.vec_enabled = on;
    }

    /// Whether the batched DSD engine is enabled.
    pub fn vectorize_enabled(&self) -> bool {
        self.vec_enabled
    }

    /// How many DSD operations ran through the slice kernels (0 when
    /// vectorization is disabled or no operation was admitted).
    pub fn vec_ops_executed(&self) -> u64 {
        self.vec_ops
    }

    /// Set the worker-thread count for [`Simulator::run`]. `1` runs the
    /// classic single-queue event loop; any count ≥ 2 runs the
    /// epoch-parallel engine over a shard decomposition that is fixed
    /// per plan (never a function of the thread count), so results are
    /// bit-identical across all values. Defaults to `SPADA_THREADS`
    /// from the environment, else the host's available parallelism.
    pub fn set_threads(&mut self, n: usize) {
        self.threads = n.max(1);
    }

    /// The configured worker-thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enable cycle-accurate trace capture for subsequent runs (see
    /// [`super::trace`]). Off by default; tracing records what the
    /// engines already compute and never perturbs simulated time —
    /// reports and outputs are bit-identical either way.
    pub fn set_tracing(&mut self, on: bool) {
        self.tracing = on;
    }

    /// Whether trace capture is enabled.
    pub fn tracing_enabled(&self) -> bool {
        self.tracing
    }

    /// The last run's merged trace (`None` unless tracing was enabled).
    /// Records are sorted by `(start, pe)` with per-PE emission order
    /// preserved — byte-identical across thread counts.
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// Take ownership of the last run's trace.
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.take()
    }

    /// Engine shape of the last run: shard count, epochs, per-shard
    /// event totals and barrier-wait time. Populated by both engines
    /// (the classic loop reports one shard, zero epochs).
    pub fn engine_stats(&self) -> &EngineStats {
        &self.engine
    }

    /// Reset all runtime state so this allocation can run again:
    /// restores every PE's memory to the plan's pristine image (fields
    /// are zero-initialized; inputs are staged per run, so pristine =
    /// zeroed), clears task/endpoint/scheduler state, and re-arms
    /// [`Simulator::run`]. Staged inputs are kept and reloaded by the
    /// next run. This is the bench-sweep lever: repeated runs of one
    /// compilation reuse a single allocation instead of re-cloning the
    /// machine program and every PE image per run.
    pub fn reset(&mut self) {
        for pe in &mut self.pes {
            pe.mem.fill(0);
            pe.regs = [SVal::I(0); NUM_REGS];
            for t in &mut pe.tasks {
                *t = TaskState::default();
            }
            pe.ready = 0;
            pe.busy_until = 0;
            pe.last_activity = 0;
            for ep in &mut pe.endpoints {
                ep.buf.clear();
                ep.consumers.clear();
            }
            pe.ran_anything = false;
            pe.busy_cycles = 0;
        }
        self.vec_ops = 0;
        self.ran = false;
        self.trace_raw.clear();
        self.epoch_raw.clear();
        self.trace = None;
        self.engine = EngineStats::default();
        self.err_site = None;
    }

    /// The last run's engine error site as `(cycle, x, y)`, if one was
    /// recorded — feed to [`SimError::to_json`].
    pub fn error_site(&self) -> Option<(u64, i64, i64)> {
        self.err_site.map(|(t, g)| {
            let p = &self.plan.pes[g as usize];
            (t, p.x, p.y)
        })
    }

    /// Dense PE lookup (row-major grid table).
    fn pe_index(&self, x: i64, y: i64) -> Option<usize> {
        self.plan.pe_index(x, y)
    }

    /// Stage input data for a kernel argument (f32 layout).
    pub fn set_input(&mut self, arg: &str, data: &[f32]) -> Result<(), SimError> {
        let words: Vec<u32> = data.iter().map(|v| v.to_bits()).collect();
        self.set_input_words(arg, words)
    }

    /// Stage raw 32-bit words for a kernel argument.
    pub fn set_input_words(&mut self, arg: &str, words: Vec<u32>) -> Result<(), SimError> {
        let binding = self
            .prog
            .io
            .iter()
            .find(|b| b.arg == arg && b.dir == IoDir::In)
            .ok_or_else(|| SimError::Io(format!("no input binding for {arg}")))?;
        let expect = binding.total_ports as usize * binding.elems_per_pe as usize;
        if words.len() != expect {
            return Err(SimError::Io(format!(
                "input {arg}: got {} elements, binding expects {expect}",
                words.len()
            )));
        }
        self.inputs.insert(arg.to_string(), words);
        Ok(())
    }

    /// Load staged inputs into extern fields.
    fn load_inputs(&mut self) -> Result<(), SimError> {
        let prog = Arc::clone(&self.prog);
        for binding in prog.io.iter().filter(|b| b.dir == IoDir::In) {
            let words = match self.inputs.get(&binding.arg) {
                Some(w) => w.clone(),
                None => {
                    vec![0u32; binding.total_ports as usize * binding.elems_per_pe as usize]
                }
            };
            for (x, y) in binding.subgrid.iter() {
                let pe_idx = self.pe_index(x, y).ok_or_else(|| {
                    SimError::Io(format!(
                        "input {} targets PE ({x},{y}) with no code",
                        binding.arg
                    ))
                })?;
                let class = &prog.classes[self.pes[pe_idx].class];
                let field = class.field(&binding.field).ok_or_else(|| {
                    SimError::Io(format!(
                        "input {}: field {} missing in class {}",
                        binding.arg, binding.field, class.name
                    ))
                })?;
                if binding.elems_per_pe > field.len {
                    return Err(SimError::Io(format!(
                        "input {}: {} elems/PE > field {} len {}",
                        binding.arg, binding.elems_per_pe, field.name, field.len
                    )));
                }
                let port = binding.port_map.port(x, y);
                if port < 0 || port >= binding.total_ports as i64 {
                    return Err(SimError::Io(format!(
                        "input {}: PE ({x},{y}) maps to port {port} outside [0,{})",
                        binding.arg, binding.total_ports
                    )));
                }
                let off = port as usize * binding.elems_per_pe as usize;
                let esz = binding.ty.size();
                for k in 0..binding.elems_per_pe as usize {
                    let addr = field.addr as usize + k * esz;
                    let w = words[off + k];
                    match esz {
                        4 => self.pes[pe_idx].mem[addr..addr + 4].copy_from_slice(&w.to_le_bytes()),
                        2 => self.pes[pe_idx].mem[addr..addr + 2]
                            .copy_from_slice(&(w as u16).to_le_bytes()),
                        _ => unreachable!(),
                    }
                }
            }
        }
        Ok(())
    }

    /// Read an output argument back (f32 layout).
    pub fn get_output(&self, arg: &str) -> Result<Vec<f32>, SimError> {
        Ok(self.get_output_words(arg)?.into_iter().map(f32::from_bits).collect())
    }

    pub fn get_output_words(&self, arg: &str) -> Result<Vec<u32>, SimError> {
        let bindings: Vec<_> =
            self.prog.io.iter().filter(|b| b.arg == arg && b.dir == IoDir::Out).collect();
        if bindings.is_empty() {
            return Err(SimError::Io(format!("no output binding for {arg}")));
        }
        let total =
            bindings[0].total_ports as usize * bindings[0].elems_per_pe as usize;
        let mut out = vec![0u32; total];
        for binding in bindings {
            for (x, y) in binding.subgrid.iter() {
                let pe_idx = self
                    .pe_index(x, y)
                    .ok_or_else(|| SimError::Io(format!("output {arg}: PE ({x},{y}) has no code")))?;
                let class = &self.prog.classes[self.pes[pe_idx].class];
                let field = class.field(&binding.field).ok_or_else(|| {
                    SimError::Io(format!("output {arg}: field {} missing", binding.field))
                })?;
                let port = binding.port_map.port(x, y);
                if port < 0 || port >= binding.total_ports as i64 {
                    return Err(SimError::Io(format!(
                        "output {}: PE ({x},{y}) maps to port {port} outside [0,{})",
                        binding.arg, binding.total_ports
                    )));
                }
                let off = port as usize * binding.elems_per_pe as usize;
                let esz = binding.ty.size();
                for k in 0..binding.elems_per_pe as usize {
                    let addr = field.addr as usize + k * esz;
                    let w = match esz {
                        4 => u32::from_le_bytes(
                            self.pes[pe_idx].mem[addr..addr + 4].try_into().unwrap(),
                        ),
                        2 => u16::from_le_bytes(
                            self.pes[pe_idx].mem[addr..addr + 2].try_into().unwrap(),
                        ) as u32,
                        _ => unreachable!(),
                    };
                    out[off + k] = w;
                }
            }
        }
        Ok(out)
    }

    /// Debug: read `len` elements of `field` at PE (x, y) as f32.
    pub fn read_field(&self, x: i64, y: i64, field: &str) -> Option<Vec<f32>> {
        let pe_idx = self.pe_index(x, y)?;
        let class = &self.prog.classes[self.pes[pe_idx].class];
        let f = class.field(field)?;
        let mut out = Vec::with_capacity(f.len as usize);
        for k in 0..f.len as usize {
            let addr = f.addr as usize + k * f.ty.size();
            out.push(f32::from_bits(u32::from_le_bytes(
                self.pes[pe_idx].mem[addr..addr + 4].try_into().unwrap(),
            )));
        }
        Some(out)
    }

    /// Run the kernel to quiescence. Returns the run report.
    ///
    /// With one worker thread (or a plan whose PEs all share one
    /// link-sharing island) this is the classic single-queue event
    /// loop. Otherwise the epoch-parallel engine steps the shards
    /// concurrently — bit-identical results either way (pinned by
    /// `tests/parallel_equiv.rs`).
    pub fn run(&mut self) -> Result<RunReport, SimError> {
        assert!(!self.ran, "Simulator::run is single-shot (use Simulator::reset to rerun)");
        self.ran = true;
        // Fault configuration is validated loudly up front: a malformed
        // `SPADA_FAULTS` string or a spec naming a site this fabric /
        // program doesn't have would otherwise arm a campaign that
        // silently never fires.
        if let Some(msg) = self.cfg.faults.invalid.clone() {
            return Err(SimError::Validation(vec![format!("SPADA_FAULTS: {msg}")]));
        }
        let faults = FaultSet::compile(&self.cfg.faults, &self.cfg, &self.plan)
            .map_err(|e| SimError::Validation(vec![e]))?;
        let deadline = self
            .cfg
            .timeout_ms
            .map(|ms| std::time::Instant::now() + std::time::Duration::from_millis(ms));
        self.load_inputs()?;
        // Arm (or disarm) endpoint stall logging to match the tracing
        // flag — logging mirrors credit accounting without touching
        // admission times, so this cannot perturb the run.
        let tracing = self.tracing;
        for pe in &mut self.pes {
            for ep in &mut pe.endpoints {
                ep.buf.set_logging(tracing);
            }
        }
        let plan = Arc::clone(&self.plan);
        let threads = self.threads.max(1);
        // The parallel engine needs ≥ 2 islands to decompose and a
        // positive lookahead to advance epochs (lookahead 0 only occurs
        // under a zero-hop-cost config, where no window can close).
        let result = if threads == 1 || plan.n_islands <= 1 || plan.lookahead == 0 {
            self.run_single(faults.as_ref(), deadline)
        } else {
            self.run_parallel(threads, faults.as_ref(), deadline)
        };
        if tracing {
            // Deterministic merge: per-shard buffers were concatenated
            // in shard-index order; a *stable* sort by (start, pe)
            // reproduces the single-threaded emission order exactly —
            // equal-key records come from one PE, which is owned by one
            // shard and emits in nondecreasing start order.
            let mut records = std::mem::take(&mut self.trace_raw);
            records.sort_by_key(|r| (r.start(), r.pe()));
            self.trace = Some(Trace { records, epochs: std::mem::take(&mut self.epoch_raw) });
        }
        let metrics = match result {
            // The watchdog aborted mid-flight: name where the fabric's
            // backlog sits. The PEs are already reassembled (both
            // engines restore them before returning an error), so the
            // endpoint scan below sees the aborted run's real state.
            Err(SimError::Timeout(msg)) => {
                return Err(SimError::Timeout(format!("{msg}; {}", self.busiest_endpoints())))
            }
            other => other?,
        };
        self.finish(metrics)
    }

    /// Name the most loaded endpoints of the (reassembled) PE table —
    /// queued plus fabric-stalled words — for the watchdog's abort
    /// diagnostic. Cold: runs once, only on `SimError::Timeout`.
    fn busiest_endpoints(&self) -> String {
        let mut tops: Vec<(u64, i64, i64, u8)> = Vec::new();
        for pe in &self.pes {
            let cp = &self.plan.classes[pe.class];
            for (slot, ep) in pe.endpoints.iter().enumerate() {
                let load = ep.buf.occupancy() + ep.buf.stalled_words();
                if load > 0 {
                    tops.push((load, pe.x, pe.y, cp.slot_color[slot]));
                }
            }
        }
        if tops.is_empty() {
            return "no queued endpoint words".to_string();
        }
        tops.sort_by_key(|&(load, x, y, c)| (Reverse(load), x, y, c));
        tops.truncate(3);
        let parts: Vec<String> = tops
            .iter()
            .map(|&(load, x, y, c)| format!("PE ({x},{y}) color {c}: {load} words"))
            .collect();
        format!("busiest endpoints: {}", parts.join(", "))
    }

    /// Classic path: one shard spanning the whole fabric (identity
    /// index maps), one event queue, run to completion.
    fn run_single(
        &mut self,
        faults: Option<&FaultSet>,
        deadline: Option<std::time::Instant>,
    ) -> Result<Metrics, SimError> {
        let plan = Arc::clone(&self.plan);
        let cfg = self.cfg.clone();
        let events_total = AtomicU64::new(0); // unused: one shard checks exactly
        let ctx = Ctx {
            cfg: &cfg,
            plan: &plan,
            vec_enabled: self.vec_enabled,
            trace: self.tracing,
            maps: None,
            events_total: &events_total,
            faults,
            deadline,
        };
        let mut shard = ShardState::new(0, std::mem::take(&mut self.pes), cfg.link_slots());
        if let Some(fs) = faults {
            shard.fault_fired = vec![false; fs.n_specs];
        }
        shard.init_pes(&ctx);
        shard.run_until(&ctx, u64::MAX);
        shard.fold_flowctl();
        self.pes = shard.pes;
        self.vec_ops += shard.vec_ops;
        self.engine = EngineStats {
            shards: 1,
            epochs: 0,
            shard_events: vec![shard.metrics.events],
            barrier_wait_ns: 0,
        };
        self.trace_raw = shard.trace;
        if let Some((t, g, e)) = shard.error {
            self.err_site = Some((t, g));
            return Err(e);
        }
        Ok(shard.metrics)
    }

    /// Epoch-parallel path: conservative parallel discrete-event
    /// simulation over the plan's link-sharing islands.
    fn run_parallel(
        &mut self,
        threads: usize,
        faults: Option<&FaultSet>,
        deadline: Option<std::time::Instant>,
    ) -> Result<Metrics, SimError> {
        let plan = Arc::clone(&self.plan);
        let cfg = self.cfg.clone();
        // A halted PE or dead link removes arrivals but never creates
        // earlier ones, so the clean lookahead is already sound under
        // faults; the re-derivation can only widen the window (see
        // [`FaultSet::effective_lookahead`]).
        let lookahead = match faults {
            Some(fs) => fs.effective_lookahead(&plan, &cfg),
            None => plan.lookahead,
        };

        // --- runtime shards: islands folded onto a fixed count ---
        let n_shards = plan.n_islands.min(MAX_SHARDS);
        let mut maps = ShardMaps {
            shard_of: vec![0u32; plan.pes.len()],
            pe_loc: vec![0u32; plan.pes.len()],
            link_loc: vec![u32::MAX; cfg.link_slots()],
        };
        let mut pe_counts = vec![0u32; n_shards];
        for (g, &isl) in plan.island_of.iter().enumerate() {
            let s = isl as usize % n_shards;
            maps.shard_of[g] = s as u32;
            maps.pe_loc[g] = pe_counts[s];
            pe_counts[s] += 1;
        }
        // Every link is occupied only by flows of one island (the
        // union-find invariant), so each gets a dense slot in the
        // island's shard.
        let mut link_counts = vec![0u32; n_shards];
        for flow in &plan.flows {
            if flow.error.is_some() {
                continue;
            }
            let s = maps.shard_of[flow.src_pe as usize] as usize;
            for &(li, _) in &flow.links {
                if maps.link_loc[li as usize] == u32::MAX {
                    maps.link_loc[li as usize] = link_counts[s];
                    link_counts[s] += 1;
                }
            }
        }

        // Partition the PEs (global order preserved inside each shard,
        // matching the `pe_loc` assignment above).
        let mut shard_pes: Vec<Vec<Pe>> =
            pe_counts.iter().map(|&c| Vec::with_capacity(c as usize)).collect();
        for pe in std::mem::take(&mut self.pes) {
            shard_pes[maps.shard_of[pe.gix as usize] as usize].push(pe);
        }
        let shards: Vec<Mutex<ShardState>> = shard_pes
            .into_iter()
            .enumerate()
            .map(|(s, p)| {
                let mut sh = ShardState::new(s as u32, p, link_counts[s] as usize);
                if let Some(fs) = faults {
                    sh.fault_fired = vec![false; fs.n_specs];
                }
                Mutex::new(sh)
            })
            .collect();
        let events_total = AtomicU64::new(0);
        let tracing = self.tracing;
        let ctx = Ctx {
            cfg: &cfg,
            plan: &plan,
            vec_enabled: self.vec_enabled,
            trace: tracing,
            maps: Some(&maps),
            events_total: &events_total,
            faults,
            deadline,
        };
        for sh in &shards {
            lock_shard(sh).init_pes(&ctx);
        }

        // --- epoch loop: persistent scoped workers + a coordinator ---
        let workers = threads.min(n_shards).max(1);
        let barrier = Barrier::new(workers + 1);
        let epoch_end = AtomicU64::new(0);
        let stop = AtomicBool::new(false);
        let mut run_error: Option<(u64, u32, SimError)> = None;
        // Engine introspection. `epochs`/`barrier_wait` are always
        // collected (cheap); the per-epoch log only under tracing.
        // A pending `(window start, window end, merged msgs)` closes
        // into an EpochRecord at the *next* scan, when every shard's
        // post-epoch event counter is visible under its lock.
        let mut epochs: u64 = 0;
        let mut barrier_wait = std::time::Duration::ZERO;
        let mut epoch_log: Vec<EpochRecord> = Vec::new();
        let mut prev_events = vec![0u64; n_shards];
        let mut pending: Option<(u64, u64, u64)> = None;

        std::thread::scope(|scope| {
            for w in 0..workers {
                let (barrier, shards, epoch_end, stop, ctx) =
                    (&barrier, &shards, &epoch_end, &stop, &ctx);
                scope.spawn(move || loop {
                    barrier.wait();
                    if stop.load(Ordering::Acquire) {
                        break;
                    }
                    let end = epoch_end.load(Ordering::Acquire);
                    let mut si = w;
                    while si < shards.len() {
                        // A panicking handler must not strand the other
                        // threads at the barrier: convert it into a
                        // shard error the coordinator aborts on.
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            lock_shard(&shards[si]).run_until(ctx, end);
                        }));
                        if r.is_err() {
                            let mut sh = lock_shard(&shards[si]);
                            if sh.error.is_none() {
                                sh.error = Some((
                                    0,
                                    0,
                                    SimError::Program(
                                        "simulator worker thread panicked (engine bug)".into(),
                                    ),
                                ));
                            }
                        }
                        si += workers;
                    }
                    barrier.wait();
                });
            }
            // Coordinator. Workers park at the top barrier between
            // epochs, so every lock below is uncontended.
            loop {
                let mut next = u64::MAX;
                let mut err: Option<(u64, u32, SimError)> = None;
                let mut events_now: Vec<u64> = Vec::new();
                for sh in &shards {
                    let sh = lock_shard(sh);
                    if tracing {
                        events_now.push(sh.metrics.events);
                    }
                    if let Some(e) = &sh.error {
                        // Pick the globally earliest (time, PE) error,
                        // with real program errors strictly preferred
                        // over the budget and watchdog guards: *whether*
                        // a shard trips Runaway can depend on how the
                        // other shards' batched counter flushes
                        // interleave (and Timeout is wall-clock by
                        // nature), so neither must ever shadow a
                        // deterministic error from the event stream.
                        let key = |e: &(u64, u32, SimError)| {
                            (
                                matches!(e.2, SimError::Runaway(_) | SimError::Timeout(_)),
                                e.0,
                                e.1,
                            )
                        };
                        let earlier = match &err {
                            None => true,
                            Some(b) => key(e) < key(b),
                        };
                        if earlier {
                            err = Some(e.clone());
                        }
                    }
                    if let Some(&Reverse(ev)) = sh.events.peek() {
                        next = next.min(ev.time);
                    }
                }
                // Close the previous epoch's record before the exit
                // check so the final epoch is logged too.
                if let Some((start, end, merged)) = pending.take() {
                    epoch_log.push(EpochRecord {
                        start,
                        end,
                        merged,
                        shard_events: events_now
                            .iter()
                            .zip(&prev_events)
                            .map(|(&now, &prev)| now - prev)
                            .collect(),
                    });
                    prev_events.copy_from_slice(&events_now);
                }
                if err.is_some() || next == u64::MAX {
                    run_error = err;
                    stop.store(true, Ordering::Release);
                    barrier.wait(); // release workers into their break
                    break;
                }
                // Conservative window: every cross-shard arrival sent
                // while processing events in [next, end) lands at or
                // after `end` (send start ≥ event time; arrival = start
                // + depth + hop ≥ time + lookahead).
                let end = next.saturating_add(lookahead);
                epoch_end.store(end, Ordering::Release);
                epochs += 1;
                // The coordinator is blocked for the whole epoch step
                // — this interval is the serialized (straggler-bound)
                // epoch time the shard-balancing lever wants to shrink.
                let t0 = std::time::Instant::now();
                barrier.wait(); // workers step the epoch
                barrier.wait(); // workers parked again
                barrier_wait += t0.elapsed();
                // Deterministic merge: deliver every buffered arrival
                // ordered by (arrival time, send time, source PE,
                // source sequence) — a total order independent of
                // worker interleaving.
                let mut msgs: Vec<OutMsg> = vec![];
                for sh in &shards {
                    msgs.append(&mut lock_shard(sh).outbox);
                }
                msgs.sort_by_key(|m| (m.time, m.sched, m.src_pe, m.src_seq));
                let merged = msgs.len() as u64;
                for m in msgs {
                    debug_assert!(m.time >= end, "cross-shard arrival inside its own epoch");
                    let dst = maps.shard_of[m.dst as usize] as usize;
                    lock_shard(&shards[dst]).deliver(m);
                }
                if tracing {
                    pending = Some((next, end, merged));
                }
            }
        });

        // Reassemble the dense PE table and merge the counters.
        let mut metrics = Metrics::default();
        let mut slots: Vec<Option<Pe>> = Vec::with_capacity(plan.pes.len());
        slots.resize_with(plan.pes.len(), || None);
        let mut shard_events = Vec::with_capacity(n_shards);
        for sh in shards {
            let mut sh = sh.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner());
            sh.fold_flowctl();
            metrics.merge(&sh.metrics);
            self.vec_ops += sh.vec_ops;
            shard_events.push(sh.metrics.events);
            // Shard-index order: the precondition of the deterministic
            // (start, pe) merge sort in `run`.
            self.trace_raw.append(&mut sh.trace);
            for pe in sh.pes {
                let g = pe.gix as usize;
                slots[g] = Some(pe);
            }
        }
        self.engine = EngineStats {
            shards: n_shards,
            epochs,
            shard_events,
            barrier_wait_ns: barrier_wait.as_nanos() as u64,
        };
        self.epoch_raw = epoch_log;
        self.pes = slots.into_iter().map(|p| p.expect("every PE returns from its shard")).collect();
        if let Some((t, g, e)) = run_error {
            self.err_site = Some((t, g));
            return Err(e);
        }
        Ok(metrics)
    }

    /// Post-run epilogue shared by both engines: deadlock detection
    /// over the reassembled PE table (starved consumers and, with a
    /// finite buffer capacity, credit-exhausted endpoints), then the
    /// report.
    fn finish(&mut self, metrics: Metrics) -> Result<RunReport, SimError> {
        let plan = Arc::clone(&self.plan);
        let mut stuck = vec![];
        let mut buffer_stall = false;
        for pe in &self.pes {
            let cp = &plan.classes[pe.class];
            for (slot, ep) in pe.endpoints.iter().enumerate() {
                if let Some(c) = ep.consumers.front() {
                    stuck.push(format!(
                        "PE ({},{}) color {} waiting for {} more wavelets",
                        pe.x,
                        pe.y,
                        cp.slot_color[slot],
                        c.need - c.taken.len()
                    ));
                }
                let stalled = ep.buf.stalled_words();
                if stalled > 0 {
                    // Credits exhausted for good: the flow's tail is
                    // wedged in the fabric. Name the endpoint and how
                    // far upstream the stall reaches along its route.
                    buffer_stall = true;
                    let color = cp.slot_color[slot];
                    // Link stages upstream of this endpoint = the hop
                    // depth of its own delivery (not the multicast
                    // tree's total link count).
                    let reach = plan
                        .flows_into(pe.gix, slot as u8)
                        .flat_map(|f| {
                            f.dests
                                .iter()
                                .filter(|&&(d, s, _)| d == pe.gix && s == slot as u8)
                                .map(|&(_, _, depth)| depth)
                        })
                        .max()
                        .unwrap_or(0);
                    let slack = reach * self.cfg.link_buffer_words.unwrap_or(0);
                    let upstream = if stalled > slack {
                        " and back into the source on-ramp"
                    } else {
                        ""
                    };
                    stuck.push(format!(
                        "PE ({},{}) color {} endpoint full ({}/{} words): {} words stalled \
                         across {} link stage(s){}",
                        pe.x,
                        pe.y,
                        color,
                        ep.buf.occupancy(),
                        ep.buf.capacity().unwrap_or(u64::MAX),
                        stalled,
                        reach,
                        upstream,
                    ));
                }
            }
        }
        if !stuck.is_empty() {
            stuck.truncate(8);
            // Cross-reference the static dataflow checker. When the
            // compiler already ran the checker (Options::check) the
            // stored verdict is reused instead of re-running the full
            // analysis here — except for buffer deadlocks, where the
            // credit pass's finite-capacity verdict is the relevant
            // one (`spada check --buffers`), so it is always consulted.
            let verdict = match crate::analysis::is_statically_clean(&self.prog) {
                true if !buffer_stall => {
                    "static check passed at compile time: no static deadlock (dynamic-only)"
                        .to_string()
                }
                _ => {
                    let report =
                        crate::analysis::check_with_plan(&self.prog, &self.cfg, &self.plan);
                    let statics: Vec<String> = report
                        .errors()
                        .filter(|d| {
                            matches!(
                                d.kind,
                                crate::analysis::DiagKind::Deadlock
                                    | crate::analysis::DiagKind::Starvation
                                    | crate::analysis::DiagKind::BufferDeadlock
                            )
                        })
                        .take(2)
                        .map(|d| d.to_string())
                        .collect();
                    if statics.is_empty() {
                        if buffer_stall {
                            "static credit check found no certain wedge (dynamic-only; \
                             see `spada check --buffers`)"
                                .to_string()
                        } else {
                            "static check found no deadlock (dynamic-only)".to_string()
                        }
                    } else {
                        let cmd = if buffer_stall { "spada check --buffers" } else { "spada check" };
                        format!("confirmed by static analysis (`{cmd}`): {}", statics.join("; "))
                    }
                }
            };
            let fault_note = if metrics.faults_injected > 0 {
                format!("; {} fault effect(s) injected this run", metrics.faults_injected)
            } else {
                String::new()
            };
            return Err(SimError::Deadlock(format!(
                "{}; {}{}",
                stuck.join("; "),
                verdict,
                fault_note
            )));
        }

        let cycles = self.pes.iter().map(|p| p.last_activity).max().unwrap_or(0);
        let mut m = metrics;
        m.active_pes = self.pes.iter().filter(|p| p.ran_anything).count() as u64;
        m.busy_cycles = self.pes.iter().map(|p| p.busy_cycles).sum();
        Ok(RunReport {
            kernel: self.prog.name.clone(),
            cycles,
            metrics: m,
            width: self.cfg.width,
            height: self.cfg.height,
            colors_used: plan.colors_used,
            task_ids_used: self.prog.max_task_ids_used(),
            mem_bytes_used: self.prog.max_mem_used(),
        })
    }
}

impl ShardState {
    fn new(ix: u32, pes: Vec<Pe>, link_slots: usize) -> ShardState {
        ShardState {
            ix,
            pes,
            link_busy: vec![0u64; link_slots],
            payloads: Vec::new(),
            free_payloads: Vec::new(),
            events: BinaryHeap::with_capacity(1024),
            now: 0,
            seq: 0,
            metrics: Metrics::default(),
            vec_ops: 0,
            scratch_a: Vec::new(),
            scratch_b: Vec::new(),
            outbox: Vec::new(),
            error: None,
            trace: Vec::new(),
            fault_fired: Vec::new(),
        }
    }

    /// Initialize task states and entry activations for this shard's
    /// PEs (ascending global order, matching the classic seed order).
    fn init_pes(&mut self, ctx: &Ctx<'_>) {
        for lp in 0..self.pes.len() {
            let cp = &ctx.plan.classes[self.pes[lp].class];
            for (ti, t) in cp.tasks.iter().enumerate() {
                let st = &mut self.pes[lp].tasks[ti];
                st.active = t.initially_active || matches!(t.kind, PTaskKind::Data { .. });
                st.blocked = t.initially_blocked;
            }
            for &ti in &cp.entry {
                self.pes[lp].tasks[ti as usize].active = true;
            }
            for ti in 0..cp.tasks.len() {
                self.refresh_task_bit(ctx, lp, ti);
            }
            if !cp.entry.is_empty() {
                let g = self.pes[lp].gix;
                self.schedule(0, EventKind::PeReady(g));
            }
        }
    }

    /// Fold the per-endpoint flow-control counters into this shard's
    /// metrics — stall cycles by sum, peak queue depth by max — so the
    /// cross-shard [`Metrics::merge`] yields the global totals (each
    /// endpoint is owned by exactly one shard).
    fn fold_flowctl(&mut self) {
        for pe in &self.pes {
            for ep in &pe.endpoints {
                self.metrics.stall_cycles += ep.buf.stall_cycles();
                self.metrics.peak_queue_depth =
                    self.metrics.peak_queue_depth.max(ep.buf.peak());
            }
        }
    }

    fn schedule(&mut self, time: u64, kind: EventKind) {
        self.seq += 1;
        let time = time.max(self.now);
        self.events.push(Reverse(Event { time, sched: self.now, seq: self.seq, kind }));
    }

    /// Merge one cross-shard arrival (coordinator-side, at the epoch
    /// barrier). Allocates a pool slot in *this* shard's payload pool;
    /// the receiver-side sequence number is assigned here, in the
    /// coordinator's deterministic merge order.
    fn deliver(&mut self, m: OutMsg) {
        let entry = FlowPayload { words: Some(m.words), pending: 1 };
        let payload = match self.free_payloads.pop() {
            Some(ix) => {
                self.payloads[ix as usize] = entry;
                ix
            }
            None => {
                self.payloads.push(entry);
                (self.payloads.len() - 1) as u32
            }
        };
        self.seq += 1;
        self.events.push(Reverse(Event {
            time: m.time,
            sched: m.sched,
            seq: self.seq,
            kind: EventKind::FlowArrive {
                pe: m.dst,
                slot: m.slot,
                first_word: m.first_word,
                payload,
            },
        }));
    }

    /// Process every queued event with `time < end` (the event loop:
    /// pure dense-array arithmetic; every event variant is `Copy` and
    /// all routing/action state is preresolved). Errors freeze the
    /// shard; the driver surfaces the globally earliest one.
    fn run_until(&mut self, ctx: &Ctx<'_>, end: u64) {
        if self.error.is_some() {
            return;
        }
        // Watchdog: once at entry (epochs can be nearly empty) and
        // every EVENT_BATCH events below.
        if self.watchdog_fired(ctx, self.now) {
            return;
        }
        let single = ctx.maps.is_none();
        // Events processed this call but not yet flushed into the
        // global budget counter (parallel mode only).
        let mut unflushed = 0u64;
        while let Some(&Reverse(ev)) = self.events.peek() {
            if ev.time >= end {
                break;
            }
            self.events.pop();
            self.metrics.events += 1;
            let gpe = match ev.kind {
                EventKind::PeReady(pe)
                | EventKind::FlowArrive { pe, .. }
                | EventKind::Complete { pe, .. } => pe,
            };
            if single {
                // Exact classic semantics: error on event max_events+1.
                if self.metrics.events > ctx.cfg.max_events {
                    self.error = Some((ev.time, gpe, SimError::Runaway(ctx.cfg.max_events)));
                    return;
                }
            } else {
                unflushed += 1;
                if unflushed >= EVENT_BATCH {
                    let total =
                        ctx.events_total.fetch_add(unflushed, Ordering::Relaxed) + unflushed;
                    unflushed = 0;
                    if total > ctx.cfg.max_events {
                        self.error = Some((ev.time, gpe, SimError::Runaway(ctx.cfg.max_events)));
                        return;
                    }
                }
            }
            if ctx.deadline.is_some()
                && self.metrics.events & (EVENT_BATCH - 1) == 0
                && self.watchdog_fired(ctx, ev.time)
            {
                return;
            }
            self.now = ev.time;
            // A halted PE processes nothing from its halt cycle on: its
            // wakeups and microthread completions are dropped here
            // (counted once per halt); arriving flows still buffer (see
            // `flow_arrive`) so upstream credit accounting stays
            // physical.
            if let Some(fs) = ctx.faults {
                if let Some((si, at)) = fs.halt_of(gpe) {
                    if ev.time >= at && !matches!(ev.kind, EventKind::FlowArrive { .. }) {
                        self.note_halt(ctx, gpe, si, at);
                        continue;
                    }
                }
            }
            let res = match ev.kind {
                EventKind::PeReady(pe) => self.pe_ready(ctx, ctx.loc(pe)),
                EventKind::FlowArrive { pe, slot, first_word, payload } => {
                    self.flow_arrive(ctx, ctx.loc(pe), slot, first_word, payload)
                }
                EventKind::Complete { pe, actions } => {
                    self.apply_actions_id(ctx, ctx.loc(pe), actions);
                    self.schedule(self.now, EventKind::PeReady(pe));
                    Ok(())
                }
            };
            if let Err(e) = res {
                self.error = Some((ev.time, gpe, e));
                return;
            }
        }
        if !single && unflushed > 0 {
            // Flush the tail so a terminating run whose global total
            // exceeds the budget still errors, exactly as one thread
            // would have.
            let total = ctx.events_total.fetch_add(unflushed, Ordering::Relaxed) + unflushed;
            if total > ctx.cfg.max_events && self.error.is_none() {
                let gpe = self.pes.first().map(|p| p.gix).unwrap_or(0);
                self.error = Some((self.now, gpe, SimError::Runaway(ctx.cfg.max_events)));
            }
        }
    }

    /// Check the wall-clock watchdog; on expiry freeze this shard with
    /// a [`SimError::Timeout`] sited at `(t, first owned PE)` and
    /// return true. Abort-only: simulated time is never touched, and
    /// the coordinator's error pick deprioritizes Timeout exactly like
    /// Runaway (which shard notices first is wall-clock racy).
    fn watchdog_fired(&mut self, ctx: &Ctx<'_>, t: u64) -> bool {
        let Some(dl) = ctx.deadline else { return false };
        if std::time::Instant::now() < dl {
            return false;
        }
        let gpe = self.pes.first().map(|p| p.gix).unwrap_or(0);
        self.error = Some((
            t,
            gpe,
            SimError::Timeout(format!(
                "wall-clock watchdog ({} ms) fired; last progress at cycle {}",
                ctx.cfg.timeout_ms.unwrap_or(0),
                self.now
            )),
        ));
        true
    }

    /// Record a halted-PE fault application — the metric increment and
    /// trace record fire once per halt spec, on the first event the
    /// halt actually swallows.
    fn note_halt(&mut self, ctx: &Ctx<'_>, gpe: u32, si: usize, at: u64) {
        if !self.fault_fired[si] {
            self.fault_fired[si] = true;
            self.metrics.faults_injected += 1;
            if ctx.trace {
                self.trace.push(TraceRecord::Fault { pe: gpe, kind: FK_PE_HALT, start: at });
            }
        }
    }

    // ------------------------------------------------------------------
    // Task scheduling
    // ------------------------------------------------------------------

    fn pe_ready(&mut self, ctx: &Ctx<'_>, pe_idx: usize) -> Result<(), SimError> {
        let gpe = self.pes[pe_idx].gix;
        if self.pes[pe_idx].busy_until > self.now {
            let t = self.pes[pe_idx].busy_until;
            self.schedule(t, EventKind::PeReady(gpe));
            return Ok(());
        }
        let cp = &ctx.plan.classes[self.pes[pe_idx].class];

        // Pick the lowest-hardware-ID runnable task by walking the set
        // bits of the ready mask in rank order: quiescent tasks are
        // never re-inspected. Local bits are exact; data bits still
        // need the (time-dependent) head-word availability check.
        let mut chosen: Option<usize> = None;
        let mut next_wakeup: Option<u64> = None;
        let mut mask = self.pes[pe_idx].ready;
        while mask != 0 {
            let rank = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let ti = cp.order[rank] as usize;
            match cp.tasks[ti].kind {
                PTaskKind::Local => {
                    chosen = Some(ti);
                    break;
                }
                PTaskKind::Data { slot, .. } => {
                    // `next_word_time` is `None` both for an empty
                    // endpoint and for one whose head words are all
                    // stalled tails — the admission that makes them
                    // available is itself a consumption event on this
                    // endpoint, which reschedules, so no wakeup is
                    // needed (or possible) here.
                    if let Some(t0) =
                        self.pes[pe_idx].endpoints[slot as usize].buf.next_word_time()
                    {
                        if t0 <= self.now {
                            chosen = Some(ti);
                            break;
                        } else {
                            next_wakeup = Some(next_wakeup.map_or(t0, |w: u64| w.min(t0)));
                        }
                    }
                }
            }
        }
        let Some(ti) = chosen else {
            if let Some(t) = next_wakeup {
                self.schedule(t, EventKind::PeReady(gpe));
            }
            return Ok(());
        };
        self.metrics.task_runs += 1;
        self.pes[pe_idx].ran_anything = true;

        let start = self.now.max(self.pes[pe_idx].busy_until);
        let mut clock = start + ctx.cfg.task_wakeup_cycles;

        match cp.tasks[ti].kind {
            PTaskKind::Local => {
                self.pes[pe_idx].tasks[ti].active = false;
                self.refresh_task_bit(ctx, pe_idx, ti);
                self.exec_ops(ctx, pe_idx, &cp.tasks[ti].body, &mut clock)?;
            }
            PTaskKind::Data { slot, wavelet_reg } => {
                // Consume available wavelets one at a time (hardware fires
                // the task per wavelet; we batch into one scheduling event).
                // Each popped word returns a credit, so a stalled tail
                // trickles into the endpoint at the consumption rate.
                loop {
                    let word =
                        self.pes[pe_idx].endpoints[slot as usize].buf.pop_word(clock);
                    let Some(w) = word else { break };
                    self.pes[pe_idx].regs[wavelet_reg as usize] =
                        SVal::F(f32::from_bits(w) as f64);
                    clock += ctx.cfg.data_task_wavelet_cycles;
                    self.exec_ops(ctx, pe_idx, &cp.tasks[ti].body, &mut clock)?;
                    if self.pes[pe_idx].tasks[ti].blocked {
                        break; // body blocked its own task
                    }
                }
                // If more words are in flight, wake up again.
                if let Some(t0) = self.pes[pe_idx].endpoints[slot as usize].buf.next_word_time()
                {
                    self.schedule(t0.max(clock), EventKind::PeReady(gpe));
                }
                self.refresh_task_bit(ctx, pe_idx, ti);
                if ctx.trace {
                    self.drain_stall_log(ctx, pe_idx, slot);
                }
            }
        }

        if ctx.trace {
            // The span covers exactly the cycles this activation adds
            // to `pe.busy_cycles` below, so profile busy totals
            // reconcile with `Metrics::busy_cycles` to the cycle.
            self.trace.push(TraceRecord::Task { pe: gpe, task: ti as u16, start, end: clock });
        }
        let pe = &mut self.pes[pe_idx];
        pe.busy_cycles += clock - start;
        pe.busy_until = clock;
        pe.last_activity = pe.last_activity.max(clock);
        self.schedule(clock, EventKind::PeReady(gpe));
        Ok(())
    }

    /// Drain the endpoint buffer's logged stall intervals into trace
    /// records. Cold: called only when tracing is on, right after the
    /// consumption/arrival that triggered admissions.
    #[cold]
    fn drain_stall_log(&mut self, ctx: &Ctx<'_>, pe_idx: usize, slot: u8) {
        let gpe = self.pes[pe_idx].gix;
        let color = ctx.plan.classes[self.pes[pe_idx].class].slot_color[slot as usize];
        for (natural, admitted, words) in
            self.pes[pe_idx].endpoints[slot as usize].buf.take_stalls()
        {
            self.trace.push(TraceRecord::Stall {
                pe: gpe,
                color,
                start: natural,
                end: admitted,
                words,
            });
        }
    }

    /// Recompute one task's ready-mask bit from its actual state. Every
    /// state transition that can change runnability funnels through
    /// here, so the bit is always consistent with the predicate.
    fn refresh_task_bit(&mut self, ctx: &Ctx<'_>, pe_idx: usize, ti: usize) {
        let cp = &ctx.plan.classes[self.pes[pe_idx].class];
        let runnable = {
            let pe = &self.pes[pe_idx];
            let st = &pe.tasks[ti];
            match cp.tasks[ti].kind {
                PTaskKind::Local => st.active && !st.blocked,
                PTaskKind::Data { slot, .. } => {
                    let ep = &pe.endpoints[slot as usize];
                    !st.blocked && ep.consumers.is_empty() && ep.buf.queued()
                }
            }
        };
        let bit = 1u32 << cp.rank_of[ti];
        let pe = &mut self.pes[pe_idx];
        if runnable {
            pe.ready |= bit;
        } else {
            pe.ready &= !bit;
        }
    }

    /// Refresh the ready bit of the data task bound to an endpoint slot
    /// (if any) after the endpoint's queues changed.
    fn refresh_data_bit(&mut self, ctx: &Ctx<'_>, pe_idx: usize, slot: u8) {
        let ti = ctx.plan.classes[self.pes[pe_idx].class].data_task_of_slot[slot as usize];
        if ti != TASK_NONE {
            self.refresh_task_bit(ctx, pe_idx, ti as usize);
        }
    }

    /// Apply an interned completion-action list.
    fn apply_actions_id(&mut self, ctx: &Ctx<'_>, pe_idx: usize, actions: u32) {
        if actions == ACTIONS_EMPTY {
            return;
        }
        for a in &ctx.plan.actions[actions as usize] {
            self.apply_paction(ctx, pe_idx, a);
        }
    }

    fn apply_paction(&mut self, ctx: &Ctx<'_>, pe_idx: usize, a: &PAction) {
        if let Some((reg, val)) = a.set_reg {
            self.pes[pe_idx].regs[reg as usize] = SVal::I(val);
            self.metrics.dispatches += 1;
        }
        if a.task_ix != TASK_NONE {
            let ti = a.task_ix as usize;
            let st = &mut self.pes[pe_idx].tasks[ti];
            match a.kind {
                TaskActionKind::Activate => st.active = true,
                TaskActionKind::Unblock => st.blocked = false,
                TaskActionKind::Block => st.blocked = true,
            }
            self.refresh_task_bit(ctx, pe_idx, ti);
        }
    }

    // ------------------------------------------------------------------
    // Fabric
    // ------------------------------------------------------------------

    fn flow_arrive(
        &mut self,
        ctx: &Ctx<'_>,
        pe_idx: usize,
        slot: u8,
        first_word: u64,
        payload: u32,
    ) -> Result<(), SimError> {
        let words = {
            let p = &mut self.payloads[payload as usize];
            let words = Arc::clone(p.words.as_ref().expect("payload already released"));
            p.pending -= 1;
            if p.pending == 0 {
                // Last arrival: the endpoints own the data now; the pool
                // slot is free for the next flow.
                p.words = None;
                self.free_payloads.push(payload);
            }
            words
        };
        self.metrics.ramp_bytes += 4 * words.len() as u64;
        // Credit-managed admission: with a finite capacity the flow may
        // stall part of its payload in the fabric; with none this is
        // exactly the historical enqueue (see `machine::flowctl`).
        self.pes[pe_idx].endpoints[slot as usize].buf.push_flow(first_word, words);
        let gpe = self.pes[pe_idx].gix;
        if let Some(fs) = ctx.faults {
            if let Some((si, at)) = fs.halt_of(gpe) {
                if self.now >= at {
                    // Halted consumer: the words buffer (and stall
                    // their tails, backpressuring upstream) but are
                    // never consumed, and no task dispatch fires.
                    self.note_halt(ctx, gpe, si, at);
                    return Ok(());
                }
            }
        }
        self.try_satisfy(ctx, pe_idx, slot)?;
        if ctx.trace {
            self.drain_stall_log(ctx, pe_idx, slot);
        }
        // A data task may be waiting for this color.
        self.schedule(first_word.max(self.now), EventKind::PeReady(gpe));
        Ok(())
    }

    /// Inject a flow from local PE `src_pe` on `color` with payload
    /// `words`, not before `earliest`. Returns (start_time, drain_end).
    /// The route (links, destinations, endpoint slots) was precompiled
    /// at construction; route errors stored in the plan surface here,
    /// on first use, exactly as the lazily-traced simulator did.
    ///
    /// The start time is clamped to the current event time: a flow
    /// never enters the fabric before the event that sends it. (The
    /// pre-parallel simulator allowed a retroactive start in one corner
    /// — a consume assembled from several flows whose earlier words
    /// were queued long before the last arrival — which would let an
    /// arrival land inside the sending epoch. The clamp also gives the
    /// plan's cross-island lookahead its hard guarantee.)
    fn send_flow(
        &mut self,
        ctx: &Ctx<'_>,
        src_pe: usize,
        color: u8,
        words: Arc<Vec<u32>>,
        earliest: u64,
    ) -> Result<(u64, u64), SimError> {
        let n = words.len() as u64;
        if n == 0 {
            return Ok((earliest, earliest));
        }
        let src = &self.pes[src_pe];
        let (sx, sy, src_g) = (src.x, src.y, src.gix);
        let Some(fi) = ctx.plan.flow_index(src_g as usize, color) else {
            return Err(SimError::Program(format!(
                "flow on color {color} from ({sx},{sy}) has no precompiled route"
            )));
        };
        let flow = &ctx.plan.flows[fi];
        if let Some(err) = &flow.error {
            return Err(match err {
                FlowError::Route(e) => SimError::Route(e.clone()),
                FlowError::NoDest => SimError::Program(format!(
                    "flow on color {color} from ({sx},{sy}) has no destinations"
                )),
                FlowError::NoCode { x, y } => SimError::Program(format!(
                    "flow on color {color} delivered to PE ({x},{y}) with no code"
                )),
            });
        }
        // Wormhole start: every link l must be free at start + depth(l).
        let mut start = earliest.max(self.now);
        for &(li, depth) in &flow.links {
            let busy = self.link_busy[ctx.link(li)];
            start = start.max(busy.saturating_sub(depth));
        }
        for &(li, depth) in &flow.links {
            self.link_busy[ctx.link(li)] = start + depth + n;
        }
        self.metrics.flows += 1;
        self.metrics.wavelets += n;
        self.metrics.wavelet_hops += n * flow.links.len() as u64;
        self.metrics.ramp_bytes += 4 * n; // source on-ramp
        if ctx.trace {
            self.trace.push(TraceRecord::Flow {
                pe: src_g,
                color,
                flow: fi as u32,
                start,
                words: n as u32,
            });
        }

        // Fault effects (see `machine::fault`): dropped and delayed
        // deliveries, seeded payload corruption. Everything is keyed
        // off `start` and per-flow compiled state, both identical
        // across thread counts, so faulted runs stay bit-identical.
        // Link occupancy above is deliberately untouched — a dead link
        // still holds its upstream path; only deliveries change.
        let mut words = words;
        let mut dropped: Option<Vec<bool>> = None;
        let mut extra_of: Option<Vec<u64>> = None;
        if let Some(fx) = ctx.faults.and_then(|fs| fs.fx_of(fi)) {
            for (thr, mask) in &fx.kills {
                if start >= *thr && mask.iter().any(|&m| m) {
                    let d = dropped.get_or_insert_with(|| vec![false; flow.dests.len()]);
                    for (j, &m) in mask.iter().enumerate() {
                        if m {
                            d[j] = true;
                        }
                    }
                    self.metrics.faults_injected += 1;
                    if ctx.trace {
                        self.trace.push(TraceRecord::Fault {
                            pe: src_g,
                            kind: FK_LINK_KILL,
                            start,
                        });
                    }
                }
            }
            for (thr, extra, mask) in &fx.slows {
                if start >= *thr && mask.iter().any(|&m| m) {
                    let e = extra_of.get_or_insert_with(|| vec![0u64; flow.dests.len()]);
                    for (j, &m) in mask.iter().enumerate() {
                        if m {
                            e[j] = e[j].saturating_add(*extra);
                        }
                    }
                    self.metrics.faults_injected += 1;
                    if ctx.trace {
                        self.trace.push(TraceRecord::Fault {
                            pe: src_g,
                            kind: FK_LINK_SLOW,
                            start,
                        });
                    }
                }
            }
            if let Some((at, extra)) = fx.delay {
                if start >= at {
                    let e = extra_of.get_or_insert_with(|| vec![0u64; flow.dests.len()]);
                    for v in e.iter_mut() {
                        *v = v.saturating_add(extra);
                    }
                    self.metrics.faults_injected += 1;
                    if ctx.trace {
                        self.trace.push(TraceRecord::Fault { pe: src_g, kind: FK_DELAY, start });
                    }
                }
            }
            if let Some((at, si)) = fx.corrupt {
                if start >= at && !self.fault_fired[si as usize] {
                    self.fault_fired[si as usize] = true;
                    let mut w = (*words).clone();
                    ctx.faults.expect("fx implies faults").corrupt_words(fi, &mut w);
                    words = Arc::new(w);
                    self.metrics.faults_injected += 1;
                    if ctx.trace {
                        self.trace.push(TraceRecord::Fault { pe: src_g, kind: FK_CORRUPT, start });
                    }
                }
            }
        }
        let is_dropped = |j: usize| dropped.as_ref().is_some_and(|d| d[j]);

        // In-shard destinations share one pool entry; every cross-shard
        // destination ships its own message through the epoch barrier.
        // Dropped deliveries count in neither: their `FlowArrive` never
        // exists, so the payload's pending count must not include them.
        let local = flow
            .dests
            .iter()
            .enumerate()
            .filter(|&(j, &(d, _, _))| !is_dropped(j) && ctx.shard_of(d) == self.ix)
            .count();
        let payload = if local > 0 {
            let entry = FlowPayload { words: Some(Arc::clone(&words)), pending: local as u32 };
            match self.free_payloads.pop() {
                Some(ix) => {
                    self.payloads[ix as usize] = entry;
                    ix
                }
                None => {
                    self.payloads.push(entry);
                    (self.payloads.len() - 1) as u32
                }
            }
        } else {
            0 // never read: no local FlowArrive references it
        };
        for (j, &(dst, slot, depth)) in flow.dests.iter().enumerate() {
            if is_dropped(j) {
                continue;
            }
            let extra = extra_of.as_ref().map_or(0, |e| e[j]);
            let first = start + depth + ctx.cfg.hop_cycles + extra;
            if ctx.shard_of(dst) == self.ix {
                self.schedule(
                    first.max(self.now),
                    EventKind::FlowArrive { pe: dst, slot, first_word: first, payload },
                );
            } else {
                self.seq += 1;
                self.outbox.push(OutMsg {
                    time: first.max(self.now),
                    sched: self.now,
                    first_word: first,
                    dst,
                    slot,
                    words: Arc::clone(&words),
                    src_pe: src_g,
                    src_seq: self.seq,
                });
            }
        }
        Ok((start, start + n))
    }

    /// Try to satisfy the head consumer(s) on a (PE, slot) endpoint.
    fn try_satisfy(&mut self, ctx: &Ctx<'_>, pe_idx: usize, slot: u8) -> Result<(), SimError> {
        let now = self.now;
        loop {
            let popped = {
                let ep = &mut self.pes[pe_idx].endpoints[slot as usize];
                let Some(head) = ep.consumers.front_mut() else { break };
                // Pull available words into the head consumer. Each
                // pulled word returns a credit (no earlier than this
                // event), so a stalled tail streams in behind the pull
                // and the take loop drains it in the same pass.
                let need = head.need - head.taken.len();
                if need > 0 {
                    if let Some(t) = ep.buf.take(need, now, &mut head.taken) {
                        head.last_avail = head.last_avail.max(t);
                    }
                }
                if head.taken.len() < head.need {
                    break; // wait for more flows
                }
                ep.consumers.pop_front().unwrap()
            };
            self.complete_consume(ctx, pe_idx, popped)?;
        }
        self.refresh_data_bit(ctx, pe_idx, slot);
        Ok(())
    }

    /// Apply a completed fabric-in consumption: compute the op, write the
    /// destination (memory or a forwarded out-flow), schedule completion.
    /// The operation is read from the plan's consume-template table.
    fn complete_consume(
        &mut self,
        ctx: &Ctx<'_>,
        pe_idx: usize,
        c: PendingConsume,
    ) -> Result<(), SimError> {
        let tmpl = &ctx.plan.classes[self.pes[pe_idx].class].consumes[c.consume_ix as usize];
        let words = c.taken;
        let n = words.len();
        let ty = tmpl
            .src0
            .as_ref()
            .or(tmpl.src1.as_ref())
            .map(|r| r.ty())
            .unwrap_or(Dtype::F32);
        // Processing cannot beat the ALU (1 elem/cycle f32) nor the data.
        let elem_cycles = self.elem_cycles(ctx, ty, n as u64);
        let proc_done = (c.issue_time + elem_cycles).max(c.last_avail + 1);

        // Gather the in-stream values.
        let in_vals: Vec<f64> = words.iter().map(|w| f32::from_bits(*w) as f64).collect();
        let scalar = tmpl
            .scalar
            .as_ref()
            .map(|e| self.eval(pe_idx, e).as_f())
            .unwrap_or(1.0);

        let a = match &tmpl.src0 {
            Some(DsdRef::FabIn { .. }) => VOp::Vals(&in_vals),
            Some(r @ DsdRef::Mem { .. }) => VOp::Mem(r),
            _ => VOp::Nothing,
        };
        let b = match &tmpl.src1 {
            Some(DsdRef::FabIn { .. }) => VOp::Vals(&in_vals),
            Some(r @ DsdRef::Mem { .. }) => VOp::Mem(r),
            _ => VOp::Nothing,
        };
        let v0 = self.vec_ops;
        let out = self.apply_dsd(ctx, pe_idx, tmpl.kind, &tmpl.dst, a, b, scalar, n, tmpl.vec)?;

        if let Some(out_words) = out {
            let out_color = match &tmpl.dst {
                DsdRef::FabOut { color, .. } => *color,
                _ => unreachable!(),
            };
            // Streaming forward: out word i departs one cycle after in
            // word i is processed → out flow starts right behind the
            // in flow.
            let earliest = (c.issue_time + 1).max(proc_done.saturating_sub(n as u64) + 1);
            self.send_flow(ctx, pe_idx, out_color, Arc::new(out_words), earliest)?;
        }

        if tmpl.actions != ACTIONS_EMPTY {
            let gpe = self.pes[pe_idx].gix;
            self.schedule(proc_done, EventKind::Complete { pe: gpe, actions: tmpl.actions });
        }
        if ctx.trace {
            let gpe = self.pes[pe_idx].gix;
            self.trace.push(TraceRecord::Dsd {
                pe: gpe,
                kind: tmpl.kind,
                n: n as u32,
                vectorized: self.vec_ops > v0,
                start: c.issue_time,
                end: proc_done,
            });
        }
        let pe = &mut self.pes[pe_idx];
        pe.last_activity = pe.last_activity.max(proc_done);
        Ok(())
    }

    // ------------------------------------------------------------------
    // Interpreter
    // ------------------------------------------------------------------

    fn elem_cycles(&self, ctx: &Ctx<'_>, ty: Dtype, n: u64) -> u64 {
        if ty.is_16bit() {
            n.div_ceil(ctx.cfg.simd16_width)
        } else {
            n
        }
    }

    fn eval(&self, pe_idx: usize, e: &SExpr) -> SVal {
        let pe = &self.pes[pe_idx];
        match e {
            SExpr::ImmI(v) => SVal::I(*v),
            SExpr::ImmF(v) => SVal::F(*v),
            SExpr::CoordX => SVal::I(pe.x),
            SExpr::CoordY => SVal::I(pe.y),
            SExpr::Reg(r) => pe.regs[*r as usize],
            SExpr::LoadMem { addr, ty } => {
                let a = self.eval(pe_idx, addr).as_i() as usize;
                self.load_scalar(pe_idx, a, *ty)
            }
            SExpr::Neg(a) => match self.eval(pe_idx, a) {
                SVal::I(v) => SVal::I(-v),
                SVal::F(v) => SVal::F(-v),
            },
            SExpr::Not(a) => SVal::I(!self.eval(pe_idx, a).truthy() as i64),
            SExpr::Select(c, a, b) => {
                if self.eval(pe_idx, c).truthy() {
                    self.eval(pe_idx, a)
                } else {
                    self.eval(pe_idx, b)
                }
            }
            SExpr::Bin(op, a, b) => {
                let va = self.eval(pe_idx, a);
                let vb = self.eval(pe_idx, b);
                let float = matches!(va, SVal::F(_)) || matches!(vb, SVal::F(_));
                use SBinOp::*;
                if float {
                    let (x, y) = (va.as_f(), vb.as_f());
                    match op {
                        Add => SVal::F(x + y),
                        Sub => SVal::F(x - y),
                        Mul => SVal::F(x * y),
                        Div => SVal::F(x / y),
                        Mod => SVal::F(x % y),
                        Min => SVal::F(x.min(y)),
                        Max => SVal::F(x.max(y)),
                        Eq => SVal::I((x == y) as i64),
                        Ne => SVal::I((x != y) as i64),
                        Lt => SVal::I((x < y) as i64),
                        Le => SVal::I((x <= y) as i64),
                        Gt => SVal::I((x > y) as i64),
                        Ge => SVal::I((x >= y) as i64),
                        And => SVal::I((x != 0.0 && y != 0.0) as i64),
                        Or => SVal::I((x != 0.0 || y != 0.0) as i64),
                    }
                } else {
                    let (x, y) = (va.as_i(), vb.as_i());
                    match op {
                        Add => SVal::I(x + y),
                        Sub => SVal::I(x - y),
                        Mul => SVal::I(x * y),
                        Div => SVal::I(if y != 0 { x / y } else { 0 }),
                        Mod => SVal::I(if y != 0 { x.rem_euclid(y) } else { 0 }),
                        Min => SVal::I(x.min(y)),
                        Max => SVal::I(x.max(y)),
                        Eq => SVal::I((x == y) as i64),
                        Ne => SVal::I((x != y) as i64),
                        Lt => SVal::I((x < y) as i64),
                        Le => SVal::I((x <= y) as i64),
                        Gt => SVal::I((x > y) as i64),
                        Ge => SVal::I((x >= y) as i64),
                        And => SVal::I((x != 0 && y != 0) as i64),
                        Or => SVal::I((x != 0 || y != 0) as i64),
                    }
                }
            }
        }
    }

    fn load_scalar(&self, pe_idx: usize, addr: usize, ty: Dtype) -> SVal {
        let mem = &self.pes[pe_idx].mem;
        match ty {
            Dtype::F32 => SVal::F(f32::from_bits(u32::from_le_bytes(
                mem[addr..addr + 4].try_into().unwrap(),
            )) as f64),
            Dtype::I32 | Dtype::U32 => {
                SVal::I(i32::from_le_bytes(mem[addr..addr + 4].try_into().unwrap()) as i64)
            }
            Dtype::F16 => {
                let bits = u16::from_le_bytes(mem[addr..addr + 2].try_into().unwrap());
                SVal::F(f16_to_f64(bits))
            }
            Dtype::I16 => {
                SVal::I(i16::from_le_bytes(mem[addr..addr + 2].try_into().unwrap()) as i64)
            }
            Dtype::U16 => {
                SVal::I(u16::from_le_bytes(mem[addr..addr + 2].try_into().unwrap()) as i64)
            }
        }
    }

    fn store_scalar(&mut self, pe_idx: usize, addr: usize, ty: Dtype, v: SVal) {
        let mem = &mut self.pes[pe_idx].mem;
        match ty {
            Dtype::F32 => {
                mem[addr..addr + 4].copy_from_slice(&(v.as_f() as f32).to_bits().to_le_bytes())
            }
            Dtype::I32 | Dtype::U32 => {
                mem[addr..addr + 4].copy_from_slice(&(v.as_i() as i32).to_le_bytes())
            }
            Dtype::F16 => {
                mem[addr..addr + 2].copy_from_slice(&f64_to_f16(v.as_f()).to_le_bytes())
            }
            Dtype::I16 | Dtype::U16 => {
                mem[addr..addr + 2].copy_from_slice(&(v.as_i() as i16).to_le_bytes())
            }
        }
    }

    /// Apply a DSD op. Statically eligible operations ([`VecOp::Map`] /
    /// [`VecOp::Fold`], see [`crate::machine::vecop`]) that also pass
    /// the runtime admission check (resolved operands in bounds and
    /// non-overlapping) execute as one slice pass per operation;
    /// everything else falls back to the lazy per-element loop, whose
    /// reads (per element, from current memory) define the reference
    /// semantics for aliased / strided descriptors (e.g. a stride-0
    /// destination accumulates — the idiom for scalar reductions).
    /// Both paths are bit-identical in destination memory, emitted
    /// fabric words, and metrics.
    /// Returns `Some(words)` if the destination is a fabric output.
    #[allow(clippy::too_many_arguments)]
    fn apply_dsd(
        &mut self,
        ctx: &Ctx<'_>,
        pe_idx: usize,
        kind: DsdKind,
        dst: &DsdRef,
        a: VOp<'_>,
        b: VOp<'_>,
        scalar: f64,
        n: usize,
        vec: VecOp,
    ) -> Result<Option<Vec<u32>>, SimError> {
        let mut out: Option<Vec<u32>> = match dst {
            DsdRef::FabOut { .. } => Some(Vec::with_capacity(n)),
            DsdRef::Mem { .. } => None,
            DsdRef::FabIn { .. } => {
                return Err(SimError::Program("DSD destination cannot be FabIn".into()))
            }
        };
        // Hot path: resolve descriptors to (base, stride) once, so the
        // per-element loop is pure pointer arithmetic.
        let ra = self.resolve_vop(pe_idx, &a);
        let rb = self.resolve_vop(pe_idx, &b);
        let rdst = match dst {
            DsdRef::Mem { .. } => Some(self.resolve_mem(pe_idx, dst)),
            _ => None,
        };
        let vectorized = ctx.vec_enabled
            && vec != VecOp::None
            && n > 0
            && self.apply_vec(pe_idx, kind, vec, &rdst, &mut out, &ra, &rb, scalar, n);
        if vectorized {
            self.vec_ops += 1;
        } else {
            for i in 0..n {
                let av = self.rv_val(pe_idx, &ra, i);
                let bv = self.rv_val(pe_idx, &rb, i);
                let r = match kind {
                    DsdKind::Fadd => av + bv,
                    DsdKind::Fsub => av - bv,
                    DsdKind::Fmul => av * bv,
                    DsdKind::Fmac => av + bv * scalar,
                    DsdKind::Fscale => av * scalar,
                    DsdKind::Mov => av,
                    DsdKind::Fill => scalar,
                    DsdKind::FmaxOp => av.max(bv),
                };
                match (&mut out, &rdst) {
                    (Some(words), _) => words.push((r as f32).to_bits()),
                    (None, Some(d)) => {
                        let addr = (d.base as isize + i as isize * d.stride) as usize;
                        if d.ty == Dtype::F32 {
                            self.pes[pe_idx].mem[addr..addr + 4]
                                .copy_from_slice(&(r as f32).to_le_bytes());
                        } else {
                            self.store_scalar(pe_idx, addr, d.ty, SVal::F(r));
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
        self.metrics.flops += kind.flops_per_elem() * n as u64;
        self.metrics.mem_bytes += (n * dst.ty().size()) as u64;
        self.metrics.dsd_ops += 1;
        Ok(out)
    }

    /// Try to execute an eligible DSD op as one slice pass. Returns
    /// `false` (without touching any state) when the resolved operands
    /// fail runtime admission — the caller then runs the interpreter.
    #[allow(clippy::too_many_arguments)]
    fn apply_vec(
        &mut self,
        pe_idx: usize,
        kind: DsdKind,
        vec: VecOp,
        rdst: &Option<RMem>,
        out: &mut Option<Vec<u32>>,
        ra: &RVOp<'_>,
        rb: &RVOp<'_>,
        scalar: f64,
        n: usize,
    ) -> bool {
        let mem_len = self.pes[pe_idx].mem.len();
        let span = |r: &RMem| Span { base: r.base, stride: r.stride };
        // Memory sources must match the kernel's dtype to enter the
        // slice passes; the static hint guarantees this, but
        // re-checking is cheap and keeps admission self-contained.
        let src_span = |o: &RVOp<'_>, want: Dtype| -> Result<Option<Span>, ()> {
            match o {
                RVOp::Mem(r) if r.ty != want => Err(()),
                RVOp::Mem(r) => Ok(Some(span(r))),
                _ => Ok(None),
            }
        };
        match vec {
            VecOp::Map => {
                let (fa, fb) = (src_span(ra, Dtype::F32), src_span(rb, Dtype::F32));
                let (Ok(sa), Ok(sb)) = (fa, fb) else { return false };
                let sd = match rdst {
                    Some(d) if d.ty != Dtype::F32 => return false,
                    Some(d) => Some(span(d)),
                    None => None,
                };
                if !vecop::admit_map(mem_len, sd, &[sa, sb], n, ELEM) {
                    return false;
                }
                let mut va = std::mem::take(&mut self.scratch_a);
                let mut vb = std::mem::take(&mut self.scratch_b);
                self.gather(pe_idx, ra, n, &mut va);
                self.gather(pe_idx, rb, n, &mut vb);
                match out {
                    Some(words) => map_out_kernel(kind, words, &va, &vb, scalar),
                    None => {
                        let d = rdst.as_ref().expect("map without fabout has a mem dst");
                        let dst = &mut self.pes[pe_idx].mem[d.base..d.base + 4 * n];
                        map_mem_kernel(kind, dst, &va, &vb, scalar);
                    }
                }
                self.scratch_a = va;
                self.scratch_b = vb;
                true
            }
            VecOp::Map16 => {
                // 16-bit integer elementwise pass (memory destinations
                // only; the classifier never marks a fabric-out Map16).
                if out.is_some() {
                    return false;
                }
                let Some(d) = rdst else { return false };
                if !matches!(d.ty, Dtype::I16 | Dtype::U16) {
                    return false;
                }
                let (fa, fb) = (src_span(ra, d.ty), src_span(rb, d.ty));
                let (Ok(sa), Ok(sb)) = (fa, fb) else { return false };
                if !vecop::admit_map(mem_len, Some(span(d)), &[sa, sb], n, 2) {
                    return false;
                }
                let mut va = std::mem::take(&mut self.scratch_a);
                let mut vb = std::mem::take(&mut self.scratch_b);
                self.gather16(pe_idx, ra, n, &mut va);
                self.gather16(pe_idx, rb, n, &mut vb);
                let base = d.base;
                let dst = &mut self.pes[pe_idx].mem[base..base + 2 * n];
                map_mem16_kernel(kind, dst, &va, &vb, scalar);
                self.scratch_a = va;
                self.scratch_b = vb;
                true
            }
            VecOp::MapF16 => {
                // f16 elementwise pass (memory destinations only; the
                // classifier never marks a fabric-out MapF16).
                if out.is_some() {
                    return false;
                }
                let Some(d) = rdst else { return false };
                if d.ty != Dtype::F16 {
                    return false;
                }
                let (fa, fb) = (src_span(ra, Dtype::F16), src_span(rb, Dtype::F16));
                let (Ok(sa), Ok(sb)) = (fa, fb) else { return false };
                if !vecop::admit_map(mem_len, Some(span(d)), &[sa, sb], n, 2) {
                    return false;
                }
                let mut va = std::mem::take(&mut self.scratch_a);
                let mut vb = std::mem::take(&mut self.scratch_b);
                self.gather_f16(pe_idx, ra, n, &mut va);
                self.gather_f16(pe_idx, rb, n, &mut vb);
                let base = d.base;
                let dst = &mut self.pes[pe_idx].mem[base..base + 2 * n];
                map_mem_f16_kernel(kind, dst, &va, &vb, scalar);
                self.scratch_a = va;
                self.scratch_b = vb;
                true
            }
            VecOp::Fold => {
                let (fa, fb) = (src_span(ra, Dtype::F32), src_span(rb, Dtype::F32));
                let (Ok(_), Ok(sb)) = (fa, fb) else { return false };
                let Some(d) = rdst else { return false };
                let RVOp::Mem(a0) = ra else { return false };
                if d.ty != Dtype::F32 || d.stride != 0 || a0.base != d.base || a0.stride != 0 {
                    return false;
                }
                if !vecop::admit_fold(mem_len, Span { base: d.base, stride: 0 }, sb, n) {
                    return false;
                }
                let mut vb = std::mem::take(&mut self.scratch_b);
                self.gather(pe_idx, rb, n, &mut vb);
                let mem = &mut self.pes[pe_idx].mem;
                let acc = f32::from_le_bytes(mem[d.base..d.base + 4].try_into().unwrap());
                let acc = fold_kernel(kind, acc, &vb, scalar);
                mem[d.base..d.base + 4].copy_from_slice(&acc.to_le_bytes());
                self.scratch_b = vb;
                true
            }
            VecOp::None => false,
        }
    }

    /// Materialize one admitted source operand as a dense f64 slice
    /// (the interpreter's element representation, so rounding agrees).
    fn gather(&self, pe_idx: usize, o: &RVOp<'_>, n: usize, buf: &mut Vec<f64>) {
        buf.clear();
        match o {
            RVOp::Vals(v) => buf.extend_from_slice(&v[..n]),
            RVOp::Mem(r) => {
                let mem = &self.pes[pe_idx].mem;
                buf.extend(
                    mem[r.base..r.base + 4 * n]
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()) as f64),
                );
            }
            RVOp::Nothing => buf.resize(n, 0.0),
        }
    }

    /// 16-bit variant of [`ShardState::gather`]: materialize an
    /// admitted i16/u16 source as the interpreter's f64 element
    /// representation (sign- or zero-extended exactly like
    /// `load_scalar` + `SVal::as_f`).
    fn gather16(&self, pe_idx: usize, o: &RVOp<'_>, n: usize, buf: &mut Vec<f64>) {
        buf.clear();
        match o {
            RVOp::Vals(v) => buf.extend_from_slice(&v[..n]),
            RVOp::Mem(r) => {
                let mem = &self.pes[pe_idx].mem;
                let bytes = &mem[r.base..r.base + 2 * n];
                if r.ty == Dtype::I16 {
                    buf.extend(
                        bytes
                            .chunks_exact(2)
                            .map(|c| i16::from_le_bytes(c.try_into().unwrap()) as f64),
                    );
                } else {
                    buf.extend(
                        bytes
                            .chunks_exact(2)
                            .map(|c| u16::from_le_bytes(c.try_into().unwrap()) as f64),
                    );
                }
            }
            RVOp::Nothing => buf.resize(n, 0.0),
        }
    }

    /// f16 variant of [`ShardState::gather`]: materialize an admitted
    /// f16 source as the interpreter's f64 element representation
    /// (widened exactly like `load_scalar`'s f16 → f32 → f64 chain).
    fn gather_f16(&self, pe_idx: usize, o: &RVOp<'_>, n: usize, buf: &mut Vec<f64>) {
        buf.clear();
        match o {
            RVOp::Vals(v) => buf.extend_from_slice(&v[..n]),
            RVOp::Mem(r) => {
                let mem = &self.pes[pe_idx].mem;
                buf.extend(
                    mem[r.base..r.base + 2 * n]
                        .chunks_exact(2)
                        .map(|c| f16_to_f64(u16::from_le_bytes(c.try_into().unwrap()))),
                );
            }
            RVOp::Nothing => buf.resize(n, 0.0),
        }
    }

    fn resolve_mem(&self, pe_idx: usize, r: &DsdRef) -> RMem {
        match r {
            DsdRef::Mem { base, offset, stride, ty, .. } => {
                let off = self.eval(pe_idx, offset).as_i();
                RMem {
                    base: (*base as i64 + off * ty.size() as i64) as usize,
                    stride: (*stride * ty.size() as i64) as isize,
                    ty: *ty,
                }
            }
            _ => panic!("resolve_mem on fabric DSD"),
        }
    }

    fn resolve_vop<'a>(&self, pe_idx: usize, o: &VOp<'a>) -> RVOp<'a> {
        match o {
            VOp::Vals(v) => RVOp::Vals(v),
            VOp::Mem(r) => RVOp::Mem(self.resolve_mem(pe_idx, r)),
            VOp::Nothing => RVOp::Nothing,
        }
    }

    #[inline]
    fn rv_val(&self, pe_idx: usize, o: &RVOp<'_>, i: usize) -> f64 {
        match o {
            RVOp::Vals(v) => v[i],
            RVOp::Mem(r) => {
                let addr = (r.base as isize + i as isize * r.stride) as usize;
                if r.ty == Dtype::F32 {
                    // Fast path: the dominant case in every kernel.
                    let mem = &self.pes[pe_idx].mem;
                    f32::from_le_bytes(mem[addr..addr + 4].try_into().unwrap()) as f64
                } else {
                    self.load_scalar(pe_idx, addr, r.ty).as_f()
                }
            }
            RVOp::Nothing => 0.0,
        }
    }

    fn dsd_len(&self, pe_idx: usize, op: &PDsd) -> usize {
        let from = |r: &DsdRef| -> i64 {
            match r {
                DsdRef::Mem { len, .. } | DsdRef::FabIn { len, .. } | DsdRef::FabOut { len, .. } => {
                    self.eval(pe_idx, len).as_i()
                }
            }
        };
        from(&op.dst)
            .min(op.src0.as_ref().map(|r| from(r)).unwrap_or(i64::MAX))
            .min(op.src1.as_ref().map(|r| from(r)).unwrap_or(i64::MAX))
            .max(0) as usize
    }

    fn exec_ops(
        &mut self,
        ctx: &Ctx<'_>,
        pe_idx: usize,
        ops: &[POp],
        clock: &mut u64,
    ) -> Result<(), SimError> {
        for op in ops {
            match op {
                POp::SetReg { reg, val } => {
                    let v = self.eval(pe_idx, val);
                    self.pes[pe_idx].regs[*reg as usize] = v;
                    *clock += ctx.cfg.scalar_op_cycles + val.cost();
                }
                POp::Store { addr, ty, val } => {
                    let a = self.eval(pe_idx, addr).as_i() as usize;
                    let v = self.eval(pe_idx, val);
                    self.store_scalar(pe_idx, a, *ty, v);
                    self.metrics.mem_bytes += ty.size() as u64;
                    *clock += ctx.cfg.scalar_op_cycles + addr.cost() + val.cost();
                }
                POp::Control(a) => {
                    self.apply_paction(ctx, pe_idx, a);
                    *clock += ctx.cfg.scalar_op_cycles;
                    // Activation becomes visible now; the post-task
                    // PeReady event will pick it up.
                }
                POp::If { cond, then_ops, else_ops } => {
                    *clock += ctx.cfg.scalar_op_cycles + cond.cost();
                    if self.eval(pe_idx, cond).truthy() {
                        self.exec_ops(ctx, pe_idx, then_ops, clock)?;
                    } else {
                        self.exec_ops(ctx, pe_idx, else_ops, clock)?;
                    }
                }
                POp::For { reg, start, stop, step, body } => {
                    let s = self.eval(pe_idx, start).as_i();
                    let e = self.eval(pe_idx, stop).as_i();
                    let st = self.eval(pe_idx, step).as_i().max(1);
                    let mut i = s;
                    *clock += ctx.cfg.scalar_op_cycles;
                    while i < e {
                        self.pes[pe_idx].regs[*reg as usize] = SVal::I(i);
                        self.exec_ops(ctx, pe_idx, body, clock)?;
                        *clock += ctx.cfg.scalar_op_cycles; // inc + branch
                        i += st;
                    }
                }
                POp::Halt => {
                    let pe = &mut self.pes[pe_idx];
                    pe.last_activity = pe.last_activity.max(*clock);
                }
                POp::Trace(msg) => {
                    let pe = &self.pes[pe_idx];
                    eprintln!("[{}] PE({},{}): {}", *clock, pe.x, pe.y, msg);
                }
                POp::Dsd(d) => self.exec_dsd(ctx, pe_idx, d, clock)?,
            }
        }
        Ok(())
    }

    fn exec_dsd(
        &mut self,
        ctx: &Ctx<'_>,
        pe_idx: usize,
        op: &PDsd,
        clock: &mut u64,
    ) -> Result<(), SimError> {
        let t0 = *clock;
        let v0 = self.vec_ops;
        *clock += ctx.cfg.dsd_issue_cycles;
        let n = self.dsd_len(pe_idx, op);
        let fabout_dst = matches!(op.dst, DsdRef::FabOut { .. });

        if op.fab_slot != SLOT_NONE {
            if !op.is_async {
                return Err(SimError::Program(
                    "fabric-in DSD operations must be asynchronous (microthreaded)".into(),
                ));
            }
            self.pes[pe_idx].endpoints[op.fab_slot as usize].consumers.push_back(
                PendingConsume {
                    consume_ix: op.consume_ix,
                    need: n,
                    taken: Vec::with_capacity(n),
                    last_avail: 0,
                    issue_time: *clock,
                },
            );
            self.try_satisfy(ctx, pe_idx, op.fab_slot)?;
            if ctx.trace {
                // The DSD span itself is emitted when the consume
                // completes (`complete_consume`); only freshly logged
                // admission stalls are drained here.
                self.drain_stall_log(ctx, pe_idx, op.fab_slot);
            }
            return Ok(());
        }

        if fabout_dst {
            // Compute payload from memory/scalar sources at issue time.
            let scalar = op.scalar.as_ref().map(|e| self.eval(pe_idx, e).as_f()).unwrap_or(
                if op.kind == DsdKind::Fill { 0.0 } else { 1.0 },
            );
            let a = op.src0.as_ref().map(VOp::Mem).unwrap_or(VOp::Nothing);
            let b = op.src1.as_ref().map(VOp::Mem).unwrap_or(VOp::Nothing);
            let words = self
                .apply_dsd(ctx, pe_idx, op.kind, &op.dst, a, b, scalar, n, op.vec)?
                .expect("fabout dst produces words");
            let color = match &op.dst {
                DsdRef::FabOut { color, .. } => *color,
                _ => unreachable!(),
            };
            let (_start, drain_end) =
                self.send_flow(ctx, pe_idx, color, Arc::new(words), *clock + 1)?;
            if ctx.trace {
                let gpe = self.pes[pe_idx].gix;
                self.trace.push(TraceRecord::Dsd {
                    pe: gpe,
                    kind: op.kind,
                    n: n as u32,
                    vectorized: self.vec_ops > v0,
                    start: t0,
                    end: drain_end,
                });
            }
            if op.is_async {
                if op.actions != ACTIONS_EMPTY {
                    let gpe = self.pes[pe_idx].gix;
                    self.schedule(
                        drain_end,
                        EventKind::Complete { pe: gpe, actions: op.actions },
                    );
                }
            } else {
                // Synchronous send: spin until the buffer drains.
                *clock = (*clock).max(drain_end);
                self.apply_actions_id(ctx, pe_idx, op.actions);
            }
            let pe = &mut self.pes[pe_idx];
            pe.last_activity = pe.last_activity.max(drain_end);
            return Ok(());
        }

        // Pure memory op: synchronous semantics (async mem ops share the
        // ALU anyway), cost = per-element cycles.
        let ty = op.dst.ty();
        let scalar = op.scalar.as_ref().map(|e| self.eval(pe_idx, e).as_f()).unwrap_or(
            if op.kind == DsdKind::Fill { 0.0 } else { 1.0 },
        );
        let a = op.src0.as_ref().map(VOp::Mem).unwrap_or(VOp::Nothing);
        let b = op.src1.as_ref().map(VOp::Mem).unwrap_or(VOp::Nothing);
        self.apply_dsd(ctx, pe_idx, op.kind, &op.dst, a, b, scalar, n, op.vec)?;
        *clock += self.elem_cycles(ctx, ty, n as u64);
        if ctx.trace {
            let gpe = self.pes[pe_idx].gix;
            self.trace.push(TraceRecord::Dsd {
                pe: gpe,
                kind: op.kind,
                n: n as u32,
                vectorized: self.vec_ops > v0,
                start: t0,
                end: *clock,
            });
        }
        self.apply_actions_id(ctx, pe_idx, op.actions);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Batched DSD slice kernels
// ---------------------------------------------------------------------
//
// One monomorphized pass per `DsdKind`. Each element is computed with
// the interpreter's exact arithmetic — f32 sources widened to f64,
// the operation applied in f64, the result rounded back to f32 — so
// destination memory and emitted fabric words are bit-identical to the
// per-element loop. The win is structural: no per-element operand
// dispatch, no strided address math, and loops the compiler can keep
// in registers and auto-vectorize.

/// Elementwise pass into a contiguous f32 memory destination.
fn map_mem_kernel(kind: DsdKind, dst: &mut [u8], a: &[f64], b: &[f64], scalar: f64) {
    fn run(dst: &mut [u8], a: &[f64], b: &[f64], f: impl Fn(f64, f64) -> f64) {
        for ((o, x), y) in dst.chunks_exact_mut(4).zip(a).zip(b) {
            o.copy_from_slice(&(f(*x, *y) as f32).to_le_bytes());
        }
    }
    match kind {
        DsdKind::Fadd => run(dst, a, b, |x, y| x + y),
        DsdKind::Fsub => run(dst, a, b, |x, y| x - y),
        DsdKind::Fmul => run(dst, a, b, |x, y| x * y),
        DsdKind::Fmac => run(dst, a, b, |x, y| x + y * scalar),
        DsdKind::Fscale => run(dst, a, b, |x, _| x * scalar),
        DsdKind::Mov => run(dst, a, b, |x, _| x),
        DsdKind::Fill => run(dst, a, b, |_, _| scalar),
        DsdKind::FmaxOp => run(dst, a, b, |x, y| x.max(y)),
    }
}

/// Elementwise pass into a fabric-out word stream.
fn map_out_kernel(kind: DsdKind, words: &mut Vec<u32>, a: &[f64], b: &[f64], scalar: f64) {
    fn run(words: &mut Vec<u32>, a: &[f64], b: &[f64], f: impl Fn(f64, f64) -> f64) {
        words.extend(a.iter().zip(b).map(|(x, y)| (f(*x, *y) as f32).to_bits()));
    }
    match kind {
        DsdKind::Fadd => run(words, a, b, |x, y| x + y),
        DsdKind::Fsub => run(words, a, b, |x, y| x - y),
        DsdKind::Fmul => run(words, a, b, |x, y| x * y),
        DsdKind::Fmac => run(words, a, b, |x, y| x + y * scalar),
        DsdKind::Fscale => run(words, a, b, |x, _| x * scalar),
        DsdKind::Mov => run(words, a, b, |x, _| x),
        DsdKind::Fill => run(words, a, b, |_, _| scalar),
        DsdKind::FmaxOp => run(words, a, b, |x, y| x.max(y)),
    }
}

/// Elementwise pass into a contiguous 16-bit integer memory
/// destination. The interpreter computes every element in f64 and
/// stores through `SVal::as_i` (a saturating f64→i64 cast) truncated
/// to 16 bits; the kernel reproduces that exact conversion chain, so
/// i16 and u16 destinations are bit-identical to the per-element path.
fn map_mem16_kernel(kind: DsdKind, dst: &mut [u8], a: &[f64], b: &[f64], scalar: f64) {
    fn run(dst: &mut [u8], a: &[f64], b: &[f64], f: impl Fn(f64, f64) -> f64) {
        for ((o, x), y) in dst.chunks_exact_mut(2).zip(a).zip(b) {
            o.copy_from_slice(&((f(*x, *y) as i64) as i16).to_le_bytes());
        }
    }
    match kind {
        DsdKind::Fadd => run(dst, a, b, |x, y| x + y),
        DsdKind::Fsub => run(dst, a, b, |x, y| x - y),
        DsdKind::Fmul => run(dst, a, b, |x, y| x * y),
        DsdKind::Fmac => run(dst, a, b, |x, y| x + y * scalar),
        DsdKind::Fscale => run(dst, a, b, |x, _| x * scalar),
        DsdKind::Mov => run(dst, a, b, |x, _| x),
        DsdKind::Fill => run(dst, a, b, |_, _| scalar),
        DsdKind::FmaxOp => run(dst, a, b, |x, y| x.max(y)),
    }
}

/// Elementwise pass into a contiguous f16 memory destination. The
/// interpreter computes every element in f64 and stores through
/// `store_scalar` → `f64_to_f16` (an f64→f32 rounding followed by the
/// f32→f16 conversion); the kernel reproduces that exact rounding
/// chain, so f16 destinations are bit-identical to the per-element
/// path.
fn map_mem_f16_kernel(kind: DsdKind, dst: &mut [u8], a: &[f64], b: &[f64], scalar: f64) {
    fn run(dst: &mut [u8], a: &[f64], b: &[f64], f: impl Fn(f64, f64) -> f64) {
        for ((o, x), y) in dst.chunks_exact_mut(2).zip(a).zip(b) {
            o.copy_from_slice(&f64_to_f16(f(*x, *y)).to_le_bytes());
        }
    }
    match kind {
        DsdKind::Fadd => run(dst, a, b, |x, y| x + y),
        DsdKind::Fsub => run(dst, a, b, |x, y| x - y),
        DsdKind::Fmul => run(dst, a, b, |x, y| x * y),
        DsdKind::Fmac => run(dst, a, b, |x, y| x + y * scalar),
        DsdKind::Fscale => run(dst, a, b, |x, _| x * scalar),
        DsdKind::Mov => run(dst, a, b, |x, _| x),
        DsdKind::Fill => run(dst, a, b, |_, _| scalar),
        DsdKind::FmaxOp => run(dst, a, b, |x, y| x.max(y)),
    }
}

/// Scalar-fold pass for the stride-0 accumulate idiom: the interpreter
/// stores the f32-rounded partial result every element and re-reads it
/// as the next element's `src0`, so the fold rounds to f32 after every
/// step to stay bit-identical.
fn fold_kernel(kind: DsdKind, acc0: f32, b: &[f64], scalar: f64) -> f32 {
    fn run(acc0: f32, b: &[f64], f: impl Fn(f64, f64) -> f64) -> f32 {
        let mut acc = acc0;
        for y in b {
            acc = f(acc as f64, *y) as f32;
        }
        acc
    }
    match kind {
        DsdKind::Fadd => run(acc0, b, |x, y| x + y),
        DsdKind::Fsub => run(acc0, b, |x, y| x - y),
        DsdKind::Fmul => run(acc0, b, |x, y| x * y),
        DsdKind::Fmac => run(acc0, b, |x, y| x + y * scalar),
        DsdKind::Fscale => run(acc0, b, |x, _| x * scalar),
        DsdKind::Mov => run(acc0, b, |x, _| x),
        DsdKind::Fill => run(acc0, b, |_, _| scalar),
        DsdKind::FmaxOp => run(acc0, b, |x, y| x.max(y)),
    }
}

// ---------------------------------------------------------------------
// f16 conversion helpers (no external deps)
// ---------------------------------------------------------------------

fn f16_to_f64(bits: u16) -> f64 {
    let sign = ((bits >> 15) & 1) as u32;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let frac = (bits & 0x3ff) as u32;
    let f32_bits = if exp == 0 {
        if frac == 0 {
            sign << 31
        } else {
            // subnormal
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        (sign << 31) | (0xff << 23) | (frac << 13)
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(f32_bits) as f64
}

fn f64_to_f16(v: f64) -> u16 {
    let bits = (v as f32).to_bits();
    let sign = ((bits >> 31) & 1) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7fffff;
    if exp == 0xff {
        return (sign << 15) | (0x1f << 10) | ((frac >> 13) as u16 & 0x3ff);
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        (sign << 15) | (0x1f << 10) // overflow -> inf
    } else if e <= 0 {
        // subnormal / zero
        if e < -10 {
            sign << 15
        } else {
            let f = (frac | 0x800000) >> (1 - e + 13);
            (sign << 15) | f as u16
        }
    } else {
        (sign << 15) | ((e as u16) << 10) | ((frac >> 13) as u16 & 0x3ff)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::*;
    use crate::util::{Range1, Subgrid};

    fn cfg(w: i64, h: i64) -> MachineConfig {
        MachineConfig::with_grid(w, h)
    }

    /// Single PE doubles an input field with a Fmac (out = in + in*1).
    #[test]
    fn single_pe_vector_op() {
        let k = 8u32;
        let class = PeClass {
            name: "only".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![
                FieldAlloc { name: "in".into(), addr: 0, len: k, ty: Dtype::F32, is_extern: true },
                FieldAlloc { name: "out".into(), addr: 4 * k, len: k, ty: Dtype::F32, is_extern: true },
            ],
            mem_size: 8 * k,
            tasks: vec![TaskDef {
                name: "main".into(),
                hw_id: 24,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![
                    MOp::Dsd(DsdOp {
                        kind: DsdKind::Fmac,
                        dst: DsdRef::mem(4 * k, SExpr::imm(k as i64), Dtype::F32),
                        src0: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F32)),
                        src1: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F32)),
                        scalar: Some(SExpr::ImmF(1.0)),
                        is_async: false,
                        on_complete: vec![],
                    }),
                    MOp::Halt,
                ],
            }],
            entry_tasks: vec![24],
        };
        let prog = MachineProgram {
            name: "double".into(),
            classes: vec![class],
            io: vec![
                IoBinding {
                    arg: "in".into(),
                    field: "in".into(),
                    dir: IoDir::In,
                    subgrid: Subgrid::point(0, 0),
                    elems_per_pe: k,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
                IoBinding {
                    arg: "out".into(),
                    field: "out".into(),
                    dir: IoDir::Out,
                    subgrid: Subgrid::point(0, 0),
                    elems_per_pe: k,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
            ],
            ..Default::default()
        };
        let mut sim = Simulator::new(cfg(2, 2), prog).unwrap();
        let input: Vec<f32> = (0..k).map(|i| i as f32).collect();
        sim.set_input("in", &input).unwrap();
        let report = sim.run().unwrap();
        let out = sim.get_output("out").unwrap();
        let expect: Vec<f32> = input.iter().map(|v| 2.0 * v).collect();
        assert_eq!(out, expect);
        assert!(report.cycles > 0);
        assert_eq!(report.metrics.flops, 2 * k as u64);
    }

    /// Two PEs: PE0 sends its array east, PE1 receives and accumulates
    /// (shared by the send/receive, thread-equivalence and reset tests).
    fn p2p_prog(k: u32, color: u8) -> MachineProgram {
        let sender = PeClass {
            name: "sender".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: k,
                ty: Dtype::F32,
                is_extern: true,
            }],
            mem_size: 4 * k,
            tasks: vec![TaskDef {
                name: "send".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len: SExpr::imm(k as i64), ty: Dtype::F32 },
                    src0: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F32)),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        let recv = PeClass {
            name: "recv".into(),
            subgrids: vec![Subgrid::point(1, 0)],
            fields: vec![FieldAlloc {
                name: "acc".into(),
                addr: 0,
                len: k,
                ty: Dtype::F32,
                is_extern: true,
            }],
            mem_size: 4 * k,
            tasks: vec![TaskDef {
                name: "recv".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Fadd,
                    dst: DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F32),
                    src0: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F32)),
                    src1: Some(DsdRef::FabIn { color, len: SExpr::imm(k as i64), ty: Dtype::F32 }),
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        MachineProgram {
            name: "p2p".into(),
            classes: vec![sender, recv],
            routes: vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            io: vec![
                IoBinding {
                    arg: "a".into(),
                    field: "a".into(),
                    dir: IoDir::In,
                    subgrid: Subgrid::point(0, 0),
                    elems_per_pe: k,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
                IoBinding {
                    arg: "acc0".into(),
                    field: "acc".into(),
                    dir: IoDir::In,
                    subgrid: Subgrid::point(1, 0),
                    elems_per_pe: k,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
                IoBinding {
                    arg: "acc".into(),
                    field: "acc".into(),
                    dir: IoDir::Out,
                    subgrid: Subgrid::point(1, 0),
                    elems_per_pe: k,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
            ],
            colors_used: vec![color],
            ..Default::default()
        }
    }

    fn run_p2p(threads: usize) -> (RunReport, Vec<f32>) {
        let k = 16u32;
        let mut sim = Simulator::new(cfg(2, 1), p2p_prog(k, 1)).unwrap();
        sim.set_threads(threads);
        let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let acc0: Vec<f32> = vec![100.0; k as usize];
        sim.set_input("a", &a).unwrap();
        sim.set_input("acc0", &acc0).unwrap();
        let report = sim.run().unwrap();
        let out = sim.get_output("acc").unwrap();
        (report, out)
    }

    #[test]
    fn two_pe_send_receive() {
        let k = 16u32;
        let (report, out) = run_p2p(1);
        let expect: Vec<f32> = (0..k).map(|i| 100.0 + i as f32).collect();
        assert_eq!(out, expect);
        assert_eq!(report.metrics.flows, 1);
        assert_eq!(report.metrics.wavelets, k as u64);
        // Pipelined: runtime ~ K + overheads, far less than 2K.
        assert!(report.cycles < 2 * k as u64 + 40, "cycles = {}", report.cycles);
    }

    /// The epoch-parallel engine (≥ 2 threads forces the sharded path:
    /// sender and receiver are distinct link-sharing islands) must be
    /// bit-identical to the classic single-queue loop.
    #[test]
    fn parallel_threads_bit_identical() {
        let (seq_report, seq_out) = run_p2p(1);
        for threads in [2, 4, 8] {
            let (par_report, par_out) = run_p2p(threads);
            assert_eq!(par_report, seq_report, "threads={threads}: RunReport diverged");
            assert_eq!(par_out, seq_out, "threads={threads}: outputs diverged");
        }
    }

    /// `Simulator::reset` re-arms one allocation for another run with
    /// identical results (the bench-sweep reuse lever).
    #[test]
    fn reset_reruns_bit_identical() {
        let k = 16u32;
        let mut sim = Simulator::new(cfg(2, 1), p2p_prog(k, 1)).unwrap();
        sim.set_threads(1);
        let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
        let acc0: Vec<f32> = vec![100.0; k as usize];
        sim.set_input("a", &a).unwrap();
        sim.set_input("acc0", &acc0).unwrap();
        let first = sim.run().unwrap();
        let first_out = sim.get_output("acc").unwrap();
        // Staged inputs survive reset; everything else is pristine.
        sim.reset();
        let second = sim.run().unwrap();
        let second_out = sim.get_output("acc").unwrap();
        assert_eq!(first, second, "reset run diverged from the first run");
        assert_eq!(first_out, second_out);
    }

    /// Deadlock detection: receiver waits for data nobody sends.
    #[test]
    fn deadlock_detected() {
        let class = PeClass {
            name: "waiter".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: 4,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 16,
            tasks: vec![TaskDef {
                name: "recv".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::mem(0, SExpr::imm(4), Dtype::F32),
                    src0: Some(DsdRef::FabIn { color: 0, len: SExpr::imm(4), ty: Dtype::F32 }),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        let prog = MachineProgram {
            name: "dead".into(),
            classes: vec![class],
            ..Default::default()
        };
        let mut sim = Simulator::new(cfg(1, 1), prog).unwrap();
        let err = sim.run().unwrap_err();
        assert!(matches!(err, SimError::Deadlock(_)), "{err}");
    }

    /// Local task chaining via activate.
    #[test]
    fn activation_chain() {
        let class = PeClass {
            name: "chain".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "v".into(),
                addr: 0,
                len: 1,
                ty: Dtype::F32,
                is_extern: true,
            }],
            mem_size: 4,
            tasks: vec![
                TaskDef {
                    name: "first".into(),
                    hw_id: 24,
                    kind: TaskKind::Local,
                    initially_active: false,
                    initially_blocked: false,
                    body: vec![
                        MOp::Store {
                            addr: SExpr::imm(0),
                            ty: Dtype::F32,
                            val: SExpr::ImmF(1.0),
                        },
                        MOp::Control(TaskAction::activate(25)),
                    ],
                },
                TaskDef {
                    name: "second".into(),
                    hw_id: 25,
                    kind: TaskKind::Local,
                    initially_active: false,
                    initially_blocked: false,
                    body: vec![MOp::Store {
                        addr: SExpr::imm(0),
                        ty: Dtype::F32,
                        val: SExpr::bin(
                            SBinOp::Add,
                            SExpr::LoadMem { addr: Box::new(SExpr::imm(0)), ty: Dtype::F32 },
                            SExpr::ImmF(41.0),
                        ),
                    }],
                },
            ],
            entry_tasks: vec![24],
        };
        let prog = MachineProgram {
            name: "chain".into(),
            classes: vec![class],
            io: vec![IoBinding {
                arg: "v".into(),
                field: "v".into(),
                dir: IoDir::Out,
                subgrid: Subgrid::point(0, 0),
                elems_per_pe: 1,
                total_ports: 1,
                port_map: PortMap::default(),
ty: Dtype::F32,
            }],
            ..Default::default()
        };
        let mut sim = Simulator::new(cfg(1, 1), prog).unwrap();
        sim.run().unwrap();
        assert_eq!(sim.get_output("v").unwrap(), vec![42.0]);
    }

    /// Sender streams `n` words east; a receiver *data task* fires per
    /// wavelet and accumulates into addr 0 (shared by the data-task and
    /// stall-trace tests — the per-wavelet consumption rate is far
    /// slower than the wire, so a small endpoint cap guarantees stalls).
    fn datatask_prog(n: u32, color: u8) -> MachineProgram {
        let sender = PeClass {
            name: "s".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: n,
                ty: Dtype::F32,
                is_extern: true,
            }],
            mem_size: 4 * n,
            tasks: vec![TaskDef {
                name: "send".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len: SExpr::imm(n as i64), ty: Dtype::F32 },
                    src0: Some(DsdRef::mem(0, SExpr::imm(n as i64), Dtype::F32)),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        // Receiver data task: sum += wavelet (scalar accumulate at addr 0).
        let recv = PeClass {
            name: "r".into(),
            subgrids: vec![Subgrid::point(1, 0)],
            fields: vec![FieldAlloc {
                name: "sum".into(),
                addr: 0,
                len: 1,
                ty: Dtype::F32,
                is_extern: true,
            }],
            mem_size: 4,
            tasks: vec![TaskDef {
                name: "on_wavelet".into(),
                hw_id: color,
                kind: TaskKind::Data { color, wavelet_reg: 0 },
                initially_active: true,
                initially_blocked: false,
                body: vec![MOp::Store {
                    addr: SExpr::imm(0),
                    ty: Dtype::F32,
                    val: SExpr::bin(
                        SBinOp::Add,
                        SExpr::LoadMem { addr: Box::new(SExpr::imm(0)), ty: Dtype::F32 },
                        SExpr::Reg(0),
                    ),
                }],
            }],
            entry_tasks: vec![],
        };
        MachineProgram {
            name: "datatask".into(),
            classes: vec![sender, recv],
            routes: vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            io: vec![
                IoBinding {
                    arg: "a".into(),
                    field: "a".into(),
                    dir: IoDir::In,
                    subgrid: Subgrid::point(0, 0),
                    elems_per_pe: n,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
                IoBinding {
                    arg: "sum".into(),
                    field: "sum".into(),
                    dir: IoDir::Out,
                    subgrid: Subgrid::point(1, 0),
                    elems_per_pe: 1,
                    total_ports: 1,
                    port_map: PortMap::default(),
ty: Dtype::F32,
                },
            ],
            colors_used: vec![color],
            ..Default::default()
        }
    }

    /// Data task fires once per wavelet.
    #[test]
    fn data_task_per_wavelet() {
        let mut sim = Simulator::new(cfg(2, 1), datatask_prog(5, 2)).unwrap();
        sim.set_input("a", &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        sim.run().unwrap();
        assert_eq!(sim.get_output("sum").unwrap(), vec![15.0]);
    }

    /// The 16-bit integer slice kernel must be bit-identical to the
    /// per-element interpreter (i16 Fadd over contiguous operands).
    #[test]
    fn map16_slice_kernel_equivalent() {
        let k = 8u32;
        let prog = || {
            let class = PeClass {
                name: "only".into(),
                subgrids: vec![Subgrid::point(0, 0)],
                fields: vec![
                    FieldAlloc {
                        name: "in".into(),
                        addr: 0,
                        len: k,
                        ty: Dtype::I16,
                        is_extern: true,
                    },
                    FieldAlloc {
                        name: "out".into(),
                        addr: 2 * k,
                        len: k,
                        ty: Dtype::I16,
                        is_extern: true,
                    },
                ],
                mem_size: 4 * k,
                tasks: vec![TaskDef {
                    name: "main".into(),
                    hw_id: 24,
                    kind: TaskKind::Local,
                    initially_active: false,
                    initially_blocked: false,
                    body: vec![
                        MOp::Dsd(DsdOp {
                            kind: DsdKind::Fadd,
                            dst: DsdRef::mem(2 * k, SExpr::imm(k as i64), Dtype::I16),
                            src0: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::I16)),
                            src1: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::I16)),
                            scalar: None,
                            is_async: false,
                            on_complete: vec![],
                        }),
                        MOp::Halt,
                    ],
                }],
                entry_tasks: vec![24],
            };
            MachineProgram {
                name: "double16".into(),
                classes: vec![class],
                io: vec![
                    IoBinding {
                        arg: "in".into(),
                        field: "in".into(),
                        dir: IoDir::In,
                        subgrid: Subgrid::point(0, 0),
                        elems_per_pe: k,
                        total_ports: 1,
                        port_map: PortMap::default(),
                        ty: Dtype::I16,
                    },
                    IoBinding {
                        arg: "out".into(),
                        field: "out".into(),
                        dir: IoDir::Out,
                        subgrid: Subgrid::point(0, 0),
                        elems_per_pe: k,
                        total_ports: 1,
                        port_map: PortMap::default(),
                        ty: Dtype::I16,
                    },
                ],
                ..Default::default()
            }
        };
        // Values incl. negatives: stored as 16-bit two's complement.
        let input: Vec<u32> = (0..k).map(|i| (i as i16 - 3) as u16 as u32).collect();
        let run = |vectorize: bool| -> (RunReport, Vec<u32>, u64) {
            let mut sim = Simulator::new(cfg(1, 1), prog()).unwrap();
            sim.set_threads(1);
            sim.set_vectorize(vectorize);
            sim.set_input_words("in", input.clone()).unwrap();
            let report = sim.run().unwrap();
            let out = sim.get_output_words("out").unwrap();
            (report, out, sim.vec_ops_executed())
        };
        let (vec_report, vec_out, vec_ops) = run(true);
        let (int_report, int_out, int_ops) = run(false);
        assert!(vec_ops > 0, "Map16 slice kernel never engaged");
        assert_eq!(int_ops, 0);
        assert_eq!(vec_report, int_report, "16-bit engines diverged in report");
        assert_eq!(vec_out, int_out, "16-bit engines diverged in memory");
        let expect: Vec<u32> =
            (0..k).map(|i| (2 * (i as i16 - 3)) as u16 as u32).collect();
        assert_eq!(vec_out, expect);
    }

    /// The f16 slice kernel must be bit-identical to the per-element
    /// interpreter (f16 Fadd over contiguous operands) — the last
    /// dtype that used to be forced onto the interpreter.
    #[test]
    fn f16_slice_kernel_equivalent() {
        let k = 8u32;
        let prog = || {
            let class = PeClass {
                name: "only".into(),
                subgrids: vec![Subgrid::point(0, 0)],
                fields: vec![
                    FieldAlloc {
                        name: "in".into(),
                        addr: 0,
                        len: k,
                        ty: Dtype::F16,
                        is_extern: true,
                    },
                    FieldAlloc {
                        name: "out".into(),
                        addr: 2 * k,
                        len: k,
                        ty: Dtype::F16,
                        is_extern: true,
                    },
                ],
                mem_size: 4 * k,
                tasks: vec![TaskDef {
                    name: "main".into(),
                    hw_id: 24,
                    kind: TaskKind::Local,
                    initially_active: false,
                    initially_blocked: false,
                    body: vec![
                        MOp::Dsd(DsdOp {
                            kind: DsdKind::Fmac,
                            dst: DsdRef::mem(2 * k, SExpr::imm(k as i64), Dtype::F16),
                            src0: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F16)),
                            src1: Some(DsdRef::mem(0, SExpr::imm(k as i64), Dtype::F16)),
                            scalar: Some(SExpr::ImmF(0.5)),
                            is_async: false,
                            on_complete: vec![],
                        }),
                        MOp::Halt,
                    ],
                }],
                entry_tasks: vec![24],
            };
            MachineProgram {
                name: "scale16".into(),
                classes: vec![class],
                io: vec![
                    IoBinding {
                        arg: "in".into(),
                        field: "in".into(),
                        dir: IoDir::In,
                        subgrid: Subgrid::point(0, 0),
                        elems_per_pe: k,
                        total_ports: 1,
                        port_map: PortMap::default(),
                        ty: Dtype::F16,
                    },
                    IoBinding {
                        arg: "out".into(),
                        field: "out".into(),
                        dir: IoDir::Out,
                        subgrid: Subgrid::point(0, 0),
                        elems_per_pe: k,
                        total_ports: 1,
                        port_map: PortMap::default(),
                        ty: Dtype::F16,
                    },
                ],
                ..Default::default()
            }
        };
        // f16 bit patterns incl. values that round on the f64→f16 path.
        let input: Vec<u32> =
            (0..k).map(|i| f64_to_f16(i as f64 * 0.3 - 1.1) as u32).collect();
        let run = |vectorize: bool| -> (RunReport, Vec<u32>, u64) {
            let mut sim = Simulator::new(cfg(1, 1), prog()).unwrap();
            sim.set_threads(1);
            sim.set_vectorize(vectorize);
            sim.set_input_words("in", input.clone()).unwrap();
            let report = sim.run().unwrap();
            let out = sim.get_output_words("out").unwrap();
            (report, out, sim.vec_ops_executed())
        };
        let (vec_report, vec_out, vec_ops) = run(true);
        let (int_report, int_out, int_ops) = run(false);
        assert!(vec_ops > 0, "MapF16 slice kernel never engaged");
        assert_eq!(int_ops, 0);
        assert_eq!(vec_report, int_report, "f16 engines diverged in report");
        assert_eq!(vec_out, int_out, "f16 engines diverged in memory");
        // Spot-check the arithmetic: out = in + in·0.5 in the f64
        // interpreter chain, rounded through f16 exactly once.
        let expect: Vec<u32> = input
            .iter()
            .map(|&w| {
                let x = f16_to_f64(w as u16);
                f64_to_f16(x + x * 0.5) as u32
            })
            .collect();
        assert_eq!(vec_out, expect);
    }

    /// A finite endpoint capacity with an eager consumer completes with
    /// the unbounded run's outputs, and a capacity at the unbounded
    /// run's peak queue depth is bit-identical to the unbounded run.
    #[test]
    fn finite_buffers_trickle_and_size_from_peak() {
        let k = 16u32;
        // Unbounded p2p run for the reference output and peak depth.
        let run_with = |cap: Option<u64>| {
            let mut c = cfg(2, 1);
            c.endpoint_capacity_words = cap;
            let mut sim = Simulator::new(c, p2p_prog(k, 1)).unwrap();
            sim.set_threads(1);
            let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
            let acc0: Vec<f32> = vec![100.0; k as usize];
            sim.set_input("a", &a).unwrap();
            sim.set_input("acc0", &acc0).unwrap();
            let report = sim.run().unwrap();
            let out = sim.get_output("acc").unwrap();
            (report, out)
        };
        let (unbounded, out_unbounded) = run_with(None);
        // The p2p receiver issues its consume at entry, so even a tiny
        // capacity drains at wire rate: same outputs, zero stalls.
        let (capped, out_capped) = run_with(Some(4));
        assert_eq!(out_capped, out_unbounded, "eager consumer must see identical values");
        assert_eq!(capped.metrics.wavelets, unbounded.metrics.wavelets);
        assert!(
            unbounded.metrics.peak_queue_depth > 0,
            "unbounded run must report its high-water mark"
        );
        // Capacity at the unbounded peak: bit-identical run report.
        let (sized, out_sized) = run_with(Some(unbounded.metrics.peak_queue_depth));
        assert_eq!(sized, unbounded, "cap >= peak depth must be bit-identical");
        assert_eq!(out_sized, out_unbounded);
    }

    /// A flow whose destination never consumes it completes unbounded
    /// (leftover words are legal) but deadlocks at a small capacity —
    /// the class of failure the flow-control subsystem exists to catch.
    #[test]
    fn buffer_deadlock_reported_at_small_capacity() {
        let k = 16u32;
        let taken = 4u32;
        let mk = || {
            // Sender ships K words; receiver consumes only `taken`.
            let mut prog = p2p_prog(k, 1);
            // Shrink the receiver's consume to `taken` words.
            let recv = &mut prog.classes[1];
            if let MOp::Dsd(d) = &mut recv.tasks[0].body[0] {
                d.dst = DsdRef::mem(0, SExpr::imm(taken as i64), Dtype::F32);
                d.src0 = Some(DsdRef::mem(0, SExpr::imm(taken as i64), Dtype::F32));
                d.src1 = Some(DsdRef::FabIn {
                    color: 1,
                    len: SExpr::imm(taken as i64),
                    ty: Dtype::F32,
                });
            }
            prog
        };
        let mut c = cfg(2, 1);
        c.endpoint_capacity_words = None; // explicit: ignore SPADA_BUF_CAP
        let mut sim = Simulator::new(c.clone(), mk()).unwrap();
        sim.set_threads(1);
        sim.set_input("a", &(0..k).map(|i| i as f32).collect::<Vec<f32>>()).unwrap();
        sim.set_input("acc0", &vec![0.0f32; k as usize]).unwrap();
        sim.run().expect("unbounded leftover words are legal");

        c.endpoint_capacity_words = Some(8);
        let mut sim = Simulator::new(c, mk()).unwrap();
        sim.set_threads(1);
        sim.set_input("a", &(0..k).map(|i| i as f32).collect::<Vec<f32>>()).unwrap();
        sim.set_input("acc0", &vec![0.0f32; k as usize]).unwrap();
        let err = sim.run().unwrap_err();
        let SimError::Deadlock(msg) = err else { panic!("want buffer deadlock, got {err}") };
        assert!(msg.contains("endpoint full"), "{msg}");
        assert!(msg.contains("stalled"), "{msg}");
        assert!(msg.contains("spada check --buffers"), "{msg}");
    }

    #[test]
    fn f16_roundtrip() {
        for v in [0.0, 1.0, -2.5, 0.125, 100.0] {
            let bits = f64_to_f16(v);
            assert!((f16_to_f64(bits) - v).abs() < 1e-3, "{v}");
        }
    }

    /// Tracing must never perturb the run (reports and outputs are
    /// bit-identical with it on or off), the merged record stream must
    /// be identical across thread counts, and busy cycles must
    /// reconcile with `Metrics::busy_cycles` exactly.
    #[test]
    fn tracing_inert_and_thread_invariant() {
        let k = 16u32;
        let run = |threads: usize, tracing: bool| {
            let mut sim = Simulator::new(cfg(2, 1), p2p_prog(k, 1)).unwrap();
            sim.set_threads(threads);
            sim.set_tracing(tracing);
            sim.set_input("a", &(0..k).map(|i| i as f32).collect::<Vec<f32>>()).unwrap();
            sim.set_input("acc0", &vec![100.0f32; k as usize]).unwrap();
            let report = sim.run().unwrap();
            let out = sim.get_output("acc").unwrap();
            (report, out, sim.take_trace())
        };
        let (plain_report, plain_out, none) = run(1, false);
        assert!(none.is_none(), "no trace unless enabled");
        let (base_report, base_out, base_trace) = run(1, true);
        assert_eq!(base_report, plain_report, "tracing must not change the report");
        assert_eq!(base_out, plain_out);
        let base_trace = base_trace.expect("tracing run produces a trace");
        assert!(!base_trace.records.is_empty());
        assert!(base_trace.epochs.is_empty(), "classic engine has no epochs");
        // Sorted by (start, pe) — the documented merge order.
        let keys: Vec<(u64, u32)> =
            base_trace.records.iter().map(|r| (r.start(), r.pe())).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
        // Task spans reconcile with the metrics busy counter exactly.
        let busy: u64 = base_trace
            .records
            .iter()
            .map(|r| match *r {
                TraceRecord::Task { start, end, .. } => end - start,
                _ => 0,
            })
            .sum();
        assert_eq!(busy, base_report.metrics.busy_cycles);
        assert!(base_trace
            .records
            .iter()
            .any(|r| matches!(r, TraceRecord::Flow { .. })));
        // The sharded engine (p2p = 2 islands at >= 2 threads) emits
        // the identical record stream.
        for threads in [2, 4] {
            let (report, out, trace) = run(threads, true);
            assert_eq!(report, base_report, "threads={threads}");
            assert_eq!(out, base_out);
            let trace = trace.unwrap();
            assert_eq!(
                trace.records, base_trace.records,
                "trace records diverged at threads={threads}"
            );
            assert!(!trace.epochs.is_empty(), "parallel engine logs its epochs");
            let merged_events: u64 =
                trace.epochs.iter().flat_map(|e| e.shard_events.iter()).sum();
            assert!(merged_events <= report.metrics.events);
        }
    }

    /// With a finite endpoint capacity and a slow consumer, stall
    /// records appear and reconcile with `Metrics::stall_cycles`
    /// exactly: sum of (admission - natural) * words.
    #[test]
    fn stall_records_reconcile_with_metrics() {
        let mut c = cfg(2, 1);
        c.endpoint_capacity_words = Some(2);
        let mut sim = Simulator::new(c, datatask_prog(5, 2)).unwrap();
        sim.set_threads(1);
        sim.set_tracing(true);
        sim.set_input("a", &[1.0, 2.0, 3.0, 4.0, 5.0]).unwrap();
        let report = sim.run().unwrap();
        assert_eq!(sim.get_output("sum").unwrap(), vec![15.0]);
        assert!(report.metrics.stall_cycles > 0, "slow consumer must stall the tail");
        let trace = sim.take_trace().unwrap();
        let logged: u64 = trace
            .records
            .iter()
            .map(|r| match *r {
                TraceRecord::Stall { start, end, words, .. } => (end - start) * words as u64,
                _ => 0,
            })
            .sum();
        assert_eq!(logged, report.metrics.stall_cycles, "stall records must reconcile");
    }

    /// Both engines report their shape: the classic loop as one shard
    /// with zero epochs, the parallel engine with its real shard count
    /// and per-shard event totals summing to `Metrics::events`.
    #[test]
    fn engine_stats_cover_both_engines() {
        let run = |threads: usize| {
            let k = 16u32;
            let mut sim = Simulator::new(cfg(2, 1), p2p_prog(k, 1)).unwrap();
            sim.set_threads(threads);
            sim.set_input("a", &(0..k).map(|i| i as f32).collect::<Vec<f32>>()).unwrap();
            sim.set_input("acc0", &vec![100.0f32; k as usize]).unwrap();
            let report = sim.run().unwrap();
            (report, sim.engine_stats().clone())
        };
        let (report, st) = run(1);
        assert_eq!((st.shards, st.epochs), (1, 0));
        assert_eq!(st.shard_events, vec![report.metrics.events]);
        assert_eq!(st.imbalance(), 1.0);
        let (report, st) = run(4);
        assert_eq!(st.shards, 2, "p2p decomposes into 2 link-sharing islands");
        assert!(st.epochs > 0);
        assert_eq!(st.shard_events.iter().sum::<u64>(), report.metrics.events);
        assert!(st.imbalance() >= 1.0);
    }
}

//! Circuit-switched route resolution.
//!
//! Flows follow the static per-(PE, color) router configuration. Given a
//! source PE and a color, [`trace_route`] walks the configured rx/tx sets
//! and produces the full (possibly multicast) path: the ordered list of
//! links the flow occupies and the set of destination PEs with their hop
//! depths.
//!
//! This is the single source of truth for route geometry. It runs only
//! at setup time: [`crate::machine::plan::RoutingPlan`] traces every
//! (source PE, color) pair once when a program is loaded, and both the
//! simulator's event loop and the static checker
//! ([`crate::analysis::flowgraph`]) consume those precompiled paths, so
//! the two can never disagree about where a flow goes.

use super::program::{Direction, MachineProgram, RouteRule};
use super::MachineConfig;
use std::collections::HashSet;

/// One link of a flow path: the wavelet leaves PE `(x, y)` through `dir`
/// at hop depth `depth` (source ramp is depth 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PathLink {
    pub x: i64,
    pub y: i64,
    pub dir: Direction,
    pub depth: u64,
}

/// A resolved flow path.
#[derive(Clone, Debug, Default)]
pub struct FlowPath {
    pub links: Vec<PathLink>,
    /// (x, y, hop depth at delivery) for every PE whose router forwards
    /// the flow to its ramp.
    pub dests: Vec<(i64, i64, u64)>,
}

/// Errors during route tracing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    /// No route configured for this color at an intermediate PE.
    Unrouted { x: i64, y: i64, color: u8 },
    /// The flow leaves the fabric.
    OffFabric { x: i64, y: i64, dir: &'static str },
    /// Routing loop detected.
    Loop { x: i64, y: i64 },
    /// Route enters a PE whose rx set does not include the arrival port.
    RxMismatch { x: i64, y: i64, color: u8 },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::Unrouted { x, y, color } => {
                write!(f, "no route for color {color} at PE ({x},{y})")
            }
            RouteError::OffFabric { x, y, dir } => {
                write!(f, "route leaves fabric at PE ({x},{y}) towards {dir}")
            }
            RouteError::Loop { x, y } => write!(f, "routing loop at PE ({x},{y})"),
            RouteError::RxMismatch { x, y, color } => {
                write!(f, "rx mismatch for color {color} at PE ({x},{y})")
            }
        }
    }
}

impl std::error::Error for RouteError {}

fn rule_at<'a>(prog: &'a MachineProgram, color: u8, x: i64, y: i64) -> Option<&'a RouteRule> {
    prog.route_at(color, x, y)
}

/// Trace the route of color `color` injected at PE `(sx, sy)` (entering
/// the router from the ramp).
pub fn trace_route(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    color: u8,
    sx: i64,
    sy: i64,
) -> Result<FlowPath, RouteError> {
    let mut path = FlowPath::default();
    let mut visited: HashSet<(i64, i64, Direction)> = HashSet::new();
    // BFS frontier: (x, y, arrival direction into this router, depth).
    let mut frontier: Vec<(i64, i64, Direction, u64)> = vec![(sx, sy, Direction::Ramp, 0)];

    while let Some((x, y, arrived_via, depth)) = frontier.pop() {
        if !visited.insert((x, y, arrived_via)) {
            return Err(RouteError::Loop { x, y });
        }
        let rule = rule_at(prog, color, x, y).ok_or(RouteError::Unrouted { x, y, color })?;
        if !rule.rx.contains(arrived_via) {
            return Err(RouteError::RxMismatch { x, y, color });
        }
        for out in rule.tx.iter() {
            if out == Direction::Ramp {
                // Deliver locally. Source loopback (ramp->ramp at the
                // injecting PE) is allowed by hardware but we treat it as
                // delivery too.
                path.dests.push((x, y, depth));
                continue;
            }
            let (dx, dy) = out.delta();
            let (nx, ny) = (x + dx, y + dy);
            if !cfg.in_bounds(nx, ny) {
                return Err(RouteError::OffFabric { x, y, dir: out.csl_name() });
            }
            path.links.push(PathLink { x, y, dir: out, depth });
            frontier.push((nx, ny, out.opposite(), depth + cfg.hop_cycles));
        }
    }
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::{DirSet, MachineProgram, RouteRule};
    use crate::util::{Range1, Subgrid};

    fn cfg() -> MachineConfig {
        MachineConfig::with_grid(8, 8)
    }

    /// Row pipeline west→east on color 1: PE 0 sends, PEs 1..6 forward +
    /// deliver, PE 7 delivers.
    fn row_multicast_prog() -> MachineProgram {
        MachineProgram {
            name: "row".into(),
            routes: vec![
                RouteRule {
                    color: 1,
                    subgrid: Subgrid::new(Range1::point(0), Range1::point(0)),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color: 1,
                    subgrid: Subgrid::new(Range1::dense(1, 7), Range1::point(0)),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::East).with(Direction::Ramp),
                },
                RouteRule {
                    color: 1,
                    subgrid: Subgrid::new(Range1::point(7), Range1::point(0)),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            ..Default::default()
        }
    }

    #[test]
    fn multicast_row() {
        let prog = row_multicast_prog();
        let path = trace_route(&prog, &cfg(), 1, 0, 0).unwrap();
        assert_eq!(path.links.len(), 7);
        assert_eq!(path.dests.len(), 7); // PEs 1..=7
        let depths: Vec<u64> = {
            let mut d: Vec<_> = path.dests.iter().map(|(x, _, dep)| (*x, *dep)).collect();
            d.sort();
            d.iter().map(|(_, dep)| *dep).collect()
        };
        assert_eq!(depths, vec![1, 2, 3, 4, 5, 6, 7]); // +1 per hop from source
    }

    #[test]
    fn single_hop() {
        let prog = MachineProgram {
            name: "p2p".into(),
            routes: vec![
                RouteRule {
                    color: 2,
                    subgrid: Subgrid::point(3, 3),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::North),
                },
                RouteRule {
                    color: 2,
                    subgrid: Subgrid::point(3, 2),
                    rx: DirSet::single(Direction::South),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            ..Default::default()
        };
        let path = trace_route(&prog, &cfg(), 2, 3, 3).unwrap();
        assert_eq!(path.dests, vec![(3, 2, 1)]);
        assert_eq!(path.links.len(), 1);
        assert_eq!(path.links[0].dir, Direction::North);
    }

    #[test]
    fn unrouted_err() {
        let prog = MachineProgram::default();
        let err = trace_route(&prog, &cfg(), 0, 0, 0).unwrap_err();
        assert!(matches!(err, RouteError::Unrouted { .. }));
    }

    #[test]
    fn off_fabric_err() {
        let prog = MachineProgram {
            name: "edge".into(),
            routes: vec![RouteRule {
                color: 0,
                subgrid: Subgrid::point(0, 0),
                rx: DirSet::single(Direction::Ramp),
                tx: DirSet::single(Direction::West),
            }],
            ..Default::default()
        };
        let err = trace_route(&prog, &cfg(), 0, 0, 0).unwrap_err();
        assert!(matches!(err, RouteError::OffFabric { .. }));
    }

    /// A route that turns at the grid corner: south→north into (0,0),
    /// then east along the top row. Exercises rx/tx handoff when the
    /// turn happens on the fabric boundary.
    #[test]
    fn grid_boundary_turn() {
        let prog = MachineProgram {
            name: "turn".into(),
            routes: vec![
                RouteRule {
                    color: 4,
                    subgrid: Subgrid::point(0, 1),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::North),
                },
                RouteRule {
                    color: 4,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::South),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color: 4,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            ..Default::default()
        };
        let path = trace_route(&prog, &cfg(), 4, 0, 1).unwrap();
        assert_eq!(path.dests, vec![(1, 0, 2)]);
        assert_eq!(path.links.len(), 2);
        assert_eq!(path.links.iter().filter(|l| l.dir == Direction::North).count(), 1);
        assert_eq!(path.links.iter().filter(|l| l.dir == Direction::East).count(), 1);
    }

    /// A router forking one flow into three directions (multicast tx
    /// set), including a local ramp delivery at the fork PE itself.
    #[test]
    fn fork_multicast_with_loopback() {
        let prog = MachineProgram {
            name: "fork".into(),
            routes: vec![
                RouteRule {
                    color: 5,
                    subgrid: Subgrid::point(1, 1),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::North)
                        .with(Direction::South)
                        .with(Direction::Ramp),
                },
                RouteRule {
                    color: 5,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::South),
                    tx: DirSet::single(Direction::Ramp),
                },
                RouteRule {
                    color: 5,
                    subgrid: Subgrid::point(1, 2),
                    rx: DirSet::single(Direction::North),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            ..Default::default()
        };
        let path = trace_route(&prog, &cfg(), 5, 1, 1).unwrap();
        let mut dests = path.dests.clone();
        dests.sort();
        assert_eq!(dests, vec![(1, 0, 1), (1, 1, 0), (1, 2, 1)]);
        assert_eq!(path.links.len(), 2);
    }

    /// Two distinct colors may legally traverse the same physical link:
    /// each traces independently (they serialize at runtime; only
    /// same-color sharing is ambiguous, which `analysis` flags).
    #[test]
    fn overlapping_paths_on_distinct_colors() {
        let mk = |color: u8| {
            vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ]
        };
        let mut routes = mk(6);
        routes.extend(mk(7));
        let prog = MachineProgram { name: "share".into(), routes, ..Default::default() };
        for color in [6u8, 7u8] {
            let path = trace_route(&prog, &cfg(), color, 0, 0).unwrap();
            assert_eq!(path.dests, vec![(1, 0, 1)]);
            assert_eq!(path.links[0].dir, Direction::East);
        }
    }

    #[test]
    fn loop_err() {
        // Two PEs forwarding to each other with rx sets that accept it.
        let prog = MachineProgram {
            name: "loop".into(),
            routes: vec![
                RouteRule {
                    color: 0,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp).with(Direction::East),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color: 0,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::West),
                },
            ],
            ..Default::default()
        };
        let err = trace_route(&prog, &cfg(), 0, 0, 0).unwrap_err();
        assert!(matches!(err, RouteError::Loop { .. }));
    }
}

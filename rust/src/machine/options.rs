//! `SimOptions` — every simulator runtime option, resolved in one place.
//!
//! Historically the `SPADA_*` environment variables were read wherever
//! they were consumed: `SPADA_THREADS` and `SPADA_NO_VEC` inside the
//! simulator constructor, `SPADA_BUF_CAP` / `SPADA_TIMEOUT_MS` /
//! `SPADA_FAULTS` inside `MachineConfig::with_grid`, `SPADA_TRACE` in
//! the CLI. That is fine for one process running one simulation, but a
//! batch fleet runs *concurrent* jobs with *different* options — and
//! process-global env cannot express that. This module is the redesign:
//!
//! - [`SimOptions`] is an explicit, per-simulation options value with a
//!   builder API. [`crate::kernels::CompiledKernel::simulator_with`] and
//!   [`super::Simulator::with_plan_opts`] consume it directly; nothing
//!   on that path touches the environment.
//! - [`SimOptions::from_env`] is the **single** place in the crate that
//!   reads `SPADA_*` variables. The compatibility constructors
//!   ([`super::Simulator::new`], [`super::Simulator::with_plan`],
//!   [`crate::kernels::CompiledKernel::simulator`]) resolve it once at
//!   construction, so the CLI and the test suites keep their historical
//!   env-driven behaviour — through exactly one resolve site.
//!
//! Precedence: options mirroring a [`MachineConfig`] field (buffer
//! capacity, credit latency, watchdog, faults) are applied only when
//! the config still holds its pristine default — an explicitly
//! configured `MachineConfig` always wins over ambient environment.
//! This reproduces the historical behaviour, where `with_grid` seeded
//! the config from env and callers overrode fields afterwards.
//!
//! The old→new mapping is documented in `docs/sim-options.md`.

use super::config::MachineConfig;
use super::fault::FaultPlan;

/// Per-simulation runtime options. Construct with [`SimOptions::default`]
/// (fully explicit, ignores the environment) or [`SimOptions::from_env`]
/// (the single `SPADA_*` resolve site), then refine with the builder
/// methods.
#[derive(Clone, Debug, Default)]
pub struct SimOptions {
    /// Worker threads for the epoch-parallel engine. `None` = the
    /// host's available parallelism. Results are bit-identical at
    /// every count (`SPADA_THREADS`).
    pub threads: Option<usize>,
    /// Force the per-element DSD interpreter instead of the batched
    /// slice kernels. Bit-identical either way (`SPADA_NO_VEC`).
    pub no_vectorize: bool,
    /// Finite (PE, color) endpoint buffers: capacity in words with
    /// credit-based backpressure. `None` = leave the config as built
    /// (unbounded unless the caller set a capacity) (`SPADA_BUF_CAP`).
    pub buf_cap: Option<u64>,
    /// Words of per-link-stage slack for the static credit pass and
    /// deadlock reports. No env var; builder/config only.
    pub link_buffer_words: Option<u64>,
    /// Credit-return latency in cycles (`MachineConfig::
    /// credit_latency_cycles`). No env var; builder/config only.
    pub credit_latency: Option<u64>,
    /// Wall-clock watchdog in milliseconds (`SPADA_TIMEOUT_MS`; `None`
    /// = leave the config as built).
    pub timeout_ms: Option<u64>,
    /// Fault-injection plan (`SPADA_FAULTS`). `None` = leave the
    /// config as built. A malformed ambient spec is preserved inside
    /// the plan's `invalid` field so the *run* rejects it loudly.
    pub faults: Option<FaultPlan>,
    /// Capture a cycle-accurate trace ([`super::trace`]).
    pub tracing: bool,
    /// Chrome-trace output path (`SPADA_TRACE` / `spada run --trace`).
    /// Consumed by the CLI; implies [`SimOptions::tracing`].
    pub trace_path: Option<String>,
}

impl SimOptions {
    /// Resolve every `SPADA_*` environment variable once. This is the
    /// **only** function in the crate that reads simulation options
    /// from the environment; everything downstream takes the value.
    pub fn from_env() -> SimOptions {
        SimOptions {
            threads: std::env::var("SPADA_THREADS")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .map(|n| n.max(1)),
            no_vectorize: std::env::var_os("SPADA_NO_VEC").is_some(),
            // A positive word count caps every endpoint; unset,
            // unparsable or zero means "leave unbounded".
            buf_cap: std::env::var("SPADA_BUF_CAP")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .filter(|&n| n > 0),
            link_buffer_words: None,
            credit_latency: None,
            // 0, unset or unparsable disables the watchdog (0 would
            // abort every run before its first event — never useful,
            // so it reads as "off").
            timeout_ms: std::env::var("SPADA_TIMEOUT_MS")
                .ok()
                .and_then(|s| match s.trim().parse::<u64>() {
                    Ok(0) | Err(_) => None,
                    Ok(ms) => Some(ms),
                }),
            faults: match std::env::var("SPADA_FAULTS") {
                Ok(s) if !s.trim().is_empty() => Some(match FaultPlan::parse(&s) {
                    Ok(p) => p,
                    // Preserved so the run (not the config constructor)
                    // rejects it — a typo must never run clean.
                    Err(e) => FaultPlan { invalid: Some(e), ..FaultPlan::default() },
                }),
                _ => None,
            },
            tracing: false,
            trace_path: std::env::var("SPADA_TRACE").ok().filter(|s| !s.is_empty()),
        }
    }

    /// Builder: worker-thread count (1 = classic single-queue loop).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Builder: enable/disable the batched DSD engine.
    pub fn vectorize(mut self, on: bool) -> Self {
        self.no_vectorize = !on;
        self
    }

    /// Builder: finite endpoint buffer capacity in words.
    pub fn buf_cap(mut self, cap: u64) -> Self {
        self.buf_cap = Some(cap);
        self
    }

    /// Builder: credit-return latency in cycles.
    pub fn credit_latency(mut self, cycles: u64) -> Self {
        self.credit_latency = Some(cycles);
        self
    }

    /// Builder: wall-clock watchdog in milliseconds.
    pub fn timeout_ms(mut self, ms: u64) -> Self {
        self.timeout_ms = Some(ms);
        self
    }

    /// Builder: fault-injection plan.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Builder: capture a cycle-accurate trace.
    pub fn tracing(mut self, on: bool) -> Self {
        self.tracing = on;
        self
    }

    /// The effective worker-thread count: the explicit value, else the
    /// host's available parallelism.
    pub fn resolved_threads(&self) -> usize {
        self.threads
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
            .max(1)
    }

    /// Whether trace capture should be armed (an output path implies
    /// capture).
    pub fn tracing_enabled(&self) -> bool {
        self.tracing || self.trace_path.is_some()
    }

    /// Fold the config-mirroring options into `cfg`. Each field is
    /// applied only when the config still holds its pristine default,
    /// so an explicitly configured `MachineConfig` wins over these
    /// options (see the module docs on precedence).
    pub fn apply_defaults_to(&self, cfg: &mut MachineConfig) {
        if cfg.endpoint_capacity_words.is_none() {
            cfg.endpoint_capacity_words = self.buf_cap;
        }
        if cfg.link_buffer_words.is_none() {
            cfg.link_buffer_words = self.link_buffer_words;
        }
        if cfg.credit_latency_cycles == 0 {
            if let Some(l) = self.credit_latency {
                cfg.credit_latency_cycles = l;
            }
        }
        if cfg.timeout_ms.is_none() {
            cfg.timeout_ms = self.timeout_ms;
        }
        if cfg.faults.is_empty() {
            if let Some(f) = &self.faults {
                cfg.faults = f.clone();
            }
        }
    }
}

/// Size budget for the fleet plan cache
/// ([`crate::fleet::PlanCache`]): entry-count and/or byte ceilings with
/// LRU eviction. `None` on both axes (the default) means unbounded —
/// the historical one-batch-per-process behaviour. Long-lived
/// processes (`spada serve`) should bound at least one axis.
///
/// Lives in this module so the `SPADA_CACHE_*` reads stay at the single
/// env resolve site, next to every other `SPADA_*` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheBudget {
    /// Maximum distinct cached shapes; least-recently-used entries are
    /// evicted past it (`SPADA_CACHE_ENTRIES`).
    pub max_entries: Option<usize>,
    /// Approximate byte ceiling over the cached plans
    /// (`SPADA_CACHE_BYTES`); a single in-use entry may exceed it.
    pub max_bytes: Option<u64>,
}

impl CacheBudget {
    /// No bounds: entries live for the process lifetime.
    pub fn unbounded() -> CacheBudget {
        CacheBudget::default()
    }

    /// Resolve `SPADA_CACHE_ENTRIES` / `SPADA_CACHE_BYTES` once. Zero,
    /// unset or unparsable means "no bound on that axis" (matching the
    /// `SPADA_BUF_CAP` convention: zero-sized caches are never useful,
    /// so 0 reads as "off").
    pub fn from_env() -> CacheBudget {
        CacheBudget {
            max_entries: std::env::var("SPADA_CACHE_ENTRIES")
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
                .filter(|&n| n > 0),
            max_bytes: std::env::var("SPADA_CACHE_BYTES")
                .ok()
                .and_then(|s| s.trim().parse::<u64>().ok())
                .filter(|&n| n > 0),
        }
    }

    /// Whether any axis is bounded (an unbounded budget makes eviction
    /// a no-op).
    pub fn bounded(&self) -> bool {
        self.max_entries.is_some() || self.max_bytes.is_some()
    }
}

/// `SPADA_BLESS`: re-bless the golden cycle-identity snapshots. Test
/// harness plumbing, not a simulation option — it lives here so every
/// `SPADA_*` environment read stays at this one resolve site.
pub fn env_bless() -> bool {
    std::env::var_os("SPADA_BLESS").is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_fully_explicit() {
        let o = SimOptions::default();
        assert_eq!(o.threads, None);
        assert!(!o.no_vectorize);
        assert_eq!(o.buf_cap, None);
        assert_eq!(o.timeout_ms, None);
        assert!(o.faults.is_none());
        assert!(!o.tracing_enabled());
        assert!(o.resolved_threads() >= 1);
    }

    #[test]
    fn builder_round_trip() {
        let o = SimOptions::default()
            .threads(2)
            .vectorize(false)
            .buf_cap(8)
            .credit_latency(5)
            .timeout_ms(100)
            .tracing(true);
        assert_eq!(o.threads, Some(2));
        assert!(o.no_vectorize);
        assert_eq!(o.buf_cap, Some(8));
        assert_eq!(o.credit_latency, Some(5));
        assert_eq!(o.timeout_ms, Some(100));
        assert!(o.tracing_enabled());
        assert_eq!(o.resolved_threads(), 2);
    }

    #[test]
    fn cache_budget_default_is_unbounded() {
        let b = CacheBudget::default();
        assert_eq!(b, CacheBudget::unbounded());
        assert!(!b.bounded());
        assert!(CacheBudget { max_entries: Some(4), max_bytes: None }.bounded());
        assert!(CacheBudget { max_entries: None, max_bytes: Some(1 << 20) }.bounded());
    }

    #[test]
    fn apply_defaults_never_clobbers_explicit_config() {
        let mut cfg = MachineConfig::with_grid(4, 4);
        cfg.endpoint_capacity_words = Some(2);
        cfg.timeout_ms = Some(7);
        cfg.credit_latency_cycles = 3;
        let opts = SimOptions::default().buf_cap(8).timeout_ms(100).credit_latency(9);
        opts.apply_defaults_to(&mut cfg);
        assert_eq!(cfg.endpoint_capacity_words, Some(2));
        assert_eq!(cfg.timeout_ms, Some(7));
        assert_eq!(cfg.credit_latency_cycles, 3);
    }

    #[test]
    fn apply_defaults_fills_pristine_fields() {
        let mut cfg = MachineConfig::with_grid(4, 4);
        assert_eq!(cfg.endpoint_capacity_words, None, "with_grid must be env-free");
        assert_eq!(cfg.timeout_ms, None);
        assert!(cfg.faults.is_empty());
        let opts = SimOptions::default()
            .buf_cap(8)
            .timeout_ms(100)
            .credit_latency(9)
            .faults(FaultPlan::parse("pe(1,0):halt@5").unwrap());
        opts.apply_defaults_to(&mut cfg);
        assert_eq!(cfg.endpoint_capacity_words, Some(8));
        assert_eq!(cfg.timeout_ms, Some(100));
        assert_eq!(cfg.credit_latency_cycles, 9);
        assert_eq!(cfg.faults.specs.len(), 1);
    }
}

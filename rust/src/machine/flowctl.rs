//! Credit-based finite-buffer flow control — the runtime half of the
//! buffer model (the static half is [`crate::analysis::credits`]).
//!
//! The paper's dataflow semantics define deadlock over *finite* router
//! and endpoint buffers, but the simulator historically queued arrived
//! flows at (PE, color) endpoints without bound, so backpressure stalls
//! and buffer-wedge deadlocks — a real class of WSE failure modes —
//! were invisible. This module gives every endpoint a finite word
//! capacity with credit-based admission:
//!
//! - **Credits.** An endpoint with capacity `cap` holds at most `cap`
//!   admitted-but-unconsumed words. Each consumed word returns one
//!   credit; credits return at the consuming word's availability time
//!   (never before the event that consumes it) plus
//!   `MachineConfig::credit_latency_cycles` — the return-path wire
//!   delay. The default latency of 0 is the historical
//!   instant-turnaround model: the capacity bound is exact, the timing
//!   optimistic by the credit round-trip; a nonzero latency charges
//!   that round-trip to every readmission wave (arrival-side admission
//!   spends no credit round-trip and is never delayed by it).
//! - **Wormhole tails.** A flow whose payload exceeds the free credits
//!   admits a prefix and leaves its tail *in the fabric*: the words
//!   wait in the route's link-stage buffers, upstream of the endpoint,
//!   exactly like a wormhole packet stalling in place. Tail words are
//!   admitted in FIFO order as credits free, each admission wave
//!   streaming in at link rate (one word per cycle) from its release
//!   time; the induced per-word delay is accounted as
//!   [`Metrics::stall_cycles`](crate::machine::Metrics).
//! - **FIFO per endpoint.** Admission is strictly first-flow-first:
//!   a later flow's words never overtake an earlier flow's stalled
//!   tail (same color ⇒ same virtual channel ⇒ in-order wire). Cross-
//!   *flow* head-of-line blocking on a shared link does not arise in
//!   statically clean programs: the routing checker rejects two
//!   distinct flows on one (link, color), and WSE-class routers buffer
//!   per color, so another color's traffic is never behind a stalled
//!   tail. The `link_buffer_words` capacity is therefore enforced by
//!   the *static* credit pass (how much tail a route can absorb before
//!   the stall backs into the source ramp), not re-modeled dynamically.
//! - **Deadlock.** A run that quiesces with unadmitted tail words has
//!   exhausted credits that can never return — the simulator reports a
//!   buffer deadlock naming the blocked endpoints, cross-referenced
//!   with the static verdict (`spada check --buffers`).
//!
//! With no capacity configured (`MachineConfig::endpoint_capacity_words
//! = None`, `SPADA_BUF_CAP` unset) every flow is admitted wholesale at
//! its natural arrival times and no stall state is ever created, so the
//! unbounded machine is **bit-identical** to the historical simulator —
//! golden snapshots, the `parallel_equiv` and `dsd_batch` suites all
//! hold unchanged. Because admission depends only on endpoint-local
//! state and the deterministic arrival order, a capped run is also
//! bit-identical across worker thread counts: cross-shard arrivals that
//! find a full endpoint simply enqueue their stalled tail in the merged
//! (deterministic) order, and stalls only *delay* word availability, so
//! the epoch-parallel engine's conservative lookahead stays sound.

use std::collections::VecDeque;
use std::sync::Arc;

/// Internal sentinel for "no capacity bound".
const UNBOUNDED: u64 = u64::MAX;

/// One arrived flow queued at an endpoint, with its admission state.
struct BufFlow {
    /// Natural availability time of word 0 at the PE ramp (the
    /// arrival-event timing; words stream in one per cycle after it).
    first_word: u64,
    words: Arc<Vec<u32>>,
    /// Next unconsumed word index (`< admitted`).
    cursor: usize,
    /// Words admitted into the endpoint buffer; `words[admitted..]` is
    /// the stalled tail still in the fabric.
    admitted: usize,
    /// Late-admission waves `(start index, base time)`, ascending by
    /// start index: word `i` of the wave starting at `s` becomes
    /// available at `base + (i - s)` (link rate). Words before the
    /// first wave arrive at their natural time `first_word + i`.
    waves: Vec<(usize, u64)>,
}

impl BufFlow {
    /// Availability time of word `idx` (must be `< admitted`).
    fn time(&self, idx: usize) -> u64 {
        let natural = self.first_word + idx as u64;
        for &(s, b) in self.waves.iter().rev() {
            if s <= idx {
                return natural.max(b + (idx - s) as u64);
            }
        }
        natural
    }

    fn stalled(&self) -> usize {
        self.words.len() - self.admitted
    }
}

/// The credit-managed buffer of one (PE, color) endpoint. With an
/// unbounded capacity this is exactly the historical `VecDeque` of
/// arrived flows (every word admitted at its natural time); with a
/// finite capacity it adds credit accounting, stalled-tail admission
/// and stall metrics. All state is endpoint-local, so the structure is
/// trivially deterministic under the epoch-parallel engine.
pub struct EndpointBuf {
    /// Capacity in words ([`UNBOUNDED`] when no cap is configured).
    cap: u64,
    /// Cycles for a freed credit to travel back upstream; added to
    /// every consumption-side credit release (never to arrival-side
    /// admission, which spends no round-trip).
    credit_latency: u64,
    /// Admitted, unconsumed words currently buffered.
    in_use: u64,
    flows: VecDeque<BufFlow>,
    /// Index into `flows` of the first flow with an unadmitted tail
    /// (== `flows.len()` when everything is admitted). Admission is
    /// strictly FIFO, so this only ever moves forward — it makes every
    /// admission attempt O(1) amortized and keeps the hot unbounded
    /// path free of scans.
    first_unadmitted: usize,
    /// Total unadmitted words across all flows (the stalled tail).
    stalled: u64,
    /// High-water mark of `in_use` — the capacity-sizing observable
    /// surfaced as `Metrics::peak_queue_depth`.
    peak: u64,
    /// Word-cycles of admission delay attributable to backpressure.
    stall_cycles: u64,
    /// When set, every delayed admission wave is also logged to
    /// `stalls` for the tracing layer. Off by default so the hot
    /// admission path stays allocation-free.
    log: bool,
    /// Logged stall intervals: `(natural_arrival, admission, words)`
    /// per delayed wave. Drained by the simulator via
    /// [`EndpointBuf::take_stalls`] right after the admissions happen.
    stalls: Vec<(u64, u64, u32)>,
}

impl EndpointBuf {
    pub fn new(cap: Option<u64>) -> EndpointBuf {
        Self::with_credit_latency(cap, 0)
    }

    pub fn with_credit_latency(cap: Option<u64>, credit_latency: u64) -> EndpointBuf {
        EndpointBuf {
            cap: cap.unwrap_or(UNBOUNDED),
            credit_latency,
            in_use: 0,
            flows: VecDeque::new(),
            first_unadmitted: 0,
            stalled: 0,
            peak: 0,
            stall_cycles: 0,
            log: false,
            stalls: Vec::new(),
        }
    }

    /// Reset all runtime state and counters, keeping the capacity and
    /// the logging flag.
    pub fn clear(&mut self) {
        self.in_use = 0;
        self.flows.clear();
        self.first_unadmitted = 0;
        self.stalled = 0;
        self.peak = 0;
        self.stall_cycles = 0;
        self.stalls.clear();
    }

    /// Enable or disable stall-interval logging (tracing support).
    /// Logging only records what the credit accounting already
    /// computed — it never changes admission times.
    pub fn set_logging(&mut self, on: bool) {
        self.log = on;
        self.stalls.clear();
    }

    /// Drain the logged stall intervals accumulated since the last
    /// call: `(natural_arrival, admission_time, words)` per wave.
    pub fn take_stalls(&mut self) -> Vec<(u64, u64, u32)> {
        std::mem::take(&mut self.stalls)
    }

    /// Enqueue an arrived flow. Words are admitted up to the free
    /// credits at their natural wire times; any remainder stalls in
    /// the fabric until credits return.
    pub fn push_flow(&mut self, first_word: u64, words: Arc<Vec<u32>>) {
        let len = words.len();
        if self.stalled == 0 {
            self.first_unadmitted = self.flows.len();
        }
        self.flows.push_back(BufFlow { first_word, words, cursor: 0, admitted: 0, waves: vec![] });
        self.stalled += len as u64;
        // Arrival admission: base time 0 degrades to the natural wire
        // times, so the uncapped path is byte-identical to history.
        self.admit(0);
    }

    /// Admit stalled words into freed credits, strictly FIFO. Each
    /// admission wave starts no earlier than `t_rel` (the credit
    /// release time), no earlier than its natural wire time, and no
    /// earlier than one cycle after the previous word (link rate).
    fn admit(&mut self, t_rel: u64) {
        while self.stalled > 0 {
            let free = if self.cap == UNBOUNDED {
                usize::MAX
            } else {
                (self.cap - self.in_use) as usize
            };
            if free == 0 {
                return;
            }
            let f = &mut self.flows[self.first_unadmitted];
            let take = free.min(f.stalled());
            let s = f.admitted;
            let natural = f.first_word + s as u64;
            let prev_end = if s > 0 { f.time(s - 1) + 1 } else { 0 };
            let base = t_rel.max(natural).max(prev_end);
            if base > natural {
                f.waves.push((s, base));
                self.stall_cycles += (base - natural) * take as u64;
                if self.log {
                    self.stalls.push((natural, base, take as u32));
                }
            }
            f.admitted += take;
            self.in_use += take as u64;
            self.stalled -= take as u64;
            self.peak = self.peak.max(self.in_use);
            if f.admitted == f.words.len() {
                self.first_unadmitted += 1;
            }
            // A partial admission leaves the loop via free == 0.
        }
    }

    /// Availability time of the next unconsumed word at the FIFO head
    /// (`None`: nothing admitted and unconsumed — the scheduler has
    /// nothing to wake for until a consumption event frees credits).
    pub fn next_word_time(&self) -> Option<u64> {
        let f = self.flows.front()?;
        if f.cursor < f.admitted {
            Some(f.time(f.cursor))
        } else {
            None
        }
    }

    /// Drop the fully-consumed front flow (it is by construction fully
    /// admitted, so the FIFO admission cursor shifts down with it).
    fn pop_front_flow(&mut self) {
        self.flows.pop_front();
        self.first_unadmitted -= 1;
    }

    /// Pop the head word if it is available by `clock` (the data-task
    /// consume path: one wavelet per activation step). Returns the
    /// word; frees its credit at `clock` and admits stalled tails.
    pub fn pop_word(&mut self, clock: u64) -> Option<u32> {
        let (w, done) = {
            let f = self.flows.front_mut()?;
            if f.cursor >= f.admitted || f.time(f.cursor) > clock {
                return None;
            }
            let w = f.words[f.cursor];
            f.cursor += 1;
            (w, f.cursor == f.words.len())
        };
        if done {
            self.pop_front_flow();
        }
        self.in_use -= 1;
        self.admit(clock.saturating_add(self.credit_latency));
        Some(w)
    }

    /// Pull up to `need` available words into `out` (the microthreaded
    /// consume path), in FIFO order, freeing credits as it goes —
    /// credits return no earlier than `now` (the pulling event's time)
    /// and no earlier than the consumed word's own availability.
    /// Returns the availability time of the last word taken, if any.
    pub fn take(&mut self, mut need: usize, now: u64, out: &mut Vec<u32>) -> Option<u64> {
        let mut last: Option<u64> = None;
        while need > 0 {
            let (taken, t_last, done) = {
                let Some(f) = self.flows.front_mut() else { break };
                let avail = f.admitted - f.cursor;
                let take = need.min(avail);
                if take == 0 {
                    break;
                }
                out.extend_from_slice(&f.words[f.cursor..f.cursor + take]);
                let t = f.time(f.cursor + take - 1);
                f.cursor += take;
                (take, t, f.cursor == f.words.len())
            };
            if done {
                self.pop_front_flow();
            }
            self.in_use -= taken as u64;
            need -= taken;
            last = Some(last.map_or(t_last, |l: u64| l.max(t_last)));
            self.admit(t_last.max(now).saturating_add(self.credit_latency));
        }
        last
    }

    /// Any flow queued (admitted or stalled) — the data-task ready-bit
    /// predicate.
    pub fn queued(&self) -> bool {
        !self.flows.is_empty()
    }

    /// Words stalled in the fabric (arrived but never admitted). A
    /// nonzero value at quiescence is a buffer deadlock.
    pub fn stalled_words(&self) -> u64 {
        self.stalled
    }

    /// Admitted, unconsumed words currently buffered.
    pub fn occupancy(&self) -> u64 {
        self.in_use
    }

    /// High-water mark of the occupancy over the run so far.
    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Word-cycles of backpressure-induced admission delay.
    pub fn stall_cycles(&self) -> u64 {
        self.stall_cycles
    }

    /// The configured capacity (`None` = unbounded).
    pub fn capacity(&self) -> Option<u64> {
        if self.cap == UNBOUNDED {
            None
        } else {
            Some(self.cap)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn words(n: usize) -> Arc<Vec<u32>> {
        Arc::new((0..n as u32).collect())
    }

    /// Unbounded: every word admitted at its natural wire time, no
    /// stall state — the historical endpoint, bit for bit.
    #[test]
    fn unbounded_is_natural() {
        let mut b = EndpointBuf::new(None);
        b.push_flow(10, words(4));
        assert_eq!(b.next_word_time(), Some(10));
        assert_eq!(b.stalled_words(), 0);
        assert_eq!(b.occupancy(), 4);
        assert_eq!(b.peak(), 4);
        let mut out = vec![];
        let last = b.take(4, 10, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(last, Some(13)); // word 3 at first_word + 3
        assert_eq!(b.occupancy(), 0);
        assert_eq!(b.stall_cycles(), 0);
        assert!(!b.queued());
    }

    /// Capped: the prefix admits at natural times, the tail stalls and
    /// streams in at link rate from the release time.
    #[test]
    fn capped_tail_stalls_then_trickles() {
        let mut b = EndpointBuf::new(Some(4));
        b.push_flow(10, words(10));
        assert_eq!(b.occupancy(), 4);
        assert_eq!(b.stalled_words(), 6);
        // Consumer shows up late, at t = 100: pulls the 4 admitted
        // words, credits release at 100, 4 more words admit at
        // 100, 101, 102, 103.
        let mut out = vec![];
        let last = b.take(10, 100, &mut out);
        // take loops: 4 at natural (last avail 13), release at 100
        // admits 4 more (avail 100..104), pulled with last 103, then
        // the final 2 admit at 104, 105.
        assert_eq!(out.len(), 10);
        assert_eq!(out, (0..10).collect::<Vec<u32>>());
        assert_eq!(last, Some(105));
        assert_eq!(b.stalled_words(), 0);
        assert!(b.stall_cycles() > 0, "late drain must account stall cycles");
    }

    /// Stall logging mirrors the credit accounting exactly — the sum
    /// of logged `(admission - natural) * words` reproduces
    /// `stall_cycles` — and never perturbs admission behaviour.
    #[test]
    fn stall_log_reconciles_and_is_inert() {
        let run = |log: bool| {
            let mut b = EndpointBuf::new(Some(4));
            b.set_logging(log);
            b.push_flow(10, words(10));
            let mut out = vec![];
            let last = b.take(10, 100, &mut out);
            (out, last, b.stall_cycles(), b.take_stalls())
        };
        let (out_on, last_on, cycles_on, stalls) = run(true);
        let (out_off, last_off, cycles_off, none) = run(false);
        assert_eq!(out_on, out_off, "logging must not change admitted words");
        assert_eq!(last_on, last_off);
        assert_eq!(cycles_on, cycles_off);
        assert!(none.is_empty(), "logging off records nothing");
        assert!(!stalls.is_empty());
        let logged: u64 = stalls.iter().map(|&(nat, adm, w)| (adm - nat) * w as u64).sum();
        assert_eq!(logged, cycles_on, "log must reconcile with stall_cycles");
        for &(nat, adm, w) in &stalls {
            assert!(adm > nat && w > 0);
        }
    }

    /// A pending consumer pulls words as they stream in: credits free
    /// at wire rate, so the tail admits at its natural times and the
    /// stall costs nothing (the ALU drains at link rate).
    #[test]
    fn eager_consumer_costs_nothing() {
        let mut b = EndpointBuf::new(Some(4));
        b.push_flow(10, words(10));
        let mut out = vec![];
        // Pull at the arrival event (now = wire time of word 0).
        let last = b.take(10, 10, &mut out);
        assert_eq!(out.len(), 10);
        // Word 9 at natural time 19: releases chain at wire rate.
        assert_eq!(last, Some(19));
        assert_eq!(b.stall_cycles(), 0);
    }

    /// FIFO across flows: a later flow's words never overtake an
    /// earlier flow's stalled tail.
    #[test]
    fn admission_is_fifo_across_flows() {
        let mut b = EndpointBuf::new(Some(3));
        b.push_flow(10, words(5)); // admits 3, stalls 2
        b.push_flow(20, Arc::new(vec![100, 101])); // fully stalled
        assert_eq!(b.occupancy(), 3);
        assert_eq!(b.stalled_words(), 4);
        let mut out = vec![];
        b.take(7, 50, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 100, 101]);
        assert_eq!(b.stalled_words(), 0);
    }

    /// The data-task path: words pop one at a time, gated on their
    /// availability; each pop returns a credit.
    #[test]
    fn pop_word_gates_on_availability() {
        let mut b = EndpointBuf::new(Some(2));
        b.push_flow(10, words(4));
        assert_eq!(b.pop_word(9), None, "word 0 not available before t=10");
        assert_eq!(b.pop_word(10), Some(0));
        // Credit freed at t=10: word 2 admits with base max(10, 12) = 12.
        assert_eq!(b.pop_word(11), Some(1));
        assert_eq!(b.pop_word(11), None, "word 2 streams in at t=12");
        assert_eq!(b.pop_word(12), Some(2));
        assert_eq!(b.pop_word(13), Some(3));
        assert!(!b.queued());
        assert_eq!(b.stall_cycles(), 0, "wire-rate pops never stall");
    }

    /// Late pops delay the tail and the delay is accounted.
    #[test]
    fn late_pop_accounts_stall() {
        let mut b = EndpointBuf::new(Some(1));
        b.push_flow(10, words(2));
        assert_eq!(b.pop_word(50), Some(0));
        // Word 1 natural time 11, admitted at 50: 39 stall cycles.
        assert_eq!(b.stall_cycles(), 39);
        assert_eq!(b.next_word_time(), Some(50));
        assert_eq!(b.pop_word(50), Some(1));
    }

    /// Peak occupancy tracks the unbounded high-water mark — the
    /// capacity-sizing observable.
    #[test]
    fn peak_tracks_high_water() {
        let mut b = EndpointBuf::new(None);
        b.push_flow(0, words(3));
        b.push_flow(5, words(4));
        assert_eq!(b.peak(), 7);
        let mut out = vec![];
        b.take(7, 10, &mut out);
        b.push_flow(20, words(2));
        assert_eq!(b.peak(), 7, "peak never decreases");
    }

    /// Latency 0 is the historical instant-turnaround model, bit for
    /// bit — the constructor pair must agree exactly.
    #[test]
    fn zero_credit_latency_is_identical() {
        let run = |mut b: EndpointBuf| {
            b.push_flow(10, words(10));
            let mut out = vec![];
            let last = b.take(10, 100, &mut out);
            (out, last, b.stall_cycles())
        };
        assert_eq!(
            run(EndpointBuf::new(Some(4))),
            run(EndpointBuf::with_credit_latency(Some(4), 0))
        );
    }

    /// A nonzero latency delays every readmission wave by exactly the
    /// round-trip: the late-drain scenario's tail admits `latency`
    /// cycles later, and the extra delay lands in `stall_cycles`.
    #[test]
    fn credit_latency_delays_readmission() {
        let drain = |lat: u64| {
            let mut b = EndpointBuf::with_credit_latency(Some(4), lat);
            b.push_flow(10, words(10));
            let mut out = vec![];
            let last = b.take(10, 100, &mut out).unwrap();
            assert_eq!(out, (0..10).collect::<Vec<u32>>(), "latency never drops words");
            (last, b.stall_cycles())
        };
        let (last0, stall0) = drain(0);
        let (last5, stall5) = drain(5);
        // lat 0: waves admit at 100 (t_rel) and 104 (link-rate prev_end
        // dominates the 103 release) → last word at 105.
        // lat 5: waves admit at 105 and 113 (release 108+5 dominates)
        // → last word at 114.
        assert_eq!((last0, last5), (105, 114));
        assert!(stall5 > stall0, "the round-trip is charged as stall cycles");

        // Unbounded endpoints never spend credits, so latency is inert.
        let mut b = EndpointBuf::with_credit_latency(None, 50);
        b.push_flow(10, words(4));
        let mut out = vec![];
        assert_eq!(b.take(4, 10, &mut out), Some(13));
        assert_eq!(b.stall_cycles(), 0);
    }

    /// Latency also gates the one-word pop path: the freed credit
    /// readmits the tail only after the round-trip.
    #[test]
    fn credit_latency_on_pop_word() {
        let mut b = EndpointBuf::with_credit_latency(Some(1), 3);
        b.push_flow(10, words(2));
        assert_eq!(b.pop_word(10), Some(0));
        // Credit freed at 10 returns at 13: word 1 (natural 11) admits at 13.
        assert_eq!(b.pop_word(12), None, "credit still in flight");
        assert_eq!(b.next_word_time(), Some(13));
        assert_eq!(b.pop_word(13), Some(1));
        assert_eq!(b.stall_cycles(), 2);
    }

    #[test]
    fn env_cap_parses_positive_only() {
        // Pure parse behaviour is covered by the filter; exercise the
        // clear/capacity plumbing here.
        let mut b = EndpointBuf::new(Some(8));
        assert_eq!(b.capacity(), Some(8));
        b.push_flow(0, words(12));
        assert_eq!(b.stalled_words(), 4);
        b.clear();
        assert_eq!(b.stalled_words(), 0);
        assert_eq!(b.peak(), 0);
        assert_eq!(b.capacity(), Some(8), "clear keeps the capacity");
    }
}

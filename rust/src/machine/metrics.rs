//! Simulation metrics and run reports (the paper's measurement protocol).

use super::MachineConfig;

/// Counters accumulated during a simulation.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Total events processed.
    pub events: u64,
    /// Total flows injected into the fabric.
    pub flows: u64,
    /// Total wavelets (32-bit words) transported.
    pub wavelets: u64,
    /// Total wavelet-hops (fabric traffic).
    pub wavelet_hops: u64,
    /// Floating-point operations executed (per DSD semantics).
    pub flops: u64,
    /// Local-memory bytes read + written by DSD ops.
    pub mem_bytes: u64,
    /// Fabric on/off-ramp bytes (PE <-> router traffic).
    pub ramp_bytes: u64,
    /// Task activations executed.
    pub task_runs: u64,
    /// DSD operations issued.
    pub dsd_ops: u64,
    /// Busy cycles summed over all PEs (for utilization).
    pub busy_cycles: u64,
    /// Number of PEs that executed at least one task.
    pub active_pes: u64,
    /// Dispatch state-machine invocations (recycled task overhead).
    pub dispatches: u64,
    /// Word-cycles of backpressure delay: for every word admitted late
    /// into a finite endpoint buffer, the cycles between its natural
    /// wire arrival and its actual admission (0 when no capacity is
    /// configured — unbounded endpoints never stall).
    pub stall_cycles: u64,
    /// High-water mark of admitted-but-unconsumed words over all
    /// (PE, color) endpoints — the observable to size
    /// `endpoint_capacity_words` from: any capacity ≥ this value
    /// reproduces the unbounded run bit for bit.
    pub peak_queue_depth: u64,
    /// Fault-effect applications (see [`super::fault`]): one per
    /// send/dispatch a configured fault actually altered — dropped or
    /// delayed deliveries, word corruptions, halted-PE event drops
    /// (counted once per halt). 0 on every clean run.
    pub faults_injected: u64,
}

impl Metrics {
    /// Fold another counter set into this one. Every field except
    /// `peak_queue_depth` is a sum of per-event increments, so
    /// accumulating thread-locally per shard and merging at the epoch
    /// barrier yields exactly the totals a single-threaded run would
    /// have counted (addition commutes; the event multiset is
    /// identical) — the invariant the epoch-parallel simulator's
    /// bit-identical `RunReport` guarantee rests on. `peak_queue_depth`
    /// is a per-endpoint maximum, so it merges by `max` (which also
    /// commutes — endpoints are owned by exactly one shard).
    /// (`active_pes` and `busy_cycles` are additionally recomputed from
    /// per-PE state in the run epilogue, after reassembly.)
    pub fn merge(&mut self, other: &Metrics) {
        self.events += other.events;
        self.flows += other.flows;
        self.wavelets += other.wavelets;
        self.wavelet_hops += other.wavelet_hops;
        self.flops += other.flops;
        self.mem_bytes += other.mem_bytes;
        self.ramp_bytes += other.ramp_bytes;
        self.task_runs += other.task_runs;
        self.dsd_ops += other.dsd_ops;
        self.busy_cycles += other.busy_cycles;
        self.active_pes += other.active_pes;
        self.dispatches += other.dispatches;
        self.stall_cycles += other.stall_cycles;
        self.peak_queue_depth = self.peak_queue_depth.max(other.peak_queue_depth);
        self.faults_injected += other.faults_injected;
    }
}

/// The result of one kernel simulation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunReport {
    pub kernel: String,
    /// Max cycle count over all participating PEs — the paper's
    /// "maximal cycle count among all PEs".
    pub cycles: u64,
    pub metrics: Metrics,
    /// Fabric geometry used.
    pub width: i64,
    pub height: i64,
    /// Resource usage.
    pub colors_used: usize,
    pub task_ids_used: usize,
    pub mem_bytes_used: u32,
}

impl RunReport {
    pub fn runtime_us(&self, cfg: &MachineConfig) -> f64 {
        cfg.cycles_to_us(self.cycles)
    }

    /// Achieved FLOP/s given the machine clock.
    pub fn flops_per_sec(&self, cfg: &MachineConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.metrics.flops as f64 / (self.runtime_us(cfg) * 1e-6)
    }

    /// Simulated events per wall-clock second — the simulator-side
    /// throughput metric tracked by `spada bench --exp sim`.
    pub fn events_per_sec(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        self.metrics.events as f64 / wall_s
    }

    /// Mean PE utilization: busy cycles / (PEs × makespan).
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 || self.metrics.active_pes == 0 {
            return 0.0;
        }
        self.metrics.busy_cycles as f64 / (self.metrics.active_pes as f64 * self.cycles as f64)
    }

    /// Arithmetic intensity w.r.t. local memory traffic (flop/byte).
    pub fn intensity_mem(&self) -> f64 {
        if self.metrics.mem_bytes == 0 {
            return 0.0;
        }
        self.metrics.flops as f64 / self.metrics.mem_bytes as f64
    }

    /// Arithmetic intensity w.r.t. ramp traffic (flop/byte).
    pub fn intensity_ramp(&self) -> f64 {
        if self.metrics.ramp_bytes == 0 {
            return f64::INFINITY;
        }
        self.metrics.flops as f64 / self.metrics.ramp_bytes as f64
    }

    /// The full report as machine-readable JSON (`spada run --json`):
    /// every counter plus the derived runtime/utilization figures.
    /// Hand-rolled with a fixed field order so output is deterministic.
    pub fn to_json(&self, cfg: &MachineConfig) -> String {
        let m = &self.metrics;
        format!(
            "{{\"kernel\":\"{}\",\"cycles\":{},\"width\":{},\"height\":{},\
             \"colors_used\":{},\"task_ids_used\":{},\"mem_bytes_used\":{},\
             \"runtime_us\":{:.3},\"utilization\":{:.4},\"metrics\":{{\
             \"events\":{},\"flows\":{},\"wavelets\":{},\"wavelet_hops\":{},\
             \"flops\":{},\"mem_bytes\":{},\"ramp_bytes\":{},\"task_runs\":{},\
             \"dsd_ops\":{},\"busy_cycles\":{},\"active_pes\":{},\
             \"dispatches\":{},\"stall_cycles\":{},\"peak_queue_depth\":{},\
             \"faults_injected\":{}}}}}\n",
            self.kernel.replace('\\', "\\\\").replace('"', "\\\""),
            self.cycles,
            self.width,
            self.height,
            self.colors_used,
            self.task_ids_used,
            self.mem_bytes_used,
            self.runtime_us(cfg),
            self.utilization(),
            m.events,
            m.flows,
            m.wavelets,
            m.wavelet_hops,
            m.flops,
            m.mem_bytes,
            m.ramp_bytes,
            m.task_runs,
            m.dsd_ops,
            m.busy_cycles,
            m.active_pes,
            m.dispatches,
            m.stall_cycles,
            m.peak_queue_depth,
            m.faults_injected,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pin the merge rule for EVERY field: all counters sum except
    /// `peak_queue_depth`, which is a per-endpoint high-water mark and
    /// merges by max. Exhaustive by construction — the final
    /// whole-struct equality means a new field added with the wrong
    /// rule (or no rule) fails here before it can silently break the
    /// parallel engine's bit-identical-metrics guarantee.
    #[test]
    fn metrics_merge_rule_pinned_for_every_field() {
        let a = Metrics {
            events: 1,
            flows: 2,
            wavelets: 3,
            wavelet_hops: 4,
            flops: 5,
            mem_bytes: 6,
            ramp_bytes: 7,
            task_runs: 8,
            dsd_ops: 9,
            busy_cycles: 10,
            active_pes: 11,
            dispatches: 12,
            stall_cycles: 13,
            peak_queue_depth: 9,
            faults_injected: 14,
        };
        let b = Metrics {
            events: 100,
            flows: 200,
            wavelets: 300,
            wavelet_hops: 400,
            flops: 500,
            mem_bytes: 600,
            ramp_bytes: 700,
            task_runs: 800,
            dsd_ops: 900,
            busy_cycles: 1000,
            active_pes: 1100,
            dispatches: 1200,
            stall_cycles: 1300,
            peak_queue_depth: 3,
            faults_injected: 1400,
        };
        let mut merged = a.clone();
        merged.merge(&b);
        let expect = Metrics {
            events: 101,
            flows: 202,
            wavelets: 303,
            wavelet_hops: 404,
            flops: 505,
            mem_bytes: 606,
            ramp_bytes: 707,
            task_runs: 808,
            dsd_ops: 909,
            busy_cycles: 1010,
            active_pes: 1111,
            dispatches: 1212,
            stall_cycles: 1313,
            peak_queue_depth: 9, // max(9, 3), NOT 12
            faults_injected: 1414,
        };
        assert_eq!(merged, expect, "every field must merge by sum except peak (max)");
        // Max is symmetric: merging the other way picks the same peak.
        let mut rev = b.clone();
        rev.merge(&a);
        assert_eq!(rev, expect, "merge must commute");
        // Merging the identity changes nothing.
        let mut id = a.clone();
        id.merge(&Metrics::default());
        assert_eq!(id, a);
    }

    #[test]
    fn run_report_json_round_trips_every_counter() {
        let r = RunReport {
            kernel: "gemv".into(),
            cycles: 850,
            metrics: Metrics {
                events: 1,
                flows: 2,
                wavelets: 3,
                wavelet_hops: 4,
                flops: 8500,
                mem_bytes: 6,
                ramp_bytes: 7,
                task_runs: 8,
                dsd_ops: 9,
                busy_cycles: 425,
                active_pes: 1,
                dispatches: 12,
                stall_cycles: 13,
                peak_queue_depth: 14,
                faults_injected: 15,
            },
            width: 4,
            height: 4,
            colors_used: 2,
            task_ids_used: 3,
            mem_bytes_used: 64,
        };
        let cfg = MachineConfig::wse2();
        let json = r.to_json(&cfg);
        for key in [
            "\"kernel\":\"gemv\"",
            "\"cycles\":850",
            "\"runtime_us\":1.000",
            "\"utilization\":0.5000",
            "\"stall_cycles\":13",
            "\"peak_queue_depth\":14",
            "\"faults_injected\":15",
            "\"busy_cycles\":425",
            "\"dispatches\":12",
        ] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.ends_with("}\n"));
    }

    #[test]
    fn report_math() {
        let r = RunReport {
            kernel: "k".into(),
            cycles: 850,
            metrics: Metrics { flops: 8500, busy_cycles: 425, active_pes: 1, ..Default::default() },
            width: 1,
            height: 1,
            colors_used: 0,
            task_ids_used: 1,
            mem_bytes_used: 0,
        };
        let cfg = MachineConfig::wse2();
        assert!((r.runtime_us(&cfg) - 1.0).abs() < 1e-9);
        assert!((r.flops_per_sec(&cfg) - 8.5e9).abs() < 1e3);
        assert!((r.utilization() - 0.5).abs() < 1e-9);
    }
}

//! Simulation metrics and run reports (the paper's measurement protocol).

use super::MachineConfig;

/// Counters accumulated during a simulation.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Metrics {
    /// Total events processed.
    pub events: u64,
    /// Total flows injected into the fabric.
    pub flows: u64,
    /// Total wavelets (32-bit words) transported.
    pub wavelets: u64,
    /// Total wavelet-hops (fabric traffic).
    pub wavelet_hops: u64,
    /// Floating-point operations executed (per DSD semantics).
    pub flops: u64,
    /// Local-memory bytes read + written by DSD ops.
    pub mem_bytes: u64,
    /// Fabric on/off-ramp bytes (PE <-> router traffic).
    pub ramp_bytes: u64,
    /// Task activations executed.
    pub task_runs: u64,
    /// DSD operations issued.
    pub dsd_ops: u64,
    /// Busy cycles summed over all PEs (for utilization).
    pub busy_cycles: u64,
    /// Number of PEs that executed at least one task.
    pub active_pes: u64,
    /// Dispatch state-machine invocations (recycled task overhead).
    pub dispatches: u64,
    /// Word-cycles of backpressure delay: for every word admitted late
    /// into a finite endpoint buffer, the cycles between its natural
    /// wire arrival and its actual admission (0 when no capacity is
    /// configured — unbounded endpoints never stall).
    pub stall_cycles: u64,
    /// High-water mark of admitted-but-unconsumed words over all
    /// (PE, color) endpoints — the observable to size
    /// `endpoint_capacity_words` from: any capacity ≥ this value
    /// reproduces the unbounded run bit for bit.
    pub peak_queue_depth: u64,
}

impl Metrics {
    /// Fold another counter set into this one. Every field except
    /// `peak_queue_depth` is a sum of per-event increments, so
    /// accumulating thread-locally per shard and merging at the epoch
    /// barrier yields exactly the totals a single-threaded run would
    /// have counted (addition commutes; the event multiset is
    /// identical) — the invariant the epoch-parallel simulator's
    /// bit-identical `RunReport` guarantee rests on. `peak_queue_depth`
    /// is a per-endpoint maximum, so it merges by `max` (which also
    /// commutes — endpoints are owned by exactly one shard).
    /// (`active_pes` and `busy_cycles` are additionally recomputed from
    /// per-PE state in the run epilogue, after reassembly.)
    pub fn merge(&mut self, other: &Metrics) {
        self.events += other.events;
        self.flows += other.flows;
        self.wavelets += other.wavelets;
        self.wavelet_hops += other.wavelet_hops;
        self.flops += other.flops;
        self.mem_bytes += other.mem_bytes;
        self.ramp_bytes += other.ramp_bytes;
        self.task_runs += other.task_runs;
        self.dsd_ops += other.dsd_ops;
        self.busy_cycles += other.busy_cycles;
        self.active_pes += other.active_pes;
        self.dispatches += other.dispatches;
        self.stall_cycles += other.stall_cycles;
        self.peak_queue_depth = self.peak_queue_depth.max(other.peak_queue_depth);
    }
}

/// The result of one kernel simulation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunReport {
    pub kernel: String,
    /// Max cycle count over all participating PEs — the paper's
    /// "maximal cycle count among all PEs".
    pub cycles: u64,
    pub metrics: Metrics,
    /// Fabric geometry used.
    pub width: i64,
    pub height: i64,
    /// Resource usage.
    pub colors_used: usize,
    pub task_ids_used: usize,
    pub mem_bytes_used: u32,
}

impl RunReport {
    pub fn runtime_us(&self, cfg: &MachineConfig) -> f64 {
        cfg.cycles_to_us(self.cycles)
    }

    /// Achieved FLOP/s given the machine clock.
    pub fn flops_per_sec(&self, cfg: &MachineConfig) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.metrics.flops as f64 / (self.runtime_us(cfg) * 1e-6)
    }

    /// Simulated events per wall-clock second — the simulator-side
    /// throughput metric tracked by `spada bench --exp sim`.
    pub fn events_per_sec(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            return 0.0;
        }
        self.metrics.events as f64 / wall_s
    }

    /// Mean PE utilization: busy cycles / (PEs × makespan).
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 || self.metrics.active_pes == 0 {
            return 0.0;
        }
        self.metrics.busy_cycles as f64 / (self.metrics.active_pes as f64 * self.cycles as f64)
    }

    /// Arithmetic intensity w.r.t. local memory traffic (flop/byte).
    pub fn intensity_mem(&self) -> f64 {
        if self.metrics.mem_bytes == 0 {
            return 0.0;
        }
        self.metrics.flops as f64 / self.metrics.mem_bytes as f64
    }

    /// Arithmetic intensity w.r.t. ramp traffic (flop/byte).
    pub fn intensity_ramp(&self) -> f64 {
        if self.metrics.ramp_bytes == 0 {
            return f64::INFINITY;
        }
        self.metrics.flops as f64 / self.metrics.ramp_bytes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_merge_sums_fields() {
        let mut a = Metrics {
            events: 1,
            flows: 2,
            wavelets: 3,
            stall_cycles: 4,
            peak_queue_depth: 9,
            ..Default::default()
        };
        let b = Metrics {
            events: 10,
            flops: 5,
            dispatches: 7,
            stall_cycles: 6,
            peak_queue_depth: 3,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.events, 11);
        assert_eq!(a.flows, 2);
        assert_eq!(a.wavelets, 3);
        assert_eq!(a.flops, 5);
        assert_eq!(a.dispatches, 7);
        assert_eq!(a.stall_cycles, 10, "stall cycles merge by sum");
        assert_eq!(a.peak_queue_depth, 9, "peak queue depth merges by max");
    }

    #[test]
    fn report_math() {
        let r = RunReport {
            kernel: "k".into(),
            cycles: 850,
            metrics: Metrics { flops: 8500, busy_cycles: 425, active_pes: 1, ..Default::default() },
            width: 1,
            height: 1,
            colors_used: 0,
            task_ids_used: 1,
            mem_bytes_used: 0,
        };
        let cfg = MachineConfig::wse2();
        assert!((r.runtime_us(&cfg) - 1.0).abs() < 1e-9);
        assert!((r.flops_per_sec(&cfg) - 8.5e9).abs() < 1e3);
        assert!((r.utilization() - 0.5).abs() < 1e-9);
    }
}

//! Deterministic fault injection and outcome triage.
//!
//! The real WSE ships with fabricated-defective PEs and links that the
//! platform routes around; every guarantee the static checker makes
//! (routing correctness, deadlock freedom) is only interesting when the
//! fabric can misbehave. This module is the adversary: a seeded,
//! deterministic fault layer that both engines apply at *fixed program
//! points*, so a faulted run — like a clean one — is bit-identical at
//! every `SPADA_THREADS` count.
//!
//! # Fault models
//!
//! | spec                      | effect                                              |
//! |---------------------------|-----------------------------------------------------|
//! | `link(x,y,D):kill@T`      | the link leaving cell (x,y) through D drops every   |
//! |                           | flow whose head word would traverse it at/after T   |
//! | `link(x,y,D):slow@T+N`    | same predicate, but delivery to downstream dests is |
//! |                           | delayed by N cycles instead of dropped              |
//! | `pe(x,y):halt@T`          | the PE processes no task/completion events at/after |
//! |                           | T; arrivals still buffer at its endpoints           |
//! | `flow(x,y,c):corrupt@T`   | one seeded word-flip in the first payload PE (x,y)  |
//! |                           | sends on color c at/after T (fires exactly once)    |
//! | `flow(x,y,c):delay@T+N`   | every delivery of that flow sent at/after T lands N |
//! |                           | cycles late                                         |
//!
//! `D` ∈ {`N`,`E`,`S`,`W`,`R`}; specs are joined with `;` and an
//! optional `seed=K` entry seeds the corruption RNG. The same grammar
//! is accepted by `SPADA_FAULTS`, `spada run --faults`, and
//! [`FaultPlan::parse`], and [`FaultSpec`]'s `Display` round-trips it —
//! the campaign matrix records sites in exactly this syntax so any row
//! can be replayed by hand.
//!
//! # Determinism and the injection points
//!
//! Faults are compiled once per run against the [`RoutingPlan`] into a
//! [`FaultSet`]: per-flow effects (which destinations sit downstream of
//! a dead link, at what send-time threshold) and per-PE halt cycles.
//! The engines consult it at exactly two places — `send_flow` (kill /
//! slow / delay / corrupt, as a pure function of the flow's start time)
//! and event dispatch (halt, as a pure function of `(event kind, PE,
//! time)`). Neither depends on shard layout or wall-clock, so the
//! epoch-parallel engine reproduces the classic engine bit for bit.
//!
//! A fault can remove or postpone arrivals but never create an earlier
//! one, so the clean plan's cross-island lookahead remains a sound
//! lower bound; [`FaultSet::effective_lookahead`] re-derives it anyway
//! (dropping arrivals a fault provably removes for every send), which
//! can only widen epochs — see the method's soundness note.

use super::config::MachineConfig;
use super::metrics::RunReport;
use super::plan::RoutingPlan;
use super::program::Direction;
use super::router::FlowPath;
use super::sim::SimError;
use crate::util::rng::SplitMix64;
use std::fmt;

/// Default corruption-RNG seed (overridden by a `seed=K` spec entry).
pub const DEFAULT_FAULT_SEED: u64 = 0xFA17;

/// Trace-lane kind codes carried by `TraceRecord::Fault`.
pub const FK_LINK_KILL: u8 = 0;
pub const FK_LINK_SLOW: u8 = 1;
pub const FK_PE_HALT: u8 = 2;
pub const FK_CORRUPT: u8 = 3;
pub const FK_DELAY: u8 = 4;
/// Chrome-trace event names, indexed by the `FK_*` codes.
pub const FAULT_KIND_NAMES: [&str; 5] = ["link-kill", "link-slow", "pe-halt", "corrupt", "delay"];

/// One parsed fault, in the grammar documented at module level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    LinkKill { x: i64, y: i64, dir: Direction, at: u64 },
    LinkSlow { x: i64, y: i64, dir: Direction, at: u64, extra: u64 },
    PeHalt { x: i64, y: i64, at: u64 },
    Corrupt { x: i64, y: i64, color: u8, at: u64 },
    Delay { x: i64, y: i64, color: u8, at: u64, extra: u64 },
}

fn dir_char(d: Direction) -> char {
    match d {
        Direction::North => 'N',
        Direction::East => 'E',
        Direction::South => 'S',
        Direction::West => 'W',
        Direction::Ramp => 'R',
    }
}

fn dir_of(s: &str) -> Option<Direction> {
    match s {
        "N" => Some(Direction::North),
        "E" => Some(Direction::East),
        "S" => Some(Direction::South),
        "W" => Some(Direction::West),
        "R" => Some(Direction::Ramp),
        _ => None,
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            FaultSpec::LinkKill { x, y, dir, at } => {
                write!(f, "link({x},{y},{}):kill@{at}", dir_char(dir))
            }
            FaultSpec::LinkSlow { x, y, dir, at, extra } => {
                write!(f, "link({x},{y},{}):slow@{at}+{extra}", dir_char(dir))
            }
            FaultSpec::PeHalt { x, y, at } => write!(f, "pe({x},{y}):halt@{at}"),
            FaultSpec::Corrupt { x, y, color, at } => {
                write!(f, "flow({x},{y},{color}):corrupt@{at}")
            }
            FaultSpec::Delay { x, y, color, at, extra } => {
                write!(f, "flow({x},{y},{color}):delay@{at}+{extra}")
            }
        }
    }
}

/// A full fault configuration: the parsed specs plus the corruption
/// seed. Construction is infallible — `SPADA_FAULTS` parse errors are
/// carried in `invalid` and surfaced loudly when the simulator runs,
/// never silently dropped at config-build time.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
    pub seed: u64,
    /// Parse error from the environment, if any; `Simulator::run`
    /// rejects the run with it.
    pub invalid: Option<String>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { specs: Vec::new(), seed: DEFAULT_FAULT_SEED, invalid: None }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for s in &self.specs {
            if !first {
                f.write_str("; ")?;
            }
            write!(f, "{s}")?;
            first = false;
        }
        if self.seed != DEFAULT_FAULT_SEED {
            if !first {
                f.write_str("; ")?;
            }
            write!(f, "seed={}", self.seed)?;
        }
        Ok(())
    }
}

fn parse_u64(s: &str, what: &str) -> Result<u64, String> {
    s.trim().parse::<u64>().map_err(|_| format!("{what}: `{s}` is not a non-negative integer"))
}

fn parse_i64(s: &str, what: &str) -> Result<i64, String> {
    s.trim().parse::<i64>().map_err(|_| format!("{what}: `{s}` is not an integer"))
}

fn parse_spec(s: &str) -> Result<FaultSpec, String> {
    let (site, action) = s
        .split_once(':')
        .ok_or_else(|| format!("`{s}`: expected SITE:ACTION@T (e.g. link(0,0,E):kill@100)"))?;
    let (verb, when) =
        action.split_once('@').ok_or_else(|| format!("`{s}`: expected ACTION@T"))?;
    let verb = verb.trim();
    let (at, extra) = match when.split_once('+') {
        Some((t, n)) => {
            (parse_u64(t, "fault time")?, Some(parse_u64(n, "fault extra cycles")?))
        }
        None => (parse_u64(when, "fault time")?, None),
    };
    let site = site.trim();
    let (kind, rest) =
        site.split_once('(').ok_or_else(|| format!("`{s}`: expected SITE like link(x,y,D)"))?;
    let args = rest
        .strip_suffix(')')
        .ok_or_else(|| format!("`{s}`: unterminated site argument list"))?;
    let parts: Vec<&str> = args.split(',').map(str::trim).collect();
    let need_extra = |e: Option<u64>| {
        e.ok_or_else(|| format!("`{s}`: {verb} needs `@T+N` (delay amount in cycles)"))
    };
    let no_extra = |e: Option<u64>| match e {
        Some(_) => Err(format!("`{s}`: {verb} takes `@T`, not `@T+N`")),
        None => Ok(()),
    };
    match (kind.trim(), verb) {
        ("link", "kill") | ("link", "slow") => {
            if parts.len() != 3 {
                return Err(format!("`{s}`: link site needs (x,y,DIR)"));
            }
            let x = parse_i64(parts[0], "link x")?;
            let y = parse_i64(parts[1], "link y")?;
            let dir = dir_of(parts[2])
                .ok_or_else(|| format!("`{s}`: direction must be one of N,E,S,W,R"))?;
            if verb == "kill" {
                no_extra(extra)?;
                Ok(FaultSpec::LinkKill { x, y, dir, at })
            } else {
                Ok(FaultSpec::LinkSlow { x, y, dir, at, extra: need_extra(extra)? })
            }
        }
        ("pe", "halt") => {
            if parts.len() != 2 {
                return Err(format!("`{s}`: pe site needs (x,y)"));
            }
            no_extra(extra)?;
            Ok(FaultSpec::PeHalt {
                x: parse_i64(parts[0], "pe x")?,
                y: parse_i64(parts[1], "pe y")?,
                at,
            })
        }
        ("flow", "corrupt") | ("flow", "delay") => {
            if parts.len() != 3 {
                return Err(format!("`{s}`: flow site needs (x,y,color)"));
            }
            let x = parse_i64(parts[0], "flow x")?;
            let y = parse_i64(parts[1], "flow y")?;
            let color = parts[2]
                .parse::<u8>()
                .map_err(|_| format!("`{s}`: color must be a u8"))?;
            if verb == "corrupt" {
                no_extra(extra)?;
                Ok(FaultSpec::Corrupt { x, y, color, at })
            } else {
                Ok(FaultSpec::Delay { x, y, color, at, extra: need_extra(extra)? })
            }
        }
        (k, v) => Err(format!("`{s}`: unknown fault `{k}:{v}` (link:kill, link:slow, pe:halt, flow:corrupt, flow:delay)")),
    }
}

impl FaultPlan {
    /// Parse the `SPADA_FAULTS` grammar: `;`-separated specs plus an
    /// optional `seed=K` entry.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for part in s.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = parse_u64(v, "seed")?;
                continue;
            }
            plan.specs.push(parse_spec(part)?);
        }
        Ok(plan)
    }

    /// A plan holding exactly one spec (the campaign's per-site shape).
    pub fn single(spec: FaultSpec) -> FaultPlan {
        FaultPlan { specs: vec![spec], ..FaultPlan::default() }
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty() && self.invalid.is_none()
    }
}

/// Compiled per-flow fault effects. `kills`/`slows` pair a *start-time
/// threshold* (the earliest flow start whose head word meets the fault:
/// the head traverses a depth-`d` link at `start + d`, so threshold =
/// `T - d`, saturating) with a per-destination mask of which deliveries
/// sit downstream of the faulted link.
#[derive(Clone, Debug, Default)]
pub struct FlowFx {
    pub kills: Vec<(u64, Vec<bool>)>,
    pub slows: Vec<(u64, u64, Vec<bool>)>,
    /// `(T, extra)` — uniform delivery delay for sends at/after `T`.
    pub delay: Option<(u64, u64)>,
    /// `(T, spec index)` — one seeded word-flip, fires once.
    pub corrupt: Option<(u64, u32)>,
}

/// A [`FaultPlan`] compiled against one routing plan: what the engines
/// actually consult. Construction validates sites against the fabric.
#[derive(Clone, Debug)]
pub struct FaultSet {
    pub n_specs: usize,
    pub seed: u64,
    /// `(PE index, spec index, halt cycle)`, sorted by PE index; one
    /// entry per halted PE (earliest halt wins).
    halts: Vec<(u32, u32, u64)>,
    /// Planned-flow index → effects (dense; `None` = flow unaffected).
    fx: Vec<Option<FlowFx>>,
}

/// Walk the route tree backward from `dest` toward the source and
/// report whether the unique upstream chain crosses `(lx, ly, dir)`.
/// `None` when the chain is not uniquely reconstructible (re-converging
/// routes, zero hop latency) — callers treat that conservatively.
fn upstream_crosses(
    path: &FlowPath,
    hop: u64,
    dest: (i64, i64, u64),
    lx: i64,
    ly: i64,
    dir: Direction,
) -> Option<bool> {
    let (mut cx, mut cy, mut cd) = dest;
    loop {
        if cd == 0 {
            return Some(false);
        }
        if hop == 0 {
            return None;
        }
        let mut found = None;
        for l in &path.links {
            let (dx, dy) = l.dir.delta();
            if l.x + dx == cx && l.y + dy == cy && l.depth + hop == cd {
                if found.is_some() {
                    return None;
                }
                found = Some(l);
            }
        }
        let l = found?;
        if l.x == lx && l.y == ly && l.dir == dir {
            return Some(true);
        }
        (cx, cy, cd) = (l.x, l.y, l.depth);
    }
}

impl FaultSet {
    /// Compile a plan. `Ok(None)` when no faults are configured; `Err`
    /// when a spec references a site the fabric/program doesn't have
    /// (loud beats silent for a fault that would never fire).
    pub fn compile(
        fp: &FaultPlan,
        cfg: &MachineConfig,
        plan: &RoutingPlan,
    ) -> Result<Option<FaultSet>, String> {
        if fp.specs.is_empty() {
            return Ok(None);
        }
        let mut fx: Vec<Option<FlowFx>> = vec![None; plan.flows.len()];
        let mut halts: Vec<(u32, u32, u64)> = Vec::new();
        for (si, spec) in fp.specs.iter().enumerate() {
            match *spec {
                FaultSpec::PeHalt { x, y, at } => {
                    let g = plan
                        .pe_index(x, y)
                        .ok_or_else(|| format!("fault {spec}: no PE with code at ({x},{y})"))?;
                    halts.push((g as u32, si as u32, at));
                }
                FaultSpec::LinkKill { x, y, dir, at }
                | FaultSpec::LinkSlow { x, y, dir, at, .. } => {
                    if x < 0 || y < 0 || x >= plan.width || y >= plan.height {
                        return Err(format!(
                            "fault {spec}: cell ({x},{y}) is outside the {}x{} fabric",
                            plan.width, plan.height
                        ));
                    }
                    let slot = ((y * plan.width + x) * 5) as u32 + dir.index() as u32;
                    let extra = match *spec {
                        FaultSpec::LinkSlow { extra, .. } => Some(extra),
                        _ => None,
                    };
                    for (fi, flow) in plan.flows.iter().enumerate() {
                        if flow.error.is_some() {
                            continue;
                        }
                        let Some(&(_, ldepth)) =
                            flow.links.iter().find(|&&(l, _)| l == slot)
                        else {
                            continue;
                        };
                        let Ok(fpath) = &flow.trace else { continue };
                        // Which deliveries sit downstream of the faulted
                        // link? Ambiguous chains count as affected —
                        // dropping/delaying too much is sound (arrivals
                        // only ever get later), delivering through a
                        // dead link would not be.
                        let mask: Vec<bool> = fpath
                            .dests
                            .iter()
                            .map(|&d| {
                                upstream_crosses(fpath, cfg.hop_cycles, d, x, y, dir)
                                    .unwrap_or(true)
                            })
                            .collect();
                        let thr = at.saturating_sub(ldepth);
                        let e = fx[fi].get_or_insert_with(FlowFx::default);
                        match extra {
                            None => e.kills.push((thr, mask)),
                            Some(n) => e.slows.push((thr, n, mask)),
                        }
                    }
                }
                FaultSpec::Corrupt { x, y, color, at }
                | FaultSpec::Delay { x, y, color, at, .. } => {
                    let g = plan
                        .pe_index(x, y)
                        .ok_or_else(|| format!("fault {spec}: no PE with code at ({x},{y})"))?;
                    let fi = plan.flow_index(g, color).ok_or_else(|| {
                        format!("fault {spec}: PE ({x},{y}) sends no flow on color {color}")
                    })?;
                    let e = fx[fi].get_or_insert_with(FlowFx::default);
                    match *spec {
                        FaultSpec::Corrupt { .. } => {
                            if e.corrupt.is_some() {
                                return Err(format!("fault {spec}: duplicate corrupt spec"));
                            }
                            e.corrupt = Some((at, si as u32));
                        }
                        FaultSpec::Delay { extra, .. } => {
                            if e.delay.is_some() {
                                return Err(format!("fault {spec}: duplicate delay spec"));
                            }
                            e.delay = Some((at, extra));
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
        halts.sort_unstable_by_key(|&(g, _, at)| (g, at));
        halts.dedup_by_key(|&mut (g, _, _)| g);
        Ok(Some(FaultSet { n_specs: fp.specs.len(), seed: fp.seed, halts, fx }))
    }

    /// Effects for a planned-flow index, if any.
    #[inline]
    pub fn fx_of(&self, flow: usize) -> Option<&FlowFx> {
        self.fx.get(flow).and_then(|o| o.as_ref())
    }

    /// `(spec index, halt cycle)` when the PE is configured to halt.
    #[inline]
    pub fn halt_of(&self, gix: u32) -> Option<(usize, u64)> {
        self.halts
            .binary_search_by_key(&gix, |&(g, _, _)| g)
            .ok()
            .map(|i| (self.halts[i].1 as usize, self.halts[i].2))
    }

    /// Is the PE halted at time `t`?
    #[inline]
    pub fn halted_at(&self, gix: u32, t: u64) -> bool {
        matches!(self.halt_of(gix), Some((_, at)) if t >= at)
    }

    /// Deterministic corruption: flip one word of `words` in place,
    /// seeded by the fault seed and the flow index (never by time or
    /// shard layout). The high bit is forced into the flip so the
    /// altered word always differs substantially.
    pub fn corrupt_words(&self, flow: usize, words: &mut [u32]) -> usize {
        let mut rng = SplitMix64::new(
            self.seed ^ 0x9E3779B97F4A7C15u64.wrapping_mul(flow as u64 + 1),
        );
        let idx = rng.below(words.len().max(1) as u64) as usize;
        words[idx] ^= (rng.next_u64() as u32) | 0x8000_0000;
        idx
    }

    /// Re-derive the cross-island lookahead under this fault set.
    ///
    /// Soundness: every fault model delays, drops, or value-alters an
    /// arrival — none creates an *earlier* one — so the clean
    /// `plan.lookahead` is already a valid lower bound on faulted
    /// cross-island arrival gaps. The re-derivation can therefore only
    /// *raise* it, by excluding arrivals the fault set provably removes
    /// for every send: destinations downstream of a link killed from
    /// threshold 0, and every flow out of a PE halted at cycle 0
    /// (a halt drops all its task/completion events, so it never
    /// sends). The result is clamped to `>= plan.lookahead`.
    pub fn effective_lookahead(&self, plan: &RoutingPlan, cfg: &MachineConfig) -> u64 {
        let mut min_cross = u64::MAX;
        for (fi, flow) in plan.flows.iter().enumerate() {
            if flow.error.is_some() {
                continue;
            }
            if matches!(self.halt_of(flow.src_pe), Some((_, 0))) {
                continue;
            }
            let src_island = plan.island_of[flow.src_pe as usize];
            let fxe = self.fx_of(fi);
            for (j, &(dst, _, depth)) in flow.dests.iter().enumerate() {
                if plan.island_of[dst as usize] == src_island {
                    continue;
                }
                if let Some(fxe) = fxe {
                    if fxe.kills.iter().any(|(thr, m)| *thr == 0 && m[j]) {
                        continue;
                    }
                }
                min_cross = min_cross.min(depth);
            }
        }
        let rederived = match min_cross {
            u64::MAX => u64::MAX,
            d => d.saturating_add(cfg.hop_cycles),
        };
        rederived.max(plan.lookahead)
    }
}

/// The triage verdict for one (possibly faulted) run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Run completed and every output word matches the clean reference.
    Correct,
    /// Run completed but outputs differ — silent data corruption.
    Sdc { detail: String },
    /// Wedged on credit exhaustion (finite endpoint buffers).
    BufferDeadlock { detail: String },
    /// Wedged on a circular consumer/producer wait.
    CircularWait { detail: String },
    /// Event budget exhausted.
    Runaway { events: u64 },
    /// Wall-clock watchdog fired.
    Timeout { detail: String },
    /// Any other `SimError`.
    Error { detail: String },
}

impl Outcome {
    /// Stable machine-readable label (the campaign JSONL vocabulary).
    pub fn label(&self) -> &'static str {
        match self {
            Outcome::Correct => "correct",
            Outcome::Sdc { .. } => "sdc",
            Outcome::BufferDeadlock { .. } => "buffer-deadlock",
            Outcome::CircularWait { .. } => "circular-wait",
            Outcome::Runaway { .. } => "runaway",
            Outcome::Timeout { .. } => "timeout",
            Outcome::Error { .. } => "error",
        }
    }

    pub fn detail(&self) -> String {
        match self {
            Outcome::Correct => String::new(),
            Outcome::Sdc { detail }
            | Outcome::BufferDeadlock { detail }
            | Outcome::CircularWait { detail }
            | Outcome::Timeout { detail }
            | Outcome::Error { detail } => detail.clone(),
            Outcome::Runaway { events } => format!("event budget exhausted ({events})"),
        }
    }
}

/// First differing output word between a faulted run and the clean
/// reference, for the SDC detail string.
fn first_diff(outs: &[(String, Vec<u32>)], reference: &[(String, Vec<u32>)]) -> String {
    if outs.len() != reference.len() {
        return format!("output arity differs: {} vs {}", outs.len(), reference.len());
    }
    for ((name, a), (rname, b)) in outs.iter().zip(reference) {
        if name != rname {
            return format!("output order differs: {name} vs {rname}");
        }
        if a.len() != b.len() {
            return format!("{name}: length {} vs {}", a.len(), b.len());
        }
        if let Some(i) = (0..a.len()).find(|&i| a[i] != b[i]) {
            return format!("{name}[{i}]: {:#010x} != {:#010x}", a[i], b[i]);
        }
    }
    "outputs differ".into()
}

/// Classify one run against its clean reference. Every `SimError` path
/// maps to a verdict — a faulted run is never "unclassified": either it
/// completed (correct or SDC by output diff), or the error itself is
/// the classification, cross-referencing the flow-control report via
/// [`crate::analysis::runtime_deadlock_kind`].
pub fn classify(
    result: &Result<RunReport, SimError>,
    outputs: &[(String, Vec<u32>)],
    reference: &[(String, Vec<u32>)],
) -> Outcome {
    match result {
        Ok(_) => {
            if outputs == reference {
                Outcome::Correct
            } else {
                Outcome::Sdc { detail: first_diff(outputs, reference) }
            }
        }
        Err(SimError::Deadlock(msg)) => {
            match crate::analysis::runtime_deadlock_kind(msg) {
                crate::analysis::DiagKind::BufferDeadlock => {
                    Outcome::BufferDeadlock { detail: msg.clone() }
                }
                _ => Outcome::CircularWait { detail: msg.clone() },
            }
        }
        Err(SimError::Runaway(n)) => Outcome::Runaway { events: *n },
        Err(e @ SimError::Timeout { .. }) => Outcome::Timeout { detail: e.to_string() },
        Err(e) => Outcome::Error { detail: e.to_string() },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::router::PathLink;

    #[test]
    fn specs_round_trip_through_display() {
        let src = "link(1,2,E):kill@100; link(0,0,R):slow@5+3; pe(3,1):halt@0; \
                   flow(2,2,7):corrupt@40; flow(0,1,3):delay@9+16; seed=99";
        let plan = FaultPlan::parse(src).unwrap();
        assert_eq!(plan.specs.len(), 5);
        assert_eq!(plan.seed, 99);
        let printed = plan.to_string();
        let again = FaultPlan::parse(&printed).unwrap();
        assert_eq!(plan, again, "Display must round-trip: {printed}");
    }

    #[test]
    fn default_seed_is_omitted_from_display() {
        let plan = FaultPlan::single(FaultSpec::PeHalt { x: 0, y: 0, at: 7 });
        assert_eq!(plan.to_string(), "pe(0,0):halt@7");
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "link(0,0,E)",             // no action
            "link(0,0):kill@5",        // missing direction
            "link(0,0,Q):kill@5",      // bad direction
            "link(0,0,E):kill@5+2",    // kill takes no extra
            "link(0,0,E):slow@5",      // slow needs extra
            "pe(0):halt@5",            // pe needs (x,y)
            "flow(0,0,300):corrupt@5", // color out of u8 range
            "pe(0,0):explode@5",       // unknown verb
            "seed=banana",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` must not parse");
        }
        // Empty and whitespace-only plans are valid and empty.
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse(" ; ;; ").unwrap().is_empty());
    }

    /// A 3-hop eastward chain: source (0,0) → dests at (1,0) and (2,0).
    fn chain_path() -> FlowPath {
        FlowPath {
            links: vec![
                PathLink { x: 0, y: 0, dir: Direction::East, depth: 0 },
                PathLink { x: 1, y: 0, dir: Direction::East, depth: 1 },
            ],
            dests: vec![(1, 0, 1), (2, 0, 2)],
        }
    }

    #[test]
    fn upstream_walk_separates_dests_by_link() {
        let p = chain_path();
        // The (0,0)->E link feeds both dests.
        assert_eq!(upstream_crosses(&p, 1, (1, 0, 1), 0, 0, Direction::East), Some(true));
        assert_eq!(upstream_crosses(&p, 1, (2, 0, 2), 0, 0, Direction::East), Some(true));
        // The (1,0)->E link feeds only the far dest.
        assert_eq!(upstream_crosses(&p, 1, (1, 0, 1), 1, 0, Direction::East), Some(false));
        assert_eq!(upstream_crosses(&p, 1, (2, 0, 2), 1, 0, Direction::East), Some(true));
        // Zero hop latency is ambiguous — conservative None.
        assert_eq!(upstream_crosses(&p, 0, (2, 0, 2), 1, 0, Direction::East), None);
    }

    #[test]
    fn corruption_is_deterministic_and_changes_a_word() {
        let fs = FaultSet { n_specs: 1, seed: 7, halts: vec![], fx: vec![] };
        let mut a = vec![0u32; 8];
        let mut b = vec![0u32; 8];
        let ia = fs.corrupt_words(3, &mut a);
        let ib = fs.corrupt_words(3, &mut b);
        assert_eq!((ia, &a), (ib, &b), "same seed + flow index → same flip");
        assert_ne!(a[ia], 0, "the flipped word must change");
        assert!(a[ia] & 0x8000_0000 != 0, "high bit forced into the flip");
        let mut c = vec![0u32; 8];
        fs.corrupt_words(4, &mut c);
        assert_ne!((ia, a), (ia, c), "different flow index → different flip");
    }

    #[test]
    fn outcome_labels_are_stable() {
        let cases: Vec<(Outcome, &str)> = vec![
            (Outcome::Correct, "correct"),
            (Outcome::Sdc { detail: String::new() }, "sdc"),
            (Outcome::BufferDeadlock { detail: String::new() }, "buffer-deadlock"),
            (Outcome::CircularWait { detail: String::new() }, "circular-wait"),
            (Outcome::Runaway { events: 1 }, "runaway"),
            (Outcome::Timeout { detail: String::new() }, "timeout"),
            (Outcome::Error { detail: String::new() }, "error"),
        ];
        for (o, want) in cases {
            assert_eq!(o.label(), want);
        }
    }

    #[test]
    fn classify_splits_deadlocks_by_flow_control_report() {
        let reference: Vec<(String, Vec<u32>)> = vec![("y".into(), vec![1, 2, 3])];
        let buf = Err(SimError::Deadlock("endpoint full (8/8 words): 4 stalled".into()));
        assert_eq!(classify(&buf, &[], &reference).label(), "buffer-deadlock");
        let circ = Err(SimError::Deadlock("PE (1,0) waiting for 4 more wavelets".into()));
        assert_eq!(classify(&circ, &[], &reference).label(), "circular-wait");
        let run = Err(SimError::Runaway(9));
        assert_eq!(classify(&run, &[], &reference).label(), "runaway");
    }
}

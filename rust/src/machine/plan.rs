//! Precompiled routing and execution plans — the compile-time half of
//! the flat-memory simulator core.
//!
//! The WSE-2 hardware resolves *nothing* at runtime: routes are burned
//! into router registers, task tables into sequencer state, and colors
//! into fixed virtual-channel slots before the first wavelet moves.
//! [`RoutingPlan`] mirrors that split for the simulator: everything
//! that is a pure function of the loaded [`MachineProgram`] and the
//! [`MachineConfig`] is resolved once at `Simulator::new` time, so the
//! event loop is pure dense-array arithmetic:
//!
//! - **Dense geometry.** `pe_at` maps row-major grid cells to PE
//!   indices (replacing a `HashMap<(i64,i64),u32>`), and every flow's
//!   links are pre-flattened to indices into a dense link-occupancy
//!   array (`(y·width + x)·5 + direction`).
//! - **Precompiled flows.** For every (source PE, color) pair that any
//!   task can inject on, the full multicast path is traced via
//!   [`trace_route`] up front: link indices with hop depths, and
//!   destination PEs resolved to (PE index, endpoint slot, depth)
//!   triples. Route errors are stored per flow and surfaced only if the
//!   flow is actually sent, preserving the lazy-trace semantics of the
//!   original simulator (a guarded producer on an edge PE that never
//!   fires must not fail the whole run).
//! - **Color→slot tables.** Each PE class gets a compact endpoint slot
//!   per color it consumes or receives (colors are ≤ 24 per the
//!   hardware budget), so endpoint access is two array indexes instead
//!   of a `HashMap<u8, _>` probe.
//! - **Compiled task bodies.** Task bodies are lowered to [`POp`]s:
//!   completion-action lists are interned into one action table
//!   (`EventKind::Complete` carries a `u32` id, keeping heap events
//!   `Copy`), action targets are pre-resolved from hardware task IDs to
//!   task indices, and fabric-in operations reference a per-class
//!   consume-template table so issuing a microthread never clones the
//!   operation.
//!
//! The static checker ([`crate::analysis::flowgraph`]) reads paths out
//! of the *same instance* the simulator executes from: `kernels::compile`
//! builds one plan per compiled kernel and threads it through the
//! checker, the [`crate::kernels::CompiledKernel`] it returns, and
//! [`crate::machine::Simulator::with_plan`] — so the simulator and the
//! checker cannot disagree about route geometry, and a checked run
//! traces every route exactly once.

use super::config::MachineConfig;
use super::program::{
    DsdKind, DsdOp, DsdRef, Dtype, MOp, MachineProgram, SExpr, TaskAction, TaskActionKind,
    TaskKind,
};
use super::router::{trace_route, FlowPath, RouteError};
use super::vecop::{classify_vec, VecOp};
use std::collections::BTreeSet;

/// Sentinel for "no entry" in `u32` index tables.
pub const NONE_U32: u32 = u32::MAX;
/// Sentinel for "no endpoint slot".
pub const SLOT_NONE: u8 = u8::MAX;
/// Sentinel for "no task".
pub const TASK_NONE: u16 = u16::MAX;
/// The interned id of the empty completion-action list.
pub const ACTIONS_EMPTY: u32 = 0;

/// A pre-resolved task-control action: like
/// [`crate::machine::TaskAction`] but with the hardware task ID already
/// resolved to a task index in its class (or [`TASK_NONE`] when the ID
/// names no task — matching the original silently-ignored semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PAction {
    pub kind: TaskActionKind,
    pub task_ix: u16,
    pub set_reg: Option<(u8, i64)>,
}

/// A compiled DSD operation: same payload as [`DsdOp`], plus the
/// plan-resolved pieces the hot loop needs without lookups.
#[derive(Clone, Debug)]
pub struct PDsd {
    pub kind: DsdKind,
    pub dst: DsdRef,
    pub src0: Option<DsdRef>,
    pub src1: Option<DsdRef>,
    pub scalar: Option<SExpr>,
    pub is_async: bool,
    /// Interned completion-action list ([`ACTIONS_EMPTY`] = none).
    pub actions: u32,
    /// Endpoint slot of the fabric-in operand ([`SLOT_NONE`] = no
    /// fabric-in source).
    pub fab_slot: u8,
    /// Index into the class's consume-template table (valid iff
    /// `fab_slot != SLOT_NONE`).
    pub consume_ix: u32,
    /// Static batched-execution verdict (see [`crate::machine::vecop`]):
    /// [`VecOp::Map`]/[`VecOp::Fold`] operations run as single slice
    /// passes when the runtime admission check also passes; everything
    /// else (and every inadmissible instance) takes the per-element
    /// interpreter.
    pub vec: VecOp,
}

/// Compiled machine operations — [`MOp`] with plan-resolved actions.
#[derive(Clone, Debug)]
pub enum POp {
    SetReg { reg: u8, val: SExpr },
    Store { addr: SExpr, ty: Dtype, val: SExpr },
    Dsd(PDsd),
    Control(PAction),
    If { cond: SExpr, then_ops: Vec<POp>, else_ops: Vec<POp> },
    For { reg: u8, start: SExpr, stop: SExpr, step: SExpr, body: Vec<POp> },
    Halt,
    Trace(String),
}

/// Compiled task flavor (data-task colors resolved to endpoint slots).
#[derive(Clone, Copy, Debug)]
pub enum PTaskKind {
    Local,
    Data { slot: u8, wavelet_reg: u8 },
}

/// One compiled task.
#[derive(Clone, Debug)]
pub struct PTask {
    pub kind: PTaskKind,
    pub initially_active: bool,
    pub initially_blocked: bool,
    pub body: Vec<POp>,
}

/// Per-class compile results.
#[derive(Clone, Debug, Default)]
pub struct ClassPlan {
    /// color → endpoint slot (len = `RoutingPlan::ncolors`).
    pub color_slot: Vec<u8>,
    /// endpoint slot → color.
    pub slot_color: Vec<u8>,
    /// endpoint slot → data-task index bound to that color.
    pub data_task_of_slot: Vec<u16>,
    /// hardware task ID → task index (len 256; first definition wins,
    /// matching the original linear `position()` resolution).
    pub task_by_id: Vec<u16>,
    /// Task indices sorted by hardware ID — the scheduler scan order.
    pub order: Vec<u16>,
    /// task index → rank in `order` (bit position in the ready mask).
    pub rank_of: Vec<u8>,
    /// Resolved entry-task indices.
    pub entry: Vec<u16>,
    /// Compiled tasks, parallel to `prog.classes[ci].tasks`.
    pub tasks: Vec<PTask>,
    /// Fabric-in consume templates referenced by [`PDsd::consume_ix`].
    pub consumes: Vec<PDsd>,
}

/// Why a planned flow cannot be sent (surfaced only on first use).
#[derive(Clone, Debug)]
pub enum FlowError {
    Route(RouteError),
    NoDest,
    NoCode { x: i64, y: i64 },
}

/// One pre-traced (source PE, color) flow.
#[derive(Clone, Debug)]
pub struct PlannedFlow {
    pub src: (i64, i64),
    /// Dense index of the source PE (the flow's injection point).
    pub src_pe: u32,
    pub color: u8,
    /// Raw trace result — shared verbatim with the static checker.
    pub trace: Result<FlowPath, RouteError>,
    /// Set when sending on this flow must fail.
    pub error: Option<FlowError>,
    /// (dense link index, hop depth) per occupied link.
    pub links: Vec<(u32, u64)>,
    /// (destination PE index, destination endpoint slot, hop depth).
    pub dests: Vec<(u32, u8, u64)>,
}

/// One planned PE.
#[derive(Clone, Copy, Debug)]
pub struct PlanPe {
    pub x: i64,
    pub y: i64,
    pub class: usize,
}

/// The complete precompiled plan for one (program, machine) pair.
pub struct RoutingPlan {
    pub width: i64,
    pub height: i64,
    /// Color-table dimension (≥ `cfg.max_colors`, covering every color
    /// the program references, even out-of-range ones).
    pub ncolors: usize,
    /// Row-major (y·width + x) → PE index ([`NONE_U32`] = no code).
    pub pe_at: Vec<u32>,
    /// PE list in class-major order (the simulator's PE indexing).
    pub pes: Vec<PlanPe>,
    /// (pe index · ncolors + color) → index into `flows`.
    pub flow_of: Vec<u32>,
    pub flows: Vec<PlannedFlow>,
    pub classes: Vec<ClassPlan>,
    /// Interned completion-action lists; id [`ACTIONS_EMPTY`] is `[]`.
    pub actions: Vec<Vec<PAction>>,
    /// Count of distinct colors referenced (the run-report metric,
    /// precomputed instead of clone+sort+dedup per run).
    pub colors_used: usize,
    /// PE → link-sharing island (see [`RoutingPlan::build`]): two
    /// source PEs whose planned flows can occupy the same physical
    /// link must arbitrate it in event order, so the epoch-parallel
    /// simulator keeps every such group of PEs inside one shard.
    /// Island ids are compact (`0..n_islands`) and assigned in dense
    /// PE order, so the partition is deterministic.
    pub island_of: Vec<u32>,
    /// Number of link-sharing islands (1 = no parallelism available).
    pub n_islands: usize,
    /// Conservative cross-island lookahead in cycles: every flow
    /// arrival whose destination lies in a different island lands at
    /// least this many cycles after the event that sent it (arrival =
    /// send time + hop depth + `hop_cycles`, and `send_flow` never
    /// starts a flow before the current event time). `u64::MAX` when
    /// no flow ever crosses islands — each island then runs to
    /// completion in a single epoch.
    pub lookahead: u64,
    /// Defects that make the program unrunnable (the simulator rejects
    /// them at construction; the static checker reports its own).
    pub build_errors: Vec<String>,
}

impl RoutingPlan {
    /// Approximate resident size in bytes: the dense tables that scale
    /// with the grid, counted at their element sizes. Heap owned by
    /// nested element fields is not walked — this is the budget
    /// heuristic the fleet plan cache charges entries with
    /// ([`crate::machine::CacheBudget`]), not an allocator audit.
    pub fn approx_bytes(&self) -> u64 {
        use std::mem::size_of;
        let nested_actions: usize = self
            .actions
            .iter()
            .map(|a| a.len() * size_of::<PAction>() + size_of::<Vec<PAction>>())
            .sum();
        (size_of::<RoutingPlan>()
            + self.pe_at.len() * size_of::<u32>()
            + self.pes.len() * size_of::<PlanPe>()
            + self.flow_of.len() * size_of::<u32>()
            + self.flows.len() * size_of::<PlannedFlow>()
            + self.classes.len() * size_of::<ClassPlan>()
            + nested_actions
            + self.island_of.len() * size_of::<u32>()
            + self.build_errors.iter().map(|e| e.len()).sum::<usize>()) as u64
    }
}

/// Union-find `find` with path halving (roots are self-parents).
fn uf_find(parent: &mut [u32], mut a: u32) -> u32 {
    while parent[a as usize] != a {
        let grand = parent[parent[a as usize] as usize];
        parent[a as usize] = grand;
        a = grand;
    }
    a
}

/// Union two sets; the smaller root index wins, so the partition is
/// independent of union order.
fn uf_union(parent: &mut [u32], a: u32, b: u32) {
    let (ra, rb) = (uf_find(parent, a), uf_find(parent, b));
    if ra != rb {
        parent[ra.max(rb) as usize] = ra.min(rb);
    }
}

/// Per-class color usage discovered by scanning task bodies.
#[derive(Default)]
struct ClassColors {
    produced: BTreeSet<u8>,
    consumed: BTreeSet<u8>,
}

fn scan_colors(ops: &[MOp], colors: &mut ClassColors) {
    for op in ops {
        match op {
            MOp::Dsd(d) => {
                if let DsdRef::FabOut { color, .. } = &d.dst {
                    colors.produced.insert(*color);
                }
                for s in [&d.src0, &d.src1] {
                    if let Some(DsdRef::FabIn { color, .. }) = s {
                        colors.consumed.insert(*color);
                    }
                }
            }
            MOp::If { then_ops, else_ops, .. } => {
                scan_colors(then_ops, colors);
                scan_colors(else_ops, colors);
            }
            MOp::For { body, .. } => scan_colors(body, colors),
            _ => {}
        }
    }
}

/// Body compiler state shared across one class.
struct BodyCompiler<'a> {
    color_slot: &'a [u8],
    task_by_id: &'a [u16],
    actions: &'a mut Vec<Vec<PAction>>,
    consumes: &'a mut Vec<PDsd>,
}

impl<'a> BodyCompiler<'a> {
    fn resolve_action(&self, a: &TaskAction) -> PAction {
        PAction { kind: a.kind, task_ix: self.task_by_id[a.task as usize], set_reg: a.set_reg }
    }

    fn intern(&mut self, list: Vec<PAction>) -> u32 {
        if let Some(i) = self.actions.iter().position(|l| *l == list) {
            i as u32
        } else {
            self.actions.push(list);
            (self.actions.len() - 1) as u32
        }
    }

    fn compile_dsd(&mut self, d: &DsdOp) -> PDsd {
        let resolved: Vec<PAction> = d.on_complete.iter().map(|a| self.resolve_action(a)).collect();
        let actions = self.intern(resolved);
        let fab_slot = match (&d.src0, &d.src1) {
            (Some(DsdRef::FabIn { color, .. }), _) | (_, Some(DsdRef::FabIn { color, .. })) => {
                self.color_slot[*color as usize]
            }
            _ => SLOT_NONE,
        };
        let mut p = PDsd {
            kind: d.kind,
            dst: d.dst.clone(),
            src0: d.src0.clone(),
            src1: d.src1.clone(),
            scalar: d.scalar.clone(),
            is_async: d.is_async,
            actions,
            fab_slot,
            consume_ix: NONE_U32,
            vec: classify_vec(&d.dst, &d.src0, &d.src1),
        };
        if fab_slot != SLOT_NONE {
            p.consume_ix = self.consumes.len() as u32;
            self.consumes.push(p.clone());
        }
        p
    }

    fn compile_ops(&mut self, ops: &[MOp]) -> Vec<POp> {
        ops.iter()
            .map(|op| match op {
                MOp::SetReg { reg, val } => POp::SetReg { reg: *reg, val: val.clone() },
                MOp::Store { addr, ty, val } => {
                    POp::Store { addr: addr.clone(), ty: *ty, val: val.clone() }
                }
                MOp::Dsd(d) => POp::Dsd(self.compile_dsd(d)),
                MOp::Control(a) => POp::Control(self.resolve_action(a)),
                MOp::If { cond, then_ops, else_ops } => POp::If {
                    cond: cond.clone(),
                    then_ops: self.compile_ops(then_ops),
                    else_ops: self.compile_ops(else_ops),
                },
                MOp::For { reg, start, stop, step, body } => POp::For {
                    reg: *reg,
                    start: start.clone(),
                    stop: stop.clone(),
                    step: step.clone(),
                    body: self.compile_ops(body),
                },
                MOp::Halt => POp::Halt,
                MOp::Trace(s) => POp::Trace(s.clone()),
            })
            .collect()
    }
}

impl RoutingPlan {
    /// Build the full plan. Never fails: defects that make the program
    /// unrunnable are collected in `build_errors` (the simulator turns
    /// the first into a [`crate::machine::SimError`]; the static
    /// checker reports its own diagnostics and ignores them).
    ///
    /// One plan instance per compiled kernel: `kernels::compile` builds
    /// it, hands the same instance to the static checker
    /// ([`crate::analysis::check_with_plan`]), and returns it inside
    /// [`crate::kernels::CompiledKernel`] for
    /// [`crate::machine::Simulator::with_plan`] — routes are traced
    /// exactly once per (program, machine) pair.
    pub fn build(prog: &MachineProgram, cfg: &MachineConfig) -> RoutingPlan {
        let (width, height) = (cfg.width, cfg.height);
        let mut build_errors: Vec<String> = vec![];

        // --- PE enumeration: identical order to the simulator's ---
        let cells = cfg.grid_cells();
        let mut pe_at = vec![NONE_U32; cells];
        let mut pes: Vec<PlanPe> = vec![];
        for (ci, class) in prog.classes.iter().enumerate() {
            for g in &class.subgrids {
                for (x, y) in g.iter() {
                    if !cfg.in_bounds(x, y) {
                        continue; // out-of-fabric: a validation error
                    }
                    let cell = (y * width + x) as usize;
                    if pe_at[cell] != NONE_U32 {
                        continue; // class overlap: a validation error
                    }
                    pe_at[cell] = pes.len() as u32;
                    pes.push(PlanPe { x, y, class: ci });
                }
            }
        }

        // --- color dimension + per-class produced/consumed sets ---
        let mut maxc: u16 = cfg.max_colors as u16;
        for r in &prog.routes {
            maxc = maxc.max(r.color as u16 + 1);
        }
        for c in &prog.colors_used {
            maxc = maxc.max(*c as u16 + 1);
        }
        let mut scans: Vec<ClassColors> = Vec::with_capacity(prog.classes.len());
        for class in &prog.classes {
            let mut colors = ClassColors::default();
            for t in &class.tasks {
                if let TaskKind::Data { color, .. } = &t.kind {
                    colors.consumed.insert(*color);
                }
                scan_colors(&t.body, &mut colors);
            }
            for c in colors.produced.iter().chain(colors.consumed.iter()) {
                maxc = maxc.max(*c as u16 + 1);
            }
            scans.push(colors);
        }
        let ncolors = maxc as usize;

        // --- trace every (source PE, produced color) flow once ---
        let mut flow_of = vec![NONE_U32; pes.len() * ncolors];
        let mut flows: Vec<PlannedFlow> = vec![];
        let mut delivered: Vec<BTreeSet<u8>> = vec![BTreeSet::new(); prog.classes.len()];
        for (pi, pe) in pes.iter().enumerate() {
            for &color in &scans[pe.class].produced {
                let key = pi * ncolors + color as usize;
                if flow_of[key] != NONE_U32 {
                    continue;
                }
                let trace = trace_route(prog, cfg, color, pe.x, pe.y);
                let mut flow = PlannedFlow {
                    src: (pe.x, pe.y),
                    src_pe: pi as u32,
                    color,
                    trace,
                    error: None,
                    links: vec![],
                    dests: vec![],
                };
                match &flow.trace {
                    Err(e) => flow.error = Some(FlowError::Route(e.clone())),
                    Ok(path) => {
                        if path.dests.is_empty() {
                            flow.error = Some(FlowError::NoDest);
                        }
                        for (dx, dy, depth) in &path.dests {
                            if flow.error.is_some() {
                                break;
                            }
                            let cell = (dy * width + dx) as usize;
                            let dst = if cfg.in_bounds(*dx, *dy) { pe_at[cell] } else { NONE_U32 };
                            if dst == NONE_U32 {
                                flow.error = Some(FlowError::NoCode { x: *dx, y: *dy });
                                break;
                            }
                            delivered[pes[dst as usize].class].insert(color);
                            // Destination slot resolved after slot assignment.
                            flow.dests.push((dst, SLOT_NONE, *depth));
                        }
                        if flow.error.is_none() {
                            flow.links = path
                                .links
                                .iter()
                                .map(|l| {
                                    (((l.y * width + l.x) * 5) as u32 + l.dir.index() as u32, l.depth)
                                })
                                .collect();
                        } else {
                            flow.dests.clear();
                        }
                    }
                }
                flow_of[key] = flows.len() as u32;
                flows.push(flow);
            }
        }

        // --- per-class slot tables + task tables + compiled bodies ---
        let mut actions: Vec<Vec<PAction>> = vec![vec![]]; // id 0 = empty
        let mut classes: Vec<ClassPlan> = Vec::with_capacity(prog.classes.len());
        for (ci, class) in prog.classes.iter().enumerate() {
            let mut cp = ClassPlan::default();

            // Endpoint slots: every color the class consumes or receives.
            let mut endpoint_colors: BTreeSet<u8> = scans[ci].consumed.clone();
            endpoint_colors.extend(delivered[ci].iter().copied());
            if endpoint_colors.len() >= SLOT_NONE as usize {
                build_errors.push(format!(
                    "class {}: {} endpoint colors exceed the plan's slot budget",
                    class.name,
                    endpoint_colors.len()
                ));
                // Keep `classes` index-parallel to `prog.classes`; the
                // build error stops the simulator from ever running it.
                classes.push(ClassPlan::default());
                continue;
            }
            cp.color_slot = vec![SLOT_NONE; ncolors];
            for (slot, color) in endpoint_colors.iter().enumerate() {
                cp.color_slot[*color as usize] = slot as u8;
                cp.slot_color.push(*color);
            }
            cp.data_task_of_slot = vec![TASK_NONE; cp.slot_color.len()];

            // Task tables.
            cp.task_by_id = vec![TASK_NONE; 256];
            for (ti, t) in class.tasks.iter().enumerate() {
                if cp.task_by_id[t.hw_id as usize] == TASK_NONE {
                    cp.task_by_id[t.hw_id as usize] = ti as u16;
                }
            }
            let mut order: Vec<u16> = (0..class.tasks.len() as u16).collect();
            order.sort_by_key(|ti| class.tasks[*ti as usize].hw_id);
            cp.rank_of = vec![0u8; class.tasks.len()];
            for (rank, ti) in order.iter().enumerate() {
                cp.rank_of[*ti as usize] = rank as u8;
            }
            cp.order = order;
            for id in &class.entry_tasks {
                let ti = cp.task_by_id[*id as usize];
                if ti == TASK_NONE {
                    build_errors
                        .push(format!("class {}: entry task id {} undefined", class.name, id));
                } else {
                    cp.entry.push(ti);
                }
            }

            // The scheduler's ready mask is a u32 over scheduler ranks.
            // Post-validation this cannot trip (hardware task IDs are
            // unique and < 28), but guard it so an unvalidated program
            // can never alias two tasks onto one bit.
            let mask_ok = class.tasks.len() <= 32;
            if !mask_ok {
                build_errors.push(format!(
                    "class {}: {} tasks exceed the 32-task scheduler mask",
                    class.name,
                    class.tasks.len()
                ));
            }

            // Compile bodies.
            let mut consumes: Vec<PDsd> = vec![];
            if mask_ok {
                for (ti, t) in class.tasks.iter().enumerate() {
                    let kind = match &t.kind {
                        TaskKind::Local => PTaskKind::Local,
                        TaskKind::Data { color, wavelet_reg } => {
                            let slot = cp.color_slot[*color as usize];
                            // One data task per color is guaranteed by
                            // validation (data task ID == color, IDs
                            // unique); first-wins matches the original
                            // linear scan for unvalidated programs.
                            if cp.data_task_of_slot[slot as usize] == TASK_NONE {
                                cp.data_task_of_slot[slot as usize] = ti as u16;
                            }
                            PTaskKind::Data { slot, wavelet_reg: *wavelet_reg }
                        }
                    };
                    let body = {
                        let mut bc = BodyCompiler {
                            color_slot: &cp.color_slot,
                            task_by_id: &cp.task_by_id,
                            actions: &mut actions,
                            consumes: &mut consumes,
                        };
                        bc.compile_ops(&t.body)
                    };
                    cp.tasks.push(PTask {
                        kind,
                        initially_active: t.initially_active,
                        initially_blocked: t.initially_blocked,
                        body,
                    });
                }
            }
            cp.consumes = consumes;
            classes.push(cp);
        }

        // --- resolve destination endpoint slots (needs slot tables) ---
        for flow in &mut flows {
            for d in &mut flow.dests {
                let ci = pes[d.0 as usize].class;
                let slots = &classes[ci].color_slot;
                d.1 = slots.get(flow.color as usize).copied().unwrap_or(SLOT_NONE);
            }
        }

        // --- link-sharing islands + cross-island lookahead ---
        // Union-find over flow sources: any two PEs whose planned flows
        // occupy a common link contend for it (wormhole arbitration is
        // event-order-dependent), so the parallel simulator must step
        // them in one shard. Destinations do not union — arrivals cross
        // shard boundaries through the epoch barrier. Erroneous flows
        // never touch a link (send_flow fails before arbitration).
        let mut parent: Vec<u32> = (0..pes.len() as u32).collect();
        let mut link_src: Vec<u32> = vec![NONE_U32; cfg.link_slots()];
        for flow in &flows {
            if flow.error.is_some() {
                continue;
            }
            for &(li, _) in &flow.links {
                let owner = link_src[li as usize];
                if owner == NONE_U32 {
                    link_src[li as usize] = flow.src_pe;
                } else {
                    uf_union(&mut parent, owner, flow.src_pe);
                }
            }
        }
        let mut island_of = vec![0u32; pes.len()];
        let mut island_id = vec![NONE_U32; pes.len()];
        let mut n_islands = 0usize;
        for p in 0..pes.len() {
            let root = uf_find(&mut parent, p as u32) as usize;
            if island_id[root] == NONE_U32 {
                island_id[root] = n_islands as u32;
                n_islands += 1;
            }
            island_of[p] = island_id[root];
        }
        // Minimum hop depth over deliveries that leave their island.
        // Arrival events fire at send_start + depth + hop_cycles with
        // send_start >= the sending event's time, so depth + hop_cycles
        // lower-bounds every cross-island latency.
        let mut min_cross = u64::MAX;
        for flow in &flows {
            if flow.error.is_some() {
                continue;
            }
            for &(dst, _, depth) in &flow.dests {
                if island_of[dst as usize] != island_of[flow.src_pe as usize] {
                    min_cross = min_cross.min(depth);
                }
            }
        }
        let lookahead =
            if min_cross == u64::MAX { u64::MAX } else { min_cross + cfg.hop_cycles };

        RoutingPlan {
            width,
            height,
            ncolors,
            pe_at,
            pes,
            flow_of,
            flows,
            classes,
            actions,
            colors_used: prog.distinct_colors().len(),
            island_of,
            n_islands,
            lookahead,
            build_errors,
        }
    }

    /// Dense PE lookup.
    pub fn pe_index(&self, x: i64, y: i64) -> Option<usize> {
        if x < 0 || x >= self.width || y < 0 || y >= self.height {
            return None;
        }
        let v = self.pe_at[(y * self.width + x) as usize];
        if v == NONE_U32 {
            None
        } else {
            Some(v as usize)
        }
    }

    /// Flow index for a (PE index, color) injection point, if planned.
    pub fn flow_index(&self, pe: usize, color: u8) -> Option<usize> {
        let v = self.flow_of[pe * self.ncolors + color as usize];
        if v == NONE_U32 {
            None
        } else {
            Some(v as usize)
        }
    }

    /// The traced path for a flow injected at `(x, y)` on `color`, if
    /// any task there can produce it — the shared route source for the
    /// static checker.
    pub fn path(&self, x: i64, y: i64, color: u8) -> Option<&Result<FlowPath, RouteError>> {
        let pi = self.pe_index(x, y)?;
        self.flow_index(pi, color).map(|fi| &self.flows[fi].trace)
    }

    /// Planned flows that deliver to a (dense PE index, endpoint slot).
    /// Cold-path reverse lookup (linear over the flow table) used by
    /// the runtime buffer-deadlock report to describe how many link
    /// stages a stalled tail occupies upstream of the endpoint. (The
    /// static credit pass bounds route slack from the flow graph's own
    /// traced paths instead — same plan-backed geometry.)
    pub fn flows_into(&self, pe: u32, slot: u8) -> impl Iterator<Item = &PlannedFlow> {
        self.flows.iter().filter(move |f| {
            f.error.is_none() && f.dests.iter().any(|&(d, s, _)| d == pe && s == slot)
        })
    }

    /// Human-readable label for a dense link index (the inverse of the
    /// `(y·width + x)·5 + direction` packing used by the router):
    /// `"(x,y)->D"` where `D` is the egress direction at that cell.
    /// Used by the trace/profile consumers to print link paths.
    pub fn link_label(&self, li: u32) -> String {
        const DIRS: [&str; 5] = ["N", "E", "S", "W", "R"];
        let cell = (li / 5) as i64;
        let (x, y) = (cell % self.width.max(1), cell / self.width.max(1));
        format!("({x},{y})->{}", DIRS[(li % 5) as usize])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::{
        DirSet, Direction, FieldAlloc, PeClass, RouteRule, TaskDef,
    };
    use crate::util::Subgrid;

    fn send_recv_prog(color: u8) -> MachineProgram {
        let sender = PeClass {
            name: "sender".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: 4,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 16,
            tasks: vec![TaskDef {
                name: "send".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len: SExpr::imm(4), ty: Dtype::F32 },
                    src0: Some(DsdRef::mem(0, SExpr::imm(4), Dtype::F32)),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![TaskAction::activate(26)],
                })],
            }],
            entry_tasks: vec![25],
        };
        let recv = PeClass {
            name: "recv".into(),
            subgrids: vec![Subgrid::point(1, 0)],
            fields: vec![FieldAlloc {
                name: "b".into(),
                addr: 0,
                len: 4,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 16,
            tasks: vec![TaskDef {
                name: "recv".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::mem(0, SExpr::imm(4), Dtype::F32),
                    src0: Some(DsdRef::FabIn { color, len: SExpr::imm(4), ty: Dtype::F32 }),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![TaskAction::activate(26)],
                })],
            }],
            entry_tasks: vec![25],
        };
        MachineProgram {
            name: "plan_test".into(),
            classes: vec![sender, recv],
            routes: vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            colors_used: vec![color],
            ..Default::default()
        }
    }

    #[test]
    fn plan_precompiles_flow_and_slots() {
        let prog = send_recv_prog(3);
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        assert!(plan.build_errors.is_empty(), "{:?}", plan.build_errors);
        assert_eq!(plan.pes.len(), 2);
        let src = plan.pe_index(0, 0).unwrap();
        let dst = plan.pe_index(1, 0).unwrap();
        let fi = plan.flow_index(src, 3).expect("sender flow planned");
        let flow = &plan.flows[fi];
        assert!(flow.error.is_none());
        assert_eq!(flow.links.len(), 1);
        assert_eq!(flow.dests.len(), 1);
        assert_eq!(flow.dests[0].0 as usize, dst);
        // The receiver class has exactly one endpoint slot, for color 3.
        let recv_class = plan.pes[dst].class;
        let cp = &plan.classes[recv_class];
        assert_eq!(cp.slot_color, vec![3]);
        assert_eq!(cp.color_slot[3], 0);
        assert_eq!(flow.dests[0].1, 0);
        // Consume template registered for the receiver's fabric-in op.
        assert_eq!(cp.consumes.len(), 1);
        assert_eq!(cp.consumes[0].fab_slot, 0);
    }

    #[test]
    fn plan_interns_action_lists() {
        let prog = send_recv_prog(1);
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        // Id 0 is the reserved empty list; both classes' on_complete
        // lists resolve to [activate(26)] with task 26 undefined →
        // task_ix = TASK_NONE, identical content → one interned entry.
        assert!(plan.actions[ACTIONS_EMPTY as usize].is_empty());
        assert_eq!(plan.actions.len(), 2);
        assert_eq!(plan.actions[1].len(), 1);
        assert_eq!(plan.actions[1][0].task_ix, TASK_NONE);
    }

    #[test]
    fn plan_stores_route_errors_lazily() {
        // Producer with no routes: the flow is planned but erroneous;
        // building must still succeed (lazy error surfacing).
        let mut prog = send_recv_prog(2);
        prog.routes.clear();
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        assert!(plan.build_errors.is_empty());
        let src = plan.pe_index(0, 0).unwrap();
        let fi = plan.flow_index(src, 2).unwrap();
        assert!(matches!(plan.flows[fi].error, Some(FlowError::Route(_))));
        assert!(plan.flows[fi].trace.is_err());
    }

    #[test]
    fn plan_entry_task_resolution() {
        let mut prog = send_recv_prog(1);
        prog.classes[0].entry_tasks = vec![9]; // undefined id
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        assert!(plan.build_errors.iter().any(|e| e.contains("entry task id 9")));
    }

    #[test]
    fn plan_islands_and_lookahead() {
        let prog = send_recv_prog(3);
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        // The single flow shares its link with nobody: every PE is its
        // own island, and the one delivery (depth 1) sets the lookahead.
        assert_eq!(plan.n_islands, 2);
        assert_ne!(plan.island_of[0], plan.island_of[1]);
        assert_eq!(plan.lookahead, 1 + cfg.hop_cycles);
    }

    #[test]
    fn plan_unions_sources_sharing_a_link() {
        // Two producers at (0,0) and (1,0) both inject color 5 east
        // toward a sink at (2,0): the flows share link (1,0)→East, so
        // the two source PEs must land in one island.
        let color = 5u8;
        let producer = PeClass {
            name: "producer".into(),
            subgrids: vec![Subgrid::rect(2, 1)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: 4,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 16,
            tasks: vec![TaskDef {
                name: "send".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len: SExpr::imm(4), ty: Dtype::F32 },
                    src0: Some(DsdRef::mem(0, SExpr::imm(4), Dtype::F32)),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        let sink = PeClass {
            name: "sink".into(),
            subgrids: vec![Subgrid::point(2, 0)],
            fields: vec![FieldAlloc {
                name: "b".into(),
                addr: 0,
                len: 8,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 32,
            tasks: vec![TaskDef {
                name: "recv".into(),
                hw_id: 24,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::mem(0, SExpr::imm(8), Dtype::F32),
                    src0: Some(DsdRef::FabIn { color, len: SExpr::imm(8), ty: Dtype::F32 }),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![24],
        };
        let prog = MachineProgram {
            name: "shared_link".into(),
            classes: vec![producer, sink],
            routes: vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::Ramp).with(Direction::West),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(2, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            colors_used: vec![color],
            ..Default::default()
        };
        let cfg = MachineConfig::with_grid(3, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        assert!(plan.build_errors.is_empty(), "{:?}", plan.build_errors);
        let p0 = plan.pe_index(0, 0).unwrap();
        let p1 = plan.pe_index(1, 0).unwrap();
        let p2 = plan.pe_index(2, 0).unwrap();
        assert_eq!(plan.island_of[p0], plan.island_of[p1], "shared link must union sources");
        assert_ne!(plan.island_of[p0], plan.island_of[p2], "the sink sends nothing");
        assert_eq!(plan.n_islands, 2);
    }

    #[test]
    fn scheduler_order_follows_hw_ids() {
        let mut prog = send_recv_prog(1);
        // Add a second, lower-ID task to the sender class.
        prog.classes[0].tasks.push(TaskDef {
            name: "early".into(),
            hw_id: 10,
            kind: TaskKind::Local,
            initially_active: true,
            initially_blocked: false,
            body: vec![],
        });
        let cfg = MachineConfig::with_grid(2, 1);
        let plan = RoutingPlan::build(&prog, &cfg);
        let cp = &plan.classes[0];
        assert_eq!(cp.order, vec![1, 0]); // hw 10 before hw 25
        assert_eq!(cp.rank_of[1], 0);
        assert_eq!(cp.rank_of[0], 1);
        assert_eq!(cp.task_by_id[10], 1);
        assert_eq!(cp.task_by_id[25], 0);
    }
}

//! Cycle-accurate tracing and profiling — the simulator's observability
//! substrate.
//!
//! The simulator's only end-of-run observables used to be the aggregate
//! [`super::Metrics`] counters; this module captures *where* the cycles
//! go. When tracing is enabled ([`super::sim::Simulator::set_tracing`])
//! both engines emit typed [`TraceRecord`]s at the same semantic points
//! — task activations, DSD operations, flow injections, backpressure
//! stalls — into per-shard buffers with no synchronization. After the
//! run the buffers are concatenated in shard-index order and stably
//! sorted by `(start_cycle, pe)`, which reproduces the single-threaded
//! emission order exactly: records with equal keys come from the same
//! PE (a PE emits in nondecreasing start order and is owned by exactly
//! one shard), so the stable sort preserves their relative order and
//! the merged stream is byte-identical across `SPADA_THREADS`.
//!
//! Tracing never perturbs simulated time: every emission site reads
//! state the simulator computed anyway and is gated on a boolean that
//! is false by default (zero-cost-when-off).
//!
//! Three consumers sit on top of the deterministic stream, all driven
//! through the [`TraceSink`] trait by [`Trace::replay`]:
//!
//! 1. [`chrome_trace_json`] — a Chrome trace-event JSON writer
//!    (Perfetto-loadable; `spada run --trace out.json`);
//! 2. [`Profile`] — per-PE busy/stall/idle breakdowns, per-link
//!    occupancy, hot-PE/hot-link tables (`spada profile`);
//! 3. [`ascii_heatmap`] — a time-binned utilization heatmap for quick
//!    terminal diagnosis.
//!
//! Engine-level introspection (shard/epoch structure, barrier-wait
//! attribution) is deliberately split off into [`EngineStats`] and
//! [`EpochRecord`]: epoch structure legitimately differs between
//! thread counts (the single-queue loop has no epochs at all) and
//! barrier wait is wall-clock, so neither may participate in the
//! deterministic stream. Epoch tracks appear in the Chrome export only
//! behind an explicit opt-in.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::plan::RoutingPlan;
use super::program::{DsdKind, MachineProgram};

/// One typed trace record. Cheap (`Copy`) so emission is a guarded
/// push into a per-shard `Vec` and nothing more.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceRecord {
    /// A task activation span on one PE.
    Task { pe: u32, task: u16, start: u64, end: u64 },
    /// A DSD operation span (vectorized by the batch engine or
    /// interpreted element-wise).
    Dsd { pe: u32, kind: DsdKind, n: u32, vectorized: bool, start: u64, end: u64 },
    /// A flow injected into the fabric at `pe` on `color`. `flow`
    /// indexes [`RoutingPlan::flows`]; consumers resolve the link path
    /// and destinations from the plan, so the record itself stays
    /// small. The drain occupies `[start, start + words)` at the
    /// injection ramp.
    Flow { pe: u32, color: u8, flow: u32, start: u64, words: u32 },
    /// A backpressure interval from [`super::flowctl`]: `words` words
    /// whose natural wire arrival was `start` were admitted into the
    /// finite endpoint buffer at `end`. Contributes
    /// `(end - start) * words` to `Metrics::stall_cycles`.
    Stall { pe: u32, color: u8, start: u64, end: u64, words: u32 },
    /// A fault effect fired (see [`super::fault`]): `kind` is one of
    /// the `FK_*` codes, `pe` the PE it applied at (source PE for
    /// link/flow faults, the halted PE for halts). Instant — faults
    /// have no duration, only an application point.
    Fault { pe: u32, kind: u8, start: u64 },
}

impl TraceRecord {
    /// The PE this record is attributed to (source PE for flows).
    pub fn pe(&self) -> u32 {
        match *self {
            TraceRecord::Task { pe, .. }
            | TraceRecord::Dsd { pe, .. }
            | TraceRecord::Flow { pe, .. }
            | TraceRecord::Stall { pe, .. }
            | TraceRecord::Fault { pe, .. } => pe,
        }
    }

    /// The record's start cycle — the primary merge key.
    pub fn start(&self) -> u64 {
        match *self {
            TraceRecord::Task { start, .. }
            | TraceRecord::Dsd { start, .. }
            | TraceRecord::Flow { start, .. }
            | TraceRecord::Stall { start, .. }
            | TraceRecord::Fault { start, .. } => start,
        }
    }
}

/// One conservative-lookahead epoch of the parallel engine. Engine
/// introspection only — excluded from the deterministic record stream
/// (the single-threaded loop has no epochs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EpochRecord {
    /// Epoch window `[start, end)` in simulated cycles.
    pub start: u64,
    /// Window end (exclusive).
    pub end: u64,
    /// Cross-shard messages merged at this epoch's barrier.
    pub merged: u64,
    /// Events each shard processed inside this window, indexed by
    /// shard.
    pub shard_events: Vec<u64>,
}

/// Aggregate engine statistics for one run, populated by both engines
/// (the classic loop reports itself as a single shard with zero
/// epochs). Cheap enough to collect unconditionally — this is what the
/// bench harness surfaces as the shard-balancing baseline.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EngineStats {
    /// Shards the fabric was folded onto (1 = classic engine).
    pub shards: usize,
    /// Epoch barriers crossed (0 = classic engine).
    pub epochs: u64,
    /// Total events processed per shard, in shard-index order.
    pub shard_events: Vec<u64>,
    /// Wall-clock nanoseconds the coordinator spent inside epoch
    /// barriers (not simulated time; varies run to run).
    pub barrier_wait_ns: u64,
}

impl EngineStats {
    /// Shard load imbalance: max/mean of per-shard event counts. 1.0
    /// is perfectly balanced; 1.0 is also reported for a single shard
    /// or an empty run, where imbalance is not meaningful.
    pub fn imbalance(&self) -> f64 {
        if self.shard_events.len() <= 1 {
            return 1.0;
        }
        let max = *self.shard_events.iter().max().unwrap_or(&0);
        let sum: u64 = self.shard_events.iter().sum();
        let mean = sum as f64 / self.shard_events.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max as f64 / mean
        }
    }
}

/// A consumer of trace records. Implementations receive the merged
/// deterministic stream in `(start, pe)` order via [`Trace::replay`];
/// epoch records (engine introspection, not deterministic) arrive
/// separately and default to ignored.
pub trait TraceSink {
    fn record(&mut self, rec: TraceRecord);
    fn epoch(&mut self, _rec: &EpochRecord) {}
}

/// A completed run's trace: the merged deterministic record stream
/// plus (for parallel runs) the epoch log.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Trace {
    /// Records sorted by `(start, pe)`, ties in per-PE emission order.
    pub records: Vec<TraceRecord>,
    /// Epoch log, empty for single-threaded runs.
    pub epochs: Vec<EpochRecord>,
}

impl Trace {
    /// Drive a sink over the whole trace: every record in merged
    /// order, then every epoch.
    pub fn replay(&self, sink: &mut dyn TraceSink) {
        for rec in &self.records {
            sink.record(*rec);
        }
        for ep in &self.epochs {
            sink.epoch(ep);
        }
    }
}

/// Escape a string for embedding in a JSON string literal.
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Longest flow path rendered into Chrome event args before eliding.
const MAX_PATH_HOPS: usize = 16;

/// Track (pid) layout of the Chrome export. Tasks and DSD spans get
/// separate processes because an async fabric-in consume span can
/// overlap the task spans of the same PE, and Chrome slice tracks
/// require proper nesting within one (pid, tid).
const PID_TASKS: u32 = 0;
const PID_DSD: u32 = 1;
const PID_FLOWS: u32 = 2;
const PID_STALLS: u32 = 3;
const PID_FAULTS: u32 = 4;
const PID_EPOCHS: u32 = 9;

/// Streams the trace into Chrome trace-event JSON ("JSON array
/// format" wrapped in `{"traceEvents": [...]}`), loadable in Perfetto
/// or `chrome://tracing`. Timestamps and durations are simulated
/// cycles written as integers — no floating point, so the output is
/// byte-identical whenever the record stream is.
struct ChromeWriter<'a> {
    prog: &'a MachineProgram,
    plan: &'a RoutingPlan,
    include_epochs: bool,
    out: String,
    first: bool,
}

impl<'a> ChromeWriter<'a> {
    fn new(prog: &'a MachineProgram, plan: &'a RoutingPlan, include_epochs: bool) -> Self {
        let mut w = ChromeWriter {
            prog,
            plan,
            include_epochs,
            out: String::from("{\"traceEvents\":[\n"),
            first: true,
        };
        w.metadata();
        w
    }

    fn push(&mut self, ev: &str) {
        if !self.first {
            self.out.push_str(",\n");
        }
        self.first = false;
        self.out.push_str(ev);
    }

    fn meta(&mut self, kind: &str, pid: u32, tid: u32, name: &str) {
        let ev = format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{kind}\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(name)
        );
        self.push(&ev);
    }

    fn metadata(&mut self) {
        self.meta("process_name", PID_TASKS, 0, "PE tasks");
        self.meta("process_name", PID_DSD, 0, "DSD ops");
        self.meta("process_name", PID_FLOWS, 0, "flows (by source PE)");
        self.meta("process_name", PID_STALLS, 0, "endpoint stalls");
        self.meta("process_name", PID_FAULTS, 0, "injected faults");
        if self.include_epochs {
            self.meta("process_name", PID_EPOCHS, 0, "engine epochs");
        }
        for (pi, pe) in self.plan.pes.iter().enumerate() {
            self.meta("thread_name", PID_TASKS, pi as u32, &format!("PE({},{})", pe.x, pe.y));
        }
    }

    fn task_name(&self, pe: u32, task: u16) -> String {
        let class = match self.plan.pes.get(pe as usize) {
            Some(p) => p.class,
            None => return format!("task{task}"),
        };
        self.prog
            .classes
            .get(class)
            .and_then(|c| c.tasks.get(task as usize))
            .map(|t| t.name.clone())
            .unwrap_or_else(|| format!("task{task}"))
    }

    /// Human-readable link path of a planned flow: per-hop
    /// `(x,y)->DIR@depth` labels, elided past [`MAX_PATH_HOPS`].
    fn flow_path(&self, fi: u32) -> String {
        let Some(flow) = self.plan.flows.get(fi as usize) else {
            return String::new();
        };
        let mut parts: Vec<String> = flow
            .links
            .iter()
            .take(MAX_PATH_HOPS)
            .map(|&(li, depth)| format!("{}@{depth}", self.plan.link_label(li)))
            .collect();
        if flow.links.len() > MAX_PATH_HOPS {
            parts.push("…".into());
        }
        parts.join(" ")
    }

    fn finish(mut self) -> String {
        self.out.push_str("\n]}\n");
        self.out
    }
}

impl TraceSink for ChromeWriter<'_> {
    fn record(&mut self, rec: TraceRecord) {
        let ev = match rec {
            TraceRecord::Task { pe, task, start, end } => format!(
                "{{\"ph\":\"X\",\"pid\":{PID_TASKS},\"tid\":{pe},\"ts\":{start},\
                 \"dur\":{},\"name\":\"{}\",\"args\":{{\"task\":{task}}}}}",
                end - start,
                esc(&self.task_name(pe, task)),
            ),
            TraceRecord::Dsd { pe, kind, n, vectorized, start, end } => format!(
                "{{\"ph\":\"X\",\"pid\":{PID_DSD},\"tid\":{pe},\"ts\":{start},\
                 \"dur\":{},\"name\":\"{kind:?}\",\
                 \"args\":{{\"n\":{n},\"vectorized\":{vectorized}}}}}",
                end - start,
            ),
            TraceRecord::Flow { pe, color, flow, start, words } => {
                let hops =
                    self.plan.flows.get(flow as usize).map(|f| f.links.len()).unwrap_or(0);
                format!(
                    "{{\"ph\":\"X\",\"pid\":{PID_FLOWS},\"tid\":{pe},\"ts\":{start},\
                     \"dur\":{words},\"name\":\"c{color}\",\
                     \"args\":{{\"words\":{words},\"hops\":{hops},\"path\":\"{}\"}}}}",
                    esc(&self.flow_path(flow)),
                )
            }
            TraceRecord::Stall { pe, color, start, end, words } => format!(
                "{{\"ph\":\"X\",\"pid\":{PID_STALLS},\"tid\":{pe},\"ts\":{start},\
                 \"dur\":{},\"name\":\"stall c{color}\",\"args\":{{\"words\":{words}}}}}",
                end - start,
            ),
            TraceRecord::Fault { pe, kind, start } => {
                let name = super::fault::FAULT_KIND_NAMES
                    .get(kind as usize)
                    .copied()
                    .unwrap_or("fault");
                format!(
                    "{{\"ph\":\"i\",\"s\":\"t\",\"pid\":{PID_FAULTS},\"tid\":{pe},\
                     \"ts\":{start},\"name\":\"{name}\",\"args\":{{\"kind\":{kind}}}}}"
                )
            }
        };
        self.push(&ev);
    }

    fn epoch(&mut self, rec: &EpochRecord) {
        if !self.include_epochs {
            return;
        }
        let dur = rec.end.saturating_sub(rec.start).max(1);
        let events: u64 = rec.shard_events.iter().sum();
        let ev = format!(
            "{{\"ph\":\"X\",\"pid\":{PID_EPOCHS},\"tid\":0,\"ts\":{},\"dur\":{dur},\
             \"name\":\"epoch\",\"args\":{{\"merged\":{},\"events\":{events}}}}}",
            rec.start, rec.merged,
        );
        self.push(&ev);
        for (si, &n) in rec.shard_events.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let ev = format!(
                "{{\"ph\":\"X\",\"pid\":{PID_EPOCHS},\"tid\":{},\"ts\":{},\"dur\":{dur},\
                 \"name\":\"shard\",\"args\":{{\"events\":{n}}}}}",
                si + 1,
                rec.start,
            );
            self.push(&ev);
        }
    }
}

/// Render a trace as Chrome trace-event JSON. `include_epochs` adds
/// the parallel engine's epoch/shard tracks — engine introspection
/// that varies with the thread count, so it is off for the default
/// deterministic export.
pub fn chrome_trace_json(
    trace: &Trace,
    prog: &MachineProgram,
    plan: &RoutingPlan,
    include_epochs: bool,
) -> String {
    let mut w = ChromeWriter::new(prog, plan, include_epochs);
    trace.replay(&mut w);
    w.finish()
}

/// Per-PE cycle attribution in a [`Profile`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PeBreakdown {
    pub pe: u32,
    pub x: i64,
    pub y: i64,
    /// Cycles inside task-activation spans. Task spans on one PE never
    /// overlap (the scheduler is non-preemptive), so `busy <= makespan`
    /// and summing over PEs reproduces `Metrics::busy_cycles` exactly.
    pub busy: u64,
    /// Word-cycles of backpressure delay at this PE's endpoints
    /// (sums to `Metrics::stall_cycles` over all PEs). Word-cycles,
    /// not wall cycles — overlapping per-word delays accumulate.
    pub stall: u64,
    /// `makespan - busy`.
    pub idle: u64,
    /// Task activations.
    pub tasks: u64,
}

/// In-memory profile aggregator: one [`TraceSink`] pass over the
/// record stream, then cheap queries (hot PEs, hot links, occupancy
/// histogram). Built from a finished trace with [`Profile::build`].
#[derive(Clone, Debug, Default)]
pub struct Profile {
    /// Run makespan the breakdowns are measured against.
    pub cycles: u64,
    /// One entry per planned PE, in PE-index order.
    pub pes: Vec<PeBreakdown>,
    /// Dense link index → busy word-cycles (each word occupies each
    /// link on its path for one cycle; wormhole arbitration keeps the
    /// per-link intervals disjoint, so busy ≤ makespan per link).
    pub links: BTreeMap<u32, u64>,
    pub total_busy: u64,
    pub total_stall: u64,
    pub dsd_ops: u64,
    pub dsd_vectorized: u64,
    /// Flow count (fabric injections).
    pub flows: u64,
    /// Fault-effect applications (0 on clean runs).
    pub faults: u64,
    link_paths: BTreeMap<u32, Vec<u32>>,
}

impl Profile {
    /// Aggregate a finished trace against its routing plan.
    /// `cycles` is the run makespan (`RunReport::cycles`).
    pub fn build(trace: &Trace, plan: &RoutingPlan, cycles: u64) -> Profile {
        let mut p = Profile {
            cycles,
            pes: plan
                .pes
                .iter()
                .enumerate()
                .map(|(i, pe)| PeBreakdown {
                    pe: i as u32,
                    x: pe.x,
                    y: pe.y,
                    idle: cycles,
                    ..Default::default()
                })
                .collect(),
            ..Default::default()
        };
        for (fi, flow) in plan.flows.iter().enumerate() {
            p.link_paths.insert(fi as u32, flow.links.iter().map(|&(li, _)| li).collect());
        }
        trace.replay(&mut p);
        for pe in &mut p.pes {
            pe.idle = cycles.saturating_sub(pe.busy);
        }
        p.total_busy = p.pes.iter().map(|b| b.busy).sum();
        p.total_stall = p.pes.iter().map(|b| b.stall).sum();
        p
    }

    /// Top-`n` PEs by busy cycles (ties broken by PE index).
    pub fn hot_pes(&self, n: usize) -> Vec<&PeBreakdown> {
        let mut v: Vec<&PeBreakdown> = self.pes.iter().filter(|b| b.busy > 0).collect();
        v.sort_by(|a, b| b.busy.cmp(&a.busy).then(a.pe.cmp(&b.pe)));
        v.truncate(n);
        v
    }

    /// Top-`n` links by busy word-cycles (ties broken by link index).
    pub fn hot_links(&self, n: usize) -> Vec<(u32, u64)> {
        let mut v: Vec<(u32, u64)> = self.links.iter().map(|(&li, &b)| (li, b)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(n);
        v
    }

    /// Occupancy histogram over used links: decile bins of
    /// `busy / makespan` (bin 0 = <10 % occupied, bin 9 = ≥90 %).
    pub fn link_histogram(&self) -> [u64; 10] {
        let mut bins = [0u64; 10];
        if self.cycles == 0 {
            return bins;
        }
        for &busy in self.links.values() {
            let decile = (10 * busy / self.cycles).min(9) as usize;
            bins[decile] += 1;
        }
        bins
    }

    /// Machine-readable JSON (hand-rolled, deterministic field order).
    pub fn to_json(&self, plan: &RoutingPlan, top: usize) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"cycles\":{},\"total_busy\":{},\"total_stall\":{},\
             \"dsd_ops\":{},\"dsd_vectorized\":{},\"flows\":{},\"pes\":[",
            self.cycles, self.total_busy, self.total_stall, self.dsd_ops,
            self.dsd_vectorized, self.flows,
        );
        for (i, b) in self.pes.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"pe\":{},\"x\":{},\"y\":{},\"busy\":{},\"stall\":{},\
                 \"idle\":{},\"tasks\":{}}}",
                b.pe, b.x, b.y, b.busy, b.stall, b.idle, b.tasks,
            );
        }
        out.push_str("],\"hot_links\":[");
        for (i, (li, busy)) in self.hot_links(top).iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"link\":\"{}\",\"busy\":{busy}}}",
                esc(&plan.link_label(*li)),
            );
        }
        out.push_str("],\"link_histogram\":[");
        for (i, n) in self.link_histogram().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{n}");
        }
        out.push_str("]}\n");
        out
    }
}

impl TraceSink for Profile {
    fn record(&mut self, rec: TraceRecord) {
        match rec {
            TraceRecord::Task { pe, start, end, .. } => {
                if let Some(b) = self.pes.get_mut(pe as usize) {
                    b.busy += end - start;
                    b.tasks += 1;
                }
            }
            TraceRecord::Dsd { vectorized, .. } => {
                self.dsd_ops += 1;
                if vectorized {
                    self.dsd_vectorized += 1;
                }
            }
            TraceRecord::Flow { flow, words, .. } => {
                self.flows += 1;
                if let Some(path) = self.link_paths.get(&flow) {
                    for &li in path {
                        *self.links.entry(li).or_insert(0) += words as u64;
                    }
                }
            }
            TraceRecord::Stall { pe, start, end, words, .. } => {
                if let Some(b) = self.pes.get_mut(pe as usize) {
                    b.stall += (end - start) * words as u64;
                }
            }
            TraceRecord::Fault { .. } => {
                self.faults += 1;
            }
        }
    }
}

/// Character ramp for heatmap cells, blank → saturated.
const HEAT_RAMP: &[u8; 10] = b" .:-=+*#%@";

/// Render a time-binned PE-utilization heatmap: rows are groups of
/// consecutive PE indices (at most `max_rows`), columns are `nbins`
/// equal time bins over `[0, cycles)`, cell intensity is the group's
/// mean busy fraction inside the bin. Memory is bounded by
/// `max_rows × nbins` regardless of fabric size.
pub fn ascii_heatmap(
    trace: &Trace,
    npes: usize,
    cycles: u64,
    nbins: usize,
    max_rows: usize,
) -> String {
    if npes == 0 || cycles == 0 || nbins == 0 || max_rows == 0 {
        return String::from("(no activity)\n");
    }
    let chunk = npes.div_ceil(max_rows);
    let rows = npes.div_ceil(chunk);
    let binw = cycles as f64 / nbins as f64;
    let mut grid = vec![0.0f64; rows * nbins];
    for rec in &trace.records {
        let TraceRecord::Task { pe, start, end, .. } = *rec else { continue };
        let row = (pe as usize / chunk).min(rows - 1);
        let (s, e) = (start as f64, end as f64);
        let b0 = ((s / binw) as usize).min(nbins - 1);
        let b1 = ((e / binw).ceil() as usize).min(nbins);
        for b in b0..b1 {
            let lo = (b as f64 * binw).max(s);
            let hi = ((b + 1) as f64 * binw).min(e);
            if hi > lo {
                grid[row * nbins + b] += hi - lo;
            }
        }
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "PE utilization heatmap — {rows} row(s) of {chunk} PE(s), \
         {nbins} bins of {binw:.1} cycles:",
    );
    for row in 0..rows {
        let first = row * chunk;
        let last = (first + chunk - 1).min(npes - 1);
        let _ = write!(out, "  PE {first:>4}-{last:<4} |");
        for b in 0..nbins {
            let v = (grid[row * nbins + b] / (chunk as f64 * binw)).clamp(0.0, 1.0);
            let idx = ((v * 9.0).round() as usize).min(9);
            out.push(HEAT_RAMP[idx] as char);
        }
        out.push_str("|\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::{Dtype, FieldAlloc, MOp, PeClass, TaskDef, TaskKind};
    use crate::machine::MachineConfig;
    use crate::util::Subgrid;

    /// A minimal 1-PE program/plan pair for writer tests.
    fn tiny() -> (MachineProgram, RoutingPlan) {
        let class = PeClass {
            name: "only".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: 4,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 16,
            tasks: vec![TaskDef {
                name: "main".into(),
                hw_id: 24,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Halt],
            }],
            entry_tasks: vec![24],
        };
        let prog = MachineProgram { name: "tiny".into(), classes: vec![class], ..Default::default() };
        let cfg = MachineConfig::with_grid(2, 2);
        let plan = RoutingPlan::build(&prog, &cfg);
        (prog, plan)
    }

    fn sample_trace() -> Trace {
        Trace {
            records: vec![
                TraceRecord::Task { pe: 0, task: 0, start: 6, end: 20 },
                TraceRecord::Dsd {
                    pe: 0,
                    kind: DsdKind::Fmac,
                    n: 8,
                    vectorized: true,
                    start: 9,
                    end: 17,
                },
                TraceRecord::Stall { pe: 0, color: 3, start: 10, end: 14, words: 2 },
                TraceRecord::Fault { pe: 0, kind: 3, start: 12 },
                TraceRecord::Task { pe: 0, task: 0, start: 30, end: 40 },
            ],
            epochs: vec![EpochRecord {
                start: 0,
                end: 32,
                merged: 1,
                shard_events: vec![5, 3],
            }],
        }
    }

    #[test]
    fn record_accessors() {
        let r = TraceRecord::Flow { pe: 7, color: 1, flow: 0, start: 42, words: 9 };
        assert_eq!(r.pe(), 7);
        assert_eq!(r.start(), 42);
        let s = TraceRecord::Stall { pe: 2, color: 0, start: 5, end: 9, words: 1 };
        assert_eq!((s.pe(), s.start()), (2, 5));
        let f = TraceRecord::Fault { pe: 3, kind: 0, start: 11 };
        assert_eq!((f.pe(), f.start()), (3, 11));
    }

    #[test]
    fn imbalance_math() {
        let mut st = EngineStats { shards: 1, shard_events: vec![100], ..Default::default() };
        assert_eq!(st.imbalance(), 1.0, "single shard is defined as balanced");
        st.shard_events = vec![10, 10, 10, 10];
        assert_eq!(st.imbalance(), 1.0);
        st.shard_events = vec![30, 10];
        assert!((st.imbalance() - 1.5).abs() < 1e-12, "max/mean = 30/20");
        st.shard_events = vec![0, 0];
        assert_eq!(st.imbalance(), 1.0, "empty run is defined as balanced");
    }

    #[test]
    fn json_escaping() {
        assert_eq!(esc("plain"), "plain");
        assert_eq!(esc("a\"b\\c\n"), "a\\\"b\\\\c\\n");
        assert_eq!(esc("\u{1}"), "\\u0001");
    }

    #[test]
    fn chrome_writer_structure() {
        let (prog, plan) = tiny();
        let json = chrome_trace_json(&sample_trace(), &prog, &plan, false);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        // Task spans resolve to the program's task name.
        assert!(json.contains("\"name\":\"main\""), "{json}");
        // Integer timestamps in cycles, duration = end - start.
        assert!(json.contains("\"ts\":6,\"dur\":14"), "{json}");
        assert!(json.contains("\"name\":\"Fmac\""));
        assert!(json.contains("\"vectorized\":true"));
        assert!(json.contains("\"name\":\"stall c3\""));
        // Faults render as instant events on the dedicated lane, named
        // by their FK_* code (3 = corrupt).
        assert!(json.contains("\"ph\":\"i\",\"s\":\"t\",\"pid\":4,\"tid\":0,\"ts\":12,\"name\":\"corrupt\""), "{json}");
        assert!(json.contains("\"name\":\"injected faults\""), "{json}");
        // Epochs are excluded from the default deterministic export...
        assert!(!json.contains("\"epoch\""));
        // ...and included behind the explicit opt-in.
        let with = chrome_trace_json(&sample_trace(), &prog, &plan, true);
        assert!(with.contains("\"name\":\"epoch\""));
        assert!(with.contains("\"merged\":1,\"events\":8"));
        assert!(with.contains("\"name\":\"shard\""));
    }

    #[test]
    fn chrome_writer_deterministic() {
        let (prog, plan) = tiny();
        let a = chrome_trace_json(&sample_trace(), &prog, &plan, true);
        let b = chrome_trace_json(&sample_trace(), &prog, &plan, true);
        assert_eq!(a, b);
    }

    #[test]
    fn profile_breakdowns() {
        let (_prog, plan) = tiny();
        let p = Profile::build(&sample_trace(), &plan, 50);
        assert_eq!(p.pes.len(), 1);
        let b = &p.pes[0];
        assert_eq!(b.busy, 14 + 10, "sum of the two task spans");
        assert_eq!(b.tasks, 2);
        assert_eq!(b.stall, (14 - 10) * 2, "(end - start) * words");
        assert_eq!(b.idle, 50 - 24);
        assert_eq!(p.total_busy, 24);
        assert_eq!(p.total_stall, 8);
        assert_eq!(p.dsd_ops, 1);
        assert_eq!(p.dsd_vectorized, 1);
        assert_eq!(p.faults, 1, "the corrupt record counts, attributing no cycles");
        assert_eq!(p.hot_pes(4).len(), 1);
        let json = p.to_json(&plan, 8);
        assert!(json.contains("\"total_busy\":24"), "{json}");
        assert!(json.contains("\"link_histogram\":[0,0,0,0,0,0,0,0,0,0]"));
    }

    #[test]
    fn heatmap_bounded_and_saturating() {
        let mut t = Trace::default();
        // PE 0 busy the whole run; PE 1 idle.
        t.records.push(TraceRecord::Task { pe: 0, task: 0, start: 0, end: 100 });
        let art = ascii_heatmap(&t, 2, 100, 10, 2);
        let lines: Vec<&str> = art.lines().collect();
        assert_eq!(lines.len(), 3, "header + 2 rows: {art}");
        assert!(lines[1].contains("@@@@@@@@@@"), "fully busy row saturates: {art}");
        assert!(lines[2].contains("          "), "idle row stays blank: {art}");
        // Thousands of PEs still render at most max_rows rows.
        let big = ascii_heatmap(&t, 10_000, 100, 64, 24);
        assert!(big.lines().count() <= 25);
        assert_eq!(ascii_heatmap(&Trace::default(), 0, 0, 0, 0), "(no activity)\n");
    }
}

//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! [`run_prop`] drives a property over `cases` random inputs generated
//! from a [`SplitMix64`] seed; on failure it reports the seed and case
//! index so the exact input reproduces deterministically.

use crate::util::SplitMix64;

/// Run `prop` over `cases` random cases. `gen` builds an input from the
/// RNG; `prop` returns Err(description) on violation.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = SplitMix64::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name} failed (seed={seed}, case={case}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        run_prop(
            "abs-nonneg",
            42,
            100,
            |r| r.next_f32(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property always-fails failed")]
    fn reports_failures() {
        run_prop("always-fails", 1, 10, |r| r.next_u64(), |_| Err("nope".into()));
    }
}

//! Batch job specs and result rows — the JSONL wire format of
//! `spada batch`.
//!
//! One line in = one [`JobSpec`]; one line out = one [`JobResult`].
//! The repo carries no JSON dependency, so specs are read with a small
//! flat-object scanner (string/number/bool/null values, unknown keys
//! tolerated) and rows are written with the same hand-rolled style the
//! fault campaign and bench harness use.
//!
//! Result rows are **deterministic**: they carry simulated observables
//! only (cycles, events, traffic, stalls) and never wall-clock fields,
//! so the same job list produces byte-identical rows at any pool size.

use crate::machine::{Metrics, RunReport, SimError};

/// One simulation job, parsed from a JSONL spec line.
///
/// `kernel` is required; everything else defaults. `g`/`k` follow the
/// harness scaling convention ([`crate::harness::common::scaled_binds`]):
/// `g` is the grid scale factor, `k` the per-PE vector length. The
/// remaining fields override run options for this job only — they
/// never touch the process environment, so jobs with different
/// buffer capacities, fault plans or watchdogs coexist in one fleet.
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    /// Row correlation ID (defaults to `job-<index>` when absent).
    pub id: String,
    pub kernel: String,
    /// Grid scale factor (`g × g` grids for 2-D kernels, `g × 1` for
    /// 1-D ones).
    pub g: i64,
    /// Per-PE vector length.
    pub k: i64,
    /// Input-staging seed (one `SplitMix64` stream over the kernel's
    /// input bindings in declaration order).
    pub seed: u64,
    /// Finite endpoint-buffer capacity in words (default unbounded).
    pub buf_cap: Option<u64>,
    /// Credit return latency in cycles.
    pub credit_latency: Option<u64>,
    /// Fault plan in the `SPADA_FAULTS` grammar.
    pub faults: Option<String>,
    /// Wall-clock watchdog for this job.
    pub timeout_ms: Option<u64>,
    /// Inner (epoch-parallel) thread override; default = fleet budget
    /// policy. Never changes results — only wall-clock.
    pub threads: Option<usize>,
    /// Force the per-element DSD interpreter (bit-identical).
    pub no_vec: bool,
    /// Chaos hook: panic the job deterministically on attempts `<= N`
    /// (so attempt `N+1` succeeds). Exercises the serve retry path and
    /// batch panic isolation without a real engine bug; never set by
    /// production clients.
    pub inject_fail: Option<u32>,
}

impl Default for JobSpec {
    fn default() -> JobSpec {
        JobSpec {
            id: String::new(),
            kernel: String::new(),
            g: 4,
            k: 8,
            seed: 0xF1EE7,
            buf_cap: None,
            credit_latency: None,
            faults: None,
            timeout_ms: None,
            threads: None,
            no_vec: false,
            inject_fail: None,
        }
    }
}

impl JobSpec {
    /// Parse one JSONL spec line. Unknown keys are ignored (forward
    /// compatibility); a known key with the wrong type is an error.
    pub fn parse(line: &str) -> Result<JobSpec, String> {
        let mut spec = JobSpec::default();
        for (key, val) in parse_flat_object(line)? {
            match key.as_str() {
                "id" => spec.id = val.str(&key)?,
                "kernel" => spec.kernel = val.str(&key)?,
                "g" | "grid" => spec.g = val.int(&key)?,
                "k" => spec.k = val.int(&key)?,
                "seed" => spec.seed = val.int(&key)? as u64,
                "buf_cap" => spec.buf_cap = val.opt_int(&key)?.map(|v| v as u64),
                "credit_latency" => {
                    spec.credit_latency = val.opt_int(&key)?.map(|v| v as u64)
                }
                "faults" => spec.faults = val.opt_str(&key)?,
                "timeout_ms" => spec.timeout_ms = val.opt_int(&key)?.map(|v| v as u64),
                "threads" => spec.threads = val.opt_int(&key)?.map(|v| v.max(1) as usize),
                "no_vec" => spec.no_vec = val.bool(&key)?,
                "inject_fail" => {
                    spec.inject_fail = val.opt_int(&key)?.map(|v| v.max(0) as u32)
                }
                _ => {}
            }
        }
        if spec.kernel.is_empty() {
            return Err("missing required key \"kernel\"".to_string());
        }
        if spec.g < 1 {
            return Err(format!("g must be >= 1, got {}", spec.g));
        }
        if spec.k < 1 {
            return Err(format!("k must be >= 1, got {}", spec.k));
        }
        Ok(spec)
    }
}

/// One result row: either a completed simulation's observables or an
/// isolated failure. Serialized with [`JobResult::to_jsonl`].
#[derive(Clone, Debug)]
pub struct JobResult {
    pub id: String,
    pub kernel: String,
    /// `WxH` geometry, empty when the spec never resolved to a grid.
    pub grid: String,
    /// Plan-cache disposition — `Some(true)` = this job was the first
    /// of its shape in input order (the compile), `Some(false)` = it
    /// shared an earlier job's compilation. `None` when the job failed
    /// before reaching the cache. Deterministic: derived from input
    /// order, not from which worker won the compile race.
    pub cache_miss: Option<bool>,
    /// Simulated observables (completed jobs only).
    pub report: Option<RowMetrics>,
    /// How many attempts this row took (serve mode only: `Some(1)` =
    /// first try, `Some(n>1)` = retried). `None` in batch mode, which
    /// never retries — the key stays absent so batch rows are
    /// unchanged.
    pub attempts: Option<u32>,
    /// `(kind, message)` for failed jobs — `kind` is
    /// [`SimError::kind`] plus the fleet's own `spec` / `compile` /
    /// `panic` discriminants, and serve's `overload` (job shed by
    /// admission control).
    pub error: Option<(String, String)>,
}

/// The deterministic slice of a [`RunReport`] a row carries.
#[derive(Clone, Debug)]
pub struct RowMetrics {
    pub cycles: u64,
    pub events: u64,
    pub flows: u64,
    pub wavelets: u64,
    pub flops: u64,
    pub peak_queue_depth: u64,
    pub stall_cycles: u64,
    pub faults_injected: u64,
}

impl RowMetrics {
    pub fn of(report: &RunReport) -> RowMetrics {
        let m: &Metrics = &report.metrics;
        RowMetrics {
            cycles: report.cycles,
            events: m.events,
            flows: m.flows,
            wavelets: m.wavelets,
            flops: m.flops,
            peak_queue_depth: m.peak_queue_depth,
            stall_cycles: m.stall_cycles,
            faults_injected: m.faults_injected,
        }
    }
}

impl JobResult {
    /// A failure row. Timeout messages are normalized here: the
    /// engine's diagnostic cites wall-clock state ("last progress at
    /// cycle N; busiest endpoints …") that legitimately varies run to
    /// run, and rows must be byte-identical at any pool size.
    pub fn failed(id: &str, kernel: &str, grid: &str, kind: &str, message: String) -> JobResult {
        let message = if kind == "timeout" {
            "wall-clock watchdog fired".to_string()
        } else {
            message
        };
        JobResult {
            id: id.to_string(),
            kernel: kernel.to_string(),
            grid: grid.to_string(),
            cache_miss: None,
            report: None,
            attempts: None,
            error: Some((kind.to_string(), message)),
        }
    }

    /// A failure row from a [`SimError`].
    pub fn from_sim_error(id: &str, kernel: &str, grid: &str, e: &SimError) -> JobResult {
        JobResult::failed(id, kernel, grid, e.kind(), e.to_string())
    }

    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// The row as one JSON line (trailing newline included).
    pub fn to_jsonl(&self) -> String {
        let mut s = String::with_capacity(160);
        s.push_str(&format!(
            "{{\"id\":\"{}\",\"kernel\":\"{}\",\"grid\":\"{}\",\"ok\":{}",
            esc(&self.id),
            esc(&self.kernel),
            esc(&self.grid),
            self.ok()
        ));
        if let Some(miss) = self.cache_miss {
            s.push_str(&format!(",\"cache\":\"{}\"", if miss { "miss" } else { "hit" }));
        }
        if let Some(n) = self.attempts {
            s.push_str(&format!(",\"attempts\":{n}"));
        }
        if let Some(m) = &self.report {
            s.push_str(&format!(
                ",\"cycles\":{},\"events\":{},\"flows\":{},\"wavelets\":{},\"flops\":{},\
                 \"peak_queue_depth\":{},\"stall_cycles\":{},\"faults_injected\":{}",
                m.cycles,
                m.events,
                m.flows,
                m.wavelets,
                m.flops,
                m.peak_queue_depth,
                m.stall_cycles,
                m.faults_injected
            ));
        }
        if let Some((kind, msg)) = &self.error {
            s.push_str(&format!(
                ",\"error\":{{\"kind\":\"{}\",\"message\":\"{}\"}}",
                esc(kind),
                esc(msg)
            ));
        }
        s.push_str("}\n");
        s
    }
}

/// Minimal JSON string escaping (mirrors the fault campaign's writer).
fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// A scanned flat-JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonVal {
    Str(String),
    Int(i64),
    Num(f64),
    Bool(bool),
    Null,
}

impl JsonVal {
    fn str(self, key: &str) -> Result<String, String> {
        match self {
            JsonVal::Str(s) => Ok(s),
            other => Err(format!("\"{key}\" wants a string, got {other:?}")),
        }
    }
    fn opt_str(self, key: &str) -> Result<Option<String>, String> {
        match self {
            JsonVal::Null => Ok(None),
            other => other.str(key).map(Some),
        }
    }
    fn int(self, key: &str) -> Result<i64, String> {
        match self {
            JsonVal::Int(v) => Ok(v),
            other => Err(format!("\"{key}\" wants an integer, got {other:?}")),
        }
    }
    fn opt_int(self, key: &str) -> Result<Option<i64>, String> {
        match self {
            JsonVal::Null => Ok(None),
            other => other.int(key).map(Some),
        }
    }
    fn bool(self, key: &str) -> Result<bool, String> {
        match self {
            JsonVal::Bool(b) => Ok(b),
            other => Err(format!("\"{key}\" wants a boolean, got {other:?}")),
        }
    }
}

/// Scan one flat JSON object — `{"key": value, ...}` with string,
/// number, boolean and null values. No nesting (a spec line is flat by
/// construction); arrays or objects as values are rejected loudly.
pub fn parse_flat_object(line: &str) -> Result<Vec<(String, JsonVal)>, String> {
    let mut p = Scanner { bytes: line.as_bytes(), pos: 0 };
    p.ws();
    p.expect(b'{')?;
    let mut pairs = Vec::new();
    p.ws();
    if p.peek() == Some(b'}') {
        p.pos += 1;
    } else {
        loop {
            p.ws();
            let key = p.string()?;
            p.ws();
            p.expect(b':')?;
            p.ws();
            let val = p.value()?;
            pairs.push((key, val));
            p.ws();
            match p.next() {
                Some(b',') => continue,
                Some(b'}') => break,
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, got {:?}",
                        p.pos,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
    p.ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage after object at byte {}", p.pos));
    }
    Ok(pairs)
}

struct Scanner<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Scanner<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn next(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }
    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.next() {
            Some(b) if b == want => Ok(()),
            other => Err(format!(
                "expected '{}' at byte {}, got {:?}",
                want as char,
                self.pos,
                other.map(|b| b as char)
            )),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.next() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.next() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .next()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or("bad \\u escape")?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                // Multi-byte UTF-8: the line came in as &str, so the
                // remaining bytes of the scalar follow contiguously.
                Some(b) if b >= 0x80 => {
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..end]).map_err(|e| e.to_string())?,
                    );
                    self.pos = end;
                }
                Some(b) => out.push(b as char),
            }
        }
    }

    fn value(&mut self) -> Result<JsonVal, String> {
        match self.peek() {
            Some(b'"') => self.string().map(JsonVal::Str),
            Some(b't') => self.literal("true", JsonVal::Bool(true)),
            Some(b'f') => self.literal("false", JsonVal::Bool(false)),
            Some(b'n') => self.literal("null", JsonVal::Null),
            Some(b'-' | b'0'..=b'9') => {
                let start = self.pos;
                while matches!(
                    self.peek(),
                    Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
                ) {
                    self.pos += 1;
                }
                let tok = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
                if let Ok(v) = tok.parse::<i64>() {
                    Ok(JsonVal::Int(v))
                } else {
                    tok.parse::<f64>()
                        .map(JsonVal::Num)
                        .map_err(|_| format!("bad number {tok:?}"))
                }
            }
            Some(b'{' | b'[') => Err("nested values are not part of the spec schema".to_string()),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: JsonVal) -> Result<JsonVal, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("expected {word} at byte {}", self.pos))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_minimal() {
        let s = JobSpec::parse(r#"{"kernel": "gemv"}"#).unwrap();
        assert_eq!(s.kernel, "gemv");
        assert_eq!(s.g, 4);
        assert_eq!(s.k, 8);
        assert!(s.buf_cap.is_none() && s.faults.is_none());
    }

    #[test]
    fn spec_full() {
        let s = JobSpec::parse(
            r#"{"id":"j7","kernel":"tree_reduce","g":8,"k":16,"seed":42,
                "buf_cap":8,"credit_latency":2,"faults":"pe(1,1):halt@10",
                "timeout_ms":500,"threads":2,"no_vec":true,"future_key":"ignored"}"#,
        )
        .unwrap();
        assert_eq!(s.id, "j7");
        assert_eq!(s.g, 8);
        assert_eq!(s.seed, 42);
        assert_eq!(s.buf_cap, Some(8));
        assert_eq!(s.credit_latency, Some(2));
        assert_eq!(s.faults.as_deref(), Some("pe(1,1):halt@10"));
        assert_eq!(s.timeout_ms, Some(500));
        assert_eq!(s.threads, Some(2));
        assert!(s.no_vec);
    }

    #[test]
    fn spec_rejects_missing_kernel_and_bad_types() {
        assert!(JobSpec::parse(r#"{"g": 4}"#).unwrap_err().contains("kernel"));
        assert!(JobSpec::parse(r#"{"kernel": 3}"#).is_err());
        assert!(JobSpec::parse(r#"{"kernel":"gemv","g":"four"}"#).is_err());
        assert!(JobSpec::parse(r#"{"kernel":"gemv","#).is_err());
        assert!(JobSpec::parse(r#"{"kernel":"gemv"} trailing"#).is_err());
        assert!(JobSpec::parse(r#"{"kernel":"gemv","g":0}"#).is_err());
    }

    #[test]
    fn string_escapes_round_trip() {
        let pairs =
            parse_flat_object(r#"{"a": "x\"y\\z\n", "b": "A"}"#).unwrap();
        assert_eq!(pairs[0].1, JsonVal::Str("x\"y\\z\n".to_string()));
        assert_eq!(pairs[1].1, JsonVal::Str("A".to_string()));
    }

    #[test]
    fn row_shapes() {
        let ok = JobResult {
            id: "a".into(),
            kernel: "gemv".into(),
            grid: "4x4".into(),
            cache_miss: Some(true),
            report: Some(RowMetrics {
                cycles: 10,
                events: 20,
                flows: 3,
                wavelets: 40,
                flops: 50,
                peak_queue_depth: 6,
                stall_cycles: 0,
                faults_injected: 0,
            }),
            attempts: None,
            error: None,
        };
        let line = ok.to_jsonl();
        assert!(line.contains("\"ok\":true") && line.contains("\"cache\":\"miss\""));
        assert!(line.ends_with("}\n"));
        // Success rows are flat: they must round-trip through the
        // spec scanner (schema sanity for downstream tooling).
        let parsed = parse_flat_object(line.trim_end()).unwrap();
        assert!(parsed.iter().any(|(k, v)| k == "cycles" && *v == JsonVal::Int(10)));

        let err = JobResult::failed("b", "nope", "", "compile", "unknown kernel \"nope\"".into());
        let line = err.to_jsonl();
        assert!(line.contains("\"ok\":false"));
        assert!(line.contains("\\\"nope\\\""));
        assert!(!line.contains("\"cache\""));
    }

    #[test]
    fn attempts_and_inject_fail_round_trip() {
        let s = JobSpec::parse(r#"{"kernel":"gemv","inject_fail":2}"#).unwrap();
        assert_eq!(s.inject_fail, Some(2));
        let mut row = JobResult::failed("r", "gemv", "8x8", "panic", "injected".into());
        row.attempts = Some(3);
        let line = row.to_jsonl();
        assert!(line.contains("\"attempts\":3"));
        // Batch rows never carry the key.
        let plain = JobResult::failed("r", "gemv", "8x8", "panic", "injected".into());
        assert!(!plain.to_jsonl().contains("attempts"));
    }

    #[test]
    fn timeout_rows_are_normalized() {
        let r = JobResult::failed(
            "t",
            "gemv",
            "4x4",
            "timeout",
            "wall-clock watchdog (1 ms) fired; last progress at cycle 7312".into(),
        );
        assert_eq!(r.error.unwrap().1, "wall-clock watchdog fired");
    }
}

//! A minimal indexed worker pool for whole-simulation (outer)
//! parallelism.
//!
//! Work items are identified by index; workers pull the next index
//! from a shared atomic counter, so scheduling is dynamic (a slow job
//! never convoys the queue behind it) while results stay slot-indexed
//! by input order — the property every deterministic-output consumer
//! (the batch engine, the fault campaign) builds on.
//!
//! [`drain_shared`] is the open-ended counterpart for service mode
//! ([`crate::fleet::serve`]): the work list is a channel, not a known
//! count, and workers drain it until the producer hangs up or a stop
//! flag is raised.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Mutex;
use std::time::Duration;

/// Run `f(i)` for every `i in 0..n` on `workers` threads and return
/// the results in input order. `f` must be panic-free (wrap the body
/// in `catch_unwind` when isolation is required — the fleet driver
/// does); a panic that does escape tears down the scope and propagates.
///
/// `on_done(i, &result)` fires immediately after item `i` completes,
/// from the completing worker's thread, serialized under a lock — the
/// hook for streaming emitters that must not wait for the barrier.
pub fn run_indexed<T, F, D>(n: usize, workers: usize, f: F, on_done: D) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    D: FnMut(usize, &T) + Send,
{
    let workers = workers.max(1).min(n.max(1));
    let done = Mutex::new(on_done);
    if workers <= 1 {
        return (0..n)
            .map(|i| {
                let r = f(i);
                (done.lock().unwrap_or_else(|p| p.into_inner()))(i, &r);
                r
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                (done.lock().unwrap_or_else(|p| p.into_inner()))(i, &r);
                *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .expect("every index visited exactly once")
        })
        .collect()
}

/// Worker loop over a shared receiver: pull items until the sending
/// side disconnects (and the buffer is drained) or `stop` becomes
/// nonzero. The in-progress item always completes — a raised stop flag
/// stops *intake*, it never abandons work, which is exactly the
/// graceful-drain contract `spada serve` exposes on SIGTERM. Items
/// still buffered in the channel when the flag rises are left behind
/// for the journal/resume path.
///
/// The receiver sits behind a mutex because `mpsc::Receiver` is
/// single-consumer; the short `recv_timeout` bounds how long any one
/// worker monopolizes it (and how stale its view of `stop` can get).
/// Call from one thread per pool slot.
pub fn drain_shared<T: Send>(rx: &Mutex<Receiver<T>>, stop: &AtomicU32, mut f: impl FnMut(T)) {
    loop {
        if stop.load(Ordering::SeqCst) > 0 {
            return;
        }
        let item = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv_timeout(Duration::from_millis(25))
        };
        match item {
            Ok(t) => f(t),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_input_ordered_at_any_width() {
        for workers in [1, 2, 7] {
            let out = run_indexed(20, workers, |i| i * i, |_, _| {});
            assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn on_done_sees_every_item() {
        let seen = Mutex::new(vec![false; 12]);
        run_indexed(
            12,
            4,
            |i| i,
            |i, r| {
                assert_eq!(i, *r);
                seen.lock().unwrap()[i] = true;
            },
        );
        assert!(seen.into_inner().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn zero_items_is_fine() {
        let out: Vec<u32> = run_indexed(0, 4, |_| unreachable!(), |_, _| {});
        assert!(out.is_empty());
    }

    #[test]
    fn drain_shared_consumes_everything_then_stops_on_disconnect() {
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..40 {
            tx.send(i).unwrap();
        }
        drop(tx);
        let rx = Mutex::new(rx);
        let stop = AtomicU32::new(0);
        let seen = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| drain_shared(&rx, &stop, |i| seen.lock().unwrap().push(i)));
            }
        });
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn drain_shared_stop_flag_leaves_buffered_items_behind() {
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let rx = Mutex::new(rx);
        let stop = AtomicU32::new(1); // raised before the loop starts
        let mut seen = Vec::new();
        drain_shared(&rx, &stop, |i: u32| seen.push(i));
        assert!(seen.is_empty(), "a raised stop flag must halt intake immediately");
        // The items are still in the channel for a resumed consumer.
        stop.store(0, Ordering::SeqCst);
        drop(tx);
        drain_shared(&rx, &stop, |i: u32| seen.push(i));
        assert_eq!(seen.len(), 10);
    }
}

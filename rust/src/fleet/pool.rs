//! A minimal indexed worker pool for whole-simulation (outer)
//! parallelism.
//!
//! Work items are identified by index; workers pull the next index
//! from a shared atomic counter, so scheduling is dynamic (a slow job
//! never convoys the queue behind it) while results stay slot-indexed
//! by input order — the property every deterministic-output consumer
//! (the batch engine, the fault campaign) builds on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` on `workers` threads and return
/// the results in input order. `f` must be panic-free (wrap the body
/// in `catch_unwind` when isolation is required — the fleet driver
/// does); a panic that does escape tears down the scope and propagates.
///
/// `on_done(i, &result)` fires immediately after item `i` completes,
/// from the completing worker's thread, serialized under a lock — the
/// hook for streaming emitters that must not wait for the barrier.
pub fn run_indexed<T, F, D>(n: usize, workers: usize, f: F, on_done: D) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
    D: FnMut(usize, &T) + Send,
{
    let workers = workers.max(1).min(n.max(1));
    let done = Mutex::new(on_done);
    if workers <= 1 {
        return (0..n)
            .map(|i| {
                let r = f(i);
                (done.lock().unwrap_or_else(|p| p.into_inner()))(i, &r);
                r
            })
            .collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                (done.lock().unwrap_or_else(|p| p.into_inner()))(i, &r);
                *slots[i].lock().unwrap_or_else(|p| p.into_inner()) = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| {
            s.into_inner()
                .unwrap_or_else(|p| p.into_inner())
                .expect("every index visited exactly once")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_input_ordered_at_any_width() {
        for workers in [1, 2, 7] {
            let out = run_indexed(20, workers, |i| i * i, |_, _| {});
            assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn on_done_sees_every_item() {
        let seen = Mutex::new(vec![false; 12]);
        run_indexed(
            12,
            4,
            |i| i,
            |i, r| {
                assert_eq!(i, *r);
                seen.lock().unwrap()[i] = true;
            },
        );
        assert!(seen.into_inner().unwrap().iter().all(|&b| b));
    }

    #[test]
    fn zero_items_is_fine() {
        let out: Vec<u32> = run_indexed(0, 4, |_| unreachable!(), |_, _| {});
        assert!(out.is_empty());
    }
}

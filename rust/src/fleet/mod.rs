//! Simulation-as-a-service: the batch fleet engine.
//!
//! `spada batch` turns the simulator into a service: a JSONL stream of
//! job specs in, one JSONL result row out per job. The engine layers
//! *outer* parallelism (whole simulations on a worker pool) over the
//! simulator's *inner* epoch-parallelism, with three guarantees:
//!
//! - **Compile once per shape.** Jobs are keyed by (kernel, binds,
//!   machine-config fingerprint) into a [`PlanCache`]; N jobs of one
//!   shape share a single compilation and [`RoutingPlan`]
//!   (see [`cache`]).
//! - **Deterministic output at any pool size.** Result rows carry only
//!   simulated observables (never wall-clock), are labeled hit/miss by
//!   input order (never by compile race), and are emitted in input
//!   order — the same job list is byte-identical at `--pool 1` and
//!   `--pool 16`.
//! - **Per-job isolation.** A job that fails to parse, compile, run —
//!   or panics, or trips its watchdog — becomes an error row; its
//!   siblings and the fleet are unaffected.
//!
//! Thread budget: `outer × inner ≤ budget` (default: the host's
//! available parallelism). The pool width is the outer factor; each
//! job's simulator gets `max(1, budget / pool)` inner threads unless
//! its spec pins `threads` explicitly. Inner thread count never
//! changes results (the epoch-parallel engine's bit-identity
//! guarantee), so the budget policy is pure scheduling.
//!
//! `spada batch` runs one batch per process; [`serve`] is the
//! long-lived counterpart (continuous intake, bounded cache/queue,
//! deadlines + retry, graceful drain, crash-safe journal) built on the
//! same [`PlanCache`] / [`pool`] / [`JobSpec`] primitives.
//!
//! [`RoutingPlan`]: crate::machine::RoutingPlan

pub mod cache;
pub mod job;
pub mod pool;
pub mod serve;

pub use cache::PlanCache;
pub use job::{JobResult, JobSpec, RowMetrics};
pub use serve::{ServeOptions, ServeSummary};

use crate::harness::common::{scaled_binds, stage_kernel_inputs};
use crate::machine::{FaultPlan, MachineConfig, SimOptions};
use crate::passes::Options;
use std::collections::HashSet;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Fleet-level scheduling knobs (per-job options live in [`JobSpec`]).
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// Outer worker-pool width: simulations in flight at once.
    pub pool: usize,
    /// Total thread budget shared by outer × inner parallelism.
    /// Defaults to the host's available parallelism.
    pub budget: usize,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            pool: 1,
            budget: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        }
    }
}

impl FleetOptions {
    /// Inner (epoch-parallel) threads per job under the
    /// `outer × inner ≤ budget` policy.
    pub fn inner_threads(&self) -> usize {
        (self.budget / self.pool.max(1)).max(1)
    }
}

/// What a batch did, for the operator summary (rows carry the per-job
/// story; this is the fleet-level one).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchSummary {
    pub jobs: usize,
    pub ok: usize,
    pub errors: usize,
    /// Plan-cache compiles this batch ran = distinct shapes among the
    /// jobs that reached the cache.
    pub compiles: u64,
    /// Plan-cache lookups this batch performed.
    pub lookups: u64,
}

/// Run every job against the pool, emitting rows **in input order**
/// through `sink` as their prefix completes (a streaming consumer
/// never waits for the whole batch). Returns the summary; the emitted
/// rows are byte-identical for a given job list at any pool width.
pub fn run_batch<F>(
    jobs: &[JobSpec],
    fleet: &FleetOptions,
    cache: &PlanCache,
    mut sink: F,
) -> BatchSummary
where
    F: FnMut(&JobResult) + Send,
{
    let pass_opts = Options::default();
    // Fill default IDs so every row is correlatable.
    let jobs: Vec<JobSpec> = jobs
        .iter()
        .enumerate()
        .map(|(i, j)| {
            let mut j = j.clone();
            if j.id.is_empty() {
                j.id = format!("job-{i}");
            }
            j
        })
        .collect();
    // Deterministic hit/miss labels: the first job of each shape *in
    // input order* is the miss. (Which worker actually wins the
    // compile race varies with pool width; the rows must not.)
    let mut seen = HashSet::new();
    let labels: Vec<Option<bool>> = jobs
        .iter()
        .map(|j| {
            let (binds, w, h) = scaled_binds(&j.kernel, j.g, j.k).ok()?;
            let cfg = MachineConfig::with_grid(w, h);
            Some(seen.insert(PlanCache::key(&j.kernel, &binds, &cfg, &pass_opts)))
        })
        .collect();
    let inner = fleet.inner_threads();
    let (lookups0, compiles0) = (cache.lookups(), cache.compiles());

    // Streaming input-order emitter: buffer out-of-order completions,
    // flush the contiguous prefix.
    let mut next_emit = 0usize;
    let mut buffered: Vec<Option<JobResult>> = jobs.iter().map(|_| None).collect();
    let results = pool::run_indexed(
        jobs.len(),
        fleet.pool,
        |i| {
            let spec = &jobs[i];
            // Isolation: a panicking job (engine bug, corrupt state)
            // becomes an error row; the fleet keeps draining.
            let run = || run_job_attempt(spec, 1, inner, cache, &pass_opts);
            let mut row = catch_unwind(AssertUnwindSafe(run)).unwrap_or_else(|payload| {
                JobResult::failed(
                    &spec.id,
                    &spec.kernel,
                    "",
                    "panic",
                    cache::panic_message(&*payload),
                )
            });
            if row.cache_miss.is_none() {
                row.cache_miss = labels[i];
            }
            row
        },
        |i, row| {
            buffered[i] = Some(row.clone());
            while next_emit < buffered.len() {
                match buffered[next_emit].take() {
                    Some(r) => {
                        sink(&r);
                        next_emit += 1;
                    }
                    None => break,
                }
            }
        },
    );
    let ok = results.iter().filter(|r| r.ok()).count();
    BatchSummary {
        jobs: results.len(),
        ok,
        errors: results.len() - ok,
        compiles: cache.compiles() - compiles0,
        lookups: cache.lookups() - lookups0,
    }
}

/// One job, start to finish: resolve shape → cached compile → explicit
/// per-job [`SimOptions`] → stage → run. Every failure mode returns an
/// error row naming the stage that failed.
///
/// `attempt` is 1-based and only consulted by the `inject_fail` chaos
/// hook on [`JobSpec`] (batch always passes 1; serve's retry loop
/// counts up) — a real job runs identically at every attempt number.
pub(crate) fn run_job_attempt(
    spec: &JobSpec,
    attempt: u32,
    inner_threads: usize,
    cache: &PlanCache,
    pass_opts: &Options,
) -> JobResult {
    if let Some(n) = spec.inject_fail {
        if attempt <= n {
            panic!("injected fault: attempt {attempt} <= inject_fail {n}");
        }
    }
    let (binds, w, h) = match scaled_binds(&spec.kernel, spec.g, spec.k) {
        Ok(v) => v,
        Err(e) => return JobResult::failed(&spec.id, &spec.kernel, "", "spec", format!("{e:#}")),
    };
    let grid = format!("{w}x{h}");
    let cfg = MachineConfig::with_grid(w, h);
    let ck = match cache.get(&spec.kernel, &binds, &cfg, pass_opts) {
        Ok(ck) => ck,
        Err(msg) => return JobResult::failed(&spec.id, &spec.kernel, &grid, "compile", msg),
    };
    let mut opts = SimOptions::default().threads(spec.threads.unwrap_or(inner_threads));
    opts.no_vectorize = spec.no_vec;
    opts.buf_cap = spec.buf_cap;
    opts.credit_latency = spec.credit_latency;
    opts.timeout_ms = spec.timeout_ms;
    if let Some(fspec) = &spec.faults {
        match FaultPlan::parse(fspec) {
            Ok(plan) => opts.faults = Some(plan),
            Err(e) => return JobResult::failed(&spec.id, &spec.kernel, &grid, "faults", e),
        }
    }
    let mut sim = match ck.simulator_with(&opts) {
        Ok(s) => s,
        Err(e) => return JobResult::from_sim_error(&spec.id, &spec.kernel, &grid, &e),
    };
    // Seeded noise for dense kernels; sparse kernels additionally get
    // the registry's demo CSR matrix (matching the compiled binds), so
    // an `spmv_*` job simulates a real matrix, not noise.
    if let Err(e) = stage_kernel_inputs(&mut sim, &spec.kernel, spec.g, spec.k, spec.seed) {
        return JobResult::failed(&spec.id, &spec.kernel, &grid, "stage", format!("{e:#}"));
    }
    match sim.run() {
        Ok(report) => JobResult {
            id: spec.id.clone(),
            kernel: spec.kernel.clone(),
            grid,
            cache_miss: None, // labeled by the batch driver
            report: Some(RowMetrics::of(&report)),
            attempts: None, // stamped by serve's retry loop
            error: None,
        },
        Err(e) => JobResult::from_sim_error(&spec.id, &spec.kernel, &grid, &e),
    }
}

/// Parse a whole JSONL spec stream. Malformed lines become error
/// *specs* — sentinel jobs whose run immediately yields an error row —
/// so one bad line never aborts the batch and row K still corresponds
/// to input line K. Blank lines and `#` comments are skipped.
pub fn parse_jobs(text: &str) -> Vec<Result<JobSpec, (String, String)>> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        out.push(match JobSpec::parse(line) {
            Ok(mut spec) => {
                if spec.id.is_empty() {
                    spec.id = format!("job-{}", lineno + 1);
                }
                Ok(spec)
            }
            Err(e) => Err((format!("job-{}", lineno + 1), e)),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(jobs: &[JobSpec], fleet: &FleetOptions, cache: &PlanCache) -> Vec<String> {
        let mut rows = Vec::new();
        run_batch(jobs, fleet, cache, |r| rows.push(r.to_jsonl()));
        rows
    }

    #[test]
    fn rows_are_input_ordered_and_labeled() {
        let jobs: Vec<JobSpec> = [("a", 4), ("b", 4), ("c", 8)]
            .iter()
            .map(|(id, g)| JobSpec {
                id: id.to_string(),
                kernel: "broadcast".into(),
                g: *g,
                ..JobSpec::default()
            })
            .collect();
        let cache = PlanCache::new();
        let rows = collect(&jobs, &FleetOptions { pool: 2, budget: 2 }, &cache);
        assert_eq!(rows.len(), 3);
        assert!(rows[0].contains("\"id\":\"a\"") && rows[0].contains("\"cache\":\"miss\""));
        assert!(rows[1].contains("\"id\":\"b\"") && rows[1].contains("\"cache\":\"hit\""));
        assert!(rows[2].contains("\"id\":\"c\"") && rows[2].contains("\"cache\":\"miss\""));
        assert_eq!(cache.compiles(), 2);
    }

    #[test]
    fn bad_jobs_become_rows_not_failures() {
        let jobs = vec![
            JobSpec { id: "good".into(), kernel: "broadcast".into(), ..JobSpec::default() },
            JobSpec { id: "bad".into(), kernel: "no_such".into(), ..JobSpec::default() },
            JobSpec {
                id: "badfault".into(),
                kernel: "broadcast".into(),
                faults: Some("pe(9:nope".into()),
                ..JobSpec::default()
            },
        ];
        let cache = PlanCache::new();
        let rows = collect(&jobs, &FleetOptions::default(), &cache);
        assert!(rows[0].contains("\"ok\":true"));
        assert!(rows[1].contains("\"ok\":false") && rows[1].contains("\"kind\":\"spec\""));
        assert!(rows[2].contains("\"ok\":false") && rows[2].contains("\"kind\":\"faults\""));
    }

    #[test]
    fn parse_jobs_keeps_line_correspondence() {
        let text = "\n# comment\n{\"kernel\":\"gemv\"}\nnot json\n{\"kernel\":\"broadcast\",\"id\":\"x\"}\n";
        let parsed = parse_jobs(text);
        assert_eq!(parsed.len(), 3);
        assert_eq!(parsed[0].as_ref().unwrap().id, "job-3");
        assert_eq!(parsed[1].as_ref().unwrap_err().0, "job-4");
        assert_eq!(parsed[2].as_ref().unwrap().id, "x");
    }

    #[test]
    fn budget_policy() {
        let f = FleetOptions { pool: 4, budget: 8 };
        assert_eq!(f.inner_threads(), 2);
        let f = FleetOptions { pool: 8, budget: 4 };
        assert_eq!(f.inner_threads(), 1);
    }
}

//! The fleet plan cache: one compilation per distinct kernel shape.
//!
//! A batch of N jobs typically contains far fewer *shapes* — distinct
//! (kernel, binds, machine-config fingerprint) triples — than jobs.
//! Compilation (parse → instantiate → lower → route trace → static
//! check) dominates small-grid job latency, so the cache compiles each
//! shape exactly once and hands every job of that shape the same
//! [`CompiledKernel`] behind an `Arc`. The shared [`RoutingPlan`]
//! inside is immutable; per-job state lives entirely in the
//! [`Simulator`](crate::machine::Simulator) each job builds from it
//! via [`CompiledKernel::simulator_with`].
//!
//! Exactly-once is enforced under concurrency with a per-entry mutex:
//! the first thread to reach a shape compiles while holding the
//! entry's slot lock; latecomers block on that lock and then clone the
//! finished result (success *or* failure — a kernel that fails to
//! compile fails every job of its shape without recompiling per job).

use crate::kernels::{self, CompiledKernel};
use crate::machine::MachineConfig;
use crate::passes::Options;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Compile-once cache over kernel shapes. Cheap to share: all methods
/// take `&self`, so one instance serves the whole worker pool.
#[derive(Default)]
pub struct PlanCache {
    entries: Mutex<HashMap<String, Arc<Entry>>>,
    lookups: AtomicU64,
    compiles: AtomicU64,
}

/// One shape's slot. `None` until the winning thread fills it; the
/// compile runs under the slot lock so a shape is never compiled twice.
#[derive(Default)]
struct Entry {
    slot: Mutex<Option<Result<Arc<CompiledKernel>, String>>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// The cache key of a shape: kernel name, meta-parameter bindings,
    /// and every compile-relevant machine parameter
    /// ([`MachineConfig::fingerprint`]) plus the pass configuration.
    /// Run-time options (threads, buffer capacity, faults, watchdog —
    /// see [`SimOptions`](crate::machine::SimOptions)) are deliberately
    /// absent: jobs differing only in run options share a compilation.
    pub fn key(kernel: &str, binds: &[(&str, i64)], cfg: &MachineConfig, opts: &Options) -> String {
        use std::fmt::Write as _;
        let mut key = String::with_capacity(96);
        key.push_str(kernel);
        key.push('|');
        for (name, v) in binds {
            let _ = write!(key, "{name}={v},");
        }
        let _ = write!(
            key,
            "|{}|p{}{}{}{}",
            cfg.fingerprint(),
            opts.fusion as u8,
            opts.recycling as u8,
            opts.copy_elim as u8,
            opts.check as u8
        );
        key
    }

    /// Fetch the compilation for a shape, compiling it on first touch.
    /// Concurrent callers of the same shape block until the winner
    /// finishes, then share its result. Compile errors (and compile
    /// panics, defused so they can never poison the slot) are cached
    /// like successes.
    pub fn get(
        &self,
        kernel: &str,
        binds: &[(&str, i64)],
        cfg: &MachineConfig,
        opts: &Options,
    ) -> Result<Arc<CompiledKernel>, String> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let key = Self::key(kernel, binds, cfg, opts);
        let entry = {
            let mut map = lock(&self.entries);
            Arc::clone(map.entry(key).or_default())
        };
        let mut slot = lock(&entry.slot);
        if slot.is_none() {
            self.compiles.fetch_add(1, Ordering::Relaxed);
            let compiled = catch_unwind(AssertUnwindSafe(|| {
                kernels::compile(kernel, binds, cfg, opts)
            }));
            *slot = Some(match compiled {
                Ok(Ok(ck)) => Ok(Arc::new(ck)),
                Ok(Err(e)) => Err(format!("{e:#}")),
                Err(payload) => Err(format!("compile panicked: {}", panic_message(&payload))),
            });
        }
        slot.clone().expect("slot filled above")
    }

    /// Total `get` calls since construction.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Compilations actually run — `lookups() - compiles()` is the hit
    /// count. With exactly-once enforcement this equals the number of
    /// distinct shapes ever requested.
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Number of distinct shapes currently cached.
    pub fn len(&self) -> usize {
        lock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Lock a mutex, recovering from poisoning: cache state is only ever
/// written under `catch_unwind`-defused compiles, so a poisoned lock
/// still guards coherent data.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Best-effort text of a panic payload (the standard `&str` / `String`
/// forms; anything else gets a placeholder).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_compile_per_shape() {
        let cache = PlanCache::new();
        let cfg = MachineConfig::with_grid(4, 1);
        let binds: &[(&str, i64)] = &[("K", 8), ("N", 4)];
        let opts = Options::default();
        let a = cache.get("broadcast", binds, &cfg, &opts).unwrap();
        let b = cache.get("broadcast", binds, &cfg, &opts).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first compilation");
        assert_eq!(cache.compiles(), 1);
        assert_eq!(cache.lookups(), 2);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn distinct_shapes_compile_separately() {
        let cache = PlanCache::new();
        let opts = Options::default();
        let cfg4 = MachineConfig::with_grid(4, 1);
        let cfg8 = MachineConfig::with_grid(8, 1);
        cache.get("broadcast", &[("K", 8), ("N", 4)], &cfg4, &opts).unwrap();
        cache.get("broadcast", &[("K", 8), ("N", 8)], &cfg8, &opts).unwrap();
        cache.get("broadcast", &[("K", 16), ("N", 4)], &cfg4, &opts).unwrap();
        assert_eq!(cache.compiles(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn compile_errors_are_cached() {
        let cache = PlanCache::new();
        let cfg = MachineConfig::with_grid(4, 1);
        let opts = Options::default();
        let e1 = cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        let e2 = cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!(cache.compiles(), 1, "a failing shape still compiles only once");
    }

    #[test]
    fn run_options_do_not_split_the_key() {
        // Two configs differing only in non-compile fields (watchdog,
        // faults) share one key; a compile-relevant field splits it.
        let opts = Options::default();
        let a = MachineConfig::with_grid(4, 4);
        let mut b = a.clone();
        b.timeout_ms = Some(1);
        b.faults = crate::machine::FaultPlan::parse("seed=9").unwrap();
        assert_eq!(
            PlanCache::key("gemv", &[("M", 8)], &a, &opts),
            PlanCache::key("gemv", &[("M", 8)], &b, &opts)
        );
        let mut c = a.clone();
        c.endpoint_capacity_words = Some(8);
        assert_ne!(
            PlanCache::key("gemv", &[("M", 8)], &a, &opts),
            PlanCache::key("gemv", &[("M", 8)], &c, &opts)
        );
    }
}

//! The fleet plan cache: one compilation per distinct kernel shape,
//! bounded for long-lived processes.
//!
//! A batch of N jobs typically contains far fewer *shapes* — distinct
//! (kernel, binds, machine-config fingerprint) triples — than jobs.
//! Compilation (parse → instantiate → lower → route trace → static
//! check) dominates small-grid job latency, so the cache compiles each
//! shape exactly once and hands every job of that shape the same
//! [`CompiledKernel`] behind an `Arc`. The shared [`RoutingPlan`]
//! inside is immutable; per-job state lives entirely in the
//! [`Simulator`](crate::machine::Simulator) each job builds from it
//! via [`CompiledKernel::simulator_with`].
//!
//! Exactly-once is enforced under concurrency with a per-entry mutex:
//! the first thread to reach a shape compiles while holding the
//! entry's slot lock; latecomers block on that lock and then clone the
//! finished result (success *or* failure — a kernel that fails to
//! compile fails every job of its shape without recompiling per job).
//!
//! **Bounding.** One batch per process can run unbounded
//! ([`PlanCache::new`]), but a day-long `spada serve` process cannot:
//! distinct shapes accumulate forever. [`PlanCache::bounded`] accepts a
//! [`CacheBudget`] (entry-count and/or approximate-byte ceilings,
//! resolved like every other knob through `machine/options.rs`) and
//! evicts least-recently-used entries past it. Eviction prefers cached
//! *errors* over successes: an error entry is one failed shape's
//! diagnostic, cheap to recreate, and — crucially — may be *transient*
//! (a compile panic from a resource blip), so evicting it makes the
//! shape retryable; a success entry is a whole routing plan that other
//! jobs are actively sharing. Entries mid-compile are never evicted.
//! Counters reconcile exactly: `hits + misses == lookups` and
//! `evictions <= misses` (every eviction removes an entry some miss
//! created).
//!
//! [`RoutingPlan`]: crate::machine::RoutingPlan

use crate::kernels::{self, CompiledKernel};
use crate::machine::{CacheBudget, MachineConfig};
use crate::passes::Options;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, TryLockError};

/// Compile-once cache over kernel shapes. Cheap to share: all methods
/// take `&self`, so one instance serves the whole worker pool.
#[derive(Default)]
pub struct PlanCache {
    entries: Mutex<HashMap<String, Arc<Entry>>>,
    budget: CacheBudget,
    /// Monotone LRU clock; every lookup stamps its entry.
    tick: AtomicU64,
    lookups: AtomicU64,
    compiles: AtomicU64,
    evictions: AtomicU64,
}

/// One shape's slot. `None` until the winning thread fills it; the
/// compile runs under the slot lock so a shape is never compiled twice
/// while it stays cached (an evicted shape recompiles on next touch).
#[derive(Default)]
struct Entry {
    slot: Mutex<Option<Result<Arc<CompiledKernel>, String>>>,
    /// LRU stamp of the most recent lookup that touched this entry.
    last_used: AtomicU64,
    /// Approximate bytes charged against [`CacheBudget::max_bytes`];
    /// zero until the compile finishes.
    cost: AtomicU64,
}

impl PlanCache {
    /// An unbounded cache — the one-batch-per-process configuration.
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// A cache bounded by `budget`, for long-lived processes. With an
    /// unbounded budget this is identical to [`PlanCache::new`].
    pub fn bounded(budget: CacheBudget) -> PlanCache {
        PlanCache { budget, ..PlanCache::default() }
    }

    /// The cache key of a shape: kernel name, meta-parameter bindings,
    /// and every compile-relevant machine parameter
    /// ([`MachineConfig::fingerprint`]) plus the pass configuration.
    /// Run-time options (threads, buffer capacity, faults, watchdog —
    /// see [`SimOptions`](crate::machine::SimOptions)) are deliberately
    /// absent: jobs differing only in run options share a compilation.
    pub fn key(kernel: &str, binds: &[(&str, i64)], cfg: &MachineConfig, opts: &Options) -> String {
        use std::fmt::Write as _;
        let mut key = String::with_capacity(96);
        key.push_str(kernel);
        key.push('|');
        for (name, v) in binds {
            let _ = write!(key, "{name}={v},");
        }
        let _ = write!(
            key,
            "|{}|p{}{}{}{}",
            cfg.fingerprint(),
            opts.fusion as u8,
            opts.recycling as u8,
            opts.copy_elim as u8,
            opts.check as u8
        );
        key
    }

    /// Fetch the compilation for a shape, compiling it on first touch.
    /// Concurrent callers of the same shape block until the winner
    /// finishes, then share its result. Compile errors (and compile
    /// panics, defused so they can never poison the slot) are cached
    /// like successes — and, like successes, charged to the budget and
    /// evictable, so a transiently failing shape becomes retryable
    /// once it ages out.
    pub fn get(
        &self,
        kernel: &str,
        binds: &[(&str, i64)],
        cfg: &MachineConfig,
        opts: &Options,
    ) -> Result<Arc<CompiledKernel>, String> {
        self.lookups.fetch_add(1, Ordering::Relaxed);
        let key = Self::key(kernel, binds, cfg, opts);
        let entry = {
            let mut map = lock(&self.entries);
            Arc::clone(map.entry(key.clone()).or_default())
        };
        entry.last_used.store(self.tick.fetch_add(1, Ordering::Relaxed) + 1, Ordering::Relaxed);
        let result = {
            let mut slot = lock(&entry.slot);
            if slot.is_none() {
                self.compiles.fetch_add(1, Ordering::Relaxed);
                let compiled = catch_unwind(AssertUnwindSafe(|| {
                    kernels::compile(kernel, binds, cfg, opts)
                }));
                let result = match compiled {
                    Ok(Ok(ck)) => Ok(Arc::new(ck)),
                    Ok(Err(e)) => Err(format!("{e:#}")),
                    Err(payload) => Err(format!("compile panicked: {}", panic_message(&payload))),
                };
                entry.cost.store(cost_of(&result), Ordering::Relaxed);
                *slot = Some(result);
            }
            slot.clone().expect("slot filled above")
        };
        self.enforce_budget(&key);
        result
    }

    /// Evict least-recently-used completed entries until the cache fits
    /// its budget again. `protect` (the key just served) is never the
    /// victim, so a lookup always leaves its own entry resident — with
    /// a byte budget smaller than one plan the cache degrades to
    /// "cache of one", never to livelock. Entries whose compile is
    /// still running are skipped (their slot lock is held). Cached
    /// errors are evicted before any success of equal recency.
    fn enforce_budget(&self, protect: &str) {
        if !self.budget.bounded() {
            return;
        }
        let mut map = lock(&self.entries);
        loop {
            let count = map.len();
            let bytes: u64 = map.values().map(|e| e.cost.load(Ordering::Relaxed)).sum();
            let over_entries = self.budget.max_entries.is_some_and(|m| count > m);
            let over_bytes = self.budget.max_bytes.is_some_and(|m| bytes > m);
            if !over_entries && !over_bytes {
                return;
            }
            let victim = map
                .iter()
                .filter_map(|(k, e)| {
                    if k == protect {
                        return None;
                    }
                    let slot = match e.slot.try_lock() {
                        Ok(guard) => guard,
                        Err(TryLockError::Poisoned(p)) => p.into_inner(),
                        Err(TryLockError::WouldBlock) => return None, // mid-compile
                    };
                    let is_err = slot.as_ref()?.is_err();
                    Some((k.clone(), is_err, e.last_used.load(Ordering::Relaxed)))
                })
                // Errors first (`!is_err` sorts false < true), then
                // least recent.
                .min_by_key(|&(_, is_err, used)| (!is_err, used))
                .map(|(k, _, _)| k);
            match victim {
                Some(k) => {
                    map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                // Everything left is protected or mid-compile; give up
                // rather than spin.
                None => return,
            }
        }
    }

    /// Drop every cached *error* entry (compiles that failed), making
    /// those shapes retryable immediately instead of waiting for LRU
    /// aging. Returns how many were dropped; each counts as an
    /// eviction.
    pub fn purge_errors(&self) -> usize {
        let mut map = lock(&self.entries);
        let before = map.len();
        map.retain(|_, e| {
            let slot = match e.slot.try_lock() {
                Ok(guard) => guard,
                Err(TryLockError::Poisoned(p)) => p.into_inner(),
                Err(TryLockError::WouldBlock) => return true, // mid-compile
            };
            !matches!(slot.as_ref(), Some(Err(_)))
        });
        let dropped = before - map.len();
        self.evictions.fetch_add(dropped as u64, Ordering::Relaxed);
        dropped
    }

    /// Total `get` calls since construction.
    pub fn lookups(&self) -> u64 {
        self.lookups.load(Ordering::Relaxed)
    }

    /// Compilations actually run. Unbounded, this equals the number of
    /// distinct shapes ever requested; bounded, an evicted shape
    /// recompiles on its next touch.
    pub fn compiles(&self) -> u64 {
        self.compiles.load(Ordering::Relaxed)
    }

    /// Lookups that ran a compile (the shape was absent — never seen,
    /// or evicted). Identical to [`PlanCache::compiles`]; named for the
    /// counter-reconciliation invariant `hits + misses == lookups`.
    pub fn misses(&self) -> u64 {
        self.compiles()
    }

    /// Lookups served from a resident entry (including callers that
    /// blocked on the winner's in-flight compile and shared its
    /// result).
    pub fn hits(&self) -> u64 {
        self.lookups() - self.compiles()
    }

    /// Entries evicted to hold the budget (plus [`purge_errors`]
    /// drops). Always `<= misses()`: each eviction removes an entry
    /// exactly one miss created.
    ///
    /// [`purge_errors`]: PlanCache::purge_errors
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Approximate bytes currently charged against the budget.
    pub fn bytes(&self) -> u64 {
        lock(&self.entries).values().map(|e| e.cost.load(Ordering::Relaxed)).sum()
    }

    /// Number of distinct shapes currently cached.
    pub fn len(&self) -> usize {
        lock(&self.entries).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Budget cost of a finished slot: the compilation's resident estimate,
/// or a small flat charge for a cached error (the entry struct plus its
/// message — enough that error floods still hit the byte ceiling).
fn cost_of(result: &Result<Arc<CompiledKernel>, String>) -> u64 {
    match result {
        Ok(ck) => ck.approx_bytes(),
        Err(msg) => 128 + msg.len() as u64,
    }
}

/// Lock a mutex, recovering from poisoning: cache state is only ever
/// written under `catch_unwind`-defused compiles, so a poisoned lock
/// still guards coherent data.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Best-effort text of a panic payload (the standard `&str` / `String`
/// forms; anything else gets a placeholder).
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_compile_per_shape() {
        let cache = PlanCache::new();
        let cfg = MachineConfig::with_grid(4, 1);
        let binds: &[(&str, i64)] = &[("K", 8), ("N", 4)];
        let opts = Options::default();
        let a = cache.get("broadcast", binds, &cfg, &opts).unwrap();
        let b = cache.get("broadcast", binds, &cfg, &opts).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must share the first compilation");
        assert_eq!(cache.compiles(), 1);
        assert_eq!(cache.lookups(), 2);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.evictions(), 0);
        assert_eq!(cache.len(), 1);
        assert!(cache.bytes() > 0, "a cached success must carry a nonzero cost");
    }

    #[test]
    fn distinct_shapes_compile_separately() {
        let cache = PlanCache::new();
        let opts = Options::default();
        let cfg4 = MachineConfig::with_grid(4, 1);
        let cfg8 = MachineConfig::with_grid(8, 1);
        cache.get("broadcast", &[("K", 8), ("N", 4)], &cfg4, &opts).unwrap();
        cache.get("broadcast", &[("K", 8), ("N", 8)], &cfg8, &opts).unwrap();
        cache.get("broadcast", &[("K", 16), ("N", 4)], &cfg4, &opts).unwrap();
        assert_eq!(cache.compiles(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn compile_errors_are_cached() {
        let cache = PlanCache::new();
        let cfg = MachineConfig::with_grid(4, 1);
        let opts = Options::default();
        let e1 = cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        let e2 = cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        assert_eq!(e1, e2);
        assert_eq!(cache.compiles(), 1, "a failing shape still compiles only once");
    }

    #[test]
    fn run_options_do_not_split_the_key() {
        // Two configs differing only in non-compile fields (watchdog,
        // faults) share one key; a compile-relevant field splits it.
        let opts = Options::default();
        let a = MachineConfig::with_grid(4, 4);
        let mut b = a.clone();
        b.timeout_ms = Some(1);
        b.faults = crate::machine::FaultPlan::parse("seed=9").unwrap();
        assert_eq!(
            PlanCache::key("gemv", &[("M", 8)], &a, &opts),
            PlanCache::key("gemv", &[("M", 8)], &b, &opts)
        );
        let mut c = a.clone();
        c.endpoint_capacity_words = Some(8);
        assert_ne!(
            PlanCache::key("gemv", &[("M", 8)], &a, &opts),
            PlanCache::key("gemv", &[("M", 8)], &c, &opts)
        );
    }

    /// Shape helper for the bounding tests: K splits the key, the grid
    /// stays tiny so six compiles stay fast.
    fn shape(cache: &PlanCache, k: i64) -> Result<Arc<CompiledKernel>, String> {
        let cfg = MachineConfig::with_grid(4, 1);
        cache.get("broadcast", &[("K", k), ("N", 4)], &cfg, &Options::default())
    }

    #[test]
    fn entry_budget_evicts_lru_and_counters_reconcile() {
        let cache =
            PlanCache::bounded(CacheBudget { max_entries: Some(3), max_bytes: None });
        for k in 4..=9 {
            shape(&cache, k).unwrap();
            assert!(cache.len() <= 3, "budget violated at k={k}: len={}", cache.len());
        }
        assert_eq!(cache.lookups(), 6);
        assert_eq!(cache.misses(), 6);
        assert_eq!(cache.hits(), 0);
        assert_eq!(cache.evictions(), 3);
        assert_eq!(cache.hits() + cache.misses(), cache.lookups());
        assert!(cache.evictions() <= cache.misses());

        // k=9 is the most recent entry: a hit, no eviction.
        shape(&cache, 9).unwrap();
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 6);
        // k=4 aged out long ago: recompiles (a miss), and the cache
        // stays at its ceiling.
        shape(&cache, 4).unwrap();
        assert_eq!(cache.misses(), 7);
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.hits() + cache.misses(), cache.lookups());
    }

    #[test]
    fn byte_budget_keeps_most_recent_entry() {
        // A byte ceiling of 1 is smaller than any plan: every lookup
        // evicts everything but its own (protected) entry — a cache of
        // one, never zero.
        let cache = PlanCache::bounded(CacheBudget { max_entries: None, max_bytes: Some(1) });
        shape(&cache, 4).unwrap();
        assert_eq!(cache.len(), 1);
        shape(&cache, 5).unwrap();
        assert_eq!(cache.len(), 1, "the just-served entry survives, the older one goes");
        assert_eq!(cache.evictions(), 1);
        // The resident entry is k=5; k=4 must recompile.
        shape(&cache, 5).unwrap();
        assert_eq!(cache.hits(), 1);
        shape(&cache, 4).unwrap();
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn evicted_error_entries_become_retryable() {
        // Satellite fix pin: a cached compile error must not be
        // permanent. Once evicted, the shape compiles again from
        // scratch instead of replaying the stale diagnostic forever.
        let cache =
            PlanCache::bounded(CacheBudget { max_entries: Some(2), max_bytes: None });
        let opts = Options::default();
        let cfg = MachineConfig::with_grid(4, 1);
        cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        assert_eq!(cache.compiles(), 1);
        shape(&cache, 4).unwrap();
        // Touch the error again so it is *more* recent than the
        // success — eviction must still pick it first.
        cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        assert_eq!(cache.compiles(), 2, "the resident error replays without recompiling");
        shape(&cache, 5).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1, "the error entry goes before any success");
        assert!(
            shape(&cache, 4).is_ok() && shape(&cache, 5).is_ok(),
            "both successes stayed resident"
        );
        assert_eq!(cache.compiles(), 3, "resident successes are hits");
        // The failed shape retries: a fresh compile, not the cache.
        cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        assert_eq!(cache.compiles(), 4, "the evicted error shape compiled again");
        assert_eq!(cache.hits() + cache.misses(), cache.lookups());
    }

    #[test]
    fn purge_errors_drops_only_errors() {
        let cache = PlanCache::new();
        let opts = Options::default();
        let cfg = MachineConfig::with_grid(4, 1);
        shape(&cache, 4).unwrap();
        cache.get("no_such_kernel", &[], &cfg, &opts).unwrap_err();
        cache.get("also_missing", &[], &cfg, &opts).unwrap_err();
        assert_eq!(cache.len(), 3);
        assert_eq!(cache.purge_errors(), 2);
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 2);
        shape(&cache, 4).unwrap();
        assert_eq!(cache.hits(), 1, "the success entry survived the purge");
    }
}

//! `spada serve` — the long-lived service loop over the fleet engine.
//!
//! `spada batch` runs one job list per process; this module keeps a
//! process up indefinitely: JSONL job specs stream in continuously
//! (stdin, a file, or a Unix socket), result rows stream out as their
//! input-order prefix completes, and four robustness layers make
//! unattended day-long operation survivable:
//!
//! - **Bounded plan cache.** The caller constructs the [`PlanCache`]
//!   with a [`CacheBudget`](crate::machine::CacheBudget) so distinct
//!   shapes cannot grow memory forever; hit/miss/eviction counters
//!   surface in the heartbeat and final stats.
//! - **Admission control.** Intake flows through a bounded queue
//!   ([`ServeOptions::queue_cap`]). When it fills, the reader either
//!   blocks (backpressure onto the client, the default) or — with
//!   [`ServeOptions::shed`] — emits a structured
//!   `{"error":{"kind":"overload"}}` row and drops the job, so memory
//!   stays bounded under burst traffic either way.
//! - **Deadlines + bounded retry.** Jobs without an explicit
//!   `timeout_ms` get [`ServeOptions::deadline_ms`] as a default
//!   watchdog, so no single job wedges the pool. Failures of
//!   *transient* kinds (`io`, `panic`) are retried up to
//!   [`ServeOptions::retries`] times with capped exponential backoff;
//!   the row records its attempt count. Deterministic outcomes (spec,
//!   compile, sdc, deadlock, timeout…) are never retried — rerunning
//!   them reproduces the same answer.
//! - **Graceful drain + crash-safe journal.** Raising the shutdown
//!   flag (the CLI wires SIGTERM/SIGINT to it) stops intake, lets
//!   in-flight jobs finish, flushes the emitted prefix, and writes a
//!   final stats line. With [`ServeOptions::journal`], every emitted
//!   row's id is appended (flushed per row) so a restart with
//!   [`ServeOptions::resume`] skips finished work — the concatenation
//!   of an interrupted run's rows and its resumed run's rows is
//!   byte-identical to one uninterrupted run.
//!
//! **Output ordering.** Rows are emitted strictly in input order (the
//! batch engine's contract), buffered minimally: a completion beyond
//! the first gap waits for the gap to fill. On drain, completions
//! beyond the gap are discarded rather than emitted out of order —
//! they were never journaled, so a resumed run recomputes them
//! deterministically and byte-identity holds. Shed rows and timeouts
//! are the deliberate exceptions to identity claims: both depend on
//! wall-clock load, which is the point of emitting them as structured
//! errors.
//!
//! **Journal format.** One row id per line, appended after the row
//! itself is flushed (at-least-once: a crash between row flush and
//! journal flush re-runs at most one job on resume, and the resumed
//! stream then re-emits that row — concatenated output drops the
//! duplicate prefix row, see `docs/serve.md`). Ids must be unique
//! across the stream for resume to be exact; the default line-number
//! ids (`job-<line>`) are.

use super::{cache, pool, FleetOptions, JobResult, JobSpec, PlanCache};
use crate::passes::Options;
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, HashSet};
use std::fs::{File, OpenOptions};
use std::io::{BufRead, BufReader, Read, Write};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Service-mode knobs. Everything resolves explicitly here (flags in
/// the CLI); the only env-derived piece — the plan-cache budget — is
/// resolved through `machine/options.rs` like every other `SPADA_*`
/// knob and handed to the [`PlanCache`] the caller constructs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Worker pool / thread budget, as in batch mode.
    pub fleet: FleetOptions,
    /// Bounded intake queue depth (admission control). Minimum 1.
    pub queue_cap: usize,
    /// When the queue is full: `true` = emit an `overload` error row
    /// and drop the job; `false` = block the reader (backpressure).
    pub shed: bool,
    /// Retry budget for *transient* failures (`io` / `panic` kinds):
    /// a job runs at most `retries + 1` times.
    pub retries: u32,
    /// Base backoff between retry attempts, doubled per attempt and
    /// capped (32× base, 10 s hard ceiling).
    pub backoff_ms: u64,
    /// Default wall-clock watchdog applied to jobs that do not pin
    /// their own `timeout_ms`. `None` disables the default (a job can
    /// then only be bounded by its own spec).
    pub deadline_ms: Option<u64>,
    /// Append every emitted row's id to this file (crash-safe journal).
    pub journal: Option<String>,
    /// Skip jobs whose ids are already in the journal (requires
    /// [`ServeOptions::journal`]).
    pub resume: bool,
    /// Emit a heartbeat stats line every N completed rows.
    pub stats_every: Option<u64>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            fleet: FleetOptions::default(),
            queue_cap: 64,
            shed: false,
            retries: 0,
            backoff_ms: 50,
            // One minute: generous for any sane simulation job, short
            // enough that a wedged job frees its pool slot the same
            // hour it wedged.
            deadline_ms: Some(60_000),
            journal: None,
            resume: false,
            stats_every: None,
        }
    }
}

/// What a serve session did, reported once at shutdown (the same
/// counters stream periodically via `stats_every`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeSummary {
    /// Rows emitted (ok + errors, including shed rows).
    pub rows: u64,
    pub ok: u64,
    pub errors: u64,
    /// Rows that were overload-shed by admission control.
    pub shed: u64,
    /// Jobs skipped because their id was already journaled (resume).
    pub skipped: u64,
    /// Extra attempts spent on transient-failure retries.
    pub retries: u64,
    /// Total simulated cycles across completed jobs.
    pub sim_cycles: u64,
    /// `true` when the session ended on the shutdown flag (drain)
    /// rather than input EOF.
    pub drained: bool,
}

/// One admitted job: its spec plus the emit sequence number the intake
/// reader assigned (parse errors and shed rows consume numbers too, so
/// the emitted stream is gap-free in input order).
struct Task {
    seq: u64,
    spec: JobSpec,
}

/// Serve a byte stream of JSONL job specs (stdin, a file, a pipe).
/// Returns at input EOF once every admitted job has been emitted, or
/// earlier when `shutdown` becomes nonzero (graceful drain: intake
/// stops, in-flight jobs finish, the contiguous emitted prefix is
/// flushed).
///
/// `input` is read on a detached thread (a reader blocked on stdin
/// cannot be joined); it exits on EOF or when the service's channels
/// close. `out` receives result rows (flushed per row); `stats`
/// receives heartbeat/final JSON lines (wall-clock fields live here,
/// never in rows).
pub fn serve<R: Read + Send + 'static>(
    input: R,
    opts: &ServeOptions,
    cache: &PlanCache,
    out: &mut dyn Write,
    stats: &mut dyn Write,
    shutdown: &AtomicU32,
) -> Result<ServeSummary> {
    serve_core(
        Box::new(move |mut feeder: Feeder| {
            for line in BufReader::new(input).lines() {
                let Ok(line) = line else { break };
                feeder.feed_line(&line);
                if feeder.closed {
                    break;
                }
            }
        }),
        opts,
        cache,
        out,
        stats,
        shutdown,
    )
}

/// Serve JSONL job specs from a Unix socket: connections are accepted
/// sequentially and read to EOF, each line a spec; rows still stream
/// to `out`. There is no input EOF on a listener, so only the shutdown
/// flag ends the session.
#[cfg(unix)]
pub fn serve_unix(
    listener: std::os::unix::net::UnixListener,
    opts: &ServeOptions,
    cache: &PlanCache,
    out: &mut dyn Write,
    stats: &mut dyn Write,
    shutdown: &AtomicU32,
) -> Result<ServeSummary> {
    serve_core(
        Box::new(move |mut feeder: Feeder| {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                for line in BufReader::new(stream).lines() {
                    let Ok(line) = line else { break };
                    feeder.feed_line(&line);
                    if feeder.closed {
                        return;
                    }
                }
                if feeder.closed {
                    return;
                }
            }
        }),
        opts,
        cache,
        out,
        stats,
        shutdown,
    )
}

/// Intake state handed to the reader thread: parses lines, assigns
/// sequence numbers, applies resume-skip and admission control.
/// Everything it shares with the service is an owned channel end or an
/// `Arc` — the reader is detached and must not borrow the serve frame.
struct Feeder {
    /// 1-based physical input line counter (blank/comment lines count,
    /// matching `parse_jobs`' `job-<line>` id convention).
    lineno: u64,
    /// Next emit sequence number (row-producing lines only).
    seq: u64,
    queue_cap: usize,
    shed: bool,
    intake_tx: SyncSender<Task>,
    done_tx: Sender<(u64, JobResult)>,
    queue_depth: Arc<AtomicU64>,
    /// Ids already journaled by a previous run (resume mode).
    done_ids: HashSet<String>,
    skipped: Arc<AtomicU64>,
    /// Set when the service hung up; the reader loop should stop.
    closed: bool,
}

impl Feeder {
    fn feed_line(&mut self, raw: &str) {
        self.lineno += 1;
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            return;
        }
        match JobSpec::parse(line) {
            Ok(mut spec) => {
                if spec.id.is_empty() {
                    spec.id = format!("job-{}", self.lineno);
                }
                if self.done_ids.contains(&spec.id) {
                    self.skipped.fetch_add(1, Ordering::SeqCst);
                    return;
                }
                let task = Task { seq: self.seq, spec };
                self.seq += 1;
                if self.shed {
                    match self.intake_tx.try_send(task) {
                        Ok(()) => {
                            self.queue_depth.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(TrySendError::Full(t)) => {
                            let row = JobResult::failed(
                                &t.spec.id,
                                &t.spec.kernel,
                                "",
                                "overload",
                                format!(
                                    "admission queue full ({} jobs queued); job shed",
                                    self.queue_cap
                                ),
                            );
                            if self.done_tx.send((t.seq, row)).is_err() {
                                self.closed = true;
                            }
                        }
                        Err(TrySendError::Disconnected(_)) => self.closed = true,
                    }
                } else if self.intake_tx.send(task).is_ok() {
                    self.queue_depth.fetch_add(1, Ordering::SeqCst);
                } else {
                    self.closed = true;
                }
            }
            Err(e) => {
                // Same contract as batch: a malformed line becomes an
                // error row under its line-number id, never an abort.
                let id = format!("job-{}", self.lineno);
                if self.done_ids.contains(&id) {
                    self.skipped.fetch_add(1, Ordering::SeqCst);
                    return;
                }
                let row = JobResult::failed(&id, "", "", "spec", e);
                let seq = self.seq;
                self.seq += 1;
                if self.done_tx.send((seq, row)).is_err() {
                    self.closed = true;
                }
            }
        }
    }
}

/// Per-worker retry configuration (copied out of [`ServeOptions`] so
/// worker closures capture plain values).
struct RetryCfg {
    inner_threads: usize,
    retries: u32,
    backoff_ms: u64,
    deadline_ms: Option<u64>,
}

/// Run one job to a final row: default deadline applied, transient
/// failures (`io` / `panic` kinds, including escaped panics) retried
/// with capped exponential backoff, attempt count stamped on the row.
fn run_with_retry(
    spec: &JobSpec,
    cfg: &RetryCfg,
    cache: &PlanCache,
    pass_opts: &Options,
) -> JobResult {
    let mut eff = spec.clone();
    if eff.timeout_ms.is_none() {
        eff.timeout_ms = cfg.deadline_ms;
    }
    let mut attempt = 0u32;
    loop {
        attempt += 1;
        let run = || super::run_job_attempt(&eff, attempt, cfg.inner_threads, cache, pass_opts);
        let mut row = catch_unwind(AssertUnwindSafe(run)).unwrap_or_else(|payload| {
            JobResult::failed(&eff.id, &eff.kernel, "", "panic", cache::panic_message(&*payload))
        });
        let transient = matches!(&row.error, Some((kind, _)) if kind == "io" || kind == "panic");
        if transient && attempt <= cfg.retries {
            let delay = cfg
                .backoff_ms
                .saturating_mul(1u64 << (attempt - 1).min(5))
                .min(cfg.backoff_ms.saturating_mul(32))
                .min(10_000);
            std::thread::sleep(Duration::from_millis(delay));
            continue;
        }
        row.attempts = Some(attempt);
        return row;
    }
}

/// The service core shared by [`serve`] and [`serve_unix`]: spawn the
/// detached intake reader, run the worker pool under a scope, and emit
/// rows in input order from the calling thread.
fn serve_core(
    reader: Box<dyn FnOnce(Feeder) + Send + 'static>,
    opts: &ServeOptions,
    cache: &PlanCache,
    out: &mut dyn Write,
    stats: &mut dyn Write,
    shutdown: &AtomicU32,
) -> Result<ServeSummary> {
    // Resume set: ids journaled by previous runs of this stream.
    let mut done_ids = HashSet::new();
    if opts.resume {
        let Some(path) = &opts.journal else {
            bail!("--resume requires --journal (there is nothing to resume from)");
        };
        if let Ok(text) = std::fs::read_to_string(path) {
            for line in text.lines() {
                let line = line.trim();
                if !line.is_empty() && !line.starts_with('#') {
                    done_ids.insert(line.to_string());
                }
            }
        }
    }
    // Fresh runs truncate a stale journal (its ids describe a stream
    // this run is restarting from scratch); resumed runs append.
    let mut journal = match &opts.journal {
        Some(path) => Some(if opts.resume {
            OpenOptions::new()
                .create(true)
                .append(true)
                .open(path)
                .with_context(|| format!("opening journal {path}"))?
        } else {
            File::create(path).with_context(|| format!("creating journal {path}"))?
        }),
        None => None,
    };

    let pool_width = opts.fleet.pool.max(1);
    let (intake_tx, intake_rx) = mpsc::sync_channel::<Task>(opts.queue_cap.max(1));
    let (done_tx, done_rx) = mpsc::channel::<(u64, JobResult)>();
    let queue_depth = Arc::new(AtomicU64::new(0));
    let skipped = Arc::new(AtomicU64::new(0));
    let in_flight = AtomicU64::new(0);
    let workers_alive = AtomicUsize::new(pool_width);
    // Workers watch this, not `shutdown` directly: the emitter raises
    // it on drain *and* on an output write failure, so the pool can
    // never outlive its consumer.
    let stop = AtomicU32::new(0);

    let feeder = Feeder {
        lineno: 0,
        seq: 0,
        queue_cap: opts.queue_cap.max(1),
        shed: opts.shed,
        intake_tx,
        done_tx: done_tx.clone(),
        queue_depth: Arc::clone(&queue_depth),
        done_ids,
        skipped: Arc::clone(&skipped),
        closed: false,
    };
    // Detached on purpose: a reader blocked on stdin/accept cannot be
    // joined. It exits on EOF or when the service's channel ends drop.
    std::thread::Builder::new()
        .name("spada-serve-intake".into())
        .spawn(move || reader(feeder))
        .context("spawning intake reader")?;

    let retry_cfg = RetryCfg {
        inner_threads: opts.fleet.inner_threads(),
        retries: opts.retries,
        backoff_ms: opts.backoff_ms,
        deadline_ms: opts.deadline_ms,
    };
    let pass_opts = Options::default();
    let rx = Mutex::new(intake_rx);
    let start = Instant::now();
    let stats_every = opts.stats_every.filter(|&n| n > 0);

    std::thread::scope(|scope| -> Result<ServeSummary> {
        for _ in 0..pool_width {
            let done = done_tx.clone();
            let queue_depth = Arc::clone(&queue_depth);
            let (rx, stop, retry_cfg) = (&rx, &stop, &retry_cfg);
            let (in_flight, workers_alive, pass_opts) = (&in_flight, &workers_alive, &pass_opts);
            scope.spawn(move || {
                pool::drain_shared(rx, stop, |task: Task| {
                    queue_depth.fetch_sub(1, Ordering::SeqCst);
                    in_flight.fetch_add(1, Ordering::SeqCst);
                    let row = run_with_retry(&task.spec, retry_cfg, cache, pass_opts);
                    in_flight.fetch_sub(1, Ordering::SeqCst);
                    let _ = done.send((task.seq, row));
                });
                workers_alive.fetch_sub(1, Ordering::SeqCst);
            });
        }
        // Only the reader and the workers hold senders now, so the
        // emitter's channel disconnects exactly when both are done.
        drop(done_tx);

        let mut summary = ServeSummary::default();
        let emitted = (|| -> Result<()> {
            let mut pending: BTreeMap<u64, JobResult> = BTreeMap::new();
            let mut next_emit = 0u64;
            let mut flush = |pending: &mut BTreeMap<u64, JobResult>,
                             next_emit: &mut u64,
                             summary: &mut ServeSummary,
                             out: &mut dyn Write,
                             stats: &mut dyn Write,
                             journal: &mut Option<File>|
             -> Result<()> {
                while let Some(row) = pending.remove(next_emit) {
                    out.write_all(row.to_jsonl().as_bytes())?;
                    out.flush()?;
                    if let Some(j) = journal.as_mut() {
                        writeln!(j, "{}", row.id)?;
                        j.flush()?;
                    }
                    *next_emit += 1;
                    summary.rows += 1;
                    if row.ok() {
                        summary.ok += 1;
                    } else {
                        summary.errors += 1;
                    }
                    if matches!(&row.error, Some((kind, _)) if kind == "overload") {
                        summary.shed += 1;
                    }
                    if let Some(a) = row.attempts {
                        summary.retries += u64::from(a.saturating_sub(1));
                    }
                    if let Some(m) = &row.report {
                        summary.sim_cycles += m.cycles;
                    }
                    summary.skipped = skipped.load(Ordering::SeqCst);
                    if stats_every.is_some_and(|n| summary.rows % n == 0) {
                        write_stats_line(
                            stats,
                            "heartbeat",
                            summary,
                            cache,
                            queue_depth.load(Ordering::SeqCst),
                            in_flight.load(Ordering::SeqCst),
                            start.elapsed().as_millis() as u64,
                        )?;
                    }
                }
                Ok(())
            };
            loop {
                if shutdown.load(Ordering::SeqCst) > 0 {
                    stop.store(1, Ordering::SeqCst);
                }
                match done_rx.recv_timeout(Duration::from_millis(25)) {
                    Ok((seq, row)) => {
                        pending.insert(seq, row);
                        flush(
                            &mut pending,
                            &mut next_emit,
                            &mut summary,
                            out,
                            stats,
                            &mut journal,
                        )?;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::SeqCst) > 0
                            && workers_alive.load(Ordering::SeqCst) == 0
                        {
                            // Drain whatever already completed; rows
                            // beyond the first gap are discarded (the
                            // journal/resume path recomputes them).
                            while let Ok((seq, row)) = done_rx.try_recv() {
                                pending.insert(seq, row);
                            }
                            flush(
                                &mut pending,
                                &mut next_emit,
                                &mut summary,
                                out,
                                stats,
                                &mut journal,
                            )?;
                            return Ok(());
                        }
                    }
                    // Reader and workers all gone: input EOF, fully
                    // drained (a receiver yields its buffer before
                    // reporting disconnect).
                    Err(RecvTimeoutError::Disconnected) => {
                        flush(
                            &mut pending,
                            &mut next_emit,
                            &mut summary,
                            out,
                            stats,
                            &mut journal,
                        )?;
                        return Ok(());
                    }
                }
            }
        })();
        // Whatever happened, release the pool before leaving the scope
        // (scope exit joins the workers).
        stop.store(1, Ordering::SeqCst);
        emitted?;
        summary.drained = shutdown.load(Ordering::SeqCst) > 0;
        summary.skipped = skipped.load(Ordering::SeqCst);
        write_stats_line(
            stats,
            "final",
            &summary,
            cache,
            queue_depth.load(Ordering::SeqCst),
            in_flight.load(Ordering::SeqCst),
            start.elapsed().as_millis() as u64,
        )?;
        Ok(summary)
    })
}

/// One heartbeat/final stats line: service counters plus the cache's
/// reconciling counter set. Wall-clock (`uptime_ms`) is allowed here —
/// this stream is operator telemetry, never part of the row contract.
fn write_stats_line(
    stats: &mut dyn Write,
    event: &str,
    s: &ServeSummary,
    cache: &PlanCache,
    queue_depth: u64,
    in_flight: u64,
    uptime_ms: u64,
) -> Result<()> {
    let mut line = format!(
        "{{\"event\":\"{event}\",\"rows\":{},\"ok\":{},\"errors\":{},\"shed\":{},\
         \"skipped\":{},\"retries\":{},\"queue_depth\":{queue_depth},\
         \"in_flight\":{in_flight},\"sim_cycles\":{},\"uptime_ms\":{uptime_ms},\
         \"cache\":{{\"lookups\":{},\"hits\":{},\"misses\":{},\"evictions\":{},\
         \"entries\":{},\"bytes\":{}}}",
        s.rows,
        s.ok,
        s.errors,
        s.shed,
        s.skipped,
        s.retries,
        s.sim_cycles,
        cache.lookups(),
        cache.hits(),
        cache.misses(),
        cache.evictions(),
        cache.len(),
        cache.bytes(),
    );
    if event == "final" {
        line.push_str(&format!(",\"drained\":{}", s.drained));
    }
    line.push_str("}\n");
    stats.write_all(line.as_bytes())?;
    stats.flush()?;
    Ok(())
}

//! Strided half-open integer ranges and 2-D subgrids.

use std::fmt;

/// A strided half-open range `[start : stop : step]`, `step >= 1`.
///
/// Membership: `x ∈ r ⇔ start <= x < stop ∧ (x - start) % step == 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Range1 {
    pub start: i64,
    pub stop: i64,
    pub step: i64,
}

impl fmt::Debug for Range1 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.step == 1 {
            write!(f, "[{}:{}]", self.start, self.stop)
        } else {
            write!(f, "[{}:{}:{}]", self.start, self.stop, self.step)
        }
    }
}

impl Range1 {
    pub fn new(start: i64, stop: i64, step: i64) -> Self {
        assert!(step >= 1, "range step must be >= 1, got {step}");
        Range1 { start, stop, step }
    }

    /// A single point `[v : v+1]`.
    pub fn point(v: i64) -> Self {
        Range1::new(v, v + 1, 1)
    }

    /// Dense range `[start : stop]`.
    pub fn dense(start: i64, stop: i64) -> Self {
        Range1::new(start, stop, 1)
    }

    pub fn is_empty(&self) -> bool {
        self.start >= self.stop
    }

    /// Number of members.
    pub fn len(&self) -> i64 {
        if self.is_empty() {
            0
        } else {
            (self.stop - self.start + self.step - 1) / self.step
        }
    }

    pub fn contains(&self, x: i64) -> bool {
        x >= self.start && x < self.stop && (x - self.start) % self.step == 0
    }

    /// Iterate members.
    pub fn iter(&self) -> impl Iterator<Item = i64> + '_ {
        (0..self.len()).map(move |k| self.start + k * self.step)
    }

    /// The `k`-th member.
    pub fn at(&self, k: i64) -> i64 {
        debug_assert!(k >= 0 && k < self.len());
        self.start + k * self.step
    }

    /// Index of member `x` (inverse of [`Range1::at`]), if present.
    pub fn index_of(&self, x: i64) -> Option<i64> {
        if self.contains(x) {
            Some((x - self.start) / self.step)
        } else {
            None
        }
    }

    /// Last member, if non-empty.
    pub fn last(&self) -> Option<i64> {
        if self.is_empty() {
            None
        } else {
            Some(self.at(self.len() - 1))
        }
    }

    /// Canonical form: `stop` trimmed to `last + 1`; empty ranges map to
    /// `[0:0:1]`. Canonical ranges compare equal iff they have the same
    /// member set (for step-1 and equal-step ranges).
    pub fn canonical(&self) -> Self {
        match self.last() {
            None => Range1::new(0, 0, 1),
            Some(l) => {
                let step = if self.len() == 1 { 1 } else { self.step };
                Range1::new(self.start, l + 1, step)
            }
        }
    }

    /// Intersection of two strided ranges (CRT on the strides).
    pub fn intersect(&self, other: &Range1) -> Range1 {
        if self.is_empty() || other.is_empty() {
            return Range1::new(0, 0, 1);
        }
        // Solve x ≡ self.start (mod self.step), x ≡ other.start (mod other.step).
        let Some((x0, lcm)) = crt2(self.start, self.step, other.start, other.step) else {
            return Range1::new(0, 0, 1);
        };
        // Smallest solution >= lo.
        let lo = self.start.max(other.start);
        let first = lo + (x0 - lo).rem_euclid(lcm);
        let hi = self.stop.min(other.stop);
        Range1::new(first, hi.max(first), lcm).canonical()
    }

    /// Split `self` by parity of members: (even-members, odd-members).
    /// Each part is again a strided range.
    pub fn split_parity(&self) -> (Range1, Range1) {
        let empty = Range1::new(0, 0, 1);
        if self.is_empty() {
            return (empty, empty);
        }
        if self.step % 2 == 0 {
            // All members share the parity of start.
            if self.start % 2 == 0 {
                (self.canonical(), empty)
            } else {
                (empty, self.canonical())
            }
        } else {
            // Members alternate parity; same-parity members are 2*step apart.
            let mk = |first: i64| -> Range1 {
                if first < self.stop {
                    Range1::new(first, self.stop, 2 * self.step).canonical()
                } else {
                    empty
                }
            };
            let (first_even, first_odd) = if self.start % 2 == 0 {
                (self.start, self.start + self.step)
            } else {
                (self.start + self.step, self.start)
            };
            (mk(first_even), mk(first_odd))
        }
    }

    /// Remove `other` from `self`, returning up to 3 disjoint ranges that
    /// cover `self \ other` exactly (only supported when both have step 1
    /// or `other` fully covers a contiguous stretch).
    pub fn subtract_dense(&self, other: &Range1) -> Vec<Range1> {
        assert_eq!(self.step, 1);
        assert_eq!(other.step, 1);
        let mut out = vec![];
        let lo = Range1::dense(self.start, self.stop.min(other.start));
        let hi = Range1::dense(self.start.max(other.stop), self.stop);
        if !lo.is_empty() {
            out.push(lo);
        }
        if !hi.is_empty() {
            out.push(hi);
        }
        out
    }
}

/// Extended gcd: returns (g, x, y) with a*x + b*y = g.
fn egcd(a: i64, b: i64) -> (i64, i64, i64) {
    if b == 0 {
        (a, 1, 0)
    } else {
        let (g, x, y) = egcd(b, a % b);
        (g, y, x - (a / b) * y)
    }
}

/// Chinese remainder for x ≡ r1 (mod m1), x ≡ r2 (mod m2).
/// Returns Some((x0, lcm)) where x0 is one solution (all solutions are
/// x0 + k·lcm), or None if the congruences are incompatible.
fn crt2(r1: i64, m1: i64, r2: i64, m2: i64) -> Option<(i64, i64)> {
    let (g, p, _q) = egcd(m1, m2);
    if (r2 - r1) % g != 0 {
        return None;
    }
    let lcm = m1 / g * m2;
    // x0 = r1 + m1 * ((r2 - r1)/g * p mod (m2/g))
    let m2g = m2 / g;
    let t = mod_mul((r2 - r1) / g, p.rem_euclid(m2g), m2g);
    let x0 = r1 + m1 * t;
    Some((x0.rem_euclid(lcm), lcm))
}

fn mod_mul(a: i64, b: i64, m: i64) -> i64 {
    if m == 0 {
        return 0;
    }
    ((a as i128 * b as i128).rem_euclid(m as i128)) as i64
}

/// A 2-D strided rectangle of PE coordinates: `dims[0]` ranges over the
/// first (x / west-east) coordinate, `dims[1]` over the second (y /
/// north-south) coordinate.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct Subgrid {
    pub dims: [Range1; 2],
}

impl fmt::Debug for Subgrid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}x{:?}", self.dims[0], self.dims[1])
    }
}

impl Subgrid {
    pub fn new(x: Range1, y: Range1) -> Self {
        Subgrid { dims: [x, y] }
    }

    /// Full dense rectangle `[0:w, 0:h]`.
    pub fn rect(w: i64, h: i64) -> Self {
        Subgrid::new(Range1::dense(0, w), Range1::dense(0, h))
    }

    pub fn point(x: i64, y: i64) -> Self {
        Subgrid::new(Range1::point(x), Range1::point(y))
    }

    pub fn is_empty(&self) -> bool {
        self.dims[0].is_empty() || self.dims[1].is_empty()
    }

    pub fn len(&self) -> i64 {
        self.dims[0].len() * self.dims[1].len()
    }

    pub fn contains(&self, x: i64, y: i64) -> bool {
        self.dims[0].contains(x) && self.dims[1].contains(y)
    }

    pub fn intersect(&self, other: &Subgrid) -> Subgrid {
        Subgrid::new(
            self.dims[0].intersect(&other.dims[0]),
            self.dims[1].intersect(&other.dims[1]),
        )
    }

    /// Iterate all (x, y) members, x-major.
    pub fn iter(&self) -> impl Iterator<Item = (i64, i64)> + '_ {
        self.dims[0]
            .iter()
            .flat_map(move |x| self.dims[1].iter().map(move |y| (x, y)))
    }

    /// Checkerboard split along dimension `d` (0 = x, 1 = y):
    /// (even-coordinate part, odd-coordinate part).
    pub fn split_parity(&self, d: usize) -> (Subgrid, Subgrid) {
        let (e, o) = self.dims[d].split_parity();
        let mut ev = self.clone();
        let mut od = self.clone();
        ev.dims[d] = e;
        od.dims[d] = o;
        (ev, od)
    }

    pub fn canonical(&self) -> Subgrid {
        Subgrid::new(self.dims[0].canonical(), self.dims[1].canonical())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_len_iter() {
        let r = Range1::new(0, 10, 3);
        assert_eq!(r.len(), 4);
        assert_eq!(r.iter().collect::<Vec<_>>(), vec![0, 3, 6, 9]);
        assert_eq!(r.last(), Some(9));
        assert!(r.contains(6));
        assert!(!r.contains(7));
        assert_eq!(r.index_of(6), Some(2));
        assert_eq!(r.index_of(7), None);
    }

    #[test]
    fn range_empty() {
        let r = Range1::new(5, 5, 1);
        assert!(r.is_empty());
        assert_eq!(r.len(), 0);
        assert_eq!(r.last(), None);
        assert_eq!(r.canonical(), Range1::new(0, 0, 1));
    }

    #[test]
    fn range_intersect_dense() {
        let a = Range1::dense(0, 10);
        let b = Range1::dense(5, 20);
        let c = a.intersect(&b);
        assert_eq!(c.iter().collect::<Vec<_>>(), (5..10).collect::<Vec<_>>());
    }

    #[test]
    fn range_intersect_strided() {
        let a = Range1::new(0, 20, 2); // evens
        let b = Range1::new(1, 20, 3); // 1,4,7,10,13,16,19
        let c = a.intersect(&b);
        assert_eq!(c.iter().collect::<Vec<_>>(), vec![4, 10, 16]);
    }

    #[test]
    fn range_intersect_disjoint_strides() {
        let a = Range1::new(0, 20, 2);
        let b = Range1::new(1, 20, 2);
        assert!(a.intersect(&b).is_empty());
    }

    #[test]
    fn split_parity_dense() {
        let r = Range1::dense(1, 8); // 1..7
        let (e, o) = r.split_parity();
        assert_eq!(e.iter().collect::<Vec<_>>(), vec![2, 4, 6]);
        assert_eq!(o.iter().collect::<Vec<_>>(), vec![1, 3, 5, 7]);
    }

    #[test]
    fn split_parity_strided() {
        let r = Range1::new(1, 12, 2); // 1,3,5,7,9,11 — all odd
        let (e, o) = r.split_parity();
        assert!(e.is_empty());
        assert_eq!(o.iter().collect::<Vec<_>>(), vec![1, 3, 5, 7, 9, 11]);
    }

    #[test]
    fn subgrid_iter_contains() {
        let g = Subgrid::new(Range1::dense(0, 3), Range1::new(0, 4, 2));
        assert_eq!(g.len(), 6);
        assert!(g.contains(2, 2));
        assert!(!g.contains(2, 1));
        let pts: Vec<_> = g.iter().collect();
        assert_eq!(pts.len(), 6);
        assert_eq!(pts[0], (0, 0));
    }

    #[test]
    fn subgrid_checkerboard() {
        let g = Subgrid::rect(4, 4);
        let (e, o) = g.split_parity(0);
        assert_eq!(e.len() + o.len(), 16);
        for (x, _) in e.iter() {
            assert_eq!(x % 2, 0);
        }
        for (x, _) in o.iter() {
            assert_eq!(x % 2, 1);
        }
    }

    #[test]
    fn subtract_dense() {
        let a = Range1::dense(0, 10);
        let b = Range1::dense(3, 6);
        let parts = a.subtract_dense(&b);
        let members: Vec<i64> = parts.iter().flat_map(|r| r.iter().collect::<Vec<_>>()).collect();
        assert_eq!(members, vec![0, 1, 2, 6, 7, 8, 9]);
    }
}

//! Grid/range utilities shared by the compiler and simulator.
//!
//! SpaDA blocks are defined over *subgrids*: strided half-open ranges per
//! dimension (`[0:I:2, 1:J-1]`). The canonicalization pass computes PE
//! equivalence classes by intersecting and splitting these rectangles, so
//! the strided-range algebra here is load-bearing for the whole pipeline.

pub mod range;
pub mod rng;

pub use range::{Range1, Subgrid};
pub use rng::SplitMix64;

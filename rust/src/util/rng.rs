//! Deterministic RNG for workload generation (no external deps).

/// SplitMix64 — tiny, deterministic, good enough for synthetic workloads.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [-1, 1).
    pub fn next_f32(&mut self) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        2.0 * u - 1.0
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_range() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_f32();
            assert!((-1.0..1.0).contains(&v));
        }
    }
}

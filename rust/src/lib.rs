//! SpaDA — A Spatial Dataflow Architecture Programming Language.
//!
//! This crate reproduces the SpaDA system (CS.DC 2025): a programming
//! language and optimizing compiler for spatial dataflow architectures
//! (the Cerebras WSE-2), together with the substrate the paper depends on
//! — here, a discrete-event WSE-2 fabric/PE simulator — and the full
//! benchmark harness that regenerates every table and figure of the
//! paper's evaluation.
//!
//! Architecture (three layers):
//! - **L3 (this crate)**: the SpaDA compiler ([`spada`] → [`sem`] → [`ir`]
//!   → [`passes`] → [`csl`]), the static dataflow semantics checker
//!   ([`analysis`]: routing correctness, data-race and deadlock
//!   verification between lowering and execution), the WSE-2 simulator
//!   ([`machine`]), the GT4Py-style stencil frontend ([`frontend`]),
//!   the batch fleet engine ([`fleet`]: plan cache + job queue behind
//!   `spada batch`), baselines and the experiment harness ([`harness`]).
//! - **L2/L1 (python/, build-time only)**: JAX reference compute graphs and
//!   Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//! - **Runtime bridge** ([`runtime`]): PJRT CPU client that loads the AOT
//!   artifacts and serves as the numerical oracle for simulator outputs.

pub mod util;
pub mod machine;
pub mod spada;
pub mod sem;
pub mod ir;
pub mod passes;
pub mod csl;
pub mod analysis;
pub mod frontend;
pub mod kernels;
pub mod sparse;
pub mod baselines;
pub mod fleet;
pub mod harness;
pub mod runtime;
pub mod bench;
pub mod ptest;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

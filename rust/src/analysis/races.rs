//! Data-race detection: unsequenced writers to one channel endpoint,
//! and host-I/O port collisions.
//!
//! Arrival order on a (PE, color) endpoint is only defined when every
//! wavelet is issued from one core (program order) — two distinct
//! source PEs delivering to the same endpoint interleave
//! nondeterministically, which the paper's semantics classifies as a
//! data race regardless of the payload.

use super::flowgraph::FlowGraph;
use super::{AnalysisReport, DiagKind, Diagnostic, Severity};
use crate::machine::{IoDir, MachineProgram};
use std::collections::HashMap;

pub fn check_races(prog: &MachineProgram, graph: &FlowGraph, report: &mut AnalysisReport) {
    check_endpoint_races(graph, report);
    check_output_port_collisions(prog, report);
}

/// Two flows from distinct source PEs delivering to one (PE, color)
/// endpoint race: their wavelets interleave in link order, not program
/// order.
fn check_endpoint_races(graph: &FlowGraph, report: &mut AnalysisReport) {
    let mut keys: Vec<_> = graph.deliveries.keys().copied().collect();
    keys.sort_unstable();
    for (pi, color) in keys {
        let flows = &graph.deliveries[&(pi, color)];
        let mut sources: Vec<(i64, i64)> =
            flows.iter().map(|&fi| graph.flows[fi].src).collect();
        sources.sort_unstable();
        sources.dedup();
        if sources.len() < 2 {
            continue;
        }
        let (x, y, _) = graph.pes[pi];
        report.push(Diagnostic {
            kind: DiagKind::DataRace,
            severity: Severity::Error,
            pe: Some((x, y)),
            color: Some(color),
            task: None,
            message: format!(
                "endpoint receives from {} distinct source PEs ({}): arrival order is \
                 unsequenced — a data race",
                sources.len(),
                sources
                    .iter()
                    .map(|(sx, sy)| format!("({sx},{sy})"))
                    .collect::<Vec<_>>()
                    .join(", ")
            ),
        });
    }
}

/// Two PEs bound to the same port of one output argument overwrite each
/// other in host memory — the host-side flavor of a two-writer race.
fn check_output_port_collisions(prog: &MachineProgram, report: &mut AnalysisReport) {
    let mut args: Vec<&str> = prog
        .io
        .iter()
        .filter(|b| b.dir == IoDir::Out)
        .map(|b| b.arg.as_str())
        .collect();
    args.sort_unstable();
    args.dedup();
    for arg in args {
        let mut owner: HashMap<i64, (i64, i64)> = HashMap::new();
        for binding in prog.io.iter().filter(|b| b.dir == IoDir::Out && b.arg == arg) {
            for (x, y) in binding.subgrid.iter() {
                let port = binding.port_map.port(x, y);
                match owner.get(&port) {
                    None => {
                        owner.insert(port, (x, y));
                    }
                    Some(&(ox, oy)) if (ox, oy) != (x, y) => {
                        report.push(Diagnostic {
                            kind: DiagKind::DataRace,
                            severity: Severity::Error,
                            pe: Some((x, y)),
                            color: None,
                            task: None,
                            message: format!(
                                "output argument {arg} port {port} is written by both \
                                 PE ({ox},{oy}) and PE ({x},{y})"
                            ),
                        });
                    }
                    Some(_) => {}
                }
            }
        }
    }
}

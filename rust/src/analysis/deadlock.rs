//! Deadlock verification: a monotone progress fixpoint over the
//! wait-for structure of channel consumers, producers and task
//! activations.
//!
//! The machine model's only blocking constructs are (a) asynchronous
//! `FabIn` consumers, which complete when enough wavelets reach their
//! (PE, color) endpoint, and (b) task activation/unblocking, driven by
//! `Control` ops and async-op completions. The fixpoint optimistically
//! propagates progress — a task that can start issues all its fabric
//! ops, deliveries accumulate along traced flows, completions fire
//! their actions — until nothing changes. Whatever is still waiting can
//! *never* be satisfied (the abstraction over-approximates progress),
//! so every leftover consumer is a genuine static deadlock: either
//! starvation (no producer reaches the endpoint), a wavelet-count
//! shortfall, or a circular wait, which is reported with the cycle
//! spelled out PE by PE.

use super::flowgraph::{eval_const, FlowGraph, Trigger};
use super::{AnalysisReport, DiagKind, Diagnostic, Severity};
use crate::machine::program::TaskActionKind;
use crate::machine::MachineProgram;
use std::collections::{HashMap, HashSet};

/// Wavelets accumulated at one endpoint.
#[derive(Clone, Copy, Debug, Default)]
struct Delivered {
    known: i64,
    /// Some contribution had a statically unknown count.
    unknown: bool,
}

impl Delivered {
    fn any(&self) -> bool {
        self.known > 0 || self.unknown
    }

    fn satisfies(&self, need: Option<i64>) -> bool {
        match need {
            _ if self.unknown => self.any(),
            Some(n) => self.known >= n,
            None => self.any(),
        }
    }
}

/// The whole fixpoint state, flattened over (PE, task).
struct State<'g> {
    graph: &'g FlowGraph,
    /// Global task index base per PE.
    base: Vec<usize>,
    activated: Vec<bool>,
    unblocked: Vec<bool>,
    running: Vec<bool>,
    consume_done: Vec<Vec<bool>>,
    produce_issued: Vec<Vec<bool>>,
    delivered: HashMap<(usize, u8), Delivered>,
    /// hw id → task index, per class.
    hw_map: Vec<HashMap<u8, usize>>,
}

impl<'g> State<'g> {
    fn gid(&self, pi: usize, ti: usize) -> usize {
        self.base[pi] + ti
    }

    fn model(&self, pi: usize, ti: usize) -> &super::flowgraph::TaskModel {
        let (_, _, ci) = self.graph.pes[pi];
        &self.graph.models[ci][ti]
    }

    fn data_received(&self, pi: usize, ti: usize) -> bool {
        match self.model(pi, ti).data_color {
            Some(c) => self.delivered.get(&(pi, c)).map(|d| d.any()).unwrap_or(false),
            None => false,
        }
    }

    /// Does this task execute its body at least once?
    fn runs(&self, pi: usize, ti: usize) -> bool {
        let m = self.model(pi, ti);
        if m.data_color.is_some() {
            self.data_received(pi, ti)
        } else {
            self.running[self.gid(pi, ti)]
        }
    }

    fn trigger_fired(&self, pi: usize, ti: usize, trigger: Trigger) -> bool {
        let g = self.gid(pi, ti);
        match trigger {
            Trigger::OnRun => self.runs(pi, ti),
            Trigger::OnConsume(i) => self.consume_done[g][i],
            Trigger::OnProduce(i) => self.produce_issued[g][i],
            Trigger::OnWavelets(th) => {
                let Some(c) = self.model(pi, ti).data_color else { return false };
                self.delivered
                    .get(&(pi, c))
                    .map(|d| d.satisfies(th))
                    .unwrap_or(false)
            }
        }
    }
}

pub fn check_deadlock(prog: &MachineProgram, graph: &FlowGraph, report: &mut AnalysisReport) {
    if graph.pes.is_empty() {
        return;
    }
    let mut st = init_state(prog, graph);
    run_fixpoint(&mut st);
    report_stuck(prog, graph, &st, report);
}

fn init_state<'g>(prog: &MachineProgram, graph: &'g FlowGraph) -> State<'g> {
    let mut base = Vec::with_capacity(graph.pes.len());
    let mut total = 0usize;
    for &(_, _, ci) in &graph.pes {
        base.push(total);
        total += graph.models[ci].len();
    }
    let hw_map: Vec<HashMap<u8, usize>> = graph
        .models
        .iter()
        .map(|ms| ms.iter().enumerate().map(|(i, m)| (m.hw_id, i)).collect())
        .collect();

    let mut st = State {
        graph,
        base,
        activated: vec![false; total],
        unblocked: vec![false; total],
        running: vec![false; total],
        consume_done: vec![vec![]; total],
        produce_issued: vec![vec![]; total],
        delivered: HashMap::new(),
        hw_map,
    };
    for (pi, &(_, _, ci)) in graph.pes.iter().enumerate() {
        for (ti, m) in graph.models[ci].iter().enumerate() {
            let g = st.gid(pi, ti);
            st.activated[g] = m.initially_active;
            st.unblocked[g] = !m.initially_blocked;
            st.consume_done[g] = vec![false; m.consumes.len()];
            st.produce_issued[g] = vec![false; m.produces.len()];
        }
        for hw in &prog.classes[ci].entry_tasks {
            if let Some(&ti) = st.hw_map[ci].get(hw) {
                let g = st.gid(pi, ti);
                st.activated[g] = true;
            }
        }
    }
    st
}

fn run_fixpoint(st: &mut State<'_>) {
    let npes = st.graph.pes.len();
    loop {
        let mut changed = false;
        for pi in 0..npes {
            let (x, y, ci) = st.graph.pes[pi];
            let ntasks = st.graph.models[ci].len();
            for ti in 0..ntasks {
                let g = st.gid(pi, ti);
                // Local tasks start once activated and unblocked.
                let is_data = st.graph.models[ci][ti].data_color.is_some();
                if !is_data && st.activated[g] && st.unblocked[g] && !st.running[g] {
                    st.running[g] = true;
                    changed = true;
                }
                if !st.runs(pi, ti) {
                    continue;
                }
                // Issue produces: wavelets accumulate at every traced
                // destination endpoint. Fused accumulate-and-forward ops
                // only emit once their paired consume completes.
                for oi in 0..st.graph.models[ci][ti].produces.len() {
                    if st.produce_issued[g][oi] {
                        continue;
                    }
                    let gate = st.graph.models[ci][ti].produces[oi].after_consume;
                    if let Some(ci_gate) = gate {
                        if !st.consume_done[g][ci_gate] {
                            continue;
                        }
                    }
                    st.produce_issued[g][oi] = true;
                    changed = true;
                    let p = &st.graph.models[ci][ti].produces[oi];
                    let count = if is_data || p.conditional {
                        None // per-wavelet or guarded: count unknown
                    } else {
                        let len = eval_const(&p.len, x, y);
                        let trips =
                            p.trips.as_ref().and_then(|t| eval_const(t, x, y));
                        match (len, trips) {
                            (Some(l), Some(t)) => Some(l * t),
                            _ => None,
                        }
                    };
                    if let Some(&fi) = st.graph.flow_lookup.get(&(x, y, p.color)) {
                        if let Ok(path) = &st.graph.flows[fi].path {
                            for (dx, dy, _) in &path.dests {
                                if let Some(&di) = st.graph.pe_lookup.get(&(*dx, *dy)) {
                                    let entry = st
                                        .delivered
                                        .entry((di, p.color))
                                        .or_default();
                                    match count {
                                        Some(n) => entry.known += n,
                                        None => entry.unknown = true,
                                    }
                                }
                            }
                        }
                    }
                }
                // Complete consumes whose endpoint is satisfied.
                for coi in 0..st.graph.models[ci][ti].consumes.len() {
                    if st.consume_done[g][coi] {
                        continue;
                    }
                    let c = &st.graph.models[ci][ti].consumes[coi];
                    let need = eval_const(&c.len, x, y);
                    let ok = st
                        .delivered
                        .get(&(pi, c.color))
                        .map(|d| d.satisfies(need))
                        .unwrap_or(false);
                    if ok {
                        st.consume_done[g][coi] = true;
                        changed = true;
                    }
                }
            }
        }
        // Fire every satisfied action site.
        for pi in 0..npes {
            let (_, _, ci) = st.graph.pes[pi];
            for ti in 0..st.graph.models[ci].len() {
                let nacts = st.graph.models[ci][ti].actions.len();
                for ai in 0..nacts {
                    let site = st.graph.models[ci][ti].actions[ai].clone();
                    if !st.trigger_fired(pi, ti, site.trigger) {
                        continue;
                    }
                    if let Some(&target) = st.hw_map[ci].get(&site.action.task) {
                        let tg = st.gid(pi, target);
                        match site.action.kind {
                            TaskActionKind::Activate => {
                                if !st.activated[tg] {
                                    st.activated[tg] = true;
                                    changed = true;
                                }
                            }
                            TaskActionKind::Unblock => {
                                if !st.unblocked[tg] {
                                    st.unblocked[tg] = true;
                                    changed = true;
                                }
                            }
                            // Blocking never *prevents* progress in the
                            // optimistic abstraction.
                            TaskActionKind::Block => {}
                        }
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
}

/// A node in the blocked-why explanation walk.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Why {
    Consume(usize, usize, usize),
    Task(usize, usize),
}

fn report_stuck(
    prog: &MachineProgram,
    graph: &FlowGraph,
    st: &State<'_>,
    report: &mut AnalysisReport,
) {
    for pi in 0..graph.pes.len() {
        let (x, y, ci) = graph.pes[pi];
        for ti in 0..graph.models[ci].len() {
            if !st.runs(pi, ti) {
                continue;
            }
            let g = st.gid(pi, ti);
            let model = &graph.models[ci][ti];
            for (coi, c) in model.consumes.iter().enumerate() {
                if st.consume_done[g][coi] {
                    continue;
                }
                let delivered = st.delivered.get(&(pi, c.color)).copied().unwrap_or_default();
                if c.conditional && delivered.any() {
                    // Guarded by a runtime branch and partially fed:
                    // cannot statically prove it ever runs short.
                    continue;
                }
                let task_name = format!("{}.{}", prog.classes[ci].name, model.name);
                let all_flows = graph
                    .deliveries
                    .get(&(pi, c.color))
                    .cloned()
                    .unwrap_or_default();
                if all_flows.is_empty() {
                    // A consume behind a genuine runtime conditional may
                    // never execute; without a disproof, only warn.
                    let severity =
                        if c.conditional { Severity::Warning } else { Severity::Error };
                    report.push(Diagnostic {
                        kind: DiagKind::Starvation,
                        severity,
                        pe: Some((x, y)),
                        color: Some(c.color),
                        task: Some(task_name),
                        message: format!(
                            "consumer waits on color {} but no flow ever delivers to this \
                             PE (the simulator would report SimError::Deadlock here)",
                            c.color
                        ),
                    });
                    continue;
                }
                // Some producer exists — either it never issues
                // (circular wait) or it under-delivers.
                if let Some(cycle) = find_cycle(graph, st, pi, ti, coi) {
                    report.push(Diagnostic {
                        kind: DiagKind::Deadlock,
                        severity: Severity::Error,
                        pe: Some((x, y)),
                        color: Some(c.color),
                        task: Some(task_name),
                        message: format!("circular wait: {}", cycle.join(" <- ")),
                    });
                } else {
                    let need = eval_const(&c.len, x, y);
                    let detail = match need {
                        Some(n) => format!(
                            "waiting for {} more wavelets",
                            (n - delivered.known).max(1)
                        ),
                        None => "waiting for wavelets".to_string(),
                    };
                    report.push(Diagnostic {
                        kind: DiagKind::Deadlock,
                        severity: Severity::Error,
                        pe: Some((x, y)),
                        color: Some(c.color),
                        task: Some(task_name),
                        message: format!(
                            "consumer can never be satisfied: {detail} on color {} \
                             (producers deliver {} statically known wavelets)",
                            c.color, delivered.known
                        ),
                    });
                }
            }
        }
    }
}

/// Walk the blocked-because relation from a stuck consume, looking for
/// a cycle back to itself. Returns the human-readable cycle on success.
fn find_cycle(
    graph: &FlowGraph,
    st: &State<'_>,
    pi: usize,
    ti: usize,
    coi: usize,
) -> Option<Vec<String>> {
    let start = Why::Consume(pi, ti, coi);
    let mut stack: Vec<Why> = vec![];
    let mut visited: HashSet<Why> = HashSet::new();
    let mut labels: Vec<String> = vec![];

    fn describe(graph: &FlowGraph, node: Why) -> String {
        match node {
            Why::Consume(pi, ti, coi) => {
                let (x, y, ci) = graph.pes[pi];
                let m = &graph.models[ci][ti];
                format!(
                    "PE ({x},{y}) task {} awaiting color {}",
                    m.name, m.consumes[coi].color
                )
            }
            Why::Task(pi, ti) => {
                let (x, y, ci) = graph.pes[pi];
                format!("PE ({x},{y}) task {} never starts", graph.models[ci][ti].name)
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn visit(
        graph: &FlowGraph,
        st: &State<'_>,
        node: Why,
        start: Why,
        stack: &mut Vec<Why>,
        visited: &mut HashSet<Why>,
        labels: &mut Vec<String>,
        depth: usize,
    ) -> bool {
        if depth > 64 {
            return false;
        }
        if node == start && !stack.is_empty() {
            return true; // closed the loop
        }
        if !visited.insert(node) {
            return false;
        }
        stack.push(node);
        labels.push(describe(graph, node));
        let found = match node {
            Why::Consume(pi, ti, coi) => {
                let c = &graph.models[graph.pes[pi].2][ti].consumes[coi];
                let flows = graph
                    .deliveries
                    .get(&(pi, c.color))
                    .cloned()
                    .unwrap_or_default();
                let mut hit = false;
                for fi in flows {
                    for &(ppi, pti, poi) in &graph.flows[fi].producers {
                        let pg = st.gid(ppi, pti);
                        if st.produce_issued[pg][poi] {
                            continue;
                        }
                        // Why didn't the producer emit? Either its task
                        // never starts, or (fused form) its own consume
                        // is stuck.
                        let pmodel = &graph.models[graph.pes[ppi].2][pti];
                        let next = match pmodel.produces[poi].after_consume {
                            Some(gci) if !st.consume_done[pg][gci] => {
                                Why::Consume(ppi, pti, gci)
                            }
                            _ => Why::Task(ppi, pti),
                        };
                        if visit(graph, st, next, start, stack, visited, labels, depth + 1)
                        {
                            hit = true;
                            break;
                        }
                    }
                    if hit {
                        break;
                    }
                }
                hit
            }
            Why::Task(pi, ti) => {
                // The task never starts: follow the action sites that
                // would have activated / unblocked it.
                let (_, _, ci) = graph.pes[pi];
                let hw = graph.models[ci][ti].hw_id;
                let mut hit = false;
                'outer: for (oti, om) in graph.models[ci].iter().enumerate() {
                    for site in &om.actions {
                        if site.action.task != hw {
                            continue;
                        }
                        if st.trigger_fired(pi, oti, site.trigger) {
                            continue; // this source fired; look elsewhere
                        }
                        let next = match site.trigger {
                            Trigger::OnConsume(i) => Some(Why::Consume(pi, oti, i)),
                            Trigger::OnRun | Trigger::OnProduce(_) => Some(Why::Task(pi, oti)),
                            Trigger::OnWavelets(_) => None,
                        };
                        if let Some(next) = next {
                            if visit(
                                graph,
                                st,
                                next,
                                start,
                                stack,
                                visited,
                                labels,
                                depth + 1,
                            ) {
                                hit = true;
                                break 'outer;
                            }
                        }
                    }
                }
                hit
            }
        };
        if !found {
            stack.pop();
            labels.pop();
        }
        found
    }

    if visit(graph, st, start, start, &mut stack, &mut visited, &mut labels, 0) {
        labels.push(describe(graph, start));
        Some(labels)
    } else {
        None
    }
}

//! Routing-correctness checks (paper §V-B's invariant, verified rather
//! than assumed): every flow must resolve to a well-formed circuit, and
//! no router or link may carry an ambiguous configuration.

use super::flowgraph::FlowGraph;
use super::{AnalysisReport, DiagKind, Diagnostic, Severity};
use crate::machine::{Direction, MachineConfig, MachineProgram};
use std::collections::HashMap;

pub fn check_routing(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    graph: &FlowGraph,
    report: &mut AnalysisReport,
) {
    check_rule_ambiguity(prog, report);
    check_flow_traces(prog, graph, report);
    check_link_sharing(graph, report);
    let _ = cfg;
}

/// One router holds exactly one configuration per color: two distinct
/// route rules for the same color whose subgrids overlap are ambiguous.
fn check_rule_ambiguity(prog: &MachineProgram, report: &mut AnalysisReport) {
    for i in 0..prog.routes.len() {
        for j in (i + 1)..prog.routes.len() {
            let (a, b) = (&prog.routes[i], &prog.routes[j]);
            if a.color != b.color {
                continue;
            }
            let shared = a.subgrid.intersect(&b.subgrid);
            if shared.is_empty() {
                continue;
            }
            if a.rx == b.rx && a.tx == b.tx {
                continue; // identical duplicate — harmless
            }
            let pe = shared.iter().next();
            report.push(Diagnostic {
                kind: DiagKind::RouteConflict,
                severity: Severity::Error,
                pe,
                color: Some(a.color),
                task: None,
                message: format!(
                    "color {} has two distinct router configurations on {:?} \
                     (rule {:?}/{:?} vs {:?}/{:?})",
                    a.color, shared, a.rx, a.tx, b.rx, b.tx
                ),
            });
        }
    }
}

/// Every producer's flow must trace cleanly and deliver to PEs that run
/// code.
fn check_flow_traces(prog: &MachineProgram, graph: &FlowGraph, report: &mut AnalysisReport) {
    for flow in &graph.flows {
        match &flow.path {
            Err(e) => report.push(Diagnostic {
                kind: DiagKind::RouteError,
                severity: Severity::Error,
                pe: Some(flow.src),
                color: Some(flow.color),
                task: producer_name(graph, flow),
                message: format!("flow cannot be routed: {e}"),
            }),
            Ok(path) => {
                if path.dests.is_empty() {
                    report.push(Diagnostic {
                        kind: DiagKind::RouteError,
                        severity: Severity::Error,
                        pe: Some(flow.src),
                        color: Some(flow.color),
                        task: producer_name(graph, flow),
                        message: "flow has no destinations (no router forwards it to a ramp)"
                            .into(),
                    });
                }
                for (dx, dy, _) in &path.dests {
                    if prog.class_at(*dx, *dy).is_none() {
                        report.push(Diagnostic {
                            kind: DiagKind::RouteError,
                            severity: Severity::Error,
                            pe: Some((*dx, *dy)),
                            color: Some(flow.color),
                            task: None,
                            message: format!(
                                "flow from PE ({},{}) delivers to PE ({dx},{dy}), \
                                 which runs no code",
                                flow.src.0, flow.src.1
                            ),
                        });
                    }
                }
            }
        }
    }
}

/// Two distinct flows sharing a (link, color) merge ambiguously: the
/// circuit-switched router cannot tell their wavelets apart. (Distinct
/// colors on one physical link merely serialize — that is legal.)
fn check_link_sharing(graph: &FlowGraph, report: &mut AnalysisReport) {
    let mut occupancy: HashMap<(i64, i64, Direction, u8), Vec<usize>> = HashMap::new();
    for (fi, flow) in graph.flows.iter().enumerate() {
        if let Ok(path) = &flow.path {
            for link in &path.links {
                occupancy
                    .entry((link.x, link.y, link.dir, flow.color))
                    .or_default()
                    .push(fi);
            }
        }
    }
    let mut keys: Vec<_> = occupancy.keys().copied().collect();
    keys.sort_by_key(|(x, y, d, c)| (*x, *y, d.index(), *c));
    let mut reported: std::collections::HashSet<(usize, usize)> = Default::default();
    for key in keys {
        let flows = &occupancy[&key];
        if flows.len() < 2 {
            continue;
        }
        let (x, y, dir, color) = key;
        for w in flows.windows(2) {
            let pair = (w[0].min(w[1]), w[0].max(w[1]));
            if pair.0 == pair.1 || !reported.insert(pair) {
                continue;
            }
            let a = &graph.flows[pair.0];
            let b = &graph.flows[pair.1];
            report.push(Diagnostic {
                kind: DiagKind::RouteConflict,
                severity: Severity::Error,
                pe: Some((x, y)),
                color: Some(color),
                task: None,
                message: format!(
                    "flows from PE ({},{}) and PE ({},{}) share link ({x},{y})→{} on \
                     color {color}: ambiguous circuit merge",
                    a.src.0,
                    a.src.1,
                    b.src.0,
                    b.src.1,
                    dir.csl_name()
                ),
            });
        }
    }
}

fn producer_name(graph: &FlowGraph, flow: &super::flowgraph::Flow) -> Option<String> {
    flow.producers.first().map(|&(pi, ti, _)| {
        let (_, _, ci) = graph.pes[pi];
        graph.models[ci][ti].name.clone()
    })
}

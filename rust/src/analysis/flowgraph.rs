//! Explicit flow-graph construction over a lowered [`MachineProgram`].
//!
//! Walks every PE class's task bodies collecting the fabric events the
//! checker reasons about — `FabOut` producers, `FabIn` consumers, task
//! control actions and their triggers — then instantiates them per PE.
//! Producer routes are read from the precompiled
//! [`crate::machine::plan::RoutingPlan`] *instance passed in by the
//! caller* — for a compiled kernel, the very plan the simulator will
//! execute from (`kernels::compile` builds it once) — so the static
//! checker and the runtime can never disagree about route geometry,
//! and a checked run traces every route exactly once.

use crate::machine::plan::RoutingPlan;
use crate::machine::program::{
    DsdRef, MOp, SBinOp, SExpr, TaskAction, TaskKind,
};
use crate::machine::router::{trace_route, FlowPath, RouteError};
use crate::machine::{MachineConfig, MachineProgram};
use std::collections::HashMap;

/// Const-evaluate an [`SExpr`] that depends only on immediates and the
/// PE coordinates. `Reg`/`LoadMem` make the value statically unknown.
pub fn eval_const(e: &SExpr, x: i64, y: i64) -> Option<i64> {
    Some(match e {
        SExpr::ImmI(v) => *v,
        SExpr::ImmF(v) => *v as i64,
        SExpr::CoordX => x,
        SExpr::CoordY => y,
        SExpr::Reg(_) | SExpr::LoadMem { .. } => return None,
        SExpr::Neg(a) => -eval_const(a, x, y)?,
        SExpr::Not(a) => (eval_const(a, x, y)? == 0) as i64,
        SExpr::Select(c, a, b) => {
            if eval_const(c, x, y)? != 0 {
                eval_const(a, x, y)?
            } else {
                eval_const(b, x, y)?
            }
        }
        SExpr::Bin(op, a, b) => {
            let va = eval_const(a, x, y)?;
            let vb = eval_const(b, x, y)?;
            match op {
                SBinOp::Add => va + vb,
                SBinOp::Sub => va - vb,
                SBinOp::Mul => va * vb,
                SBinOp::Div => {
                    if vb == 0 {
                        return None;
                    }
                    va / vb
                }
                SBinOp::Mod => {
                    if vb == 0 {
                        return None;
                    }
                    va.rem_euclid(vb)
                }
                SBinOp::Min => va.min(vb),
                SBinOp::Max => va.max(vb),
                SBinOp::Eq => (va == vb) as i64,
                SBinOp::Ne => (va != vb) as i64,
                SBinOp::Lt => (va < vb) as i64,
                SBinOp::Le => (va <= vb) as i64,
                SBinOp::Gt => (va > vb) as i64,
                SBinOp::Ge => (va >= vb) as i64,
                SBinOp::And => (va != 0 && vb != 0) as i64,
                SBinOp::Or => (va != 0 || vb != 0) as i64,
            }
        }
    })
}

/// What makes a task-control action fire.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trigger {
    /// Fires whenever the owning (local) task runs.
    OnRun,
    /// Fires when the owning task's `consumes[i]` completes.
    OnConsume(usize),
    /// Fires when the owning task's `produces[i]` drains. The
    /// optimistic progress fixpoint treats this as "the produce
    /// issued" (sound for deadlock detection: progress is only ever
    /// over-approximated); whether a produce can actually drain under
    /// *finite* endpoint buffers is the credit pass's concern
    /// ([`super::credits`]).
    OnProduce(usize),
    /// Fires once the owning data task has received `threshold`
    /// wavelets (`None` = any wavelet).
    OnWavelets(Option<i64>),
}

/// A fabric producer (`FabOut` destination) in a task body.
#[derive(Clone, Debug)]
pub struct ProduceOp {
    pub color: u8,
    /// Per-issue wavelet count (evaluated per PE; `None` = unknown).
    pub len: SExpr,
    /// Trip-count multiplier from enclosing `For` loops (`None` when a
    /// bound is not statically known).
    pub trips: Option<SExpr>,
    /// Inside a genuine runtime conditional (not a dispatch wrapper).
    pub conditional: bool,
    /// Inside a dispatch-guard branch (task-ID recycling's
    /// `if scratch_reg == k` wrapper). The optimistic deadlock fixpoint
    /// treats every branch as reachable — correct for progress — but an
    /// *exact word count* cannot sum sibling branches (each activation
    /// runs one), so the credit pass treats these sites as unknown.
    pub dispatched: bool,
    /// Fused accumulate-and-forward ops (`FabIn` source + `FabOut`
    /// destination, the chain pipeline's streaming form) only emit
    /// once the paired consume (index into `consumes`) completes.
    pub after_consume: Option<usize>,
}

/// A fabric consumer (`FabIn` source) in a task body.
#[derive(Clone, Debug)]
pub struct ConsumeOp {
    pub color: u8,
    pub len: SExpr,
    /// Trip-count multiplier from enclosing `For` loops (`None` when a
    /// bound is not statically known) — symmetric with
    /// [`ProduceOp::trips`], so the credit pass can bound total
    /// consumption the same way it bounds total delivery.
    pub trips: Option<SExpr>,
    pub conditional: bool,
    /// Inside a dispatch-guard branch — see [`ProduceOp::dispatched`].
    pub dispatched: bool,
    pub on_complete: Vec<TaskAction>,
}

/// A task-control action site with its firing trigger.
#[derive(Clone, Debug)]
pub struct ActionSite {
    pub action: TaskAction,
    pub trigger: Trigger,
    pub conditional: bool,
}

/// The checker's view of one [`crate::machine::TaskDef`].
#[derive(Clone, Debug, Default)]
pub struct TaskModel {
    pub name: String,
    pub hw_id: u8,
    /// `Some(color)` for data tasks.
    pub data_color: Option<u8>,
    pub initially_active: bool,
    pub initially_blocked: bool,
    pub consumes: Vec<ConsumeOp>,
    pub produces: Vec<ProduceOp>,
    pub actions: Vec<ActionSite>,
}

/// Dispatch-wrapper recognition: task-ID recycling guards each merged
/// logical task with `if scratch_reg == branch`. Those branches all run
/// over the task's lifetime, so the checker treats them as
/// unconditional. Registers at/above 24 are reserved for the recycling
/// machinery (see `csl::lower`).
fn is_dispatch_guard(cond: &SExpr) -> bool {
    matches!(
        cond,
        SExpr::Bin(SBinOp::Eq, a, b)
            if matches!(a.as_ref(), SExpr::Reg(r) if *r >= 24)
                && matches!(b.as_ref(), SExpr::ImmI(_))
    )
}

/// Counted-foreach guard: the data-task fallback blocks itself and
/// activates a completion proxy behind `if count_reg >= n`.
fn wavelet_threshold(cond: &SExpr) -> Option<&SExpr> {
    match cond {
        SExpr::Bin(SBinOp::Ge, a, n) if matches!(a.as_ref(), SExpr::Reg(_)) => Some(n.as_ref()),
        _ => None,
    }
}

struct BodyWalker<'m> {
    model: &'m mut TaskModel,
    is_data_task: bool,
}

impl<'m> BodyWalker<'m> {
    /// `conditional`: inside a genuine runtime `If`. `trips`: product of
    /// enclosing `For` trip-count expressions (`None` = unknown).
    /// `threshold`: wavelet-count guard context (data tasks).
    /// `dispatched`: inside a dispatch-guard branch (see
    /// [`ProduceOp::dispatched`]).
    fn walk(
        &mut self,
        ops: &[MOp],
        conditional: bool,
        trips: Option<SExpr>,
        threshold: Option<&SExpr>,
        dispatched: bool,
    ) {
        for op in ops {
            match op {
                MOp::Control(a) => self.action(*a, conditional, threshold),
                MOp::Dsd(d) => {
                    let consume_color = match (&d.src0, &d.src1) {
                        (Some(DsdRef::FabIn { color, len, .. }), _)
                        | (_, Some(DsdRef::FabIn { color, len, .. })) => {
                            Some((*color, len.clone()))
                        }
                        _ => None,
                    };
                    let consume_idx = consume_color.map(|(color, len)| {
                        self.model.consumes.push(ConsumeOp {
                            color,
                            len,
                            trips: trips.clone(),
                            conditional,
                            dispatched,
                            on_complete: d.on_complete.clone(),
                        });
                        self.model.consumes.len() - 1
                    });
                    let produce_idx = if let DsdRef::FabOut { color, len, .. } = &d.dst {
                        self.model.produces.push(ProduceOp {
                            color: *color,
                            len: len.clone(),
                            trips: trips.clone(),
                            conditional,
                            dispatched,
                            after_consume: consume_idx,
                        });
                        Some(self.model.produces.len() - 1)
                    } else {
                        None
                    };
                    // Completion actions: a fused op completes when its
                    // consume does; a pure send when it drains; a
                    // memory-only op as soon as the body runs.
                    match (consume_idx, produce_idx) {
                        (Some(ci), _) => {
                            for a in &d.on_complete {
                                let trigger = if self.is_data_task {
                                    Trigger::OnWavelets(None)
                                } else {
                                    Trigger::OnConsume(ci)
                                };
                                self.model.actions.push(ActionSite {
                                    action: *a,
                                    trigger,
                                    conditional,
                                });
                            }
                        }
                        (None, Some(pi)) => {
                            for a in &d.on_complete {
                                self.model.actions.push(ActionSite {
                                    action: *a,
                                    trigger: Trigger::OnProduce(pi),
                                    conditional,
                                });
                            }
                        }
                        (None, None) => {
                            for a in &d.on_complete {
                                self.action(*a, conditional, threshold);
                            }
                        }
                    }
                }
                MOp::If { cond, then_ops, else_ops } => {
                    if is_dispatch_guard(cond) {
                        self.walk(then_ops, conditional, trips.clone(), threshold, true);
                        self.walk(else_ops, conditional, trips.clone(), threshold, true);
                    } else if self.is_data_task {
                        if let Some(n) = wavelet_threshold(cond) {
                            self.walk(then_ops, conditional, trips.clone(), Some(n), dispatched);
                            self.walk(else_ops, conditional, trips.clone(), threshold, dispatched);
                        } else {
                            self.walk(then_ops, true, trips.clone(), threshold, dispatched);
                            self.walk(else_ops, true, trips.clone(), threshold, dispatched);
                        }
                    } else {
                        self.walk(then_ops, true, trips.clone(), threshold, dispatched);
                        self.walk(else_ops, true, trips.clone(), threshold, dispatched);
                    }
                }
                MOp::For { start, stop, step, body, .. } => {
                    // Trip count (stop - start) / step when step is a
                    // positive constant-ish expression; conservatively
                    // unknown otherwise.
                    let count = trip_count(start, stop, step);
                    let combined = match (trips.clone(), count) {
                        (Some(t), Some(c)) => Some(SExpr::mul(t, c)),
                        _ => None,
                    };
                    self.walk(body, conditional, combined, threshold, dispatched);
                }
                _ => {}
            }
        }
    }

    fn action(&mut self, action: TaskAction, conditional: bool, threshold: Option<&SExpr>) {
        let trigger = if self.is_data_task {
            // Task models are shared by every PE of the class, so only a
            // coordinate-independent threshold can be baked in; anything
            // else degrades to "any wavelet" (may miss deadlocks, never
            // invents them).
            Trigger::OnWavelets(threshold.and_then(coord_free_const))
        } else {
            Trigger::OnRun
        };
        self.model.actions.push(ActionSite { action, trigger, conditional });
    }
}

/// Evaluate an expression that must not depend on the PE coordinates
/// (probed at two distinct coordinate points).
fn coord_free_const(e: &SExpr) -> Option<i64> {
    match (eval_const(e, 0, 0), eval_const(e, 7, 3)) {
        (Some(a), Some(b)) if a == b => Some(a),
        _ => None,
    }
}

/// Symbolic trip count of a `For`: `ceil((stop - start) / step)` when
/// the pieces are expressions; `None` when the step is dynamic.
fn trip_count(start: &SExpr, stop: &SExpr, step: &SExpr) -> Option<SExpr> {
    match step {
        SExpr::ImmI(1) => Some(SExpr::bin(
            SBinOp::Max,
            SExpr::bin(SBinOp::Sub, stop.clone(), start.clone()),
            SExpr::imm(0),
        )),
        SExpr::ImmI(s) if *s > 1 => {
            let span = SExpr::bin(SBinOp::Sub, stop.clone(), start.clone());
            let up = SExpr::bin(SBinOp::Add, span, SExpr::imm(s - 1));
            Some(SExpr::bin(
                SBinOp::Max,
                SExpr::bin(SBinOp::Div, up, SExpr::imm(*s)),
                SExpr::imm(0),
            ))
        }
        _ => None,
    }
}

/// Build the checker model of a task definition.
pub fn model_task(def: &crate::machine::TaskDef) -> TaskModel {
    let (data_color, initially_active) = match &def.kind {
        TaskKind::Data { color, .. } => (Some(*color), true),
        TaskKind::Local => (None, def.initially_active),
    };
    let mut model = TaskModel {
        name: def.name.clone(),
        hw_id: def.hw_id,
        data_color,
        initially_active,
        initially_blocked: def.initially_blocked,
        ..TaskModel::default()
    };
    let mut walker = BodyWalker { model: &mut model, is_data_task: data_color.is_some() };
    walker.walk(&def.body, false, Some(SExpr::imm(1)), None, false);
    model
}

/// One traced fabric flow: a (source PE, color) injection point and its
/// resolved (possibly multicast) path.
#[derive(Debug)]
pub struct Flow {
    pub src: (i64, i64),
    pub color: u8,
    /// Producing (pe index, task index) sites and their produce-op
    /// indices within the task model.
    pub producers: Vec<(usize, usize, usize)>,
    pub path: Result<FlowPath, RouteError>,
}

/// The whole-program flow graph.
pub struct FlowGraph {
    /// PE list in class-major order: (x, y, class index).
    pub pes: Vec<(i64, i64, usize)>,
    pub pe_lookup: HashMap<(i64, i64), usize>,
    /// Task models per class (indexed like `prog.classes[i].tasks`).
    pub models: Vec<Vec<TaskModel>>,
    /// Distinct traced flows, one per (source PE, color).
    pub flows: Vec<Flow>,
    pub flow_lookup: HashMap<(i64, i64, u8), usize>,
    /// Deliveries: (pe index, color) → flow indices arriving there.
    pub deliveries: HashMap<(usize, u8), Vec<usize>>,
}

impl FlowGraph {
    /// Build the checker's flow graph, reading every producer route out
    /// of `plan` — the caller-supplied precompiled plan (one trace per
    /// (source PE, color), shared with the simulator).
    pub fn build(prog: &MachineProgram, cfg: &MachineConfig, plan: &RoutingPlan) -> FlowGraph {
        let mut pes = vec![];
        let mut pe_lookup = HashMap::new();
        for (ci, class) in prog.classes.iter().enumerate() {
            for g in &class.subgrids {
                for (x, y) in g.iter() {
                    pe_lookup.entry((x, y)).or_insert_with(|| {
                        pes.push((x, y, ci));
                        pes.len() - 1
                    });
                }
            }
        }
        let models: Vec<Vec<TaskModel>> = prog
            .classes
            .iter()
            .map(|c| c.tasks.iter().map(model_task).collect())
            .collect();

        // One flow per distinct (source PE, color); paths come from the
        // precompiled plan (falling back to a direct trace only for
        // out-of-fabric sources, which the plan does not enumerate).
        let mut flows: Vec<Flow> = vec![];
        let mut flow_lookup: HashMap<(i64, i64, u8), usize> = HashMap::new();
        for (pi, &(x, y, ci)) in pes.iter().enumerate() {
            for (ti, model) in models[ci].iter().enumerate() {
                for (oi, p) in model.produces.iter().enumerate() {
                    let key = (x, y, p.color);
                    let fi = *flow_lookup.entry(key).or_insert_with(|| {
                        let path = match plan.path(x, y, p.color) {
                            Some(r) => r.clone(),
                            None => trace_route(prog, cfg, p.color, x, y),
                        };
                        flows.push(Flow {
                            src: (x, y),
                            color: p.color,
                            producers: vec![],
                            path,
                        });
                        flows.len() - 1
                    });
                    flows[fi].producers.push((pi, ti, oi));
                }
            }
        }

        let mut deliveries: HashMap<(usize, u8), Vec<usize>> = HashMap::new();
        for (fi, flow) in flows.iter().enumerate() {
            if let Ok(path) = &flow.path {
                for (dx, dy, _) in &path.dests {
                    if let Some(&pi) = pe_lookup.get(&(*dx, *dy)) {
                        deliveries.entry((pi, flow.color)).or_default().push(fi);
                    }
                }
            }
        }

        FlowGraph { pes, pe_lookup, models, flows, flow_lookup, deliveries }
    }

    /// All (pe index, color) endpoints with at least one fabric
    /// consumer (DSD consume op or data task).
    pub fn consumer_endpoints(&self) -> Vec<(usize, u8)> {
        let mut out = vec![];
        let mut seen = std::collections::HashSet::new();
        for (pi, &(_, _, ci)) in self.pes.iter().enumerate() {
            for model in &self.models[ci] {
                for c in &model.consumes {
                    if seen.insert((pi, c.color)) {
                        out.push((pi, c.color));
                    }
                }
                if let Some(c) = model.data_color {
                    if seen.insert((pi, c)) {
                        out.push((pi, c));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::program::{DsdKind, DsdOp, Dtype, TaskDef};

    fn fab_out(color: u8, len: i64) -> MOp {
        MOp::Dsd(DsdOp {
            kind: DsdKind::Mov,
            dst: DsdRef::FabOut { color, len: SExpr::imm(len), ty: Dtype::F32 },
            src0: None,
            src1: None,
            scalar: None,
            is_async: true,
            on_complete: vec![],
        })
    }

    #[test]
    fn eval_const_coords_and_arith() {
        let e = SExpr::add(SExpr::mul(SExpr::CoordX, SExpr::imm(4)), SExpr::CoordY);
        assert_eq!(eval_const(&e, 3, 2), Some(14));
        assert_eq!(eval_const(&SExpr::Reg(0), 0, 0), None);
    }

    #[test]
    fn model_extracts_produce_and_dispatch_guard() {
        let def = TaskDef {
            name: "t".into(),
            hw_id: 27,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::If {
                cond: SExpr::bin(SBinOp::Eq, SExpr::Reg(24), SExpr::imm(1)),
                then_ops: vec![fab_out(3, 8)],
                else_ops: vec![],
            }],
        };
        let m = model_task(&def);
        assert_eq!(m.produces.len(), 1);
        assert!(!m.produces[0].conditional, "dispatch guard must not mark conditional");
    }

    #[test]
    fn model_marks_runtime_conditionals() {
        let def = TaskDef {
            name: "t".into(),
            hw_id: 27,
            kind: TaskKind::Local,
            initially_active: false,
            initially_blocked: false,
            body: vec![MOp::If {
                cond: SExpr::bin(SBinOp::Eq, SExpr::CoordX, SExpr::imm(0)),
                then_ops: vec![fab_out(3, 8)],
                else_ops: vec![],
            }],
        };
        let m = model_task(&def);
        assert!(m.produces[0].conditional);
    }
}

//! Static dataflow semantics checker (paper §III's correctness
//! conditions, checked before lowering to hardware state).
//!
//! The SpaDA paper *defines* what makes a spatial dataflow program
//! well-formed — unambiguous routing, race-free channel endpoints, and
//! a deadlock-free wait structure — but in a compile-and-hope pipeline
//! those properties only surface as runtime failures inside the
//! discrete-event simulator (`SimError::Deadlock`, `RouteError`). This
//! subsystem verifies them statically on the loadable
//! [`MachineProgram`], after `sem::instantiate` + the `passes`/`csl`
//! pipeline have produced concrete routes, colors and task tables:
//!
//! - [`flowgraph`] reconstructs the explicit flow graph: every fabric
//!   producer/consumer endpoint per PE, with routed paths traced
//!   through the same geometry as [`crate::machine::router::trace_route`]
//!   and the color assignments produced by [`crate::passes::colors`];
//! - [`routing`] checks **routing correctness**: route rules must be
//!   unambiguous (one configuration per (router, color)), every flow
//!   must trace to in-fabric destinations with code, and no two
//!   distinct flows may share a (link, color) pair;
//! - [`races`] detects **data races**: two writers delivering to the
//!   same (PE, color) channel endpoint whose arrival order is not
//!   sequenced by issue order on one core, and two PEs bound to the
//!   same host output port;
//! - [`deadlock`] runs a monotone progress fixpoint over the wait-for
//!   graph of channel consumers/producers and task activations,
//!   reporting starved consumers, wavelet-count shortfalls, and
//!   circular waits (with the cycle spelled out);
//! - [`credits`] verifies **credit sufficiency** under finite endpoint
//!   buffers (`SPADA_BUF_CAP` / `endpoint_capacity_words`): statically
//!   known leftover words larger than the capacity wedge the fabric
//!   (the exact condition of the simulator's runtime buffer-deadlock
//!   report), and `spada check --buffers` additionally audits capacity
//!   sizing and gated-consumer bursts that risk buffer-cycle deadlocks.
//!
//! [`check_with_plan`] runs in `kernels::compile` by default (opt out
//! with [`crate::passes::Options::check`]) against the same
//! [`crate::machine::RoutingPlan`] instance the compiled kernel ships
//! to the simulator, so a checked run traces routes once; [`check`] is
//! the standalone form that builds its own plan. The `spada check` CLI
//! subcommand verifies a `.spada` source without simulating; and the
//! simulator cross-references the static verdict in its runtime
//! deadlock message. The checker is O(program): PEs × task events, not
//! simulated events.

pub mod credits;
pub mod deadlock;
pub mod flowgraph;
pub mod races;
pub mod routing;

use crate::machine::{MachineConfig, MachineProgram, RoutingPlan};
use crate::passes::Options;
use crate::sem::Bindings;
use std::fmt;

/// How bad a finding is. `Error` findings fail `kernels::compile` and
/// make `spada check` exit nonzero.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

/// The class of defect a diagnostic reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagKind {
    /// A flow fails to trace: unrouted color, off-fabric hop, routing
    /// loop, rx mismatch, or delivery to a PE without code.
    RouteError,
    /// Ambiguous router state: one (router, color) with two distinct
    /// configurations, or two distinct flows sharing a (link, color).
    RouteConflict,
    /// Two unsequenced writers reach one channel endpoint or one host
    /// output port.
    DataRace,
    /// A circular wait on the consumer/producer/activation graph.
    Deadlock,
    /// A consumer endpoint no flow can ever satisfy.
    Starvation,
    /// Credit exhaustion under finite endpoint buffers: delivered words
    /// that can never drain wedge the fabric (see [`credits`]).
    BufferDeadlock,
    /// Resource-limit violation (the paper's OOR / OOM), surfaced from
    /// `MachineProgram::validate`.
    Resource,
}

impl fmt::Display for DiagKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            DiagKind::RouteError => "route-error",
            DiagKind::RouteConflict => "route-conflict",
            DiagKind::DataRace => "data-race",
            DiagKind::Deadlock => "deadlock",
            DiagKind::Starvation => "starvation",
            DiagKind::BufferDeadlock => "buffer-deadlock",
            DiagKind::Resource => "resource",
        };
        f.write_str(s)
    }
}

/// One finding, located as precisely as the machine program allows.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub kind: DiagKind,
    pub severity: Severity,
    /// PE coordinates the finding anchors to.
    pub pe: Option<(i64, i64)>,
    /// Hardware color (virtual channel) involved.
    pub color: Option<u8>,
    /// Task name (class-qualified) involved.
    pub task: Option<String>,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{sev}[{}]", self.kind)?;
        if let Some((x, y)) = self.pe {
            write!(f, " at PE ({x},{y})")?;
        }
        if let Some(c) = self.color {
            write!(f, " color {c}")?;
        }
        if let Some(t) = &self.task {
            write!(f, " task {t}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// The checker's verdict over one machine program.
#[derive(Debug, Default)]
pub struct AnalysisReport {
    pub diagnostics: Vec<Diagnostic>,
    /// Distinct fabric flows traced ((source PE, color) pairs).
    pub flows: usize,
    /// Distinct consumer endpoints ((PE, color) pairs).
    pub endpoints: usize,
    /// PEs covered by the program's classes.
    pub pes_analyzed: usize,
}

impl AnalysisReport {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn has_errors(&self) -> bool {
        self.errors().next().is_some()
    }

    /// No findings at all — the acceptance bar for the paper kernels.
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    pub fn has_kind(&self, kind: DiagKind) -> bool {
        self.diagnostics.iter().any(|d| d.kind == kind)
    }

    pub(crate) fn push(&mut self, d: Diagnostic) {
        self.diagnostics.push(d);
    }
}

impl fmt::Display for AnalysisReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "static dataflow check: {} PEs, {} flows, {} endpoints",
            self.pes_analyzed, self.flows, self.endpoints
        )?;
        if self.diagnostics.is_empty() {
            write!(f, "no findings")
        } else {
            for (i, d) in self.diagnostics.iter().enumerate() {
                if i > 0 {
                    writeln!(f)?;
                }
                write!(f, "{d}")?;
            }
            Ok(())
        }
    }
}

/// Map a runtime deadlock report onto the static taxonomy: the
/// flow-control layer's quiescence scan names blocked endpoints with
/// "endpoint full" (credit exhaustion — [`DiagKind::BufferDeadlock`]),
/// anything else wedged is a circular consumer/producer wait
/// ([`DiagKind::Deadlock`]). Fault-injection triage
/// ([`crate::machine::fault::classify`]) uses this to file faulted
/// runs under the same vocabulary the static checker reports in.
pub fn runtime_deadlock_kind(msg: &str) -> DiagKind {
    if msg.contains("endpoint full") {
        DiagKind::BufferDeadlock
    } else {
        DiagKind::Deadlock
    }
}

/// Did `kernels::compile` already verify this program deadlock-free?
/// (The verdict is recorded in program metadata so runtime consumers —
/// the simulator's deadlock report, fault triage — can cite the
/// compile-time check instead of re-running the whole analysis.)
pub fn is_statically_clean(prog: &MachineProgram) -> bool {
    prog.meta.get("static_check").map(String::as_str) == Some("clean")
}

/// Run every static check on a lowered machine program, building a
/// fresh [`RoutingPlan`] for it.
///
/// Prefer [`check_with_plan`] when a plan already exists (the
/// `kernels::compile` pipeline and the simulator's runtime-deadlock
/// path both hold one) — routes are then traced exactly once per
/// compiled kernel.
pub fn check(prog: &MachineProgram, cfg: &MachineConfig) -> AnalysisReport {
    let plan = RoutingPlan::build(prog, cfg);
    check_with_plan(prog, cfg, &plan)
}

/// Run every static check against an existing precompiled plan — the
/// same instance the simulator executes from, so checker and runtime
/// cannot disagree about route geometry. Includes the credit pass's
/// certain-wedge verdicts whenever the config carries a finite
/// endpoint capacity (`SPADA_BUF_CAP` / `endpoint_capacity_words`).
pub fn check_with_plan(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    plan: &RoutingPlan,
) -> AnalysisReport {
    check_full(prog, cfg, plan, false)
}

/// [`check_with_plan`] plus the advisory buffer audit — capacity
/// sizing hints and potential buffer-cycle warnings — the engine
/// behind `spada check --buffers`.
pub fn check_buffers(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    plan: &RoutingPlan,
) -> AnalysisReport {
    check_full(prog, cfg, plan, true)
}

fn check_full(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    plan: &RoutingPlan,
    buffers_audit: bool,
) -> AnalysisReport {
    let mut report = AnalysisReport::default();

    // Resource limits first (OOR/OOM) — the cheapest class of failure.
    for err in prog.validate(cfg) {
        report.push(Diagnostic {
            kind: DiagKind::Resource,
            severity: Severity::Error,
            pe: None,
            color: None,
            task: None,
            message: err,
        });
    }

    let graph = flowgraph::FlowGraph::build(prog, cfg, plan);
    report.flows = graph.flows.len();
    report.endpoints = graph.consumer_endpoints().len();
    report.pes_analyzed = graph.pes.len();

    routing::check_routing(prog, cfg, &graph, &mut report);
    races::check_races(prog, &graph, &mut report);
    deadlock::check_deadlock(prog, &graph, &mut report);
    credits::check_credits(prog, cfg, &graph, buffers_audit, &mut report);

    report
}

/// Compile a SpaDA source text and statically check it — the engine
/// behind the `spada check` CLI subcommand. Front-half pass failures
/// (e.g. the color allocator's "ambiguous router configuration") are
/// reported as located-as-possible diagnostics rather than opaque
/// errors, so a bad program always yields an [`AnalysisReport`]; only
/// parse/semantic errors (no program to check) return `Err`.
pub fn check_source(
    src: &str,
    bindings: &Bindings,
    cfg: &MachineConfig,
    opts: &Options,
) -> anyhow::Result<AnalysisReport> {
    check_source_opts(src, bindings, cfg, opts, false)
}

/// [`check_source`] with the buffer audit switched on — the engine
/// behind `spada check --buffers`: adds capacity sizing hints and
/// potential buffer-cycle warnings on top of the standard checks.
pub fn check_source_opts(
    src: &str,
    bindings: &Bindings,
    cfg: &MachineConfig,
    opts: &Options,
    buffers_audit: bool,
) -> anyhow::Result<AnalysisReport> {
    let kernel = crate::spada::parse_kernel(src).map_err(|e| anyhow::anyhow!("{e}"))?;
    let prog = crate::sem::instantiate(&kernel, bindings)?;
    // Run the backend with checking disabled: `check` below IS the check
    // (and we want a report even when compilation half-succeeds).
    let opts = Options { check: false, ..*opts };
    match crate::csl::compile(&prog, cfg, &opts) {
        Ok(compiled) => {
            let plan = RoutingPlan::build(&compiled.machine, cfg);
            Ok(check_full(&compiled.machine, cfg, &plan, buffers_audit))
        }
        Err(pass_err) => {
            let msg = pass_err.0;
            let kind = if msg.contains(crate::passes::colors::AMBIGUOUS_ROUTER) {
                DiagKind::RouteConflict
            } else if msg.contains("leaves the") {
                DiagKind::RouteError
            } else {
                DiagKind::Resource
            };
            let mut report = AnalysisReport::default();
            report.push(Diagnostic {
                kind,
                severity: Severity::Error,
                pe: None,
                color: None,
                task: None,
                message: msg,
            });
            Ok(report)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineConfig;

    #[test]
    fn empty_program_is_clean() {
        let prog = MachineProgram::default();
        let report = check(&prog, &MachineConfig::with_grid(4, 4));
        assert!(report.is_clean(), "{report}");
    }

    #[test]
    fn diagnostic_display_carries_location() {
        let d = Diagnostic {
            kind: DiagKind::Deadlock,
            severity: Severity::Error,
            pe: Some((3, 4)),
            color: Some(7),
            task: Some("waiter".into()),
            message: "stuck".into(),
        };
        let s = d.to_string();
        assert!(s.contains("PE (3,4)"), "{s}");
        assert!(s.contains("color 7"), "{s}");
        assert!(s.contains("deadlock"), "{s}");
    }
}

//! Static credit-sufficiency verification — the compile-time half of
//! the finite-buffer model (the runtime half is
//! [`crate::machine::flowctl`]).
//!
//! With a finite endpoint capacity configured
//! ([`MachineConfig::endpoint_capacity_words`] / `SPADA_BUF_CAP`), a
//! flow whose words are never consumed wedges in the fabric: the
//! endpoint's credits are exhausted for good, the tail stalls across
//! the route's link stages, and the run deadlocks where the unbounded
//! machine would have completed. This pass bounds that statically,
//! conservatively, over the same flow graph the deadlock checker uses:
//!
//! - **Certain wedges** (`Severity::Error`, always on): for every
//!   delivered (PE, color) endpoint whose total delivered and consumed
//!   word counts are both statically known (unconditional producers and
//!   consumers, const-evaluable lengths and loop trip counts, no
//!   per-wavelet data task), a leftover `delivered − consumed` larger
//!   than the endpoint capacity can never drain — the exact condition
//!   under which the simulator reports its runtime buffer deadlock, so
//!   the two verdicts cross-reference each other.
//! - **Advisory audit** (`spada check --buffers`): without a configured
//!   capacity, any statically known leftover is reported as a sizing
//!   warning (the words park in the endpoint buffer forever — legal
//!   only on an unbounded fabric); with one, single bursts that exceed
//!   the endpoint capacity *plus* the route's link-stage slack
//!   (`links × link_buffer_words`) into an endpoint whose every
//!   consumer is gated behind an activation are flagged as *potential
//!   buffer-cycle deadlocks* — if the gate transitively depends on
//!   traffic queued behind the burst, the fabric wedges even though
//!   every word has a consumer.
//!
//! Everything unknown (conditional sends, dynamic lengths, data tasks,
//! unbounded loops) is skipped, never guessed: the pass may miss a
//! wedge but never invents one, matching the repository's
//! "conservative verdicts only" checker contract.

use super::flowgraph::{eval_const, ConsumeOp, FlowGraph};
use super::{AnalysisReport, DiagKind, Diagnostic, Severity};
use crate::machine::{MachineConfig, MachineProgram};

/// Statically known words delivered/consumed at one endpoint; `None`
/// when any contribution is unknown (conditional, dynamic, data task).
fn known_total(pairs: &[(Option<i64>, Option<i64>)]) -> Option<i64> {
    let mut total = 0i64;
    for (len, trips) in pairs {
        match (len, trips) {
            (Some(l), Some(t)) => total += (*l).max(0) * (*t).max(0),
            _ => return None,
        }
    }
    Some(total)
}

/// Per-endpoint static accounting, gathered once per (PE, color).
struct EndpointBound {
    /// Total statically known delivered words (`None` = unknown).
    delivered: Option<i64>,
    /// Total statically known consumed words (`None` = unknown).
    consumed: Option<i64>,
    /// A data task drains this color wavelet by wavelet — consumption
    /// is unbounded and eager.
    consumes_all: bool,
    /// Largest single statically known delivery burst, with the link
    /// count of the route that carries it.
    max_burst: Option<(i64, usize)>,
    /// Every consuming task is gated behind an activation (not an
    /// entry task, not initially active); `None` when nothing consumes.
    all_consumers_gated: Option<bool>,
    /// One gated consumer's class-qualified name, for the message.
    gated_consumer: Option<String>,
}

/// How many times a (local) task's body runs, statically: `Some(0)`
/// when nothing ever starts it, `Some(1)` when exactly its entry /
/// initial activation does, `None` (unknown) when any `Activate`
/// action targets it — a re-activated task reruns its consumes and
/// produces arbitrarily often — or when any `Block` action or an
/// initial block could stop it before it runs. The exact-count
/// contract is what lets the certain-wedge check use one bound for
/// both sides (delivered needs a lower bound, consumed an upper);
/// everything uncertain degrades to unknown, which skips the endpoint
/// rather than inventing a wedge. (Data tasks rerun per wavelet by
/// construction and are handled separately via `consumes_all`.)
fn runs_bound(
    prog: &MachineProgram,
    graph: &FlowGraph,
    ci: usize,
    m: &super::flowgraph::TaskModel,
) -> Option<i64> {
    use crate::machine::TaskActionKind;
    let retargeted = graph.models[ci].iter().any(|om| {
        om.actions.iter().any(|site| {
            site.action.task == m.hw_id
                && matches!(site.action.kind, TaskActionKind::Activate | TaskActionKind::Block)
        })
    });
    if retargeted || m.initially_blocked {
        return None;
    }
    let entry = prog.classes[ci].entry_tasks.contains(&m.hw_id);
    if m.initially_active || entry {
        Some(1)
    } else {
        Some(0)
    }
}

fn bound_endpoint(
    prog: &MachineProgram,
    graph: &FlowGraph,
    pi: usize,
    color: u8,
    flow_ixs: &[usize],
) -> EndpointBound {
    let (x, y, ci) = graph.pes[pi];

    // Delivered side: every producer of every flow reaching here. A
    // producer's contribution is len × trips × runs, each factor
    // statically known or the whole endpoint degrades to unknown.
    let mut deliveries: Vec<(Option<i64>, Option<i64>)> = vec![];
    let mut max_burst: Option<(i64, usize)> = None;
    for &fi in flow_ixs {
        let flow = &graph.flows[fi];
        // Link stages upstream of *this* destination = its hop depth
        // on the traced path (a multicast tree's total link count
        // would overstate the slack available to one endpoint).
        let links = flow
            .path
            .as_ref()
            .ok()
            .and_then(|p| {
                p.dests
                    .iter()
                    .find(|&&(dx, dy, _)| (dx, dy) == (x, y))
                    .map(|&(_, _, depth)| depth as usize)
            })
            .unwrap_or(0);
        for &(ppi, pti, poi) in &flow.producers {
            let (px, py, pci) = graph.pes[ppi];
            let pm = &graph.models[pci][pti];
            let p = &pm.produces[poi];
            // Dispatch-guard branches are walked as unconditional for
            // the optimistic deadlock fixpoint, but sibling branches
            // cannot be *summed* (each activation runs one) — exact
            // counting degrades to unknown for them.
            if pm.data_color.is_some() || p.conditional || p.dispatched {
                deliveries.push((None, None));
                continue;
            }
            let runs = runs_bound(prog, graph, pci, pm);
            let len = eval_const(&p.len, px, py);
            let trips = p
                .trips
                .as_ref()
                .and_then(|t| eval_const(t, px, py))
                .and_then(|t| runs.map(|r| t * r));
            deliveries.push((len, trips));
            // A producer that provably never runs sends no burst.
            if runs == Some(0) {
                continue;
            }
            if let Some(l) = len {
                if max_burst.map(|(b, _)| l > b).unwrap_or(true) {
                    max_burst = Some((l, links));
                }
            }
        }
    }

    // Consumed side: every consume and data task at this PE's class,
    // bounded the same way (a re-activatable consumer can drain more
    // than one pass's worth, so its count is unknown — which skips the
    // endpoint rather than inventing a wedge).
    let mut consumes: Vec<(Option<i64>, Option<i64>)> = vec![];
    let mut consumes_all = false;
    let mut any_consumer = false;
    let mut all_gated = true;
    let mut gated_consumer = None;
    for m in &graph.models[ci] {
        let owns_color = m.data_color == Some(color)
            || m.consumes.iter().any(|c: &ConsumeOp| c.color == color);
        if !owns_color {
            continue;
        }
        any_consumer = true;
        if m.data_color == Some(color) {
            consumes_all = true;
        }
        let runs = runs_bound(prog, graph, ci, m);
        let entry = prog.classes[ci].entry_tasks.contains(&m.hw_id);
        if m.initially_active || entry {
            all_gated = false;
        } else if gated_consumer.is_none() {
            gated_consumer = Some(format!("{}.{}", prog.classes[ci].name, m.name));
        }
        for c in &m.consumes {
            if c.color != color {
                continue;
            }
            if c.conditional || c.dispatched {
                consumes.push((None, None));
                continue;
            }
            let len = eval_const(&c.len, x, y);
            let trips = c
                .trips
                .as_ref()
                .and_then(|t| eval_const(t, x, y))
                .and_then(|t| runs.map(|r| t * r));
            consumes.push((len, trips));
        }
    }

    EndpointBound {
        delivered: known_total(&deliveries),
        consumed: known_total(&consumes),
        consumes_all,
        max_burst,
        all_consumers_gated: if any_consumer { Some(all_gated) } else { None },
        gated_consumer,
    }
}

/// Run the credit-sufficiency checks over every delivered endpoint.
/// `audit` adds the advisory findings (`spada check --buffers`); the
/// certain-wedge errors are always on — but only fire when a finite
/// capacity is actually configured, so the default unbounded pipeline
/// reports nothing.
pub fn check_credits(
    prog: &MachineProgram,
    cfg: &MachineConfig,
    graph: &FlowGraph,
    audit: bool,
    report: &mut AnalysisReport,
) {
    let cap = cfg.endpoint_capacity_words;
    if cap.is_none() && !audit {
        return;
    }
    let link_slack = cfg.link_buffer_words.unwrap_or(0);

    // Deterministic order: endpoints sorted by (PE, color).
    let mut endpoints: Vec<(&(usize, u8), &Vec<usize>)> = graph.deliveries.iter().collect();
    endpoints.sort_by_key(|(k, _)| **k);

    for (&(pi, color), flow_ixs) in endpoints {
        let (x, y, _) = graph.pes[pi];
        let b = bound_endpoint(prog, graph, pi, color, flow_ixs);

        // --- leftover words: the certain-wedge condition ---
        if !b.consumes_all {
            if let (Some(d), Some(c)) = (b.delivered, b.consumed) {
                let leftover = d - c;
                if leftover > 0 {
                    match cap {
                        Some(capw) if leftover as u64 > capw => {
                            report.push(Diagnostic {
                                kind: DiagKind::BufferDeadlock,
                                severity: Severity::Error,
                                pe: Some((x, y)),
                                color: Some(color),
                                task: None,
                                message: format!(
                                    "{d} words delivered but at most {c} consumed: the \
                                     {leftover} leftover words exceed the endpoint capacity \
                                     ({capw}); the flow's tail wedges in the fabric (the \
                                     simulator reports a buffer deadlock here)"
                                ),
                            });
                        }
                        None if audit => {
                            report.push(Diagnostic {
                                kind: DiagKind::BufferDeadlock,
                                severity: Severity::Warning,
                                pe: Some((x, y)),
                                color: Some(color),
                                task: None,
                                message: format!(
                                    "{d} words delivered but at most {c} consumed: completes \
                                     only with unbounded buffering — size \
                                     endpoint_capacity_words >= {leftover} (SPADA_BUF_CAP) or \
                                     drain the endpoint"
                                ),
                            });
                        }
                        _ => {} // fits in the configured buffer
                    }
                }
            }
        }

        // --- gated-consumer bursts: the potential buffer-cycle ---
        if audit && !b.consumes_all {
            if let (Some(capw), Some((burst, links)), Some(true)) =
                (cap, b.max_burst, b.all_consumers_gated)
            {
                let slack = links as u64 * link_slack;
                if burst as u64 > capw + slack {
                    let task = b.gated_consumer.clone();
                    report.push(Diagnostic {
                        kind: DiagKind::BufferDeadlock,
                        severity: Severity::Warning,
                        pe: Some((x, y)),
                        color: Some(color),
                        task,
                        message: format!(
                            "potential buffer-cycle: a single {burst}-word burst exceeds \
                             the endpoint capacity ({capw}) plus {slack} words of route \
                             slack ({links} link stage(s)); every consumer is gated behind \
                             an activation — if that gate depends on traffic queued behind \
                             this flow, the fabric wedges"
                        ),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis;
    use crate::machine::program::{
        DirSet, Direction, DsdKind, DsdOp, DsdRef, Dtype, FieldAlloc, MOp, PeClass, RouteRule,
        SExpr, TaskAction, TaskDef, TaskKind,
    };
    use crate::util::Subgrid;

    /// Sender ships `send` words east on `color`; receiver consumes
    /// `recv` of them (entry-activated unless `gated`).
    fn unbalanced_prog(color: u8, send: i64, recv: i64, gated: bool) -> MachineProgram {
        let sender = PeClass {
            name: "sender".into(),
            subgrids: vec![Subgrid::point(0, 0)],
            fields: vec![FieldAlloc {
                name: "a".into(),
                addr: 0,
                len: send as u32,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 4 * send as u32,
            tasks: vec![TaskDef {
                name: "send".into(),
                hw_id: 25,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::FabOut { color, len: SExpr::imm(send), ty: Dtype::F32 },
                    src0: Some(DsdRef::mem(0, SExpr::imm(send), Dtype::F32)),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![],
                })],
            }],
            entry_tasks: vec![25],
        };
        let recv_class = PeClass {
            name: "recv".into(),
            subgrids: vec![Subgrid::point(1, 0)],
            fields: vec![FieldAlloc {
                name: "b".into(),
                addr: 0,
                len: recv.max(1) as u32,
                ty: Dtype::F32,
                is_extern: false,
            }],
            mem_size: 4 * recv.max(1) as u32,
            tasks: vec![TaskDef {
                name: "recv".into(),
                hw_id: 26,
                kind: TaskKind::Local,
                initially_active: false,
                initially_blocked: false,
                body: vec![MOp::Dsd(DsdOp {
                    kind: DsdKind::Mov,
                    dst: DsdRef::mem(0, SExpr::imm(recv), Dtype::F32),
                    src0: Some(DsdRef::FabIn { color, len: SExpr::imm(recv), ty: Dtype::F32 }),
                    src1: None,
                    scalar: None,
                    is_async: true,
                    on_complete: vec![TaskAction::activate(27)],
                })],
            }],
            entry_tasks: if gated { vec![] } else { vec![26] },
        };
        MachineProgram {
            name: "unbalanced".into(),
            classes: vec![sender, recv_class],
            routes: vec![
                RouteRule {
                    color,
                    subgrid: Subgrid::point(0, 0),
                    rx: DirSet::single(Direction::Ramp),
                    tx: DirSet::single(Direction::East),
                },
                RouteRule {
                    color,
                    subgrid: Subgrid::point(1, 0),
                    rx: DirSet::single(Direction::West),
                    tx: DirSet::single(Direction::Ramp),
                },
            ],
            colors_used: vec![color],
            ..Default::default()
        }
    }

    fn capped_cfg(cap: Option<u64>) -> MachineConfig {
        let mut cfg = MachineConfig::with_grid(2, 1);
        cfg.endpoint_capacity_words = cap;
        cfg
    }

    #[test]
    fn leftover_beyond_capacity_is_a_certain_wedge() {
        let prog = unbalanced_prog(1, 16, 4, false);
        let report = analysis::check(&prog, &capped_cfg(Some(8)));
        let diag = report
            .diagnostics
            .iter()
            .find(|d| d.kind == DiagKind::BufferDeadlock)
            .expect("credit pass must flag the wedge");
        assert_eq!(diag.severity, Severity::Error);
        assert_eq!(diag.pe, Some((1, 0)));
        assert_eq!(diag.color, Some(1));
        assert!(diag.message.contains("12 leftover"), "{}", diag.message);
    }

    #[test]
    fn leftover_within_capacity_is_fine() {
        let prog = unbalanced_prog(1, 16, 4, false);
        let report = analysis::check(&prog, &capped_cfg(Some(12)));
        assert!(
            !report.has_kind(DiagKind::BufferDeadlock),
            "a leftover that fits the buffer is not a wedge:\n{report}"
        );
    }

    #[test]
    fn balanced_endpoints_are_clean_under_any_capacity() {
        let prog = unbalanced_prog(1, 16, 16, false);
        for cap in [Some(1), Some(8), None] {
            let report = analysis::check(&prog, &capped_cfg(cap));
            assert!(
                !report.has_kind(DiagKind::BufferDeadlock),
                "balanced traffic must never wedge (cap {cap:?}):\n{report}"
            );
        }
    }

    #[test]
    fn unbounded_pipeline_reports_nothing_without_audit() {
        // Default checks on an unbounded config: the leftover exists
        // but nothing finite is violated and no audit was requested.
        let prog = unbalanced_prog(1, 16, 4, false);
        let report = analysis::check(&prog, &capped_cfg(None));
        assert!(!report.has_kind(DiagKind::BufferDeadlock), "{report}");
    }

    #[test]
    fn audit_sizes_unbounded_leftovers() {
        let prog = unbalanced_prog(1, 16, 4, false);
        let cfg = capped_cfg(None);
        let plan = crate::machine::RoutingPlan::build(&prog, &cfg);
        let report = analysis::check_buffers(&prog, &cfg, &plan);
        let diag = report
            .diagnostics
            .iter()
            .find(|d| d.kind == DiagKind::BufferDeadlock)
            .expect("audit must report the sizing hint");
        assert_eq!(diag.severity, Severity::Warning);
        assert!(diag.message.contains(">= 12"), "{}", diag.message);
    }

    #[test]
    fn audit_flags_gated_consumer_bursts() {
        // Balanced word counts, but the consumer only starts after an
        // activation and the burst exceeds capacity + route slack.
        let prog = unbalanced_prog(1, 16, 16, true);
        let mut cfg = capped_cfg(Some(4));
        cfg.link_buffer_words = Some(2);
        let plan = crate::machine::RoutingPlan::build(&prog, &cfg);
        let report = analysis::check_buffers(&prog, &cfg, &plan);
        let diag = report
            .diagnostics
            .iter()
            .find(|d| d.message.contains("potential buffer-cycle"))
            .expect("audit must flag the gated burst");
        assert_eq!(diag.severity, Severity::Warning);
        assert!(diag.task.as_deref().unwrap_or("").contains("recv"), "{diag:?}");
    }
}

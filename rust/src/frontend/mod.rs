//! GT4Py-style stencil DSL frontend (paper §IV).
//!
//! The production path in the paper is GT4Py (Python) → Stencil IR →
//! SpaDA → CSL. Here the same Stencil IR and the three lowering passes
//! (placement / dataflow / compute) are implemented over a textual
//! GT4Py-style stencil language; `python/gt4py_like/` emits this text
//! from Python stencil definitions, so the Python front half of the
//! pipeline is preserved while the build stays Rust-only at runtime.

pub mod parser;
pub mod lower;

pub use lower::{lower_stencil, StencilKernel};
pub use parser::parse_stencil;

/// Built-in stencil sources (the paper's three evaluated stencils).
pub const LAPLACIAN: &str = include_str!("stencils/laplacian.gt");
pub const VERTICAL: &str = include_str!("stencils/vertical.gt");
pub const UVBKE: &str = include_str!("stencils/uvbke.gt");

pub fn stencil_sources() -> Vec<(&'static str, &'static str)> {
    vec![("laplacian", LAPLACIAN), ("vertical", VERTICAL), ("uvbke", UVBKE)]
}

pub fn stencil_source(name: &str) -> Option<&'static str> {
    stencil_sources().into_iter().find(|(n, _)| *n == name).map(|(_, s)| s)
}

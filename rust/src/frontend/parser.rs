//! Parser for the GT4Py-style stencil DSL.
//!
//! Grammar (keywords are ordinary identifiers, reusing the SpaDA lexer):
//!
//! ```text
//! stencil NAME(f32 field, ...) {
//!   computation(PARALLEL|FORWARD|BACKWARD) interval(lo, hi_rel) {
//!     field = expr          // expr over field[di, dj, dk] and literals
//!     ...
//!   }
//!   ...
//! }
//! ```
//!
//! `interval(lo, hi_rel)` selects vertical levels `lo .. K + hi_rel`
//! (GT4Py's `interval(...)` ≡ `interval(0, 0)`).

use crate::ir::stencil::{Access, KInterval, KOrder, Region, SExpr, SStmt, StencilIr};
use crate::spada::lexer::Lexer;
use crate::spada::token::{Tok, Token};

/// Parse a stencil definition into the analyzed Stencil IR.
pub fn parse_stencil(src: &str) -> Result<StencilIr, String> {
    let tokens = Lexer::new(src).tokenize().map_err(|e| e.to_string())?;
    let mut p = P { toks: tokens, pos: 0 };
    p.stencil()
}

struct P {
    toks: Vec<Token>,
    pos: usize,
}

impl P {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos.min(self.toks.len() - 1)].tok
    }

    fn bump(&mut self) -> Tok {
        let t = self.peek().clone();
        self.pos += 1;
        t
    }

    fn expect(&mut self, t: Tok) -> Result<(), String> {
        if *self.peek() == t {
            self.bump();
            Ok(())
        } else {
            Err(format!("expected {t}, found {}", self.peek()))
        }
    }

    fn ident(&mut self) -> Result<String, String> {
        match self.bump() {
            Tok::Ident(s) => Ok(s),
            other => Err(format!("expected identifier, found {other}")),
        }
    }

    fn kw(&mut self, word: &str) -> Result<(), String> {
        match self.bump() {
            Tok::Ident(s) if s == word => Ok(()),
            other => Err(format!("expected '{word}', found {other}")),
        }
    }

    fn int(&mut self) -> Result<i64, String> {
        match self.bump() {
            Tok::Int(v) => Ok(v),
            Tok::Minus => match self.bump() {
                Tok::Int(v) => Ok(-v),
                other => Err(format!("expected integer, found {other}")),
            },
            other => Err(format!("expected integer, found {other}")),
        }
    }

    fn stencil(&mut self) -> Result<StencilIr, String> {
        self.kw("stencil")?;
        let name = self.ident()?;
        self.expect(Tok::LParen)?;
        let mut fields = vec![];
        while *self.peek() != Tok::RParen {
            // `f32 name` — the type token comes from the SpaDA lexer.
            match self.bump() {
                Tok::TyF32 => {}
                other => return Err(format!("only f32 fields are supported, found {other}")),
            }
            fields.push(self.ident()?);
            if *self.peek() == Tok::Comma {
                self.bump();
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let mut regions = vec![];
        while *self.peek() != Tok::RBrace {
            regions.push(self.region(&fields)?);
        }
        self.expect(Tok::RBrace)?;
        StencilIr::analyze(&name, fields, regions)
    }

    fn region(&mut self, fields: &[String]) -> Result<Region, String> {
        self.kw("computation")?;
        self.expect(Tok::LParen)?;
        let order = match self.ident()?.as_str() {
            "PARALLEL" => KOrder::Parallel,
            "FORWARD" => KOrder::Forward,
            "BACKWARD" => KOrder::Backward,
            other => return Err(format!("unknown computation order {other}")),
        };
        self.expect(Tok::RParen)?;
        self.kw("interval")?;
        self.expect(Tok::LParen)?;
        let lo = self.int()?;
        self.expect(Tok::Comma)?;
        let hi_rel = self.int()?;
        self.expect(Tok::RParen)?;
        if lo < 0 || hi_rel > 0 {
            return Err(format!("interval({lo}, {hi_rel}): need lo >= 0 and hi_rel <= 0"));
        }
        self.expect(Tok::LBrace)?;
        let mut stmts = vec![];
        while *self.peek() != Tok::RBrace {
            let target = self.ident()?;
            if !fields.contains(&target) {
                return Err(format!("assignment to undeclared field {target}"));
            }
            self.expect(Tok::Assign)?;
            let expr = self.expr(fields)?;
            stmts.push(SStmt { target, expr });
        }
        self.expect(Tok::RBrace)?;
        Ok(Region { order, interval: KInterval { lo, hi_rel }, stmts })
    }

    // Precedence: add/sub < mul/div < unary < primary.
    fn expr(&mut self, fields: &[String]) -> Result<SExpr, String> {
        let mut e = self.mul_expr(fields)?;
        loop {
            match self.peek() {
                Tok::Plus => {
                    self.bump();
                    let r = self.mul_expr(fields)?;
                    e = SExpr::Add(Box::new(e), Box::new(r));
                }
                Tok::Minus => {
                    self.bump();
                    let r = self.mul_expr(fields)?;
                    e = SExpr::Sub(Box::new(e), Box::new(r));
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn mul_expr(&mut self, fields: &[String]) -> Result<SExpr, String> {
        let mut e = self.unary_expr(fields)?;
        loop {
            match self.peek() {
                Tok::Star => {
                    self.bump();
                    let r = self.unary_expr(fields)?;
                    e = SExpr::Mul(Box::new(e), Box::new(r));
                }
                Tok::Slash => {
                    self.bump();
                    let r = self.unary_expr(fields)?;
                    e = SExpr::Div(Box::new(e), Box::new(r));
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn unary_expr(&mut self, fields: &[String]) -> Result<SExpr, String> {
        if *self.peek() == Tok::Minus {
            self.bump();
            return Ok(SExpr::Neg(Box::new(self.unary_expr(fields)?)));
        }
        self.primary(fields)
    }

    fn primary(&mut self, fields: &[String]) -> Result<SExpr, String> {
        match self.bump() {
            Tok::Int(v) => Ok(SExpr::Const(v as f64)),
            Tok::Float(v) => Ok(SExpr::Const(v)),
            Tok::LParen => {
                let e = self.expr(fields)?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            Tok::Ident(f) => {
                if !fields.contains(&f) {
                    return Err(format!("unknown field {f}"));
                }
                self.expect(Tok::LBracket)?;
                let di = self.int()?;
                self.expect(Tok::Comma)?;
                let dj = self.int()?;
                self.expect(Tok::Comma)?;
                let dk = self.int()?;
                self.expect(Tok::RBracket)?;
                Ok(SExpr::Access(Access { field: f, di, dj, dk }))
            }
            other => Err(format!("unexpected token {other} in stencil expression")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{LAPLACIAN, UVBKE, VERTICAL};
    use crate::ir::stencil::FieldRole;

    #[test]
    fn laplacian_parses() {
        let ir = parse_stencil(LAPLACIAN).unwrap();
        assert_eq!(ir.name, "laplace");
        assert_eq!(ir.comm_offsets().len(), 4);
        assert_eq!(ir.roles["out_field"], FieldRole::Output);
        assert_eq!(ir.flops_per_point(), 5);
    }

    #[test]
    fn vertical_parses() {
        let ir = parse_stencil(VERTICAL).unwrap();
        assert_eq!(ir.regions.len(), 2);
        assert!(ir.comm_offsets().is_empty());
        assert_eq!(ir.k_reach, 1);
        assert_eq!(ir.regions[1].order, KOrder::Forward);
    }

    #[test]
    fn uvbke_parses() {
        let ir = parse_stencil(UVBKE).unwrap();
        assert_eq!(ir.comm_offsets().len(), 2); // u west, v north
        let hu = ir.halos["u"];
        assert_eq!((hu.west, hu.east), (1, 0));
        let hv = ir.halos["v"];
        assert_eq!((hv.north, hv.south), (1, 0));
    }

    #[test]
    fn bad_interval_rejected() {
        let src = "stencil s(f32 a) { computation(PARALLEL) interval(-1, 0) { a = 1.0 } }";
        assert!(parse_stencil(src).is_err());
    }

    #[test]
    fn unknown_field_rejected() {
        let src = "stencil s(f32 a) { computation(PARALLEL) interval(0, 0) { b = 1.0 } }";
        assert!(parse_stencil(src).is_err());
    }
}

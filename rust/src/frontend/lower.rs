//! Stencil IR → SpaDA lowering: the placement, dataflow and compute
//! passes of paper §IV.
//!
//! - **Placement**: every PE (i, j) owns a K-level column of each field;
//!   halo buffers are allocated for each communicated (field, offset);
//!   temporaries introduced by the compute pass are phase-scoped so the
//!   memory optimizer can overlay them.
//! - **Dataflow**: each distinct horizontal access offset becomes one
//!   `relative_stream` (the Laplacian's four neighbour accesses become
//!   four streams); senders/receivers overlap, so the checkerboard pass
//!   later splits them into parity variants.
//! - **Compute**: PARALLEL regions are normalized to linear combinations
//!   of vector references plus explicit product temporaries, emitted as
//!   single-statement `map` loops that the backend vectorizes into DSD
//!   chains; FORWARD/BACKWARD regions become sequential `for` loops.

use crate::ir::stencil::{FieldRole, Halo, KOrder, SExpr as StExpr, StencilIr};
use crate::spada::ast::{
    ArgDir, BinOp, BlockHeader, Expr, Item, Kernel, KernelArg, PlaceDecl, RangeExpr, Stmt,
    StreamDecl, StreamOffset, Type,
};
use crate::spada::token::Span;

/// A stencil lowered to a SpaDA kernel.
pub struct StencilKernel {
    pub ir: StencilIr,
    pub kernel: Kernel,
    /// Global halo widths (interior domain = [W:NX-E, N:NY-S]).
    pub halo: Halo,
    /// Input / output argument names (per field: `<f>_in`, `<f>_out`).
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

// --- small AST builders ------------------------------------------------

fn sp() -> Span {
    Span::default()
}

fn e_int(v: i64) -> Expr {
    Expr::Int(v)
}

fn e_id(s: &str) -> Expr {
    Expr::Ident(s.to_string())
}

fn e_add(a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::Add, Box::new(a), Box::new(b))
}

fn e_mul(a: Expr, b: Expr) -> Expr {
    Expr::Bin(BinOp::Mul, Box::new(a), Box::new(b))
}

/// `K + c` (c may be negative or zero).
fn e_k_plus(c: i64) -> Expr {
    if c == 0 {
        e_id("K")
    } else {
        e_add(e_id("K"), e_int(c))
    }
}

/// `NX + c` / `NY + c`.
fn e_dim_plus(dim: &str, c: i64) -> Expr {
    if c == 0 {
        e_id(dim)
    } else {
        e_add(e_id(dim), e_int(c))
    }
}

fn r_span(a: Expr, b: Expr) -> RangeExpr {
    RangeExpr { start: a, stop: Some(b), step: None }
}

fn header(ranges: Vec<RangeExpr>) -> BlockHeader {
    BlockHeader {
        vars: vec![(Type::I32, "i".into()), (Type::I32, "j".into())],
        subgrid: ranges,
        span: sp(),
    }
}

/// Halo buffer name for data arriving from offset (di, dj).
fn halo_name(field: &str, di: i64, dj: i64) -> String {
    let dir = match (di, dj) {
        (1, 0) => "e".to_string(),
        (-1, 0) => "w".to_string(),
        (0, 1) => "s".to_string(),
        (0, -1) => "n".to_string(),
        _ => format!("d{}_{}", di, dj).replace('-', "m"),
    };
    format!("{field}_h_{dir}")
}

// --- normalized linear form --------------------------------------------

/// A vector reference in the lowered kernel: column `name` at vertical
/// offset `dk`.
#[derive(Clone, Debug, PartialEq)]
struct VRef {
    name: String,
    dk: i64,
}

/// Linear combination: `bias + Σ coef·ref`.
#[derive(Clone, Debug, Default)]
struct Lin {
    bias: f64,
    terms: Vec<(f64, VRef)>,
}

impl Lin {
    fn constant(v: f64) -> Lin {
        Lin { bias: v, terms: vec![] }
    }

    fn single(r: VRef) -> Lin {
        Lin { bias: 0.0, terms: vec![(1.0, r)] }
    }

    fn is_const(&self) -> bool {
        self.terms.is_empty()
    }

    fn scale(mut self, c: f64) -> Lin {
        self.bias *= c;
        for t in &mut self.terms {
            t.0 *= c;
        }
        self
    }

    fn add(mut self, other: Lin) -> Lin {
        self.bias += other.bias;
        self.terms.extend(other.terms);
        self
    }
}

/// Compute-pass state: accumulates temporaries and preamble map stmts.
struct ComputeCtx {
    temps: Vec<String>,
    preamble: Vec<Stmt>,
    /// Map range length expression (`K + hi_rel - lo`).
    len: Expr,
    /// Vertical shift folded into every index (`lo` of the interval).
    shift: i64,
}

impl ComputeCtx {
    /// `name[k + dk + shift]`
    fn idx(&self, r: &VRef) -> Expr {
        let off = r.dk + self.shift;
        let kexpr = if off == 0 {
            e_id("k")
        } else {
            e_add(e_id("k"), e_int(off))
        };
        Expr::Index(Box::new(e_id(&r.name)), vec![kexpr])
    }

    fn lin_to_expr(&self, lin: &Lin) -> Expr {
        let mut e: Option<Expr> = if lin.bias != 0.0 || lin.terms.is_empty() {
            Some(Expr::Float(lin.bias))
        } else {
            None
        };
        for (c, r) in &lin.terms {
            let term = if (*c - 1.0).abs() < f64::EPSILON {
                self.idx(r)
            } else {
                e_mul(Expr::Float(*c), self.idx(r))
            };
            e = Some(match e {
                None => term,
                Some(prev) => e_add(prev, term),
            });
        }
        e.unwrap()
    }

    fn fresh(&mut self) -> String {
        let name = format!("__t{}", self.temps.len());
        self.temps.push(name.clone());
        name
    }

    /// Emit `t[k] = expr-of-lin` and return the temp ref.
    fn materialize(&mut self, lin: Lin) -> VRef {
        if lin.bias == 0.0 && lin.terms.len() == 1 && lin.terms[0].0 == 1.0 {
            return lin.terms[0].1.clone();
        }
        let t = self.fresh();
        let rhs = self.lin_to_expr(&lin);
        let lhs = Expr::Index(Box::new(e_id(&t)), vec![e_id("k")]);
        // Temps are written at unshifted [0:len] positions.
        let saved = self.shift;
        self.shift = 0;
        let rhs_shifted = rhs; // lin refs already carry shift via idx(); see note
        self.shift = saved;
        self.preamble.push(Stmt::Map {
            vars: vec![(Type::I32, "k".into())],
            ranges: vec![r_span(e_int(0), self.len.clone())],
            body: vec![Stmt::Assign { lhs, rhs: rhs_shifted, span: sp() }],
            span: sp(),
        });
        VRef { name: t, dk: -self.shift } // so idx() re-adds shift to land on [k]
    }

    /// Emit `t[k] = a[k]·b[k]` and return the temp.
    fn product(&mut self, a: VRef, b: VRef) -> VRef {
        let t = self.fresh();
        let lhs = Expr::Index(Box::new(e_id(&t)), vec![e_id("k")]);
        let rhs = e_mul(self.idx(&a), self.idx(&b));
        self.preamble.push(Stmt::Map {
            vars: vec![(Type::I32, "k".into())],
            ranges: vec![r_span(e_int(0), self.len.clone())],
            body: vec![Stmt::Assign { lhs, rhs, span: sp() }],
            span: sp(),
        });
        VRef { name: t, dk: -self.shift }
    }
}

/// Translate a stencil expression into a linear combination, emitting
/// product temporaries into the context as needed.
fn linearize(e: &StExpr, ctx: &mut ComputeCtx) -> Result<Lin, String> {
    Ok(match e {
        StExpr::Const(v) => Lin::constant(*v),
        StExpr::Access(a) => {
            let name = if a.di == 0 && a.dj == 0 {
                a.field.clone()
            } else {
                halo_name(&a.field, a.di, a.dj)
            };
            Lin::single(VRef { name, dk: a.dk })
        }
        StExpr::Neg(a) => linearize(a, ctx)?.scale(-1.0),
        StExpr::Add(a, b) => linearize(a, ctx)?.add(linearize(b, ctx)?),
        StExpr::Sub(a, b) => linearize(a, ctx)?.add(linearize(b, ctx)?.scale(-1.0)),
        StExpr::Mul(a, b) => {
            let la = linearize(a, ctx)?;
            let lb = linearize(b, ctx)?;
            if la.is_const() {
                lb.scale(la.bias)
            } else if lb.is_const() {
                la.scale(lb.bias)
            } else {
                let ra = ctx.materialize(la);
                let rb = ctx.materialize(lb);
                Lin::single(ctx.product(ra, rb))
            }
        }
        StExpr::Div(a, b) => {
            let lb = linearize(b, ctx)?;
            if !lb.is_const() || lb.bias == 0.0 {
                return Err("division by a field is not vectorizable".into());
            }
            linearize(a, ctx)?.scale(1.0 / lb.bias)
        }
    })
}

/// Translate a stencil expression for the sequential (FORWARD/BACKWARD)
/// path: direct scalar indexing, no temporaries.
fn scalar_expr(e: &StExpr, kvar: &str) -> Expr {
    match e {
        StExpr::Const(v) => Expr::Float(*v),
        StExpr::Access(a) => {
            let name = if a.di == 0 && a.dj == 0 {
                a.field.clone()
            } else {
                halo_name(&a.field, a.di, a.dj)
            };
            let idx = if a.dk == 0 {
                e_id(kvar)
            } else {
                e_add(e_id(kvar), e_int(a.dk))
            };
            Expr::Index(Box::new(e_id(&name)), vec![idx])
        }
        StExpr::Neg(a) => Expr::Unary(crate::spada::ast::UnOp::Neg, Box::new(scalar_expr(a, kvar))),
        StExpr::Add(a, b) => {
            Expr::Bin(BinOp::Add, Box::new(scalar_expr(a, kvar)), Box::new(scalar_expr(b, kvar)))
        }
        StExpr::Sub(a, b) => {
            Expr::Bin(BinOp::Sub, Box::new(scalar_expr(a, kvar)), Box::new(scalar_expr(b, kvar)))
        }
        StExpr::Mul(a, b) => {
            Expr::Bin(BinOp::Mul, Box::new(scalar_expr(a, kvar)), Box::new(scalar_expr(b, kvar)))
        }
        StExpr::Div(a, b) => {
            Expr::Bin(BinOp::Div, Box::new(scalar_expr(a, kvar)), Box::new(scalar_expr(b, kvar)))
        }
    }
}

/// Lower an analyzed stencil to a SpaDA kernel with meta-params K, NX, NY.
pub fn lower_stencil(ir: &StencilIr) -> Result<StencilKernel, String> {
    // Global halo (interior domain bounds).
    let mut halo = Halo::default();
    for h in ir.halos.values() {
        halo.west = halo.west.max(h.west);
        halo.east = halo.east.max(h.east);
        halo.north = halo.north.max(h.north);
        halo.south = halo.south.max(h.south);
    }
    let full = vec![
        r_span(e_int(0), e_id("NX")),
        r_span(e_int(0), e_id("NY")),
    ];
    let interior = vec![
        r_span(e_int(halo.west), e_dim_plus("NX", -halo.east)),
        r_span(e_int(halo.north), e_dim_plus("NY", -halo.south)),
    ];

    let mut args: Vec<KernelArg> = vec![];
    let mut inputs = vec![];
    let mut outputs = vec![];
    for f in &ir.fields {
        let role = ir.roles[f];
        if matches!(role, FieldRole::Input | FieldRole::InOut) {
            args.push(KernelArg::Stream {
                elem_ty: Type::F32,
                extents: vec![e_id("NX"), e_id("NY")],
                dir: ArgDir::ReadOnly,
                name: format!("{f}_ain"),
            });
            inputs.push(format!("{f}_ain"));
        }
        if matches!(role, FieldRole::Output | FieldRole::InOut) {
            args.push(KernelArg::Stream {
                elem_ty: Type::F32,
                extents: vec![e_id("NX"), e_id("NY")],
                dir: ArgDir::WriteOnly,
                name: format!("{f}_aout"),
            });
            outputs.push(format!("{f}_aout"));
        }
    }

    let mut items: Vec<Item> = vec![];

    // ---- Placement pass: field columns + halo buffers ------------------
    let mut place_decls: Vec<PlaceDecl> = ir
        .fields
        .iter()
        .map(|f| PlaceDecl { ty: Type::F32, dims: vec![e_id("K")], name: f.clone(), span: sp() })
        .collect();
    let comm = ir.comm_offsets();
    for (f, di, dj) in &comm {
        place_decls.push(PlaceDecl {
            ty: Type::F32,
            dims: vec![e_id("K")],
            name: halo_name(f, *di, *dj),
            span: sp(),
        });
    }
    items.push(Item::Place { header: header(full.clone()), decls: place_decls });

    // ---- Input phase ---------------------------------------------------
    let mut in_stmts: Vec<Stmt> = vec![];
    for f in &ir.fields {
        if matches!(ir.roles[f], FieldRole::Input | FieldRole::InOut) {
            in_stmts.push(Stmt::AwaitStmt {
                op: Box::new(Stmt::Receive {
                    dst: e_id(f),
                    stream: Expr::Index(
                        Box::new(e_id(&format!("{f}_ain"))),
                        vec![e_id("i"), e_id("j")],
                    ),
                    span: sp(),
                }),
                span: sp(),
            });
        }
    }
    if !in_stmts.is_empty() {
        items.push(Item::Phase {
            items: vec![Item::Compute { header: header(full.clone()), body: in_stmts }],
            span: sp(),
        });
    }

    // ---- Dataflow pass: halo exchange phase -----------------------------
    if !comm.is_empty() {
        let mut phase_items: Vec<Item> = vec![];
        let mut streams: Vec<StreamDecl> = vec![];
        let mut sends: Vec<(Vec<RangeExpr>, Stmt)> = vec![];
        let mut recvs: Vec<(Vec<RangeExpr>, Stmt)> = vec![];
        for (f, di, dj) in &comm {
            let sname = format!("s_{}", halo_name(f, *di, *dj));
            // Owner (i+di, j+dj) sends to (i, j): stream offset (-di, -dj).
            streams.push(StreamDecl {
                elem_ty: Type::F32,
                name: sname.clone(),
                dx: StreamOffset::Scalar(e_int(-di)),
                dy: StreamOffset::Scalar(e_int(-dj)),
                span: sp(),
            });
            // Sender subgrid: PEs whose target stays on the grid.
            let xr = r_span(e_int((*di).max(0)), e_dim_plus("NX", (*di).min(0)));
            let yr = r_span(e_int((*dj).max(0)), e_dim_plus("NY", (*dj).min(0)));
            sends.push((
                vec![xr, yr],
                Stmt::Send { data: e_id(f), stream: e_id(&sname), span: sp() },
            ));
            // Receiver subgrid: shifted by (-di, -dj).
            let xr = r_span(e_int((-*di).max(0)), e_dim_plus("NX", (-*di).min(0)));
            let yr = r_span(e_int((-*dj).max(0)), e_dim_plus("NY", (-*dj).min(0)));
            recvs.push((
                vec![xr, yr],
                Stmt::Receive {
                    dst: e_id(&halo_name(f, *di, *dj)),
                    stream: e_id(&sname),
                    span: sp(),
                },
            ));
        }
        phase_items.push(Item::Dataflow { header: header(full.clone()), decls: streams });
        for (sub, stmt) in sends.into_iter().chain(recvs) {
            phase_items.push(Item::Compute { header: header(sub), body: vec![stmt] });
        }
        items.push(Item::Phase { items: phase_items, span: sp() });
    }

    // ---- Compute pass ----------------------------------------------------
    let mut temps_all: Vec<String> = vec![];
    let mut compute_stmts: Vec<Stmt> = vec![];
    for region in &ir.regions {
        match region.order {
            KOrder::Parallel => {
                let len = {
                    let c = region.interval.hi_rel - region.interval.lo;
                    e_k_plus(c)
                };
                for stmt in &region.stmts {
                    let mut ctx = ComputeCtx {
                        temps: temps_all.clone(),
                        preamble: vec![],
                        len: len.clone(),
                        shift: region.interval.lo,
                    };
                    let lin = linearize(&stmt.expr, &mut ctx)?;
                    let rhs = ctx.lin_to_expr(&lin);
                    let kexpr = if region.interval.lo == 0 {
                        e_id("k")
                    } else {
                        e_add(e_id("k"), e_int(region.interval.lo))
                    };
                    let lhs = Expr::Index(Box::new(e_id(&stmt.target)), vec![kexpr]);
                    compute_stmts.extend(ctx.preamble.clone());
                    compute_stmts.push(Stmt::Map {
                        vars: vec![(Type::I32, "k".into())],
                        ranges: vec![r_span(e_int(0), len.clone())],
                        body: vec![Stmt::Assign { lhs, rhs, span: sp() }],
                        span: sp(),
                    });
                    temps_all = ctx.temps;
                }
            }
            KOrder::Forward | KOrder::Backward => {
                for stmt in &region.stmts {
                    // Sequential loop over [lo : K + hi_rel].
                    let kvar = "k";
                    let (lhs_idx, body_expr) = if region.order == KOrder::Forward {
                        (e_id(kvar), scalar_expr(&stmt.expr, kvar))
                    } else {
                        // Backward: iterate an ascending counter, index
                        // reversed: kk = (K + hi_rel - 1) - k + lo.
                        let rev = Expr::Bin(
                            BinOp::Sub,
                            Box::new(e_k_plus(region.interval.hi_rel - 1 + region.interval.lo)),
                            Box::new(e_id(kvar)),
                        );
                        // Substitute via a let: kk = rev; use kk.
                        (rev.clone(), scalar_expr(&stmt.expr, "__kk"))
                    };
                    let mut body = vec![];
                    if region.order == KOrder::Backward {
                        body.push(Stmt::Let {
                            ty: Type::I32,
                            name: "__kk".into(),
                            init: lhs_idx.clone(),
                            span: sp(),
                        });
                        body.push(Stmt::Assign {
                            lhs: Expr::Index(Box::new(e_id(&stmt.target)), vec![e_id("__kk")]),
                            rhs: body_expr,
                            span: sp(),
                        });
                    } else {
                        body.push(Stmt::Assign {
                            lhs: Expr::Index(Box::new(e_id(&stmt.target)), vec![lhs_idx]),
                            rhs: body_expr,
                            span: sp(),
                        });
                    }
                    compute_stmts.push(Stmt::For {
                        var: (Type::I64, kvar.into()),
                        range: RangeExpr {
                            start: e_int(region.interval.lo),
                            stop: Some(e_k_plus(region.interval.hi_rel)),
                            step: None,
                        },
                        body,
                        span: sp(),
                    });
                }
            }
        }
    }
    {
        let mut phase_items: Vec<Item> = vec![];
        if !temps_all.is_empty() {
            // Temporaries are phase-scoped: the memory optimizer overlays
            // them with other phases' scratch.
            phase_items.push(Item::Place {
                header: header(interior.clone()),
                decls: temps_all
                    .iter()
                    .map(|t| PlaceDecl {
                        ty: Type::F32,
                        dims: vec![e_id("K")],
                        name: t.clone(),
                        span: sp(),
                    })
                    .collect(),
            });
        }
        phase_items.push(Item::Compute { header: header(interior.clone()), body: compute_stmts });
        items.push(Item::Phase { items: phase_items, span: sp() });
    }

    // ---- Output phase ----------------------------------------------------
    let mut out_stmts: Vec<Stmt> = vec![];
    for f in &ir.fields {
        if matches!(ir.roles[f], FieldRole::Output | FieldRole::InOut) {
            out_stmts.push(Stmt::AwaitStmt {
                op: Box::new(Stmt::Send {
                    data: e_id(f),
                    stream: Expr::Index(
                        Box::new(e_id(&format!("{f}_aout"))),
                        vec![e_id("i"), e_id("j")],
                    ),
                    span: sp(),
                }),
                span: sp(),
            });
        }
    }
    if !out_stmts.is_empty() {
        items.push(Item::Phase {
            items: vec![Item::Compute { header: header(full.clone()), body: out_stmts }],
            span: sp(),
        });
    }

    let kernel = Kernel {
        name: ir.name.clone(),
        meta_params: vec!["K".into(), "NX".into(), "NY".into()],
        args,
        items,
    };
    Ok(StencilKernel { ir: ir.clone(), kernel, halo, inputs, outputs })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::{parse_stencil, LAPLACIAN, UVBKE, VERTICAL};
    use crate::spada::pretty;

    #[test]
    fn laplacian_lowers() {
        let ir = parse_stencil(LAPLACIAN).unwrap();
        let sk = lower_stencil(&ir).unwrap();
        assert_eq!(sk.kernel.meta_params, vec!["K", "NX", "NY"]);
        // 4 streams, 1 halo per direction.
        let printed = pretty::print_kernel(&sk.kernel);
        assert!(printed.contains("relative_stream"), "{printed}");
        assert_eq!(printed.matches("relative_stream").count(), 4);
        assert!(printed.contains("in_field_h_e"));
        assert_eq!((sk.halo.west, sk.halo.east, sk.halo.north, sk.halo.south), (1, 1, 1, 1));
        // Reparses through the normal front end.
        crate::spada::parse_kernel(&printed).unwrap();
    }

    #[test]
    fn vertical_lowers_sequential() {
        let ir = parse_stencil(VERTICAL).unwrap();
        let sk = lower_stencil(&ir).unwrap();
        let printed = pretty::print_kernel(&sk.kernel);
        assert!(printed.contains("for i64 k"), "{printed}");
        assert!(!printed.contains("relative_stream"));
        crate::spada::parse_kernel(&printed).unwrap();
    }

    #[test]
    fn uvbke_introduces_temps() {
        let ir = parse_stencil(UVBKE).unwrap();
        let sk = lower_stencil(&ir).unwrap();
        let printed = pretty::print_kernel(&sk.kernel);
        assert!(printed.contains("__t0"), "{printed}");
        assert_eq!(printed.matches("relative_stream").count(), 2);
        crate::spada::parse_kernel(&printed).unwrap();
    }
}

//! `spada` — CLI for the SpaDA compiler, WSE-2 simulator, and the
//! paper-reproduction experiment harness.
//!
//! Subcommands:
//!   compile <kernel> [--bind K=64,N=8] [--emit DIR] [--no-fusion] ...
//!   stencil <name>   [--show-ir]
//!   check <kernel|file.spada> [--bind ...] [--grid WxH]
//!                    (static dataflow verification, no simulation)
//!   run <kernel>     [--bind ...]   (compile + simulate with random input)
//!   batch [--jobs FILE|-] [--pool N] (JSONL jobs in, one result row per job out)
//!   serve [--jobs FILE|-] [--listen SOCK] [--pool N] [--queue N] [--shed]
//!                    [--retries N] [--deadline-ms N] [--journal F] [--resume]
//!                    [--stats-every N] (long-lived batch service: continuous
//!                    intake, bounded plan cache, graceful drain on SIGTERM)
//!   bench --exp <table2|fig4..fig9|sim|fleet|sparse|verify|all> [--quick]
//!   sparse [--variant rows|outer|tree|auto|all] [--profile uniform|powerlaw|banded]
//!                    [--seed N] [--m N] [--grid WxH] [--jsonl]
//!                    (one seeded sparse matrix through the SpMV variants + selector)
//!   loc              (Table II shortcut)

use anyhow::{anyhow, bail, Context, Result};
use spada::frontend::{lower_stencil, parse_stencil, stencil_source};
use spada::harness;
use spada::kernels;
use spada::machine::{MachineConfig, SimOptions};
use spada::passes::Options;
use spada::sem::instantiate;
use spada::spada::pretty;
use spada::util::SplitMix64;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = vec![];
        let mut flags = vec![];
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --flag value | --flag=value | --flag
                if let Some((k, v)) = name.split_once('=') {
                    flags.push((k.to_string(), Some(v.to_string())));
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false)
                    && matches!(
                        name,
                        "bind"
                            | "emit"
                            | "exp"
                            | "grid"
                            | "compare"
                            | "current"
                            | "threshold"
                            | "trace"
                            | "format"
                            | "top"
                            | "faults"
                            | "variant"
                            | "profile"
                            | "seed"
                            | "m"
                            | "kernel"
                            | "out"
                            | "jobs"
                            | "pool"
                            | "budget"
                            | "listen"
                            | "queue"
                            | "retries"
                            | "backoff-ms"
                            | "deadline-ms"
                            | "journal"
                            | "stats-every"
                            | "cache-entries"
                            | "cache-bytes"
                    )
                {
                    flags.push((name.to_string(), it.next()));
                } else if name == "buffers"
                    && it
                        .peek()
                        .map(|n| !n.is_empty() && n.chars().all(|c| c.is_ascii_digit()))
                        .unwrap_or(false)
                {
                    // `--buffers 8` — the value is optional, so only a
                    // bare number is consumed (`--buffers kernel` keeps
                    // the kernel as a positional).
                    flags.push((name.to_string(), it.next()));
                } else {
                    flags.push((name.to_string(), None));
                }
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.iter().find(|(k, _)| k == name).and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == name)
    }
}

fn parse_binds(s: Option<&str>) -> Result<Vec<(String, i64)>> {
    let mut out = vec![];
    if let Some(s) = s {
        for part in s.split(',') {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("bad binding {part} (want NAME=INT)"))?;
            out.push((k.trim().to_string(), v.trim().parse().context(part.to_string())?));
        }
    }
    Ok(out)
}

fn options(args: &Args) -> Options {
    Options {
        fusion: !args.has("no-fusion"),
        recycling: !args.has("no-recycling"),
        copy_elim: !args.has("no-copy-elim"),
        check: !args.has("no-check"),
    }
}

/// Compile a library kernel at the grid its binds imply and stage
/// deterministic noise into every input — the shared front half of
/// `spada run` and `spada profile`. The `SPADA_*` environment is
/// resolved exactly once here, into a [`SimOptions`] value that CLI
/// flags then refine; everything downstream takes the options
/// explicitly.
fn compile_and_stage(
    name: &str,
    args: &Args,
) -> Result<(MachineConfig, spada::machine::Simulator, SimOptions)> {
    let binds = parse_binds(args.flag("bind"))?;
    let bind_refs: Vec<(&str, i64)> = binds.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    let (w, h) = grid_of(args, &binds);
    let mut opts = SimOptions::from_env();
    // --faults SPEC overrides the ambient SPADA_FAULTS plan (see
    // machine::fault for the grammar). Parse errors are loud here so a
    // typo never runs clean and reports success.
    if let Some(spec) = args.flag("faults") {
        opts.faults =
            Some(spada::machine::FaultPlan::parse(spec).map_err(|e| anyhow!("--faults: {e}"))?);
    }
    // --trace PATH wins over SPADA_TRACE when both are given.
    if let Some(path) = args.flag("trace") {
        opts.trace_path = Some(path.to_string());
    }
    let mut cfg = MachineConfig::with_grid(w, h);
    // Fold the resolved options into the compile config so compile-time
    // checks (e.g. the static credit pass under a buffer capacity) see
    // the same machine the simulator will run — the historical
    // behaviour, when `with_grid` itself read the environment.
    opts.apply_defaults_to(&mut cfg);
    let ck = kernels::compile(name, &bind_refs, &cfg, &options(args))?;
    let mut sim = ck.simulator_with(&opts)?;
    // Fill every input with deterministic noise.
    let io: Vec<(String, usize)> = sim
        .program()
        .io
        .iter()
        .filter(|b| matches!(b.dir, spada::machine::IoDir::In))
        .map(|b| (b.arg.clone(), (b.total_ports * b.elems_per_pe) as usize))
        .collect();
    let mut rng = SplitMix64::new(1);
    for (arg, len) in io {
        let data: Vec<f32> = (0..len).map(|_| rng.next_f32()).collect();
        let _ = sim.set_input(&arg, &data);
    }
    Ok((cfg, sim, opts))
}

/// Read back every declared output of a wedged run (`spada run
/// --drain`): the partial results the quiesced fabric computed before
/// the error. JSON mode emits raw 32-bit words (always valid JSON —
/// partial f32 state may hold NaN); table mode shows f32 previews.
fn drain_outputs(sim: &spada::machine::Simulator, json: bool) {
    let mut seen: Vec<String> = vec![];
    for b in sim.program().io.iter() {
        if !matches!(b.dir, spada::machine::IoDir::Out) || seen.contains(&b.arg) {
            continue;
        }
        seen.push(b.arg.clone());
        let Ok(words) = sim.get_output_words(&b.arg) else { continue };
        if json {
            let list =
                words.iter().map(|w| w.to_string()).collect::<Vec<_>>().join(",");
            println!("{{\"drain\":{{\"arg\":\"{}\",\"words\":[{list}]}}}}", b.arg);
        } else {
            let vals: Vec<f32> = words.iter().copied().map(f32::from_bits).take(8).collect();
            println!(
                "drained {} ({} words): {:?}{}",
                b.arg,
                words.len(),
                vals,
                if words.len() > 8 { " …" } else { "" }
            );
        }
    }
}

fn grid_of(args: &Args, binds: &[(String, i64)]) -> (i64, i64) {
    if let Some(g) = args.flag("grid") {
        if let Some((w, h)) = g.split_once('x') {
            return (w.parse().unwrap_or(16), h.parse().unwrap_or(16));
        }
    }
    let get = |n: &str| binds.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
    let w = get("NX").or(get("N")).unwrap_or(16);
    let h = get("NY").unwrap_or(1);
    (w, h)
}

fn real_main() -> Result<()> {
    let args = Args::parse();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "compile" => {
            let name = args.positional.get(1).ok_or_else(|| anyhow!("compile <kernel>"))?;
            let binds = parse_binds(args.flag("bind"))?;
            let (w, h) = grid_of(&args, &binds);
            let cfg = MachineConfig::with_grid(w, h);
            let opts = options(&args);
            let kernel = kernels::parse(name)?;
            let prog =
                instantiate(&kernel, &binds.iter().map(|(k, v)| (k.clone(), *v)).collect())?;
            let compiled = spada::csl::compile(&prog, &cfg, &opts).map_err(anyhow::Error::from)?;
            println!(
                "kernel {name}: {} classes, {} colors, {} logical tasks (max {} hw IDs), \
                 {} B max PE memory, {} CSL LoC",
                compiled.stats.classes,
                compiled.stats.colors_used,
                compiled.stats.logical_tasks,
                compiled.stats.hw_task_ids,
                compiled.stats.mem_bytes_max,
                compiled.csl_loc(),
            );
            if let Some(dir) = args.flag("emit") {
                std::fs::create_dir_all(dir)?;
                for (fname, text) in &compiled.csl_files {
                    let p = std::path::Path::new(dir).join(fname);
                    std::fs::write(&p, text)?;
                    println!("wrote {}", p.display());
                }
            }
            Ok(())
        }
        "stencil" => {
            let name = args.positional.get(1).ok_or_else(|| anyhow!("stencil <name>"))?;
            let src = stencil_source(name).ok_or_else(|| anyhow!("unknown stencil {name}"))?;
            let ir = parse_stencil(src).map_err(anyhow::Error::msg)?;
            if args.has("show-ir") {
                println!("{ir}");
                return Ok(());
            }
            let sk = lower_stencil(&ir).map_err(anyhow::Error::msg)?;
            println!("{}", pretty::print_kernel(&sk.kernel));
            Ok(())
        }
        "compile-stencil" => {
            // Consume a .gt file (e.g. emitted by python/gt4py_like) and
            // run the full pipeline: Stencil IR → SpaDA → CSL.
            let path =
                args.positional.get(1).ok_or_else(|| anyhow!("compile-stencil <file.gt>"))?;
            let src = std::fs::read_to_string(path).context(path.clone())?;
            let ir = parse_stencil(&src).map_err(anyhow::Error::msg)?;
            println!("{ir}");
            let sk = lower_stencil(&ir).map_err(anyhow::Error::msg)?;
            let binds = parse_binds(args.flag("bind"))?;
            let mut b: spada::sem::Bindings =
                binds.iter().map(|(k, v)| (k.clone(), *v)).collect();
            for (k, v) in [("K", 8i64), ("NX", 16), ("NY", 16)] {
                b.entry(k.to_string()).or_insert(v);
            }
            let (w, h) = (b["NX"], b["NY"]);
            let prog = instantiate(&sk.kernel, &b)?;
            let cfg = MachineConfig::with_grid(w, h);
            let compiled = spada::csl::compile(&prog, &cfg, &options(&args))?;
            println!(
                "stencil {} → SpaDA {} LoC → CSL {} LoC ({} classes, {} colors)",
                ir.name,
                pretty::count_loc(&sk.kernel),
                compiled.csl_loc(),
                compiled.stats.classes,
                compiled.stats.colors_used,
            );
            if let Some(dir) = args.flag("emit") {
                std::fs::create_dir_all(dir)?;
                for (fname, text) in &compiled.csl_files {
                    std::fs::write(std::path::Path::new(dir).join(fname), text)?;
                }
                println!("emitted CSL to {dir}");
            }
            Ok(())
        }
        "run" => {
            let name = args.positional.get(1).ok_or_else(|| anyhow!("run <kernel>"))?;
            let json = args.has("json");
            let (cfg, mut sim, opts) = match compile_and_stage(name, &args) {
                Ok(v) => v,
                Err(e) => {
                    // Pre-run failures (validation, routing, bad binds)
                    // also honor the --json contract: stdout carries a
                    // machine-readable error object, exit is nonzero.
                    if json {
                        match e.downcast_ref::<spada::machine::SimError>() {
                            Some(se) => print!("{}", se.to_json(None)),
                            None => println!(
                                "{{\"error\":{{\"kind\":\"compile\",\"message\":\"{}\"}}}}",
                                e.to_string().replace('\\', "\\\\").replace('"', "\\\"")
                            ),
                        }
                    }
                    return Err(e);
                }
            };
            // --trace PATH (or SPADA_TRACE=PATH) arms cycle-accurate
            // capture; the Chrome trace-event JSON is written after the
            // run. Tracing never changes simulated cycles. Both sources
            // were already folded into the resolved options, which armed
            // the simulator — only the output path is needed here.
            let trace_path = opts.trace_path.clone();
            let report = match sim.run() {
                Ok(r) => r,
                Err(e) => {
                    // Every SimError path: a JSON error object naming
                    // kind, cycle and PE (when the engine recorded an
                    // error site) on stdout, nonzero exit through the
                    // normal error epilogue on stderr.
                    if json {
                        print!("{}", e.to_json(sim.error_site()));
                    }
                    // --drain: partial-results mode for wedged runs —
                    // read back whatever the quiesced fabric computed.
                    if args.has("drain") {
                        drain_outputs(&sim, json);
                    }
                    return Err(e.into());
                }
            };
            if let Some(path) = &trace_path {
                let trace = sim.take_trace().expect("tracing was enabled");
                let json = spada::machine::chrome_trace_json(
                    &trace,
                    sim.program(),
                    sim.plan(),
                    args.has("trace-epochs"),
                );
                std::fs::write(path, json).context(path.clone())?;
                // stderr: `--json` keeps stdout machine-readable.
                eprintln!("wrote Chrome trace to {path} ({} records)", trace.records.len());
            }
            if args.has("json") {
                print!("{}", report.to_json(&cfg));
                return Ok(());
            }
            println!(
                "{name}: {} cycles ({:.2} us), {} flops, {} flows, {} wavelets, util {:.1}%",
                report.cycles,
                report.runtime_us(&cfg),
                report.metrics.flops,
                report.metrics.flows,
                report.metrics.wavelets,
                100.0 * report.utilization(),
            );
            // Buffer-model observables: the peak depth is the capacity
            // to size SPADA_BUF_CAP from (any cap >= it is bit-identical
            // to the unbounded run).
            println!(
                "{name}: peak endpoint queue depth {} words{}, {} stall cycles{}",
                report.metrics.peak_queue_depth,
                match cfg.endpoint_capacity_words {
                    Some(c) => format!(" (capacity {c})"),
                    None => " (unbounded)".to_string(),
                },
                report.metrics.stall_cycles,
                if report.metrics.stall_cycles > 0 { " (backpressure)" } else { "" },
            );
            Ok(())
        }
        "profile" => {
            // Compile + trace + aggregate: per-PE busy/stall/idle
            // breakdowns, hot PEs/links, link-occupancy histogram and
            // a terminal utilization heatmap. `--format json` emits the
            // same data machine-readably.
            let name = args.positional.get(1).ok_or_else(|| anyhow!("profile <kernel>"))?;
            let top: usize = match args.flag("top") {
                Some(t) => t.parse().context("--top")?,
                None => 8,
            };
            let (cfg, mut sim, _opts) = compile_and_stage(name, &args)?;
            sim.set_tracing(true);
            let report = sim.run()?;
            let trace = sim.take_trace().expect("tracing was enabled");
            let profile = spada::machine::Profile::build(&trace, sim.plan(), report.cycles);
            match args.flag("format") {
                Some("json") => {
                    print!("{}", profile.to_json(sim.plan(), top));
                    return Ok(());
                }
                None | Some("table") => {}
                Some(other) => bail!("--format {other}: want table or json"),
            }
            println!(
                "{name}: {} cycles ({:.2} us), {} PEs, busy {} cycles, \
                 stall {} word-cycles, {} flows, {}/{} DSD ops vectorized",
                report.cycles,
                report.runtime_us(&cfg),
                profile.pes.len(),
                profile.total_busy,
                profile.total_stall,
                profile.flows,
                profile.dsd_vectorized,
                profile.dsd_ops,
            );
            println!("\nhot PEs (top {top} by busy cycles):");
            let mut t = spada::bench::Table::new(&[
                "pe", "x", "y", "busy", "stall", "idle", "tasks", "util",
            ]);
            for b in profile.hot_pes(top) {
                t.row(&[
                    b.pe.to_string(),
                    b.x.to_string(),
                    b.y.to_string(),
                    b.busy.to_string(),
                    b.stall.to_string(),
                    b.idle.to_string(),
                    b.tasks.to_string(),
                    format!("{:.1}%", 100.0 * b.busy as f64 / report.cycles.max(1) as f64),
                ]);
            }
            t.print();
            println!("\nhot links (top {top} by busy word-cycles):");
            let mut t = spada::bench::Table::new(&["link", "busy", "occupancy"]);
            for (li, busy) in profile.hot_links(top) {
                t.row(&[
                    sim.plan().link_label(li),
                    busy.to_string(),
                    format!("{:.1}%", 100.0 * busy as f64 / report.cycles.max(1) as f64),
                ]);
            }
            t.print();
            let hist = profile.link_histogram();
            println!(
                "\nlink occupancy histogram (deciles, {} used links): {:?}",
                profile.links.len(),
                hist,
            );
            println!();
            print!(
                "{}",
                spada::machine::ascii_heatmap(
                    &trace,
                    sim.plan().pes.len(),
                    report.cycles,
                    64,
                    24
                )
            );
            Ok(())
        }
        "check" => {
            // Statically verify a SpaDA program (library kernel name or
            // path to a .spada file) without simulating: routing
            // correctness, data races, deadlocks. Exits nonzero on any
            // error finding.
            let target =
                args.positional.get(1).ok_or_else(|| anyhow!("check <kernel|file.spada>"))?;
            let src: String = if std::path::Path::new(target).exists() {
                std::fs::read_to_string(target).context(target.clone())?
            } else {
                kernels::source(target)?.to_string()
            };
            let kernel = spada::spada::parse_kernel(&src).map_err(|e| anyhow!("{e}"))?;
            let mut binds: spada::sem::Bindings =
                parse_binds(args.flag("bind"))?.into_iter().collect();
            for p in &kernel.meta_params {
                binds.entry(p.clone()).or_insert(8);
            }
            let prog = instantiate(&kernel, &binds)?;
            let (w, h) = match args.flag("grid").and_then(|g| g.split_once('x')) {
                Some((w, h)) => (w.parse().unwrap_or(16), h.parse().unwrap_or(16)),
                None => {
                    let (w, h) = prog.extent();
                    (w.max(1), h.max(1))
                }
            };
            let mut cfg = MachineConfig::with_grid(w, h);
            // --buffers[=N]: run the finite-buffer credit audit. A
            // value overrides the endpoint capacity (otherwise
            // SPADA_BUF_CAP, otherwise the sizing audit runs on the
            // unbounded model).
            let buffers = args.has("buffers");
            if let Some(v) = args.flag("buffers") {
                cfg.endpoint_capacity_words = Some(v.parse::<u64>().context("--buffers")?);
            }
            let report =
                spada::analysis::check_source_opts(&src, &binds, &cfg, &options(&args), buffers)?;
            println!("{report}");
            if report.has_errors() {
                bail!(
                    "{}: {} static error finding(s)",
                    target,
                    report.errors().count()
                );
            }
            let buffers_note = if buffers {
                match cfg.endpoint_capacity_words {
                    Some(c) => format!("; credit check passed at {c} words/endpoint"),
                    None => "; buffer audit ran on the unbounded model".to_string(),
                }
            } else {
                String::new()
            };
            println!(
                "{target}: statically verified on a {w}x{h} fabric — routing, race and \
                 deadlock checks passed{buffers_note}"
            );
            Ok(())
        }
        "bench" => {
            if let Some(baseline) = args.flag("compare") {
                // Bench-regression gate: compare events-per-sec against a
                // blessed baseline, failing on any per-kernel drop beyond
                // the threshold (default 25%).
                let threshold: f64 = match args.flag("threshold") {
                    Some(t) => t.parse().context("--threshold")?,
                    None => 0.25,
                };
                let current = match args.flag("current") {
                    Some(cur) => cur.to_string(),
                    None => {
                        // No current file given: run the sweep first.
                        harness::sim_scaling::run(args.has("quick"))?;
                        harness::sim_scaling::OUT_FILE.to_string()
                    }
                };
                return harness::sim_scaling::compare_files(baseline, &current, threshold);
            }
            let exp = args.flag("exp").unwrap_or("all").to_string();
            harness::run(&exp, args.has("quick"))
        }
        "faults" => {
            // Resilience campaign: sweep single-fault sites across the
            // library kernels and write the JSONL resilience matrix.
            if !args.has("campaign") {
                bail!(
                    "spada faults --campaign [--quick] [--kernel NAME] [--grid N] [--out FILE]\n\
                     (single runs take `spada run <kernel> --faults 'SPEC'` instead)"
                );
            }
            let opts = harness::faults::CampaignOpts {
                quick: args.has("quick"),
                kernel: args.flag("kernel").map(str::to_string),
                grid: match args.flag("grid") {
                    Some(g) => g.parse().context("--grid")?,
                    None => harness::faults::CampaignOpts::default().grid,
                },
                out: args
                    .flag("out")
                    .map(str::to_string)
                    .unwrap_or_else(|| harness::faults::CampaignOpts::default().out),
            };
            harness::faults::campaign(&opts)
        }
        "sparse" => run_sparse_cmd(&args),
        "batch" => run_batch_cmd(&args),
        "serve" => run_serve_cmd(&args),
        "loc" => harness::run("table2", false),
        "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command {other}");
        }
    }
}

/// `spada sparse`: run one seeded sparse matrix through a chosen SpMV
/// dataflow variant (or the adaptive selector's pick, or all three),
/// verify against the CPU CSR oracle, and report per-nonzero metrics.
/// `--jsonl` rows carry only deterministic fields (no wall-clock), so
/// the output is byte-identical under any `SPADA_THREADS` — the CI
/// smoke leg diffs 1- vs 4-thread runs literally.
fn run_sparse_cmd(args: &Args) -> Result<()> {
    use spada::sparse::{self, Profile, Variant};

    let m: usize = match args.flag("m") {
        Some(v) => v.parse().context("--m")?,
        None => 64,
    };
    let seed: u64 = match args.flag("seed") {
        Some(v) => v.parse().context("--seed")?,
        None => 0xA11CE,
    };
    let profile = match args.flag("profile").unwrap_or("uniform") {
        "uniform" => Profile::Uniform { nnz_per_row: 8 },
        "powerlaw" => Profile::PowerLaw { max_row: m },
        "banded" => Profile::Banded { half_width: 2 },
        other => bail!("--profile {other}: want uniform, powerlaw or banded"),
    };
    let (w, h): (usize, usize) = match args.flag("grid").and_then(|g| g.split_once('x')) {
        Some((gw, gh)) => (gw.parse().context("--grid")?, gh.parse().context("--grid")?),
        None => (4, 4),
    };
    let jsonl = args.has("jsonl");

    let a = sparse::generate(m, m, profile, seed);
    let x = sparse::seeded_x(m, seed ^ 0x5EED);
    let f = sparse::features(&a);
    let (pick, ests) = sparse::select(&a, w, h);
    if !jsonl {
        println!(
            "matrix {m}x{m} {} (seed {seed:#x}): {} nonzeros, mean row {:.2}, skew {:.2}, \
             bandwidth {} — selector picks {} on {w}x{h} (estimated cycles \
             rows/outer/tree = {ests:?})",
            profile.name(),
            f.nnz,
            f.mean,
            f.skew,
            f.bandwidth,
            pick.kernel(),
        );
    }

    let variants: Vec<Variant> = match args.flag("variant").unwrap_or("auto") {
        "auto" => vec![pick],
        "all" => Variant::ALL.to_vec(),
        name => vec![sparse::variant_of(&format!("spmv_{name}")).map_err(|_| {
            anyhow!("--variant {name}: want rows, outer, tree, auto or all")
        })?],
    };

    let opts = SimOptions::from_env();
    let want = sparse::spmv_ref(&a, &x);
    for v in variants {
        let staged = sparse::stage(v, &a, &x, w, h)?;
        let cfg = MachineConfig::with_grid(w as i64, h as i64);
        let ck = kernels::compile(v.kernel(), &staged.binds, &cfg, &options(args))?;
        let mut sim = ck.simulator_with(&opts)?;
        staged.apply(&mut sim)?;
        let report = sim.run().map_err(|e| anyhow!("{}: {e}", v.kernel()))?;
        let y = sim.get_output("y_out")?;
        let mut max_err = 0f32;
        for (got, exp) in y.iter().zip(want.iter()) {
            let tol = 1e-3 * (1.0 + exp.abs());
            if (got - exp).abs() > tol {
                bail!("{}: output diverged from the CSR oracle (|Δ| {} > {tol})",
                      v.kernel(), (got - exp).abs());
            }
            max_err = max_err.max((got - exp).abs());
        }
        let nnz = f.nnz.max(1) as f64;
        if jsonl {
            println!(
                "{{\"kernel\": \"{}\", \"profile\": \"{}\", \"seed\": {seed}, \
                 \"m\": {m}, \"grid\": \"{w}x{h}\", \"nnz\": {}, \"cycles\": {}, \
                 \"cycles_per_nnz\": {:.4}, \"wavelets_per_nnz\": {:.4}, \
                 \"selected\": \"{}\", \"verified\": true}}",
                v.kernel(),
                profile.name(),
                f.nnz,
                report.cycles,
                report.cycles as f64 / nnz,
                report.metrics.wavelets as f64 / nnz,
                pick.kernel(),
            );
        } else {
            println!(
                "{}{}: {} cycles ({:.3} cycles/nnz, {:.3} wavelets/nnz), \
                 verified vs oracle (max |Δ| {:.2e})",
                v.kernel(),
                if v == pick { " [selected]" } else { "" },
                report.cycles,
                report.cycles as f64 / nnz,
                report.metrics.wavelets as f64 / nnz,
                max_err,
            );
        }
    }
    Ok(())
}

/// `spada batch`: JSONL job specs in, one JSONL result row per job
/// out, in input order. Jobs run on a worker pool (`--pool N`) over
/// the epoch-parallel engine under the `outer × inner ≤ --budget`
/// thread policy; same-shape jobs share one compilation through the
/// fleet plan cache. Output is byte-identical at any pool width.
fn run_batch_cmd(args: &Args) -> Result<()> {
    use spada::fleet::{self, FleetOptions, JobResult, JobSpec, PlanCache};
    use std::io::{Read as _, Write as _};

    let text = match args.flag("jobs") {
        Some("-") | None => {
            let mut buf = String::new();
            std::io::stdin().read_to_string(&mut buf).context("reading job specs from stdin")?;
            buf
        }
        Some(path) => std::fs::read_to_string(path).context(path.to_string())?,
    };
    let pool: usize = match args.flag("pool") {
        Some(p) => p.parse::<usize>().context("--pool")?.max(1),
        None => 1,
    };
    let mut fleet_opts = FleetOptions { pool, ..FleetOptions::default() };
    if let Some(b) = args.flag("budget") {
        fleet_opts.budget = b.parse::<usize>().context("--budget")?.max(1);
    }

    // Parse every line up front; malformed lines become error rows at
    // their input position, never batch aborts.
    let parsed = fleet::parse_jobs(&text);
    let specs: Vec<JobSpec> = parsed.iter().filter_map(|r| r.as_ref().ok().cloned()).collect();
    let spec_pos: Vec<usize> = parsed
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.is_ok().then_some(i))
        .collect();

    let mut writer: Box<dyn std::io::Write + Send> = match args.flag("out") {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path).context(path.to_string())?,
        )),
        None => Box::new(std::io::BufWriter::new(std::io::stdout())),
    };
    // Streaming merge of run rows with parse-error rows: before row j
    // of the valid stream, flush every earlier input line — all
    // necessarily parse errors, since earlier valid rows arrive first.
    let mut cursor = 0usize; // next input line (within `parsed`) to emit
    let mut valid_idx = 0usize;
    let mut write_err: Option<std::io::Error> = None;
    let flush_errors_until =
        |upto: usize, cursor: &mut usize, w: &mut dyn std::io::Write| -> std::io::Result<()> {
            while *cursor < upto {
                if let Err((id, msg)) = &parsed[*cursor] {
                    w.write_all(
                        JobResult::failed(id, "", "", "spec", msg.clone()).to_jsonl().as_bytes(),
                    )?;
                }
                *cursor += 1;
            }
            Ok(())
        };

    let cache = PlanCache::new();
    let t0 = std::time::Instant::now();
    let summary = fleet::run_batch(&specs, &fleet_opts, &cache, |row| {
        if write_err.is_some() {
            return;
        }
        let pos = spec_pos[valid_idx];
        valid_idx += 1;
        let r = flush_errors_until(pos, &mut cursor, writer.as_mut())
            .and_then(|()| writer.write_all(row.to_jsonl().as_bytes()))
            .map(|()| cursor = pos + 1);
        if let Err(e) = r {
            write_err = Some(e);
        }
    });
    if let Some(e) = write_err {
        return Err(e).context("writing result rows");
    }
    flush_errors_until(parsed.len(), &mut cursor, writer.as_mut())
        .and_then(|()| writer.flush())
        .context("writing result rows")?;
    drop(writer);

    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    let parse_errors = parsed.len() - specs.len();
    // Operator summary on stderr: stdout is the result stream.
    eprintln!(
        "batch: {} job(s) in {:.1} ms ({:.1} sims/s) — {} ok, {} error row(s) ({} parse), \
         plan cache {} compile(s) / {} lookup(s), pool {} x {} inner thread(s)",
        parsed.len(),
        wall_s * 1e3,
        parsed.len() as f64 / wall_s,
        summary.ok,
        summary.errors + parse_errors,
        parse_errors,
        summary.compiles,
        summary.lookups,
        fleet_opts.pool,
        fleet_opts.inner_threads(),
    );
    Ok(())
}

/// Signal plumbing for `spada serve`: SIGTERM/SIGINT raise a flag the
/// service polls (graceful drain); a second signal aborts the process
/// immediately with the conventional 130 exit status. Raw `signal(2)`
/// FFI keeps this dependency-free — the handler only touches an
/// atomic and `_exit`, both async-signal-safe.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicU32, Ordering};

    pub static SHUTDOWN: AtomicU32 = AtomicU32::new(0);

    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn _exit(code: i32) -> !;
    }

    extern "C" fn on_signal(_signum: i32) {
        if SHUTDOWN.fetch_add(1, Ordering::SeqCst) > 0 {
            // Second signal: the operator is done waiting for the
            // drain. Abort now.
            unsafe { _exit(130) }
        }
    }

    /// Route SIGINT (2) and SIGTERM (15) into the shutdown flag.
    pub fn install() {
        unsafe {
            signal(2, on_signal as usize);
            signal(15, on_signal as usize);
        }
    }
}

#[cfg(not(unix))]
mod sig {
    use std::sync::atomic::AtomicU32;

    /// No signal routing off Unix: the flag exists so `serve` links,
    /// but only input EOF ends the session.
    pub static SHUTDOWN: AtomicU32 = AtomicU32::new(0);

    pub fn install() {}
}

#[cfg(unix)]
fn serve_listen(
    path: &str,
    opts: &spada::fleet::ServeOptions,
    cache: &spada::fleet::PlanCache,
    out: &mut dyn std::io::Write,
    shutdown: &std::sync::atomic::AtomicU32,
) -> Result<spada::fleet::ServeSummary> {
    // A stale socket file from a previous run would make bind fail.
    let _ = std::fs::remove_file(path);
    let listener = std::os::unix::net::UnixListener::bind(path)
        .with_context(|| format!("binding {path}"))?;
    eprintln!("serve: listening on {path}");
    spada::fleet::serve::serve_unix(listener, opts, cache, out, &mut std::io::stderr(), shutdown)
}

#[cfg(not(unix))]
fn serve_listen(
    path: &str,
    _opts: &spada::fleet::ServeOptions,
    _cache: &spada::fleet::PlanCache,
    _out: &mut dyn std::io::Write,
    _shutdown: &std::sync::atomic::AtomicU32,
) -> Result<spada::fleet::ServeSummary> {
    bail!("--listen {path}: Unix sockets are unix-only; use --jobs FILE|- instead");
}

/// `spada serve`: the long-lived counterpart of `spada batch`. JSONL
/// job specs stream in continuously (stdin, a file, or `--listen`
/// Unix socket); result rows stream out as their input-order prefix
/// completes. Robustness knobs: bounded plan cache (`--cache-entries`
/// / `--cache-bytes`, or SPADA_CACHE_ENTRIES / SPADA_CACHE_BYTES via
/// the options module), bounded admission queue (`--queue`, `--shed`),
/// default deadline + transient retry (`--deadline-ms`, `--retries`),
/// graceful drain on SIGTERM/SIGINT, crash-safe journal + resume
/// (`--journal`, `--resume`), heartbeat stats (`--stats-every`).
fn run_serve_cmd(args: &Args) -> Result<()> {
    use spada::fleet::{serve, FleetOptions, PlanCache, ServeOptions};
    use spada::machine::CacheBudget;

    let mut fleet = FleetOptions::default();
    if let Some(p) = args.flag("pool") {
        fleet.pool = p.parse::<usize>().context("--pool")?.max(1);
    }
    if let Some(b) = args.flag("budget") {
        fleet.budget = b.parse::<usize>().context("--budget")?.max(1);
    }
    let mut opts = ServeOptions { fleet, ..ServeOptions::default() };
    if let Some(q) = args.flag("queue") {
        opts.queue_cap = q.parse::<usize>().context("--queue")?.max(1);
    }
    opts.shed = args.has("shed");
    if let Some(r) = args.flag("retries") {
        opts.retries = r.parse().context("--retries")?;
    }
    if let Some(b) = args.flag("backoff-ms") {
        opts.backoff_ms = b.parse().context("--backoff-ms")?;
    }
    if let Some(d) = args.flag("deadline-ms") {
        // 0 disables the default watchdog (jobs can still pin their
        // own timeout_ms).
        let ms: u64 = d.parse().context("--deadline-ms")?;
        opts.deadline_ms = (ms > 0).then_some(ms);
    }
    opts.journal = args.flag("journal").map(str::to_string);
    opts.resume = args.has("resume");
    if let Some(n) = args.flag("stats-every") {
        opts.stats_every = Some(n.parse::<u64>().context("--stats-every")?).filter(|&n| n > 0);
    }

    // Cache budget: the env side (SPADA_CACHE_ENTRIES/SPADA_CACHE_BYTES)
    // resolves in machine/options.rs like every other SPADA_* knob;
    // flags win over env.
    let mut budget = CacheBudget::from_env();
    if let Some(n) = args.flag("cache-entries") {
        budget.max_entries =
            Some(n.parse::<usize>().context("--cache-entries")?).filter(|&n| n > 0);
    }
    if let Some(n) = args.flag("cache-bytes") {
        budget.max_bytes = Some(n.parse::<u64>().context("--cache-bytes")?).filter(|&n| n > 0);
    }
    let cache = PlanCache::bounded(budget);

    sig::install();
    let shutdown = &sig::SHUTDOWN;

    let mut out: Box<dyn std::io::Write> = match args.flag("out") {
        Some(path) => Box::new(std::fs::File::create(path).context(path.to_string())?),
        None => Box::new(std::io::stdout()),
    };

    let t0 = std::time::Instant::now();
    let summary = if let Some(path) = args.flag("listen") {
        serve_listen(path, &opts, &cache, out.as_mut(), shutdown)?
    } else {
        match args.flag("jobs") {
            Some("-") | None => serve::serve(
                std::io::stdin(),
                &opts,
                &cache,
                out.as_mut(),
                &mut std::io::stderr(),
                shutdown,
            )?,
            Some(path) => {
                let f = std::fs::File::open(path).context(path.to_string())?;
                serve::serve(f, &opts, &cache, out.as_mut(), &mut std::io::stderr(), shutdown)?
            }
        }
    };

    // Operator summary on stderr (stdout is the row stream).
    eprintln!(
        "serve: {} row(s) in {:.1} s — {} ok, {} error(s) ({} shed), {} skipped via journal, \
         {} retry attempt(s); plan cache {} hit(s) / {} miss(es), {} eviction(s), \
         {} entries live{}",
        summary.rows,
        t0.elapsed().as_secs_f64(),
        summary.ok,
        summary.errors,
        summary.shed,
        summary.skipped,
        summary.retries,
        cache.hits(),
        cache.misses(),
        cache.evictions(),
        cache.len(),
        if summary.drained { " — drained on signal" } else { "" },
    );
    Ok(())
}

fn print_help() {
    println!(
        "spada — SpaDA compiler + WSE-2 simulator (paper reproduction)\n\
         \n\
         USAGE:\n\
         \x20 spada compile <kernel> [--bind K=64,N=8] [--grid WxH] [--emit DIR]\n\
         \x20 spada stencil <laplacian|vertical|uvbke> [--show-ir]\n\
         \x20 spada compile-stencil <file.gt> [--bind K=8,NX=16,NY=16] [--emit DIR]\n\
         \x20 spada check <kernel|file.spada> [--bind ...] [--grid WxH] [--buffers[=N]]\n\
         \x20   (--buffers adds the finite-buffer credit audit: capacity sizing hints and\n\
         \x20    potential buffer-cycle warnings; =N caps endpoints at N words)\n\
         \x20 spada run <kernel> [--bind ...] [--grid WxH] [--json] [--trace OUT.json\n\
         \x20   [--trace-epochs]]  (--json prints the full RunReport as JSON; --trace\n\
         \x20    writes a Chrome trace-event file, loadable in Perfetto — tracing never\n\
         \x20    changes simulated cycles; --trace-epochs adds parallel-engine epoch tracks)\n\
         \x20 spada run <kernel> --faults 'pe(1,0):halt@100' [--drain] [--json]\n\
         \x20   (deterministic fault injection — grammar: link(x,y,D):kill@T | :slow@T+N,\n\
         \x20    pe(x,y):halt@T, flow(x,y,c):corrupt@T | :delay@T+N, seed=K, ';'-separated.\n\
         \x20    --drain prints partial outputs of a wedged run; --json turns every\n\
         \x20    simulator error into a JSON object with kind/cycle/PE, exit nonzero)\n\
         \x20 spada faults --campaign [--quick] [--kernel NAME] [--grid N] [--out FILE]\n\
         \x20   (resilience sweep: every used link x N injection times, every PE halt,\n\
         \x20    one corruption per flow, across every library kernel; writes a JSONL\n\
         \x20    matrix [default FAULTS_matrix.jsonl] with outcomes correct|sdc|\n\
         \x20    buffer-deadlock|circular-wait|runaway|timeout|error, byte-identical\n\
         \x20    across SPADA_THREADS)\n\
         \x20 spada profile <kernel> [--bind ...] [--grid WxH] [--format table|json] [--top N]\n\
         \x20   (cycle-accurate profile: per-PE busy/stall/idle, hot PEs/links, link\n\
         \x20    occupancy histogram and an ASCII utilization heatmap)\n\
         \x20 spada bench [--exp table2|fig4|fig5|fig6|fig7|fig8|fig9|sim|fleet|sparse|verify|all]\n\
         \x20   [--quick]\n\
         \x20   (--exp sim sweeps the six dense kernels 4x4..128x128 at 1 and 4 worker\n\
         \x20    threads and writes BENCH_sim.json; --exp sparse runs the seeded matrix\n\
         \x20    corpus through all SpMV variants + the adaptive selector and writes\n\
         \x20    BENCH_sparse.json, failing if the selector loses to any fixed variant)\n\
         \x20 spada bench --compare BASELINE.json [--current CURRENT.json] [--threshold 0.25]\n\
         \x20   (regression gate: fails if any kernel's events/s drops — or, for sparse\n\
         \x20    rows, cycles-per-nonzero rises — more than the threshold vs the baseline;\n\
         \x20    without --current it runs the sim sweep first)\n\
         \x20 spada sparse [--variant rows|outer|tree|auto|all] [--profile uniform|powerlaw|\n\
         \x20   banded] [--seed N] [--m N] [--grid WxH] [--jsonl]\n\
         \x20   (one seeded MxM sparse matrix through the chosen SpMV dataflow variant —\n\
         \x20    auto lets the structural selector pick — verified against the CPU CSR\n\
         \x20    oracle; --jsonl rows are deterministic and byte-identical across\n\
         \x20    SPADA_THREADS. See docs/sparse.md)\n\
         \x20 spada batch [--jobs FILE|-] [--pool N] [--budget N] [--out FILE]\n\
         \x20   (batch service: JSONL job specs in [default stdin], one JSONL result row\n\
         \x20    per job out [default stdout], in input order. Spec keys: kernel (required),\n\
         \x20    id, g, k, seed, buf_cap, credit_latency, faults, timeout_ms, threads,\n\
         \x20    no_vec. Same-shape jobs compile once via the plan cache; a failing job\n\
         \x20    becomes an error row, never a batch abort; rows are byte-identical at any\n\
         \x20    --pool width. Thread policy: pool x inner <= budget [default: host\n\
         \x20    parallelism]. `spada bench --exp fleet` benchmarks this engine)\n\
         \x20 spada serve [--jobs FILE|-] [--listen SOCK] [--pool N] [--budget N]\n\
         \x20   [--queue N] [--shed] [--retries N] [--backoff-ms N] [--deadline-ms N]\n\
         \x20   [--journal F] [--resume] [--stats-every N] [--cache-entries N]\n\
         \x20   [--cache-bytes N] [--out FILE]\n\
         \x20   (long-lived batch service: specs stream in continuously, rows stream out\n\
         \x20    as their input-order prefix completes. Bounded plan cache with LRU\n\
         \x20    eviction; bounded admission queue [--shed emits overload error rows\n\
         \x20    instead of blocking]; default per-job deadline [0 disables] with\n\
         \x20    transient-failure retry; SIGTERM/SIGINT drains gracefully [second\n\
         \x20    signal aborts]; --journal + --resume skip already-completed ids after\n\
         \x20    a restart, keeping concatenated output byte-identical; --stats-every\n\
         \x20    emits heartbeat JSON on stderr. See docs/serve.md)\n\
         \x20 spada loc\n\
         \n\
         Ablation flags: --no-fusion --no-recycling --no-copy-elim --no-check\n\
         Env vars (resolved once per process into SimOptions — see docs/sim-options.md;\n\
         `spada batch` jobs ignore them, their specs carry the options explicitly):\n\
         \x20         SPADA_THREADS=N  simulator worker threads (default: host parallelism;\n\
         \x20                       1 = classic single-threaded loop, results bit-identical)\n\
         \x20         SPADA_NO_VEC=1  force the per-element DSD interpreter (bit-identical)\n\
         \x20         SPADA_BUF_CAP=N finite endpoint buffers: N words per (PE, color) with\n\
         \x20                       credit backpressure (unset = unbounded; outputs identical,\n\
         \x20                       cycles may grow, wedges report a buffer deadlock)\n\
         \x20         SPADA_TRACE=PATH write a Chrome trace from `spada run` (same as --trace;\n\
         \x20                       the flag wins when both are given)\n\
         \x20         SPADA_FAULTS=SPEC ambient fault plan, same grammar as --faults\n\
         \x20                       (the flag wins when both are given)\n\
         \x20         SPADA_TIMEOUT_MS=N wall-clock watchdog: abort a hung run after N ms\n\
         \x20                       with a timeout error naming the busiest endpoints\n\
         \x20         SPADA_CACHE_ENTRIES=N / SPADA_CACHE_BYTES=N bound the `spada serve`\n\
         \x20                       plan cache (LRU eviction; flags win; unset = unbounded)\n\
         Kernels: {}",
        kernels::sources().iter().map(|(n, _)| *n).collect::<Vec<_>>().join(", ")
    );
}

//! Recursive-descent parser for SpaDA.

use super::ast::*;
use super::lexer::Lexer;
use super::token::{Span, Tok, Token};

/// Parse error with source position.
#[derive(Debug, Clone)]
pub struct ParseError {
    pub msg: String,
    pub span: Span,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "parse error at {}: {}", self.span, self.msg)
    }
}

impl std::error::Error for ParseError {}

type PResult<T> = Result<T, ParseError>;

/// Parse a complete SpaDA kernel from source text.
pub fn parse_kernel(src: &str) -> PResult<Kernel> {
    let tokens = Lexer::new(src)
        .tokenize()
        .map_err(|e| ParseError { msg: e.msg, span: e.span })?;
    Parser { tokens, pos: 0 }.kernel()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.tokens[self.pos.min(self.tokens.len() - 1)].tok
    }

    fn peek2(&self) -> &Tok {
        &self.tokens[(self.pos + 1).min(self.tokens.len() - 1)].tok
    }

    fn span(&self) -> Span {
        self.tokens[self.pos.min(self.tokens.len() - 1)].span
    }

    fn bump(&mut self) -> Tok {
        let t = self.tokens[self.pos.min(self.tokens.len() - 1)].tok.clone();
        self.pos += 1;
        t
    }

    fn err<T>(&self, msg: impl Into<String>) -> PResult<T> {
        Err(ParseError { msg: msg.into(), span: self.span() })
    }

    fn expect(&mut self, t: Tok) -> PResult<()> {
        if *self.peek() == t {
            self.bump();
            Ok(())
        } else {
            self.err(format!("expected {t}, found {}", self.peek()))
        }
    }

    fn eat(&mut self, t: Tok) -> bool {
        if *self.peek() == t {
            self.bump();
            true
        } else {
            false
        }
    }

    fn ident(&mut self) -> PResult<String> {
        match self.peek().clone() {
            Tok::Ident(s) => {
                self.bump();
                Ok(s)
            }
            other => self.err(format!("expected identifier, found {other}")),
        }
    }

    fn ty(&mut self) -> PResult<Type> {
        let t = match self.peek() {
            Tok::TyF16 => Type::F16,
            Tok::TyF32 => Type::F32,
            Tok::TyI16 => Type::I16,
            Tok::TyI32 => Type::I32,
            Tok::TyI64 => Type::I64,
            Tok::TyU16 => Type::U16,
            Tok::TyU32 => Type::U32,
            other => return self.err(format!("expected type, found {other}")),
        };
        self.bump();
        Ok(t)
    }

    // ------------------------------------------------------------------
    // Kernel header
    // ------------------------------------------------------------------

    fn kernel(&mut self) -> PResult<Kernel> {
        self.expect(Tok::Kernel)?;
        self.expect(Tok::At)?;
        let name = self.ident()?;
        let mut meta_params = vec![];
        if self.eat(Tok::Lt) {
            loop {
                meta_params.push(self.ident()?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::Gt)?;
        }
        self.expect(Tok::LParen)?;
        let mut args = vec![];
        if *self.peek() != Tok::RParen {
            loop {
                args.push(self.kernel_arg()?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
        }
        self.expect(Tok::RParen)?;
        self.expect(Tok::LBrace)?;
        let mut items = vec![];
        while *self.peek() != Tok::RBrace {
            items.push(self.item()?);
        }
        self.expect(Tok::RBrace)?;
        Ok(Kernel { name, meta_params, args, items })
    }

    fn kernel_arg(&mut self) -> PResult<KernelArg> {
        if self.eat(Tok::Const) {
            let ty = self.ty()?;
            let name = self.ident()?;
            return Ok(KernelArg::Scalar { ty, name });
        }
        self.expect(Tok::Stream)?;
        self.expect(Tok::Lt)?;
        let elem_ty = self.ty()?;
        self.expect(Tok::Gt)?;
        let mut extents = vec![];
        if self.eat(Tok::LBracket) {
            loop {
                extents.push(self.expr()?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RBracket)?;
        }
        let dir = if self.eat(Tok::Readonly) {
            ArgDir::ReadOnly
        } else if self.eat(Tok::Writeonly) {
            ArgDir::WriteOnly
        } else {
            return self.err("kernel stream argument needs readonly/writeonly");
        };
        let name = self.ident()?;
        Ok(KernelArg::Stream { elem_ty, extents, dir, name })
    }

    // ------------------------------------------------------------------
    // Items
    // ------------------------------------------------------------------

    fn item(&mut self) -> PResult<Item> {
        let span = self.span();
        match self.peek() {
            Tok::Place => {
                self.bump();
                let header = self.block_header()?;
                self.expect(Tok::LBrace)?;
                let mut decls = vec![];
                while *self.peek() != Tok::RBrace {
                    decls.push(self.place_decl()?);
                }
                self.expect(Tok::RBrace)?;
                Ok(Item::Place { header, decls })
            }
            Tok::Dataflow => {
                self.bump();
                let header = self.block_header()?;
                self.expect(Tok::LBrace)?;
                let mut decls = vec![];
                while *self.peek() != Tok::RBrace {
                    decls.push(self.stream_decl()?);
                }
                self.expect(Tok::RBrace)?;
                Ok(Item::Dataflow { header, decls })
            }
            Tok::Compute => {
                self.bump();
                let header = self.block_header()?;
                self.expect(Tok::LBrace)?;
                let mut body = vec![];
                while *self.peek() != Tok::RBrace {
                    body.push(self.stmt()?);
                }
                self.expect(Tok::RBrace)?;
                Ok(Item::Compute { header, body })
            }
            Tok::Phase => {
                self.bump();
                self.expect(Tok::LBrace)?;
                let mut items = vec![];
                while *self.peek() != Tok::RBrace {
                    items.push(self.item()?);
                }
                self.expect(Tok::RBrace)?;
                Ok(Item::Phase { items, span })
            }
            Tok::For => {
                self.bump();
                let ty = self.ty()?;
                let var = self.ident()?;
                self.expect(Tok::In)?;
                self.expect(Tok::LBracket)?;
                let range = self.range_expr()?;
                self.expect(Tok::RBracket)?;
                self.expect(Tok::LBrace)?;
                let mut body = vec![];
                while *self.peek() != Tok::RBrace {
                    body.push(self.item()?);
                }
                self.expect(Tok::RBrace)?;
                Ok(Item::MetaFor { var: (ty, var), range, body, span })
            }
            other => self.err(format!(
                "expected place/dataflow/compute/phase/for, found {other}"
            )),
        }
    }

    /// `TYPE i, TYPE j in [r0, r1]`
    fn block_header(&mut self) -> PResult<BlockHeader> {
        let span = self.span();
        let mut vars = vec![];
        loop {
            let ty = self.ty()?;
            let name = self.ident()?;
            vars.push((ty, name));
            if !self.eat(Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::In)?;
        self.expect(Tok::LBracket)?;
        let mut subgrid = vec![];
        loop {
            subgrid.push(self.range_expr()?);
            if !self.eat(Tok::Comma) {
                break;
            }
        }
        self.expect(Tok::RBracket)?;
        Ok(BlockHeader { vars, subgrid, span })
    }

    fn place_decl(&mut self) -> PResult<PlaceDecl> {
        let span = self.span();
        let ty = self.ty()?;
        let mut dims = vec![];
        if self.eat(Tok::LBracket) {
            loop {
                dims.push(self.expr()?);
                if !self.eat(Tok::Comma) {
                    break;
                }
            }
            self.expect(Tok::RBracket)?;
        }
        let name = self.ident()?;
        self.eat(Tok::Semicolon);
        Ok(PlaceDecl { ty, dims, name, span })
    }

    fn stream_decl(&mut self) -> PResult<StreamDecl> {
        let span = self.span();
        self.expect(Tok::Stream)?;
        self.expect(Tok::Lt)?;
        let elem_ty = self.ty()?;
        self.expect(Tok::Gt)?;
        let name = self.ident()?;
        self.expect(Tok::Assign)?;
        self.expect(Tok::RelativeStream)?;
        self.expect(Tok::LParen)?;
        let dx = self.stream_offset()?;
        self.expect(Tok::Comma)?;
        let dy = self.stream_offset()?;
        self.expect(Tok::RParen)?;
        self.eat(Tok::Semicolon);
        Ok(StreamDecl { elem_ty, name, dx, dy, span })
    }

    fn stream_offset(&mut self) -> PResult<StreamOffset> {
        if self.eat(Tok::LBracket) {
            let a = self.expr()?;
            self.expect(Tok::Colon)?;
            let b = self.expr()?;
            self.expect(Tok::RBracket)?;
            Ok(StreamOffset::Range(a, b))
        } else {
            Ok(StreamOffset::Scalar(self.expr()?))
        }
    }

    fn range_expr(&mut self) -> PResult<RangeExpr> {
        let start = self.expr()?;
        if self.eat(Tok::Colon) {
            let stop = self.expr()?;
            let step = if self.eat(Tok::Colon) { Some(self.expr()?) } else { None };
            Ok(RangeExpr { start, stop: Some(stop), step })
        } else {
            Ok(RangeExpr::point(start))
        }
    }

    // ------------------------------------------------------------------
    // Statements
    // ------------------------------------------------------------------

    fn stmt_block(&mut self) -> PResult<Vec<Stmt>> {
        self.expect(Tok::LBrace)?;
        let mut body = vec![];
        while *self.peek() != Tok::RBrace {
            body.push(self.stmt()?);
        }
        self.expect(Tok::RBrace)?;
        Ok(body)
    }

    fn stmt(&mut self) -> PResult<Stmt> {
        let span = self.span();
        let s = match self.peek().clone() {
            Tok::Await => {
                self.bump();
                // `await c` (named completion) vs `await <op-stmt>`.
                if let Tok::Ident(name) = self.peek().clone() {
                    // An identifier followed by something that isn't the
                    // start of an op is a completion name.
                    if !matches!(self.peek2(), Tok::LParen) {
                        self.bump();
                        self.eat(Tok::Semicolon);
                        return Ok(Stmt::AwaitName { name, span });
                    }
                }
                let op = self.stmt()?;
                Stmt::AwaitStmt { op: Box::new(op), span }
            }
            Tok::Awaitall => {
                self.bump();
                self.eat(Tok::Semicolon);
                Stmt::AwaitAll { span }
            }
            Tok::Completion => {
                self.bump();
                let name = self.ident()?;
                self.expect(Tok::Assign)?;
                let op = self.stmt()?;
                Stmt::CompletionDecl { name, op: Box::new(op), span }
            }
            Tok::Send => {
                self.bump();
                self.expect(Tok::LParen)?;
                let data = self.expr()?;
                self.expect(Tok::Comma)?;
                let stream = self.expr()?;
                self.expect(Tok::RParen)?;
                self.eat(Tok::Semicolon);
                Stmt::Send { data, stream, span }
            }
            Tok::Receive => {
                self.bump();
                self.expect(Tok::LParen)?;
                let dst = self.expr()?;
                self.expect(Tok::Comma)?;
                let stream = self.expr()?;
                self.expect(Tok::RParen)?;
                self.eat(Tok::Semicolon);
                Stmt::Receive { dst, stream, span }
            }
            Tok::Foreach => {
                self.bump();
                let ty1 = self.ty()?;
                let name1 = self.ident()?;
                let (index, elem) = if self.eat(Tok::Comma) {
                    let ty2 = self.ty()?;
                    let name2 = self.ident()?;
                    (Some((ty1, name1)), (ty2, name2))
                } else {
                    (None, (ty1, name1))
                };
                self.expect(Tok::In)?;
                let range = if self.eat(Tok::LBracket) {
                    let r = self.range_expr()?;
                    self.expect(Tok::RBracket)?;
                    self.expect(Tok::Comma)?;
                    Some(r)
                } else {
                    None
                };
                self.expect(Tok::Receive)?;
                self.expect(Tok::LParen)?;
                let stream = self.expr()?;
                self.expect(Tok::RParen)?;
                let body = self.stmt_block()?;
                Stmt::ForeachRecv { index, elem, range, stream, body, span }
            }
            Tok::Map => {
                self.bump();
                let mut vars = vec![];
                loop {
                    let ty = self.ty()?;
                    let name = self.ident()?;
                    vars.push((ty, name));
                    if !self.eat(Tok::Comma) {
                        break;
                    }
                }
                self.expect(Tok::In)?;
                self.expect(Tok::LBracket)?;
                let mut ranges = vec![];
                loop {
                    ranges.push(self.range_expr()?);
                    if !self.eat(Tok::Comma) {
                        break;
                    }
                }
                self.expect(Tok::RBracket)?;
                let body = self.stmt_block()?;
                Stmt::Map { vars, ranges, body, span }
            }
            Tok::For => {
                self.bump();
                let ty = self.ty()?;
                let name = self.ident()?;
                self.expect(Tok::In)?;
                self.expect(Tok::LBracket)?;
                let range = self.range_expr()?;
                self.expect(Tok::RBracket)?;
                let body = self.stmt_block()?;
                Stmt::For { var: (ty, name), range, body, span }
            }
            Tok::Async => {
                self.bump();
                let body = self.stmt_block()?;
                Stmt::Async { body, span }
            }
            Tok::If => {
                self.bump();
                let cond = self.expr()?;
                let then_body = self.stmt_block()?;
                let else_body = if self.eat(Tok::Else) { self.stmt_block()? } else { vec![] };
                Stmt::If { cond, then_body, else_body, span }
            }
            t if t.is_type() => {
                let ty = self.ty()?;
                let name = self.ident()?;
                self.expect(Tok::Assign)?;
                let init = self.expr()?;
                self.eat(Tok::Semicolon);
                Stmt::Let { ty, name, init, span }
            }
            _ => {
                // Assignment: expr = expr
                let lhs = self.expr()?;
                self.expect(Tok::Assign)?;
                let rhs = self.expr()?;
                self.eat(Tok::Semicolon);
                Stmt::Assign { lhs, rhs, span }
            }
        };
        Ok(s)
    }

    // ------------------------------------------------------------------
    // Expressions (precedence climbing)
    // ------------------------------------------------------------------

    pub(crate) fn expr(&mut self) -> PResult<Expr> {
        // Ternary: `a if cond else b` (right-assoc, lowest precedence).
        let e = self.or_expr()?;
        if self.eat(Tok::If) {
            let cond = self.or_expr()?;
            self.expect(Tok::Else)?;
            let els = self.expr()?;
            Ok(Expr::Cond { then: Box::new(e), cond: Box::new(cond), els: Box::new(els) })
        } else {
            Ok(e)
        }
    }

    fn or_expr(&mut self) -> PResult<Expr> {
        let mut e = self.and_expr()?;
        while self.eat(Tok::OrOr) {
            let r = self.and_expr()?;
            e = Expr::Bin(BinOp::Or, Box::new(e), Box::new(r));
        }
        Ok(e)
    }

    fn and_expr(&mut self) -> PResult<Expr> {
        let mut e = self.cmp_expr()?;
        while self.eat(Tok::AndAnd) {
            let r = self.cmp_expr()?;
            e = Expr::Bin(BinOp::And, Box::new(e), Box::new(r));
        }
        Ok(e)
    }

    fn cmp_expr(&mut self) -> PResult<Expr> {
        let e = self.add_expr()?;
        let op = match self.peek() {
            Tok::EqEq => BinOp::Eq,
            Tok::Ne => BinOp::Ne,
            Tok::Lt => BinOp::Lt,
            Tok::Le => BinOp::Le,
            Tok::Gt => BinOp::Gt,
            Tok::Ge => BinOp::Ge,
            _ => return Ok(e),
        };
        self.bump();
        let r = self.add_expr()?;
        Ok(Expr::Bin(op, Box::new(e), Box::new(r)))
    }

    fn add_expr(&mut self) -> PResult<Expr> {
        let mut e = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Plus => BinOp::Add,
                Tok::Minus => BinOp::Sub,
                _ => break,
            };
            self.bump();
            let r = self.mul_expr()?;
            e = Expr::Bin(op, Box::new(e), Box::new(r));
        }
        Ok(e)
    }

    fn mul_expr(&mut self) -> PResult<Expr> {
        let mut e = self.unary_expr()?;
        loop {
            let op = match self.peek() {
                Tok::Star => BinOp::Mul,
                Tok::Slash => BinOp::Div,
                Tok::Percent => BinOp::Mod,
                _ => break,
            };
            self.bump();
            let r = self.unary_expr()?;
            e = Expr::Bin(op, Box::new(e), Box::new(r));
        }
        Ok(e)
    }

    fn unary_expr(&mut self) -> PResult<Expr> {
        match self.peek() {
            Tok::Minus => {
                self.bump();
                Ok(Expr::Unary(UnOp::Neg, Box::new(self.unary_expr()?)))
            }
            Tok::Bang => {
                self.bump();
                Ok(Expr::Unary(UnOp::Not, Box::new(self.unary_expr()?)))
            }
            _ => self.postfix_expr(),
        }
    }

    fn postfix_expr(&mut self) -> PResult<Expr> {
        let mut e = self.primary_expr()?;
        loop {
            match self.peek() {
                Tok::LBracket => {
                    self.bump();
                    let mut idx = vec![];
                    loop {
                        idx.push(self.expr()?);
                        if !self.eat(Tok::Comma) {
                            break;
                        }
                    }
                    self.expect(Tok::RBracket)?;
                    e = Expr::Index(Box::new(e), idx);
                }
                Tok::LParen => {
                    // Call only on plain identifiers (builtins).
                    let name = match &e {
                        Expr::Ident(s) => s.clone(),
                        _ => return self.err("only identifiers are callable"),
                    };
                    self.bump();
                    let mut args = vec![];
                    if *self.peek() != Tok::RParen {
                        loop {
                            args.push(self.expr()?);
                            if !self.eat(Tok::Comma) {
                                break;
                            }
                        }
                    }
                    self.expect(Tok::RParen)?;
                    e = Expr::Call(name, args);
                }
                _ => break,
            }
        }
        Ok(e)
    }

    fn primary_expr(&mut self) -> PResult<Expr> {
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(Expr::Int(v))
            }
            Tok::Float(v) => {
                self.bump();
                Ok(Expr::Float(v))
            }
            Tok::Ident(s) => {
                self.bump();
                Ok(Expr::Ident(s))
            }
            Tok::LParen => {
                self.bump();
                let e = self.expr()?;
                self.expect(Tok::RParen)?;
                Ok(e)
            }
            other => self.err(format!("expected expression, found {other}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_kernel() {
        let k = parse_kernel("kernel @empty() { }").unwrap();
        assert_eq!(k.name, "empty");
        assert!(k.items.is_empty());
    }

    #[test]
    fn meta_params_and_args() {
        let k = parse_kernel(
            "kernel @r<K, N>(stream<f32>[K] readonly a_in, stream<f32>[1] writeonly out) { }",
        )
        .unwrap();
        assert_eq!(k.meta_params, vec!["K", "N"]);
        assert_eq!(k.args.len(), 2);
        match &k.args[0] {
            KernelArg::Stream { dir, name, .. } => {
                assert_eq!(*dir, ArgDir::ReadOnly);
                assert_eq!(name, "a_in");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn place_block() {
        let k = parse_kernel(
            "kernel @p<K>() { place i16 i, i16 j in [0:K, 0] { f32[K] a f32 s } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Place { header, decls } => {
                assert_eq!(header.vars.len(), 2);
                assert_eq!(header.subgrid.len(), 2);
                assert_eq!(decls.len(), 2);
                assert_eq!(decls[0].name, "a");
                assert_eq!(decls[0].dims.len(), 1);
                assert!(decls[1].dims.is_empty());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn dataflow_and_multicast() {
        let k = parse_kernel(
            "kernel @d<K>() { phase { dataflow i32 i, i32 j in [0:K, 0] {
                stream<f32> red = relative_stream(-1, 0)
                stream<f32> bc = relative_stream([1:K], 0)
            } } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Phase { items, .. } => match &items[0] {
                Item::Dataflow { decls, .. } => {
                    assert_eq!(decls.len(), 2);
                    assert!(matches!(decls[0].dx, StreamOffset::Scalar(_)));
                    assert!(matches!(decls[1].dx, StreamOffset::Range(_, _)));
                }
                _ => panic!(),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn ternary_stream_select() {
        let k = parse_kernel(
            "kernel @t<N>() { compute i32 i, i32 j in [N-1, 0] {
                await send(a, red if (N-1) % 2 == 0 else blue)
            } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Compute { body, .. } => match &body[0] {
                Stmt::AwaitStmt { op, .. } => match op.as_ref() {
                    Stmt::Send { stream, .. } => {
                        assert!(matches!(stream, Expr::Cond { .. }));
                    }
                    _ => panic!(),
                },
                _ => panic!(),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn foreach_forms() {
        let k = parse_kernel(
            "kernel @f<K>() { compute i32 i, i32 j in [0, 0] {
                await foreach i32 k, f32 x in [0:K], receive(red) { a[k] = a[k] + x }
                foreach f32 x in receive(blue) { s = s + x }
            } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Compute { body, .. } => {
                match &body[0] {
                    Stmt::AwaitStmt { op, .. } => match op.as_ref() {
                        Stmt::ForeachRecv { index, range, .. } => {
                            assert!(index.is_some());
                            assert!(range.is_some());
                        }
                        _ => panic!(),
                    },
                    _ => panic!(),
                }
                match &body[1] {
                    Stmt::ForeachRecv { index, range, .. } => {
                        assert!(index.is_none());
                        assert!(range.is_none());
                    }
                    _ => panic!(),
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn completion_and_await() {
        let k = parse_kernel(
            "kernel @c() { compute i32 i, i32 j in [0, 0] {
                completion c = send(a, s)
                await c
                awaitall
            } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Compute { body, .. } => {
                assert!(matches!(body[0], Stmt::CompletionDecl { .. }));
                assert!(matches!(body[1], Stmt::AwaitName { .. }));
                assert!(matches!(body[2], Stmt::AwaitAll { .. }));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn meta_for_unroll_syntax() {
        let k = parse_kernel(
            "kernel @tree<L>() { for i32 l in [0:L] { phase {
                compute i32 i, i32 j in [0:4, 0] { awaitall }
            } } }",
        )
        .unwrap();
        assert!(matches!(k.items[0], Item::MetaFor { .. }));
    }

    #[test]
    fn map_and_for_and_if() {
        let k = parse_kernel(
            "kernel @m<K>() { compute i32 i, i32 j in [0, 0] {
                map i32 k in [0:K] { out[k] = 2.0 * a[k] }
                for i64 t in [0:10:2] { s = s + 1 }
                if i % 2 == 0 { s = 0 } else { s = 1 }
            } }",
        )
        .unwrap();
        match &k.items[0] {
            Item::Compute { body, .. } => {
                assert!(matches!(body[0], Stmt::Map { .. }));
                assert!(matches!(body[1], Stmt::For { .. }));
                assert!(matches!(body[2], Stmt::If { .. }));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn listing1_full() {
        // Paper Listing 1 (pipelined chain reduce), normalized syntax.
        let src = r#"
kernel @chain_reduce<K, N>(stream<f32>[N] readonly a_in, stream<f32>[1] writeonly out) {
  place i16 i, i16 j in [0:N, 0] {
    f32[K] a
  }
  // Phase 1: Read argument stream
  phase {
    compute i32 i, i32 j in [0:N, 0] {
      await receive(a, a_in[i])
    }
  }
  // Phase 2: Perform reduction
  phase {
    dataflow i32 i, i32 j in [0:N, 0] {
      stream<f32> red = relative_stream(-1, 0)
      stream<f32> blue = relative_stream(-1, 0)
    }
    // East corner
    compute i32 i, i32 j in [N-1, 0] {
      await send(a, red if (N-1) % 2 == 0 else blue)
    }
    // Odd PEs
    compute i32 i, i32 j in [1:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(red) {
        a[k] = a[k] + x
        await send(a[k], blue)
      }
    }
    // Even PEs
    compute i32 i, i32 j in [2:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) {
        a[k] = a[k] + x
        await send(a[k], red)
      }
    }
    // West corner (root)
    compute i32 i, i32 j in [0, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) {
        a[k] = a[k] + x
      }
      await send(a, out[0])
    }
  }
}
"#;
        let k = parse_kernel(src).unwrap();
        assert_eq!(k.name, "chain_reduce");
        assert_eq!(k.items.len(), 3); // place + 2 phases
    }

    #[test]
    fn error_reporting() {
        let err = parse_kernel("kernel @x() { place }").unwrap_err();
        assert!(err.msg.contains("expected type"));
        assert_eq!(err.span.line, 1);
    }
}

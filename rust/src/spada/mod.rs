//! The SpaDA language: lexer, AST, parser, pretty-printer.
//!
//! SpaDA (paper §III) programs are *kernels* made of phases; each phase
//! contains `place` blocks (data allocation over PE subgrids), `dataflow`
//! blocks (typed relative streams between PEs), and `compute` blocks
//! (async/await computation driven by streams). Meta-programming `for`
//! loops unroll into series of phases (e.g. the levels of a reduction
//! tree).

pub mod token;
pub mod lexer;
pub mod ast;
pub mod parser;
pub mod pretty;

pub use ast::*;
pub use lexer::Lexer;
pub use parser::{parse_kernel, ParseError};

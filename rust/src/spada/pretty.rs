//! Pretty-printer for SpaDA ASTs.
//!
//! Used for the Table II LoC accounting (SpaDA source lines are counted
//! on the canonical pretty-printed form) and for debugging lowering.

use super::ast::*;

pub fn print_kernel(k: &Kernel) -> String {
    let mut p = Printer::default();
    p.kernel(k);
    p.out
}

/// Count non-blank lines of the canonical form (SpaDA LoC metric).
pub fn count_loc(k: &Kernel) -> usize {
    print_kernel(k).lines().filter(|l| !l.trim().is_empty()).count()
}

#[derive(Default)]
struct Printer {
    out: String,
    indent: usize,
}

impl Printer {
    fn line(&mut self, s: &str) {
        for _ in 0..self.indent {
            self.out.push_str("  ");
        }
        self.out.push_str(s);
        self.out.push('\n');
    }

    fn kernel(&mut self, k: &Kernel) {
        let meta = if k.meta_params.is_empty() {
            String::new()
        } else {
            format!("<{}>", k.meta_params.join(", "))
        };
        let args: Vec<String> = k.args.iter().map(arg_str).collect();
        self.line(&format!("kernel @{}{}({}) {{", k.name, meta, args.join(", ")));
        self.indent += 1;
        for item in &k.items {
            self.item(item);
        }
        self.indent -= 1;
        self.line("}");
    }

    fn item(&mut self, item: &Item) {
        match item {
            Item::Place { header, decls } => {
                self.line(&format!("place {} {{", header_str(header)));
                self.indent += 1;
                for d in decls {
                    let dims = if d.dims.is_empty() {
                        String::new()
                    } else {
                        format!("[{}]", exprs_str(&d.dims))
                    };
                    self.line(&format!("{}{} {}", d.ty.name(), dims, d.name));
                }
                self.indent -= 1;
                self.line("}");
            }
            Item::Dataflow { header, decls } => {
                self.line(&format!("dataflow {} {{", header_str(header)));
                self.indent += 1;
                for d in decls {
                    self.line(&format!(
                        "stream<{}> {} = relative_stream({}, {})",
                        d.elem_ty.name(),
                        d.name,
                        offset_str(&d.dx),
                        offset_str(&d.dy)
                    ));
                }
                self.indent -= 1;
                self.line("}");
            }
            Item::Compute { header, body } => {
                self.line(&format!("compute {} {{", header_str(header)));
                self.indent += 1;
                for s in body {
                    self.stmt(s);
                }
                self.indent -= 1;
                self.line("}");
            }
            Item::Phase { items, .. } => {
                self.line("phase {");
                self.indent += 1;
                for i in items {
                    self.item(i);
                }
                self.indent -= 1;
                self.line("}");
            }
            Item::MetaFor { var, range, body, .. } => {
                self.line(&format!(
                    "for {} {} in [{}] {{",
                    var.0.name(),
                    var.1,
                    range_str(range)
                ));
                self.indent += 1;
                for i in body {
                    self.item(i);
                }
                self.indent -= 1;
                self.line("}");
            }
        }
    }

    fn stmt(&mut self, s: &Stmt) {
        match s {
            Stmt::Send { data, stream, .. } => {
                self.line(&format!("send({}, {})", expr_str(data), expr_str(stream)))
            }
            Stmt::Receive { dst, stream, .. } => {
                self.line(&format!("receive({}, {})", expr_str(dst), expr_str(stream)))
            }
            Stmt::ForeachRecv { index, elem, range, stream, body, .. } => {
                let vars = match index {
                    Some((t, n)) => format!("{} {}, {} {}", t.name(), n, elem.0.name(), elem.1),
                    None => format!("{} {}", elem.0.name(), elem.1),
                };
                let src = match range {
                    Some(r) => format!("[{}], receive({})", range_str(r), expr_str(stream)),
                    None => format!("receive({})", expr_str(stream)),
                };
                self.line(&format!("foreach {vars} in {src} {{"));
                self.indent += 1;
                for st in body {
                    self.stmt(st);
                }
                self.indent -= 1;
                self.line("}");
            }
            Stmt::Map { vars, ranges, body, .. } => {
                let vs: Vec<String> =
                    vars.iter().map(|(t, n)| format!("{} {}", t.name(), n)).collect();
                let rs: Vec<String> = ranges.iter().map(range_str).collect();
                self.line(&format!("map {} in [{}] {{", vs.join(", "), rs.join(", ")));
                self.indent += 1;
                for st in body {
                    self.stmt(st);
                }
                self.indent -= 1;
                self.line("}");
            }
            Stmt::For { var, range, body, .. } => {
                self.line(&format!(
                    "for {} {} in [{}] {{",
                    var.0.name(),
                    var.1,
                    range_str(range)
                ));
                self.indent += 1;
                for st in body {
                    self.stmt(st);
                }
                self.indent -= 1;
                self.line("}");
            }
            Stmt::Async { body, .. } => {
                self.line("async {");
                self.indent += 1;
                for st in body {
                    self.stmt(st);
                }
                self.indent -= 1;
                self.line("}");
            }
            Stmt::CompletionDecl { name, op, .. } => {
                self.line(&format!("completion {name} ="));
                self.indent += 1;
                self.stmt(op);
                self.indent -= 1;
            }
            Stmt::AwaitStmt { op, .. } => {
                // Inline `await` prefix onto the op's first line.
                let mut sub = Printer { out: String::new(), indent: 0 };
                sub.stmt(op);
                let mut lines = sub.out.lines();
                if let Some(first) = lines.next() {
                    self.line(&format!("await {first}"));
                    for l in lines {
                        self.line(l);
                    }
                }
            }
            Stmt::AwaitName { name, .. } => self.line(&format!("await {name}")),
            Stmt::AwaitAll { .. } => self.line("awaitall"),
            Stmt::Assign { lhs, rhs, .. } => {
                self.line(&format!("{} = {}", expr_str(lhs), expr_str(rhs)))
            }
            Stmt::Let { ty, name, init, .. } => {
                self.line(&format!("{} {} = {}", ty.name(), name, expr_str(init)))
            }
            Stmt::If { cond, then_body, else_body, .. } => {
                self.line(&format!("if {} {{", expr_str(cond)));
                self.indent += 1;
                for st in then_body {
                    self.stmt(st);
                }
                self.indent -= 1;
                if else_body.is_empty() {
                    self.line("}");
                } else {
                    self.line("} else {");
                    self.indent += 1;
                    for st in else_body {
                        self.stmt(st);
                    }
                    self.indent -= 1;
                    self.line("}");
                }
            }
        }
    }
}

fn arg_str(a: &KernelArg) -> String {
    match a {
        KernelArg::Stream { elem_ty, extents, dir, name } => {
            let ext = if extents.is_empty() {
                String::new()
            } else {
                format!("[{}]", exprs_str(extents))
            };
            let d = match dir {
                ArgDir::ReadOnly => "readonly",
                ArgDir::WriteOnly => "writeonly",
            };
            format!("stream<{}>{} {} {}", elem_ty.name(), ext, d, name)
        }
        KernelArg::Scalar { ty, name } => format!("const {} {}", ty.name(), name),
    }
}

fn header_str(h: &BlockHeader) -> String {
    let vars: Vec<String> = h.vars.iter().map(|(t, n)| format!("{} {}", t.name(), n)).collect();
    let ranges: Vec<String> = h.subgrid.iter().map(range_str).collect();
    format!("{} in [{}]", vars.join(", "), ranges.join(", "))
}

fn range_str(r: &RangeExpr) -> String {
    match (&r.stop, &r.step) {
        (None, _) => expr_str(&r.start),
        (Some(stop), None) => format!("{}:{}", expr_str(&r.start), expr_str(stop)),
        (Some(stop), Some(step)) => {
            format!("{}:{}:{}", expr_str(&r.start), expr_str(stop), expr_str(step))
        }
    }
}

fn offset_str(o: &StreamOffset) -> String {
    match o {
        StreamOffset::Scalar(e) => expr_str(e),
        StreamOffset::Range(a, b) => format!("[{}:{}]", expr_str(a), expr_str(b)),
    }
}

fn exprs_str(es: &[Expr]) -> String {
    es.iter().map(expr_str).collect::<Vec<_>>().join(", ")
}

pub fn expr_str(e: &Expr) -> String {
    match e {
        Expr::Int(v) => v.to_string(),
        Expr::Float(v) => {
            if v.fract() == 0.0 {
                format!("{v:.1}")
            } else {
                v.to_string()
            }
        }
        Expr::Ident(s) => s.clone(),
        Expr::Index(b, idx) => format!("{}[{}]", expr_str(b), exprs_str(idx)),
        Expr::Unary(UnOp::Neg, a) => format!("-{}", expr_str(a)),
        Expr::Unary(UnOp::Not, a) => format!("!{}", expr_str(a)),
        Expr::Bin(op, a, b) => {
            let o = match op {
                BinOp::Add => "+",
                BinOp::Sub => "-",
                BinOp::Mul => "*",
                BinOp::Div => "/",
                BinOp::Mod => "%",
                BinOp::Eq => "==",
                BinOp::Ne => "!=",
                BinOp::Lt => "<",
                BinOp::Le => "<=",
                BinOp::Gt => ">",
                BinOp::Ge => ">=",
                BinOp::And => "&&",
                BinOp::Or => "||",
            };
            format!("({} {} {})", expr_str(a), o, expr_str(b))
        }
        Expr::Cond { then, cond, els } => {
            format!("{} if {} else {}", expr_str(then), expr_str(cond), expr_str(els))
        }
        Expr::Call(name, args) => format!("{}({})", name, exprs_str(args)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spada::parse_kernel;

    #[test]
    fn roundtrip_parses() {
        let src = "kernel @k<K>(stream<f32>[K] readonly a_in) {
            place i16 i, i16 j in [0:K, 0] { f32[K] a }
            phase { compute i32 i, i32 j in [0:K, 0] { await receive(a, a_in[i]) } }
        }";
        let k = parse_kernel(src).unwrap();
        let printed = print_kernel(&k);
        let k2 = parse_kernel(&printed).unwrap();
        assert_eq!(print_kernel(&k2), printed);
    }

    #[test]
    fn loc_counts_nonblank() {
        let k = parse_kernel("kernel @e() { }").unwrap();
        assert_eq!(count_loc(&k), 2);
    }
}

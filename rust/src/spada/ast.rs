//! SpaDA abstract syntax tree (paper §III, Table I).

use super::token::Span;
use crate::machine::Dtype;

/// Scalar element types (surface syntax `f32`, `i16`, ...).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Type {
    F16,
    F32,
    I16,
    I32,
    I64,
    U16,
    U32,
}

impl Type {
    pub fn dtype(&self) -> Dtype {
        match self {
            Type::F16 => Dtype::F16,
            Type::F32 => Dtype::F32,
            Type::I16 => Dtype::I16,
            Type::I32 | Type::I64 => Dtype::I32,
            Type::U16 => Dtype::U16,
            Type::U32 => Dtype::U32,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Type::F16 => "f16",
            Type::F32 => "f32",
            Type::I16 => "i16",
            Type::I32 => "i32",
            Type::I64 => "i64",
            Type::U16 => "u16",
            Type::U32 => "u32",
        }
    }

    pub fn is_float(&self) -> bool {
        matches!(self, Type::F16 | Type::F32)
    }
}

/// Binary operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Mod,
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
}

/// Expressions.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(i64),
    Float(f64),
    /// Identifier: meta-param, index var, field, stream, completion, arg.
    Ident(String),
    /// Indexing: `a[k]`, `a[i, j]`, `a_in[i]`.
    Index(Box<Expr>, Vec<Expr>),
    Unary(UnOp, Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
    /// Python-style conditional: `a if cond else b`.
    Cond { then: Box<Expr>, cond: Box<Expr>, els: Box<Expr> },
    /// Builtin call, e.g. `min(a, b)`.
    Call(String, Vec<Expr>),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UnOp {
    Neg,
    Not,
}

impl Expr {
    pub fn ident(s: &str) -> Expr {
        Expr::Ident(s.to_string())
    }
}

/// A range expression `[start : stop : step]` (any component may be an
/// arbitrary expression; `stop`/`step` optional → point / step 1).
#[derive(Clone, Debug, PartialEq)]
pub struct RangeExpr {
    pub start: Expr,
    pub stop: Option<Expr>,
    pub step: Option<Expr>,
}

impl RangeExpr {
    pub fn point(e: Expr) -> RangeExpr {
        RangeExpr { start: e, stop: None, step: None }
    }
}

/// Block header: `TYPE i, TYPE j in [r0, r1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockHeader {
    pub vars: Vec<(Type, String)>,
    pub subgrid: Vec<RangeExpr>,
    pub span: Span,
}

/// A declaration inside a `place` block: `f32[K] a` or `f32 scal`.
#[derive(Clone, Debug, PartialEq)]
pub struct PlaceDecl {
    pub ty: Type,
    /// Array dimensions; empty → scalar.
    pub dims: Vec<Expr>,
    pub name: String,
    pub span: Span,
}

/// Stream offset: scalar `dx` or multicast range `[dx0:dx1]`.
#[derive(Clone, Debug, PartialEq)]
pub enum StreamOffset {
    Scalar(Expr),
    Range(Expr, Expr),
}

/// A declaration inside a `dataflow` block:
/// `stream<f32> s = relative_stream(dx, dy)`.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamDecl {
    pub elem_ty: Type,
    pub name: String,
    pub dx: StreamOffset,
    pub dy: StreamOffset,
    pub span: Span,
}

/// Kernel argument direction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgDir {
    ReadOnly,
    WriteOnly,
}

/// Kernel argument: `stream<f32>[K] readonly a_in` — an array of host
/// stream ports distributed over a subgrid, or `const i32 n` scalar.
#[derive(Clone, Debug, PartialEq)]
pub enum KernelArg {
    Stream {
        elem_ty: Type,
        /// Port-grid extents (one per dimension of the port array).
        extents: Vec<Expr>,
        dir: ArgDir,
        name: String,
    },
    Scalar {
        ty: Type,
        name: String,
    },
}

impl KernelArg {
    pub fn name(&self) -> &str {
        match self {
            KernelArg::Stream { name, .. } | KernelArg::Scalar { name, .. } => name,
        }
    }
}

/// Statements inside `compute` blocks.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `send(data, stream_expr)`
    Send { data: Expr, stream: Expr, span: Span },
    /// `receive(dst, stream_expr)` — whole-array receive.
    Receive { dst: Expr, stream: Expr, span: Span },
    /// `foreach [u16 k,] f32 x in [range,] receive(s) { body }`
    ForeachRecv {
        index: Option<(Type, String)>,
        elem: (Type, String),
        range: Option<RangeExpr>,
        stream: Expr,
        body: Vec<Stmt>,
        span: Span,
    },
    /// `map i32 i in [I:J:K] { body }` — parallelizable affine loop.
    Map { vars: Vec<(Type, String)>, ranges: Vec<RangeExpr>, body: Vec<Stmt>, span: Span },
    /// `for i64 i in [I:J:K] { body }` — sequential loop.
    For { var: (Type, String), range: RangeExpr, body: Vec<Stmt>, span: Span },
    /// `async { body }`
    Async { body: Vec<Stmt>, span: Span },
    /// `completion c = <stmt>` — capture the async op's completion.
    CompletionDecl { name: String, op: Box<Stmt>, span: Span },
    /// `await <stmt>` — run op synchronously.
    AwaitStmt { op: Box<Stmt>, span: Span },
    /// `await c` — wait for a named completion.
    AwaitName { name: String, span: Span },
    /// `awaitall`
    AwaitAll { span: Span },
    /// `lhs = rhs` (lhs: scalar var or array element).
    Assign { lhs: Expr, rhs: Expr, span: Span },
    /// Local scalar declaration: `f32 t = expr`.
    Let { ty: Type, name: String, init: Expr, span: Span },
    /// Statement-level conditional.
    If { cond: Expr, then_body: Vec<Stmt>, else_body: Vec<Stmt>, span: Span },
}

impl Stmt {
    pub fn span(&self) -> Span {
        match self {
            Stmt::Send { span, .. }
            | Stmt::Receive { span, .. }
            | Stmt::ForeachRecv { span, .. }
            | Stmt::Map { span, .. }
            | Stmt::For { span, .. }
            | Stmt::Async { span, .. }
            | Stmt::CompletionDecl { span, .. }
            | Stmt::AwaitStmt { span, .. }
            | Stmt::AwaitName { span, .. }
            | Stmt::AwaitAll { span }
            | Stmt::Assign { span, .. }
            | Stmt::Let { span, .. }
            | Stmt::If { span, .. } => *span,
        }
    }
}

/// Top-level items inside a kernel or phase.
#[derive(Clone, Debug, PartialEq)]
pub enum Item {
    Place { header: BlockHeader, decls: Vec<PlaceDecl> },
    Dataflow { header: BlockHeader, decls: Vec<StreamDecl> },
    Compute { header: BlockHeader, body: Vec<Stmt> },
    Phase { items: Vec<Item>, span: Span },
    /// Meta-programming loop — unrolls into a series of phases.
    MetaFor { var: (Type, String), range: RangeExpr, body: Vec<Item>, span: Span },
}

/// A complete kernel.
#[derive(Clone, Debug, PartialEq)]
pub struct Kernel {
    pub name: String,
    /// Compile-time meta parameters `<K, N>`.
    pub meta_params: Vec<String>,
    pub args: Vec<KernelArg>,
    pub items: Vec<Item>,
}

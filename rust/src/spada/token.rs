//! SpaDA tokens.

use std::fmt;

/// Source location (byte offset + line/col for diagnostics).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

impl fmt::Display for Span {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}", self.line, self.col)
    }
}

/// Token kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    // Literals / identifiers
    Ident(String),
    Int(i64),
    Float(f64),

    // Keywords
    Kernel,
    Place,
    Dataflow,
    Compute,
    Phase,
    For,
    Foreach,
    Map,
    Async,
    Await,
    Awaitall,
    Send,
    Receive,
    Stream,
    RelativeStream,
    Completion,
    If,
    Else,
    In,
    Readonly,
    Writeonly,
    Const,

    // Types
    TyF16,
    TyF32,
    TyI16,
    TyI32,
    TyI64,
    TyU16,
    TyU32,

    // Punctuation
    At,        // @
    LParen,    // (
    RParen,    // )
    LBracket,  // [
    RBracket,  // ]
    LBrace,    // {
    RBrace,    // }
    Lt,        // <
    Gt,        // >
    Le,        // <=
    Ge,        // >=
    EqEq,      // ==
    Ne,        // !=
    Assign,    // =
    Plus,      // +
    Minus,     // -
    Star,      // *
    Slash,     // /
    Percent,   // %
    Comma,     // ,
    Colon,     // :
    Semicolon, // ;
    AndAnd,    // &&
    OrOr,      // ||
    Bang,      // !

    Eof,
}

impl Tok {
    /// Keyword lookup for identifiers.
    pub fn keyword(s: &str) -> Option<Tok> {
        Some(match s {
            "kernel" => Tok::Kernel,
            "place" => Tok::Place,
            "dataflow" => Tok::Dataflow,
            "compute" => Tok::Compute,
            "phase" => Tok::Phase,
            "for" => Tok::For,
            "foreach" => Tok::Foreach,
            "map" => Tok::Map,
            "async" => Tok::Async,
            "await" => Tok::Await,
            "awaitall" => Tok::Awaitall,
            "send" => Tok::Send,
            "receive" => Tok::Receive,
            "stream" => Tok::Stream,
            "relative_stream" => Tok::RelativeStream,
            "completion" => Tok::Completion,
            "if" => Tok::If,
            "else" => Tok::Else,
            "in" => Tok::In,
            "readonly" => Tok::Readonly,
            "writeonly" => Tok::Writeonly,
            "const" => Tok::Const,
            "f16" => Tok::TyF16,
            "f32" => Tok::TyF32,
            "i16" => Tok::TyI16,
            "i32" => Tok::TyI32,
            "i64" => Tok::TyI64,
            "u16" => Tok::TyU16,
            "u32" => Tok::TyU32,
            _ => return None,
        })
    }

    pub fn is_type(&self) -> bool {
        matches!(
            self,
            Tok::TyF16 | Tok::TyF32 | Tok::TyI16 | Tok::TyI32 | Tok::TyI64 | Tok::TyU16 | Tok::TyU32
        )
    }
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "{s}"),
            Tok::Int(v) => write!(f, "{v}"),
            Tok::Float(v) => write!(f, "{v}"),
            other => write!(f, "{}", format!("{other:?}").to_lowercase()),
        }
    }
}

/// A token with its source span.
#[derive(Clone, Debug, PartialEq)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}

//! Hand-written lexer for SpaDA source text.

use super::token::{Span, Tok, Token};

/// Lexer error with position.
#[derive(Debug, Clone)]
pub struct LexError {
    pub msg: String,
    pub span: Span,
}

impl std::fmt::Display for LexError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "lex error at {}: {}", self.span, self.msg)
    }
}

impl std::error::Error for LexError {}

pub struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    pub fn new(src: &'a str) -> Self {
        Lexer { src: src.as_bytes(), pos: 0, line: 1, col: 1 }
    }

    fn peek(&self) -> u8 {
        *self.src.get(self.pos).unwrap_or(&0)
    }

    fn peek2(&self) -> u8 {
        *self.src.get(self.pos + 1).unwrap_or(&0)
    }

    fn bump(&mut self) -> u8 {
        let c = self.peek();
        self.pos += 1;
        if c == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        c
    }

    fn span(&self) -> Span {
        Span { line: self.line, col: self.col }
    }

    fn skip_ws_and_comments(&mut self) {
        loop {
            match self.peek() {
                b' ' | b'\t' | b'\r' | b'\n' => {
                    self.bump();
                }
                b'/' if self.peek2() == b'/' => {
                    while self.peek() != b'\n' && self.peek() != 0 {
                        self.bump();
                    }
                }
                b'/' if self.peek2() == b'*' => {
                    self.bump();
                    self.bump();
                    while !(self.peek() == b'*' && self.peek2() == b'/') && self.peek() != 0 {
                        self.bump();
                    }
                    self.bump();
                    self.bump();
                }
                _ => break,
            }
        }
    }

    /// Tokenize the whole input.
    pub fn tokenize(mut self) -> Result<Vec<Token>, LexError> {
        let mut out = Vec::new();
        loop {
            self.skip_ws_and_comments();
            let span = self.span();
            let c = self.peek();
            if c == 0 {
                out.push(Token { tok: Tok::Eof, span });
                return Ok(out);
            }
            let tok = match c {
                b'a'..=b'z' | b'A'..=b'Z' | b'_' => {
                    let start = self.pos;
                    while matches!(self.peek(), b'a'..=b'z' | b'A'..=b'Z' | b'0'..=b'9' | b'_') {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
                    Tok::keyword(s).unwrap_or_else(|| Tok::Ident(s.to_string()))
                }
                b'0'..=b'9' => {
                    let start = self.pos;
                    let mut is_float = false;
                    while self.peek().is_ascii_digit() {
                        self.bump();
                    }
                    if self.peek() == b'.' && self.peek2().is_ascii_digit() {
                        is_float = true;
                        self.bump();
                        while self.peek().is_ascii_digit() {
                            self.bump();
                        }
                    }
                    if matches!(self.peek(), b'e' | b'E') {
                        is_float = true;
                        self.bump();
                        if matches!(self.peek(), b'+' | b'-') {
                            self.bump();
                        }
                        while self.peek().is_ascii_digit() {
                            self.bump();
                        }
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
                    if is_float {
                        Tok::Float(s.parse().map_err(|e| LexError {
                            msg: format!("bad float {s}: {e}"),
                            span,
                        })?)
                    } else {
                        Tok::Int(s.parse().map_err(|e| LexError {
                            msg: format!("bad int {s}: {e}"),
                            span,
                        })?)
                    }
                }
                b'@' => {
                    self.bump();
                    Tok::At
                }
                b'(' => {
                    self.bump();
                    Tok::LParen
                }
                b')' => {
                    self.bump();
                    Tok::RParen
                }
                b'[' => {
                    self.bump();
                    Tok::LBracket
                }
                b']' => {
                    self.bump();
                    Tok::RBracket
                }
                b'{' => {
                    self.bump();
                    Tok::LBrace
                }
                b'}' => {
                    self.bump();
                    Tok::RBrace
                }
                b'<' => {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::Le
                    } else {
                        Tok::Lt
                    }
                }
                b'>' => {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::Ge
                    } else {
                        Tok::Gt
                    }
                }
                b'=' => {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::EqEq
                    } else {
                        Tok::Assign
                    }
                }
                b'!' => {
                    self.bump();
                    if self.peek() == b'=' {
                        self.bump();
                        Tok::Ne
                    } else {
                        Tok::Bang
                    }
                }
                b'&' if self.peek2() == b'&' => {
                    self.bump();
                    self.bump();
                    Tok::AndAnd
                }
                b'|' if self.peek2() == b'|' => {
                    self.bump();
                    self.bump();
                    Tok::OrOr
                }
                b'+' => {
                    self.bump();
                    Tok::Plus
                }
                b'-' => {
                    self.bump();
                    Tok::Minus
                }
                b'*' => {
                    self.bump();
                    Tok::Star
                }
                b'/' => {
                    self.bump();
                    Tok::Slash
                }
                b'%' => {
                    self.bump();
                    Tok::Percent
                }
                b',' => {
                    self.bump();
                    Tok::Comma
                }
                b':' => {
                    self.bump();
                    Tok::Colon
                }
                b';' => {
                    self.bump();
                    Tok::Semicolon
                }
                other => {
                    return Err(LexError {
                        msg: format!("unexpected character {:?}", other as char),
                        span,
                    })
                }
            };
            out.push(Token { tok, span });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        Lexer::new(src).tokenize().unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn keywords_and_idents() {
        let t = toks("kernel @foo place xyz f32");
        assert_eq!(
            t,
            vec![
                Tok::Kernel,
                Tok::At,
                Tok::Ident("foo".into()),
                Tok::Place,
                Tok::Ident("xyz".into()),
                Tok::TyF32,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn numbers() {
        let t = toks("42 3.5 1e3 2.5e-2");
        assert_eq!(
            t,
            vec![Tok::Int(42), Tok::Float(3.5), Tok::Float(1e3), Tok::Float(2.5e-2), Tok::Eof]
        );
    }

    #[test]
    fn operators() {
        let t = toks("<= >= == != && || ! < > = + - * / %");
        assert_eq!(
            t,
            vec![
                Tok::Le,
                Tok::Ge,
                Tok::EqEq,
                Tok::Ne,
                Tok::AndAnd,
                Tok::OrOr,
                Tok::Bang,
                Tok::Lt,
                Tok::Gt,
                Tok::Assign,
                Tok::Plus,
                Tok::Minus,
                Tok::Star,
                Tok::Slash,
                Tok::Percent,
                Tok::Eof
            ]
        );
    }

    #[test]
    fn comments() {
        let t = toks("a // line comment\n b /* block\n comment */ c");
        assert_eq!(
            t,
            vec![
                Tok::Ident("a".into()),
                Tok::Ident("b".into()),
                Tok::Ident("c".into()),
                Tok::Eof
            ]
        );
    }

    #[test]
    fn spans_track_lines() {
        let tokens = Lexer::new("a\n  b").tokenize().unwrap();
        assert_eq!(tokens[0].span.line, 1);
        assert_eq!(tokens[1].span.line, 2);
        assert_eq!(tokens[1].span.col, 3);
    }

    #[test]
    fn listing1_snippet() {
        let src = "stream<f32> red = relative_stream(-1, 0)";
        let t = toks(src);
        assert!(t.contains(&Tok::Stream));
        assert!(t.contains(&Tok::RelativeStream));
        assert!(t.contains(&Tok::Ident("red".into())));
    }

    #[test]
    fn bad_char() {
        assert!(Lexer::new("a $ b").tokenize().is_err());
    }
}

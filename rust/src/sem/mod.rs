//! Semantic analysis: kernel instantiation, meta-for unrolling, const
//! evaluation, subgrid resolution, and type/usage checking.
pub mod eval;
pub mod instantiate;
pub use instantiate::{instantiate, Bindings, SemError};

//! Constant expression evaluation and folding over the AST.

use crate::spada::ast::{BinOp, Expr, UnOp};
use std::collections::HashMap;

/// Compile-time environment: meta-parameters and unrolled meta-for vars.
pub type Env = HashMap<String, i64>;

/// Evaluate an expression to a compile-time integer, if possible.
pub fn eval_int(e: &Expr, env: &Env) -> Option<i64> {
    Some(match e {
        Expr::Int(v) => *v,
        Expr::Float(_) => return None,
        Expr::Ident(s) => *env.get(s)?,
        Expr::Unary(UnOp::Neg, a) => -eval_int(a, env)?,
        Expr::Unary(UnOp::Not, a) => (eval_int(a, env)? == 0) as i64,
        Expr::Bin(op, a, b) => {
            let x = eval_int(a, env)?;
            let y = eval_int(b, env)?;
            match op {
                BinOp::Add => x + y,
                BinOp::Sub => x - y,
                BinOp::Mul => x * y,
                BinOp::Div => {
                    if y == 0 {
                        return None;
                    }
                    x / y
                }
                BinOp::Mod => {
                    if y == 0 {
                        return None;
                    }
                    x.rem_euclid(y)
                }
                BinOp::Eq => (x == y) as i64,
                BinOp::Ne => (x != y) as i64,
                BinOp::Lt => (x < y) as i64,
                BinOp::Le => (x <= y) as i64,
                BinOp::Gt => (x > y) as i64,
                BinOp::Ge => (x >= y) as i64,
                BinOp::And => (x != 0 && y != 0) as i64,
                BinOp::Or => (x != 0 || y != 0) as i64,
            }
        }
        Expr::Cond { then, cond, els } => {
            if eval_int(cond, env)? != 0 {
                eval_int(then, env)?
            } else {
                eval_int(els, env)?
            }
        }
        Expr::Call(name, args) => match (name.as_str(), args.len()) {
            ("min", 2) => eval_int(&args[0], env)?.min(eval_int(&args[1], env)?),
            ("max", 2) => eval_int(&args[0], env)?.max(eval_int(&args[1], env)?),
            ("abs", 1) => eval_int(&args[0], env)?.abs(),
            ("log2", 1) => {
                let v = eval_int(&args[0], env)?;
                if v <= 0 {
                    return None;
                }
                63 - v.leading_zeros() as i64
            }
            ("pow2", 1) => 1i64 << eval_int(&args[0], env)?.clamp(0, 62),
            _ => return None,
        },
        Expr::Index(..) => return None,
    })
}

/// Fold constants: substitute env vars, evaluate const subtrees, resolve
/// const conditionals. Non-const parts (PE coords, field refs) survive.
pub fn fold(e: &Expr, env: &Env) -> Expr {
    if let Some(v) = eval_int(e, env) {
        return Expr::Int(v);
    }
    match e {
        Expr::Ident(s) => match env.get(s) {
            Some(v) => Expr::Int(*v),
            None => e.clone(),
        },
        Expr::Unary(op, a) => Expr::Unary(*op, Box::new(fold(a, env))),
        Expr::Bin(op, a, b) => Expr::Bin(*op, Box::new(fold(a, env)), Box::new(fold(b, env))),
        Expr::Cond { then, cond, els } => {
            // Resolve conditionals with a constant condition even when the
            // branches are not const (e.g. stream selection).
            match eval_int(cond, env) {
                Some(v) if v != 0 => fold(then, env),
                Some(_) => fold(els, env),
                None => Expr::Cond {
                    then: Box::new(fold(then, env)),
                    cond: Box::new(fold(cond, env)),
                    els: Box::new(fold(els, env)),
                },
            }
        }
        Expr::Index(b, idx) => Expr::Index(
            Box::new(fold(b, env)),
            idx.iter().map(|i| fold(i, env)).collect(),
        ),
        Expr::Call(name, args) => {
            Expr::Call(name.clone(), args.iter().map(|a| fold(a, env)).collect())
        }
        _ => e.clone(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spada::parser::parse_kernel;
    use crate::spada::ast::Item;

    fn env(pairs: &[(&str, i64)]) -> Env {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn parse_expr(src: &str) -> Expr {
        // Parse via a small kernel wrapper (assign statement).
        let k = parse_kernel(&format!(
            "kernel @t() {{ compute i32 i, i32 j in [0,0] {{ x = {src} }} }}"
        ))
        .unwrap();
        match &k.items[0] {
            Item::Compute { body, .. } => match &body[0] {
                crate::spada::ast::Stmt::Assign { rhs, .. } => rhs.clone(),
                _ => panic!(),
            },
            _ => panic!(),
        }
    }

    #[test]
    fn arithmetic() {
        let e = parse_expr("(K - 1) % 2 == 0");
        assert_eq!(eval_int(&e, &env(&[("K", 5)])), Some(1));
        assert_eq!(eval_int(&e, &env(&[("K", 6)])), Some(0));
    }

    #[test]
    fn builtins() {
        assert_eq!(eval_int(&parse_expr("log2(8)"), &env(&[])), Some(3));
        assert_eq!(eval_int(&parse_expr("pow2(4)"), &env(&[])), Some(16));
        assert_eq!(eval_int(&parse_expr("min(3, max(1, 2))"), &env(&[])), Some(2));
    }

    #[test]
    fn non_const_survives_fold() {
        let e = parse_expr("a[i] + K");
        let f = fold(&e, &env(&[("K", 7)]));
        match f {
            Expr::Bin(BinOp::Add, _, b) => assert_eq!(*b, Expr::Int(7)),
            _ => panic!("{f:?}"),
        }
    }

    #[test]
    fn const_ternary_resolves() {
        let e = parse_expr("red if (N - 1) % 2 == 0 else blue");
        let f = fold(&e, &env(&[("N", 5)]));
        assert_eq!(f, Expr::Ident("red".into()));
        let f = fold(&e, &env(&[("N", 6)]));
        assert_eq!(f, Expr::Ident("blue".into()));
    }

    #[test]
    fn div_by_zero_is_nonconst() {
        assert_eq!(eval_int(&parse_expr("1 / 0"), &env(&[])), None);
    }
}

//! Kernel instantiation: AST → IR.
//!
//! Binds meta-parameters, unrolls meta-`for` loops into phases, resolves
//! subgrids to concrete strided rectangles, folds constants (including
//! compile-time stream selection ternaries), normalizes await/completion
//! structure, and performs the semantic checks of §III.

use super::eval::{eval_int, fold, Env};
use crate::ir::core as ir;
use crate::spada::ast::{self, ArgDir, Expr, Item, Kernel, RangeExpr, StreamOffset};
use crate::util::{Range1, Subgrid};
use std::collections::{HashMap, HashSet};

/// Meta-parameter bindings for instantiation.
pub type Bindings = HashMap<String, i64>;

/// Semantic error.
#[derive(Debug, Clone)]
pub struct SemError(pub String);

impl std::fmt::Display for SemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "semantic error: {}", self.0)
    }
}

impl std::error::Error for SemError {}

type SResult<T> = Result<T, SemError>;

fn err<T>(msg: impl Into<String>) -> SResult<T> {
    Err(SemError(msg.into()))
}

/// Instantiate `kernel` with the given meta-parameter bindings.
pub fn instantiate(kernel: &Kernel, bindings: &Bindings) -> SResult<ir::Program> {
    for p in &kernel.meta_params {
        if !bindings.contains_key(p) {
            return err(format!("meta-parameter {p} not bound"));
        }
    }
    let mut env: Env = bindings.clone();
    // Argument extents may reference meta params; resolve args first.
    let mut args = vec![];
    for a in &kernel.args {
        match a {
            ast::KernelArg::Stream { elem_ty, extents, dir, name } => {
                let mut ext = vec![];
                for e in extents {
                    ext.push(
                        eval_int(e, &env)
                            .ok_or_else(|| SemError(format!("arg {name}: non-const extent")))?,
                    );
                }
                args.push(ir::ArgDecl {
                    name: name.clone(),
                    elem_ty: elem_ty.dtype(),
                    extents: ext,
                    dir: *dir,
                });
            }
            ast::KernelArg::Scalar { ty, name } => {
                args.push(ir::ArgDecl {
                    name: name.clone(),
                    elem_ty: ty.dtype(),
                    extents: vec![],
                    dir: ArgDir::ReadOnly,
                });
            }
        }
    }

    let mut inst = Instantiator {
        env: &mut env,
        fields: vec![],
        phases: vec![],
        stream_count: 0,
        pending: ir::Phase::default(),
        pending_used: false,
        arg_names: kernel.args.iter().map(|a| a.name().to_string()).collect(),
        cur_streams: HashMap::new(),
    };
    inst.items(&kernel.items)?;
    inst.flush_pending();

    let prog = ir::Program {
        name: kernel.name.clone(),
        args,
        fields: inst.fields,
        phases: inst.phases,
    };
    check_program(&prog)?;
    Ok(prog)
}

struct Instantiator<'e> {
    env: &'e mut Env,
    fields: Vec<ir::Field>,
    phases: Vec<ir::Phase>,
    stream_count: usize,
    /// Implicit phase accumulating top-level dataflow/compute blocks.
    pending: ir::Phase,
    pending_used: bool,
    arg_names: HashSet<String>,
    /// Stream name table of the phase currently being built.
    cur_streams: HashMap<String, usize>,
}

impl<'e> Instantiator<'e> {
    fn flush_pending(&mut self) {
        if self.pending_used {
            let p = std::mem::take(&mut self.pending);
            self.phases.push(p);
            self.pending_used = false;
            self.cur_streams.clear();
        }
    }

    fn items(&mut self, items: &[Item]) -> SResult<()> {
        for item in items {
            match item {
                Item::Place { header, decls } => {
                    let subgrid = self.subgrid(&header.subgrid)?;
                    // Top-level place → kernel-lifetime fields; phase-local
                    // place is handled inside Item::Phase.
                    let phase_tag = None;
                    self.place(decls, &subgrid, phase_tag)?;
                }
                Item::Dataflow { header, decls } => {
                    let subgrid = self.subgrid(&header.subgrid)?;
                    self.dataflow(decls, &subgrid)?;
                    self.pending_used = true;
                }
                Item::Compute { header, body } => {
                    let subgrid = self.subgrid(&header.subgrid)?;
                    let cb = self.compute(header, body, &subgrid)?;
                    self.pending.computes.push(cb);
                    self.pending_used = true;
                }
                Item::Phase { items, .. } => {
                    self.flush_pending();
                    let phase_idx = self.phases.len();
                    for inner in items {
                        match inner {
                            Item::Place { header, decls } => {
                                let subgrid = self.subgrid(&header.subgrid)?;
                                self.place(decls, &subgrid, Some(phase_idx))?;
                            }
                            Item::Dataflow { header, decls } => {
                                let subgrid = self.subgrid(&header.subgrid)?;
                                self.dataflow(decls, &subgrid)?;
                                self.pending_used = true;
                            }
                            Item::Compute { header, body } => {
                                let subgrid = self.subgrid(&header.subgrid)?;
                                let cb = self.compute(header, body, &subgrid)?;
                                self.pending.computes.push(cb);
                                self.pending_used = true;
                            }
                            Item::Phase { .. } | Item::MetaFor { .. } => {
                                return err("nested phases / meta-for inside phase not supported")
                            }
                        }
                    }
                    self.pending_used = true; // even an empty phase counts
                    self.flush_pending();
                }
                Item::MetaFor { var, range, body, .. } => {
                    self.flush_pending();
                    let (start, stop, step) = self.const_range(range)?;
                    let mut v = start;
                    while v < stop {
                        let shadow = self.env.insert(var.1.clone(), v);
                        self.items(body)?;
                        self.flush_pending();
                        match shadow {
                            Some(old) => {
                                self.env.insert(var.1.clone(), old);
                            }
                            None => {
                                self.env.remove(&var.1);
                            }
                        }
                        v += step;
                    }
                }
            }
        }
        Ok(())
    }

    fn const_range(&self, r: &RangeExpr) -> SResult<(i64, i64, i64)> {
        let start = eval_int(&r.start, self.env)
            .ok_or_else(|| SemError("non-const range start".into()))?;
        let stop = match &r.stop {
            Some(e) => {
                eval_int(e, self.env).ok_or_else(|| SemError("non-const range stop".into()))?
            }
            None => start + 1,
        };
        let step = match &r.step {
            Some(e) => {
                eval_int(e, self.env).ok_or_else(|| SemError("non-const range step".into()))?
            }
            None => 1,
        };
        if step < 1 {
            return err(format!("range step must be >= 1, got {step}"));
        }
        Ok((start, stop, step))
    }

    fn subgrid(&self, ranges: &[RangeExpr]) -> SResult<Subgrid> {
        if ranges.len() != 2 {
            return err(format!("subgrids must be 2-D, got {} dims", ranges.len()));
        }
        let (s0, e0, t0) = self.const_range(&ranges[0])?;
        let (s1, e1, t1) = self.const_range(&ranges[1])?;
        if s0 < 0 || s1 < 0 {
            return err("subgrid coordinates must be non-negative");
        }
        Ok(Subgrid::new(Range1::new(s0, e0, t0), Range1::new(s1, e1, t1)))
    }

    fn place(
        &mut self,
        decls: &[ast::PlaceDecl],
        subgrid: &Subgrid,
        phase: Option<usize>,
    ) -> SResult<()> {
        for d in decls {
            if self.fields.iter().any(|f| f.name == d.name && f.phase == phase) {
                return err(format!("duplicate field {}", d.name));
            }
            let mut shape = vec![];
            for dim in &d.dims {
                let v = eval_int(dim, self.env)
                    .ok_or_else(|| SemError(format!("field {}: non-const dim", d.name)))?;
                if v <= 0 {
                    return err(format!("field {}: dimension {v} must be positive", d.name));
                }
                shape.push(v);
            }
            self.fields.push(ir::Field {
                name: d.name.clone(),
                ty: d.ty.dtype(),
                shape,
                subgrid: subgrid.clone(),
                phase,
            });
        }
        Ok(())
    }

    fn dataflow(&mut self, decls: &[ast::StreamDecl], subgrid: &Subgrid) -> SResult<()> {
        for d in decls {
            let dx = self.offset(&d.dx, &d.name)?;
            let dy = self.offset(&d.dy, &d.name)?;
            if matches!(dx, ir::Offset::Range(..)) && matches!(dy, ir::Offset::Range(..)) {
                return err(format!(
                    "stream {}: multicast is only supported in a single cardinal direction",
                    d.name
                ));
            }
            let id = self.stream_count;
            self.stream_count += 1;
            self.cur_streams.insert(d.name.clone(), id);
            self.pending.streams.push(ir::Stream {
                id,
                name: d.name.clone(),
                elem_ty: d.elem_ty.dtype(),
                subgrid: subgrid.clone(),
                dx,
                dy,
            });
        }
        Ok(())
    }

    fn offset(&self, o: &StreamOffset, stream: &str) -> SResult<ir::Offset> {
        match o {
            StreamOffset::Scalar(e) => Ok(ir::Offset::Scalar(
                eval_int(e, self.env)
                    .ok_or_else(|| SemError(format!("stream {stream}: non-const offset")))?,
            )),
            StreamOffset::Range(a, b) => {
                let lo = eval_int(a, self.env)
                    .ok_or_else(|| SemError(format!("stream {stream}: non-const offset")))?;
                let hi = eval_int(b, self.env)
                    .ok_or_else(|| SemError(format!("stream {stream}: non-const offset")))?;
                if lo >= hi {
                    return err(format!("stream {stream}: empty multicast range [{lo}:{hi}]"));
                }
                Ok(ir::Offset::Range(lo, hi))
            }
        }
    }

    fn compute(
        &mut self,
        header: &ast::BlockHeader,
        body: &[ast::Stmt],
        subgrid: &Subgrid,
    ) -> SResult<ir::ComputeBlock> {
        if header.vars.len() != 2 {
            return err("compute blocks need exactly two coordinate variables");
        }
        let coord_vars = (header.vars[0].1.clone(), header.vars[1].1.clone());
        let mut completions: HashSet<String> = HashSet::new();
        let stmts = self.stmts(body, &coord_vars, &mut completions)?;
        Ok(ir::ComputeBlock { subgrid: subgrid.clone(), coord_vars, stmts })
    }

    fn stmts(
        &mut self,
        body: &[ast::Stmt],
        coords: &(String, String),
        completions: &mut HashSet<String>,
    ) -> SResult<Vec<ir::Stmt>> {
        let mut out = vec![];
        for s in body {
            out.push(self.stmt(s, coords, completions, None, false)?);
        }
        Ok(out)
    }

    fn stmt(
        &mut self,
        s: &ast::Stmt,
        coords: &(String, String),
        completions: &mut HashSet<String>,
        completion: Option<String>,
        awaited: bool,
    ) -> SResult<ir::Stmt> {
        Ok(match s {
            ast::Stmt::AwaitStmt { op, .. } => {
                return self.stmt(op, coords, completions, completion, true)
            }
            ast::Stmt::CompletionDecl { name, op, .. } => {
                if !completions.insert(name.clone()) {
                    return err(format!("duplicate completion {name}"));
                }
                return self.stmt(op, coords, completions, Some(name.clone()), awaited);
            }
            ast::Stmt::AwaitName { name, .. } => {
                if !completions.contains(name) {
                    return err(format!("await on undeclared completion {name}"));
                }
                ir::Stmt::Await { completion: name.clone() }
            }
            ast::Stmt::AwaitAll { .. } => ir::Stmt::AwaitAll,
            ast::Stmt::Send { data, stream, .. } => {
                let data = fold(data, self.env);
                let sref = self.stream_ref(stream)?;
                self.check_arg_dir(&sref, ArgDir::WriteOnly, "send")?;
                ir::Stmt::Send { data, stream: sref, completion, awaited }
            }
            ast::Stmt::Receive { dst, stream, .. } => {
                let dst = fold(dst, self.env);
                let sref = self.stream_ref(stream)?;
                self.check_arg_dir(&sref, ArgDir::ReadOnly, "receive")?;
                ir::Stmt::Recv { dst, stream: sref, completion, awaited }
            }
            ast::Stmt::ForeachRecv { index, elem, range, stream, body, .. } => {
                let sref = self.stream_ref(stream)?;
                self.check_arg_dir(&sref, ArgDir::ReadOnly, "foreach receive")?;
                let len = match range {
                    Some(r) => {
                        let (st, sp, step) = (
                            fold(&r.start, self.env),
                            r.stop.as_ref().map(|e| fold(e, self.env)),
                            r.step.as_ref().map(|e| fold(e, self.env)),
                        );
                        if st != Expr::Int(0)
                            || step.is_some() && step != Some(Expr::Int(1))
                        {
                            return err("foreach receive ranges must be [0:N] with step 1");
                        }
                        Some(sp.ok_or_else(|| SemError("foreach needs a range stop".into()))?)
                    }
                    None => None,
                };
                let inner = self.stmts(body, coords, completions)?;
                ir::Stmt::ForeachRecv {
                    index: index.as_ref().map(|(_, n)| n.clone()),
                    elem: elem.1.clone(),
                    len,
                    stream: sref,
                    body: inner,
                    completion,
                    awaited,
                }
            }
            ast::Stmt::Map { vars, ranges, body, .. } => {
                if vars.len() != ranges.len() {
                    return err("map: vars/ranges arity mismatch");
                }
                let rs: Vec<(Expr, Expr, Expr)> = ranges
                    .iter()
                    .map(|r| {
                        (
                            fold(&r.start, self.env),
                            r.stop.as_ref().map(|e| fold(e, self.env)).unwrap_or(Expr::Int(1)),
                            r.step.as_ref().map(|e| fold(e, self.env)).unwrap_or(Expr::Int(1)),
                        )
                    })
                    .collect();
                let inner = self.stmts(body, coords, completions)?;
                ir::Stmt::Map {
                    vars: vars.iter().map(|(_, n)| n.clone()).collect(),
                    ranges: rs,
                    body: inner,
                    completion,
                    awaited,
                }
            }
            ast::Stmt::For { var, range, body, .. } => {
                let r = (
                    fold(&range.start, self.env),
                    range.stop.as_ref().map(|e| fold(e, self.env)).unwrap_or(Expr::Int(1)),
                    range.step.as_ref().map(|e| fold(e, self.env)).unwrap_or(Expr::Int(1)),
                );
                let inner = self.stmts(body, coords, completions)?;
                ir::Stmt::For { var: var.1.clone(), range: r, body: inner }
            }
            ast::Stmt::Async { body, .. } => {
                let inner = self.stmts(body, coords, completions)?;
                ir::Stmt::Async { body: inner, completion, awaited }
            }
            ast::Stmt::Assign { lhs, rhs, .. } => ir::Stmt::Assign {
                lhs: fold(lhs, self.env),
                rhs: fold(rhs, self.env),
            },
            ast::Stmt::Let { ty, name, init, .. } => ir::Stmt::Let {
                ty: ty.dtype(),
                name: name.clone(),
                init: fold(init, self.env),
            },
            ast::Stmt::If { cond, then_body, else_body, .. } => {
                let c = fold(cond, self.env);
                // Const conditions resolve at compile time.
                if let Expr::Int(v) = c {
                    let taken = if v != 0 { then_body } else { else_body };
                    let inner = self.stmts(taken, coords, completions)?;
                    return Ok(ir::Stmt::Async { body: inner, completion: None, awaited: true });
                }
                ir::Stmt::If {
                    cond: c,
                    then_body: self.stmts(then_body, coords, completions)?,
                    else_body: self.stmts(else_body, coords, completions)?,
                }
            }
        })
    }

    /// Resolve a (folded) stream expression to a StreamRef.
    fn stream_ref(&self, e: &Expr) -> SResult<ir::StreamRef> {
        let folded = fold(e, self.env);
        match &folded {
            Expr::Ident(name) => {
                if let Some(id) = self.cur_streams.get(name) {
                    Ok(ir::StreamRef::Local(*id))
                } else if self.arg_names.contains(name) {
                    Ok(ir::StreamRef::Arg { name: name.clone(), index: vec![] })
                } else {
                    err(format!("unknown stream {name}"))
                }
            }
            Expr::Index(base, idx) => match base.as_ref() {
                Expr::Ident(name) if self.arg_names.contains(name) => {
                    Ok(ir::StreamRef::Arg { name: name.clone(), index: idx.clone() })
                }
                _ => err(format!("cannot index non-argument stream {folded:?}")),
            },
            Expr::Cond { .. } => err(
                "stream selection condition must be compile-time constant \
                 (split the compute block by subgrid instead)",
            ),
            other => err(format!("invalid stream expression {other:?}")),
        }
    }

    fn check_arg_dir(&self, sref: &ir::StreamRef, want: ArgDir, what: &str) -> SResult<()> {
        // Direction check only applies to kernel-arg ports; local stream
        // direction is positional (send → +offset, receive → −offset).
        let _ = (sref, want, what);
        Ok(())
    }
}

/// Whole-program checks after instantiation.
fn check_program(prog: &ir::Program) -> SResult<()> {
    // Stream send/receive usage must reference streams of the same phase.
    for (pi, phase) in prog.phases.iter().enumerate() {
        let ids: HashSet<usize> = phase.streams.iter().map(|s| s.id).collect();
        let check_stmts = |stmts: &[ir::Stmt]| -> SResult<()> {
            fn walk(s: &ir::Stmt, ids: &HashSet<usize>, pi: usize) -> SResult<()> {
                let check_ref = |r: &ir::StreamRef| -> SResult<()> {
                    if let ir::StreamRef::Local(id) = r {
                        if !ids.contains(id) {
                            return err(format!(
                                "phase {pi}: stream id {id} not declared in this phase"
                            ));
                        }
                    }
                    Ok(())
                };
                match s {
                    ir::Stmt::Send { stream, .. } | ir::Stmt::Recv { dst: _, stream, .. } => {
                        check_ref(stream)
                    }
                    ir::Stmt::ForeachRecv { stream, body, .. } => {
                        check_ref(stream)?;
                        for st in body {
                            walk(st, ids, pi)?;
                        }
                        Ok(())
                    }
                    ir::Stmt::Map { body, .. }
                    | ir::Stmt::For { body, .. }
                    | ir::Stmt::Async { body, .. } => {
                        for st in body {
                            walk(st, ids, pi)?;
                        }
                        Ok(())
                    }
                    ir::Stmt::If { then_body, else_body, .. } => {
                        for st in then_body.iter().chain(else_body) {
                            walk(st, ids, pi)?;
                        }
                        Ok(())
                    }
                    _ => Ok(()),
                }
            }
            for st in stmts {
                walk(st, &ids, pi)?;
            }
            Ok(())
        };
        for cb in &phase.computes {
            check_stmts(&cb.stmts)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::core::{Offset, Stmt, StreamRef};
    use crate::spada::parse_kernel;

    fn bind(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    const CHAIN: &str = r#"
kernel @chain_reduce<K, N>(stream<f32>[N] readonly a_in, stream<f32>[1] writeonly out) {
  place i16 i, i16 j in [0:N, 0] { f32[K] a }
  phase {
    compute i32 i, i32 j in [0:N, 0] { await receive(a, a_in[i]) }
  }
  phase {
    dataflow i32 i, i32 j in [0:N, 0] {
      stream<f32> red = relative_stream(-1, 0)
      stream<f32> blue = relative_stream(-1, 0)
    }
    compute i32 i, i32 j in [N-1, 0] {
      await send(a, red if (N-1) % 2 == 0 else blue)
    }
    compute i32 i, i32 j in [1:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(red) {
        a[k] = a[k] + x
        await send(a[k], blue)
      }
    }
    compute i32 i, i32 j in [2:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) {
        a[k] = a[k] + x
        await send(a[k], red)
      }
    }
    compute i32 i, i32 j in [0, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) { a[k] = a[k] + x }
      await send(a, out[0])
    }
  }
}
"#;

    #[test]
    fn chain_reduce_instantiates() {
        let k = parse_kernel(CHAIN).unwrap();
        let prog = instantiate(&k, &bind(&[("K", 64), ("N", 8)])).unwrap();
        assert_eq!(prog.phases.len(), 2);
        assert_eq!(prog.fields.len(), 1);
        assert_eq!(prog.fields[0].shape, vec![64]);
        assert_eq!(prog.fields[0].subgrid.len(), 8);
        let p2 = &prog.phases[1];
        assert_eq!(p2.streams.len(), 2);
        assert_eq!(p2.computes.len(), 4);
        // East corner with N=8: (N-1)%2==1 → blue (stream id 1).
        match &p2.computes[0].stmts[0] {
            Stmt::Send { stream: StreamRef::Local(id), awaited, .. } => {
                assert_eq!(*id, 1);
                assert!(awaited);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(prog.extent(), (8, 1));
    }

    #[test]
    fn meta_for_unrolls_phases() {
        let src = "kernel @t<L>() { for i32 l in [0:L] { phase {
            compute i32 i, i32 j in [0:pow2(l), 0] { awaitall }
        } } }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("L", 3)])).unwrap();
        assert_eq!(prog.phases.len(), 3);
        assert_eq!(prog.phases[2].computes[0].subgrid.len(), 4); // 2^2
    }

    #[test]
    fn missing_binding_errors() {
        let k = parse_kernel("kernel @t<K>() { }").unwrap();
        assert!(instantiate(&k, &bind(&[])).is_err());
    }

    #[test]
    fn unknown_stream_errors() {
        let src = "kernel @t() { compute i32 i, i32 j in [0,0] { send(a, nosuch) } }";
        let k = parse_kernel(src).unwrap();
        assert!(instantiate(&k, &bind(&[])).is_err());
    }

    #[test]
    fn nonconst_stream_select_errors() {
        let src = "kernel @t<N>() {
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> red = relative_stream(-1, 0)
                stream<f32> blue = relative_stream(-1, 0)
            }
            compute i32 i, i32 j in [0:N, 0] { send(a, red if i % 2 == 0 else blue) }
        }";
        let k = parse_kernel(src).unwrap();
        let e = instantiate(&k, &bind(&[("N", 4)])).unwrap_err();
        assert!(e.0.contains("compile-time"));
    }

    #[test]
    fn multicast_stream() {
        let src = "kernel @b<N>() {
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> bc = relative_stream([1:N], 0)
            }
            compute i32 i, i32 j in [0, 0] { awaitall }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 8)])).unwrap();
        assert_eq!(prog.phases[0].streams[0].dx, Offset::Range(1, 8));
        assert_eq!(prog.phases[0].streams[0].dy, Offset::Scalar(0));
    }

    #[test]
    fn duplicate_completion_errors() {
        let src = "kernel @t() { compute i32 i, i32 j in [0,0] {
            completion c = async { }
            completion c = async { }
        } }";
        let k = parse_kernel(src).unwrap();
        assert!(instantiate(&k, &bind(&[])).is_err());
    }

    #[test]
    fn const_if_resolves() {
        let src = "kernel @t<N>() { compute i32 i, i32 j in [0,0] {
            if N > 4 { x = 1 } else { x = 2 }
        } }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 8)])).unwrap();
        match &prog.phases[0].computes[0].stmts[0] {
            Stmt::Async { body, .. } => match &body[0] {
                Stmt::Assign { rhs, .. } => assert_eq!(*rhs, Expr::Int(1)),
                other => panic!("{other:?}"),
            },
            other => panic!("{other:?}"),
        }
    }
}

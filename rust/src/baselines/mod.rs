//! Analytic baselines for the paper's comparisons.
//!
//! - [`luczynski`]: the handwritten near-optimal WSE-2 reduce kernels of
//!   Luczynski et al. (HPDC'24) — the Fig. 4/5 comparison target. We
//!   model their published cost structure (latency–bandwidth tradeoffs
//!   of chain / tree / two-phase) in cycles on the same clock.
//! - [`a100`]: NVIDIA A100 40 GB roofline baselines (the paper's GPU
//!   comparison points are themselves bandwidth-bound: "the A100 kernels
//!   are highly optimized and hit the DRAM bandwidth").
//! - [`sdk_gemv`]: the Cerebras SDK `gemv-collectives_2d` 1-D GEMV
//!   benchmark model, including its OOM behaviour (it does not partition
//!   x and y; §VI-D).

pub mod luczynski {
    //! Cost models in cycles for P PEs reducing K 32-bit words.

    /// Per-level fixed overhead (task wakeup + DSD issue + hop setup).
    pub const LEVEL_OVERHEAD: f64 = 30.0;

    /// 1-D pipelined chain across `p` PEs: one wavelet/cycle once the
    /// pipeline fills → `K + P` shape.
    pub fn chain_1d(p: u64, k: u64) -> f64 {
        k as f64 + p as f64 + LEVEL_OVERHEAD
    }

    /// 2-D binary-tree reduce on a `px × py` grid: log2 levels, each
    /// moving the full vector.
    pub fn tree_2d(px: u64, py: u64, k: u64) -> f64 {
        let levels = (px.max(2).ilog2() + py.max(2).ilog2()) as f64;
        levels * (k as f64 + LEVEL_OVERHEAD)
    }

    /// 2-D two-phase (rows then root column), bandwidth-optimal for
    /// large vectors: the pipelines of both phases overlap except for
    /// the fill terms.
    pub fn two_phase_2d(px: u64, py: u64, k: u64) -> f64 {
        k as f64 + px as f64 + py as f64 + 2.0 * LEVEL_OVERHEAD
    }

    /// 1-D multicast broadcast: single circuit, one wavelet/cycle.
    pub fn broadcast_1d(p: u64, k: u64) -> f64 {
        k as f64 + p as f64 + LEVEL_OVERHEAD
    }

    /// The best handwritten reduce at a given size (their adaptive
    /// choice).
    pub fn best_reduce_2d(px: u64, py: u64, k: u64) -> f64 {
        tree_2d(px, py, k).min(two_phase_2d(px, py, k))
    }
}

pub mod a100 {
    //! A100 40 GB roofline parameters (datasheet + paper §VI-E/F).

    /// Effective DRAM bandwidth, bytes/s.
    pub const DRAM_BW: f64 = 1.555e12;
    /// FP32 peak, flop/s.
    pub const PEAK_F32: f64 = 19.5e12;
    /// Board power, watts.
    pub const POWER_W: f64 = 250.0;

    /// Roofline-limited runtime (s) for `flops` total flops moving
    /// `bytes` DRAM bytes.
    pub fn runtime_s(flops: f64, bytes: f64) -> f64 {
        (bytes / DRAM_BW).max(flops / PEAK_F32)
    }

    /// Achieved flop/s for a kernel with the given per-point costs.
    pub fn floprate(flops: f64, bytes: f64) -> f64 {
        flops / runtime_s(flops, bytes)
    }

    /// Stencil baseline: GT4Py GPU backends stream in+out once (plus
    /// halo re-reads folded into a small factor).
    pub fn stencil_floprate(flops_per_point: f64, fields_rw: f64, points: f64) -> f64 {
        let flops = flops_per_point * points;
        let bytes = 4.0 * fields_rw * points;
        floprate(flops, bytes)
    }

    /// CUBLAS GEMV: reads A once (2 flops / 4 bytes per element).
    pub fn gemv_floprate(m: f64, n: f64) -> f64 {
        floprate(2.0 * m * n, 4.0 * m * n)
    }

    /// GEMV runtime in microseconds.
    pub fn gemv_runtime_us(m: f64, n: f64) -> f64 {
        runtime_s(2.0 * m * n, 4.0 * m * n) * 1e6
    }
}

pub mod wse2 {
    //! WSE-2 roofline + power parameters (paper §VI-E/F, Jacquelin et al.).

    /// Effective SRAM bandwidth (STREAM-measured), bytes/s.
    pub const SRAM_BW: f64 = 8.8e15;
    /// Off/on-ramp (fabric ↔ PE) aggregate bandwidth, bytes/s.
    pub const RAMP_BW: f64 = 3.3e15;
    /// FP32 peak: one FMA per PE per cycle across the usable fabric.
    pub fn peak_f32(pes: f64, freq_hz: f64) -> f64 {
        2.0 * pes * freq_hz
    }
    /// Reported power envelope, watts.
    pub const POWER_LOW_W: f64 = 16_500.0;
    pub const POWER_HIGH_W: f64 = 23_000.0;

    /// Roofline bound given arithmetic intensities against local memory
    /// and ramp traffic (flop/byte).
    pub fn bound_floprate(pes: f64, freq_hz: f64, int_mem: f64, int_ramp: f64) -> f64 {
        let peak = peak_f32(pes, freq_hz);
        peak.min(int_mem * SRAM_BW).min(int_ramp * RAMP_BW)
    }
}

pub mod sdk_gemv {
    //! Cerebras SDK `gemv-collectives_2d` 1-D partitioned GEMV model.
    //!
    //! The SDK benchmark distributes A's rows but replicates x and y on
    //! every PE, so per-PE memory is 4·(N + M + rows·N) bytes — OOM for
    //! matrices larger than 2048² (§VI-D). Cycle constants are
    //! calibrated to the paper's measurement: 15,410 cycles at 2048².

    /// PEs the SDK benchmark uses (one fabric row).
    pub const P: u64 = 750;

    /// Per-PE memory footprint in bytes.
    pub fn mem_bytes(m: u64, n: u64) -> u64 {
        let rows = m.div_ceil(P);
        4 * (n + m + rows * n)
    }

    /// Does the size fit in 48 KB PEs?
    pub fn fits(m: u64, n: u64) -> bool {
        mem_bytes(m, n) <= 48 * 1024
    }

    /// Modeled cycles: broadcast x + serial row-block MACs + y gather,
    /// with the SDK's collective overheads (calibration factor fitted to
    /// the published 15,410-cycle measurement at 2048²).
    pub fn cycles(m: u64, n: u64) -> Option<u64> {
        if !fits(m, n) {
            return None;
        }
        let rows = m.div_ceil(P);
        let raw = n + rows * n + m;
        // 2048²: raw = 2048 + 3·2048 + 2048 = 10,240 → ×1.505 ≈ 15,410.
        Some((raw as f64 * 1.505) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_vs_tree_crossover() {
        // Small vectors → tree wins; large vectors → two-phase wins.
        let (px, py) = (512, 512);
        assert!(luczynski::tree_2d(px, py, 8) < luczynski::two_phase_2d(px, py, 8));
        assert!(luczynski::two_phase_2d(px, py, 16384) < luczynski::tree_2d(px, py, 16384));
    }

    #[test]
    fn a100_stencil_is_bw_bound() {
        // Laplacian: 5 flops/point, 2 fields → ~0.97 Tflop/s ≪ 19.5 peak.
        let rate = a100::stencil_floprate(5.0, 2.0, 1e9);
        assert!(rate < 2e12, "{rate}");
        assert!(rate > 5e11, "{rate}");
    }

    #[test]
    fn sdk_gemv_oom_beyond_2048() {
        assert!(sdk_gemv::fits(2048, 2048));
        assert!(!sdk_gemv::fits(4096, 4096));
        let c = sdk_gemv::cycles(2048, 2048).unwrap();
        assert!((15_000..16_000).contains(&c), "{c}");
    }

    #[test]
    fn wse2_roofline_orders() {
        let peak = wse2::peak_f32(745_500.0, 0.85e9);
        assert!(peak > 1e15); // ~1.27 Pflop/s fp32
        // Ramp-bound kernels sit below the ramp line.
        let b = wse2::bound_floprate(745_500.0, 0.85e9, 10.0, 0.1);
        assert!((b - 0.33e15).abs() / 0.33e15 < 0.01);
    }
}

//! Checkerboard decomposition (paper §V-B, Fig. 2).
//!
//! A single-hop stream whose senders and receivers overlap (e.g. the
//! pipeline pattern of Listing 1, where every PE both sends west and
//! receives from the east) cannot be realized with one color: the same
//! router would need `rx = {RAMP, EAST}, tx = {WEST, RAMP}`, which is
//! ambiguous for a circuit-switched fabric. The checkerboard pass splits
//! each conflicting compute block by PE-coordinate parity along the
//! stream's active dimension and duplicates the stream into `_even` /
//! `_odd` variants: even-parity senders use one color, odd-parity senders
//! the other, so every router configuration is unambiguous *by
//! construction*.

use super::PassError;
use crate::ir::core as ir;
use crate::util::Subgrid;
use std::collections::{HashMap, HashSet};

/// Result of the pass.
pub struct CheckerboardResult {
    pub program: ir::Program,
    pub streams_split: usize,
    pub blocks_split: usize,
}

/// Which streams a block touches, by role.
#[derive(Default, Debug)]
struct Usage {
    sends: HashSet<usize>,
    recvs: HashSet<usize>,
}

fn collect_usage(stmts: &[ir::Stmt], u: &mut Usage) {
    for s in stmts {
        match s {
            ir::Stmt::Send { stream: ir::StreamRef::Local(id), .. } => {
                u.sends.insert(*id);
            }
            ir::Stmt::Recv { stream: ir::StreamRef::Local(id), .. } => {
                u.recvs.insert(*id);
            }
            ir::Stmt::ForeachRecv { stream, body, .. } => {
                if let ir::StreamRef::Local(id) = stream {
                    u.recvs.insert(*id);
                }
                collect_usage(body, u);
            }
            ir::Stmt::Map { body, .. }
            | ir::Stmt::For { body, .. }
            | ir::Stmt::Async { body, .. } => collect_usage(body, u),
            ir::Stmt::If { then_body, else_body, .. } => {
                collect_usage(then_body, u);
                collect_usage(else_body, u);
            }
            _ => {}
        }
    }
}

/// Shift a subgrid by (dx, dy).
fn shift(g: &Subgrid, dx: i64, dy: i64) -> Subgrid {
    let mut out = g.clone();
    out.dims[0].start += dx;
    out.dims[0].stop += dx;
    out.dims[1].start += dy;
    out.dims[1].stop += dy;
    out
}

/// The active dimension of a stream (0 = x, 1 = y); errors if both are
/// active (the paper's checkerboard restricts to single-hop streams).
fn active_dim(s: &ir::Stream) -> Result<Option<usize>, PassError> {
    match (s.dx.is_active(), s.dy.is_active()) {
        (false, false) => Ok(None),
        (true, false) => Ok(Some(0)),
        (false, true) => Ok(Some(1)),
        (true, true) => Err(PassError(format!(
            "stream {}: diagonal offsets need multi-hop routing, which the \
             checkerboard pass does not support (allocate channels manually)",
            s.name
        ))),
    }
}

/// Run checkerboard decomposition on an instantiated program.
pub fn checkerboard(prog: &ir::Program) -> Result<CheckerboardResult, PassError> {
    let mut out = prog.clone();
    let mut streams_split = 0;
    let mut blocks_split = 0;
    // Fresh stream ids start after the current maximum.
    let mut next_id = prog
        .phases
        .iter()
        .flat_map(|p| p.streams.iter())
        .map(|s| s.id + 1)
        .max()
        .unwrap_or(0);

    for phase in &mut out.phases {
        // 1. Per-block usage.
        let usages: Vec<Usage> = phase
            .computes
            .iter()
            .map(|b| {
                let mut u = Usage::default();
                collect_usage(&b.stmts, &mut u);
                u
            })
            .collect();

        // 2. Decide which streams conflict (sender set ∩ receiver set ≠ ∅).
        let mut split_streams: HashMap<usize, usize> = HashMap::new(); // id → dim
        for s in &phase.streams {
            let Some(dim) = active_dim(s)? else { continue };
            let (dx, dy) = match (s.dx.scalar(), s.dy.scalar()) {
                (Some(dx), Some(dy)) => (dx, dy),
                _ => continue, // multicast: single sender region, no pipeline conflict
            };
            let senders: Vec<&Subgrid> = phase
                .computes
                .iter()
                .zip(&usages)
                .filter(|(_, u)| u.sends.contains(&s.id))
                .map(|(b, _)| &b.subgrid)
                .collect();
            let receivers: Vec<Subgrid> = phase
                .computes
                .iter()
                .zip(&usages)
                .filter(|(_, u)| u.recvs.contains(&s.id))
                .map(|(b, _)| b.subgrid.clone())
                .collect();
            // A sender's router and a receiver's router coincide when a
            // PE both sends and receives on s — equivalently when the
            // sender set intersects the receiver set.
            let mut conflict = false;
            for a in &senders {
                for b in &receivers {
                    if !a.intersect(b).is_empty() {
                        conflict = true;
                    }
                    // Also conflicting: two distinct senders routing
                    // through each other (sender at p, sender at p+off).
                    if !a.intersect(&shift(b, dx, dy)).is_empty() && !(dx == 0 && dy == 0) {
                        // receiver routers sit at sender+off; fine.
                    }
                }
            }
            if conflict {
                split_streams.insert(s.id, dim);
            }
        }

        if split_streams.is_empty() {
            continue;
        }

        // 3. Create variants for each split stream.
        //    variant_map[id] = (even_id, odd_id, dim, |off| parity flip)
        let mut variant_map: HashMap<usize, (usize, usize, usize, bool)> = HashMap::new();
        let mut new_streams = vec![];
        for s in &phase.streams {
            match split_streams.get(&s.id) {
                None => new_streams.push(s.clone()),
                Some(&dim) => {
                    let off = if dim == 0 {
                        s.dx.scalar().unwrap_or(0)
                    } else {
                        s.dy.scalar().unwrap_or(0)
                    };
                    let flip = off.rem_euclid(2) == 1;
                    let (ev, od) = s.subgrid.split_parity(dim);
                    let even_id = next_id;
                    let odd_id = next_id + 1;
                    next_id += 2;
                    variant_map.insert(s.id, (even_id, odd_id, dim, flip));
                    if !ev.is_empty() {
                        new_streams.push(ir::Stream {
                            id: even_id,
                            name: format!("{}_even", s.name),
                            elem_ty: s.elem_ty,
                            subgrid: ev,
                            dx: s.dx,
                            dy: s.dy,
                        });
                    }
                    if !od.is_empty() {
                        new_streams.push(ir::Stream {
                            id: odd_id,
                            name: format!("{}_odd", s.name),
                            elem_ty: s.elem_ty,
                            subgrid: od,
                            dx: s.dx,
                            dy: s.dy,
                        });
                    }
                    streams_split += 1;
                }
            }
        }
        phase.streams = new_streams;

        // 4. Split blocks that use split streams, and rewrite refs.
        let mut new_blocks = vec![];
        for (block, usage) in phase.computes.iter().zip(&usages) {
            // Dimensions along which this block must be parity-split.
            let mut dims: Vec<usize> = usage
                .sends
                .iter()
                .chain(&usage.recvs)
                .filter_map(|id| split_streams.get(id).copied())
                .collect();
            dims.sort_unstable();
            dims.dedup();
            if dims.is_empty() {
                new_blocks.push(block.clone());
                continue;
            }
            let mut parts: Vec<Subgrid> = vec![block.subgrid.clone()];
            for &d in &dims {
                parts = parts
                    .iter()
                    .flat_map(|g| {
                        let (e, o) = g.split_parity(d);
                        [e, o]
                    })
                    .filter(|g| !g.is_empty())
                    .collect();
            }
            if parts.len() > 1 {
                blocks_split += 1;
            }
            for part in parts {
                // Parities of this part along each split dim.
                let parity = |d: usize| part.dims[d].start.rem_euclid(2); // uniform by construction
                let mut nb = block.clone();
                nb.subgrid = part.clone();
                rewrite_refs(&mut nb.stmts, &variant_map, &parity);
                new_blocks.push(nb);
            }
        }
        phase.computes = new_blocks;
    }

    Ok(CheckerboardResult { program: out, streams_split, blocks_split })
}

/// Rewrite stream references to parity variants inside a split block.
fn rewrite_refs(
    stmts: &mut [ir::Stmt],
    variants: &HashMap<usize, (usize, usize, usize, bool)>,
    parity: &dyn Fn(usize) -> i64,
) {
    let pick = |id: usize, is_send: bool| -> usize {
        match variants.get(&id) {
            None => id,
            Some(&(even_id, odd_id, dim, flip)) => {
                let p = parity(dim);
                // Senders use their own parity's variant; receivers use
                // the *sender's* parity: own parity flipped when |off| is
                // odd.
                let effective = if is_send {
                    p
                } else if flip {
                    1 - p
                } else {
                    p
                };
                if effective == 0 {
                    even_id
                } else {
                    odd_id
                }
            }
        }
    };
    for s in stmts {
        match s {
            ir::Stmt::Send { stream, .. } => {
                if let ir::StreamRef::Local(id) = stream {
                    *id = pick(*id, true);
                }
            }
            ir::Stmt::Recv { stream, .. } => {
                if let ir::StreamRef::Local(id) = stream {
                    *id = pick(*id, false);
                }
            }
            ir::Stmt::ForeachRecv { stream, body, .. } => {
                if let ir::StreamRef::Local(id) = stream {
                    *id = pick(*id, false);
                }
                rewrite_refs(body, variants, parity);
            }
            ir::Stmt::Map { body, .. }
            | ir::Stmt::For { body, .. }
            | ir::Stmt::Async { body, .. } => rewrite_refs(body, variants, parity),
            ir::Stmt::If { then_body, else_body, .. } => {
                rewrite_refs(then_body, variants, parity);
                rewrite_refs(else_body, variants, parity);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sem::{instantiate, Bindings};
    use crate::spada::parse_kernel;

    fn bind(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    /// A pipeline where every PE sends west and receives from the east on
    /// the same stream — the canonical checkerboard trigger.
    #[test]
    fn pipeline_stream_splits() {
        let src = "kernel @p<N, K>() {
            place i16 i, i16 j in [0:N, 0] { f32[K] a }
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> s = relative_stream(-1, 0)
            }
            compute i32 i, i32 j in [1:N, 0] {
                await send(a, s)
            }
            compute i32 i, i32 j in [0:N-1, 0] {
                await receive(a, s)
            }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 8), ("K", 4)])).unwrap();
        let res = checkerboard(&prog).unwrap();
        assert_eq!(res.streams_split, 1);
        let phase = &res.program.phases[0];
        assert_eq!(phase.streams.len(), 2);
        assert!(phase.streams.iter().any(|s| s.name == "s_even"));
        assert!(phase.streams.iter().any(|s| s.name == "s_odd"));
        // Sender blocks split into odd/even parts.
        assert!(phase.computes.len() >= 4);
        // Every sender block's variant matches its parity.
        for b in &phase.computes {
            let mut u = Usage::default();
            collect_usage(&b.stmts, &mut u);
            for id in &u.sends {
                let s = phase.streams.iter().find(|s| s.id == *id).unwrap();
                let p = b.subgrid.dims[0].start.rem_euclid(2);
                if p == 0 {
                    assert!(s.name.ends_with("_even"), "{}", s.name);
                } else {
                    assert!(s.name.ends_with("_odd"), "{}", s.name);
                }
            }
            // Receivers reference the opposite-parity variant (off = -1).
            for id in &u.recvs {
                let s = phase.streams.iter().find(|s| s.id == *id).unwrap();
                let p = b.subgrid.dims[0].start.rem_euclid(2);
                if p == 0 {
                    assert!(s.name.ends_with("_odd"), "{}", s.name);
                } else {
                    assert!(s.name.ends_with("_even"), "{}", s.name);
                }
            }
        }
    }

    /// Disjoint sender/receiver sets (tree-reduce level): no split.
    #[test]
    fn disjoint_no_split() {
        let src = "kernel @t<N, K>() {
            place i16 i, i16 j in [0:N, 0] { f32[K] a }
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> s = relative_stream(-1, 0)
            }
            compute i32 i, i32 j in [1:N:2, 0] { await send(a, s) }
            compute i32 i, i32 j in [0:N:2, 0] { await receive(a, s) }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 8), ("K", 4)])).unwrap();
        let res = checkerboard(&prog).unwrap();
        assert_eq!(res.streams_split, 0);
        assert_eq!(res.program.phases[0].streams.len(), 1);
    }

    /// Diagonal streams are rejected (paper's single-hop restriction).
    #[test]
    fn diagonal_rejected() {
        let src = "kernel @d<N>() {
            place i16 i, i16 j in [0:N, 0:N] { f32 v }
            dataflow i32 i, i32 j in [0:N, 0:N] {
                stream<f32> s = relative_stream(1, 1)
            }
            compute i32 i, i32 j in [0:N, 0:N] {
                await send(v, s)
                await receive(v, s)
            }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 4)])).unwrap();
        assert!(checkerboard(&prog).is_err());
    }

    /// Vertical (y-offset) pipeline splits along dim 1.
    #[test]
    fn vertical_split() {
        let src = "kernel @v<N, K>() {
            place i16 i, i16 j in [0, 0:N] { f32[K] a }
            dataflow i32 i, i32 j in [0, 0:N] {
                stream<f32> s = relative_stream(0, 1)
            }
            compute i32 i, i32 j in [0, 0:N-1] { await send(a, s) }
            compute i32 i, i32 j in [0, 1:N] { await receive(a, s) }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 6), ("K", 2)])).unwrap();
        let res = checkerboard(&prog).unwrap();
        assert_eq!(res.streams_split, 1);
        for s in &res.program.phases[0].streams {
            // Variants partition by y parity.
            let ys: Vec<i64> = s.subgrid.dims[1].iter().collect();
            assert!(ys.iter().all(|y| y % 2 == ys[0] % 2));
        }
    }
}

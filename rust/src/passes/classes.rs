//! PE equivalence classes (canonicalization, paper §V-A).
//!
//! Consolidates rectangles into *PE equivalence classes* mapped to
//! non-overlapping strided regions, ensuring each PE corresponds to a
//! single CSL code file without generating one file per PE. Two PEs are
//! equivalent iff the same compute blocks (across all phases) and the
//! same fields cover them — their generated code is then identical as a
//! function of the PE coordinates.

use crate::ir::core as ir;
use crate::util::{Range1, Subgrid};
use std::collections::{BTreeMap, HashSet};

/// One equivalence class: the blocks/fields covering it and the strided
/// regions it occupies.
#[derive(Clone, Debug)]
pub struct ClassRegion {
    pub name: String,
    /// (phase index, compute-block index) pairs covering this class.
    pub blocks: Vec<(usize, usize)>,
    /// Field indices (into `Program::fields`) allocated on this class.
    pub fields: Vec<usize>,
    /// Disjoint strided rectangles covering exactly this class's PEs.
    pub subgrids: Vec<Subgrid>,
}

/// Compute the PE equivalence classes of a program.
pub fn equivalence_classes(prog: &ir::Program) -> Vec<ClassRegion> {
    // Enumerate covering entities.
    let mut block_list: Vec<(usize, usize, &Subgrid)> = vec![];
    for (pi, phase) in prog.phases.iter().enumerate() {
        for (bi, b) in phase.computes.iter().enumerate() {
            block_list.push((pi, bi, &b.subgrid));
        }
    }
    let field_list: Vec<(usize, &Subgrid)> =
        prog.fields.iter().enumerate().map(|(fi, f)| (fi, &f.subgrid)).collect();

    // Signature per PE over the extent.
    let (w, h) = prog.extent();
    let mut groups: BTreeMap<(Vec<(usize, usize)>, Vec<usize>), Vec<(i64, i64)>> = BTreeMap::new();
    for x in 0..w {
        for y in 0..h {
            let blocks: Vec<(usize, usize)> = block_list
                .iter()
                .filter(|(_, _, g)| g.contains(x, y))
                .map(|(pi, bi, _)| (*pi, *bi))
                .collect();
            let fields: Vec<usize> = field_list
                .iter()
                .filter(|(_, g)| g.contains(x, y))
                .map(|(fi, _)| *fi)
                .collect();
            if blocks.is_empty() && fields.is_empty() {
                continue;
            }
            groups.entry((blocks, fields)).or_default().push((x, y));
        }
    }

    let mut out = vec![];
    for (idx, ((blocks, fields), pes)) in groups.into_iter().enumerate() {
        let subgrids = recover_rects(&pes);
        debug_assert_eq!(
            subgrids.iter().map(|g| g.len()).sum::<i64>(),
            pes.len() as i64,
            "rect recovery must cover exactly the class"
        );
        out.push(ClassRegion { name: format!("pe_class_{idx}"), blocks, fields, subgrids });
    }
    out
}

/// Reassemble a set of PE coordinates into disjoint strided rectangles.
///
/// Per-row greedy arithmetic-run decomposition, then rows with identical
/// run patterns are merged across strided y-progressions.
pub fn recover_rects(pes: &[(i64, i64)]) -> Vec<Subgrid> {
    // Group x coordinates by row.
    let mut rows: BTreeMap<i64, Vec<i64>> = BTreeMap::new();
    for (x, y) in pes {
        rows.entry(*y).or_default().push(*x);
    }
    // Decompose each row into maximal arithmetic runs.
    let mut run_rows: BTreeMap<Range1, Vec<i64>> = BTreeMap::new(); // run → list of y
    for (y, xs) in &mut rows {
        xs.sort_unstable();
        for run in arith_runs(xs) {
            run_rows.entry(run).or_default().push(*y);
        }
    }
    // Merge identical runs over strided y-progressions.
    let mut out = vec![];
    for (run, ys) in &run_rows {
        for yrun in arith_runs(ys) {
            out.push(Subgrid::new(*run, yrun));
        }
    }
    out
}

/// Decompose a sorted slice into maximal arithmetic runs (greedy).
fn arith_runs(v: &[i64]) -> Vec<Range1> {
    let mut out = vec![];
    let mut i = 0;
    while i < v.len() {
        if i + 1 == v.len() {
            out.push(Range1::point(v[i]));
            break;
        }
        let step = v[i + 1] - v[i];
        let mut j = i + 1;
        while j + 1 < v.len() && v[j + 1] - v[j] == step {
            j += 1;
        }
        if j == i + 1 && step != 1 {
            // A two-element run with a large step is often better split so
            // the next element can start its own denser run; but two
            // points always form a valid run, keep it.
        }
        out.push(Range1::new(v[i], v[j] + 1, step.max(1)));
        i = j + 1;
    }
    out
}

/// BTreeMap key support for Range1.
impl Ord for Range1 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.start, self.stop, self.step).cmp(&(other.start, other.stop, other.step))
    }
}

impl PartialOrd for Range1 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Sanity check: classes must be disjoint and cover all used PEs.
pub fn check_partition(classes: &[ClassRegion]) -> Result<(), String> {
    let mut seen: HashSet<(i64, i64)> = HashSet::new();
    for c in classes {
        for g in &c.subgrids {
            for pe in g.iter() {
                if !seen.insert(pe) {
                    return Err(format!("PE {pe:?} in two classes"));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sem::{instantiate, Bindings};
    use crate::spada::parse_kernel;

    fn bind(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn arith_runs_mixed() {
        let runs = arith_runs(&[0, 1, 2, 3, 10, 12, 14, 20]);
        assert_eq!(runs[0], Range1::new(0, 4, 1));
        let all: Vec<i64> = runs.iter().flat_map(|r| r.iter().collect::<Vec<_>>()).collect();
        assert_eq!(all, vec![0, 1, 2, 3, 10, 12, 14, 20]);
    }

    #[test]
    fn recover_dense_rect() {
        let pes: Vec<(i64, i64)> =
            (0..4).flat_map(|x| (0..3).map(move |y| (x, y))).collect();
        let rects = recover_rects(&pes);
        assert_eq!(rects.len(), 1);
        assert_eq!(rects[0], Subgrid::rect(4, 3));
    }

    #[test]
    fn recover_parity_rows() {
        let pes: Vec<(i64, i64)> = (0..8).step_by(2).map(|x| (x, 0)).collect();
        let rects = recover_rects(&pes);
        assert_eq!(rects.len(), 1);
        assert_eq!(rects[0].dims[0], Range1::new(0, 7, 2));
    }

    #[test]
    fn chain_reduce_classes() {
        let src = r#"
kernel @chain<K, N>() {
  place i16 i, i16 j in [0:N, 0] { f32[K] a }
  phase {
    dataflow i32 i, i32 j in [0:N, 0] {
      stream<f32> red = relative_stream(-1, 0)
      stream<f32> blue = relative_stream(-1, 0)
    }
    compute i32 i, i32 j in [N-1, 0] { await send(a, blue) }
    compute i32 i, i32 j in [1:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(red) { a[k] = a[k] + x await send(a[k], blue) }
    }
    compute i32 i, i32 j in [2:N-1:2, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) { a[k] = a[k] + x await send(a[k], red) }
    }
    compute i32 i, i32 j in [0, 0] {
      await foreach i32 k, f32 x in [0:K], receive(blue) { a[k] = a[k] + x }
    }
  }
}
"#;
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("K", 8), ("N", 8)])).unwrap();
        let classes = equivalence_classes(&prog);
        // 4 distinct roles: east corner, odd, even, root.
        assert_eq!(classes.len(), 4);
        check_partition(&classes).unwrap();
        let total: i64 = classes.iter().flat_map(|c| c.subgrids.iter()).map(|g| g.len()).sum();
        assert_eq!(total, 8);
    }

    #[test]
    fn stencil_interior_boundary_classes() {
        // A 2-D region where interior PEs run one block and the full grid
        // another: expect interior/border split into strided regions.
        let src = "kernel @st<N>() {
            place i16 i, i16 j in [0:N, 0:N] { f32 v }
            compute i32 i, i32 j in [0:N, 0:N] { v = 0.0 }
            compute i32 i, i32 j in [1:N-1, 1:N-1] { v = 1.0 }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 6)])).unwrap();
        let classes = equivalence_classes(&prog);
        assert_eq!(classes.len(), 2);
        check_partition(&classes).unwrap();
        let interior = classes
            .iter()
            .find(|c| c.blocks.len() == 2)
            .expect("interior class");
        let n: i64 = interior.subgrids.iter().map(|g| g.len()).sum();
        assert_eq!(n, 16); // 4x4 interior
    }
}

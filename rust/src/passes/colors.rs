//! Global color allocation and route-rule generation (paper §V-B,
//! "Layout and Resource Allocation").
//!
//! After checkerboard decomposition every stream (variant) admits an
//! unambiguous per-router configuration. This pass computes each
//! stream's *router footprint* (the set of PEs whose router needs a
//! configuration for it), builds a conflict graph (footprints that share
//! a router cannot share a color — one router has exactly one route per
//! color), and greedily colors it onto the 24 routable hardware channels.
//! Streams in different phases still conflict: phases are only locally
//! sequential, so two phases may be in flight on neighbouring PEs
//! simultaneously.

use super::PassError;
use crate::ir::core as ir;
use crate::machine::{DirSet, Direction, MachineConfig, RouteRule};
use crate::util::Subgrid;
use std::collections::{HashMap, HashSet};

/// Marker for ambiguous-router-configuration failures; shared with
/// [`crate::analysis::check_source`] so diagnostics classify pass
/// errors without re-deriving the message text.
pub const AMBIGUOUS_ROUTER: &str = "ambiguous router configuration";

/// Allocation result.
#[derive(Debug, Default)]
pub struct ColorAllocation {
    /// stream id → hardware color.
    pub stream_color: HashMap<usize, u8>,
    pub routes: Vec<RouteRule>,
    pub colors_used: Vec<u8>,
}

/// Uncolored route entry for one stream.
#[derive(Debug, Clone)]
struct ProtoRule {
    subgrid: Subgrid,
    rx: DirSet,
    tx: DirSet,
}

fn shift(g: &Subgrid, dx: i64, dy: i64) -> Subgrid {
    let mut out = g.clone();
    out.dims[0].start += dx;
    out.dims[0].stop += dx;
    out.dims[1].start += dy;
    out.dims[1].stop += dy;
    out
}

/// Collect the union of sender subgrids for stream `id` in `phase`.
fn sender_set(phase: &ir::Phase, id: usize) -> Vec<Subgrid> {
    fn sends(stmts: &[ir::Stmt], id: usize) -> bool {
        stmts.iter().any(|s| match s {
            ir::Stmt::Send { stream: ir::StreamRef::Local(sid), .. } => *sid == id,
            ir::Stmt::ForeachRecv { body, .. }
            | ir::Stmt::Map { body, .. }
            | ir::Stmt::For { body, .. }
            | ir::Stmt::Async { body, .. } => sends(body, id),
            ir::Stmt::If { then_body, else_body, .. } => {
                sends(then_body, id) || sends(else_body, id)
            }
            _ => false,
        })
    }
    phase
        .computes
        .iter()
        .filter(|b| sends(&b.stmts, id))
        .map(|b| b.subgrid.intersect(&stream_of(phase, id).subgrid))
        .filter(|g| !g.is_empty())
        .collect()
}

fn stream_of(phase: &ir::Phase, id: usize) -> &ir::Stream {
    phase.streams.iter().find(|s| s.id == id).unwrap()
}

/// Build the proto route rules for one stream given its sender set.
fn build_rules(s: &ir::Stream, senders: &[Subgrid]) -> Result<Vec<ProtoRule>, PassError> {
    let mut rules: Vec<ProtoRule> = vec![];
    let mut push = |subgrid: Subgrid, rx: DirSet, tx: DirSet| {
        if subgrid.is_empty() {
            return;
        }
        // Merge with an existing rule on the same subgrid (identical
        // shape): union rx/tx. Distinct overlapping subgrids are a
        // conflict caught later.
        for r in rules.iter_mut() {
            if r.subgrid == subgrid {
                r.rx.0 |= rx.0;
                r.tx.0 |= tx.0;
                return;
            }
        }
        rules.push(ProtoRule { subgrid, rx, tx });
    };

    let (dim, lo, hi) = match (s.dx, s.dy) {
        (ir::Offset::Scalar(v), ir::Offset::Scalar(0)) if v != 0 => (0usize, v, v + 1),
        (ir::Offset::Scalar(0), ir::Offset::Scalar(v)) if v != 0 => (1usize, v, v + 1),
        (ir::Offset::Range(a, b), ir::Offset::Scalar(0)) => (0usize, a, b),
        (ir::Offset::Scalar(0), ir::Offset::Range(a, b)) => (1usize, a, b),
        (ir::Offset::Scalar(0), ir::Offset::Scalar(0)) => {
            return Err(PassError(format!("stream {}: zero offset (self-loop)", s.name)))
        }
        _ => {
            return Err(PassError(format!(
                "stream {}: diagonal offsets are not routable single-hop",
                s.name
            )))
        }
    };
    if lo < 0 && hi > 1 {
        return Err(PassError(format!(
            "stream {}: multicast range must not cross zero",
            s.name
        )));
    }
    let positive = lo > 0 || (lo == 0 && hi > 0);
    let _sign: i64 = if positive { 1 } else { -1 };
    let dir = match (dim, positive) {
        (0, true) => Direction::East,
        (0, false) => Direction::West,
        (1, true) => Direction::South,
        (1, false) => Direction::North,
        _ => unreachable!(),
    };
    let unit = dir.delta();
    // Hop distances (absolute) that receive the flow.
    let (first_recv, last_recv) = if positive {
        (lo.max(1), hi - 1)
    } else {
        ((-(hi - 1)).max(1), -lo)
    };
    if first_recv > last_recv {
        return Err(PassError(format!("stream {}: empty offset range", s.name)));
    }

    for v in senders {
        // Sender: ramp → dir.
        push(v.clone(), DirSet::single(Direction::Ramp), DirSet::single(dir));
        for k in 1..=last_recv {
            let (dx, dy) = (unit.0 * k, unit.1 * k);
            let g = shift(v, dx, dy);
            let deliver = k >= first_recv;
            let forward = k < last_recv;
            let mut tx = DirSet::empty();
            if deliver {
                tx = tx.with(Direction::Ramp);
            }
            if forward {
                tx = tx.with(dir);
            }
            push(g, DirSet::single(dir.opposite()), tx);
        }
    }
    Ok(rules)
}

/// Allocate colors for all streams of a program.
pub fn allocate_colors(
    prog: &ir::Program,
    cfg: &MachineConfig,
) -> Result<ColorAllocation, PassError> {
    // 1. Gather proto rules per stream.
    let mut per_stream: Vec<(usize, String, Vec<ProtoRule>)> = vec![];
    for phase in &prog.phases {
        for s in &phase.streams {
            let senders = sender_set(phase, s.id);
            if senders.is_empty() {
                continue; // declared but never used
            }
            let rules = build_rules(s, &senders)?;
            // Bounds check.
            for r in &rules {
                let gx = &r.subgrid.dims[0];
                let gy = &r.subgrid.dims[1];
                if gx.start < 0
                    || gy.start < 0
                    || gx.last().unwrap_or(0) >= cfg.width
                    || gy.last().unwrap_or(0) >= cfg.height
                {
                    return Err(PassError(format!(
                        "stream {}: route {:?} leaves the {}x{} fabric",
                        s.name, r.subgrid, cfg.width, cfg.height
                    )));
                }
            }
            // Self-conflict check: a stream's own rules must not place two
            // *different* configurations on one router.
            for i in 0..rules.len() {
                for j in (i + 1)..rules.len() {
                    if !rules[i].subgrid.intersect(&rules[j].subgrid).is_empty() {
                        return Err(PassError(format!(
                            "stream {}: {AMBIGUOUS_ROUTER} on {:?} \
                             (needs checkerboard decomposition)",
                            s.name,
                            rules[i].subgrid.intersect(&rules[j].subgrid)
                        )));
                    }
                }
            }
            per_stream.push((s.id, s.name.clone(), rules));
        }
    }

    // 2. Conflict graph: footprints sharing any router.
    let n = per_stream.len();
    let mut adj: Vec<HashSet<usize>> = vec![HashSet::new(); n];
    for i in 0..n {
        for j in (i + 1)..n {
            let conflict = per_stream[i].2.iter().any(|a| {
                per_stream[j].2.iter().any(|b| !a.subgrid.intersect(&b.subgrid).is_empty())
            });
            if conflict {
                adj[i].insert(j);
                adj[j].insert(i);
            }
        }
    }

    // 3. Greedy coloring, highest degree first (Welsh–Powell).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(adj[i].len()));
    let mut color_of: Vec<Option<u8>> = vec![None; n];
    for &i in &order {
        let used: HashSet<u8> =
            adj[i].iter().filter_map(|&j| color_of[j]).collect();
        let c = (0..cfg.max_colors).find(|c| !used.contains(c));
        match c {
            Some(c) => color_of[i] = Some(c),
            None => {
                return Err(PassError(format!(
                    "OOR: stream {} needs a {}th color, only {} routable channels",
                    per_stream[i].1,
                    used.len() + 1,
                    cfg.max_colors
                )))
            }
        }
    }

    // 4. Emit colored route rules.
    let mut out = ColorAllocation::default();
    for (i, (id, _, rules)) in per_stream.iter().enumerate() {
        let color = color_of[i].unwrap();
        out.stream_color.insert(*id, color);
        for r in rules {
            out.routes.push(RouteRule {
                color,
                subgrid: r.subgrid.clone(),
                rx: r.rx,
                tx: r.tx,
            });
        }
    }
    let mut used: Vec<u8> = out.stream_color.values().copied().collect();
    used.sort_unstable();
    used.dedup();
    out.colors_used = used;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::passes::checkerboard::checkerboard;
    use crate::sem::{instantiate, Bindings};
    use crate::spada::parse_kernel;

    fn bind(pairs: &[(&str, i64)]) -> Bindings {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn cfg() -> MachineConfig {
        MachineConfig::with_grid(16, 16)
    }

    fn compile_streams(src: &str, binds: &[(&str, i64)]) -> (ir::Program, ColorAllocation) {
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(binds)).unwrap();
        let prog = checkerboard(&prog).unwrap().program;
        let alloc = allocate_colors(&prog, &cfg()).unwrap();
        (prog, alloc)
    }

    #[test]
    fn chain_pipeline_uses_distinct_colors() {
        let src = "kernel @p<N, K>() {
            place i16 i, i16 j in [0:N, 0] { f32[K] a }
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> s = relative_stream(-1, 0)
            }
            compute i32 i, i32 j in [1:N, 0] { await send(a, s) }
            compute i32 i, i32 j in [0:N-1, 0] { await receive(a, s) }
        }";
        let (prog, alloc) = compile_streams(src, &[("N", 8), ("K", 4)]);
        // Two variants (even/odd senders) with overlapping footprints →
        // two colors.
        assert_eq!(alloc.colors_used.len(), 2);
        // Every variant got a color; route rules exist for sender and
        // receiver sides.
        let n_streams = prog.phases[0].streams.len();
        assert_eq!(alloc.stream_color.len(), n_streams);
        assert!(alloc.routes.len() >= 2 * n_streams);
    }

    #[test]
    fn multicast_row_routes() {
        let src = "kernel @b<N, K>() {
            place i16 i, i16 j in [0:N, 0] { f32[K] a }
            dataflow i32 i, i32 j in [0:1, 0] {
                stream<f32> bc = relative_stream([1:N], 0)
            }
            compute i32 i, i32 j in [0, 0] { await send(a, bc) }
            compute i32 i, i32 j in [1:N, 0] { await receive(a, bc) }
        }";
        let (_, alloc) = compile_streams(src, &[("N", 8), ("K", 4)]);
        assert_eq!(alloc.colors_used.len(), 1);
        let color = alloc.colors_used[0];
        // Sender rule at PE0, middle rules forward+deliver, last delivers.
        let sender = alloc
            .routes
            .iter()
            .find(|r| r.subgrid.contains(0, 0))
            .unwrap();
        assert!(sender.rx.contains(Direction::Ramp));
        assert!(sender.tx.contains(Direction::East));
        let last = alloc.routes.iter().find(|r| r.subgrid.contains(7, 0)).unwrap();
        assert!(last.tx.contains(Direction::Ramp));
        assert!(!last.tx.contains(Direction::East));
        let mid = alloc.routes.iter().find(|r| r.subgrid.contains(3, 0)).unwrap();
        assert!(mid.tx.contains(Direction::Ramp));
        assert!(mid.tx.contains(Direction::East));
        assert_eq!(sender.color, color);
    }

    #[test]
    fn disjoint_streams_share_colors() {
        // Two streams on disjoint rows can share one color.
        let src = "kernel @d<N>() {
            place i16 i, i16 j in [0:N, 0:2] { f32 v }
            dataflow i32 i, i32 j in [0:2, 0] {
                stream<f32> s1 = relative_stream(1, 0)
            }
            dataflow i32 i, i32 j in [0:2, 1] {
                stream<f32> s2 = relative_stream(1, 0)
            }
            compute i32 i, i32 j in [0, 0] { await send(v, s1) }
            compute i32 i, i32 j in [1, 0] { await receive(v, s1) }
            compute i32 i, i32 j in [0, 1] { await send(v, s2) }
            compute i32 i, i32 j in [1, 1] { await receive(v, s2) }
        }";
        let (_, alloc) = compile_streams(src, &[("N", 4)]);
        assert_eq!(alloc.colors_used.len(), 1, "{:?}", alloc.stream_color);
    }

    #[test]
    fn cross_phase_streams_conflict() {
        // Same footprint in two phases → distinct colors (phases are
        // asynchronous across PEs).
        let src = "kernel @x<N>() {
            place i16 i, i16 j in [0:N, 0] { f32 v }
            phase {
                dataflow i32 i, i32 j in [0:N, 0] { stream<f32> s1 = relative_stream(1, 0) }
                compute i32 i, i32 j in [0, 0] { await send(v, s1) }
                compute i32 i, i32 j in [1, 0] { await receive(v, s1) }
            }
            phase {
                dataflow i32 i, i32 j in [0:N, 0] { stream<f32> s2 = relative_stream(1, 0) }
                compute i32 i, i32 j in [0, 0] { await send(v, s2) }
                compute i32 i, i32 j in [1, 0] { await receive(v, s2) }
            }
        }";
        let (_, alloc) = compile_streams(src, &[("N", 4)]);
        assert_eq!(alloc.colors_used.len(), 2);
    }

    #[test]
    fn color_exhaustion_is_oor() {
        // 30 overlapping streams in one phase on the same row → OOR.
        let mut decls = String::new();
        let mut sends = String::new();
        for i in 0..30 {
            decls.push_str(&format!("stream<f32> s{i} = relative_stream(1, 0)\n"));
            sends.push_str(&format!("send(v, s{i})\n"));
        }
        let src = format!(
            "kernel @o<N>() {{
                place i16 i, i16 j in [0:N, 0] {{ f32 v }}
                dataflow i32 i, i32 j in [0:N, 0] {{ {decls} }}
                compute i32 i, i32 j in [0, 0] {{ {sends} awaitall }}
            }}"
        );
        let k = parse_kernel(&src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 4)])).unwrap();
        let prog = checkerboard(&prog).unwrap().program;
        let err = allocate_colors(&prog, &cfg()).unwrap_err();
        assert!(err.0.contains("OOR"), "{}", err.0);
    }

    /// Mutually-conflicting streams up to exactly the hardware budget
    /// (24 routable channels) must color; one more is OOR.
    #[test]
    fn color_budget_boundary() {
        let build = |count: usize| {
            let mut decls = String::new();
            let mut sends = String::new();
            for i in 0..count {
                decls.push_str(&format!("stream<f32> s{i} = relative_stream(1, 0)\n"));
                sends.push_str(&format!("send(v, s{i})\n"));
            }
            let src = format!(
                "kernel @budget<N>() {{
                    place i16 i, i16 j in [0:N, 0] {{ f32 v }}
                    dataflow i32 i, i32 j in [0:N, 0] {{ {decls} }}
                    compute i32 i, i32 j in [0, 0] {{ {sends} awaitall }}
                }}"
            );
            let k = parse_kernel(&src).unwrap();
            let prog = instantiate(&k, &bind(&[("N", 4)])).unwrap();
            checkerboard(&prog).unwrap().program
        };
        // Exactly 24 overlapping streams fit the budget, each with its
        // own channel.
        let alloc = allocate_colors(&build(24), &cfg()).unwrap();
        assert_eq!(alloc.colors_used.len(), 24);
        assert!(alloc.colors_used.iter().all(|c| *c < 24));
        // The 25th conflicting stream exhausts the channels.
        let err = allocate_colors(&build(25), &cfg()).unwrap_err();
        assert!(err.0.contains("OOR"), "{}", err.0);
        assert!(err.0.contains("24"), "message names the budget: {}", err.0);
    }

    /// The budget tracks the machine config, not a hard-coded 24.
    #[test]
    fn color_budget_follows_config() {
        let src = "kernel @two<N>() {
            place i16 i, i16 j in [0:N, 0] { f32 v }
            dataflow i32 i, i32 j in [0:N, 0] {
                stream<f32> s0 = relative_stream(1, 0)
                stream<f32> s1 = relative_stream(1, 0)
            }
            compute i32 i, i32 j in [0, 0] { send(v, s0) send(v, s1) awaitall }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 4)])).unwrap();
        let prog = checkerboard(&prog).unwrap().program;
        let mut tiny = cfg();
        tiny.max_colors = 1;
        let err = allocate_colors(&prog, &tiny).unwrap_err();
        assert!(err.0.contains("OOR"), "{}", err.0);
        let mut two = cfg();
        two.max_colors = 2;
        let alloc = allocate_colors(&prog, &two).unwrap();
        assert_eq!(alloc.colors_used.len(), 2);
    }

    #[test]
    fn off_fabric_route_rejected() {
        let src = "kernel @e<N>() {
            place i16 i, i16 j in [0:N, 0] { f32 v }
            dataflow i32 i, i32 j in [0:N, 0] { stream<f32> s = relative_stream(-1, 0) }
            compute i32 i, i32 j in [0, 0] { await send(v, s) }
        }";
        let k = parse_kernel(src).unwrap();
        let prog = instantiate(&k, &bind(&[("N", 4)])).unwrap();
        let prog = checkerboard(&prog).unwrap().program;
        let err = allocate_colors(&prog, &cfg()).unwrap_err();
        assert!(err.0.contains("leaves"), "{}", err.0);
    }
}

//! Optimizing compiler passes (paper §V).
//!
//! Pipeline order:
//! 1. [`checkerboard`] — conflict-free routing decomposition (§V-B):
//!    splits compute blocks by PE-coordinate parity and duplicates
//!    streams into even/odd variants so no router carries an ambiguous
//!    configuration.
//! 2. [`classes`] — PE equivalence classes (§V-A canonicalization):
//!    partitions the fabric into maximal strided regions whose PEs run
//!    identical code (one CSL file per class, not per PE).
//! 3. [`colors`] — global color allocation + route-rule generation:
//!    conflict-graph coloring of stream variants onto the 24 routable
//!    hardware channels.
//!
//! Task fusion, task-ID recycling and copy elimination operate on the
//! per-class lowering and live in [`crate::csl::lower`]; they are toggled
//! by [`Options`] for the Fig. 9 ablations.

pub mod checkerboard;
pub mod classes;
pub mod colors;

pub use checkerboard::checkerboard;
pub use classes::{equivalence_classes, ClassRegion};
pub use colors::{allocate_colors, ColorAllocation};

/// Compilation options (ablation knobs, Fig. 9, plus the static
/// checker toggle).
#[derive(Clone, Copy, Debug)]
pub struct Options {
    /// Task fusion: coarsen chains of statements into single CSL tasks.
    pub fusion: bool,
    /// Task-ID recycling: map multiple logical tasks onto one hardware
    /// task ID via dispatch state machines.
    pub recycling: bool,
    /// Copy elimination: forward single-producer/single-consumer staging
    /// fields (incl. extern I/O fields) and reuse phase-scoped memory.
    pub copy_elim: bool,
    /// Run the static dataflow semantics checker
    /// ([`crate::analysis::check`]) after lowering; error findings fail
    /// the compile. On by default ("verify, then lower"); opt out for
    /// raw pipeline benchmarking.
    pub check: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options { fusion: true, recycling: true, copy_elim: true, check: true }
    }
}

impl Options {
    /// All codegen optimizations off (Fig. 9's "none" ablation). The
    /// static checker is not an optimization and stays on.
    pub fn none() -> Self {
        Options { fusion: false, recycling: false, copy_elim: false, check: true }
    }
}

/// Pass error (compile-time failure, including OOR conditions).
#[derive(Debug, Clone)]
pub struct PassError(pub String);

impl std::fmt::Display for PassError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pass error: {}", self.0)
    }
}

impl std::error::Error for PassError {}

/// Statistics reported by the pipeline (used by the Fig. 9 harness).
#[derive(Debug, Clone, Default)]
pub struct PassStats {
    pub streams_split: usize,
    pub blocks_split: usize,
    pub classes: usize,
    pub colors_used: usize,
    pub logical_tasks: usize,
    pub hw_task_ids: usize,
    pub fused_tasks: usize,
    pub copies_eliminated: usize,
    pub mem_bytes_max: u32,
}

//! Sparse workload subsystem: CSR matrices, a seeded corpus generator,
//! per-PE staging for the three SpMV dataflow variants, a CPU reference
//! oracle, and the adaptive variant selector.
//!
//! The three `.spada` kernels this module feeds (`spmv_rows`,
//! `spmv_tree`, `spmv_outer` — see `kernels/spada/`) differ only in how
//! work is partitioned and combined:
//!
//! - **rows** / **tree**: row-stationary 2-D blocks (PE `(i, j)` owns
//!   rows `[j·M/NY, …)` × cols `[i·N/NX, …)`); partials are `M/NY`
//!   words and combine west per row, pipelined chain vs binary tree.
//! - **outer**: column slices over all `NX·NY` PEs in port order;
//!   partials are full `M`-length vectors combined west then north.
//!
//! Per-PE work tracks the partition's nonzero count, so the right
//! variant depends on matrix *structure*, not size: uniform matrices
//! keep row blocks balanced (rows wins), skewed or banded matrices
//! concentrate row blocks on few PEs while column slices stay balanced
//! (outer wins), and deep narrow grids with short partials favor the
//! tree combine. [`select`] encodes exactly that trade as a closed-form
//! cycle estimate built from the machine's published cost constants;
//! the decision inputs are structural features of the input
//! ([`features`], [`rows_critical`], [`outer_critical`]) — never a
//! measurement.
//!
//! Everything here is deterministic: the generator runs on
//! [`SplitMix64`] streams keyed by caller seeds (no wall-clock or OS
//! randomness), and staging emits raw little-endian words so integer
//! CSR arrays cross the fabric bit-exact.

use crate::machine::Simulator;
use crate::util::SplitMix64;
use anyhow::{anyhow, bail, Result};

// ---------------------------------------------------------------------
// CSR format + seeded generator
// ---------------------------------------------------------------------

/// A compressed-sparse-row matrix. `rp` has `rows + 1` entries;
/// column indices within each row are strictly ascending.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    pub rp: Vec<u32>,
    pub ci: Vec<u32>,
    pub av: Vec<f32>,
}

impl CsrMatrix {
    pub fn nnz(&self) -> usize {
        self.ci.len()
    }

    /// Nonzero count of row `r`.
    pub fn row_len(&self, r: usize) -> usize {
        (self.rp[r + 1] - self.rp[r]) as usize
    }
}

/// Structural profile of a generated matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Profile {
    /// Every row draws `nnz_per_row` columns uniformly at random
    /// (deduplicated, so a row may hold slightly fewer).
    Uniform { nnz_per_row: usize },
    /// Geometrically decaying row lengths — row `r` targets
    /// `max(max_row >> (8·r/rows), 2)` nonzeros, so the heaviest rows
    /// cluster at the top (power-law skew).
    PowerLaw { max_row: usize },
    /// Band of half-width `half_width` around the diagonal — every
    /// in-range column is present.
    Banded { half_width: usize },
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Uniform { .. } => "uniform",
            Profile::PowerLaw { .. } => "powerlaw",
            Profile::Banded { .. } => "banded",
        }
    }
}

/// Generate a seeded matrix: same `(rows, cols, profile, seed)` →
/// bit-identical CSR on every host. Values are uniform in [-1, 1).
pub fn generate(rows: usize, cols: usize, profile: Profile, seed: u64) -> CsrMatrix {
    let mut rng = SplitMix64::new(seed);
    let mut rp = Vec::with_capacity(rows + 1);
    let mut ci = Vec::new();
    let mut av = Vec::new();
    rp.push(0u32);
    for r in 0..rows {
        let mut row_cols: Vec<u32> = match profile {
            Profile::Uniform { nnz_per_row } => {
                let want = nnz_per_row.clamp(1, cols);
                (0..want).map(|_| rng.below(cols as u64) as u32).collect()
            }
            Profile::PowerLaw { max_row } => {
                let want = (max_row >> (8 * r / rows.max(1))).max(2).min(cols);
                (0..want).map(|_| rng.below(cols as u64) as u32).collect()
            }
            Profile::Banded { half_width } => {
                let lo = r.saturating_sub(half_width);
                let hi = (r + half_width + 1).min(cols);
                (lo..hi.max(lo)).map(|c| c as u32).collect()
            }
        };
        row_cols.sort_unstable();
        row_cols.dedup();
        for c in row_cols {
            ci.push(c);
            av.push(rng.next_f32());
        }
        rp.push(ci.len() as u32);
    }
    CsrMatrix { rows, cols, rp, ci, av }
}

/// Deterministic dense vector in [-1, 1) for the `x` operand.
pub fn seeded_x(cols: usize, seed: u64) -> Vec<f32> {
    let mut rng = SplitMix64::new(seed);
    (0..cols).map(|_| rng.next_f32()).collect()
}

// ---------------------------------------------------------------------
// Reference oracle
// ---------------------------------------------------------------------

/// CPU reference `y = A·x`, accumulated in f64 and rounded once — the
/// oracle the harness and tests compare simulator outputs against
/// (with a tolerance: the fabric accumulates in a different order).
pub fn spmv_ref(a: &CsrMatrix, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), a.cols, "x length must match the column count");
    let mut y = vec![0f32; a.rows];
    for r in 0..a.rows {
        let mut acc = 0f64;
        for t in a.rp[r] as usize..a.rp[r + 1] as usize {
            acc += a.av[t] as f64 * x[a.ci[t] as usize] as f64;
        }
        y[r] = acc as f32;
    }
    y
}

// ---------------------------------------------------------------------
// Structural features
// ---------------------------------------------------------------------

/// Structural features of a matrix — the selector's decision inputs,
/// and the per-row diagnostics `BENCH_sparse.json` reports.
#[derive(Clone, Copy, Debug)]
pub struct Features {
    pub nnz: usize,
    /// Mean row length.
    pub mean: f64,
    /// Population variance of row lengths.
    pub variance: f64,
    /// Max row length / mean row length (1.0 = perfectly regular).
    pub skew: f64,
    /// Max |col - row| over all nonzeros.
    pub bandwidth: usize,
}

pub fn features(a: &CsrMatrix) -> Features {
    let n = a.rows.max(1) as f64;
    let mean = a.nnz() as f64 / n;
    let mut var = 0f64;
    let mut max_len = 0usize;
    for r in 0..a.rows {
        let len = a.row_len(r);
        var += (len as f64 - mean) * (len as f64 - mean);
        max_len = max_len.max(len);
    }
    let mut bandwidth = 0usize;
    for r in 0..a.rows {
        for t in a.rp[r] as usize..a.rp[r + 1] as usize {
            bandwidth = bandwidth.max((a.ci[t] as i64 - r as i64).unsigned_abs() as usize);
        }
    }
    Features {
        nnz: a.nnz(),
        mean,
        variance: var / n,
        skew: if mean > 0.0 { max_len as f64 / mean } else { 1.0 },
        bandwidth,
    }
}

// ---------------------------------------------------------------------
// Partition criticals + adaptive selector
// ---------------------------------------------------------------------

/// The three SpMV dataflow variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Rows,
    Tree,
    Outer,
}

impl Variant {
    pub const ALL: [Variant; 3] = [Variant::Rows, Variant::Outer, Variant::Tree];

    /// The library kernel this variant compiles to.
    pub fn kernel(&self) -> &'static str {
        match self {
            Variant::Rows => "spmv_rows",
            Variant::Tree => "spmv_tree",
            Variant::Outer => "spmv_outer",
        }
    }
}

/// Map a sparse kernel name back to its variant.
pub fn variant_of(kernel: &str) -> Result<Variant> {
    Ok(match kernel {
        "spmv_rows" => Variant::Rows,
        "spmv_tree" => Variant::Tree,
        "spmv_outer" => Variant::Outer,
        other => bail!("not a sparse library kernel: {other}"),
    })
}

/// Max nonzeros on any PE under the row-stationary 2-D block partition
/// — the compute critical path of `spmv_rows` / `spmv_tree`.
pub fn rows_critical(a: &CsrMatrix, nx: usize, ny: usize) -> u64 {
    let mb = a.rows.div_ceil(ny.max(1));
    let nb = a.cols.div_ceil(nx.max(1));
    let mut per_pe = vec![0u64; nx * ny];
    for r in 0..a.rows {
        let j = (r / mb).min(ny - 1);
        for t in a.rp[r] as usize..a.rp[r + 1] as usize {
            let i = (a.ci[t] as usize / nb).min(nx - 1);
            per_pe[i * ny + j] += 1;
        }
    }
    per_pe.into_iter().max().unwrap_or(0)
}

/// Max nonzeros on any PE under the contiguous column-slice partition
/// — the scatter critical path of `spmv_outer`.
pub fn outer_critical(a: &CsrMatrix, nx: usize, ny: usize) -> u64 {
    let p = (nx * ny).max(1);
    let ncp = a.cols.div_ceil(p);
    let mut per_pe = vec![0u64; p];
    for &c in &a.ci {
        per_pe[(c as usize / ncp).min(p - 1)] += 1;
    }
    per_pe.into_iter().max().unwrap_or(0)
}

// Cost-model constants, calibrated against the machine's published
// per-event costs (`machine::config`): ~one scalar inner iteration of
// the CSR loop (bound eval + clamped index + fmac + store) per
// nonzero, ~`data_task_wavelet_cycles` per combined word, ~`hop +
// dispatch + task_wakeup` per chain stage fill, and ~`task_wakeup +
// dsd_issue + dispatch` per extra phase level. Absolute cycles don't
// matter — only that the *ratios* track the simulator, which the
// sparse harness verifies corpus-wide (selector ≤ every fixed
// variant).

/// Estimated cycles per nonzero on the row-stationary critical PE.
pub const COST_NNZ_ROWS: u64 = 18;
/// Estimated cycles per nonzero for the outer scatter (extra indexed
/// store vs the rows inner loop).
pub const COST_NNZ_SCATTER: u64 = 20;
/// Pipelined cycles per combined partial word.
pub const COST_WORD: u64 = 2;
/// Fill cost per chain stage (hop + dispatch + wakeup).
pub const COST_HOP: u64 = 11;
/// Overhead per tree level / extra combine phase (barrier + wakeup +
/// DSD issue).
pub const COST_LEVEL: u64 = 13;

fn ceil_log2(n: u64) -> u64 {
    (64 - n.max(1).saturating_sub(1).leading_zeros() as u64).min(63)
}

/// Closed-form cycle estimate for one variant on an `nx × ny` grid.
pub fn estimate(v: Variant, a: &CsrMatrix, nx: usize, ny: usize) -> u64 {
    let mb = a.rows.div_ceil(ny.max(1)) as u64;
    match v {
        Variant::Rows => {
            COST_NNZ_ROWS * rows_critical(a, nx, ny)
                + (nx as u64 - 1) * COST_HOP
                + mb * COST_WORD
        }
        Variant::Tree => {
            COST_NNZ_ROWS * rows_critical(a, nx, ny)
                + ceil_log2(nx as u64) * (COST_LEVEL + mb * COST_WORD)
        }
        Variant::Outer => {
            COST_NNZ_SCATTER * outer_critical(a, nx, ny)
                + 2 * (COST_LEVEL + a.rows as u64 * COST_WORD)
                + (nx as u64 + ny as u64 - 2) * COST_HOP
        }
    }
}

/// Pick the variant with the smallest estimate (ties resolve in
/// [`Variant::ALL`] order: rows, then outer, then tree). Returns the
/// winner and the per-variant estimates `[rows, outer, tree]` in
/// `Variant::ALL` order.
pub fn select(a: &CsrMatrix, nx: usize, ny: usize) -> (Variant, [u64; 3]) {
    let ests: Vec<u64> = Variant::ALL.iter().map(|&v| estimate(v, a, nx, ny)).collect();
    let mut best = 0usize;
    for k in 1..ests.len() {
        if ests[k] < ests[best] {
            best = k;
        }
    }
    (Variant::ALL[best], [ests[0], ests[1], ests[2]])
}

// ---------------------------------------------------------------------
// Per-PE staging
// ---------------------------------------------------------------------

/// A matrix packed for one kernel variant: the meta-parameter binds to
/// compile with and the raw input words to stage, in binding order.
/// Integer arrays are little-endian `i32` words; padding entries are
/// zero so clamped kernel loops never read them.
#[derive(Clone, Debug)]
pub struct Staged {
    pub binds: Vec<(&'static str, i64)>,
    pub inputs: Vec<(&'static str, Vec<u32>)>,
    /// The padded per-PE nonzero capacity (also present in `binds`).
    pub nnzp: i64,
}

impl Staged {
    /// Stage every input into a simulator compiled with `self.binds`.
    pub fn apply(&self, sim: &mut Simulator) -> Result<()> {
        for (arg, words) in &self.inputs {
            sim.set_input_words(arg, words.clone()).map_err(|e| anyhow!("{arg}: {e}"))?;
        }
        Ok(())
    }
}

/// Pack for `spmv_rows` / `spmv_tree`: per-PE CSR blocks in port order
/// (`i·NY + j`), block-local row pointers and column indices, arrays
/// padded to the fabric-wide max block nonzero count.
pub fn stage_rows(a: &CsrMatrix, x: &[f32], nx: usize, ny: usize) -> Result<Staged> {
    if nx < 1 || ny < 2 {
        bail!("spmv_rows/spmv_tree need nx >= 1, ny >= 2 (got {nx}x{ny})");
    }
    if a.rows % ny != 0 || a.cols % nx != 0 {
        bail!("matrix {}x{} does not tile a {nx}x{ny} grid", a.rows, a.cols);
    }
    if x.len() != a.cols {
        bail!("x has {} entries, matrix has {} columns", x.len(), a.cols);
    }
    let (mb, nb) = (a.rows / ny, a.cols / nx);
    // blocks[i][j] = (local rp, local ci, values)
    let mut blocks: Vec<(Vec<u32>, Vec<u32>, Vec<f32>)> =
        vec![(vec![0u32], vec![], vec![]); nx * ny];
    for j in 0..ny {
        for r in j * mb..(j + 1) * mb {
            for t in a.rp[r] as usize..a.rp[r + 1] as usize {
                let c = a.ci[t] as usize;
                let i = c / nb;
                let b = &mut blocks[i * ny + j];
                b.1.push((c - i * nb) as u32);
                b.2.push(a.av[t]);
            }
            // Close row `r` in every column block of this row band.
            for i in 0..nx {
                let b = &mut blocks[i * ny + j];
                b.0.push(b.1.len() as u32);
            }
        }
    }
    let nnzp = blocks.iter().map(|b| b.1.len()).max().unwrap_or(0).max(1);
    let mut rp_w = Vec::with_capacity(nx * ny * (mb + 1));
    let mut ci_w = Vec::with_capacity(nx * ny * nnzp);
    let mut av_w = Vec::with_capacity(nx * ny * nnzp);
    for (rp, ci, av) in &blocks {
        debug_assert_eq!(rp.len(), mb + 1);
        rp_w.extend(rp.iter().copied());
        ci_w.extend(ci.iter().copied());
        ci_w.extend(std::iter::repeat(0u32).take(nnzp - ci.len()));
        av_w.extend(av.iter().map(|v| v.to_bits()));
        av_w.extend(std::iter::repeat(0f32.to_bits()).take(nnzp - av.len()));
    }
    let x_w: Vec<u32> = x.iter().map(|v| v.to_bits()).collect();
    Ok(Staged {
        binds: vec![
            ("M", a.rows as i64),
            ("N", a.cols as i64),
            ("NX", nx as i64),
            ("NY", ny as i64),
            ("NNZP", nnzp as i64),
        ],
        inputs: vec![("rp_in", rp_w), ("ci_in", ci_w), ("av_in", av_w), ("x_in", x_w)],
        nnzp: nnzp as i64,
    })
}

/// Pack for `spmv_outer`: contiguous column slices over all `nx·ny`
/// PEs in port order, column-compressed with *global* row indices,
/// plus the matching x slice per PE.
pub fn stage_outer(a: &CsrMatrix, x: &[f32], nx: usize, ny: usize) -> Result<Staged> {
    if nx < 1 || ny < 2 {
        bail!("spmv_outer needs nx >= 1, ny >= 2 (got {nx}x{ny})");
    }
    let p = nx * ny;
    if a.cols % p != 0 {
        bail!("matrix with {} columns does not slice over {p} PEs", a.cols);
    }
    if x.len() != a.cols {
        bail!("x has {} entries, matrix has {} columns", x.len(), a.cols);
    }
    let ncp = a.cols / p;
    // Column-major gather: per column, (row, value) in ascending row
    // order (CSR row iteration order).
    let mut by_col: Vec<Vec<(u32, f32)>> = vec![vec![]; a.cols];
    for r in 0..a.rows {
        for t in a.rp[r] as usize..a.rp[r + 1] as usize {
            by_col[a.ci[t] as usize].push((r as u32, a.av[t]));
        }
    }
    let mut slices: Vec<(Vec<u32>, Vec<u32>, Vec<f32>)> = Vec::with_capacity(p);
    for p0 in 0..p {
        let mut cp = vec![0u32];
        let mut ri = vec![];
        let mut av = vec![];
        for c in p0 * ncp..(p0 + 1) * ncp {
            for &(r, v) in &by_col[c] {
                ri.push(r);
                av.push(v);
            }
            cp.push(ri.len() as u32);
        }
        slices.push((cp, ri, av));
    }
    let nnzp = slices.iter().map(|s| s.1.len()).max().unwrap_or(0).max(1);
    let mut cp_w = Vec::with_capacity(p * (ncp + 1));
    let mut ri_w = Vec::with_capacity(p * nnzp);
    let mut av_w = Vec::with_capacity(p * nnzp);
    let mut x_w = Vec::with_capacity(a.cols);
    for (p0, (cp, ri, av)) in slices.iter().enumerate() {
        cp_w.extend(cp.iter().copied());
        ri_w.extend(ri.iter().copied());
        ri_w.extend(std::iter::repeat(0u32).take(nnzp - ri.len()));
        av_w.extend(av.iter().map(|v| v.to_bits()));
        av_w.extend(std::iter::repeat(0f32.to_bits()).take(nnzp - av.len()));
        x_w.extend(x[p0 * ncp..(p0 + 1) * ncp].iter().map(|v| v.to_bits()));
    }
    Ok(Staged {
        binds: vec![
            ("M", a.rows as i64),
            ("N", a.cols as i64),
            ("NX", nx as i64),
            ("NY", ny as i64),
            ("NNZP", nnzp as i64),
        ],
        inputs: vec![("cp_in", cp_w), ("ri_in", ri_w), ("av_in", av_w), ("x_in", x_w)],
        nnzp: nnzp as i64,
    })
}

/// Pack for any variant.
pub fn stage(v: Variant, a: &CsrMatrix, x: &[f32], nx: usize, ny: usize) -> Result<Staged> {
    match v {
        Variant::Rows | Variant::Tree => stage_rows(a, x, nx, ny),
        Variant::Outer => stage_outer(a, x, nx, ny),
    }
}

// ---------------------------------------------------------------------
// Demo problem: the registry / fault-campaign workload
// ---------------------------------------------------------------------

/// Seed of the registry's demo matrices (`scaled_binds` on a sparse
/// kernel and the fault campaign's staging both derive from it, so the
/// binds and the staged words always describe the same matrix).
pub const DEMO_SEED: u64 = 0x5EED;

/// Grid side for scale factor `g`: at least 2 (multicast and the
/// north chain need two rows) and a power of two (`spmv_tree`).
pub fn demo_grid(g: i64) -> i64 {
    (g.max(2) as u64).next_power_of_two() as i64
}

/// The deterministic demo problem at scale `g` with density knob `k`:
/// a uniform `4g²  × 4g²` matrix (divisible by every partition the
/// variants need) with ~`clamp(k, 1, 8)` nonzeros per row.
pub fn demo_problem(g: i64, k: i64) -> (CsrMatrix, Vec<f32>) {
    let g2 = demo_grid(g) as usize;
    let m = 4 * g2 * g2;
    let per_row = k.clamp(1, 8) as usize;
    let a = generate(m, m, Profile::Uniform { nnz_per_row: per_row }, DEMO_SEED ^ k as u64);
    let x = seeded_x(m, DEMO_SEED.wrapping_add(1));
    (a, x)
}

/// Bind list and grid for a sparse library kernel at scale `g` —
/// the sparse arm of `harness::common::scaled_binds`.
pub fn demo_binds(kernel: &str, g: i64, k: i64) -> Result<(Vec<(&'static str, i64)>, i64, i64)> {
    let v = variant_of(kernel)?;
    let (a, x) = demo_problem(g, k);
    let g2 = demo_grid(g);
    let staged = stage(v, &a, &x, g2 as usize, g2 as usize)?;
    Ok((staged.binds, g2, g2))
}

/// Stage the demo problem into a simulator compiled from
/// [`demo_binds`] with the same `(kernel, g, k)`.
pub fn stage_demo(sim: &mut Simulator, kernel: &str, g: i64, k: i64) -> Result<()> {
    let v = variant_of(kernel)?;
    let (a, x) = demo_problem(g, k);
    let g2 = demo_grid(g) as usize;
    stage(v, &a, &x, g2, g2)?.apply(sim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_well_formed() {
        for profile in [
            Profile::Uniform { nnz_per_row: 4 },
            Profile::PowerLaw { max_row: 32 },
            Profile::Banded { half_width: 2 },
        ] {
            let a = generate(32, 32, profile, 7);
            let b = generate(32, 32, profile, 7);
            assert_eq!(a, b, "{profile:?}: same seed must reproduce bit-identically");
            let c = generate(32, 32, profile, 8);
            assert_ne!(a, c, "{profile:?}: different seed must differ");
            assert_eq!(a.rp.len(), 33);
            assert_eq!(*a.rp.last().unwrap() as usize, a.nnz());
            assert_eq!(a.ci.len(), a.av.len());
            for r in 0..a.rows {
                assert!(a.rp[r] <= a.rp[r + 1], "{profile:?}: rp must be monotone");
                let row = &a.ci[a.rp[r] as usize..a.rp[r + 1] as usize];
                assert!(row.windows(2).all(|w| w[0] < w[1]), "{profile:?}: cols ascend");
                assert!(row.iter().all(|&c| (c as usize) < a.cols));
            }
        }
    }

    #[test]
    fn oracle_on_hand_built_matrix() {
        // [[2, 0], [1, 3]] · [1, -1] = [2, -2]
        let a = CsrMatrix {
            rows: 2,
            cols: 2,
            rp: vec![0, 1, 3],
            ci: vec![0, 0, 1],
            av: vec![2.0, 1.0, 3.0],
        };
        assert_eq!(spmv_ref(&a, &[1.0, -1.0]), vec![2.0, -2.0]);
    }

    #[test]
    fn features_on_hand_built_matrices() {
        // Perfectly regular diagonal: skew 1, variance 0, bandwidth 0.
        let diag = generate(16, 16, Profile::Banded { half_width: 0 }, 1);
        let f = features(&diag);
        assert_eq!(f.nnz, 16);
        assert!((f.mean - 1.0).abs() < 1e-12);
        assert!(f.variance < 1e-12);
        assert!((f.skew - 1.0).abs() < 1e-12);
        assert_eq!(f.bandwidth, 0);

        // One heavy row: skew = max/mean spikes.
        let mut heavy = diag.clone();
        heavy.rp = vec![0; 17];
        heavy.ci = (0..16u32).collect();
        heavy.av = vec![1.0; 16];
        for r in 1..=16 {
            heavy.rp[r] = 16; // row 0 holds everything
        }
        let f = features(&heavy);
        assert_eq!(f.nnz, 16);
        assert!((f.skew - 16.0).abs() < 1e-9, "one-row matrix skews to rows·max/mean");
        assert_eq!(f.bandwidth, 15);
    }

    #[test]
    fn staging_partitions_every_nonzero_exactly_once() {
        let a = generate(32, 32, Profile::PowerLaw { max_row: 16 }, 3);
        let x = seeded_x(32, 4);
        let st = stage_rows(&a, &x, 4, 4).unwrap();
        // 16 ports × (MB+1) row pointers; final pointer of each port
        // sums the block nonzeros — together they cover nnz exactly.
        let rp = &st.inputs[0].1;
        assert_eq!(rp.len(), 16 * 9);
        let covered: u32 = (0..16).map(|p| rp[p * 9 + 8]).sum();
        assert_eq!(covered as usize, a.nnz());

        let st = stage_outer(&a, &x, 4, 4).unwrap();
        let cp = &st.inputs[0].1;
        assert_eq!(cp.len(), 16 * 3); // NCP = 32/16 = 2, +1 pointer
        let covered: u32 = (0..16).map(|p| cp[p * 3 + 2]).sum();
        assert_eq!(covered as usize, a.nnz());
        assert!(st.nnzp >= 1);
    }

    #[test]
    fn criticals_match_hand_partition() {
        // Banded matrices concentrate row blocks near the diagonal:
        // the rows partition goes critical, column slices stay flat.
        let a = generate(32, 32, Profile::Banded { half_width: 2 }, 5);
        let rc = rows_critical(&a, 4, 4);
        let oc = outer_critical(&a, 4, 4);
        assert!(
            rc >= 2 * oc,
            "banded: rows partition must be ≥2× more critical (rows {rc}, outer {oc})"
        );
        // Uniform matrices keep both partitions balanced.
        let u = generate(32, 32, Profile::Uniform { nnz_per_row: 4 }, 5);
        let (rc, oc) = (rows_critical(&u, 4, 4), outer_critical(&u, 4, 4));
        assert!(rc < 3 * oc, "uniform: partitions stay comparable (rows {rc}, outer {oc})");
    }

    #[test]
    fn selector_picks_expected_variants_on_synthetic_shapes() {
        // Uniform on a square grid: balanced row blocks, short
        // partials — row-stationary chain wins.
        let u = generate(64, 64, Profile::Uniform { nnz_per_row: 8 }, 11);
        assert_eq!(select(&u, 4, 4).0, Variant::Rows);

        // Banded: row blocks go critical, column slices balance —
        // outer wins despite the full-length combine.
        let b = generate(64, 64, Profile::Banded { half_width: 2 }, 11);
        assert_eq!(select(&b, 4, 4).0, Variant::Outer);

        // Power-law: heavy rows cluster in one row band — outer wins.
        let p = generate(64, 64, Profile::PowerLaw { max_row: 64 }, 11);
        assert_eq!(select(&p, 4, 4).0, Variant::Outer);

        // Deep narrow grid with short partials: tree combine beats the
        // chain fill (8 stages of fill vs 3 levels).
        let t = generate(8, 64, Profile::Uniform { nnz_per_row: 4 }, 11);
        assert_eq!(select(&t, 8, 2).0, Variant::Tree);
    }

    #[test]
    fn demo_binds_and_staging_agree() {
        for kernel in ["spmv_rows", "spmv_tree", "spmv_outer"] {
            let (binds, w, h) = demo_binds(kernel, 4, 8).unwrap();
            assert_eq!((w, h), (4, 4));
            let get = |n: &str| binds.iter().find(|(k, _)| *k == n).map(|(_, v)| *v).unwrap();
            assert_eq!(get("M"), 64);
            assert_eq!(get("N"), 64);
            assert!(get("NNZP") >= 1);
            // Regenerating stages the same NNZP the binds promised.
            let (a, x) = demo_problem(4, 8);
            let st = stage(variant_of(kernel).unwrap(), &a, &x, 4, 4).unwrap();
            assert_eq!(st.nnzp, get("NNZP"));
        }
        assert!(demo_binds("gemv", 4, 8).is_err());
    }

    #[test]
    fn estimates_are_monotone_in_critical_path() {
        let sparse9 = generate(64, 64, Profile::Uniform { nnz_per_row: 2 }, 2);
        let dense9 = generate(64, 64, Profile::Uniform { nnz_per_row: 8 }, 2);
        for v in Variant::ALL {
            assert!(
                estimate(v, &dense9, 4, 4) > estimate(v, &sparse9, 4, 4),
                "{v:?}: more nonzeros must never estimate cheaper"
            );
        }
    }
}

//! Minimal micro-benchmark helper (criterion is unavailable offline).
//!
//! Benches in `rust/benches/` are `harness = false` binaries that use
//! [`bench_ms`] / [`Table`] to time runs and print aligned result tables
//! that mirror the paper's figures.

use std::time::Instant;

/// Time `f` over `iters` iterations after `warmup` untimed ones; returns
/// (median_ms, min_ms, max_ms).
pub fn bench_ms(warmup: usize, iters: usize, mut f: impl FnMut()) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    (median, samples[0], *samples.last().unwrap())
}

/// Simple aligned text table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                if i < widths.len() {
                    widths[i] = widths[i].max(c.len());
                }
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>width$}  ", c, width = widths.get(i).copied().unwrap_or(8)));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for r in &self.rows {
            line(r);
        }
    }
}

/// Human-friendly engineering formatting.
pub fn eng(v: f64) -> String {
    let a = v.abs();
    if a >= 1e12 {
        format!("{:.2}T", v / 1e12)
    } else if a >= 1e9 {
        format!("{:.2}G", v / 1e9)
    } else if a >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if a >= 1e3 {
        format!("{:.2}k", v / 1e3)
    } else {
        format!("{v:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs() {
        let (m, lo, hi) = bench_ms(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(lo <= m && m <= hi);
    }

    #[test]
    fn eng_format() {
        assert_eq!(eng(2.5e12), "2.50T");
        assert_eq!(eng(999.0), "999.00");
    }
}

//! SpaDA kernel library — the paper's evaluated kernels as SpaDA source.
//!
//! Each kernel is an embedded `.spada` file parsed and instantiated on
//! demand; [`KernelSpec`] couples the source with its meta-parameters so
//! the harness, examples and tests share one entry point.

use crate::machine::{MachineConfig, MachineProgram, RoutingPlan, SimError, SimOptions, Simulator};
use crate::passes::{Options, PassStats};
use crate::sem::{instantiate, Bindings};
use crate::spada::{parse_kernel, pretty, Kernel};
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

pub const CHAIN_REDUCE: &str = include_str!("spada/chain_reduce.spada");
pub const BROADCAST: &str = include_str!("spada/broadcast.spada");
pub const TREE_REDUCE: &str = include_str!("spada/tree_reduce.spada");
pub const TWO_PHASE_REDUCE: &str = include_str!("spada/two_phase_reduce.spada");
pub const GEMV: &str = include_str!("spada/gemv.spada");
pub const GEMV_TREE: &str = include_str!("spada/gemv_tree.spada");
pub const SPMV_ROWS: &str = include_str!("spada/spmv_rows.spada");
pub const SPMV_TREE: &str = include_str!("spada/spmv_tree.spada");
pub const SPMV_OUTER: &str = include_str!("spada/spmv_outer.spada");

/// One library kernel plus its meta-parameter recipe — the single
/// list the harnesses, the fault campaign and the equivalence suites
/// iterate instead of each hard-coding the kernel names. Sparse
/// kernels carry matrix-shaped binds (CSR extents, `NNZP`) derived
/// from the seeded demo problem in [`crate::sparse`], not just a grid
/// size, which is why the recipe lives behind [`KernelSpec::scaled_binds`]
/// rather than a plain bind list.
#[derive(Clone, Copy, Debug)]
pub struct KernelSpec {
    pub name: &'static str,
    pub source: &'static str,
    /// Takes CSR matrix binds and stages a seeded sparse matrix (the
    /// generic noise stagers remain *safe* on these kernels — clamped
    /// loops terminate in-bounds — but a real workload needs
    /// [`crate::sparse::stage_demo`]).
    pub sparse: bool,
    /// Instantiates only on power-of-two grid sides (tree combines).
    pub grid_pow2: bool,
}

impl KernelSpec {
    /// Bind list and grid geometry at scale factor `g` with per-PE
    /// vector length / density knob `k`: `(binds, width, height)`.
    /// Dense kernels reproduce the historical `scaled_binds` recipes;
    /// sparse kernels defer to the seeded demo problem (which clamps
    /// `g` to a power-of-two grid side ≥ 2 internally).
    pub fn scaled_binds(&self, g: i64, k: i64) -> Result<(Vec<(&'static str, i64)>, i64, i64)> {
        Ok(match self.name {
            "chain_reduce" => (vec![("K", k), ("N", g)], g.max(2), 1),
            "broadcast" => (vec![("K", k), ("N", g)], g, 1),
            "tree_reduce" | "two_phase_reduce" => {
                (vec![("K", k), ("NX", g), ("NY", g)], g, g)
            }
            "gemv" | "gemv_tree" => {
                let n = 2 * g;
                (vec![("M", n), ("N", n), ("NX", g), ("NY", g)], g, g)
            }
            name if self.sparse => crate::sparse::demo_binds(name, g, k)?,
            other => return Err(anyhow!("no scaling recipe for kernel {other}")),
        })
    }
}

/// The kernel registry: the paper's six dense kernels plus the three
/// sparse SpMV dataflow variants.
pub fn specs() -> Vec<KernelSpec> {
    let dense = |name, source| KernelSpec { name, source, sparse: false, grid_pow2: false };
    let sparse = |name, source| KernelSpec { name, source, sparse: true, grid_pow2: true };
    vec![
        dense("chain_reduce", CHAIN_REDUCE),
        dense("broadcast", BROADCAST),
        KernelSpec { name: "tree_reduce", source: TREE_REDUCE, sparse: false, grid_pow2: true },
        dense("two_phase_reduce", TWO_PHASE_REDUCE),
        KernelSpec { name: "gemv", source: GEMV, sparse: false, grid_pow2: true },
        KernelSpec { name: "gemv_tree", source: GEMV_TREE, sparse: false, grid_pow2: true },
        sparse("spmv_rows", SPMV_ROWS),
        sparse("spmv_tree", SPMV_TREE),
        sparse("spmv_outer", SPMV_OUTER),
    ]
}

/// Look up one registry entry.
pub fn spec(name: &str) -> Result<KernelSpec> {
    specs().into_iter().find(|s| s.name == name).ok_or_else(|| anyhow!("unknown kernel {name}"))
}

/// Every library kernel name, registry order.
pub fn names() -> Vec<&'static str> {
    specs().into_iter().map(|s| s.name).collect()
}

/// The dense-regular subset (the paper's original six kernels) — the
/// `sim_scaling` bench sweeps exactly these so `BENCH_sim.json` rows
/// stay comparable against blessed baselines.
pub fn dense_names() -> Vec<&'static str> {
    specs().into_iter().filter(|s| !s.sparse).map(|s| s.name).collect()
}

/// All named kernels in the library.
pub fn sources() -> Vec<(&'static str, &'static str)> {
    specs().into_iter().map(|s| (s.name, s.source)).collect()
}

pub fn source(name: &str) -> Result<&'static str> {
    sources()
        .into_iter()
        .find(|(n, _)| *n == name)
        .map(|(_, s)| s)
        .ok_or_else(|| anyhow!("unknown kernel {name}"))
}

/// Parse a library kernel.
pub fn parse(name: &str) -> Result<Kernel> {
    let src = source(name)?;
    parse_kernel(src).map_err(|e| anyhow!("{name}: {e}"))
}

/// SpaDA LoC of a library kernel (Table II metric).
pub fn spada_loc(name: &str) -> Result<usize> {
    Ok(pretty::count_loc(&parse(name)?))
}

/// A fully compiled library kernel: the loadable machine program plus
/// the one [`RoutingPlan`] built for it.
///
/// The plan is traced exactly once per compiled kernel and shared by
/// every consumer: the static checker sees it inside [`compile`], the
/// simulator executes from it via [`CompiledKernel::simulator`], and
/// the harness/benches reuse it across runs of the same compilation.
pub struct CompiledKernel {
    pub machine: MachineProgram,
    /// Machine config the kernel was compiled (and the plan built) for.
    pub cfg: MachineConfig,
    /// The shared precompiled routing/execution plan.
    pub plan: Arc<RoutingPlan>,
    pub stats: PassStats,
    /// Generated CSL lines of code (Table II metric).
    pub csl_loc: usize,
}

impl CompiledKernel {
    /// Build a simulator that executes from the shared plan instance —
    /// no route is re-traced. Each call yields a fresh single-shot
    /// simulator over the same compilation, with runtime options
    /// resolved from the environment once (the historical `SPADA_*`
    /// behaviour via [`SimOptions::from_env`]).
    pub fn simulator(&self) -> Result<Simulator, SimError> {
        Simulator::with_plan(self.cfg.clone(), self.machine.clone(), Arc::clone(&self.plan))
    }

    /// Build a simulator with **explicit** runtime options — the
    /// environment is never consulted, so concurrent jobs of one
    /// compiled kernel can run with different thread counts, buffer
    /// capacities, fault plans or watchdogs in the same process (the
    /// batch-fleet path; see [`crate::fleet`]).
    pub fn simulator_with(&self, opts: &SimOptions) -> Result<Simulator, SimError> {
        Simulator::with_plan_opts(
            self.cfg.clone(),
            self.machine.clone(),
            Arc::clone(&self.plan),
            opts,
        )
    }

    /// Approximate resident bytes of this compilation: the routing
    /// plan's dense tables plus a flat per-element estimate of the
    /// machine program (class/route/IO bodies are not walked). This is
    /// what the fleet plan cache charges an entry against its byte
    /// budget ([`crate::machine::CacheBudget`]).
    pub fn approx_bytes(&self) -> u64 {
        self.plan.approx_bytes()
            + self.machine.classes.len() as u64 * 256
            + self.machine.routes.len() as u64 * 64
            + self.machine.io.len() as u64 * 96
            + 1024
    }
}

/// Convenience: parse + instantiate + compile a kernel.
///
/// Unless [`Options::check`] is off, the compiled machine program is
/// verified by the static dataflow semantics checker
/// ([`crate::analysis::check_with_plan`]) — routing correctness, data
/// races, deadlock freedom — before it is handed back ("verify, then
/// lower"). The checker runs against the same [`RoutingPlan`] instance
/// returned in the [`CompiledKernel`], so a checked-and-simulated run
/// traces every route once, not twice.
pub fn compile(
    name: &str,
    binds: &[(&str, i64)],
    cfg: &MachineConfig,
    opts: &Options,
) -> Result<CompiledKernel> {
    let kernel = parse(name)?;
    let bindings: Bindings = binds.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    let prog = instantiate(&kernel, &bindings).context(name.to_string())?;
    let compiled = crate::csl::compile(&prog, cfg, opts).map_err(|e| anyhow!("{name}: {e}"))?;
    let loc = compiled.csl_loc();
    let mut machine = compiled.machine;
    // One plan per compiled kernel; the plan reads only classes/routes,
    // so the meta updates below cannot invalidate it.
    let plan = RoutingPlan::build(&machine, cfg);
    if opts.check {
        let report = crate::analysis::check_with_plan(&machine, cfg, &plan);
        if report.has_errors() {
            return Err(anyhow!("{name}: static dataflow check failed\n{report}"));
        }
        // Record the verdict so the simulator's runtime-deadlock path
        // can cite the compile-time check instead of re-running the
        // whole analysis.
        machine.meta.insert("static_check".into(), "clean".into());
    }
    Ok(CompiledKernel {
        machine,
        cfg: cfg.clone(),
        plan: Arc::new(plan),
        stats: compiled.stats,
        csl_loc: loc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sources_parse() {
        for (name, _) in sources() {
            parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn registry_covers_every_source_with_a_scaling_recipe() {
        assert_eq!(specs().len(), sources().len());
        for s in specs() {
            let (binds, w, h) =
                s.scaled_binds(4, 8).unwrap_or_else(|e| panic!("{}: {e:#}", s.name));
            assert!(!binds.is_empty(), "{}", s.name);
            assert!(w >= 1 && h >= 1, "{}", s.name);
            if s.sparse {
                // Sparse recipes self-clamp to power-of-two grids ≥ 2
                // and carry the matrix-shaped binds.
                assert!(binds.iter().any(|(k, _)| *k == "NNZP"), "{}", s.name);
                let (_, w3, h3) = s.scaled_binds(3, 8).unwrap();
                assert_eq!((w3, h3), (4, 4), "{}: grid must clamp to a power of two", s.name);
            }
        }
        assert_eq!(dense_names().len(), 6);
        assert!(spec("spmv_rows").unwrap().sparse);
        assert!(spec("nope").is_err());
    }

    #[test]
    fn spada_loc_counts() {
        // Order-of-magnitude agreement with the paper's Table II SpaDA
        // column (broadcast 23, chain 91-ish for 2-D; ours are the 1-D /
        // parameterized forms).
        assert!(spada_loc("broadcast").unwrap() >= 15);
        assert!(spada_loc("chain_reduce").unwrap() >= 30);
    }
}

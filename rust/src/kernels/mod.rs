//! SpaDA kernel library — the paper's evaluated kernels as SpaDA source.
//!
//! Each kernel is an embedded `.spada` file parsed and instantiated on
//! demand; [`KernelSpec`] couples the source with its meta-parameters so
//! the harness, examples and tests share one entry point.

use crate::machine::{MachineConfig, MachineProgram, RoutingPlan, SimError, SimOptions, Simulator};
use crate::passes::{Options, PassStats};
use crate::sem::{instantiate, Bindings};
use crate::spada::{parse_kernel, pretty, Kernel};
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

pub const CHAIN_REDUCE: &str = include_str!("spada/chain_reduce.spada");
pub const BROADCAST: &str = include_str!("spada/broadcast.spada");
pub const TREE_REDUCE: &str = include_str!("spada/tree_reduce.spada");
pub const TWO_PHASE_REDUCE: &str = include_str!("spada/two_phase_reduce.spada");
pub const GEMV: &str = include_str!("spada/gemv.spada");
pub const GEMV_TREE: &str = include_str!("spada/gemv_tree.spada");

/// All named kernels in the library.
pub fn sources() -> Vec<(&'static str, &'static str)> {
    vec![
        ("chain_reduce", CHAIN_REDUCE),
        ("broadcast", BROADCAST),
        ("tree_reduce", TREE_REDUCE),
        ("two_phase_reduce", TWO_PHASE_REDUCE),
        ("gemv", GEMV),
        ("gemv_tree", GEMV_TREE),
    ]
}

pub fn source(name: &str) -> Result<&'static str> {
    sources()
        .into_iter()
        .find(|(n, _)| *n == name)
        .map(|(_, s)| s)
        .ok_or_else(|| anyhow!("unknown kernel {name}"))
}

/// Parse a library kernel.
pub fn parse(name: &str) -> Result<Kernel> {
    let src = source(name)?;
    parse_kernel(src).map_err(|e| anyhow!("{name}: {e}"))
}

/// SpaDA LoC of a library kernel (Table II metric).
pub fn spada_loc(name: &str) -> Result<usize> {
    Ok(pretty::count_loc(&parse(name)?))
}

/// A fully compiled library kernel: the loadable machine program plus
/// the one [`RoutingPlan`] built for it.
///
/// The plan is traced exactly once per compiled kernel and shared by
/// every consumer: the static checker sees it inside [`compile`], the
/// simulator executes from it via [`CompiledKernel::simulator`], and
/// the harness/benches reuse it across runs of the same compilation.
pub struct CompiledKernel {
    pub machine: MachineProgram,
    /// Machine config the kernel was compiled (and the plan built) for.
    pub cfg: MachineConfig,
    /// The shared precompiled routing/execution plan.
    pub plan: Arc<RoutingPlan>,
    pub stats: PassStats,
    /// Generated CSL lines of code (Table II metric).
    pub csl_loc: usize,
}

impl CompiledKernel {
    /// Build a simulator that executes from the shared plan instance —
    /// no route is re-traced. Each call yields a fresh single-shot
    /// simulator over the same compilation, with runtime options
    /// resolved from the environment once (the historical `SPADA_*`
    /// behaviour via [`SimOptions::from_env`]).
    pub fn simulator(&self) -> Result<Simulator, SimError> {
        Simulator::with_plan(self.cfg.clone(), self.machine.clone(), Arc::clone(&self.plan))
    }

    /// Build a simulator with **explicit** runtime options — the
    /// environment is never consulted, so concurrent jobs of one
    /// compiled kernel can run with different thread counts, buffer
    /// capacities, fault plans or watchdogs in the same process (the
    /// batch-fleet path; see [`crate::fleet`]).
    pub fn simulator_with(&self, opts: &SimOptions) -> Result<Simulator, SimError> {
        Simulator::with_plan_opts(
            self.cfg.clone(),
            self.machine.clone(),
            Arc::clone(&self.plan),
            opts,
        )
    }

    /// Approximate resident bytes of this compilation: the routing
    /// plan's dense tables plus a flat per-element estimate of the
    /// machine program (class/route/IO bodies are not walked). This is
    /// what the fleet plan cache charges an entry against its byte
    /// budget ([`crate::machine::CacheBudget`]).
    pub fn approx_bytes(&self) -> u64 {
        self.plan.approx_bytes()
            + self.machine.classes.len() as u64 * 256
            + self.machine.routes.len() as u64 * 64
            + self.machine.io.len() as u64 * 96
            + 1024
    }
}

/// Convenience: parse + instantiate + compile a kernel.
///
/// Unless [`Options::check`] is off, the compiled machine program is
/// verified by the static dataflow semantics checker
/// ([`crate::analysis::check_with_plan`]) — routing correctness, data
/// races, deadlock freedom — before it is handed back ("verify, then
/// lower"). The checker runs against the same [`RoutingPlan`] instance
/// returned in the [`CompiledKernel`], so a checked-and-simulated run
/// traces every route once, not twice.
pub fn compile(
    name: &str,
    binds: &[(&str, i64)],
    cfg: &MachineConfig,
    opts: &Options,
) -> Result<CompiledKernel> {
    let kernel = parse(name)?;
    let bindings: Bindings = binds.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    let prog = instantiate(&kernel, &bindings).context(name.to_string())?;
    let compiled = crate::csl::compile(&prog, cfg, opts).map_err(|e| anyhow!("{name}: {e}"))?;
    let loc = compiled.csl_loc();
    let mut machine = compiled.machine;
    // One plan per compiled kernel; the plan reads only classes/routes,
    // so the meta updates below cannot invalidate it.
    let plan = RoutingPlan::build(&machine, cfg);
    if opts.check {
        let report = crate::analysis::check_with_plan(&machine, cfg, &plan);
        if report.has_errors() {
            return Err(anyhow!("{name}: static dataflow check failed\n{report}"));
        }
        // Record the verdict so the simulator's runtime-deadlock path
        // can cite the compile-time check instead of re-running the
        // whole analysis.
        machine.meta.insert("static_check".into(), "clean".into());
    }
    Ok(CompiledKernel {
        machine,
        cfg: cfg.clone(),
        plan: Arc::new(plan),
        stats: compiled.stats,
        csl_loc: loc,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_sources_parse() {
        for (name, _) in sources() {
            parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn spada_loc_counts() {
        // Order-of-magnitude agreement with the paper's Table II SpaDA
        // column (broadcast 23, chain 91-ish for 2-D; ours are the 1-D /
        // parameterized forms).
        assert!(spada_loc("broadcast").unwrap() >= 15);
        assert!(spada_loc("chain_reduce").unwrap() >= 30);
    }
}
